"""Fault-tolerance demo: train, crash mid-run, resume losslessly from the
atomic checkpoint, then "elastically" restore the same checkpoint as if
the surviving slice had a different topology.

    PYTHONPATH=src python examples/elastic_restart.py
"""
import shutil
import tempfile

import jax
import numpy as np

from repro.checkpoint import latest_step, restore_checkpoint
from repro.configs import base
from repro.models.model_zoo import build_model
from repro.train import TrainConfig, Trainer


def main():
    ckpt_dir = tempfile.mkdtemp(prefix="repro_elastic_")
    cfg = base.get("granite_3_2b").reduced()
    model = build_model(cfg)

    print("=== phase 1: train with an injected failure at step 12 ===")
    t1 = Trainer(model, TrainConfig(
        steps=20, batch=4, seq=32, ckpt_dir=ckpt_dir, ckpt_every=5,
        log_every=5, fail_at_step=12))
    try:
        t1.run()
    except RuntimeError as e:
        print(f"!! {e}")
    print(f"latest durable checkpoint: step {latest_step(ckpt_dir)}")

    print("\n=== phase 2: restart — auto-resume from the checkpoint ===")
    t2 = Trainer(model, TrainConfig(
        steps=20, batch=4, seq=32, ckpt_dir=ckpt_dir, ckpt_every=5,
        log_every=5))
    state, losses = t2.run()
    print(f"resumed and finished at step {int(state['step'])}, "
          f"final loss {losses[-1]:.4f}")

    print("\n=== phase 3: elastic rescale — restore under a new topology ===")
    # the checkpoint is topology-free; here we restore it for a 'smaller
    # slice' (single device) and verify bitwise identity of the params
    like = t2.init_state()
    restored = restore_checkpoint(ckpt_dir, int(state["step"]), like)
    same = all(
        np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(state["params"]),
                        jax.tree.leaves(restored["params"])))
    print(f"params identical after reshard-restore: {same}")
    shutil.rmtree(ckpt_dir, ignore_errors=True)


if __name__ == "__main__":
    main()
