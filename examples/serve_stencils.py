"""Serve stencil workloads through the cached, batched runtime.

Registers two designs (auto-tuned once each), pushes a mixed stream of
requests through the micro-batching server, and prints the per-design
counters — including the design-cache hit a second server observes.

    PYTHONPATH=src python examples/serve_stencils.py
"""
import numpy as np

from repro.core.dsl import parse
from repro.runtime import DesignCache
from repro.serve import StencilRequest, StencilServer

JACOBI = """
kernel: JACOBI2D
iteration: 8
input float: in_1(512, 256)
output float: out_1(0,0) = (in_1(0,1) + in_1(1,0) + in_1(0,0)
    + in_1(0,-1) + in_1(-1,0)) / 5
"""

BLUR = """
kernel: BLUR
iteration: 4
input float: in_1(512, 256)
local float: tmp(0,0) = (in_1(-1,0) + in_1(0,0) + in_1(1,0)) / 3
output float: out_1(0,0) = (tmp(0,-1) + tmp(0,0) + tmp(0,1)) / 3
"""


def main():
    rng = np.random.default_rng(0)
    cache = DesignCache()
    srv = StencilServer(max_batch=4, cache=cache)
    for name, dsl in [("jacobi", JACOBI), ("blur", BLUR)]:
        reg = srv.register(name, dsl)
        cfg = reg.config
        print(f"registered {name!r}: {cfg.variant} (k={cfg.k}, s={cfg.s}), "
              f"build {reg.counters.build_time_s * 1e3:.0f} ms, "
              f"warmup {reg.counters.warmup_time_s * 1e3:.0f} ms")

    def req(design):
        spec = srv.design(design).spec
        return StencilRequest(design, {
            n: rng.standard_normal(shape).astype(dt)
            for n, (dt, shape) in spec.inputs.items()
        })

    stream = [req("jacobi"), req("blur"), req("jacobi"), req("jacobi"),
              req("blur"), req("jacobi"), req("jacobi")]
    outs = srv.serve(stream)
    print(f"\nserved {len(outs)} requests; per-design counters:")
    for name, st in srv.stats().items():
        if name == "_cache":
            print(f"  cache: {st['hits']} hits / {st['misses']} misses "
                  f"({st['entries']} entries)")
        else:
            print(f"  {name}: {st['requests']} grids in {st['batches']} "
                  f"batches (+{st['padded_grids']} pad), "
                  f"mean dispatch {st['exec_mean_s'] * 1e3:.1f} ms")

    # a second server sharing the cache skips ranking and jitting entirely
    srv2 = StencilServer(max_batch=4, cache=cache)
    reg2 = srv2.register("jacobi", JACOBI)
    print(f"\nsecond server register('jacobi'): cache_hit="
          f"{reg2.counters.cache_hit}, build {reg2.counters.build_time_s:.3f} s")


if __name__ == "__main__":
    main()
