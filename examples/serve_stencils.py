"""Serve stencil workloads through the cached, batched, bucketed runtime.

Part 1 registers two exact-shape designs (auto-tuned once each), pushes a
mixed stream of requests through the micro-batching server, and prints
the per-design counters — including the design-cache hit a second server
observes.

Part 2 is the multi-geometry path: ONE bucketed registration serves a
trace of many distinct grid shapes.  Requests are routed to padded
canonical bucket shapes (powers of two here), one masked design is
compiled per bucket actually hit, and grids of different sizes sharing a
bucket ride the same micro-batch.  The bucket-ladder policy trades
compile time against padded compute: coarser rungs -> fewer compiled
designs but more wasted padding FLOPs/bytes (up to ~4x for a 2-D grid
just past a rung); a finer `ShapeBucketer(ladder=...)` caps the waste at
the cost of more designs.  Dispatch is async double-buffered: the host
stages micro-batch N+1 while the device executes micro-batch N.

Part 3 serves the full boundary matrix through bucketing: replicate-edge
image filters (streamed halo-index gathers re-impose the clamped edge
in-kernel) and a periodic torus kernel (the wrapped extension of each
real grid is host-streamed into the bucket's halo margin) share the same
bucketed micro-batch loop as the zero-boundary traffic — one logical
registration per kernel, any feasible geometry.

Part 4 is the warm restart: a server pointed at a persistent store
directory (`store_dir=`) writes its tuned rankings and AOT-serialized
executables through to disk, and a "restarted" server (fresh cache, same
directory) reaches its first bitwise-identical result without ranking a
single candidate or compiling a single program.  The subprocess version
of this claim — with its >= 10x cold-start gate — is
`benchmarks/cold_start.py`.

    PYTHONPATH=src python examples/serve_stencils.py
"""
import tempfile
import time

import numpy as np

from repro.runtime import DesignCache
from repro.serve import StencilRequest, StencilServer

JACOBI = """
kernel: JACOBI2D
iteration: 8
input float: in_1(512, 256)
output float: out_1(0,0) = (in_1(0,1) + in_1(1,0) + in_1(0,0)
    + in_1(0,-1) + in_1(-1,0)) / 5
"""

BLUR = """
kernel: BLUR
iteration: 4
input float: in_1(512, 256)
local float: tmp(0,0) = (in_1(-1,0) + in_1(0,0) + in_1(1,0)) / 3
output float: out_1(0,0) = (tmp(0,-1) + tmp(0,0) + tmp(0,1)) / 3
"""


def exact_shape_demo(rng):
    print("== exact-shape serving (one design per registered geometry) ==")
    cache = DesignCache()
    srv = StencilServer(max_batch=4, cache=cache)
    for name, dsl in [("jacobi", JACOBI), ("blur", BLUR)]:
        reg = srv.register(name, dsl)
        cfg = reg.config
        print(f"registered {name!r}: {cfg.variant} (k={cfg.k}, s={cfg.s}), "
              f"build {reg.counters.build_time_s * 1e3:.0f} ms, "
              f"warmup {reg.counters.warmup_time_s * 1e3:.0f} ms")

    def req(design):
        spec = srv.design(design).spec
        return StencilRequest(design, {
            n: rng.standard_normal(shape).astype(dt)
            for n, (dt, shape) in spec.inputs.items()
        })

    stream = [req("jacobi"), req("blur"), req("jacobi"), req("jacobi"),
              req("blur"), req("jacobi"), req("jacobi")]
    outs = srv.serve(stream)
    print(f"\nserved {len(outs)} requests; per-design counters:")
    for name, st in srv.stats().items():
        if name == "_cache":
            print(f"  cache: {st['hits']} hits / {st['misses']} misses "
                  f"({st['entries']} entries)")
        else:
            print(f"  {name}: {st['requests']} grids in {st['batches']} "
                  f"batches (+{st['padded_grids']} pad), "
                  f"mean dispatch {st['exec_mean_s'] * 1e3:.1f} ms")

    # a second server sharing the cache skips ranking and jitting entirely
    srv2 = StencilServer(max_batch=4, cache=cache)
    reg2 = srv2.register("jacobi", JACOBI)
    print(f"\nsecond server register('jacobi'): cache_hit="
          f"{reg2.counters.cache_hit}, build "
          f"{reg2.counters.build_time_s:.3f} s")


def bucketed_demo(rng):
    print("\n== bucketed serving (one registration, many geometries) ==")
    cache = DesignCache()
    srv = StencilServer(max_batch=4, cache=cache, bucketing=True)
    reg = srv.register("jacobi", JACOBI)
    print(f"registered 'jacobi' as a logical kernel "
          f"(warm bucket: {sorted(reg.cached.buckets)})")

    # a mixed-shape request trace: distinct geometries, few buckets
    shapes = [(512, 256), (300, 200), (257, 129), (120, 80), (500, 250),
              (260, 140), (100, 33), (444, 222), (65, 65), (512, 256)]
    reqs = [
        StencilRequest("jacobi", {
            "in_1": rng.standard_normal(s).astype(np.float32)
        })
        for s in shapes
    ]
    outs = srv.serve(reqs)
    assert all(o.shape == s for o, s in zip(outs, shapes))
    st = srv.stats()["jacobi"]
    print(f"served {len(shapes)} grids of {len(set(shapes))} distinct "
          f"shapes in {st['batches']} micro-batches from "
          f"{st['compiled_buckets']} compiled bucket designs:")
    for bucket, bst in sorted(st["buckets"].items()):
        print(f"  bucket {bucket}: {bst['requests']} grids, "
              f"{bst['hits']} hits / {bst['misses']} compiles "
              f"(build {bst['build_time_s'] * 1e3:.0f} ms)")
    print("bucket-ladder policy: powers of two per dim -> few designs, "
          "padded compute; pass ShapeBucketer(ladder=...) to trade the "
          "other way")


BLUR_REPLICATE = """
kernel: BLUR-REPLICATE
iteration: 4
boundary: replicate
input float: in_1(128, 96)
output float: out_1(0,0) = (in_1(-1,-1) + in_1(-1,0) + in_1(-1,1)
    + in_1(0,-1) + in_1(0,0) + in_1(0,1)
    + in_1(1,-1) + in_1(1,0) + in_1(1,1)) / 9
"""

HEAT_PERIODIC = """
kernel: HEAT2D-PERIODIC
iteration: 4
boundary: periodic
input float: in_1(128, 96)
output float: out_1(0,0) = in_1(0,0) + 0.125 * (in_1(1,0) + in_1(-1,0)
    + in_1(0,1) + in_1(0,-1) - 4 * in_1(0,0))
"""


def boundary_demo(rng):
    print("\n== bucketed serving across the full boundary matrix ==")
    srv = StencilServer(max_batch=4, cache=DesignCache(), bucketing=True)
    srv.register("blur_rep", BLUR_REPLICATE)
    srv.register("heat_per", HEAT_PERIODIC)
    shapes = [(128, 96), (90, 70), (128, 128), (50, 40)]
    reqs = [
        StencilRequest(design, {
            "in_1": rng.standard_normal(s).astype(np.float32)
        })
        for s in shapes for design in ("blur_rep", "heat_per")
    ]
    outs = srv.serve(reqs)
    assert all(o.shape == r.arrays["in_1"].shape
               for o, r in zip(outs, reqs))
    for name, note in [
        ("blur_rep", "replicate edges via streamed halo-index gathers"),
        ("heat_per", "periodic torus via host-streamed wrap margins"),
    ]:
        st = srv.stats()[name]
        print(f"  {name} ({note}): {st['requests']} grids, "
              f"{st['compiled_buckets']} bucket design(s) "
              f"{sorted(st['buckets'])}")
    print("every request carries its own streamed boundary inputs, so "
          "mixed-boundary traffic shares the async micro-batch loop")


def warm_restart_demo(rng):
    print("\n== persistent store (warm restart from disk) ==")
    grid = {"in_1": rng.standard_normal((512, 256)).astype(np.float32)}

    def replica(store_dir):
        # a fresh StencilServer + DesignCache each time — only the store
        # directory survives, exactly like a server process restarting
        t0 = time.perf_counter()
        srv = StencilServer(max_batch=4, store_dir=store_dir)
        srv.register("jacobi", JACOBI)
        out = srv.serve([StencilRequest("jacobi", dict(grid))])[0]
        dt = time.perf_counter() - t0
        srv.persist_telemetry()
        return srv, out, dt

    with tempfile.TemporaryDirectory() as td:
        srv1, out1, cold_s = replica(td)
        st1 = srv1.stats()["_cache"]
        print(f"cold replica: first result in {cold_s * 1e3:.0f} ms "
              f"(autotune_calls={st1['autotune_calls']}, "
              f"jit_builds={st1['jit_builds']})")

        srv2, out2, warm_s = replica(td)
        st2 = srv2.stats()["_cache"]
        print(f"warm restart: first result in {warm_s * 1e3:.0f} ms "
              f"(autotune_calls={st2['autotune_calls']}, "
              f"jit_builds={st2['jit_builds']}, "
              f"store_hits={st2['store_hits']}) — "
              f"{cold_s / warm_s:.1f}x faster")
        assert np.array_equal(out1, out2), "warm restart must be bitwise"
        print(f"store: {srv2.stats()['_store']}")
        print("outputs bitwise-identical: the warm replica replays the "
              "very executable the cold one compiled; inspect the store "
              "with `python -m repro.store list <dir>`")


def main():
    rng = np.random.default_rng(0)
    exact_shape_demo(rng)
    bucketed_demo(rng)
    boundary_demo(rng)
    warm_restart_demo(rng)


if __name__ == "__main__":
    main()
