"""HEAT3D on a simulated 8-chip slice: auto-tuned hybrid parallelism with
ppermute border streaming, validated against the single-device oracle.

Forces 8 host devices, so run it as its own process:

    PYTHONPATH=src python examples/stencil_multidevice.py
"""
import os

os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                           + os.environ.get("XLA_FLAGS", ""))

import time  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import stencils  # noqa: E402
from repro.core import autotune, model  # noqa: E402
from repro.core.distribute import build_runner  # noqa: E402
from repro.kernels import ref  # noqa: E402


def main():
    print(f"devices: {jax.device_count()}")
    spec = stencils.heat3d(shape=(256, 16, 16), iterations=8)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal(spec.shape).astype(np.float32))

    design = autotune(spec)
    print(f"auto-tuned: {design.config.variant} k={design.config.k} "
          f"s={design.config.s} (predicted "
          f"{design.prediction.latency * 1e6:.1f} us on v5e slice)")
    out = design.runner({"in_1": x})
    want = np.asarray(ref.stencil_iterations_ref(spec, {"in_1": x}))
    print(f"max |err| vs oracle: {np.abs(out - want).max():.2e}")

    print("\nmeasured on this host (8 forced devices):")
    for cfg in [model.ParallelismConfig("spatial_s", k=8, s=1),
                model.ParallelismConfig("hybrid_s", k=4, s=2),
                model.ParallelismConfig("hybrid_r", k=2, s=4),
                model.ParallelismConfig("temporal", k=1, s=8)]:
        run = build_runner(spec, cfg, tile_rows=32)
        run({"in_1": x})  # compile
        t0 = time.perf_counter()
        out = run({"in_1": x})
        dt = time.perf_counter() - t0
        ok = np.allclose(out, want, atol=2e-4)
        print(f"  {cfg.variant:10s} k={cfg.k} s={cfg.s}: {dt * 1e3:7.1f} ms "
              f"correct={ok}")


if __name__ == "__main__":
    main()
