"""End-to-end training driver: train an LM with the full production stack
(data pipeline, optimizer, async checkpointing, fault-tolerant trainer).

    PYTHONPATH=src python examples/train_lm.py --preset small   # ~2 min CPU
    PYTHONPATH=src python examples/train_lm.py --preset 100m --steps 300

The 100m preset is a ~100M-parameter internlm2-family config; on this
CPU-only container one step takes tens of seconds, so the committed
EXPERIMENTS.md run uses --preset small (10M params, 200 steps) plus a
short 100m demonstration.
"""
import argparse
import dataclasses

import jax

from repro.configs import base
from repro.models.model_zoo import build_model
from repro.train import TrainConfig, Trainer

PRESETS = {
    # (d_model, n_layers, n_heads, n_kv, d_ff, vocab, batch, seq)
    "tiny": (64, 2, 4, 2, 128, 512, 4, 64),
    "small": (256, 4, 4, 2, 1024, 4096, 8, 128),
    "100m": (768, 12, 12, 4, 2048, 16384, 8, 256),
}


def make_cfg(preset: str):
    d, L, h, kv, f, v, b, s = PRESETS[preset]
    cfg = dataclasses.replace(
        base.get("internlm2_1_8b"),
        name=f"lm-{preset}", n_layers=L, d_model=d, n_heads=h,
        n_kv_heads=kv, d_head=d // h, d_ff=f, vocab=v,
        act_dtype="float32", remat="none",
    )
    return cfg, b, s


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="small", choices=list(PRESETS))
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--lr", type=float, default=3e-3)
    args = ap.parse_args()

    cfg, batch, seq = make_cfg(args.preset)
    model = build_model(cfg)
    params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    n = sum(int(x.size) for x in jax.tree.leaves(params))
    print(f"preset={args.preset}: {n / 1e6:.1f}M params, "
          f"batch={batch} seq={seq}, {args.steps} steps")

    trainer = Trainer(model, TrainConfig(
        steps=args.steps, batch=batch, seq=seq, lr=args.lr,
        warmup=max(args.steps // 20, 5), ckpt_dir=args.ckpt_dir,
        ckpt_every=max(args.steps // 4, 10), log_every=10))
    state, losses = trainer.run()
    print(f"final loss {losses[-1]:.4f} (start {losses[0]:.4f}); "
          f"checkpoints in {args.ckpt_dir}")


if __name__ == "__main__":
    main()
