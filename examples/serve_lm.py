"""Batched serving example: prefill + KV-cache decode with the ServeEngine.

    PYTHONPATH=src python examples/serve_lm.py
"""
import time

import jax
import numpy as np

from repro.configs import base
from repro.models.model_zoo import build_model
from repro.serve.engine import Request, ServeEngine


def main():
    cfg = base.get("recurrentgemma_2b").reduced()  # hybrid: RG-LRU + local
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServeEngine(model, params, batch_size=4, cache_len=96)

    rng = np.random.default_rng(0)
    requests = [
        Request(prompt=rng.integers(1, cfg.vocab, size=n).astype(np.int32),
                max_new_tokens=24)
        for n in (12, 7, 19, 4)
    ]
    t0 = time.perf_counter()
    outs = engine.generate(requests)
    dt = time.perf_counter() - t0
    total_new = sum(len(o) for o in outs)
    print(f"arch={cfg.name}: generated {total_new} tokens for "
          f"{len(requests)} requests in {dt:.2f}s "
          f"({total_new / dt:.1f} tok/s incl. compile)")
    for i, o in enumerate(outs):
        print(f"  req{i} ({len(requests[i].prompt)} prompt toks) -> "
              f"{o[:10].tolist()}{'...' if len(o) > 10 else ''}")

    # steady-state decode throughput (cache warm, jit compiled)
    t0 = time.perf_counter()
    outs = engine.generate(requests)
    dt = time.perf_counter() - t0
    print(f"warm: {sum(len(o) for o in outs) / dt:.1f} tok/s")


if __name__ == "__main__":
    main()
