"""Quickstart: write a stencil in the SASA DSL, let the framework pick the
best parallelism, and run it.

    PYTHONPATH=src python examples/quickstart.py
"""
import time

import jax.numpy as jnp
import numpy as np

from repro.core import autotune, soda_baseline
from repro.kernels import ref

DSL = """
kernel: JACOBI2D
iteration: 8
input float: in_1(1024, 512)
output float: out_1(0,0) = (in_1(0,1) + in_1(1,0) + in_1(0,0)
    + in_1(0,-1) + in_1(-1,0)) / 5
"""


def main():
    design = autotune(DSL)
    cfg = design.config
    print(f"kernel:        {design.spec.name} "
          f"({design.spec.points}-point, r={design.spec.radius})")
    print(f"chosen design: {cfg.variant} (spatial k={cfg.k}, "
          f"temporal s={cfg.s})")
    print(f"predicted:     {design.prediction.latency * 1e6:.1f} us/run, "
          f"bottleneck={design.prediction.bottleneck}")
    print("top-5 candidates:")
    for p in design.ranking[:5]:
        print(f"  {p.config.variant:10s} k={p.config.k:2d} s={p.config.s:2d} "
              f"-> {p.latency * 1e6:8.1f} us ({p.bottleneck}-bound)")

    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((1024, 512)).astype(np.float32))
    t0 = time.perf_counter()
    out = design.runner({"in_1": x})
    dt = time.perf_counter() - t0
    want = np.asarray(ref.stencil_iterations_ref(design.spec, {"in_1": x}))
    err = float(np.abs(out - want).max())
    print(f"\nexecuted in {dt * 1e3:.1f} ms (first call includes compile); "
          f"max |err| vs oracle = {err:.2e}")

    base = soda_baseline(DSL)
    print(f"\nSODA baseline (temporal-only): s={base.config.s}, predicted "
          f"{base.prediction.latency * 1e6:.1f} us "
          f"-> SASA predicted speedup "
          f"{base.prediction.latency / design.prediction.latency:.2f}x")

    # what the tuner would pick on a real 8-chip v5e slice (plan only —
    # this host has a single device, so spatial variants aren't built)
    from repro.core.platform import DEFAULT_TPU
    slice8 = autotune(DSL, platform=DEFAULT_TPU.with_chips(8), build=False)
    sbase = soda_baseline(DSL, platform=DEFAULT_TPU.with_chips(8),
                          build=False)
    c = slice8.config
    print(f"\non an 8-chip v5e slice the tuner picks: {c.variant} "
          f"(k={c.k}, s={c.s}), predicted speedup over SODA "
          f"{sbase.prediction.latency / slice8.prediction.latency:.2f}x")


if __name__ == "__main__":
    main()
