"""Production training launcher.

Selects an architecture config (``--arch``), builds the sharding plan for
the available mesh, and runs the fault-tolerant trainer.  On this CPU
container it is exercised with reduced configs; on a real pod the same
entry point runs the full config (the dry-run proves every cell lowers
and compiles on the 16x16 / 2x16x16 meshes).

    PYTHONPATH=src python -m repro.launch.train --arch granite_3_2b \
        --reduced --steps 50 --batch 8 --seq 128
"""
from __future__ import annotations

import argparse

import jax

from repro.configs import base as config_base
from repro.launch import sharding as shlib
from repro.launch.mesh import batch_axes
from repro.models import transformer as T
from repro.models.model_zoo import build_model
from repro.train import TrainConfig, Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True,
                    choices=config_base.all_archs())
    ap.add_argument("--reduced", action="store_true",
                    help="use the smoke-test-sized config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--data-axis", type=int, default=0,
                    help="mesh data-axis size (0 = all devices)")
    ap.add_argument("--model-axis", type=int, default=1)
    ap.add_argument("--compress-grads", action="store_true")
    args = ap.parse_args()

    cfg = config_base.get(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)

    n_dev = jax.device_count()
    data_ax = args.data_axis or max(n_dev // args.model_axis, 1)
    mesh = None
    batch_spec = ()
    if n_dev > 1:
        mesh = jax.make_mesh((data_ax, args.model_axis), ("data", "model"))
        plan = shlib.DEFAULT_PLAN
        T.set_mesh_rules(mesh, {**plan.act_rule_map(mesh),
                                "batch": batch_axes(mesh)})
        batch_spec = ("data",)
        print(f"mesh: {dict(mesh.shape)}")

    trainer = Trainer(model, TrainConfig(
        steps=args.steps, batch=args.batch, seq=args.seq, lr=args.lr,
        warmup=max(args.steps // 20, 2), ckpt_dir=args.ckpt_dir,
        compress_grads=args.compress_grads,
        log_every=max(args.steps // 20, 1)), mesh=mesh,
        batch_spec=batch_spec)
    state, losses = trainer.run()
    print(f"done: arch={cfg.name} steps={int(state['step'])} "
          f"loss {losses[0]:.4f} -> {losses[-1]:.4f}")


if __name__ == "__main__":
    main()
