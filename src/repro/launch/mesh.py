"""Production mesh construction.

Defined as functions (never module-level constants) so importing this
module never touches JAX device state — required because the dry-run
forces 512 host devices via XLA_FLAGS before first JAX init, while smoke
tests and benchmarks must keep the default single device.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; multi-pod adds a leading 2-pod axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 2, model: int = 4):
    """Small mesh over forced host devices for integration tests."""
    return jax.make_mesh((data, model), ("data", "model"))


def batch_axes(mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)
