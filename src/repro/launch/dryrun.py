import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST be the first two lines: jax locks the device count on first init.
# The dry-run (and only the dry-run) builds the 512-chip production mesh on
# CPU placeholder devices; tests/benches import other modules and see 1.
if os.environ.get("REPRO_DRYRUN_DEVICES"):  # test hook (set before import)
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count="
        + os.environ["REPRO_DRYRUN_DEVICES"])

# Multi-pod dry-run: lower + compile every (architecture x input-shape x
# mesh) cell; record memory analysis, cost analysis, and the collective
# schedule for the roofline table (EXPERIMENTS.md §Dry-run / §Roofline).
#
# Usage:
#   PYTHONPATH=src python -m repro.launch.dryrun --arch granite_3_8b \
#       --shape train_4k [--multi-pod]
#   PYTHONPATH=src python -m repro.launch.dryrun --all  # every cell, cached

import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import base as config_base
from repro.data.pipeline import make_batch_specs
from repro.launch import sharding as shlib
from repro.launch.mesh import batch_axes, make_production_mesh
from repro.models import transformer as T
from repro.models.model_zoo import build_model
from repro.optim import make_optimizer
from repro.roofline import roofline_from_compiled

SHAPES = {
    # name: (seq_len, global_batch, kind)
    "train_4k": (4096, 256, "train"),
    "prefill_32k": (32768, 32, "prefill"),
    "decode_32k": (32768, 128, "decode"),
    "long_500k": (524288, 1, "decode"),
}

RESULTS_PATH = "dryrun_results.json"


def applicable(cfg, shape_name: str) -> tuple[bool, str]:
    if shape_name == "long_500k" and not cfg.sub_quadratic:
        return False, ("full-attention arch: 500k decode KV would be "
                       "quadratic-prefill-gated; skipped per docs/DESIGN.md "
                       "§Arch-applicability")
    return True, ""


def input_specs(cfg, shape_name: str, mesh):
    """ShapeDtypeStruct stand-ins for every model input of the cell."""
    seq, gbatch, kind = SHAPES[shape_name]
    ba = batch_axes(mesh)
    if kind == "train":
        specs = make_batch_specs(cfg, gbatch, seq, batch_axes=ba)
        structs = {k: v[0] for k, v in specs.items()}
        shardings = {k: NamedSharding(
            mesh, shlib.guard_spec(v[0].shape, v[1], mesh))
            for k, v in specs.items()}
        return structs, shardings
    if kind == "prefill":
        specs = make_batch_specs(cfg, gbatch, seq, batch_axes=ba)
        structs = {k: v[0] for k, v in specs.items()
                   if k != "labels"}
        shardings = {k: NamedSharding(
            mesh, shlib.guard_spec(specs[k][0].shape, specs[k][1], mesh))
            for k in structs}
        return structs, shardings
    # decode: one new token against a seq-length cache
    structs = {
        "tokens": jax.ShapeDtypeStruct((gbatch, 1), jnp.int32),
        "pos": jax.ShapeDtypeStruct((gbatch,), jnp.int32),
    }
    shardings = {
        "tokens": NamedSharding(mesh, shlib.guard_spec(
            (gbatch, 1), P(ba, None), mesh)),
        "pos": NamedSharding(mesh, shlib.guard_spec((gbatch,), P(ba), mesh)),
    }
    return structs, shardings


def model_flops_estimate(cfg, shape_name: str) -> float:
    """MODEL_FLOPS = 6*N_active*D tokens (train) or 2*N*D (inference)."""
    seq, gbatch, kind = SHAPES[shape_name]
    n = cfg.params_active_estimate
    if kind == "train":
        return 6.0 * n * seq * gbatch
    if kind == "prefill":
        return 2.0 * n * seq * gbatch
    return 2.0 * n * 1 * gbatch


@dataclasses.dataclass
class CellResult:
    arch: str
    shape: str
    mesh: str
    status: str                  # ok | skipped | failed
    reason: str = ""
    seconds: float = 0.0
    report: dict | None = None
    hlo_dump: str = ""           # gzipped HLO text (offline re-analysis)


def lower_cell(arch: str, shape_name: str, *, multi_pod=False,
               plan: shlib.Plan = shlib.DEFAULT_PLAN,
               cfg_overrides: dict | None = None,
               verbose=True) -> CellResult:
    mesh_desc = "2x16x16" if multi_pod else "16x16"
    cfg = config_base.get(arch)
    if cfg_overrides:
        cfg = dataclasses.replace(cfg, **cfg_overrides)
    ok, reason = applicable(cfg, shape_name)
    if not ok:
        return CellResult(arch, shape_name, mesh_desc, "skipped", reason)
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = int(np.prod(list(mesh.shape.values())))
    model = build_model(cfg)
    seq, gbatch, kind = SHAPES[shape_name]

    params_struct = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    if kind != "train":
        # serving deployments ship bf16 weights (fp32 masters are a
        # training-only artifact); halves parameter HBM for decode cells
        params_struct = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(s.shape, jnp.bfloat16)
            if s.dtype == jnp.float32 and s.ndim >= 2 else s,
            params_struct)
    params_sh = shlib.param_shardings(model, params_struct, mesh, plan)
    structs, input_sh = input_specs(cfg, shape_name, mesh)
    ba = batch_axes(mesh)
    act_rules = plan.act_rule_map(mesh, seq_shard=(kind != "decode"))
    act_rules["batch"] = ba

    T.set_mesh_rules(mesh, act_rules)
    try:
        if kind == "train":
            opt = make_optimizer(cfg.optimizer, total_steps=1000)
            opt_struct = jax.eval_shape(opt.init, params_struct)
            opt_sh = shlib.mirror_opt_shardings(params_sh, opt_struct, mesh)
            M = max(cfg.microbatches, 1)

            def train_step(params, opt_state, batch, step):
                if M == 1:
                    loss, grads = jax.value_and_grad(model.loss)(
                        params, batch)
                else:
                    # gradient accumulation: activations live for one
                    # microbatch at a time; fp32 grads accumulate
                    mb = jax.tree.map(
                        lambda a: a.reshape((M, a.shape[0] // M)
                                            + a.shape[1:]), batch)

                    def one(acc, mbatch):
                        l, g = jax.value_and_grad(model.loss)(
                            params, mbatch)
                        acc = jax.tree.map(
                            lambda x, y: x + y.astype(jnp.float32),
                            acc[0], g), acc[1] + l
                        return acc, None

                    g0 = jax.tree.map(
                        lambda p: jnp.zeros(p.shape, jnp.float32),
                        params)
                    (gsum, lsum), _ = jax.lax.scan(one, (g0, 0.0), mb)
                    grads = jax.tree.map(lambda g: g / M, gsum)
                    loss = lsum / M
                new_p, new_o = opt.update(grads, opt_state, params, step)
                return new_p, new_o, loss

            step_struct = jax.ShapeDtypeStruct((), jnp.int32)
            jitted = jax.jit(
                train_step,
                in_shardings=(params_sh, opt_sh, input_sh, None),
                out_shardings=(params_sh, opt_sh, None),
                donate_argnums=(0, 1),
            )
            lowered = jitted.lower(params_struct, opt_struct, structs,
                                   step_struct)
        elif kind == "prefill":
            jitted = jax.jit(
                lambda p, b: model.prefill(p, b)[0],
                in_shardings=(params_sh, input_sh),
            )
            lowered = jitted.lower(params_struct, structs)
        else:  # decode
            cache_struct = jax.eval_shape(
                lambda: model.init_cache(gbatch, seq))
            cache_sh = shlib.cache_shardings(cache_struct, mesh, ba)
            extra = {}
            extra_sh = {}
            if cfg.enc_layers:
                n_enc = max(seq // 4, 8) if shape_name != "long_500k" else 8192
                extra["enc_out"] = jax.ShapeDtypeStruct(
                    (gbatch, n_enc, cfg.d_model), jnp.dtype(cfg.act_dtype))
                extra["enc_positions"] = jax.ShapeDtypeStruct(
                    (gbatch, n_enc), jnp.int32)
                extra_sh["enc_out"] = NamedSharding(mesh, shlib.guard_spec(
                    extra["enc_out"].shape, P(ba, "model", None), mesh))
                extra_sh["enc_positions"] = NamedSharding(
                    mesh, shlib.guard_spec(extra["enc_positions"].shape,
                                           P(ba, "model"), mesh))

            def serve_step(params, tokens, caches, pos, **kw):
                return model.decode_step(params, tokens, caches, pos, **kw)

            jitted = jax.jit(
                serve_step,
                in_shardings=(params_sh, input_sh["tokens"], cache_sh,
                              input_sh["pos"]) +
                             ((extra_sh["enc_out"], extra_sh["enc_positions"])
                              if extra else ()),
                donate_argnums=(2,),
            )
            args = (params_struct, structs["tokens"], cache_struct,
                    structs["pos"])
            if extra:
                jitted = jax.jit(
                    lambda p, t, c, q, eo, ep: model.decode_step(
                        p, t, c, q, enc_out=eo, enc_positions=ep),
                    in_shardings=(params_sh, input_sh["tokens"], cache_sh,
                                  input_sh["pos"], extra_sh["enc_out"],
                                  extra_sh["enc_positions"]),
                    donate_argnums=(2,),
                )
                args = args + (extra["enc_out"], extra["enc_positions"])
            lowered = jitted.lower(*args)

        compiled = lowered.compile()
        mem = compiled.memory_analysis()
        if verbose:
            print(f"[{arch}/{shape_name}/{mesh_desc}] memory_analysis:",
                  mem)
            ca = compiled.cost_analysis()
            if isinstance(ca, list):
                ca = ca[0]
            print(f"[{arch}/{shape_name}/{mesh_desc}] cost_analysis: "
                  f"flops={ca.get('flops', 0):.3e} "
                  f"bytes={ca.get('bytes accessed', 0):.3e}")
        report = roofline_from_compiled(
            compiled, arch=arch, shape=shape_name, mesh_desc=mesh_desc,
            chips=chips, model_flops=model_flops_estimate(cfg, shape_name))
        dump = _dump_hlo(compiled, f"{arch}_{shape_name}_{mesh_desc}_"
                         f"{plan.name}")
        return CellResult(arch, shape_name, mesh_desc, "ok",
                          seconds=time.time() - t0,
                          report=report.to_dict(), hlo_dump=dump)
    finally:
        T.clear_mesh_rules()


def _dump_hlo(compiled, tag: str) -> str:
    import gzip
    import re as _re
    d = os.environ.get("REPRO_HLO_DUMP_DIR", "hlo_dumps")
    os.makedirs(d, exist_ok=True)
    path = os.path.join(d, _re.sub(r"[^A-Za-z0-9_.-]", "_", tag) + ".txt.gz")
    try:
        with gzip.open(path, "wt") as f:
            f.write(compiled.as_text())
        # memory analysis summary rides along for offline re-analysis
        mem = compiled.memory_analysis()
        with open(path + ".mem.json", "w") as f:
            json.dump({
                "arguments": int(getattr(mem, "argument_size_in_bytes", 0)),
                "outputs": int(getattr(mem, "output_size_in_bytes", 0)),
                "temps": int(getattr(mem, "temp_size_in_bytes", 0)),
                "peak": int(getattr(mem, "peak_memory_in_bytes", 0) or 0),
            }, f)
    except OSError:
        return ""
    return path


def run_all(archs=None, shapes=None, meshes=(False, True),
            results_path=RESULTS_PATH):
    archs = archs or config_base.all_archs()
    shapes = shapes or list(SHAPES)
    try:
        with open(results_path) as f:
            results = json.load(f)
    except FileNotFoundError:
        results = {}
    for multi_pod in meshes:
        for arch in archs:
            for shape in shapes:
                key = f"{arch}|{shape}|{'2x16x16' if multi_pod else '16x16'}"
                if key in results and results[key]["status"] in ("ok", "skipped"):
                    continue
                print(f"=== {key} ===", flush=True)
                try:
                    res = lower_cell(arch, shape, multi_pod=multi_pod)
                except Exception as e:
                    traceback.print_exc()
                    res = CellResult(arch, shape,
                                     "2x16x16" if multi_pod else "16x16",
                                     "failed", reason=f"{type(e).__name__}: {e}")
                results[key] = dataclasses.asdict(res)
                with open(results_path, "w") as f:
                    json.dump(results, f, indent=1)
                print(f"--- {key}: {res.status} ({res.seconds:.1f}s) "
                      f"{res.reason}", flush=True)
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--results", default=RESULTS_PATH)
    ap.add_argument("--plan", default="baseline",
                    choices=list(shlib.PLAN_VARIANTS))
    ap.add_argument("--override", action="append", default=[],
                    help="cfg override key=value (e.g. kv_block=2048)")
    args = ap.parse_args()
    overrides = {}
    for ov in args.override:
        k, v = ov.split("=", 1)
        try:
            v = int(v)
        except ValueError:
            pass
        overrides[k] = v
    if args.all:
        run_all(results_path=args.results,
                archs=[args.arch] if args.arch else None,
                shapes=[args.shape] if args.shape else None)
        return
    try:
        res = lower_cell(args.arch, args.shape, multi_pod=args.multi_pod,
                         plan=shlib.PLAN_VARIANTS[args.plan],
                         cfg_overrides=overrides or None)
    except Exception as e:
        traceback.print_exc()
        res = CellResult(args.arch, args.shape,
                         "2x16x16" if args.multi_pod else "16x16",
                         "failed", reason=f"{type(e).__name__}: {e}")
    print(json.dumps(dataclasses.asdict(res), indent=2))
    # merge into the results cache so per-cell subprocess driving works
    try:
        with open(args.results) as f:
            results = json.load(f)
    except FileNotFoundError:
        results = {}
    key = f"{args.arch}|{args.shape}|{res.mesh}"
    if args.plan != "baseline" or overrides:
        key += f"|{args.plan}" + (
            "|" + ";".join(f"{k}={v}" for k, v in overrides.items())
            if overrides else "")
    results[key] = dataclasses.asdict(res)
    with open(args.results, "w") as f:
        json.dump(results, f, indent=1)


if __name__ == "__main__":
    main()
