"""Sharding plans: logical param axes -> mesh PartitionSpecs + activation
constraint rules.

Strategy (arch-universal; the same mechanism the SASA auto-tuner uses for
stencils is applied here — a declarative plan evaluated per workload):

  * Parameter storage is FSDP/ZeRO-3: the "embed"-like dim of every weight
    shards over "data"; expert and vocab/head dims shard over "model"
    (EP / TP) *when divisible* — a per-shape guard drops any axis whose
    dim is not divisible by the mesh axis (jit arguments must be evenly
    sharded; XLA handles uneven shapes only inside the program).
  * Compute parallelism comes from activation constraints (heads / mlp /
    vocab / sequence over "model"), which tolerate uneven dims — GSPMD
    pads internally.  So yi-34b's 56 heads still compute 16-way TP even
    though its weights store FSDP-only.
  * The residual stream is sequence-sharded over "model" between layers
    (Megatron-SP): scan-carried activations shrink 16x, which is what
    keeps 40-60 layer models inside 16 GB HBM at global batch 256 x 4 k.
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class Plan:
    """Maps logical axes to mesh axes.  None = replicate."""

    name: str = "fsdp_tp"
    rules: tuple = (
        ("vocab", "model"),
        ("embed", ("data", "pod")),   # FSDP over data AND pod (multi-pod
                                      # halves per-chip master params;
                                      # guard drops "pod" on 1-pod meshes)
        ("heads", "model"),
        ("kv", "model"),
        ("head_dim", None),
        ("mlp", "model"),
        ("mlp2", None),
        ("expert", "model"),
        ("layers", None),
    )
    # activation constraints (uneven-tolerant)
    act_rules: tuple = (
        ("batch", ("pod", "data")),
        ("heads", "model"),
        ("mlp", "model"),
        ("vocab", "model"),
        ("seq", "model"),
        ("expert", "model"),
    )

    def rule(self, axis):
        return dict(self.rules).get(axis)

    def act_rule_map(self, mesh, *, seq_shard=True):
        m = dict(self.act_rules)
        if not seq_shard:
            m["seq"] = None
        return {k: _filter_axes(v, mesh) for k, v in m.items()}


def _filter_axes(axes, mesh):
    if axes is None:
        return None
    if isinstance(axes, str):
        return axes if axes in mesh.axis_names else None
    kept = tuple(a for a in axes if a in mesh.axis_names)
    return kept if kept else None


def _axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    return int(np.prod([mesh.shape[a] for a in axes]))


def guard_spec(shape, spec: P, mesh: Mesh) -> P:
    """Drop sharding on any dim not divisible by its mesh-axis product, and
    on repeated mesh axes (first occurrence wins — e.g. MoE expert weights
    map both 'expert' and 'mlp' to the model axis; EP takes priority).
    jit *arguments* require even sharding; this guard makes every spec
    legal for any shape (uneven dims fall back to replication)."""
    out = []
    used: set = set()
    for d, axes in enumerate(spec):
        axes = _filter_axes(axes, mesh)
        if axes is None or d >= len(shape):
            out.append(None)
            continue
        ax_tuple = (axes,) if isinstance(axes, str) else tuple(axes)
        ax_tuple = tuple(a for a in ax_tuple if a not in used)
        if not ax_tuple:
            out.append(None)
            continue
        axes = ax_tuple[0] if len(ax_tuple) == 1 else ax_tuple
        size = _axis_size(mesh, axes)
        if shape[d] % size == 0:
            out.append(axes)
            used.update(ax_tuple)
        else:
            out.append(None)
    return P(*out)


def logical_to_spec(logical: tuple, plan: Plan) -> list:
    """Raw per-dim axis list (NOT a PartitionSpec: P() rejects duplicate
    axes at construction, and duplicates are legitimately produced by e.g.
    MoE expert weights before the guard dedups them)."""
    return [plan.rule(a) if a is not None else None for a in logical]


def param_shardings(model, params_struct, mesh: Mesh, plan: Plan):
    """Build a NamedSharding tree for the params (struct or concrete)."""
    specs = model.param_specs()

    def walk(struct, spec):
        if isinstance(struct, dict):
            return {k: walk(struct[k], spec[k] if isinstance(spec, dict)
                            else spec) for k in struct}
        if isinstance(struct, (list, tuple)):
            if isinstance(spec, (list, tuple)) and len(spec) == len(struct):
                t = type(struct)([walk(s, sp) for s, sp in zip(struct, spec)])
                return t
            return type(struct)([walk(s, spec) for s in struct])
        # leaf array / ShapeDtypeStruct
        logical = spec if isinstance(spec, tuple) else ()
        p = logical_to_spec(logical, plan)
        p = guard_spec(struct.shape, p, mesh)
        return NamedSharding(mesh, p)

    return walk(params_struct, specs)


def mirror_opt_shardings(param_sh, opt_struct, mesh: Mesh):
    """Optimizer state shardings: leaves with the same shape as their param
    inherit the param spec; factored/shrunk leaves drop trailing axes."""
    flat_p = {tuple(str(getattr(k, "key", getattr(k, "idx", k)))
                    for k in path): s
              for path, s in
              jax.tree_util.tree_flatten_with_path(param_sh)[0]}

    def best_match(path, shape):
        # match by longest suffix of the param path present in opt path
        for plen in range(len(path), 0, -1):
            for ppath, sh in flat_p.items():
                if path[-plen:] == ppath[-plen:] or \
                        (len(ppath) <= plen and path[-len(ppath):] == ppath):
                    spec = list(sh.spec)
                    spec += [None] * (len(shape) - len(spec))
                    return guard_spec(shape, P(*spec[:len(shape)]), mesh)
        return guard_spec(shape, P(*[None] * len(shape)), mesh)

    flat_o, treedef = jax.tree_util.tree_flatten_with_path(opt_struct)
    out = []
    for path, leaf in flat_o:
        keys = tuple(str(getattr(k, "key", getattr(k, "idx", k)))
                     for k in path)
        # strip optimizer-level prefixes/suffixes like 'm','v','vr','vc'
        core = tuple(k for k in keys if k not in
                     ("m", "v", "vr", "vc", "opt"))
        sh = flat_p.get(core)
        if sh is not None and len(sh.spec) >= len(leaf.shape):
            spec = list(sh.spec)
            if keys and keys[-1] == "vr":      # factored: drop last dim
                spec = spec[:-1]
            elif keys and keys[-1] == "vc":    # factored: drop 2nd-last
                spec = spec[:-2] + spec[-1:]
            spec = (spec + [None] * len(leaf.shape))[:len(leaf.shape)]
            out.append(NamedSharding(mesh, guard_spec(leaf.shape, P(*spec), mesh)))
        else:
            out.append(NamedSharding(
                mesh, guard_spec(leaf.shape, P(*[None] * len(leaf.shape)),
                                 mesh)))
    return jax.tree_util.tree_unflatten(treedef, out)


def batch_shardings(batch_struct, mesh: Mesh, batch_axes_: tuple):
    def one(leaf):
        spec = [batch_axes_] + [None] * (len(leaf.shape) - 1)
        return NamedSharding(mesh, guard_spec(leaf.shape, P(*spec), mesh))
    return jax.tree.map(one, batch_struct)


def cache_shardings(cache_struct, mesh: Mesh, batch_axes_: tuple,
                    length_axis: str = "model"):
    """KV caches: batch over DP axes, cache-length dim over `model`
    (flash-decoding style KV parallelism); recurrent states shard their
    widest divisible channel dim over `model`.

    ``cache_struct`` is the (scanned, tail) pair from init_stack_caches:
    scanned leaves carry a leading layer-groups axis (never sharded)."""
    def spec_for(shape, layer_lead: bool):
        off = 1 if layer_lead else 0
        spec = [None] * len(shape)
        if len(shape) > off:
            spec[off] = batch_axes_
        if len(shape) > off + 1:
            spec[off + 1] = length_axis
        # fallback: if the length dim can't shard (recurrent states),
        # try the widest trailing channel dim
        if (len(shape) > off + 1
                and shape[off + 1] % _axis_size(mesh, length_axis)):
            spec[off + 1] = None
            for d in range(len(shape) - 1, off + 1, -1):
                if shape[d] % _axis_size(mesh, length_axis) == 0:
                    spec[d] = length_axis
                    break
        return NamedSharding(mesh, guard_spec(shape, P(*spec), mesh))

    scanned, tails = cache_struct
    sc_sh = jax.tree.map(lambda l: spec_for(l.shape, True), scanned)
    tail_sh = jax.tree.map(lambda l: spec_for(l.shape, False), tails)
    return (sc_sh, tail_sh)


DEFAULT_PLAN = Plan()

# Named plan variants for §Perf hillclimbing (hypothesis -> change -> measure)
PLAN_VARIANTS: dict[str, Plan] = {
    "baseline": DEFAULT_PLAN,
    # no sequence sharding of the residual stream: shows why SP is load-
    # bearing for memory (scan carries grow 16x)
    "noseq": Plan(name="noseq", act_rules=(
        ("batch", ("pod", "data")), ("heads", "model"), ("mlp", "model"),
        ("vocab", "model"), ("seq", None), ("expert", "model"))),
    # pure FSDP: no tensor parallelism on activations at all
    "fsdp_only": Plan(name="fsdp_only", act_rules=(
        ("batch", ("pod", "data")), ("heads", None), ("mlp", None),
        ("vocab", None), ("seq", "model"), ("expert", "model"))),
    # TP on params too (vocab/heads/mlp dims over model where divisible)
    # is already the baseline param rule set; this variant turns OFF fsdp
    # (params replicated over data) to measure the FSDP all-gather cost
    "no_fsdp": Plan(name="no_fsdp", rules=(
        ("vocab", "model"), ("embed", None), ("heads", "model"),
        ("kv", "model"), ("head_dim", None), ("mlp", "model"),
        ("mlp2", None), ("expert", "model"), ("layers", None))),
}
