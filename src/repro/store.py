"""``python -m repro.store`` — inspect and maintain a persistent design
store (:mod:`repro.runtime.store`).

Subcommands (all take the store root as their first argument)::

    python -m repro.store list   <root>   # entries of the current env
    python -m repro.store verify <root>   # decode all; quarantine corrupt
    python -m repro.store prune  <root>   # drop stale envs + quarantine

``list`` prints one line per entry (type, status, size, jax/backend
provenance) plus the environments present; ``verify`` exits non-zero
when any entry had to be quarantined on this pass and, under
``--strict``, also when the quarantine directory holds a backlog from
earlier runs (so CI catches store corruption that a previous replica
already moved aside); ``prune`` deletes every environment directory
except the current one (a jax upgrade leaves the old env's entries
unreachable — this reclaims them) and empties the current
environment's quarantine.
"""
from __future__ import annotations

import argparse
import sys

from repro.runtime.store import DesignStore


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.store",
        description="Inspect/maintain a persistent AOT design store.",
    )
    parser.add_argument("command", choices=("list", "verify", "prune"))
    parser.add_argument("root", help="store root directory")
    parser.add_argument(
        "--strict", action="store_true",
        help="verify: also fail on a pre-existing quarantine backlog",
    )
    args = parser.parse_args(argv)

    store = DesignStore(args.root, readonly=(args.command == "list"))
    if args.command == "list":
        envs = store.environments()
        print(f"store root: {store.root}")
        print(f"environments: {', '.join(envs) or '(none)'}")
        print(f"current env: {store.env_tag}")
        entries = store.entries()
        for e in entries:
            if e["status"] == "ok":
                kind = f" kind={e['kind']}" if e.get("kind") else ""
                print(
                    f"  [{e['type']}] {e['file']} ok {e['bytes']}B"
                    f"{kind} jax={e['jax']} backend={e['backend']}"
                )
            else:
                print(f"  [{e['type']}] {e['file']} {e['status']}")
        print(f"{len(entries)} entr{'y' if len(entries) == 1 else 'ies'}")
        return 0
    if args.command == "verify":
        report = store.verify()
        print(
            f"verify: {report['ok']} ok, "
            f"{report['quarantined']} newly quarantined, "
            f"{report['backlog']} in quarantine backlog"
        )
        if report["quarantined"]:
            return 1
        if args.strict and report["backlog"]:
            print("strict: quarantine backlog present (prune to clear)")
            return 1
        return 0
    removed = store.prune()
    print(f"pruned: {', '.join(removed) or '(nothing)'}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
