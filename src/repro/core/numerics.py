"""Certified numerics: first-order rounding-error bounds over the stencil IR.

SASA's correctness story claims generated designs are provably equivalent
to the reference stencil, yet the repo's differential gates historically
leaned on hand-tuned constants (a repo-wide ``2e-4``, a 4-ULP pipeline
bound).  This module replaces the magic with a *certified* bound: a
static analysis in the style of affine arithmetic / FPTaylor-class
first-order error analyses that propagates, for every expression node,

  * a **value envelope** — an interval (static mode) or a measured
    per-node max magnitude (envelope mode) of the *exact* real-arithmetic
    value, and
  * an **absolute error bound** ``E`` — a certified bound on
    ``|computed - exact|`` for any executor whose individual float ops
    are faithful to ``unit_roundoff(dtype)``.

Propagation rules (``u`` = :func:`repro.core.spec.unit_roundoff`, which
is ``eps`` — 2x the correctly-rounded per-op error ``eps/2``, headroom
for merely-faithful backends; ``M(x)`` = magnitude envelope of ``x``):

  ``a + b``, ``a - b``   ``E = (Ea + Eb)(1 + u) + u * M(r)``
  ``a * b``              ``E = Ea*M(b) + Eb*M(a) + Ea*Eb
                               + u * (M(a)+Ea) * (M(b)+Eb)``
  ``a / b``              with ``m = min|b| - Eb`` (certified smallest
                         computed divisor magnitude; ``E = inf`` when
                         ``m <= 0``):
                         ``E = Ea/m + M(a)*Eb/m^2 + 4u*(M(a)+Ea)/m``
                         — division charges ``4u`` because XLA may
                         rewrite ``x / c`` into ``x * (1/c)`` (two
                         roundings, each up to a couple of ULP; this is
                         also what justified the old 4-ULP pipeline
                         differential bound)
  ``-a``, ``abs(a)``     exact: ``E = Ea``
  ``max/min(a, b, ...)`` compare-select is exact: ``E = max(Ei)``
                         (``|max(a,b) - max(a',b')| <= max(|a-a'|,
                         |b-b'|)``)
  ``Num(v)``             representation error ``|v - dtype(v)|``
  ``Let``/``Var``        the binding is analyzed **once** and every use
                         shares its ``(envelope, E)`` — matching the
                         CSE'd evaluation the executors run

Per stage, one extra ``u * (M + E)`` term covers the cast of the stage
result to its declared dtype (the numpy oracle computes ops in float64
and casts per stage; executors are float32 throughout — both patterns
are covered).  Across iterations the iterate input is rebound to the
output's ``(envelope, E)``; constant inputs keep ``E = 0``.

**Soundness of the differential gate**: both an executor and the
pure-numpy oracle are float evaluations within the forward bound ``F``
of the exact iteration, so their mutual divergence is at most ``2F`` —
:func:`tolerance_for` returns exactly that (raw-tree ``F`` + lowered-
tree ``F``; lowering is exact in real arithmetic, so both evaluations
approximate the same ideal value).  tests/test_conformance.py asserts
measured divergence <= certified bound for every spec x executor x
boundary mode on the 200-seed corpus, and that the bound stays within
:data:`NONVACUITY_SLACK` of the measured error on the corpus median —
certified, and not vacuous.

Two analysis modes:

  * :func:`analyze` — **static interval mode**: inputs are assumed to
    range over ``[-input_range, input_range]`` (documented unit-range
    default; pass a mapping of per-input :class:`Interval` s to
    override).  Powers the SASA5xx diagnostics, ``repro.lint
    --numerics`` budget tables, and the stock-kernel finite-bound CI
    gate.
  * :func:`measured_report` / :func:`tolerance_for` — **envelope mode**:
    the expression trees are evaluated in float64 on the actual input
    arrays, mirroring the oracle's per-stage boundary padding, and the
    same propagation rules run **cell-by-cell** — each cell's error is
    amplified only by the magnitudes that cell actually meets, not the
    array-wide max (measured magnitudes are widened by ``1 + 2**-30``
    to cover the float64 evaluation of the envelopes themselves).
    This is what derives per-case conformance tolerances: interval
    envelopes compound geometrically on iterated multiplicative
    kernels, and even measured *scalar* (max-magnitude) envelopes
    over-charge deep product chains by orders of magnitude, because
    the large-magnitude cells and large-error cells are generally
    different cells.

Diagnostics (registered in ``analysis.DIAGNOSTIC_CODES``; all carry DSL
source spans that survive IR lowering):

  SASA500  info     certified bound attached to ``TunedDesign``
  SASA501  warning  value envelope reaches the dtype's finite max
  SASA502  warning  +/- can cancel below the accumulated error
                    (``E_in >= 2**-12 * M(result)``)
  SASA503  warning  divisor's certified magnitude spread
                    ``M(b)/m >= 1e3`` amplifies error per cell
  SASA510  warning  total relative bound beyond dtype-meaningful
                    precision (``E/M >= 2**-10``)
"""
from __future__ import annotations

import dataclasses
import math
from typing import Mapping

import numpy as np

from repro.core.analysis import (
    TOP,
    Diagnostic,
    Interval,
    _iabs,
    _iadd,
    _idiv,
    _imul,
    _ineg,
    _isub,
    sort_diagnostics,
)
from repro.core.spec import (
    BinOp,
    Call,
    Expr,
    Let,
    Neg,
    Num,
    Ref,
    Stage,
    StencilSpec,
    Var,
    finite_max,
    unit_roundoff,
)

_INF = math.inf

#: Division's unit-roundoff multiplier (reciprocal-multiply rewrites).
DIV_ROUNDOFF_FACTOR = 4.0

#: SASA502: fire when incoming accumulated error is at least this
#: fraction of the result's magnitude envelope at a +/- node.
CANCEL_THRESHOLD = 2.0 ** -12

#: SASA502's second gate: the result envelope must actually *drop* below
#: this fraction of the operand envelopes — cancellation destroys leading
#: digits; mere error accumulation (result as large as its operands) is
#: SASA510's business, not a cancellation finding.
CANCEL_MAGNITUDE_DROP = 2.0 ** -6

#: SASA503: fire when the divisor's magnitude spread ``M(b) / min|b|``
#: reaches this factor (some cells divide by values this much smaller
#: than others, amplifying their error relative to the rest).
DIV_CONDITION_THRESHOLD = 1.0e3

#: SASA510: total relative bound beyond which the result's digits stop
#: being dtype-meaningful (about 2.4 of float32's ~7.2 decimal digits).
MEANINGFUL_RELATIVE = 2.0 ** -10

#: Iteration-propagation cap: beyond this many fused rounds the static
#: bound is reported as ``inf`` (not certified) instead of looping.
ROUND_CAP = 16384

#: Documented non-vacuity slack: on the 200-seed conformance corpus the
#: certified bound must stay within this factor of the *measured*
#: executor-vs-oracle error on the corpus median (tests/test_conformance
#: asserts it).  First-order static bounds genuinely cost 1-2 orders of
#: magnitude over typical measured error (errors add as bounds, measured
#: errors partially cancel); this factor says "bounded pessimism".
NONVACUITY_SLACK = 1024.0

#: Widening applied to float64-measured envelopes so they certifiably
#: cover the exact real-arithmetic values (f64 evaluation noise is
#: ~2**-52 relative per op; 2**-30 covers any expression this DSL
#: can express with astronomic headroom).
_ENVELOPE_WIDEN = 1.0 + 2.0 ** -30


def _mag(iv: Interval) -> float:
    return max(abs(iv.lo), abs(iv.hi))


def _min_abs(iv: Interval) -> float:
    if iv.contains_zero:
        return 0.0
    return min(abs(iv.lo), abs(iv.hi))


def _pmul(a: float, b: float) -> float:
    # 0 * inf -> 0: a zero magnitude/error annihilates regardless
    if a == 0.0 or b == 0.0:
        return 0.0
    return a * b


# --------------------------------------------------------------------------
# Shared error-propagation rules (magnitudes in, absolute bound out)
# --------------------------------------------------------------------------


def err_add(ea: float, eb: float, mag_r: float, u: float) -> float:
    """``a + b`` / ``a - b``: errors add, result rounds once."""
    return (ea + eb) * (1.0 + u) + _pmul(u, mag_r)


def err_mul(
    ea: float, eb: float, mag_a: float, mag_b: float, u: float
) -> float:
    """``a * b``: first-order cross terms plus rounding of the product."""
    return (
        _pmul(ea, mag_b) + _pmul(eb, mag_a) + _pmul(ea, eb)
        + _pmul(u, _pmul(mag_a + ea, mag_b + eb))
    )


def err_div(
    ea: float, eb: float, mag_a: float, min_b: float, u_div: float
) -> float:
    """``a / b``: infinite unless the computed divisor is bounded away
    from zero (``min_b`` is the certified min magnitude of the *exact*
    divisor; subtracting ``eb`` covers the computed one)."""
    m = min_b - eb
    if not m > 0.0:
        return _INF
    return ea / m + _pmul(mag_a, eb) / (m * m) + u_div * (mag_a + ea) / m


def cast_err(err: float, mag: float, u: float) -> float:
    """One rounding of the stage result to its declared dtype."""
    return err * (1.0 + u) + _pmul(u, mag)


# --------------------------------------------------------------------------
# Report structure
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class StageBudget:
    """Error budget of one stage after the final analyzed round."""

    stage: str
    lo: float           # value envelope (interval or measured +- mag)
    hi: float
    err: float          # accumulated absolute error bound
    ulps: float         # err in units of u * max(|envelope|, 1)

    def row(self) -> str:
        return (
            f"{self.stage:<12} [{self.lo:>11.4g}, {self.hi:>11.4g}]"
            f" {self.err:>12.3g} {self.ulps:>10.1f}"
        )


@dataclasses.dataclass(frozen=True)
class ErrorReport:
    """Outcome of one certified-numerics analysis.

    ``bound`` certifies ``|computed - exact| <= bound`` per output cell
    for any executor with faithful per-op rounding; ``differential``
    (``2 * bound``) bounds the divergence between two such executors
    (or executor vs the numpy oracle).  ``assumed_range`` is the static
    input-range assumption, ``None`` for measured (envelope) analyses.
    """

    spec_name: str
    dtype: str
    iterations: int
    rounds_analyzed: int
    bound: float
    scale: float        # output magnitude envelope
    budgets: tuple[StageBudget, ...]
    diagnostics: tuple[Diagnostic, ...] = ()
    assumed_range: float | None = None
    #: Envelope mode only: the per-output-cell error-bound array (f64),
    #: ``None`` for static analyses.  ``bound`` is its max; keeping the
    #: cells lets :func:`tolerance_for` sum raw + lowered bounds
    #: cell-by-cell instead of max + max.
    cell_err: object = dataclasses.field(
        default=None, repr=False, compare=False
    )

    @property
    def relative(self) -> float:
        if self.bound == 0.0:
            return 0.0
        if not math.isfinite(self.bound) or self.scale == 0.0:
            return _INF
        return self.bound / self.scale

    @property
    def differential(self) -> float:
        """Sound bound on |executor - oracle| (two faithful evaluations)."""
        return 2.0 * self.bound

    @property
    def certified(self) -> bool:
        return math.isfinite(self.bound)

    def table(self) -> str:
        """The per-stage error budget table (``repro.lint --numerics``)."""
        head = (
            f"{'stage':<12} {'value envelope':<26} {'abs error':>12}"
            f" {'ulps':>10}"
        )
        lines = [head, "-" * len(head)]
        lines += [b.row() for b in self.budgets]
        src = (
            f"inputs in [-{self.assumed_range:g}, {self.assumed_range:g}]"
            if self.assumed_range is not None else "measured input data"
        )
        lines.append(
            f"certified ({src}, {self.dtype}): |computed - exact| <= "
            f"{self.bound:.3g} per cell over {self.iterations} "
            f"iteration(s); relative {self.relative:.3g}"
        )
        return "\n".join(lines)


# --------------------------------------------------------------------------
# Static interval mode
# --------------------------------------------------------------------------


class _StaticAnalyzer:
    """One traversal state: per-stage dtype constants + fired diagnostics."""

    def __init__(self, spec: StencilSpec, assumed_range: float | None):
        self.spec = spec
        self.assumed_range = assumed_range
        self.diags: list[Diagnostic] = []
        self._seen: set = set()
        self.unsafe_division = False
        self.stage: Stage | None = None
        self.u = unit_roundoff(spec.dtype)
        self.fmax = finite_max(spec.dtype)
        self._np_dtype = None

    def set_stage(self, st: Stage) -> None:
        self.stage = st
        self.u = unit_roundoff(st.dtype)
        self.fmax = finite_max(st.dtype)
        self._np_dtype = np.dtype(st.dtype) if st.dtype in (
            "float16", "float32", "float64"
        ) else None

    # -- diagnostics -------------------------------------------------------

    def _fire(self, code: str, node, message: str, key=None) -> None:
        span = getattr(node, "span", None) or (
            self.stage.span if self.stage is not None else None
        )
        if key is not None:
            k = key
        else:
            loc = (span.line, span.col) if span is not None else None
            k = (code, self.stage.name, loc)
        if k in self._seen:
            return
        self._seen.add(k)
        self.diags.append(Diagnostic(
            code, "warning", message, span=span,
            stage=self.stage.name if self.stage is not None else None,
        ))

    def _note_range(self) -> str:
        if self.assumed_range is None:
            return ""
        return (
            f" (assuming inputs in [-{self.assumed_range:g},"
            f" {self.assumed_range:g}])"
        )

    def _check_overflow(self, node, iv: Interval, err: float) -> None:
        if self.unsafe_division:
            return  # interval blew up through a zero-straddling divisor
        reach = _mag(iv) + err
        if reach >= self.fmax:
            self._fire(
                "SASA501", node,
                f"value envelope [{iv.lo:g}, {iv.hi:g}] (+ error {err:.3g})"
                f" reaches the {self.stage.dtype} finite max "
                f"{self.fmax:.4g}: overflow to inf is possible"
                + self._note_range(),
                key=("SASA501", self.stage.name),
            )

    def _check_cancel(
        self,
        node,
        ea: float,
        eb: float,
        a_iv: Interval,
        b_iv: Interval,
        iv: Interval,
    ) -> None:
        # both gates must hold: the operands' leading digits actually
        # cancel (result envelope drops well below the operand
        # envelopes), and what survives is dominated by incoming error.
        # Each add also charges its own u * max(mag_a, mag_b) of lost
        # exactness relative to the surviving magnitude.
        mag_in = max(_mag(a_iv), _mag(b_iv))
        mag_r = _mag(iv)
        if not math.isfinite(mag_in) or not math.isfinite(mag_r):
            return
        if mag_r > CANCEL_MAGNITUDE_DROP * mag_in:
            return
        ein = ea + eb + self.u * mag_in
        if ein <= 0.0 or not math.isfinite(ein):
            return
        if mag_r == 0.0 or ein >= CANCEL_THRESHOLD * mag_r:
            rel = _INF if mag_r == 0.0 else ein / mag_r
            self._fire(
                "SASA502", node,
                f"operands of '{node.op}' reach magnitude {mag_in:g} but"
                f" cancel to at most {mag_r:g}, leaving accumulated"
                f" rounding error <= {ein:.3g} ({rel:.3g}x of the"
                " surviving magnitude): the result's digits are dominated"
                " by error" + self._note_range(),
            )

    def _check_division(
        self, node, b_iv: Interval, eb: float
    ) -> None:
        min_b = _min_abs(b_iv)
        if min_b - eb <= 0.0:
            # zero-straddling divisor: SASA301 (division safety) owns
            # this finding; suppress the numerics codes downstream.
            self.unsafe_division = True
            return
        m = min_b - eb
        kappa = _mag(b_iv) / m
        if math.isfinite(kappa) and kappa >= DIV_CONDITION_THRESHOLD:
            self._fire(
                "SASA503", node,
                f"divisor envelope [{b_iv.lo:g}, {b_iv.hi:g}] spans a"
                f" {kappa:.3g}x magnitude range: cells dividing by values"
                f" near {m:.3g} amplify incoming absolute error by up to"
                f" {1.0 / m:.3g}x" + self._note_range(),
            )

    # -- propagation -------------------------------------------------------

    def node(
        self,
        e: Expr,
        arrays: Mapping[str, tuple[Interval, float]],
        env: dict,
    ) -> tuple[Interval, float]:
        if isinstance(e, Num):
            v = float(e.value)
            if self._np_dtype is not None and math.isfinite(v):
                rep = abs(v - float(np.asarray(v, dtype=self._np_dtype)))
            else:
                rep = 0.0 if math.isfinite(v) else _INF
            iv = Interval(v, v)
            self._check_overflow(e, iv, rep)
            return iv, rep
        if isinstance(e, Ref):
            return arrays.get(e.name, (TOP, _INF))
        if isinstance(e, Var):
            return env.get(e.name, (TOP, _INF))
        if isinstance(e, Let):
            inner = dict(env)
            for name, bound in e.bindings:
                inner[name] = self.node(bound, arrays, inner)
            return self.node(e.body, arrays, inner)
        if isinstance(e, Neg):
            iv, err = self.node(e.arg, arrays, env)
            return _ineg(iv), err
        if isinstance(e, Call):
            pairs = [self.node(a, arrays, env) for a in e.args]
            ivs = [p[0] for p in pairs]
            err = max(p[1] for p in pairs)
            if e.fn == "abs":
                iv = _iabs(ivs[0])
            elif e.fn == "max":
                iv = Interval(
                    max(v.lo for v in ivs), max(v.hi for v in ivs)
                )
            elif e.fn == "min":
                iv = Interval(
                    min(v.lo for v in ivs), min(v.hi for v in ivs)
                )
            else:  # pragma: no cover - exhaustive over INTRINSICS
                iv, err = TOP, _INF
            return iv, err
        if isinstance(e, BinOp):
            a_iv, ea = self.node(e.lhs, arrays, env)
            b_iv, eb = self.node(e.rhs, arrays, env)
            if e.op in ("+", "-"):
                iv = _iadd(a_iv, b_iv) if e.op == "+" else _isub(a_iv, b_iv)
                err = err_add(ea, eb, _mag(iv), self.u)
                self._check_cancel(e, ea, eb, a_iv, b_iv, iv)
            elif e.op == "*":
                iv = _imul(a_iv, b_iv)
                err = err_mul(ea, eb, _mag(a_iv), _mag(b_iv), self.u)
            else:  # "/"
                self._check_division(e, b_iv, eb)
                iv = _idiv(a_iv, b_iv)
                err = err_div(
                    ea, eb, _mag(a_iv), _min_abs(b_iv),
                    DIV_ROUNDOFF_FACTOR * self.u,
                )
            self._check_overflow(e, iv, err if math.isfinite(err) else 0.0)
            return iv, err
        raise TypeError(type(e))  # pragma: no cover - exhaustive over Expr


def _input_envelopes(
    spec: StencilSpec, input_range
) -> tuple[dict[str, tuple[Interval, float]], float | None]:
    """Initial (interval, error) state for every input + the noted range."""
    if isinstance(input_range, Mapping):
        state = {}
        for n in spec.inputs:
            iv = input_range.get(n, TOP)
            if not isinstance(iv, Interval):
                r = abs(float(iv))
                iv = Interval(-r, r)
            state[n] = (iv, 0.0)
        noted = None
    else:
        r = abs(float(input_range))
        state = {n: (Interval(-r, r), 0.0) for n in spec.inputs}
        noted = r
    if spec.boundary.kind in ("zero", "constant"):
        # out-of-grid taps read the fill: widen every input's envelope
        v = spec.boundary.value if spec.boundary.kind == "constant" else 0.0
        fill = Interval(v, v)
        state = {n: (iv.hull(fill), err) for n, (iv, err) in state.items()}
    return state, noted


def analyze(
    spec: StencilSpec,
    iterations: int | None = None,
    input_range=1.0,
    bucketed: bool = True,
    optimize: bool = True,
) -> ErrorReport:
    """Static interval-mode analysis: certified bound + SASA5xx findings.

    ``input_range`` is the documented unit-range assumption: every input
    is taken to lie in ``[-input_range, input_range]`` (pass a mapping of
    per-input :class:`Interval` s for real data ranges).  ``bucketed``
    widens stage envelopes by the mask-weave fill, mirroring
    ``division_diagnostics``.  ``optimize`` lowers through the IR
    pipeline first — executors run the lowered trees; pass ``False``
    when the caller (``analysis.verify``) already lowered.
    """
    if optimize:
        from repro.core.ir import lower

        spec = lower(spec).spec
    it = spec.iterations if iterations is None else int(iterations)
    analyzer = _StaticAnalyzer(spec, None)
    state, noted = _input_envelopes(spec, input_range)
    analyzer.assumed_range = noted

    fill: Interval | None = None
    if bucketed and spec.boundary.kind in ("zero", "constant"):
        v = spec.boundary.value if spec.boundary.kind == "constant" else 0.0
        fill = Interval(v, v)

    rounds = min(it, ROUND_CAP)
    budgets: list[StageBudget] = []
    out_iv, out_err = TOP, _INF
    done = 0
    for _ in range(rounds):
        budgets = []
        for st in spec.stages:
            analyzer.set_stage(st)
            iv, err = analyzer.node(st.expr, state, {})
            err = cast_err(err, _mag(iv), analyzer.u)
            stored = iv.hull(fill) if fill is not None else iv
            state[st.name] = (stored, err)
            mag = _mag(iv)
            budgets.append(StageBudget(
                st.name, iv.lo, iv.hi, err,
                err / (analyzer.u * max(mag, 1.0))
                if math.isfinite(err) else _INF,
            ))
        out_iv, out_err = state[spec.output_name]
        state[spec.iterate_input] = (out_iv, out_err)
        done += 1
        if not math.isfinite(out_err):
            break
    bound = out_err if done == it else _INF
    scale = _mag(out_iv)

    if not analyzer.unsafe_division:
        rel = (
            0.0 if bound == 0.0
            else _INF if not math.isfinite(bound) or scale == 0.0
            else bound / scale
        )
        if rel >= MEANINGFUL_RELATIVE:
            rng = (
                f" assuming inputs in [-{noted:g}, {noted:g}]"
                if noted is not None else ""
            )
            analyzer.stage = spec.output_stage
            analyzer._fire(
                "SASA510", spec.output_stage,
                f"accumulated rounding-error bound {bound:.3g} is "
                f"{rel:.3g} of the output envelope {scale:g} after "
                f"{it} iteration(s){rng}: beyond {spec.dtype}-meaningful "
                f"precision (threshold {MEANINGFUL_RELATIVE:g})",
                key=("SASA510", spec.output_name),
            )

    return ErrorReport(
        spec_name=spec.name,
        dtype=spec.dtype,
        iterations=it,
        rounds_analyzed=done,
        bound=bound,
        scale=scale,
        budgets=tuple(budgets),
        diagnostics=tuple(sort_diagnostics(analyzer.diags)),
        assumed_range=noted,
    )


# --------------------------------------------------------------------------
# Envelope (measured) mode
# --------------------------------------------------------------------------


def _amag(x) -> float:
    a = np.abs(np.asarray(x, dtype=np.float64))
    m = float(np.max(a)) if a.size else 0.0
    if not math.isfinite(m):
        return _INF
    return m * _ENVELOPE_WIDEN


def _wabs(x):
    """Per-cell widened magnitude of a float64-measured envelope."""
    return np.abs(x) * _ENVELOPE_WIDEN


def _pad_nd(a: np.ndarray, r: int, boundary, ndim: int) -> np.ndarray:
    """Pad the trailing ``ndim`` dims by ``r`` with the boundary rule
    (leading dims — a batch axis — are left alone)."""
    if r == 0:
        return a
    pads = [(0, 0)] * (a.ndim - ndim) + [(r, r)] * ndim
    k = boundary.kind
    if k == "zero":
        return np.pad(a, pads)
    if k == "constant":
        return np.pad(a, pads, constant_values=boundary.value)
    if k == "replicate":
        return np.pad(a, pads, mode="edge")
    return np.pad(a, pads, mode="wrap")


def _pad_err(e, r: int, boundary, ndim: int):
    """Boundary rule for error-bound arrays: zero/constant fills are
    exact (error 0 in the apron); replicate/periodic carry the edge
    cell's error along with its value.  Scalars broadcast unchanged."""
    if r == 0 or np.ndim(e) == 0:
        return e
    pads = [(0, 0)] * (e.ndim - ndim) + [(r, r)] * ndim
    k = boundary.kind
    if k in ("zero", "constant"):
        return np.pad(e, pads)
    if k == "replicate":
        return np.pad(e, pads, mode="edge")
    return np.pad(e, pads, mode="wrap")


class _EnvelopeAnalyzer:
    """Float64 evaluation with a per-cell error bound riding along.

    Every node returns ``(value, err)`` — float64 arrays (or scalars
    that broadcast).  Errors are propagated **cell-by-cell**: the error
    at a cell is amplified only by the magnitudes that cell actually
    multiplies or divides by, not by the array-wide max.  (A scalar
    max-magnitude envelope over-charges deep multiplicative chains by
    orders of magnitude — the large-magnitude cells and the
    large-error cells are generally *different* cells.)
    """

    def __init__(self):
        self.u = unit_roundoff("float32")
        self.u_div = DIV_ROUNDOFF_FACTOR * self.u
        self._np_dtype = np.dtype("float32")

    def set_stage(self, st: Stage) -> None:
        self.u = unit_roundoff(st.dtype)
        self.u_div = DIV_ROUNDOFF_FACTOR * self.u
        self._np_dtype = (
            np.dtype(st.dtype)
            if st.dtype in ("float16", "float32", "float64")
            else None
        )

    def node(self, e: Expr, get_ref, env: dict):
        if isinstance(e, Num):
            v = float(e.value)
            if self._np_dtype is not None and math.isfinite(v):
                rep = abs(v - float(np.asarray(v, dtype=self._np_dtype)))
            else:
                rep = 0.0 if math.isfinite(v) else _INF
            return v, rep
        if isinstance(e, Ref):
            return get_ref(e.name, e.offsets)
        if isinstance(e, Var):
            return env[e.name]
        if isinstance(e, Let):
            inner = dict(env)
            for name, bound in e.bindings:
                inner[name] = self.node(bound, get_ref, inner)
            return self.node(e.body, get_ref, inner)
        if isinstance(e, Neg):
            v, err = self.node(e.arg, get_ref, env)
            return -np.asarray(v, dtype=np.float64), err
        if isinstance(e, Call):
            pairs = [self.node(a, get_ref, env) for a in e.args]
            err = pairs[0][1]
            for _, e2 in pairs[1:]:
                err = np.maximum(err, e2)
            if e.fn == "abs":
                return np.abs(np.asarray(pairs[0][0], np.float64)), err
            acc = np.asarray(pairs[0][0], np.float64)
            for v, _ in pairs[1:]:
                acc = (
                    np.maximum(acc, v) if e.fn == "max"
                    else np.minimum(acc, v)
                )
            return acc, err
        if isinstance(e, BinOp):
            a, ea = self.node(e.lhs, get_ref, env)
            b, eb = self.node(e.rhs, get_ref, env)
            a = np.asarray(a, np.float64)
            b = np.asarray(b, np.float64)
            if e.op in ("+", "-"):
                r = a + b if e.op == "+" else a - b
                return r, (ea + eb) * (1.0 + self.u) + self.u * _wabs(r)
            if e.op == "*":
                r = a * b
                wa, wb = _wabs(a), _wabs(b)
                return r, (
                    ea * wb + eb * wa + ea * eb
                    + self.u * (wa + ea) * (wb + eb)
                )
            # "/": per cell, guard the computed divisor away from zero
            wa = _wabs(a)
            m = np.abs(b) / _ENVELOPE_WIDEN - eb
            with np.errstate(divide="ignore", invalid="ignore"):
                r = a / b
                core = (
                    ea / m + wa * eb / (m * m)
                    + self.u_div * (wa + ea) / m
                )
            err = np.where(m > 0.0, core, _INF)
            return r, err
        raise TypeError(type(e))  # pragma: no cover - exhaustive over Expr


def measured_report(
    spec: StencilSpec,
    arrays: Mapping[str, "np.ndarray"],
    iterations: int | None = None,
) -> ErrorReport:
    """Envelope-mode analysis over actual input data.

    Evaluates the (given) spec's trees in float64, mirroring the numpy
    oracle's per-stage boundary padding, and runs the error-propagation
    rules over the measured per-node magnitudes.  Arrays may carry one
    leading batch axis (the envelope then covers every batch entry).
    The spec is analyzed **as given** — callers wanting the lowered
    trees pass a lowered spec (see :func:`tolerance_for`).
    """
    it = spec.iterations if iterations is None else int(iterations)
    service = set(spec.halo_index_inputs) | set(spec.wrap_index_inputs)
    vals: dict[str, np.ndarray] = {}
    errs: dict = {}
    for n in spec.inputs:
        if n in service:
            continue  # int coordinate plumbing: never read by stages
        vals[n] = np.asarray(arrays[n], dtype=np.float64)
        errs[n] = 0.0  # executors and oracle read the same exact bits
    gshape = tuple(vals[spec.iterate_input].shape[-spec.ndim:])
    analyzer = _EnvelopeAnalyzer()

    budgets: list[StageBudget] = []
    out = vals[spec.iterate_input]
    out_err = np.zeros_like(out)
    done = 0
    rounds = min(it, ROUND_CAP)
    for _ in range(rounds):
        round_vals = dict(vals)
        round_errs = dict(errs)
        budgets = []
        for st in spec.stages:
            analyzer.set_stage(st)
            r = st.radius
            padded_v = {
                n: _pad_nd(a, r, spec.boundary, spec.ndim)
                for n, a in round_vals.items()
            }
            padded_e = {
                n: _pad_err(round_errs[n], r, spec.boundary, spec.ndim)
                for n in round_vals
            }

            def get_ref(name, offsets, pv=padded_v, pe=padded_e, r=r):
                a = pv[name]
                lead = (slice(None),) * (a.ndim - spec.ndim)
                idx = lead + tuple(
                    slice(r + o, r + o + s)
                    for o, s in zip(offsets, gshape)
                )
                err = pe[name]
                return a[idx], (err if np.ndim(err) == 0 else err[idx])

            res, err = analyzer.node(st.expr, get_ref, {})
            res = np.asarray(res, dtype=np.float64)
            if res.shape != out.shape:
                res = np.broadcast_to(res, out.shape).copy()
            err = np.asarray(err, dtype=np.float64)
            err = err * (1.0 + analyzer.u) + analyzer.u * _wabs(res)
            if err.shape != out.shape:
                err = np.broadcast_to(err, out.shape).copy()
            round_vals[st.name] = res
            round_errs[st.name] = err
            mag = _amag(res)
            emax = float(np.max(err)) if err.size else 0.0
            budgets.append(StageBudget(
                st.name, -mag, mag, emax,
                emax / (analyzer.u * max(mag, 1.0))
                if math.isfinite(emax) else _INF,
            ))
        out = round_vals[spec.output_name]
        out_err = round_errs[spec.output_name]
        vals[spec.iterate_input] = out
        errs[spec.iterate_input] = out_err
        done += 1
        if not np.all(np.isfinite(out_err)):
            break
    finite = done == it and bool(np.all(np.isfinite(out_err)))
    bound = float(np.max(out_err)) if finite and out_err.size else (
        0.0 if finite else _INF
    )
    return ErrorReport(
        spec_name=spec.name,
        dtype=spec.dtype,
        iterations=it,
        rounds_analyzed=done,
        bound=bound,
        scale=_amag(out),
        budgets=tuple(budgets),
        diagnostics=(),
        assumed_range=None,
        cell_err=out_err if finite else None,
    )


# --------------------------------------------------------------------------
# Front-door entry points
# --------------------------------------------------------------------------


def tolerance_for(
    spec: StencilSpec,
    iterations: int | None = None,
    arrays: Mapping[str, "np.ndarray"] | None = None,
    input_range=1.0,
) -> float:
    """Certified executor-vs-oracle differential tolerance for one case.

    With ``arrays`` (the conformance suite's path) the envelope mode
    runs over the actual data, once on the raw trees (covering the
    oracle's evaluation) and once on the IR-lowered trees (covering the
    executors') — the sum bounds their divergence, since lowering is
    exact in real arithmetic and both float evaluations approximate the
    same ideal iteration.  Without ``arrays`` the static interval mode
    runs under ``input_range`` and the symmetric ``2 * bound`` is
    returned.  Floored at one ``unit_roundoff`` so a degenerate case
    never produces a zero-width gate.
    """
    floor = unit_roundoff(spec.dtype)
    if arrays is None:
        rep = analyze(spec, iterations=iterations, input_range=input_range)
        return max(rep.differential, floor)
    from repro.core.ir import lower

    raw = measured_report(spec, arrays, iterations)
    lowered = measured_report(lower(spec).spec, arrays, iterations)
    if raw.cell_err is not None and lowered.cell_err is not None:
        # Both analyses produce aligned per-cell bounds; the divergence
        # at a cell is at most the *sum of that cell's* bounds, which is
        # tighter than max(raw) + max(lowered) when the worst cells
        # differ between the two trees.
        return max(float(np.max(raw.cell_err + lowered.cell_err)), floor)
    return max(raw.bound + lowered.bound, floor)


def numerics_diagnostics(
    spec: StencilSpec,
    iterations: int | None = None,
    input_range=1.0,
    bucketed: bool = True,
    optimize: bool = False,
) -> list[Diagnostic]:
    """The SASA5xx findings alone (what ``analysis.verify`` folds in).

    ``optimize`` defaults to ``False`` because ``verify`` hands over the
    already-lowered spec; spans survive lowering either way.
    """
    rep = analyze(
        spec, iterations=iterations, input_range=input_range,
        bucketed=bucketed, optimize=optimize,
    )
    return list(rep.diagnostics)


def bound_diagnostic(
    spec: StencilSpec,
    iterations: int | None = None,
    input_range=1.0,
) -> Diagnostic:
    """The SASA500 info diagnostic attaching the certified bound to a
    :class:`repro.core.autotune.TunedDesign` (autotune / DesignCache /
    StencilServer registration all ride this)."""
    rep = analyze(spec, iterations=iterations, input_range=input_range)
    rng = (
        f"inputs in [-{rep.assumed_range:g}, {rep.assumed_range:g}]"
        if rep.assumed_range is not None else "measured inputs"
    )
    body = (
        f"certified rounding-error bound: |computed - exact| <= "
        f"{rep.bound:.3g} per output cell over {rep.iterations} "
        f"iteration(s) ({rng}; relative {rep.relative:.3g})"
        if rep.certified else
        f"no finite certified rounding-error bound over "
        f"{rep.iterations} iteration(s) ({rng}); see SASA5xx findings"
    )
    return Diagnostic(
        "SASA500", "info", body,
        span=spec.output_stage.span, stage=spec.output_name,
    )
