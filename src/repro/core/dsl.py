"""SASA stencil DSL parser (Section 4.1 of the paper).

Grammar (line oriented, ``#`` comments allowed)::

    kernel: NAME
    iteration: INT                     # >= 1
    iterate: NAME                      # optional; default = last input
    boundary: zero | constant FLOAT | replicate | periodic   # default zero
    input TYPE: NAME(INT, INT[, INT])
    local TYPE: NAME(off, off[, off]) = EXPR
    output TYPE: NAME(off, off[, off]) = EXPR

Expressions support ``+ - * /``, unary minus, parentheses, numeric literals,
array references ``name(o0, o1[, o2])`` with constant integer offsets, and
the intrinsics ``max(...)``, ``min(...)``, ``abs(...)`` (needed for e.g.
DILATE which is pure compare-select logic).

The reference SASA implementation uses textX; we use a small hand-rolled
recursive-descent parser to stay dependency-free.

Every syntax error is a :class:`DSLSyntaxError` carrying a stable
diagnostic code (``SASA1xx``), the 1-based line/column, and the offending
source line; the parser also threads :class:`repro.core.spec.SourceSpan`
locations onto AST nodes (excluded from structural equality) so the
static analyzer (:mod:`repro.core.analysis`) can point findings back into
the DSL text.
"""
from __future__ import annotations

import dataclasses
import re

from repro.core.spec import (
    BOUNDARY_KINDS,
    BinOp,
    Boundary,
    Call,
    Expr,
    INTRINSICS,
    Let,
    Neg,
    Num,
    Ref,
    SourceSpan,
    Stage,
    StencilSpec,
    Var,
    walk,
)


class DSLSyntaxError(SyntaxError):
    """A located DSL parse error with a stable diagnostic code.

    ``code`` is the ``SASA1xx`` diagnostic code, ``lineno``/``col`` the
    1-based position, and ``text`` the offending source line — so callers
    (and the lint CLI) can render a caret pointing at the problem.  The
    plain :class:`SyntaxError` message is preserved as the first line of
    ``str(e)`` followed by the location, keeping existing ``except
    SyntaxError`` / message-matching callers working.
    """

    def __init__(
        self,
        msg: str,
        code: str = "SASA100",
        lineno: int | None = None,
        col: int | None = None,
        text: str | None = None,
    ):
        loc = ""
        if lineno is not None:
            loc = f" (line {lineno}" + (
                f", col {col})" if col is not None else ")"
            )
        super().__init__(msg + loc)
        self.msg = msg
        self.code = code
        self.lineno = lineno
        self.col = col
        self.text = text
        # SyntaxError's native offset attribute (1-based) for nicer
        # default tracebacks
        self.offset = col

    @property
    def span(self) -> SourceSpan | None:
        if self.lineno is None:
            return None
        col = self.col if self.col is not None else 1
        return SourceSpan(self.lineno, col, col)


_TOKEN_RE = re.compile(
    r"\s*(?:(?P<num>\d+\.\d*(?:[eE][+-]?\d+)?|\.\d+(?:[eE][+-]?\d+)?|\d+(?:[eE][+-]?\d+)?)"
    r"|(?P<name>[A-Za-z_][A-Za-z_0-9]*)"
    r"|(?P<op>[-+*/(),]))"
)


@dataclasses.dataclass(frozen=True)
class _Tok:
    kind: str | None
    val: str | None
    start: int  # 1-based column of the token's first character
    end: int    # 1-based column of the token's last character


class _ExprParser:
    """Recursive-descent expression parser with source positions.

    ``line_no``/``col_base`` locate the expression text within the DSL
    source: token columns are ``col_base + offset-in-text`` (both
    1-based), so spans point at the original line.
    """

    def __init__(self, text: str, line_no: int = 0, col_base: int = 1,
                 source_line: str | None = None):
        self.line_no = line_no
        self.col_base = col_base
        self.source_line = source_line if source_line is not None else text
        self.tokens: list[_Tok] = []
        pos = 0
        while pos < len(text):
            if text[pos:].strip() == "":
                break
            m = _TOKEN_RE.match(text, pos)
            if not m:
                bad_at = pos + len(text[pos:]) - len(text[pos:].lstrip())
                raise DSLSyntaxError(
                    f"bad token at: {text[pos:]!r}", code="SASA101",
                    lineno=line_no, col=col_base + bad_at,
                    text=self.source_line,
                )
            pos = m.end()
            for kind in ("num", "name", "op"):
                if m.group(kind) is not None:
                    self.tokens.append(_Tok(
                        kind, m.group(kind),
                        col_base + m.start(kind), col_base + m.end(kind) - 1,
                    ))
                    break
        self.i = 0
        end = col_base + len(text)
        self._eof = _Tok(None, None, end, end)

    def _err(self, msg: str, tok: _Tok, code: str = "SASA102"):
        raise DSLSyntaxError(
            msg, code=code, lineno=self.line_no, col=tok.start,
            text=self.source_line,
        )

    def _span(self, start_tok: _Tok, end_tok: _Tok | None = None) -> SourceSpan:
        end_tok = end_tok if end_tok is not None else start_tok
        return SourceSpan(self.line_no, start_tok.start, end_tok.end)

    def peek(self) -> _Tok:
        return self.tokens[self.i] if self.i < len(self.tokens) else self._eof

    def next(self) -> _Tok:
        tok = self.peek()
        self.i += 1
        return tok

    def prev(self) -> _Tok:
        """The most recently consumed token (for closing spans)."""
        return self.tokens[self.i - 1] if self.i > 0 else self._eof

    def expect(self, value: str):
        tok = self.next()
        if tok.val != value:
            self._err(f"expected {value!r}, got {tok.val!r}", tok)

    # expr := term (('+'|'-') term)*
    def parse_expr(self) -> Expr:
        first = self.peek()
        node = self.parse_term()
        while self.peek().val in ("+", "-"):
            op = self.next().val
            node = BinOp(op, node, self.parse_term(),
                         span=self._span(first, self.prev()))
        return node

    # term := factor (('*'|'/') factor)*
    def parse_term(self) -> Expr:
        first = self.peek()
        node = self.parse_factor()
        while self.peek().val in ("*", "/"):
            op = self.next().val
            node = BinOp(op, node, self.parse_factor(),
                         span=self._span(first, self.prev()))
        return node

    def parse_factor(self) -> Expr:
        tok = self.next()
        kind, val = tok.kind, tok.val
        if val == "-":
            return Neg(self.parse_factor(), span=self._span(tok, self.prev()))
        if val == "+":
            return self.parse_factor()
        if kind == "num":
            return Num(float(val), span=self._span(tok))
        if val == "(":
            node = self.parse_expr()
            self.expect(")")
            return node
        if kind == "name":
            self.expect("(")
            if val in INTRINSICS:
                args = [self.parse_expr()]
                while self.peek().val == ",":
                    self.next()
                    args.append(self.parse_expr())
                self.expect(")")
                return Call(val, tuple(args),
                            span=self._span(tok, self.prev()))
            # array reference with constant signed-integer offsets
            offsets = [self._parse_offset()]
            while self.peek().val == ",":
                self.next()
                offsets.append(self._parse_offset())
            self.expect(")")
            return Ref(val, tuple(offsets), span=self._span(tok, self.prev()))
        self._err(f"unexpected token {val!r}", tok)

    def _parse_offset(self) -> int:
        sign = 1
        tok = self.next()
        while tok.val in ("-", "+"):
            if tok.val == "-":
                sign = -sign
            tok = self.next()
        kind, val = tok.kind, tok.val
        if kind != "num" or "." in val or "e" in val or "E" in val:
            self._err(
                f"offset must be an integer, got {val!r}", tok, code="SASA103"
            )
        return sign * int(val)

    def finish(self):
        if self.i != len(self.tokens):
            self._err(
                f"trailing tokens: {[t.val for t in self.tokens[self.i:]]}",
                self.peek(),
            )


_HEADER_RE = re.compile(
    r"^(?P<kw>kernel|iteration|iterate|boundary)\s*:\s*(?P<val>.+)$"
)
_DECL_RE = re.compile(
    r"^(?P<kw>input|local|output)\s+(?P<dtype>[A-Za-z_0-9]+)\s*:\s*"
    r"(?P<name>[A-Za-z_][A-Za-z_0-9]*)\s*\((?P<args>[^)]*)\)\s*"
    r"(?:=\s*(?P<expr>.*))?$"
)

_DTYPES = {
    "float": "float32",
    "float32": "float32",
    "double": "float64",
    "float64": "float64",
    "int": "int32",
    "int32": "int32",
    "uint16": "uint16",
    "bfloat16": "bfloat16",
}


def _parse_boundary(val: str, lineno: int, line: str) -> Boundary:
    def err(msg: str) -> DSLSyntaxError:
        return DSLSyntaxError(
            msg, code="SASA105", lineno=lineno,
            col=line.find(val) + 1 if val in line else 1, text=line,
        )

    parts = val.split()
    kind = parts[0]
    if kind not in BOUNDARY_KINDS:
        raise err(
            f"unknown boundary {kind!r} (expected one of "
            f"{', '.join(BOUNDARY_KINDS)})"
        )
    if kind == "constant":
        if len(parts) != 2:
            raise err(
                "'boundary: constant' needs exactly one value, e.g. "
                "'boundary: constant 1.5'"
            )
        try:
            value = float(parts[1])
        except ValueError:
            raise err(
                f"bad boundary constant {parts[1]!r} (must be a number)"
            ) from None
        try:
            return Boundary("constant", value)
        except ValueError as e:   # e.g. non-finite value
            raise err(str(e)) from None
    if len(parts) != 1:
        raise err(f"'boundary: {kind}' takes no value, got {val!r}")
    return Boundary(kind)


def _logical_lines(text: str) -> list[tuple[int, str]]:
    """Comment-stripped logical lines as ``(first_raw_lineno, text)``.

    A line continues the previous one when the previous line has
    unbalanced parens / ends with an operator, or the line starts with
    one.  Joined lines keep the line number of their first raw line;
    columns then index into the joined text.
    """
    out: list[tuple[int, str]] = []
    for lineno, raw in enumerate(text.splitlines(), start=1):
        line = raw.split("#", 1)[0].rstrip()
        if not line.strip():
            continue
        if out and (
            out[-1][1].count("(") != out[-1][1].count(")")
            or out[-1][1].rstrip().endswith(("+", "-", "*", "/", "=", "("))
            or line.lstrip().startswith(("+", "-", "*", "/", ")"))
        ):
            out[-1] = (out[-1][0], out[-1][1] + " " + line.strip())
        else:
            out.append((lineno, line.strip()))
    return out


def parse(text: str, strict: bool = False) -> StencilSpec:
    """Parse SASA DSL text into a validated :class:`StencilSpec`.

    With ``strict=True`` the parsed spec is additionally run through the
    static verifier (:func:`repro.core.analysis.verify`) and any
    error-severity diagnostic raises
    :class:`repro.core.analysis.VerificationError`.
    """
    name = None
    iterations = 1
    iterate = None
    boundary = Boundary("zero")
    inputs: dict[str, tuple[str, tuple[int, ...]]] = {}
    stages: list[Stage] = []

    for lineno, line in _logical_lines(text):
        def err(msg: str, code: str, col: int = 1) -> DSLSyntaxError:
            return DSLSyntaxError(
                msg, code=code, lineno=lineno, col=col, text=line
            )

        m = _HEADER_RE.match(line)
        if m:
            kw, val = m.group("kw"), m.group("val").strip()
            if kw == "kernel":
                name = val
            elif kw == "iteration":
                try:
                    iterations = int(val)
                except ValueError:
                    raise err(
                        f"bad iteration count {val!r} (must be an integer)",
                        "SASA105", m.start("val") + 1,
                    ) from None
                if iterations < 1:
                    raise err(
                        f"iteration count must be >= 1, got {iterations}",
                        "SASA105", m.start("val") + 1,
                    )
            elif kw == "boundary":
                boundary = _parse_boundary(val, lineno, line)
            else:
                iterate = val
            continue
        m = _DECL_RE.match(line)
        if not m:
            raise err(f"cannot parse line: {line!r}", "SASA104")
        kw = m.group("kw")
        dtype = _DTYPES.get(m.group("dtype"))
        if dtype is None:
            raise err(
                f"unsupported dtype {m.group('dtype')!r}", "SASA105",
                m.start("dtype") + 1,
            )
        arr = m.group("name")
        name_col = m.start("name") + 1
        args = [a.strip() for a in m.group("args").split(",") if a.strip()]
        if kw == "input":
            if m.group("expr"):
                raise err(
                    "input declarations cannot have an '='", "SASA104",
                    line.find("=") + 1,
                )
            if arr in inputs:
                raise err(
                    f"duplicate input declaration {arr!r} (a second "
                    "declaration would silently overwrite the first)",
                    "SASA107", name_col,
                )
            shape = tuple(int(a) for a in args)
            inputs[arr] = (dtype, shape)
        else:
            if not m.group("expr"):
                raise err(
                    f"{kw} declaration needs an '=' expression", "SASA104"
                )
            if arr in inputs:
                raise err(
                    f"{kw} stage {arr!r} shadows the input of the same "
                    "name; rename the stage", "SASA107", name_col,
                )
            if any(s.name == arr for s in stages):
                raise err(
                    f"duplicate stage declaration {arr!r}", "SASA107",
                    name_col,
                )
            if inputs:
                ndim = len(next(iter(inputs.values()))[1])
                if len(args) != ndim:
                    raise err(
                        f"{kw} {arr!r} declares {len(args)} offsets for a "
                        f"{ndim}-D stencil", "SASA103", name_col,
                    )
            parser = _ExprParser(
                m.group("expr"), line_no=lineno,
                col_base=m.start("expr") + 1, source_line=line,
            )
            expr = parser.parse_expr()
            parser.finish()
            stages.append(Stage(
                arr, dtype, expr, is_output=(kw == "output"),
                span=SourceSpan(lineno, name_col, len(line)),
            ))

    def top_err(msg: str) -> DSLSyntaxError:
        return DSLSyntaxError(msg, code="SASA106", lineno=1, col=1)

    if name is None:
        raise top_err("missing 'kernel:' line")
    if not inputs:
        raise top_err("missing 'input' declaration")
    if not stages:
        raise top_err("missing 'output' declaration")
    # output stage must come last; locals keep declaration order
    outputs = [s for s in stages if s.is_output]
    if len(outputs) != 1:
        raise top_err("exactly one output stage is required")
    stages = [s for s in stages if not s.is_output] + outputs
    if iterate is None:
        iterate = list(inputs)[-1]

    spec = StencilSpec(
        name=name,
        iterations=iterations,
        inputs=inputs,
        stages=tuple(stages),
        iterate_input=iterate,
        boundary=boundary,
    )
    spec.validate()
    if strict:
        from repro.core.analysis import verify_or_raise

        verify_or_raise(spec, source=text)
    return spec


# --------------------------------------------------------------------------
# Pretty-printer (inverse of parse)
# --------------------------------------------------------------------------

_DTYPE_NAMES = {
    "float32": "float",
    "float64": "double",
    "int32": "int",
    "uint16": "uint16",
    "bfloat16": "bfloat16",
}

_PREC = {"+": 1, "-": 1, "*": 2, "/": 2}


def _format_num(v: float) -> str:
    return repr(float(v))


def _format_expr(expr: Expr, prec: int = 0) -> str:
    if isinstance(expr, Num):
        s = _format_num(expr.value)
        # negative literals only exist after constant folding; print them
        # as the unary-minus form the tokenizer understands
        return f"({s})" if expr.value < 0 and prec > 0 else s
    if isinstance(expr, Ref):
        return f"{expr.name}({', '.join(str(o) for o in expr.offsets)})"
    if isinstance(expr, Call):
        args = ", ".join(_format_expr(a) for a in expr.args)
        return f"{expr.fn}({args})"
    if isinstance(expr, Neg):
        return f"-{_format_expr(expr.arg, prec=3)}"
    if isinstance(expr, BinOp):
        p = _PREC[expr.op]
        # right child parenthesized at equal precedence: the parser is
        # left-associative, so "a - b - c" != "a - (b - c)"
        s = (
            f"{_format_expr(expr.lhs, p)} {expr.op} "
            f"{_format_expr(expr.rhs, p + 1)}"
        )
        return f"({s})" if p < prec else s
    raise TypeError(f"cannot format expression node {expr!r}")


def format_spec(spec: StencilSpec) -> str:
    """Render a spec back to parseable DSL text.

    ``parse(format_spec(spec)) == spec`` for every parser-producible spec
    (round-trip identity, tested over the whole benchmark suite and all
    boundary modes; source spans are excluded from node equality, so the
    identity is unaffected by location info).  Lowered specs print too —
    ``Let`` bindings have no surface syntax, so they are inlined first;
    the round trip is then semantic rather than structural.
    """
    if any(
        isinstance(n, (Let, Var))
        for st in spec.stages
        for n in walk(st.expr)
    ):
        from repro.core.ir import inline_lets

        spec = dataclasses.replace(
            spec,
            stages=tuple(
                dataclasses.replace(st, expr=inline_lets(st.expr))
                for st in spec.stages
            ),
        )
    lines = [f"kernel: {spec.name}", f"iteration: {spec.iterations}"]
    if spec.boundary.kind != "zero":
        if spec.boundary.kind == "constant":
            lines.append(
                f"boundary: constant {_format_num(spec.boundary.value)}"
            )
        else:
            lines.append(f"boundary: {spec.boundary.kind}")
    lines.append(f"iterate: {spec.iterate_input}")
    for n, (dt, shape) in spec.inputs.items():
        dtname = _DTYPE_NAMES[str(dt)]
        lines.append(
            f"input {dtname}: {n}({', '.join(str(s) for s in shape)})"
        )
    zero_off = ", ".join("0" for _ in range(spec.ndim))
    for st in spec.stages:
        kw = "output" if st.is_output else "local"
        dtname = _DTYPE_NAMES[str(st.dtype)]
        lines.append(
            f"{kw} {dtname}: {st.name}({zero_off}) = "
            f"{_format_expr(st.expr)}"
        )
    return "\n".join(lines) + "\n"
