"""SASA stencil DSL parser (Section 4.1 of the paper).

Grammar (line oriented, ``#`` comments allowed)::

    kernel: NAME
    iteration: INT                     # >= 1
    iterate: NAME                      # optional; default = last input
    boundary: zero | constant FLOAT | replicate | periodic   # default zero
    input TYPE: NAME(INT, INT[, INT])
    local TYPE: NAME(off, off[, off]) = EXPR
    output TYPE: NAME(off, off[, off]) = EXPR

Expressions support ``+ - * /``, unary minus, parentheses, numeric literals,
array references ``name(o0, o1[, o2])`` with constant integer offsets, and
the intrinsics ``max(...)``, ``min(...)``, ``abs(...)`` (needed for e.g.
DILATE which is pure compare-select logic).

The reference SASA implementation uses textX; we use a small hand-rolled
recursive-descent parser to stay dependency-free.
"""
from __future__ import annotations

import dataclasses
import re

from repro.core.spec import (
    BOUNDARY_KINDS,
    BinOp,
    Boundary,
    Call,
    Expr,
    INTRINSICS,
    Let,
    Neg,
    Num,
    Ref,
    Stage,
    StencilSpec,
    Var,
    walk,
)

_TOKEN_RE = re.compile(
    r"\s*(?:(?P<num>\d+\.\d*(?:[eE][+-]?\d+)?|\.\d+(?:[eE][+-]?\d+)?|\d+(?:[eE][+-]?\d+)?)"
    r"|(?P<name>[A-Za-z_][A-Za-z_0-9]*)"
    r"|(?P<op>[-+*/(),]))"
)


class _ExprParser:
    def __init__(self, text: str):
        self.tokens: list[tuple[str, str]] = []
        pos = 0
        while pos < len(text):
            if text[pos:].strip() == "":
                break
            m = _TOKEN_RE.match(text, pos)
            if not m:
                raise SyntaxError(f"bad token at: {text[pos:]!r}")
            pos = m.end()
            for kind in ("num", "name", "op"):
                if m.group(kind) is not None:
                    self.tokens.append((kind, m.group(kind)))
                    break
        self.i = 0

    def peek(self):
        return self.tokens[self.i] if self.i < len(self.tokens) else (None, None)

    def next(self):
        tok = self.peek()
        self.i += 1
        return tok

    def expect(self, value: str):
        kind, val = self.next()
        if val != value:
            raise SyntaxError(f"expected {value!r}, got {val!r}")

    # expr := term (('+'|'-') term)*
    def parse_expr(self) -> Expr:
        node = self.parse_term()
        while self.peek()[1] in ("+", "-"):
            _, op = self.next()
            node = BinOp(op, node, self.parse_term())
        return node

    # term := factor (('*'|'/') factor)*
    def parse_term(self) -> Expr:
        node = self.parse_factor()
        while self.peek()[1] in ("*", "/"):
            _, op = self.next()
            node = BinOp(op, node, self.parse_factor())
        return node

    def parse_factor(self) -> Expr:
        kind, val = self.next()
        if val == "-":
            return Neg(self.parse_factor())
        if val == "+":
            return self.parse_factor()
        if kind == "num":
            return Num(float(val))
        if val == "(":
            node = self.parse_expr()
            self.expect(")")
            return node
        if kind == "name":
            self.expect("(")
            if val in INTRINSICS:
                args = [self.parse_expr()]
                while self.peek()[1] == ",":
                    self.next()
                    args.append(self.parse_expr())
                self.expect(")")
                return Call(val, tuple(args))
            # array reference with constant signed-integer offsets
            offsets = [self._parse_offset()]
            while self.peek()[1] == ",":
                self.next()
                offsets.append(self._parse_offset())
            self.expect(")")
            return Ref(val, tuple(offsets))
        raise SyntaxError(f"unexpected token {val!r}")

    def _parse_offset(self) -> int:
        sign = 1
        kind, val = self.next()
        while val in ("-", "+"):
            if val == "-":
                sign = -sign
            kind, val = self.next()
        if kind != "num" or "." in val or "e" in val or "E" in val:
            raise SyntaxError(f"offset must be an integer, got {val!r}")
        return sign * int(val)

    def finish(self):
        if self.i != len(self.tokens):
            raise SyntaxError(f"trailing tokens: {self.tokens[self.i:]}")


_HEADER_RE = re.compile(
    r"^(?P<kw>kernel|iteration|iterate|boundary)\s*:\s*(?P<val>.+)$"
)
_DECL_RE = re.compile(
    r"^(?P<kw>input|local|output)\s+(?P<dtype>[A-Za-z_0-9]+)\s*:\s*"
    r"(?P<name>[A-Za-z_][A-Za-z_0-9]*)\s*\((?P<args>[^)]*)\)\s*"
    r"(?:=\s*(?P<expr>.*))?$"
)

_DTYPES = {
    "float": "float32",
    "float32": "float32",
    "double": "float64",
    "float64": "float64",
    "int": "int32",
    "int32": "int32",
    "uint16": "uint16",
    "bfloat16": "bfloat16",
}


def _parse_boundary(val: str) -> Boundary:
    parts = val.split()
    kind = parts[0]
    if kind not in BOUNDARY_KINDS:
        raise SyntaxError(
            f"unknown boundary {kind!r} (expected one of "
            f"{', '.join(BOUNDARY_KINDS)})"
        )
    if kind == "constant":
        if len(parts) != 2:
            raise SyntaxError(
                "'boundary: constant' needs exactly one value, e.g. "
                "'boundary: constant 1.5'"
            )
        try:
            value = float(parts[1])
        except ValueError:
            raise SyntaxError(
                f"bad boundary constant {parts[1]!r} (must be a number)"
            ) from None
        try:
            return Boundary("constant", value)
        except ValueError as e:   # e.g. non-finite value
            raise SyntaxError(str(e)) from None
    if len(parts) != 1:
        raise SyntaxError(
            f"'boundary: {kind}' takes no value, got {val!r}"
        )
    return Boundary(kind)


def parse(text: str) -> StencilSpec:
    """Parse SASA DSL text into a validated :class:`StencilSpec`."""
    name = None
    iterations = 1
    iterate = None
    boundary = Boundary("zero")
    inputs: dict[str, tuple[str, tuple[int, ...]]] = {}
    stages: list[Stage] = []

    # join continuation lines: a line that is a continuation starts with an
    # operator or the previous line ends with one / has unbalanced parens
    logical_lines: list[str] = []
    for raw in text.splitlines():
        line = raw.split("#", 1)[0].rstrip()
        if not line.strip():
            continue
        if logical_lines and (
            logical_lines[-1].count("(") != logical_lines[-1].count(")")
            or logical_lines[-1].rstrip().endswith(("+", "-", "*", "/", "=", "("))
            or line.lstrip().startswith(("+", "-", "*", "/", ")"))
        ):
            logical_lines[-1] += " " + line.strip()
        else:
            logical_lines.append(line.strip())

    for line in logical_lines:
        m = _HEADER_RE.match(line)
        if m:
            kw, val = m.group("kw"), m.group("val").strip()
            if kw == "kernel":
                name = val
            elif kw == "iteration":
                try:
                    iterations = int(val)
                except ValueError:
                    raise SyntaxError(
                        f"bad iteration count {val!r} (must be an integer)"
                    ) from None
                if iterations < 1:
                    raise SyntaxError(
                        f"iteration count must be >= 1, got {iterations}"
                    )
            elif kw == "boundary":
                boundary = _parse_boundary(val)
            else:
                iterate = val
            continue
        m = _DECL_RE.match(line)
        if not m:
            raise SyntaxError(f"cannot parse line: {line!r}")
        kw = m.group("kw")
        dtype = _DTYPES.get(m.group("dtype"))
        if dtype is None:
            raise SyntaxError(f"unsupported dtype {m.group('dtype')!r}")
        arr = m.group("name")
        args = [a.strip() for a in m.group("args").split(",") if a.strip()]
        if kw == "input":
            if m.group("expr"):
                raise SyntaxError("input declarations cannot have an '='")
            if arr in inputs:
                raise SyntaxError(
                    f"duplicate input declaration {arr!r} (a second "
                    "declaration would silently overwrite the first)"
                )
            shape = tuple(int(a) for a in args)
            inputs[arr] = (dtype, shape)
        else:
            if not m.group("expr"):
                raise SyntaxError(f"{kw} declaration needs an '=' expression")
            if arr in inputs:
                raise SyntaxError(
                    f"{kw} stage {arr!r} shadows the input of the same "
                    "name; rename the stage"
                )
            if any(s.name == arr for s in stages):
                raise SyntaxError(f"duplicate stage declaration {arr!r}")
            if inputs:
                ndim = len(next(iter(inputs.values()))[1])
                if len(args) != ndim:
                    raise SyntaxError(
                        f"{kw} {arr!r} declares {len(args)} offsets for a "
                        f"{ndim}-D stencil"
                    )
            parser = _ExprParser(m.group("expr"))
            expr = parser.parse_expr()
            parser.finish()
            stages.append(Stage(arr, dtype, expr, is_output=(kw == "output")))

    if name is None:
        raise SyntaxError("missing 'kernel:' line")
    if not inputs:
        raise SyntaxError("missing 'input' declaration")
    if not stages:
        raise SyntaxError("missing 'output' declaration")
    # output stage must come last; locals keep declaration order
    outputs = [s for s in stages if s.is_output]
    if len(outputs) != 1:
        raise SyntaxError("exactly one output stage is required")
    stages = [s for s in stages if not s.is_output] + outputs
    if iterate is None:
        iterate = list(inputs)[-1]

    spec = StencilSpec(
        name=name,
        iterations=iterations,
        inputs=inputs,
        stages=tuple(stages),
        iterate_input=iterate,
        boundary=boundary,
    )
    spec.validate()
    return spec


# --------------------------------------------------------------------------
# Pretty-printer (inverse of parse)
# --------------------------------------------------------------------------

_DTYPE_NAMES = {
    "float32": "float",
    "float64": "double",
    "int32": "int",
    "uint16": "uint16",
    "bfloat16": "bfloat16",
}

_PREC = {"+": 1, "-": 1, "*": 2, "/": 2}


def _format_num(v: float) -> str:
    return repr(float(v))


def _format_expr(expr: Expr, prec: int = 0) -> str:
    if isinstance(expr, Num):
        s = _format_num(expr.value)
        # negative literals only exist after constant folding; print them
        # as the unary-minus form the tokenizer understands
        return f"({s})" if expr.value < 0 and prec > 0 else s
    if isinstance(expr, Ref):
        return f"{expr.name}({', '.join(str(o) for o in expr.offsets)})"
    if isinstance(expr, Call):
        args = ", ".join(_format_expr(a) for a in expr.args)
        return f"{expr.fn}({args})"
    if isinstance(expr, Neg):
        return f"-{_format_expr(expr.arg, prec=3)}"
    if isinstance(expr, BinOp):
        p = _PREC[expr.op]
        # right child parenthesized at equal precedence: the parser is
        # left-associative, so "a - b - c" != "a - (b - c)"
        s = (
            f"{_format_expr(expr.lhs, p)} {expr.op} "
            f"{_format_expr(expr.rhs, p + 1)}"
        )
        return f"({s})" if p < prec else s
    raise TypeError(f"cannot format expression node {expr!r}")


def format_spec(spec: StencilSpec) -> str:
    """Render a spec back to parseable DSL text.

    ``parse(format_spec(spec)) == spec`` for every parser-producible spec
    (round-trip identity, tested over the whole benchmark suite and all
    boundary modes).  Lowered specs print too — ``Let`` bindings have no
    surface syntax, so they are inlined first; the round trip is then
    semantic rather than structural.
    """
    if any(
        isinstance(n, (Let, Var))
        for st in spec.stages
        for n in walk(st.expr)
    ):
        from repro.core.ir import inline_lets

        spec = dataclasses.replace(
            spec,
            stages=tuple(
                dataclasses.replace(st, expr=inline_lets(st.expr))
                for st in spec.stages
            ),
        )
    lines = [f"kernel: {spec.name}", f"iteration: {spec.iterations}"]
    if spec.boundary.kind != "zero":
        if spec.boundary.kind == "constant":
            lines.append(
                f"boundary: constant {_format_num(spec.boundary.value)}"
            )
        else:
            lines.append(f"boundary: {spec.boundary.kind}")
    lines.append(f"iterate: {spec.iterate_input}")
    for n, (dt, shape) in spec.inputs.items():
        dtname = _DTYPE_NAMES[str(dt)]
        lines.append(
            f"input {dtname}: {n}({', '.join(str(s) for s in shape)})"
        )
    zero_off = ", ".join("0" for _ in range(spec.ndim))
    for st in spec.stages:
        kw = "output" if st.is_output else "local"
        dtname = _DTYPE_NAMES[str(st.dtype)]
        lines.append(
            f"{kw} {dtname}: {st.name}({zero_off}) = "
            f"{_format_expr(st.expr)}"
        )
    return "\n".join(lines) + "\n"
