"""SASA stencil DSL parser (Section 4.1 of the paper).

Grammar (line oriented, ``#`` comments allowed)::

    kernel: NAME
    iteration: INT
    iterate: NAME                      # optional; default = last input
    input TYPE: NAME(INT, INT[, INT])
    local TYPE: NAME(off, off[, off]) = EXPR
    output TYPE: NAME(off, off[, off]) = EXPR

Expressions support ``+ - * /``, unary minus, parentheses, numeric literals,
array references ``name(o0, o1[, o2])`` with constant integer offsets, and
the intrinsics ``max(...)``, ``min(...)``, ``abs(...)`` (needed for e.g.
DILATE which is pure compare-select logic).

The reference SASA implementation uses textX; we use a small hand-rolled
recursive-descent parser to stay dependency-free.
"""
from __future__ import annotations

import re

from repro.core.spec import BinOp, Call, Expr, INTRINSICS, Neg, Num, Ref, Stage, StencilSpec

_TOKEN_RE = re.compile(
    r"\s*(?:(?P<num>\d+\.\d*(?:[eE][+-]?\d+)?|\.\d+(?:[eE][+-]?\d+)?|\d+(?:[eE][+-]?\d+)?)"
    r"|(?P<name>[A-Za-z_][A-Za-z_0-9]*)"
    r"|(?P<op>[-+*/(),]))"
)


class _ExprParser:
    def __init__(self, text: str):
        self.tokens: list[tuple[str, str]] = []
        pos = 0
        while pos < len(text):
            if text[pos:].strip() == "":
                break
            m = _TOKEN_RE.match(text, pos)
            if not m:
                raise SyntaxError(f"bad token at: {text[pos:]!r}")
            pos = m.end()
            for kind in ("num", "name", "op"):
                if m.group(kind) is not None:
                    self.tokens.append((kind, m.group(kind)))
                    break
        self.i = 0

    def peek(self):
        return self.tokens[self.i] if self.i < len(self.tokens) else (None, None)

    def next(self):
        tok = self.peek()
        self.i += 1
        return tok

    def expect(self, value: str):
        kind, val = self.next()
        if val != value:
            raise SyntaxError(f"expected {value!r}, got {val!r}")

    # expr := term (('+'|'-') term)*
    def parse_expr(self) -> Expr:
        node = self.parse_term()
        while self.peek()[1] in ("+", "-"):
            _, op = self.next()
            node = BinOp(op, node, self.parse_term())
        return node

    # term := factor (('*'|'/') factor)*
    def parse_term(self) -> Expr:
        node = self.parse_factor()
        while self.peek()[1] in ("*", "/"):
            _, op = self.next()
            node = BinOp(op, node, self.parse_factor())
        return node

    def parse_factor(self) -> Expr:
        kind, val = self.next()
        if val == "-":
            return Neg(self.parse_factor())
        if val == "+":
            return self.parse_factor()
        if kind == "num":
            return Num(float(val))
        if val == "(":
            node = self.parse_expr()
            self.expect(")")
            return node
        if kind == "name":
            self.expect("(")
            if val in INTRINSICS:
                args = [self.parse_expr()]
                while self.peek()[1] == ",":
                    self.next()
                    args.append(self.parse_expr())
                self.expect(")")
                return Call(val, tuple(args))
            # array reference with constant signed-integer offsets
            offsets = [self._parse_offset()]
            while self.peek()[1] == ",":
                self.next()
                offsets.append(self._parse_offset())
            self.expect(")")
            return Ref(val, tuple(offsets))
        raise SyntaxError(f"unexpected token {val!r}")

    def _parse_offset(self) -> int:
        sign = 1
        kind, val = self.next()
        while val in ("-", "+"):
            if val == "-":
                sign = -sign
            kind, val = self.next()
        if kind != "num" or "." in val or "e" in val or "E" in val:
            raise SyntaxError(f"offset must be an integer, got {val!r}")
        return sign * int(val)

    def finish(self):
        if self.i != len(self.tokens):
            raise SyntaxError(f"trailing tokens: {self.tokens[self.i:]}")


_HEADER_RE = re.compile(
    r"^(?P<kw>kernel|iteration|iterate)\s*:\s*(?P<val>.+)$"
)
_DECL_RE = re.compile(
    r"^(?P<kw>input|local|output)\s+(?P<dtype>[A-Za-z_0-9]+)\s*:\s*"
    r"(?P<name>[A-Za-z_][A-Za-z_0-9]*)\s*\((?P<args>[^)]*)\)\s*"
    r"(?:=\s*(?P<expr>.*))?$"
)

_DTYPES = {
    "float": "float32",
    "float32": "float32",
    "double": "float64",
    "float64": "float64",
    "int": "int32",
    "int32": "int32",
    "uint16": "uint16",
    "bfloat16": "bfloat16",
}


def parse(text: str) -> StencilSpec:
    """Parse SASA DSL text into a validated :class:`StencilSpec`."""
    name = None
    iterations = 1
    iterate = None
    inputs: dict[str, tuple[str, tuple[int, ...]]] = {}
    stages: list[Stage] = []

    # join continuation lines: a line that is a continuation starts with an
    # operator or the previous line ends with one / has unbalanced parens
    logical_lines: list[str] = []
    for raw in text.splitlines():
        line = raw.split("#", 1)[0].rstrip()
        if not line.strip():
            continue
        if logical_lines and (
            logical_lines[-1].count("(") != logical_lines[-1].count(")")
            or logical_lines[-1].rstrip().endswith(("+", "-", "*", "/", "=", "("))
            or line.lstrip().startswith(("+", "-", "*", "/", ")"))
        ):
            logical_lines[-1] += " " + line.strip()
        else:
            logical_lines.append(line.strip())

    for line in logical_lines:
        m = _HEADER_RE.match(line)
        if m:
            kw, val = m.group("kw"), m.group("val").strip()
            if kw == "kernel":
                name = val
            elif kw == "iteration":
                iterations = int(val)
            else:
                iterate = val
            continue
        m = _DECL_RE.match(line)
        if not m:
            raise SyntaxError(f"cannot parse line: {line!r}")
        kw = m.group("kw")
        dtype = _DTYPES.get(m.group("dtype"))
        if dtype is None:
            raise SyntaxError(f"unsupported dtype {m.group('dtype')!r}")
        arr = m.group("name")
        args = [a.strip() for a in m.group("args").split(",") if a.strip()]
        if kw == "input":
            if m.group("expr"):
                raise SyntaxError("input declarations cannot have an '='")
            shape = tuple(int(a) for a in args)
            inputs[arr] = (dtype, shape)
        else:
            if not m.group("expr"):
                raise SyntaxError(f"{kw} declaration needs an '=' expression")
            if inputs:
                ndim = len(next(iter(inputs.values()))[1])
                if len(args) != ndim:
                    raise SyntaxError(
                        f"{kw} {arr!r} declares {len(args)} offsets for a "
                        f"{ndim}-D stencil"
                    )
            parser = _ExprParser(m.group("expr"))
            expr = parser.parse_expr()
            parser.finish()
            stages.append(Stage(arr, dtype, expr, is_output=(kw == "output")))

    if name is None:
        raise SyntaxError("missing 'kernel:' line")
    if not inputs:
        raise SyntaxError("missing 'input' declaration")
    if not stages:
        raise SyntaxError("missing 'output' declaration")
    # output stage must come last; locals keep declaration order
    outputs = [s for s in stages if s.is_output]
    if len(outputs) != 1:
        raise SyntaxError("exactly one output stage is required")
    stages = [s for s in stages if not s.is_output] + outputs
    if iterate is None:
        iterate = list(inputs)[-1]

    spec = StencilSpec(
        name=name,
        iterations=iterations,
        inputs=inputs,
        stages=tuple(stages),
        iterate_input=iterate,
    )
    spec.validate()
    return spec
