"""SASA analytical performance model (paper Section 4.2) + TPU re-derivation.

Part 1 — paper-exact model (Eqs. 1-9) in FPGA cycles for the Alveo U280.
  Used to reproduce the paper's own parallelism decisions (Table 3) and the
  SODA-vs-SASA speedups (Sec. 5.4).  Resource estimates per PE are a
  microarchitectural byte/op model calibrated against the paper's reported
  max-PE counts (Figs. 18-20); they stand in for the Vitis HLS synthesis
  report that step 2 of the paper's tool flow runs.

Part 2 — TPU model.  Same five parallelism variants, re-derived for the TPU
  memory hierarchy:

    FPGA concept                      TPU concept
    ------------                      -----------
    PE streaming one HBM bank         chip streaming its own HBM
    U parallel PUs (512b AXI)         8x128 VPU lanes on a VMEM tile
    s cascaded PEs (FIFO dataflow)    s fused stencil iterations per VMEM
                                      residency (temporal blocking)
    k PEs on k HBM banks              k chips, grid row-sharded (shard_map)
    border streaming wires            jax.lax.ppermute over ICI
    redundant halo compute            redundant halo compute (identical)

  Latency per round = max(compute, HBM, ICI-bandwidth) + ICI latency terms,
  times the number of rounds ceil(iter/s).  The model returns all three
  roofline terms so the auto-tuner can report the dominant bottleneck.
"""
from __future__ import annotations

import dataclasses
import math
from repro.core.platform import FPGAPlatform, TPUPlatform
from repro.core.spec import BinOp, Call, Neg, StencilSpec, walk

VARIANTS = ("temporal", "spatial_r", "spatial_s", "hybrid_r", "hybrid_s")


@dataclasses.dataclass(frozen=True)
class ParallelismConfig:
    """A point in the SASA design space."""

    variant: str          # one of VARIANTS
    k: int = 1            # degree of spatial parallelism (devices / PE groups)
    s: int = 1            # degree of temporal parallelism (stages / fusion depth)
    tile_rows: int = 0    # TPU only: Pallas row-tile B (0 = executor default)
    batch_tile: int = 0   # TPU only: batch entries folded into the kernel grid
                          # per step (0 = whole batch under vmap)
    buffer_depth: int = 0  # TPU only: explicit HBM->VMEM buffers per stream.
                          # 0 = one-shot whole-block kernels under vmap
                          # (copy/compute overlap left to XLA); >= 2 = the
                          # explicitly double-buffered tile pipeline.

    def __post_init__(self):
        assert self.variant in VARIANTS, self.variant
        assert self.batch_tile >= 0, self.batch_tile
        assert self.buffer_depth in (0,) or self.buffer_depth >= 2, (
            "buffer_depth is 0 (vmapped one-shot) or >= 2 (pipelined); "
            "a single buffer cannot overlap copy with compute"
        )

    @property
    def devices_needed(self) -> int:
        """Device count this config occupies (temporal stages map to
        devices; every executor must size device pools from this)."""
        return max(self.s, 1) if self.variant == "temporal" else max(self.k, 1)


@dataclasses.dataclass(frozen=True)
class Prediction:
    config: ParallelismConfig
    latency: float              # seconds
    compute_term: float         # seconds
    memory_term: float          # seconds
    collective_term: float      # seconds
    collective_bytes: float     # per-device bytes over the whole run
    hbm_bytes: float            # per-device bytes over the whole run
    flops: float                # per-device ops over the whole run
    rounds: int
    vmem_bytes: float = 0.0     # peak VMEM working set the design schedules
    notes: str = ""

    @property
    def bottleneck(self) -> str:
        terms = {
            "compute": self.compute_term,
            "memory": self.memory_term,
            "collective": self.collective_term,
        }
        return max(terms, key=terms.get)

    @property
    def gcells_per_s(self) -> float:
        return 0.0  # filled by caller with grid knowledge; see predict()


# ===========================================================================
# Part 1: paper-exact FPGA model (Eqs. 1-9)
# ===========================================================================


def _op_mix(spec: StencilSpec) -> dict[str, int]:
    mix = {"add": 0, "mul": 0, "div": 0, "cmp": 0}
    for stage in spec.stages:
        for node in walk(stage.expr):
            if isinstance(node, BinOp):
                if node.op in "+-":
                    mix["add"] += 1
                elif node.op == "*":
                    mix["mul"] += 1
                else:
                    mix["div"] += 1
            elif isinstance(node, Call):
                mix["cmp"] += max(len(node.args) - 1, 1)
            elif isinstance(node, Neg):
                mix["add"] += 1
    return mix


def estimate_pe_resources(
    spec: StencilSpec, fpga: FPGAPlatform, U: int = 16
) -> dict[str, float]:
    """Per-PE resource vector (stand-in for the Vitis HLS synthesis report).

    Cost constants are fp32 operator costs on UltraScale+ (DSP48E2), with
    streaming infrastructure overhead calibrated so the derived max-PE
    counts match the paper's Figs. 18-20 (JACOBI2D 21, DILATE 18,
    HOTSPOT 9, others 9-15 on U280).
    """
    mix = _op_mix(spec)
    # DSPs: fp32 add/sub=2, mul=3, div=0 (LUT-heavy), cmp=0; one op set per PU.
    dsp = U * (2 * mix["add"] + 3 * mix["mul"])
    # LUTs: per-PU datapath + per-PE streaming infra + reuse-buffer muxing.
    lut = (
        9_000  # AXI-stream plumbing, control FSM
        + U * (120 * mix["add"] + 90 * mix["mul"] + 3_000 * mix["div"]
               + 150 * mix["cmp"])
        + 250 * spec.points * (1 + spec.radius)
    )
    ff = 2.2 * lut
    # BRAM: coalesced reuse buffer holds `halo` rows of every streamed input
    # at 512b width (Sec. 3.1).  4.5 KiB per BRAM36.
    reuse_bytes = (
        spec.halo * spec.cols_flat * spec.itemsize * max(spec.num_inputs, 1)
    )
    bram = max(2.0, reuse_bytes / 4608) + 4 * spec.num_inputs
    return {"lut": lut, "ff": ff, "dsp": float(dsp), "bram": bram}


def fpga_pe_res(spec: StencilSpec, fpga: FPGAPlatform, U: int = 16) -> int:
    """Eq. 1: resource-bound PE count."""
    res = estimate_pe_resources(spec, fpga, U)
    avail = {
        "lut": fpga.luts,
        "ff": fpga.ffs,
        "dsp": fpga.dsps,
        "bram": fpga.brams,
    }
    bound = min(fpga.alpha * avail[r] / max(res[r], 1e-9) for r in avail)
    return max(int(bound), 1)


def fpga_pe_bw(spec: StencilSpec, fpga: FPGAPlatform) -> int:
    """Eq. 2: bandwidth-bound spatial PE count."""
    banks_per_pe = spec.num_inputs + 1
    return max((fpga.hbm_banks - fpga.reserved_banks) // banks_per_pe, 1)


def fpga_max_pe(spec: StencilSpec, fpga: FPGAPlatform, s: int = 1) -> int:
    """Eq. 3 (temporal stages need no extra bandwidth)."""
    return min(fpga_pe_res(spec, fpga), fpga_pe_bw(spec, fpga) * max(s, 1))


def _fpga_latency_cycles(
    spec: StencilSpec, cfg: ParallelismConfig, fpga: FPGAPlatform, U: int = 16
) -> float:
    """Eqs. 4-8, verbatim (two-dimensional view: R rows x C flat columns)."""
    R, C = spec.rows, spec.cols_flat
    it = spec.iterations
    r = spec.radius
    d = halo = 2 * r
    k, s = cfg.k, cfg.s
    if cfg.variant == "temporal":
        return math.ceil((R + d * (s - 1)) * C / U) * math.ceil(it / s)
    if cfg.variant == "spatial_r":
        iter_avg = it / 2.0  # paper: halo shrinks over iterations, avg iter/2
        return math.ceil((math.ceil(R / k) + halo * iter_avg) * C / U) * it
    if cfg.variant == "spatial_s":
        return math.ceil((math.ceil(R / k) + halo) * C / U) * it
    if cfg.variant == "hybrid_r":
        iter_avg = it / 2.0
        return (
            math.ceil((math.ceil(R / k) + halo * iter_avg) * C / U)
            * math.ceil(it / s)
        )
    if cfg.variant == "hybrid_s":
        return (
            math.ceil((math.ceil(R / k) + halo * s) * C / U)
            * math.ceil(it / s)
        )
    raise ValueError(cfg.variant)


def predict_fpga(
    spec: StencilSpec, cfg: ParallelismConfig, fpga: FPGAPlatform, U: int = 16
) -> Prediction:
    cycles = _fpga_latency_cycles(spec, cfg, fpga, U)
    lat = cycles / fpga.freq_hz
    # Roofline bookkeeping for reporting parity with the TPU model.
    hbm = spec.cells * spec.itemsize * (spec.num_inputs + 1)
    if cfg.variant in ("spatial_r", "spatial_s"):
        hbm *= spec.iterations
    else:
        hbm *= math.ceil(spec.iterations / max(cfg.s, 1))
    return Prediction(
        config=cfg,
        latency=lat,
        compute_term=lat,
        memory_term=hbm / (cfg.k * fpga.bank_bw * max(spec.num_inputs, 1)),
        collective_term=0.0,
        collective_bytes=0.0,
        hbm_bytes=hbm / max(cfg.k, 1),
        flops=spec.cells * spec.ops_per_cell * spec.iterations / max(cfg.k, 1),
        rounds=math.ceil(spec.iterations / max(cfg.s, 1)),
    )


def fpga_candidate_configs(
    spec: StencilSpec,
    fpga: FPGAPlatform,
    U: int = 16,
    pe_res_override: int | None = None,
) -> list[ParallelismConfig]:
    """Step 3 of the tool flow (Sec. 4.3): the candidate set the paper explores.

    ``pe_res_override`` lets callers substitute a synthesizer-reported
    resource-bound PE count (the paper obtains this from Vitis HLS, Figs.
    18-20) for our analytical resource estimate.
    """
    pe_res = pe_res_override or fpga_pe_res(spec, fpga, U)
    pe_bw = fpga_pe_bw(spec, fpga)
    out = []
    # temporal: s_t = #PE_res, capped by iteration count
    out.append(ParallelismConfig("temporal", k=1, s=min(pe_res, spec.iterations)))
    # spatial: k = Max#PE (s=1)
    max_pe1 = min(pe_res, pe_bw)
    out.append(ParallelismConfig("spatial_r", k=max_pe1, s=1))
    out.append(ParallelismConfig("spatial_s", k=max_pe1, s=1))
    # hybrid: k multiple of #SLRs, k*s <= Max#PE(s), k <= PE_bw
    for k in range(fpga.num_slrs, pe_bw + 1, fpga.num_slrs):
        s = max(min(pe_res // k, spec.iterations), 1)
        if s >= 1 and k * s <= pe_res:
            out.append(ParallelismConfig("hybrid_r", k=k, s=s))
            out.append(ParallelismConfig("hybrid_s", k=k, s=s))
    return out


# ===========================================================================
# Part 2: TPU model
# ===========================================================================


def vmem_fusion_limit(
    spec: StencilSpec, tpu: TPUPlatform, tile_rows: int
) -> int:
    """Max fusion depth s such that a (B + 2sr) x C_pad tile (double-buffered,
    all streamed inputs + output + one intermediate) fits in VMEM.

    This is the TPU analogue of Eq. 1's resource bound: FPGA LUT/DSP/BRAM
    capacity becomes VMEM capacity.
    """
    r = spec.radius
    C = spec.cols_flat
    n_arrays = spec.num_inputs + 2  # inputs + working copy + output
    s = 1
    while True:
        rows = tile_rows + 2 * (s + 1) * r
        cpad = _round_up(C + 2 * (s + 1) * r, 128)
        if rows * cpad * spec.itemsize * n_arrays * 2 > tpu.vmem_bytes:
            return max(s, 1)
        s += 1
        if s > 256:
            return 256


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def predict_tpu(
    spec: StencilSpec,
    cfg: ParallelismConfig,
    tpu: TPUPlatform,
    iterations: int | None = None,
) -> Prediction:
    """TPU latency model for one parallelism configuration.

    Derivation mirrors Eqs. 4-8 but in seconds against the chip roofline:

      * a fused-s kernel pass reads (inputs) and writes (1) each grid cell
        once per round -> HBM term;
      * fused iterations recompute a trapezoid halo: iteration t of a round
        computes (rows_local + 2*r*(s-t)) rows -> compute term;
      * spatial_s exchanges r rows/iteration, hybrid_s s*r rows/round,
        *_r variants exchange iter*r rows once -> collective term.
    """
    it = spec.iterations if iterations is None else iterations
    R, C = spec.rows, spec.cols_flat
    r = spec.radius
    ops = spec.ops_per_cell
    k, s = cfg.k, cfg.s
    itemsize = spec.itemsize
    n_in = spec.num_inputs

    if cfg.variant == "temporal":
        k = 1
    if cfg.variant in ("spatial_r", "spatial_s"):
        s = 1
    s = max(min(s, it), 1)
    rounds = math.ceil(it / s)
    rows_local = math.ceil(R / k)

    # ---- redundant halo rows computed per round (per device) ----
    if cfg.variant in ("spatial_r", "hybrid_r"):
        # halo depth at iteration t (global) is (it - t) * r, averaged it/2
        redundant_rows_per_iter = 2 * r * (it / 2.0) if k > 1 else 0.0
    elif cfg.variant in ("spatial_s", "hybrid_s"):
        redundant_rows_per_iter = 2 * r * ((s - 1) / 2.0) if k > 1 else 0.0
    else:  # temporal: fused trapezoid within the single device's tiles
        redundant_rows_per_iter = 0.0

    # fused-kernel trapezoid overhead inside each tile (any fused variant):
    tile = cfg.tile_rows or 256
    n_tiles = math.ceil(rows_local / tile)
    trapezoid_rows_per_iter = 2 * r * ((s - 1) / 2.0) * n_tiles

    compute_rows = (
        rows_local + redundant_rows_per_iter + trapezoid_rows_per_iter
    ) * it
    flops = compute_rows * C * ops
    compute_term = flops / tpu.vpu_flops_f32

    # ---- HBM traffic ----
    # per round: read all inputs (+halo overlap), write output once.
    halo_rows_read = 2 * s * r * n_tiles
    bytes_per_round = (
        (n_in * (rows_local + halo_rows_read) + rows_local)
        * C * itemsize
    )
    hbm_bytes = bytes_per_round * rounds
    memory_term = hbm_bytes / tpu.hbm_bw

    # ---- ICI ----
    if k <= 1:
        coll_bytes, n_msgs = 0.0, 0
    elif cfg.variant in ("spatial_r", "hybrid_r"):
        coll_bytes = 2 * min(it * r, rows_local) * C * itemsize * n_in
        n_msgs = 2
    elif cfg.variant == "spatial_s":
        coll_bytes = 2 * r * C * itemsize * it
        n_msgs = 2 * it
    else:  # hybrid_s
        coll_bytes = 2 * min(s * r, rows_local) * C * itemsize * rounds
        n_msgs = 2 * rounds
    collective_term = coll_bytes / tpu.ici_bw + n_msgs * tpu.ici_latency

    # ---- VMEM footprint / pipeline overlap ----
    # Working set of one (tile + 2sr) x C_pad residency: every streamed
    # input block, one working copy, one output block.
    in_rows = tile + 2 * s * r
    cpad = _round_up(C + 2 * s * r, 128)
    tile_bytes = in_rows * cpad * itemsize * (n_in + 2)
    if cfg.buffer_depth >= 2:
        # Explicitly pipelined tile loop: HBM->VMEM copies for step i+1 are
        # issued while step i computes, so copy/compute overlap is scheduled
        # rather than hoped for.  The price is the pipeline fill — the
        # (depth-1) tile transfers before the first compute of each round —
        # and a buffer_depth-deep VMEM footprint.
        # One fill per kernel launch (per round); with the batch axis
        # folded into the grid the launch streams batch_tile * n_tiles
        # tiles, so per-grid fill cost amortizes over both.
        vmem_bytes = float(cfg.buffer_depth * tile_bytes)
        steps_per_launch = max(n_tiles * max(cfg.batch_tile, 1), 1)
        fill_term = (
            (cfg.buffer_depth - 1)
            * memory_term / max(steps_per_launch, 1)
        )
        overlap_penalty = 0.0
        notes = "tile-pipelined"
        if vmem_bytes > tpu.vmem_bytes:
            # Infeasible residency: the schedule would thrash VMEM.  Keep
            # the candidate rankable but never preferable.
            fill_term += memory_term + compute_term
            notes = "tile-pipelined (VMEM overflow)"
    else:
        # One-shot whole-block kernels under vmap: XLA's implicit double
        # buffering overlaps only part of the copy with compute, so the
        # hidden term leaks back into latency (modelled as half the
        # smaller roofline term, the overhead-decomposition idiom).
        vmem_bytes = float(2 * tile_bytes)
        fill_term = 0.0
        overlap_penalty = 0.5 * min(compute_term, memory_term)
        notes = ""

    # Dataflow overlap: compute and HBM stream concurrently (the TPU DMA
    # engine double-buffers VMEM tiles), collectives serialize with rounds
    # only for the *_s variants; *_r pay it once up front.
    latency = (
        max(compute_term, memory_term)
        + overlap_penalty + fill_term + collective_term
    )
    return Prediction(
        config=cfg,
        latency=latency,
        compute_term=compute_term,
        memory_term=memory_term,
        collective_term=collective_term,
        collective_bytes=coll_bytes,
        hbm_bytes=hbm_bytes,
        flops=flops,
        rounds=rounds,
        vmem_bytes=vmem_bytes,
        notes=notes,
    )


def tpu_candidate_configs(
    spec: StencilSpec, tpu: TPUPlatform, iterations: int | None = None
) -> list[ParallelismConfig]:
    """Enumerate the design space on a TPU slice (analogue of Sec. 4.3 step 3)."""
    it = spec.iterations if iterations is None else iterations
    R = spec.rows
    r = spec.radius
    n = tpu.num_chips
    ks = sorted({k for k in range(1, n + 1) if n % k == 0})
    tile = 256
    s_max_vmem = vmem_fusion_limit(spec, tpu, tile)
    out: list[ParallelismConfig] = []
    for s in _fusion_depths(min(it, s_max_vmem)):
        out.append(ParallelismConfig("temporal", k=1, s=s, tile_rows=tile))
        # Batch-in-grid tile pipeline: same fusion depth, but the batch
        # axis is folded into the kernel grid and HBM->VMEM copies are
        # explicitly double-buffered.  vmem_fusion_limit already bounds s
        # to a 2-deep residency, so depth-2 candidates are always feasible.
        out.append(ParallelismConfig(
            "temporal", k=1, s=s, tile_rows=tile,
            batch_tile=8, buffer_depth=2,
        ))
    for k in ks:
        if k == 1:
            continue
        rows_local = R // k
        if rows_local < 2 * r:
            continue
        if it * r <= rows_local:
            out.append(ParallelismConfig("spatial_r", k=k, s=1, tile_rows=tile))
        out.append(ParallelismConfig("spatial_s", k=k, s=1, tile_rows=tile))
        for s in _fusion_depths(min(it, s_max_vmem)):
            if s <= 1:
                continue
            if s * r <= rows_local:
                out.append(
                    ParallelismConfig("hybrid_s", k=k, s=s, tile_rows=tile)
                )
            if it * r <= rows_local:
                out.append(
                    ParallelismConfig("hybrid_r", k=k, s=s, tile_rows=tile)
                )
    return out


def _fusion_depths(s_max: int) -> list[int]:
    out = [1]
    s = 2
    while s <= s_max:
        out.append(s)
        s *= 2
    if s_max not in out and s_max > 1:
        out.append(s_max)
    return out


def choose_best(
    spec: StencilSpec,
    platform,
    iterations: int | None = None,
    pe_res_override: int | None = None,
    tie_eps: float = 0.05,
    optimize: bool = True,
) -> list[Prediction]:
    """Eq. 9: rank candidate configurations by predicted latency.

    Configurations within ``tie_eps`` of the fastest are re-ranked by
    resource efficiency (fewest spatial groups = fewest HBM banks / ICI
    links), matching the paper's "choose the most resource-efficient one"
    tie-break (Sec. 4.3 step 3).

    With ``optimize`` (the default) the spec is first lowered through the
    IR pass pipeline (:mod:`repro.core.ir`), so compute terms and op-mix
    resource estimates are derived from *post-optimization* op counts —
    the counts the executors actually run — rather than the raw DSL's.
    Callers that already hold a lowered spec pass ``optimize=False``.
    """
    if optimize:
        from repro.core.ir import lower

        spec = lower(spec).spec
    if isinstance(platform, FPGAPlatform):
        cfgs = fpga_candidate_configs(spec, platform, pe_res_override=pe_res_override)
        preds = [predict_fpga(spec, c, platform) for c in cfgs]
    else:
        cfgs = tpu_candidate_configs(spec, platform, iterations)
        preds = [predict_tpu(spec, c, platform, iterations) for c in cfgs]
    preds.sort(key=lambda p: p.latency)
    best = preds[0].latency
    near = [p for p in preds if p.latency <= best * (1 + tie_eps)]
    rest = [p for p in preds if p.latency > best * (1 + tie_eps)]
    near.sort(key=lambda p: (p.config.k, p.latency, -p.config.s))
    return near + rest
