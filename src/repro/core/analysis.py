"""Static verification of stencil specs: diagnostics before any build.

SASA's premise is that design validity and performance are decided
*statically* — the framework analyzes the DSL and rejects or ranks
configurations before any hardware build (paper §4–5).  This module is
that front door for the reproduction: a pass suite over the (lowered)
stencil IR returning structured :class:`Diagnostic` objects with stable
codes, severities, and source spans pointing back into the DSL text.

Code families (see ``DIAGNOSTIC_CODES`` for the full table, mirrored in
docs/DESIGN.md §Static verification):

  ``SASA1xx``  parse errors (lexical, expression syntax, declarations)
  ``SASA2xx``  semantic errors and dataflow hygiene (unknown arrays,
               dead stages, unused inputs, single-use bindings)
  ``SASA3xx``  feasibility (division safety, periodic divisibility,
               replicate row ownership, wrap-spec sharding, margins)
  ``SASA4xx``  performance warnings (VMEM overflow, redundant
               iteration, loop-invariant recomputation)

Analyses:

  * **Footprint/halo inference** (:func:`spec_footprint`) — a use-def
    traversal through ``Let``/``Var`` computes per-stage, per-input tap
    bounding boxes, composes them across stages (Minkowski sum per
    path, union hull across paths) and across iterations, and proves
    the bucket margin (``rounds * radius`` per side) and shard
    halo-exchange depth sufficient for each boundary mode.  Per-dim
    interval extremes compose exactly (the max of a Minkowski sum is
    the sum of the maxes), so the inferred bounding box equals the
    empirically observed blast radius — tests/test_analysis.py checks
    this against the pure-numpy oracle by NaN perturbation.
  * **Interval-domain division safety** (:func:`division_diagnostics`)
    — divisors are evaluated over value intervals (constants exact,
    streamed data unbounded, stage values widened by the mask-weave
    fill in bucketed modes); a divisor interval excluding zero is a
    proof the kernel is safe to bucket-serve, replacing the old
    syntactic refusal with a verdict that admits e.g.
    ``x / (abs(y) + 2)``.
  * **Dataflow hygiene** (:func:`hygiene_diagnostics`) — dead local
    stages, unused inputs, single-use ``Let`` bindings,
    iteration-invariant subexpressions recomputed every iteration.
  * **Feasibility preflight** (:func:`preflight`) — every
    :class:`ParallelismConfig` candidate is classified
    feasible/infeasible-with-reason by mirroring the runtime guards in
    :func:`repro.core.distribute.build_runner`, so the auto-tuner's
    retry loop consumes a precomputed verdict table instead of
    rediscovering failures via ``ValueError``.

Entry points: :func:`verify` (spec -> diagnostics), :func:`verify_or_raise`
(raises :class:`VerificationError` on error severity), :func:`lint_text`
(DSL text -> diagnostics, mapping parser errors to SASA1xx/SASA2xx), and
:func:`require_bucketable` (the analyzer-backed replacement for the old
``check_bucketable``).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Iterable, Mapping, Sequence

from repro.core.spec import (
    BinOp,
    Call,
    Expr,
    Let,
    Neg,
    Num,
    Ref,
    SourceSpan,
    Stage,
    StencilSpec,
    Var,
    count_ops,
    refs_in,
)

# --------------------------------------------------------------------------
# Diagnostics
# --------------------------------------------------------------------------

SEVERITIES = ("error", "warning", "info")
_SEV_ORDER = {s: i for i, s in enumerate(SEVERITIES)}

#: Stable code registry.  Codes are API: tests, CI lint output, and user
#: suppressions key on them, so a code is never renumbered or reused.
DIAGNOSTIC_CODES: dict[str, str] = {
    # -- SASA1xx: parse --------------------------------------------------
    "SASA100": "generic parse error",
    "SASA101": "unrecognized token",
    "SASA102": "malformed expression",
    "SASA103": "bad tap offset (non-integer or wrong arity)",
    "SASA104": "malformed declaration line",
    "SASA105": "bad header value (iteration / boundary / dtype / iterate)",
    "SASA106": "missing or duplicated section",
    "SASA107": "duplicate or shadowing declaration",
    # -- SASA2xx: semantic / dataflow hygiene ----------------------------
    "SASA200": "generic semantic error",
    "SASA201": "reference to unknown array",
    "SASA202": "tap arity does not match the grid rank",
    "SASA203": "unbound Let variable",
    "SASA210": "dead local stage (never reaches the output)",
    "SASA211": "unused input",
    "SASA212": "single-use Let binding",
    # -- SASA3xx: feasibility --------------------------------------------
    "SASA301": "divisor interval contains zero (not bucket-safe)",
    "SASA302": "periodic boundary: rows not divisible by spatial degree",
    "SASA303": "replicate boundary: a shard would own no real row",
    "SASA304": "streamed wrap margin is single-device only",
    "SASA305": "iter*radius exceeds rows per device for *_r variants",
    "SASA306": "no feasible parallelism candidate",
    "SASA307": "bucket margin smaller than the staleness depth",
    "SASA308": "candidate refused at build time (unpredicted by preflight)",
    # -- SASA4xx: performance --------------------------------------------
    "SASA401": "candidate schedules more VMEM than the platform budget",
    "SASA402": "iterations > 1 but the output never reads the iterate",
    "SASA403": "iteration-invariant subexpression recomputed per iteration",
    # -- SASA5xx: certified numerics (repro.core.numerics) ----------------
    "SASA500": "certified rounding-error bound (informational)",
    "SASA501": "value envelope may overflow the dtype's finite range",
    "SASA502": "harmful cancellation amplifies accumulated rounding error",
    "SASA503": "ill-conditioned divisor amplifies rounding error",
    "SASA510": "accumulated error bound exceeds dtype-meaningful precision",
}


@dataclasses.dataclass(frozen=True)
class Diagnostic:
    """One finding of the static analyzer.

    ``span`` points into the DSL text the spec was parsed from (None for
    hand-built specs); ``stage`` names the stage the finding concerns,
    when there is one.
    """

    code: str
    severity: str  # one of SEVERITIES
    message: str
    span: SourceSpan | None = None
    stage: str | None = None

    def __post_init__(self):
        assert self.severity in SEVERITIES, self.severity
        assert self.code in DIAGNOSTIC_CODES, self.code

    @property
    def is_error(self) -> bool:
        return self.severity == "error"

    def format(self, source: str | None = None) -> str:
        """Render ``file:line:col severity[CODE]: message`` plus, when the
        DSL source is at hand, the offending line with a caret column."""
        loc = f"{self.span} " if self.span else ""
        head = f"{loc}{self.severity}[{self.code}]: {self.message}"
        if source is None or self.span is None:
            return head
        lines = source.splitlines()
        if not 1 <= self.span.line <= len(lines):
            return head
        text = lines[self.span.line - 1]
        width = max(self.span.end_col - self.span.col, 1)
        caret = " " * (self.span.col - 1) + "^" * min(
            width, max(len(text) - self.span.col + 1, 1)
        )
        return f"{head}\n  {text}\n  {caret}"


def sort_diagnostics(diags: Iterable[Diagnostic]) -> list[Diagnostic]:
    """Errors first, then source order."""
    return sorted(
        diags,
        key=lambda d: (
            _SEV_ORDER[d.severity],
            d.span.line if d.span else 1 << 30,
            d.span.col if d.span else 0,
            d.code,
        ),
    )


class VerificationError(ValueError):
    """Raised by strict verification; carries the structured findings.

    Subclasses ``ValueError`` so pre-analyzer callers (the auto-tuner's
    retry loop, the serving layer's registration guards) keep catching
    it without change.
    """

    def __init__(self, message: str, diagnostics: Sequence[Diagnostic] = ()):
        super().__init__(message)
        self.diagnostics = tuple(diagnostics)


def _raise_errors(
    diags: Sequence[Diagnostic], spec_name: str, source: str | None = None
) -> None:
    errors = [d for d in diags if d.is_error]
    if not errors:
        return
    body = "\n".join(d.format(source) for d in sort_diagnostics(errors))
    raise VerificationError(
        f"spec {spec_name!r} failed static verification "
        f"({len(errors)} error{'s' if len(errors) != 1 else ''}):\n{body}",
        diagnostics=tuple(diags),
    )


# --------------------------------------------------------------------------
# Footprint / halo inference
# --------------------------------------------------------------------------
#
# A footprint is a per-dimension bounding box of read offsets,
# represented as ``((lo0, hi0), (lo1, hi1), ...)``.  Boxes compose by
# Minkowski sum along a use-def path and by union hull across paths;
# because per-dim extremes are additive under Minkowski sum, the hull of
# the exact (possibly non-rectangular) tap set has the same per-dim
# extremes as the composed boxes — the inference is exact for bounding
# boxes, which is what margins and halo depths are sized from.

Box = tuple[tuple[int, int], ...]


def _box_union(a: Box, b: Box) -> Box:
    return tuple(
        (min(al, bl), max(ah, bh)) for (al, ah), (bl, bh) in zip(a, b)
    )


def _box_add(a: Box, b: Box) -> Box:
    return tuple(
        (al + bl, ah + bh) for (al, ah), (bl, bh) in zip(a, b)
    )


def _merge(into: dict[str, Box], new: Mapping[str, Box]) -> None:
    for name, box in new.items():
        into[name] = _box_union(into[name], box) if name in into else box


def expr_taps(
    expr: Expr, env: Mapping[str, Mapping[str, Box]] | None = None
) -> dict[str, Box]:
    """Per-array bounding box of the offsets ``expr`` reads.

    ``Let`` bindings are traversed use-def style: a binding's taps are
    computed once and every ``Var`` use resolves to them, so the result
    matches the inlined expression regardless of CSE.
    """
    env = dict(env) if env else {}
    if isinstance(expr, Ref):
        return {expr.name: tuple((int(o), int(o)) for o in expr.offsets)}
    if isinstance(expr, Num):
        return {}
    if isinstance(expr, Var):
        return dict(env.get(expr.name, {}))
    if isinstance(expr, Let):
        for name, bound in expr.bindings:
            env[name] = expr_taps(bound, env)
        return expr_taps(expr.body, env)
    out: dict[str, Box] = {}
    if isinstance(expr, BinOp):
        children: tuple[Expr, ...] = (expr.lhs, expr.rhs)
    elif isinstance(expr, Call):
        children = expr.args
    elif isinstance(expr, Neg):
        children = (expr.arg,)
    else:  # pragma: no cover - exhaustive over Expr
        raise TypeError(type(expr))
    for c in children:
        _merge(out, expr_taps(c, env))
    return out


def stage_reach(spec: StencilSpec) -> dict[str, dict[str, Box]]:
    """For every array (input or stage), its reach onto the declared inputs.

    ``reach[name][inp]`` is the bounding box of offsets through which
    the value of array ``name`` at a cell depends on input ``inp``
    within one iteration; absent keys mean no dependence.  Inputs reach
    themselves at offset zero; stages compose their direct taps with
    the reach of what they read (Minkowski sum per read, union across
    reads).
    """
    zero: Box = tuple((0, 0) for _ in range(spec.ndim))
    reach: dict[str, dict[str, Box]] = {
        inp: {inp: zero} for inp in spec.inputs
    }
    for st in spec.stages:
        acc: dict[str, Box] = {}
        for arr, box in expr_taps(st.expr).items():
            base = reach.get(arr)
            if base is None:
                continue  # unknown array: validate()/parse reject it
            for inp, through in base.items():
                composed = _box_add(box, through)
                _merge(acc, {inp: composed})
        reach[st.name] = acc
    return reach


def spec_footprint(
    spec: StencilSpec, iterations: int | None = None
) -> dict[str, Box | None]:
    """Total reach of each declared input onto the final output.

    Composes the per-iteration output reach across ``iterations``
    ping-pong rounds: the initial iterate value is seen only through
    ``F`` composed ``it`` times (per-dim ``(it*lo, it*hi)``), while a
    constant input is re-read every round, i.e. through
    ``union_{t<it} (t*F + G)`` — per-dim
    ``(G_lo + min(0, (it-1)*F_lo), G_hi + max(0, (it-1)*F_hi))``.
    ``None`` marks an input that never influences the output (its
    empirical blast radius is empty).
    """
    it = spec.iterations if iterations is None else int(iterations)
    per_iter = stage_reach(spec)[spec.output_name]
    F = per_iter.get(spec.iterate_input)
    total: dict[str, Box | None] = {}
    for inp in spec.inputs:
        if inp == spec.iterate_input:
            total[inp] = (
                None if F is None
                else tuple((lo * it, hi * it) for lo, hi in F)
            )
            continue
        G = per_iter.get(inp)
        if G is None:
            total[inp] = None
        elif F is None or it <= 1:
            total[inp] = G
        else:
            t = it - 1
            total[inp] = tuple(
                (glo + min(0, flo * t), ghi + max(0, fhi * t))
                for (glo, ghi), (flo, fhi) in zip(G, F)
            )
    return total


def per_dim_radii(spec: StencilSpec) -> tuple[int, ...]:
    """Per-dimension one-iteration staleness depth of the composite stencil.

    The max absolute offset, per dim, through which the output depends
    on any input within a single iteration.  Bounded above by the
    declared Chebyshev ``spec.radius`` (which sums stage radii over the
    worst dim), so margins sized from ``spec.radius`` are always
    sufficient — this function makes the per-dim slack visible and lets
    :func:`margin_diagnostics` prove a given margin adequate.
    """
    per_iter = stage_reach(spec)[spec.output_name]
    radii = [0] * spec.ndim
    for box in per_iter.values():
        for d, (lo, hi) in enumerate(box):
            radii[d] = max(radii[d], -lo, hi, 0)
    return tuple(radii)


def required_margins(
    spec: StencilSpec,
    iterations: int | None = None,
    wrap_rounds: int | None = None,
) -> tuple[int, ...]:
    """Per-dim margin depth a periodic bucket must reserve per side.

    The streamed wrap extension goes stale from the bucket edge inward
    at the per-dim staleness depth per iteration, and survives
    ``rounds`` iterations between re-wraps — ``iterations`` total for
    the legacy wide margin, ``wrap_rounds`` when executors re-impose
    the wrap between fused rounds.  Non-periodic modes re-impose their
    exterior in-kernel every stage and need no margin.
    """
    if spec.boundary.kind != "periodic":
        return (0,) * spec.ndim
    it = spec.iterations if iterations is None else int(iterations)
    rounds = it if wrap_rounds is None else min(int(wrap_rounds), it)
    rounds = max(rounds, 1)
    return tuple(rounds * r for r in per_dim_radii(spec))


def margin_diagnostics(
    spec: StencilSpec,
    margins: Sequence[int],
    iterations: int | None = None,
    wrap_rounds: int | None = None,
) -> list[Diagnostic]:
    """Prove ``margins`` (per-dim, per-side) sufficient, or say why not."""
    need = required_margins(spec, iterations, wrap_rounds)
    diags = []
    for d, (have, want) in enumerate(zip(margins, need)):
        if have < want:
            diags.append(Diagnostic(
                "SASA307", "error",
                f"bucket margin for dim {d} is {have} cells but staleness "
                f"reaches {want} (= rounds * per-dim radius "
                f"{per_dim_radii(spec)[d]}); wrapped data would go stale "
                "inside the real grid",
                stage=spec.output_name,
            ))
    return diags


# --------------------------------------------------------------------------
# Interval domain: division safety
# --------------------------------------------------------------------------

_INF = math.inf


@dataclasses.dataclass(frozen=True)
class Interval:
    """Closed interval over the extended reals; TOP = (-inf, inf)."""

    lo: float
    hi: float

    @property
    def contains_zero(self) -> bool:
        return self.lo <= 0.0 <= self.hi

    def hull(self, other: "Interval") -> "Interval":
        return Interval(min(self.lo, other.lo), max(self.hi, other.hi))


TOP = Interval(-_INF, _INF)


def _xmul(a: float, b: float) -> float:
    # 0 * inf -> 0: the zero endpoint dominates in interval products
    if a == 0.0 or b == 0.0:
        return 0.0
    return a * b


def _iadd(a: Interval, b: Interval) -> Interval:
    return Interval(a.lo + b.lo, a.hi + b.hi)


def _isub(a: Interval, b: Interval) -> Interval:
    return Interval(a.lo - b.hi, a.hi - b.lo)


def _ineg(a: Interval) -> Interval:
    return Interval(-a.hi, -a.lo)


def _imul(a: Interval, b: Interval) -> Interval:
    prods = [_xmul(x, y) for x in (a.lo, a.hi) for y in (b.lo, b.hi)]
    return Interval(min(prods), max(prods))


def _idiv(a: Interval, b: Interval) -> Interval:
    if b.contains_zero:
        return TOP
    inv = Interval(
        0.0 if math.isinf(b.hi) else 1.0 / b.hi,
        0.0 if math.isinf(b.lo) else 1.0 / b.lo,
    )
    return _imul(a, inv)


def _iabs(a: Interval) -> Interval:
    if a.lo >= 0.0:
        return a
    if a.hi <= 0.0:
        return _ineg(a)
    return Interval(0.0, max(-a.lo, a.hi))


def expr_interval(
    expr: Expr,
    arrays: Mapping[str, Interval] | None = None,
    env: Mapping[str, Interval] | None = None,
    on_division=None,
) -> Interval:
    """Value interval of ``expr``.

    ``arrays`` maps array names to their value intervals (unknown names
    default to TOP — streamed data is unbounded).  ``on_division`` is
    called with ``(node, divisor_interval)`` for every ``/`` node, which
    is how :func:`division_diagnostics` collects unsafe divisors in one
    traversal.
    """
    arrays = arrays or {}
    env = dict(env) if env else {}

    def go(e: Expr, env: dict[str, Interval]) -> Interval:
        if isinstance(e, Num):
            return Interval(float(e.value), float(e.value))
        if isinstance(e, Ref):
            return arrays.get(e.name, TOP)
        if isinstance(e, Var):
            return env.get(e.name, TOP)
        if isinstance(e, Let):
            inner = dict(env)
            for name, bound in e.bindings:
                inner[name] = go(bound, inner)
            return go(e.body, inner)
        if isinstance(e, Neg):
            return _ineg(go(e.arg, env))
        if isinstance(e, Call):
            ivs = [go(a, env) for a in e.args]
            if e.fn == "abs":
                return _iabs(ivs[0])
            if e.fn == "max":
                return Interval(
                    max(v.lo for v in ivs), max(v.hi for v in ivs)
                )
            if e.fn == "min":
                return Interval(
                    min(v.lo for v in ivs), min(v.hi for v in ivs)
                )
            return TOP
        if isinstance(e, BinOp):
            a, b = go(e.lhs, env), go(e.rhs, env)
            if e.op == "+":
                return _iadd(a, b)
            if e.op == "-":
                return _isub(a, b)
            if e.op == "*":
                return _imul(a, b)
            if e.op == "/":
                if on_division is not None:
                    on_division(e, b)
                return _idiv(a, b)
        return TOP  # pragma: no cover - exhaustive over Expr

    return go(expr, env)


def division_diagnostics(
    spec: StencilSpec, bucketed: bool = True
) -> list[Diagnostic]:
    """Prove every divisor nonzero over value intervals, else SASA301.

    Stage value intervals chain: a stage dividing by an earlier local
    whose interval excludes zero (e.g. ``abs(x) + 1``) is admitted.
    With ``bucketed`` (the default — the serving north-star), stage
    intervals are widened by the mask-weave fill value: ``zero`` /
    ``constant`` buckets overwrite padding cells of *every* stage with
    the fill, so a later stage dividing by an earlier one must tolerate
    the fill appearing as a divisor.  Input arrays are TOP regardless —
    padding holds the fill, a subset of unbounded streamed data.

    Severity is ``error`` in the bucketed context (a NaN on padding
    bleeds into the real grid — the kernel must be refused) and
    ``warning`` exact-shape (the division runs on real data only; a
    zero there is the kernel author's own runtime hazard).
    """
    severity = "error" if bucketed else "warning"
    fill: Interval | None = None
    if bucketed and spec.boundary.kind in ("zero", "constant"):
        v = spec.boundary.value if spec.boundary.kind == "constant" else 0.0
        fill = Interval(v, v)

    diags: list[Diagnostic] = []
    arrays: dict[str, Interval] = {}
    for st in spec.stages:

        def report(node: BinOp, divisor: Interval, _st=st):
            if not divisor.contains_zero:
                return
            names = sorted({r.name for r in refs_in(node.rhs)})
            if names:
                what = (
                    f"divides by streamed data ({', '.join(names)}): the "
                    f"divisor's value interval "
                    f"[{divisor.lo:g}, {divisor.hi:g}] contains zero, so "
                    "zero padding could produce non-finite values that "
                    "survive the exterior mask; this kernel cannot be "
                    "shape-bucketed — serve it exact-shape, or bound the "
                    "divisor away from zero (e.g. abs(...) + c)"
                    if bucketed else
                    f"divides by streamed data ({', '.join(names)}) whose "
                    f"value interval [{divisor.lo:g}, {divisor.hi:g}] "
                    "contains zero: a zero in the real data produces "
                    "inf/NaN at run time"
                )
            else:
                what = (
                    "divides by a constant expression whose value interval "
                    f"[{divisor.lo:g}, {divisor.hi:g}] contains zero"
                )
            diags.append(Diagnostic(
                "SASA301", severity,
                f"stage {_st.name!r} {what}",
                span=node.span or _st.span,
                stage=_st.name,
            ))

        iv = expr_interval(st.expr, arrays, on_division=report)
        arrays[st.name] = iv.hull(fill) if fill is not None else iv
    return diags


# --------------------------------------------------------------------------
# Dataflow hygiene
# --------------------------------------------------------------------------


def _live_stages(spec: StencilSpec) -> set[str]:
    """Stage names whose values (transitively) reach the output."""
    reads = {
        st.name: {r.name for r in refs_in(st.expr)} for st in spec.stages
    }
    live = {spec.output_name}
    changed = True
    while changed:
        changed = False
        for st in spec.stages:
            if st.name in live:
                for dep in reads[st.name]:
                    if dep in reads and dep not in live:
                        live.add(dep)
                        changed = True
    return live


def hygiene_diagnostics(spec: StencilSpec) -> list[Diagnostic]:
    """Dead stages, unused inputs, single-use Lets, invariant subtrees."""
    from repro.core.ir import inline_lets

    diags: list[Diagnostic] = []
    live = _live_stages(spec)
    service = set(spec.halo_index_inputs) | set(spec.wrap_index_inputs)

    for st in spec.local_stages:
        if st.name not in live:
            diags.append(Diagnostic(
                "SASA210", "warning",
                f"local stage {st.name!r} is dead: no path from it to the "
                f"output stage {spec.output_name!r}",
                span=st.span, stage=st.name,
            ))

    read_by_live: set[str] = set()
    for st in spec.stages:
        if st.name in live:
            read_by_live |= {r.name for r in refs_in(st.expr)}
    it = spec.iterations
    for inp in spec.inputs:
        if inp in read_by_live or inp in service:
            continue
        if inp == spec.iterate_input and it > 1:
            continue  # reported as SASA402 below, with the iteration angle
        diags.append(Diagnostic(
            "SASA211", "warning",
            f"input {inp!r} is never read by any live stage",
            stage=None,
        ))

    # Iterations only do work if the output depends on the iterate input.
    per_iter = stage_reach(spec)[spec.output_name]
    if it > 1 and spec.iterate_input not in per_iter:
        diags.append(Diagnostic(
            "SASA402", "warning",
            f"iterations = {it} but the output never reads the iterate "
            f"input {spec.iterate_input!r}: every iteration recomputes the "
            "same grid",
            span=spec.output_stage.span, stage=spec.output_name,
        ))

    # Single-use Let bindings (hand-built IR; CSE emits multi-use ones,
    # though collapsing an outer repeat can strand an inner binding).
    for st in spec.stages:
        uses: dict[str, int] = {}
        bindings: dict[str, Let] = {}

        def scan(e: Expr):
            if isinstance(e, Var):
                uses[e.name] = uses.get(e.name, 0) + 1
            elif isinstance(e, Let):
                for name, bound in e.bindings:
                    bindings[name] = e
                    scan(bound)
                scan(e.body)
            elif isinstance(e, BinOp):
                scan(e.lhs)
                scan(e.rhs)
            elif isinstance(e, Call):
                for a in e.args:
                    scan(a)
            elif isinstance(e, Neg):
                scan(e.arg)

        scan(st.expr)
        for name, owner in bindings.items():
            if uses.get(name, 0) <= 1:
                diags.append(Diagnostic(
                    "SASA212", "info",
                    f"Let binding {name!r} in stage {st.name!r} is used "
                    f"{uses.get(name, 0)} time(s); inline it",
                    span=owner.span, stage=st.name,
                ))

    # Iteration-invariant subexpressions: a maximal subtree reading only
    # arrays outside the iterate's influence is recomputed identically
    # every iteration — hoistable in principle.
    if it > 1:
        varying = {spec.iterate_input}
        for st in spec.stages:
            if {r.name for r in refs_in(st.expr)} & varying:
                varying.add(st.name)

        def invariant(e: Expr) -> bool:
            names = {r.name for r in refs_in(e)}
            return bool(names) and not (names & varying)

        def find(e: Expr, st: Stage):
            if invariant(e) and count_ops(e) >= 2:
                diags.append(Diagnostic(
                    "SASA403", "warning",
                    f"subexpression in stage {st.name!r} reads only "
                    "iteration-invariant arrays "
                    f"({', '.join(sorted({r.name for r in refs_in(e)}))}) "
                    f"and is recomputed in each of the {it} iterations",
                    span=e.span or st.span, stage=st.name,
                ))
                return  # maximal subtree only
            if isinstance(e, BinOp):
                find(e.lhs, st)
                find(e.rhs, st)
            elif isinstance(e, Call):
                for a in e.args:
                    find(a, st)
            elif isinstance(e, Neg):
                find(e.arg, st)

        for st in spec.stages:
            if st.name in live and st.name in varying:
                find(inline_lets(st.expr), st)
    return diags


# --------------------------------------------------------------------------
# Feasibility preflight
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class CandidateVerdict:
    """Static feasibility of one parallelism candidate.

    ``code``/``reason`` explain an infeasible verdict; ``k`` is the
    device count the build would actually use (what the guards key on).
    """

    config: "object"  # ParallelismConfig (kept untyped: no model import cycle)
    feasible: bool
    k: int = 1
    code: str | None = None
    reason: str = ""

    def diagnostic(self, severity: str = "info") -> Diagnostic | None:
        if self.feasible:
            return None
        return Diagnostic(
            self.code or "SASA306", severity,
            f"candidate {self.config} infeasible: {self.reason}",
        )


def candidate_verdict(
    spec: StencilSpec,
    cfg,
    n_devices: int,
    iterations: int | None = None,
    batched: bool = False,
    k_override: int | None = None,
) -> CandidateVerdict:
    """Mirror of :func:`repro.core.distribute.build_runner`'s refusals.

    ``n_devices`` is the device pool the build would draw from; the
    guards key on ``k = min(cfg.devices_needed, n_devices)``, exactly
    as ``build_runner`` slices ``jax.devices()``.  Callers that pass an
    explicit device list to ``build_runner`` (which then uses *all* of
    them) give its length as ``k_override``.  With ``batched`` (the
    :func:`repro.runtime.batching.build_batched_runner` path) a
    candidate that degrades to a single device bypasses ``build_runner``
    entirely — the vmapped single-PE path has no shard guards.
    """
    it = spec.iterations if iterations is None else int(iterations)
    if k_override is not None:
        k = max(int(k_override), 1)
    else:
        k = min(max(cfg.devices_needed, 1), max(int(n_devices), 1))
    if batched and k <= 1:
        return CandidateVerdict(cfg, True, k=k)
    if spec.wrap_index_inputs:
        return CandidateVerdict(
            cfg, False, k=k, code="SASA304",
            reason=(
                "streamed wrap margins (wrap_index_inputs) are "
                "single-device only; shard_map designs require the wide "
                "periodic margin"
            ),
        )
    if cfg.variant == "temporal":
        return CandidateVerdict(cfg, True, k=1)
    R = spec.rows
    r = spec.radius
    R_pad = math.ceil(R / k) * k
    R_k = R_pad // k
    if cfg.variant in ("spatial_r", "hybrid_r") and it * r > R_k:
        return CandidateVerdict(
            cfg, False, k=k, code="SASA305",
            reason=(
                f"{cfg.variant} needs iter*r <= rows/device "
                f"({it}*{r} > {R_k}): the halo would span multiple "
                "neighbour shards"
            ),
        )
    if spec.boundary.kind == "periodic" and R_pad != R:
        return CandidateVerdict(
            cfg, False, k=k, code="SASA302",
            reason=(
                f"periodic boundary needs rows divisible by the spatial "
                f"degree ({R} rows over k={k} leaves {R_pad - R} padding "
                "rows that would break the wraparound halo adjacency)"
            ),
        )
    if spec.boundary.kind == "replicate" and (k - 1) * R_k > R - 1:
        return CandidateVerdict(
            cfg, False, k=k, code="SASA303",
            reason=(
                f"replicate boundary needs every device to own at least "
                f"one real grid row ({R} rows over k={k} leaves an "
                "all-padding shard that cannot clamp to the edge)"
            ),
        )
    return CandidateVerdict(cfg, True, k=k)


def preflight(
    spec: StencilSpec,
    configs: Sequence,
    n_devices: int,
    iterations: int | None = None,
    batched: bool = False,
    k_override: int | None = None,
) -> list[CandidateVerdict]:
    """Classify every candidate feasible/infeasible-with-reason, in order."""
    return [
        candidate_verdict(spec, c, n_devices, iterations, batched, k_override)
        for c in configs
    ]


# --------------------------------------------------------------------------
# Entry points
# --------------------------------------------------------------------------


def verify(
    spec: StencilSpec,
    platform=None,
    iterations: int | None = None,
    n_devices: int | None = None,
    batched: bool = False,
    bucketed: bool = True,
    optimize: bool = True,
) -> list[Diagnostic]:
    """Run the full pass suite over ``spec``; returns sorted diagnostics.

    Spec-level analyses (division safety, hygiene, margin proof) always
    run; with ``platform`` the candidate space is ranked and preflighted
    too — infeasible candidates surface as info diagnostics (the tuner
    skips them by design) and *no* feasible candidate at all is the
    SASA306 error.  ``optimize`` lowers through the IR pipeline first,
    matching what executors compile; spans survive lowering.
    """
    from repro.core.ir import lower

    from repro.core import numerics

    lowered = lower(spec).spec if optimize else spec
    it = spec.iterations if iterations is None else int(iterations)
    diags: list[Diagnostic] = []
    diags += division_diagnostics(lowered, bucketed=bucketed)
    diags += hygiene_diagnostics(lowered)
    diags += numerics.numerics_diagnostics(
        lowered, iterations=it, bucketed=bucketed, optimize=False
    )

    # Margin-sufficiency proof: the margins the bucket layer reserves
    # (rounds * spec.radius per side, see runtime.bucketing.bucket_margins)
    # against the inferred per-dim staleness depth.
    if spec.boundary.kind == "periodic":
        rounds = (
            min(spec.wrap_round_depth, it) if spec.wrap_index_inputs else it
        )
        margins = (max(rounds, 1) * spec.radius,) * spec.ndim
        diags += margin_diagnostics(
            lowered, margins, iterations=it,
            wrap_rounds=spec.wrap_round_depth or None,
        )

    if platform is not None:
        from repro.core.model import FPGAPlatform, choose_best

        ranking = choose_best(
            spec, platform, iterations=iterations, optimize=optimize
        )
        overflow = [
            p.config for p in ranking if "VMEM overflow" in p.notes
        ]
        if overflow:
            diags.append(Diagnostic(
                "SASA401", "warning",
                f"{len(overflow)} candidate(s) schedule more VMEM than "
                f"the platform budget and rank with an overflow penalty: "
                f"{overflow[:3]}{'...' if len(overflow) > 3 else ''}",
            ))
        if not isinstance(platform, FPGAPlatform):
            pool = (
                int(n_devices) if n_devices is not None
                else int(getattr(platform, "num_chips", 1))
            )
            verdicts = preflight(
                spec, [p.config for p in ranking], pool,
                iterations=iterations, batched=batched,
            )
            for v in verdicts:
                d = v.diagnostic("info")
                if d is not None:
                    diags.append(d)
            if verdicts and not any(v.feasible for v in verdicts):
                diags.append(Diagnostic(
                    "SASA306", "error",
                    f"no feasible parallelism candidate for spec "
                    f"{spec.name!r} on a {pool}-device pool: "
                    + "; ".join(
                        f"{v.config.variant}(k={v.config.k},s={v.config.s})"
                        f" -> {v.code}"
                        for v in verdicts[:6]
                    ),
                ))
    return sort_diagnostics(diags)


def verify_or_raise(
    spec: StencilSpec,
    platform=None,
    iterations: int | None = None,
    source: str | None = None,
    **kwargs,
) -> list[Diagnostic]:
    """:func:`verify`, raising :class:`VerificationError` on any error."""
    diags = verify(spec, platform=platform, iterations=iterations, **kwargs)
    _raise_errors(diags, spec.name, source)
    return diags


def require_bucketable(spec: StencilSpec) -> None:
    """Refuse specs the streamed bucket transforms cannot serve bit-exactly.

    The analyzer-backed replacement for the old syntactic
    ``check_bucketable``: instead of refusing *any* array reference in a
    denominator, the interval domain proves divisors nonzero — so
    ``x / (abs(y) + 2)`` is admitted while ``x / (y + 1)`` (interval
    straddles zero) is still refused.  Raises :class:`VerificationError`
    (a ``ValueError``) listing the offending divisions.
    """
    diags = division_diagnostics(spec, bucketed=True)
    _raise_errors(diags, spec.name)


def lint_text(text: str, platform=None, **kwargs):
    """Parse + verify DSL ``text``: ``(spec | None, diagnostics)``.

    Parser failures become SASA1xx diagnostics carrying the error's
    line/column; semantic ``ValueError``s from spec validation become
    SASA200.  On a clean parse the full :func:`verify` suite runs.
    """
    from repro.core import dsl

    try:
        spec = dsl.parse(text)
    except dsl.DSLSyntaxError as e:
        return None, [Diagnostic(
            e.code if e.code in DIAGNOSTIC_CODES else "SASA100",
            "error", e.msg, span=e.span,
        )]
    except SyntaxError as e:
        return None, [Diagnostic("SASA100", "error", str(e))]
    except ValueError as e:
        return None, [Diagnostic("SASA200", "error", str(e))]
    return spec, verify(spec, platform=platform, **kwargs)
