"""Stencil specification AST and derived static properties.

This is the in-memory representation produced by :mod:`repro.core.dsl` and
consumed by the reference executor, the Pallas kernel generator, the
distribution layer, and the analytical performance model.

Semantics (shared by every executor in the framework):
  * An iteration applies every stage (``local`` stages in declaration order,
    then the ``output`` stage) over the full grid.
  * Reads outside the grid are resolved by the spec's :class:`Boundary`
    rule, at every stage of every iteration (docs/DESIGN.md §Boundary
    semantics).  The default ``zero`` boundary matches a streaming FPGA
    design whose line buffers are zero-initialised and is linear-friendly
    for testing; ``constant``/``replicate``/``periodic`` cover physically
    meaningful edges (fixed temperature, image edge clamping, tori).
  * Between iterations the designated ``iterate`` input is rebound to the
    previous output (ping-pong buffering, Section 2.1 of the SASA paper).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Mapping, Union

import numpy as np

# --------------------------------------------------------------------------
# Source spans
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SourceSpan:
    """Location of a construct in the DSL text (1-based line / column).

    Attached to AST nodes by the parser and carried into diagnostics
    (:mod:`repro.core.analysis`).  For a logical line assembled from
    continuation lines, ``line`` is the first raw line and columns index
    into the joined text.
    """

    line: int
    col: int
    end_col: int

    def __str__(self) -> str:
        return f"{self.line}:{self.col}"


# The span field rides every AST node but is excluded from equality,
# hashing, and repr: structural identity (spec hashing, CSE's repeated-
# subtree table, repr-based cache fingerprints, parse/format round-trip
# equality) must not depend on where a node came from.
def _span_field():
    return dataclasses.field(default=None, compare=False, repr=False)


# --------------------------------------------------------------------------
# Expression AST
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Num:
    value: float
    span: "SourceSpan | None" = _span_field()


@dataclasses.dataclass(frozen=True)
class Ref:
    """Reference to array ``name`` at a constant offset from the output cell."""

    name: str
    offsets: tuple[int, ...]
    span: "SourceSpan | None" = _span_field()


@dataclasses.dataclass(frozen=True)
class BinOp:
    op: str  # '+', '-', '*', '/'
    lhs: "Expr"
    rhs: "Expr"
    span: "SourceSpan | None" = _span_field()


@dataclasses.dataclass(frozen=True)
class Call:
    """Intrinsic function call: max/min/abs over expressions."""

    fn: str
    args: tuple["Expr", ...]
    span: "SourceSpan | None" = _span_field()


@dataclasses.dataclass(frozen=True)
class Neg:
    arg: "Expr"
    span: "SourceSpan | None" = _span_field()


@dataclasses.dataclass(frozen=True)
class Var:
    """Reference to a value bound by an enclosing :class:`Let`."""

    name: str
    span: "SourceSpan | None" = _span_field()


@dataclasses.dataclass(frozen=True)
class Let:
    """Bind sub-expressions once, then evaluate ``body``.

    This is the IR node the CSE pass (:mod:`repro.core.ir`) produces: a
    repeated sub-tree is evaluated a single time and referenced through
    :class:`Var`.  Bindings evaluate in order; later bindings (and the
    body) may reference earlier ones.  ``Var`` names live in a namespace
    separate from array names, so bindings can never shadow an input.
    """

    bindings: tuple[tuple[str, "Expr"], ...]
    body: "Expr"
    span: "SourceSpan | None" = _span_field()


Expr = Union[Num, Ref, BinOp, Call, Neg, Var, Let]

INTRINSICS = ("max", "min", "abs")


def walk(expr: Expr):
    """Yield every node of the expression tree.

    A :class:`Let` binding's sub-tree is yielded once, no matter how many
    ``Var`` references consume it — which is exactly what makes
    :func:`count_ops` report post-CSE op counts.
    """
    yield expr
    if isinstance(expr, BinOp):
        yield from walk(expr.lhs)
        yield from walk(expr.rhs)
    elif isinstance(expr, Call):
        for a in expr.args:
            yield from walk(a)
    elif isinstance(expr, Neg):
        yield from walk(expr.arg)
    elif isinstance(expr, Let):
        for _, bound in expr.bindings:
            yield from walk(bound)
        yield from walk(expr.body)


def refs_in(expr: Expr) -> list[Ref]:
    return [n for n in walk(expr) if isinstance(n, Ref)]


def count_ops(expr: Expr) -> int:
    """Number of algorithmic operations (paper's OPs metric, Fig. 1)."""
    ops = 0
    for node in walk(expr):
        if isinstance(node, BinOp):
            ops += 1
        elif isinstance(node, Call):
            # an n-ary max/min is n-1 compare-select ops
            ops += max(len(node.args) - 1, 1)
        elif isinstance(node, Neg):
            ops += 1
    return ops


# --------------------------------------------------------------------------
# Boundary semantics
# --------------------------------------------------------------------------

BOUNDARY_KINDS = ("zero", "constant", "replicate", "periodic")


@dataclasses.dataclass(frozen=True)
class Boundary:
    """How reads outside the grid resolve (docs/DESIGN.md §Boundary).

      zero        out-of-grid cells read 0 (the seed semantics)
      constant    out-of-grid cells read ``value`` (e.g. fixed-temperature
                  edges in HOTSPOT-style thermal solvers)
      replicate   out-of-grid reads clamp to the nearest edge cell (image
                  filters: BLUR/SOBEL without edge darkening)
      periodic    out-of-grid reads wrap around (torus domains: spectral /
                  molecular-dynamics style HEAT3D)

    The rule applies uniformly to every array — inputs and intermediate
    ``local`` stages alike — at every stage of every iteration.
    """

    kind: str = "zero"
    value: float = 0.0

    def __post_init__(self):
        if self.kind not in BOUNDARY_KINDS:
            raise ValueError(
                f"unknown boundary kind {self.kind!r} "
                f"(expected one of {BOUNDARY_KINDS})"
            )
        if self.kind != "constant" and self.value != 0.0:
            raise ValueError(
                f"boundary value only applies to 'constant', not "
                f"{self.kind!r}"
            )
        if not math.isfinite(self.value):
            # inf/NaN edges poison every neighbouring cell, and the
            # bucketed mask+offset form (v * (1 - m)) would turn them
            # into NaN on IN-grid cells too
            raise ValueError(
                f"boundary constant must be finite, got {self.value!r}"
            )

    @property
    def is_zero(self) -> bool:
        return self.kind == "zero"


ZERO_BOUNDARY = Boundary("zero")


# --------------------------------------------------------------------------
# Dtype float limits (consumed by the certified-numerics analyzer)
# --------------------------------------------------------------------------


def _float_info(dtype: str):
    """``np.finfo`` for a DSL dtype name, tolerating bfloat16 (ml_dtypes)."""
    try:
        return np.finfo(np.dtype(dtype))
    except TypeError:
        import ml_dtypes  # registered by jax; never a new dependency

        return np.finfo(getattr(ml_dtypes, str(dtype)))


def unit_roundoff(dtype: str) -> float:
    """Per-op relative error budget the numerics analyzer charges ``dtype``.

    This is ``eps`` (the gap from 1.0 to the next float), i.e. **twice**
    the true unit roundoff of a correctly-rounded op (``eps/2``): the
    2x headroom absorbs backends whose ops are faithful rather than
    correctly rounded (docs/DESIGN.md §Certified numerics).
    """
    return float(_float_info(dtype).eps)


def finite_max(dtype: str) -> float:
    """Largest finite value of ``dtype`` (the SASA501 overflow line)."""
    return float(_float_info(dtype).max)


# --------------------------------------------------------------------------
# Stages and the full spec
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Stage:
    """One stencil loop: writes array ``name`` from the expression."""

    name: str
    dtype: str
    expr: Expr
    is_output: bool
    span: "SourceSpan | None" = _span_field()

    @property
    def radius(self) -> int:
        """Chebyshev radius (paper's ``r``): max |offset| over any dim."""
        rad = 0
        for ref in refs_in(self.expr):
            for o in ref.offsets:
                rad = max(rad, abs(int(o)))
        return rad

    @property
    def ops_per_cell(self) -> int:
        return count_ops(self.expr)


@dataclasses.dataclass(frozen=True)
class StencilSpec:
    name: str
    iterations: int
    inputs: Mapping[str, tuple[str, tuple[int, ...]]]  # name -> (dtype, shape)
    stages: tuple[Stage, ...]
    iterate_input: str  # input rebound to the output between iterations
    boundary: Boundary = ZERO_BOUNDARY
    # Streamed halo-index plumbing (docs/DESIGN.md §Boundaries × bucketed
    # serving): when non-empty, one input name per dimension naming an
    # int32 grid-shaped array of *source coordinates*.  After every stage
    # the shared trapezoid helper re-imposes ``out[i, j, ...] =
    # out[idx0[i], idx1[j], ...]`` (per-axis gather), which lets a padded
    # bucket design re-create a smaller real grid's clamped-edge
    # (replicate) exterior from per-request streamed data.  Stages never
    # read these inputs; they ride the executors like any other array.
    halo_index_inputs: tuple[str, ...] = ()
    # Streamed wrap plumbing (narrow periodic bucket margins): when
    # non-empty, one input name per dimension naming an int32 grid-shaped
    # array of *wrap source coordinates* for that axis.  Executors
    # re-impose ``out[i, j, ...] = out[widx0[i], widx1[j], ...]`` on the
    # iterate **between fused rounds** (not per stage), refreshing a
    # ``wrap_round_depth * radius``-deep periodic margin from the real
    # region so the bucket needs only that much margin instead of
    # ``iterations * radius``.  Executors must cap the fused depth they
    # run per round at ``wrap_round_depth``.  Stages never read these
    # inputs.
    wrap_index_inputs: tuple[str, ...] = ()
    wrap_round_depth: int = 0

    def __hash__(self):
        # specs are jit static args; normalise the inputs mapping
        return hash((
            self.name,
            self.iterations,
            tuple((k, v[0], tuple(v[1])) for k, v in self.inputs.items()),
            self.stages,
            self.iterate_input,
            self.boundary,
            self.halo_index_inputs,
            self.wrap_index_inputs,
            self.wrap_round_depth,
        ))

    # ---------------- derived static properties ----------------
    @property
    def ndim(self) -> int:
        return len(next(iter(self.inputs.values()))[1])

    @property
    def shape(self) -> tuple[int, ...]:
        return tuple(next(iter(self.inputs.values()))[1])

    @property
    def rows(self) -> int:
        return self.shape[0]

    @property
    def cols_flat(self) -> int:
        """Paper flattens all dims except the first into 'columns' (Sec 4.3)."""
        return int(np.prod(self.shape[1:]))

    @property
    def output_stage(self) -> Stage:
        return self.stages[-1]

    @property
    def output_name(self) -> str:
        return self.output_stage.name

    @property
    def local_stages(self) -> tuple[Stage, ...]:
        return tuple(s for s in self.stages if not s.is_output)

    @property
    def radius(self) -> int:
        """Composite per-iteration radius: stage radii accumulate."""
        return sum(s.radius for s in self.stages)

    @property
    def halo(self) -> int:
        """Paper's halo/delay per iteration: ``halo = d = 2*r`` (Table 2)."""
        return 2 * self.radius

    @property
    def ops_per_cell(self) -> int:
        return sum(s.ops_per_cell for s in self.stages)

    @property
    def points(self) -> int:
        """Number of distinct taps of the composite stencil (for reporting)."""
        return sum(len(set(refs_in(s.expr))) for s in self.stages)

    @property
    def dtype(self) -> str:
        return self.output_stage.dtype

    @property
    def itemsize(self) -> int:
        return np.dtype(self.dtype).itemsize

    @property
    def num_inputs(self) -> int:
        return len(self.inputs)

    @property
    def cells(self) -> int:
        return int(np.prod(self.shape))

    def computation_intensity(self, iterations: int | None = None) -> float:
        """OPs per byte of off-chip traffic assuming optimal reuse (Fig. 1).

        With optimal reuse each input is read once and the output written
        once for the whole iterative run, while compute scales with ``iter``.
        """
        it = self.iterations if iterations is None else iterations
        ops = self.ops_per_cell * self.cells * it
        bytes_moved = (self.num_inputs + 1) * self.cells * self.itemsize
        return ops / bytes_moved

    def validate(self) -> None:
        if self.iterations < 1:
            raise ValueError(
                f"iteration count must be >= 1, got {self.iterations}"
            )
        shapes = {tuple(shape) for _, shape in self.inputs.values()}
        if len(shapes) != 1:
            raise ValueError(f"all inputs must share a shape, got {shapes}")
        if self.iterate_input not in self.inputs:
            raise ValueError(
                f"iterate input {self.iterate_input!r} is not an input"
            )
        known = set(self.inputs)
        for stage in self.stages:
            if stage.name in self.inputs:
                raise ValueError(
                    f"stage {stage.name!r} shadows an input of the same "
                    "name; rename the stage"
                )
            for ref in refs_in(stage.expr):
                if ref.name not in known:
                    raise ValueError(
                        f"stage {stage.name!r} references unknown array "
                        f"{ref.name!r}"
                    )
                if len(ref.offsets) != self.ndim:
                    raise ValueError(
                        f"ref {ref.name}{ref.offsets} has wrong arity for "
                        f"{self.ndim}-D stencil"
                    )
            _check_vars_bound(stage.expr, frozenset(), stage.name)
            known.add(stage.name)
        if not self.stages or not self.stages[-1].is_output:
            raise ValueError("last stage must be the output stage")
        if self.halo_index_inputs:
            if len(self.halo_index_inputs) != self.ndim:
                raise ValueError(
                    f"halo_index_inputs must name one input per dimension "
                    f"({self.ndim}), got {self.halo_index_inputs}"
                )
            for n in self.halo_index_inputs:
                if n not in self.inputs:
                    raise ValueError(
                        f"halo index input {n!r} is not a declared input"
                    )
        if self.wrap_index_inputs:
            if len(self.wrap_index_inputs) != self.ndim:
                raise ValueError(
                    f"wrap_index_inputs must name one input per dimension "
                    f"({self.ndim}), got {self.wrap_index_inputs}"
                )
            for n in self.wrap_index_inputs:
                if n not in self.inputs:
                    raise ValueError(
                        f"wrap index input {n!r} is not a declared input"
                    )
            if self.wrap_round_depth < 1:
                raise ValueError(
                    "wrap_index_inputs requires wrap_round_depth >= 1 "
                    f"(got {self.wrap_round_depth})"
                )
        elif self.wrap_round_depth:
            raise ValueError(
                "wrap_round_depth without wrap_index_inputs has no effect"
            )


def _check_vars_bound(expr: Expr, bound: frozenset, stage: str) -> None:
    """Every Var must be bound by an enclosing Let (in binding order)."""
    if isinstance(expr, Var):
        if expr.name not in bound:
            raise ValueError(
                f"stage {stage!r} has unbound let-variable {expr.name!r}"
            )
    elif isinstance(expr, BinOp):
        _check_vars_bound(expr.lhs, bound, stage)
        _check_vars_bound(expr.rhs, bound, stage)
    elif isinstance(expr, Call):
        for a in expr.args:
            _check_vars_bound(a, bound, stage)
    elif isinstance(expr, Neg):
        _check_vars_bound(expr.arg, bound, stage)
    elif isinstance(expr, Let):
        for name, e in expr.bindings:
            _check_vars_bound(e, bound, stage)
            bound = bound | {name}
        _check_vars_bound(expr.body, bound, stage)


# --------------------------------------------------------------------------
# Expression evaluation (shared by reference executor and kernels)
# --------------------------------------------------------------------------


def eval_expr(
    expr: Expr,
    get_ref: Callable[[str, tuple[int, ...]], "object"],
    _env: Mapping[str, "object"] | None = None,
):
    """Evaluate an expression tree.

    ``get_ref(name, offsets)`` must return an array (any numpy-like) holding
    the referenced array shifted by ``offsets``; all returned arrays must
    share a shape.  Scalars broadcast.  ``_env`` carries :class:`Let`
    bindings — a CSE'd sub-tree is evaluated once per stage application.
    """
    if isinstance(expr, Num):
        return expr.value
    if isinstance(expr, Ref):
        return get_ref(expr.name, expr.offsets)
    if isinstance(expr, Var):
        if _env is None or expr.name not in _env:
            raise ValueError(f"unbound let-variable {expr.name!r}")
        return _env[expr.name]
    if isinstance(expr, Let):
        env = dict(_env) if _env else {}
        for name, bound in expr.bindings:
            env[name] = eval_expr(bound, get_ref, env)
        return eval_expr(expr.body, get_ref, env)
    if isinstance(expr, Neg):
        return -eval_expr(expr.arg, get_ref, _env)
    if isinstance(expr, BinOp):
        lhs = eval_expr(expr.lhs, get_ref, _env)
        rhs = eval_expr(expr.rhs, get_ref, _env)
        if expr.op == "+":
            return lhs + rhs
        if expr.op == "-":
            return lhs - rhs
        if expr.op == "*":
            return lhs * rhs
        if expr.op == "/":
            return lhs / rhs
        raise ValueError(f"unknown op {expr.op!r}")
    if isinstance(expr, Call):
        import jax.numpy as jnp

        args = [eval_expr(a, get_ref, _env) for a in expr.args]
        if expr.fn == "abs":
            return jnp.abs(args[0])
        acc = args[0]
        for a in args[1:]:
            acc = jnp.maximum(acc, a) if expr.fn == "max" else jnp.minimum(acc, a)
        return acc
    raise TypeError(f"unknown expression node {expr!r}")
