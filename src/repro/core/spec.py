"""Stencil specification AST and derived static properties.

This is the in-memory representation produced by :mod:`repro.core.dsl` and
consumed by the reference executor, the Pallas kernel generator, the
distribution layer, and the analytical performance model.

Semantics (shared by every executor in the framework):
  * An iteration applies every stage (``local`` stages in declaration order,
    then the ``output`` stage) over the full grid.
  * Cells outside the grid read as zero ("exterior-zero" boundary), at every
    iteration.  This matches the behaviour of a streaming FPGA design whose
    line buffers are zero-initialised and is linear-friendly for testing.
  * Between iterations the designated ``iterate`` input is rebound to the
    previous output (ping-pong buffering, Section 2.1 of the SASA paper).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Mapping, Sequence, Union

import numpy as np

# --------------------------------------------------------------------------
# Expression AST
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Num:
    value: float


@dataclasses.dataclass(frozen=True)
class Ref:
    """Reference to array ``name`` at a constant offset from the output cell."""

    name: str
    offsets: tuple[int, ...]


@dataclasses.dataclass(frozen=True)
class BinOp:
    op: str  # '+', '-', '*', '/'
    lhs: "Expr"
    rhs: "Expr"


@dataclasses.dataclass(frozen=True)
class Call:
    """Intrinsic function call: max/min/abs over expressions."""

    fn: str
    args: tuple["Expr", ...]


@dataclasses.dataclass(frozen=True)
class Neg:
    arg: "Expr"


Expr = Union[Num, Ref, BinOp, Call, Neg]

INTRINSICS = ("max", "min", "abs")


def walk(expr: Expr):
    """Yield every node of the expression tree."""
    yield expr
    if isinstance(expr, BinOp):
        yield from walk(expr.lhs)
        yield from walk(expr.rhs)
    elif isinstance(expr, Call):
        for a in expr.args:
            yield from walk(a)
    elif isinstance(expr, Neg):
        yield from walk(expr.arg)


def refs_in(expr: Expr) -> list[Ref]:
    return [n for n in walk(expr) if isinstance(n, Ref)]


def count_ops(expr: Expr) -> int:
    """Number of algorithmic operations (paper's OPs metric, Fig. 1)."""
    ops = 0
    for node in walk(expr):
        if isinstance(node, BinOp):
            ops += 1
        elif isinstance(node, Call):
            # an n-ary max/min is n-1 compare-select ops
            ops += max(len(node.args) - 1, 1)
        elif isinstance(node, Neg):
            ops += 1
    return ops


# --------------------------------------------------------------------------
# Stages and the full spec
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Stage:
    """One stencil loop: writes array ``name`` from the expression."""

    name: str
    dtype: str
    expr: Expr
    is_output: bool

    @property
    def radius(self) -> int:
        """Chebyshev radius (paper's ``r``): max |offset| over any dim."""
        rad = 0
        for ref in refs_in(self.expr):
            for o in ref.offsets:
                rad = max(rad, abs(int(o)))
        return rad

    @property
    def ops_per_cell(self) -> int:
        return count_ops(self.expr)


@dataclasses.dataclass(frozen=True)
class StencilSpec:
    name: str
    iterations: int
    inputs: Mapping[str, tuple[str, tuple[int, ...]]]  # name -> (dtype, shape)
    stages: tuple[Stage, ...]
    iterate_input: str  # input rebound to the output between iterations

    def __hash__(self):
        # specs are jit static args; normalise the inputs mapping
        return hash((
            self.name,
            self.iterations,
            tuple((k, v[0], tuple(v[1])) for k, v in self.inputs.items()),
            self.stages,
            self.iterate_input,
        ))

    # ---------------- derived static properties ----------------
    @property
    def ndim(self) -> int:
        return len(next(iter(self.inputs.values()))[1])

    @property
    def shape(self) -> tuple[int, ...]:
        return tuple(next(iter(self.inputs.values()))[1])

    @property
    def rows(self) -> int:
        return self.shape[0]

    @property
    def cols_flat(self) -> int:
        """Paper flattens all dims except the first into 'columns' (Sec 4.3)."""
        return int(np.prod(self.shape[1:]))

    @property
    def output_stage(self) -> Stage:
        return self.stages[-1]

    @property
    def output_name(self) -> str:
        return self.output_stage.name

    @property
    def local_stages(self) -> tuple[Stage, ...]:
        return tuple(s for s in self.stages if not s.is_output)

    @property
    def radius(self) -> int:
        """Composite per-iteration radius: stage radii accumulate."""
        return sum(s.radius for s in self.stages)

    @property
    def halo(self) -> int:
        """Paper's halo/delay per iteration: ``halo = d = 2*r`` (Table 2)."""
        return 2 * self.radius

    @property
    def ops_per_cell(self) -> int:
        return sum(s.ops_per_cell for s in self.stages)

    @property
    def points(self) -> int:
        """Number of distinct taps of the composite stencil (for reporting)."""
        return sum(len(set(refs_in(s.expr))) for s in self.stages)

    @property
    def dtype(self) -> str:
        return self.output_stage.dtype

    @property
    def itemsize(self) -> int:
        return np.dtype(self.dtype).itemsize

    @property
    def num_inputs(self) -> int:
        return len(self.inputs)

    @property
    def cells(self) -> int:
        return int(np.prod(self.shape))

    def computation_intensity(self, iterations: int | None = None) -> float:
        """OPs per byte of off-chip traffic assuming optimal reuse (Fig. 1).

        With optimal reuse each input is read once and the output written
        once for the whole iterative run, while compute scales with ``iter``.
        """
        it = self.iterations if iterations is None else iterations
        ops = self.ops_per_cell * self.cells * it
        bytes_moved = (self.num_inputs + 1) * self.cells * self.itemsize
        return ops / bytes_moved

    def validate(self) -> None:
        shapes = {tuple(shape) for _, shape in self.inputs.values()}
        if len(shapes) != 1:
            raise ValueError(f"all inputs must share a shape, got {shapes}")
        if self.iterate_input not in self.inputs:
            raise ValueError(
                f"iterate input {self.iterate_input!r} is not an input"
            )
        known = set(self.inputs)
        for stage in self.stages:
            for ref in refs_in(stage.expr):
                if ref.name not in known:
                    raise ValueError(
                        f"stage {stage.name!r} references unknown array "
                        f"{ref.name!r}"
                    )
                if len(ref.offsets) != self.ndim:
                    raise ValueError(
                        f"ref {ref.name}{ref.offsets} has wrong arity for "
                        f"{self.ndim}-D stencil"
                    )
            known.add(stage.name)
        if not self.stages or not self.stages[-1].is_output:
            raise ValueError("last stage must be the output stage")


# --------------------------------------------------------------------------
# Expression evaluation (shared by reference executor and kernels)
# --------------------------------------------------------------------------


def eval_expr(expr: Expr, get_ref: Callable[[str, tuple[int, ...]], "object"]):
    """Evaluate an expression tree.

    ``get_ref(name, offsets)`` must return an array (any numpy-like) holding
    the referenced array shifted by ``offsets``; all returned arrays must
    share a shape.  Scalars broadcast.
    """
    if isinstance(expr, Num):
        return expr.value
    if isinstance(expr, Ref):
        return get_ref(expr.name, expr.offsets)
    if isinstance(expr, Neg):
        return -eval_expr(expr.arg, get_ref)
    if isinstance(expr, BinOp):
        lhs = eval_expr(expr.lhs, get_ref)
        rhs = eval_expr(expr.rhs, get_ref)
        if expr.op == "+":
            return lhs + rhs
        if expr.op == "-":
            return lhs - rhs
        if expr.op == "*":
            return lhs * rhs
        if expr.op == "/":
            return lhs / rhs
        raise ValueError(f"unknown op {expr.op!r}")
    if isinstance(expr, Call):
        import jax.numpy as jnp

        args = [eval_expr(a, get_ref) for a in expr.args]
        if expr.fn == "abs":
            return jnp.abs(args[0])
        acc = args[0]
        for a in args[1:]:
            acc = jnp.maximum(acc, a) if expr.fn == "max" else jnp.minimum(acc, a)
        return acc
    raise TypeError(f"unknown expression node {expr!r}")
