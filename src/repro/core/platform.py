"""Hardware platform descriptions for the analytical model.

Two families:

* :class:`FPGAPlatform` — the paper's target (Xilinx Alveo U280).  Used to
  run the paper-exact analytical model (Eqs. 1-9) and reproduce the paper's
  parallelism decisions / speedups (Table 3, Sec. 5.4).

* :class:`TPUPlatform` — our deployment target (TPU v5e pods).  The SASA
  latency model is re-derived against the TPU memory hierarchy:
  HBM->VMEM->VREG replaces HBM->AXI/FIFO->FF, fused-iteration Pallas tiles
  replace cascaded PE pipelines, and ICI collective-permutes replace
  on-chip border streaming wires.

All numbers are per-chip unless stated otherwise.  TPU v5e roofline
constants follow the assignment: 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class FPGAPlatform:
    """Xilinx Alveo U280 (paper Section 5.1)."""

    name: str = "xilinx-u280"
    freq_hz: float = 225e6                 # target frequency; >=225MHz saturates HBM
    hbm_banks: int = 32
    bank_bw: float = 14.4e9                # 512b/cycle @ 225MHz
    num_slrs: int = 3
    # chip resources (U280 datasheet)
    luts: int = 1_304_000
    ffs: int = 2_607_000
    brams: int = 2_016                     # BRAM36 blocks
    dsps: int = 9_024
    alpha: float = 0.75                    # Eq. 1 utilisation constraint
    reserved_banks: int = 2                # shell/host-reserved HBM banks
    axi_bits: int = 512


@dataclasses.dataclass(frozen=True)
class TPUPlatform:
    """TPU v5e chip + pod-slice fabric."""

    name: str = "tpu-v5e"
    peak_flops_bf16: float = 197e12        # MXU peak (LM roofline)
    vpu_flops_f32: float = 12.3e12         # VPU estimate; stencils are VPU work
    hbm_bw: float = 819e9                  # B/s
    hbm_bytes: int = 16 * 2**30
    vmem_bytes: int = 64 * 2**20           # usable VMEM budget per core
    ici_bw: float = 50e9                   # B/s per link per direction
    ici_latency: float = 1e-6              # per-hop collective latency
    num_chips: int = 8                     # chips available for the stencil job
    # 2D torus per pod; per-chip aggregate ICI is links * ici_bw, but the
    # stencil 1-D ring only ever uses two links (up/down neighbour).
    ici_links: int = 4

    def with_chips(self, n: int) -> "TPUPlatform":
        return dataclasses.replace(self, num_chips=n)


@dataclasses.dataclass(frozen=True)
class CPUPlatform:
    """Calibrated description of *this* host, used to validate the analytical
    model against measured wall-clock (the Fig. 9 accuracy experiment).

    ``flops`` / ``mem_bw`` are measured by :func:`calibrate` at benchmark
    time rather than hard-coded.
    """

    name: str = "host-cpu"
    flops: float = 5.0e10
    mem_bw: float = 2.0e10
    vmem_bytes: int = 1 * 2**20            # L2-ish tile budget; only used for tiling
    num_chips: int = 1
    ici_bw: float = 1.0e10                 # shard_map on host devices: shared memcpy
    ici_latency: float = 5e-6


DEFAULT_FPGA = FPGAPlatform()
DEFAULT_TPU = TPUPlatform()
