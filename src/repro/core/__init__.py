"""SASA core: stencil DSL, analytical model, auto-tuned distributed execution."""
from repro.core import analysis, dsl, model, platform
from repro.core.analysis import (
    Diagnostic,
    VerificationError,
    lint_text,
    verify,
    verify_or_raise,
)
from repro.core.autotune import TunedDesign, autotune, soda_baseline
from repro.core.model import ParallelismConfig, Prediction, choose_best
from repro.core.spec import StencilSpec

__all__ = [
    "analysis", "dsl", "model", "platform", "autotune", "soda_baseline",
    "TunedDesign", "ParallelismConfig", "Prediction", "choose_best",
    "StencilSpec", "Diagnostic", "VerificationError", "lint_text",
    "verify", "verify_or_raise",
]
