"""SASA core: stencil DSL, analytical model, auto-tuned distributed execution."""
from repro.core import dsl, model, platform
from repro.core.autotune import TunedDesign, autotune, soda_baseline
from repro.core.model import ParallelismConfig, Prediction, choose_best
from repro.core.spec import StencilSpec

__all__ = [
    "dsl", "model", "platform", "autotune", "soda_baseline", "TunedDesign",
    "ParallelismConfig", "Prediction", "choose_best", "StencilSpec",
]
