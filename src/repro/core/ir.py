"""Stencil IR lowering: an optimizer pass pipeline between DSL and backends.

The SASA front end parses DSL text into per-stage expression trees
(:mod:`repro.core.spec`).  This module is the middle layer every consumer
goes through (docs/DESIGN.md §IR pass pipeline): ``lower(spec)`` runs a
pipeline of semantics-preserving expression passes and returns the
optimized spec together with a per-pass op-delta report, so

  * every executor (reference, jnp fused, Pallas, shard_map) evaluates the
    *optimized* trees — fewer ops per cell reach the VPU;
  * the analytical model ranks parallelism configurations from
    post-optimization op counts (``ops_per_cell`` of the lowered spec),
    not the raw DSL's.

Passes (all pure ``Expr -> Expr``, applied per stage):

  fold-constants        ``2*3 -> 6``, ``max(1,2) -> 2``, ``-(4) -> -4``
  simplify-algebraic    ``x*1 -> x``, ``x+0 -> x``, ``0*x -> 0``,
                        ``x/1 -> x``, ``--x -> x``
  cse                   repeated ``Ref`` taps and repeated sub-trees within
                        a stage are bound once via :class:`Let`/:class:`Var`

The pipeline is idempotent: ``lower(lower(spec).spec)`` is a fixpoint, so
caches and serving layers may lower defensively.

Note the usual caveat: ``0*x -> 0`` (like any compiler's fast-math
constant folding) does not preserve NaN/Inf propagation from ``x``.
Stencil kernels stream finite grids, so the trade matches the paper's
FPGA datapath, which never materialises the multiply either.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

from repro.core.spec import (
    BinOp,
    Call,
    Expr,
    Let,
    Neg,
    Num,
    StencilSpec,
    Var,
    count_ops,
    walk,
)

Pass = Callable[[Expr], Expr]


# --------------------------------------------------------------------------
# Generic tree rebuilding
# --------------------------------------------------------------------------


def _map_children(expr: Expr, fn: Pass) -> Expr:
    """Rebuild one node with ``fn`` applied to each child."""
    if isinstance(expr, BinOp):
        return BinOp(expr.op, fn(expr.lhs), fn(expr.rhs), span=expr.span)
    if isinstance(expr, Call):
        return Call(expr.fn, tuple(fn(a) for a in expr.args), span=expr.span)
    if isinstance(expr, Neg):
        return Neg(fn(expr.arg), span=expr.span)
    if isinstance(expr, Let):
        return Let(
            tuple((n, fn(e)) for n, e in expr.bindings), fn(expr.body),
            span=expr.span,
        )
    return expr  # Num, Ref, Var


def _keep_span(new: Expr, old: Expr) -> Expr:
    """Carry the rewritten node's source span onto its replacement.

    Spans are excluded from structural equality, so passes would silently
    drop them; a folded/simplified node inherits the location of the
    expression it replaced, keeping analyzer diagnostics pointable after
    lowering.
    """
    if new is not old and new.span is None and old.span is not None:
        return dataclasses.replace(new, span=old.span)
    return new


def _bottom_up(expr: Expr, rule: Pass) -> Expr:
    """Apply ``rule`` to every node, children first, to a local fixpoint.

    A rewrite can expose another at the same node (``0-(0-x)`` becomes
    ``--x`` becomes ``x``), so the rule re-applies until the node is
    stable; children of a rewritten node are already simplified.
    """
    e = _map_children(expr, lambda c: _bottom_up(c, rule))
    while True:
        e2 = _keep_span(rule(e), e)
        if e2 == e:
            return e
        e = e2


# --------------------------------------------------------------------------
# Pass: constant folding
# --------------------------------------------------------------------------


def _fold_rule(expr: Expr) -> Expr:
    if isinstance(expr, Neg) and isinstance(expr.arg, Num):
        return Num(-expr.arg.value)
    if (
        isinstance(expr, BinOp)
        and isinstance(expr.lhs, Num)
        and isinstance(expr.rhs, Num)
    ):
        a, b = expr.lhs.value, expr.rhs.value
        if expr.op == "+":
            return Num(a + b)
        if expr.op == "-":
            return Num(a - b)
        if expr.op == "*":
            return Num(a * b)
        if expr.op == "/" and b != 0.0:
            return Num(a / b)
    if isinstance(expr, Call) and all(
        isinstance(a, Num) for a in expr.args
    ):
        vals = [a.value for a in expr.args]
        if expr.fn == "abs":
            return Num(abs(vals[0]))
        if expr.fn == "max":
            return Num(max(vals))
        if expr.fn == "min":
            return Num(min(vals))
    return expr


def fold_constants(expr: Expr) -> Expr:
    """Evaluate every constant sub-tree at lowering time.

    Folding uses Python float (f64) arithmetic — identical to what
    ``eval_expr`` would have computed for the same ``Num`` nodes at run
    time, so results are bit-identical, not merely close.
    """
    return _bottom_up(expr, _fold_rule)


# --------------------------------------------------------------------------
# Pass: algebraic simplification
# --------------------------------------------------------------------------


def _is_num(e: Expr, v: float) -> bool:
    return isinstance(e, Num) and e.value == v


def _simplify_rule(expr: Expr) -> Expr:
    if isinstance(expr, Neg) and isinstance(expr.arg, Neg):
        return expr.arg.arg                      # --x -> x
    if isinstance(expr, BinOp):
        lhs, rhs = expr.lhs, expr.rhs
        if expr.op == "+":
            if _is_num(lhs, 0.0):
                return rhs                       # 0+x -> x
            if _is_num(rhs, 0.0):
                return lhs                       # x+0 -> x
        elif expr.op == "-":
            if _is_num(rhs, 0.0):
                return lhs                       # x-0 -> x
            if _is_num(lhs, 0.0):
                return Neg(rhs)                  # 0-x -> -x
        elif expr.op == "*":
            if _is_num(lhs, 1.0):
                return rhs                       # 1*x -> x
            if _is_num(rhs, 1.0):
                return lhs                       # x*1 -> x
            if _is_num(lhs, 0.0) or _is_num(rhs, 0.0):
                return Num(0.0)                  # 0*x -> 0 (fast-math)
        elif expr.op == "/":
            if _is_num(rhs, 1.0):
                return lhs                       # x/1 -> x
    return expr


def simplify_algebraic(expr: Expr) -> Expr:
    """Strip identity/annihilator ops (``x*1``, ``x+0``, ``0*x``, ``--x``)."""
    return _bottom_up(expr, _simplify_rule)


# --------------------------------------------------------------------------
# Pass: common-subexpression elimination (per stage)
# --------------------------------------------------------------------------


def _count_subtrees(expr: Expr, counts: dict) -> None:
    for node in walk(expr):
        if isinstance(node, (Num, Var)):
            continue            # trivial leaves: binding them saves nothing
        counts[node] = counts.get(node, 0) + 1


def eliminate_common_subexpressions(expr: Expr) -> Expr:
    """Bind every repeated sub-tree (including repeated ``Ref`` taps) once.

    Frozen-dataclass structural equality makes repeated sub-trees hash
    equal, so one dictionary pass finds them; the rewrite is top-down with
    inner repeats bound before the outer tree that contains them, giving a
    well-ordered ``Let``.  Repeated ``Ref``s carry no ops but deduplicate
    taps; repeated operator trees strictly reduce ``ops_per_cell``.
    """
    counts: dict = {}
    _count_subtrees(expr, counts)
    repeated = {t for t, c in counts.items() if c >= 2}
    if not repeated:
        return expr
    bindings: list[tuple[str, Expr]] = []
    assigned: dict = {}

    def rebuild(e: Expr) -> Expr:
        if e in repeated:
            if e not in assigned:
                inner = _map_children(e, rebuild)
                name = f"_t{len(bindings)}"
                assigned[e] = name
                bindings.append((name, inner))
            return Var(assigned[e])
        return _map_children(e, rebuild)

    body = rebuild(expr)
    return Let(tuple(bindings), body)


# --------------------------------------------------------------------------
# Pass manager
# --------------------------------------------------------------------------

DEFAULT_PASSES: tuple[tuple[str, Pass], ...] = (
    ("fold-constants", fold_constants),
    ("simplify-algebraic", simplify_algebraic),
    ("cse", eliminate_common_subexpressions),
)


@dataclasses.dataclass(frozen=True)
class PassReport:
    """Op-count delta of one pass over the whole spec."""

    name: str
    ops_before: int
    ops_after: int

    @property
    def delta(self) -> int:
        return self.ops_before - self.ops_after

    def __str__(self):
        return f"{self.name}: {self.ops_before} -> {self.ops_after} ops"


@dataclasses.dataclass(frozen=True)
class LoweredSpec:
    """Result of :func:`lower`: the optimized spec plus per-pass deltas."""

    spec: StencilSpec
    reports: tuple[PassReport, ...]

    @property
    def ops_per_cell(self) -> int:
        return self.spec.ops_per_cell

    @property
    def ops_removed(self) -> int:
        return sum(r.delta for r in self.reports)

    def summary(self) -> str:
        raw = self.reports[0].ops_before if self.reports else self.ops_per_cell
        lines = [
            f"{self.spec.name}: {raw} -> {self.ops_per_cell} ops/cell"
        ] + [f"  {r}" for r in self.reports]
        return "\n".join(lines)


def lower(
    spec: StencilSpec,
    passes: Sequence[tuple[str, Pass]] = DEFAULT_PASSES,
) -> LoweredSpec:
    """Run the pass pipeline over every stage of ``spec``.

    Returns a :class:`LoweredSpec` whose ``spec`` is semantically identical
    to the input (every executor produces the same grids) but whose
    expression trees are optimized, and whose ``reports`` record the op
    delta each pass achieved.  The optimized spec is what the analytical
    model ranks and what every executor compiles.
    """
    stages = list(spec.stages)
    reports = []
    for name, fn in passes:
        before = sum(count_ops(st.expr) for st in stages)
        stages = [
            dataclasses.replace(st, expr=fn(st.expr)) for st in stages
        ]
        after = sum(count_ops(st.expr) for st in stages)
        reports.append(PassReport(name, before, after))
    out = dataclasses.replace(spec, stages=tuple(stages))
    out.validate()
    return LoweredSpec(spec=out, reports=tuple(reports))


# --------------------------------------------------------------------------
# Error-relevant op metadata (consumed by repro.core.numerics)
# --------------------------------------------------------------------------

#: BinOp kinds that round their result (each charges one unit roundoff in
#: the certified-numerics analysis).  Division is charged extra headroom
#: there: backends may rewrite ``x / c`` into ``x * (1/c)``.
ROUNDED_OPS = frozenset({"+", "-", "*", "/"})

#: Ops that are exact in floating point (no new rounding error): negation
#: and compare-select intrinsics propagate their argument's error bound
#: unchanged; ``abs`` only flips a sign bit.
EXACT_OPS = frozenset({"neg", "abs", "max", "min"})


def rounding_profile(expr: Expr) -> dict[str, int]:
    """Count of *rounded* float ops per kind, ``Let`` bindings counted once.

    The per-op census behind the first-order error model: a bound of
    roughly ``sum(counts) * u * magnitude`` per stage application.
    ``walk`` yields each binding's sub-tree a single time, so the counts
    reflect what a CSE'd evaluation actually executes.
    """
    profile: dict[str, int] = {}
    for node in walk(expr):
        if isinstance(node, BinOp) and node.op in ROUNDED_OPS:
            profile[node.op] = profile.get(node.op, 0) + 1
    return profile


# --------------------------------------------------------------------------
# Utilities
# --------------------------------------------------------------------------


def inline_lets(expr: Expr, _env: dict | None = None) -> Expr:
    """Substitute every ``Var`` by its bound sub-tree (undoes CSE).

    Used by the DSL pretty-printer: ``Let`` has no surface syntax, so a
    lowered spec is printed with bindings expanded back in place.
    """
    env = dict(_env) if _env else {}
    if isinstance(expr, Var):
        return env[expr.name]
    if isinstance(expr, Let):
        for name, bound in expr.bindings:
            env[name] = inline_lets(bound, env)
        return inline_lets(expr.body, env)
    return _map_children(expr, lambda e: inline_lets(e, env))
