"""Multi-device stencil execution: the five SASA parallelisms on a TPU mesh.

FPGA -> TPU mapping (Sec. 3 of the paper re-derived for ICI-connected
chips; docs/DESIGN.md §FPGA-to-TPU mapping carries the full narrative):

  temporal    cascaded PEs, tiles streamed PE->PE     cross-device software
              through FIFOs, one HBM bank touched     pipeline: row tiles flow
                                                      through a ppermute chain,
                                                      device j applies iter j.
  spatial_r   row partitions + redundant halo         one up-front ppermute of
              compute, no inter-PE wires              iter*r rows, then local
                                                      trapezoid, no further comm.
  spatial_s   row partitions + border streaming       r-row ppermute halo
              wires each iteration                    exchange each iteration.
  hybrid_r    k spatial groups x s temporal stages,   up-front iter*r exchange,
              no sync (growing trapezoids)            rounds of s fused
                                                      (VMEM-blocked) iterations.
  hybrid_s    k groups x s stages, first stage        s*r-row ppermute per round,
              exchanges halo*s rows per round         rounds of s fused iters.

Every runner is a jit(shard_map(...)) program over a 1-D ("sp",) device
mesh, numerically equivalent to :func:`repro.kernels.ref.stencil_iterations_ref`
(tests enforce this on 8 forced host devices).

Boundary semantics (docs/DESIGN.md §Boundary semantics): for the default
``zero`` boundary ppermute conveniently zero-fills non-participating edge
devices, exactly the exterior-zero rule.  ``periodic`` boundaries map
onto a *wraparound* ppermute ring — device 0's upper halo arrives from
device k-1 — which is the ICI analogue of the paper's border-streaming
wires closed into a torus.  ``constant``/``replicate`` are re-imposed by
the shared per-stage boundary fixup inside each local trapezoid; the
non-row dimensions, resident in full on every device, carry an explicit
boundary belt the fixup refreshes.
"""
from __future__ import annotations

import math
from typing import Mapping

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import axis_size, pvary, shard_map
from repro.core.model import ParallelismConfig
from repro.core.spec import StencilSpec
from repro.kernels.blockops import boundary_pad, fused_iterations_on_block

AXIS = "sp"


# --------------------------------------------------------------------------
# Halo exchange primitives (the "border streaming" wires)
# --------------------------------------------------------------------------


def exchange_halo(local: jnp.ndarray, h: int, axis: str = AXIS,
                  wrap: bool = False):
    """Return (up_halo, down_halo): h rows from the previous / next device.

    With ``wrap=False`` edge devices receive zeros (exterior-zero boundary
    for the global grid; padded-row shards are additionally handled by the
    boundary fixup).  With ``wrap=True`` the permutation closes into a
    ring — device 0 receives device k-1's bottom rows and vice versa — the
    wraparound halo exchange periodic boundaries need; on a single device
    the ring degenerates to the shard's own opposite edge.
    """
    k = axis_size(axis)
    if h == 0 or (k == 1 and not wrap):
        zeros = jnp.zeros((h,) + local.shape[1:], local.dtype)
        return zeros, zeros
    if k == 1:
        return local[-h:], local[:h]
    if wrap:
        down_perm = [(i, (i + 1) % k) for i in range(k)]
        up_perm = [(i, (i - 1) % k) for i in range(k)]
    else:
        down_perm = [(i, i + 1) for i in range(k - 1)]  # my bottom rows -> next
        up_perm = [(i, i - 1) for i in range(1, k)]     # my top rows -> previous
    up_halo = lax.ppermute(local[-h:], axis, down_perm)   # from device i-1
    down_halo = lax.ppermute(local[:h], axis, up_perm)    # from device i+1
    return up_halo, down_halo


def _extend(local, h, axis=AXIS, wrap=False):
    up, down = exchange_halo(local, h, axis, wrap)
    return jnp.concatenate([up, local, down], axis=0)


# --------------------------------------------------------------------------
# shard_map local programs
# --------------------------------------------------------------------------


def _local_rows(R_pad: int, k: int) -> int:
    return R_pad // k


def _spatial_s_local(spec, iterations, grid_shape, R_k, col_pads, wrap):
    r = spec.radius

    def fn(arrays: dict):
        idx = lax.axis_index(AXIS)
        row0 = idx * R_k - r
        consts = {
            n: _extend(a, r, wrap=wrap) for n, a in arrays.items()
            if n != spec.iterate_input
        }
        cur = arrays[spec.iterate_input]
        for _ in range(iterations):
            ext = dict(consts)
            ext[spec.iterate_input] = _extend(cur, r, wrap=wrap)
            out = fused_iterations_on_block(
                spec, ext, 1, row0, grid_shape, col_pads
            )
            cur = out[r:r + R_k]
        return cur

    return fn


def _spatial_r_local(spec, iterations, grid_shape, R_k, col_pads, wrap):
    r = spec.radius
    H = min(iterations * r, R_k)

    def fn(arrays: dict):
        idx = lax.axis_index(AXIS)
        row0 = idx * R_k - H
        ext = {n: _extend(a, H, wrap=wrap) for n, a in arrays.items()}
        cur = ext[spec.iterate_input]
        # one HBM round trip per iteration (faithful Spatial_R: the fused
        # trapezoid depth is 1; the halo just shrinks by r per iteration)
        for _ in range(iterations):
            ext[spec.iterate_input] = cur
            cur = fused_iterations_on_block(
                spec, ext, 1, row0, grid_shape, col_pads
            )
        return cur[H:H + R_k]

    return fn


def _hybrid_local(spec, iterations, grid_shape, R_k, s, streaming: bool,
                  col_pads, wrap):
    """hybrid_s (streaming=True): exchange s*r rows per round.
    hybrid_r (streaming=False): exchange iter*r rows once, then rounds."""
    r = spec.radius

    def fn(arrays: dict):
        idx = lax.axis_index(AXIS)
        if streaming:
            consts = {
                n: a for n, a in arrays.items() if n != spec.iterate_input
            }
            cur = arrays[spec.iterate_input]
            left = iterations
            while left > 0:
                step = min(s, left)
                h = step * r
                row0 = idx * R_k - h
                ext = {n: _extend(a, h, wrap=wrap) for n, a in consts.items()}
                ext[spec.iterate_input] = _extend(cur, h, wrap=wrap)
                out = fused_iterations_on_block(
                    spec, ext, step, row0, grid_shape, col_pads
                )
                cur = out[h:h + R_k]
                left -= step
            return cur
        # hybrid_r: single up-front exchange of the full run's halo
        H = min(iterations * r, R_k)
        row0 = idx * R_k - H
        ext = {n: _extend(a, H, wrap=wrap) for n, a in arrays.items()}
        cur = ext[spec.iterate_input]
        left = iterations
        while left > 0:
            step = min(s, left)
            ext[spec.iterate_input] = cur
            cur = fused_iterations_on_block(
                spec, ext, step, row0, grid_shape, col_pads
            )
            left -= step
        return cur[H:H + R_k]

    return fn


def _temporal_pipeline_local(spec, iterations, grid_shape, tile_rows, k,
                             col_pads):
    """SODA-analogue temporal pipeline: row tiles stream through the device
    chain, device j applies stencil iteration j of the current round.

    Per round of up-to-k iterations, the loop runs T + k - 1 steps (the
    paper's d*(s_t-1) pipeline-fill delay, Eq. 4).  Input is replicated
    (one logical HBM, as on the FPGA where temporal designs touch a single
    bank); device k-1 materialises the output, which is then broadcast.
    """
    r = spec.radius
    h = k * r
    R = grid_shape[0]
    T = math.ceil(R / tile_rows)
    boundary = spec.boundary

    def _row_pad(a):
        """Boundary halo around the real rows, then tile-alignment zeros.

        The replicated array may carry host row padding past ``R``; the
        boundary fill (wrap/edge/constant) must be laid against the real
        grid edge, so the halo is applied to the first ``R`` rows and the
        alignment padding re-appended outside it.
        """
        zeros = [(0, 0)] * (spec.ndim - 1)
        if boundary.is_zero:
            return jnp.pad(a, [(h, h)] + zeros)
        padded = boundary_pad(a[:R], [(h, h)] + zeros, boundary)
        return jnp.pad(padded, [(0, a.shape[0] - R)] + zeros)

    def one_round(arrays, active):
        """active: number of live stages this round (idle PEs pass through)."""
        j = lax.axis_index(AXIS)
        cur_global = arrays[spec.iterate_input]  # replicated (R_pad, C...)
        consts = {n: a for n, a in arrays.items() if n != spec.iterate_input}
        padded = _row_pad(cur_global)
        consts_padded = {n: _row_pad(a) for n, a in consts.items()}
        tile_shape = (tile_rows + 2 * h,) + tuple(cur_global.shape[1:])
        # carries become device-varying after the first ppermute; mark the
        # initial zeros as varying so the fori_loop carry types match
        out0 = pvary(jnp.zeros_like(cur_global), (AXIS,))
        buf0 = pvary(jnp.zeros(tile_shape, cur_global.dtype), (AXIS,))

        def step(n, state):
            buf, out = state
            tile_idx = n - j
            safe_idx = jnp.clip(tile_idx, 0, T - 1)
            start = (safe_idx * tile_rows,) + (0,) * (spec.ndim - 1)
            loaded = lax.dynamic_slice(padded, start, tile_shape)
            # stage 0 ingests from "HBM"; later stages use the pipelined buf
            buf = jnp.where(j == 0, loaded, buf)
            const_tiles = {
                n: lax.dynamic_slice(a, start, tile_shape)
                for n, a in consts_padded.items()
            }
            row0 = safe_idx * tile_rows - h
            env = dict(const_tiles)
            env[spec.iterate_input] = buf
            applied = fused_iterations_on_block(
                spec, env, 1, row0, grid_shape, col_pads
            )
            applied = jnp.where(j < active, applied, buf)  # idle stage
            # last live stage commits the tile's valid center to the output
            center = lax.dynamic_slice(
                applied, (h,) + (0,) * (spec.ndim - 1),
                (tile_rows,) + tuple(cur_global.shape[1:]),
            )
            valid = (tile_idx >= 0) & (tile_idx < T) & (j == active - 1)
            prev = lax.dynamic_slice(out, start[:1] + (0,) * (spec.ndim - 1),
                                     center.shape)
            out = lax.dynamic_update_slice(
                out, jnp.where(valid, center, prev),
                (safe_idx * tile_rows,) + (0,) * (spec.ndim - 1),
            )
            # stream the tile to the next stage
            k_ = axis_size(AXIS)
            if k_ > 1:
                buf = lax.ppermute(
                    applied, AXIS, [(i, i + 1) for i in range(k_ - 1)]
                )
            else:
                buf = applied
            return buf, out

        _, out = lax.fori_loop(0, T + k - 1, step, (buf0, out0))
        # only the last live stage holds real output rows; broadcast it
        contrib = jnp.where(j == active - 1, out, jnp.zeros_like(out))
        return lax.psum(contrib, AXIS)

    def fn(arrays: dict):
        cur = arrays[spec.iterate_input]
        left = iterations
        env = dict(arrays)
        while left > 0:
            active = min(k, left)
            env[spec.iterate_input] = cur
            cur = one_round(env, active)
            left -= active
        return cur

    return fn


def _with_col_belt(local, spec: StencilSpec, boundary, p: int):
    """Wrap a local program with a boundary belt on the non-row dims.

    Columns are resident in full on every device, so the belt is filled
    locally (edge/wrap/constant of the shard's own columns equals the
    global rule) and sliced back off after the local trapezoid; the
    per-stage fixup inside the trapezoid keeps it current.
    """
    cpads = [(0, 0)] + [(p, p)] * (spec.ndim - 1)

    def fn(arrays: dict):
        ext = {n: boundary_pad(a, cpads, boundary) for n, a in arrays.items()}
        out = local(ext)
        sl = (slice(None),) + tuple(
            slice(p, p + c) for c in spec.shape[1:]
        )
        return out[sl]

    return fn


# --------------------------------------------------------------------------
# Public runner builder
# --------------------------------------------------------------------------


def build_runner(
    spec: StencilSpec,
    cfg: ParallelismConfig,
    iterations: int | None = None,
    devices=None,
    tile_rows: int = 64,
    batched: bool = False,
):
    """Build a jitted multi-device runner for a parallelism configuration.

    Returns ``(run, mesh)`` where ``run(arrays_host) -> np.ndarray`` places
    inputs with the configuration's sharding, executes, and gathers.

    With ``batched=True`` the runner takes arrays with a leading batch
    axis — ``(B,) + spec.shape`` — and evaluates B independent grids in
    one dispatch: the local shard program is vmapped over the batch axis
    while rows stay sharded over the mesh, so one compiled design serves
    many grids (the serving hot path; see :mod:`repro.runtime.batching`).
    """
    it = spec.iterations if iterations is None else iterations
    if spec.wrap_index_inputs:
        # TODO(distribute): re-imposing a streamed wrap margin between
        # rounds needs a collective gather across shards (the wrap source
        # rows live on the opposite device).  Until that lands, shard_map
        # serving keeps the wide iterations*radius periodic margin and
        # narrow-margin specs stay single-device; the auto-tuner's
        # feasibility retry falls back to the next candidate.
        raise ValueError(
            "streamed wrap margins (wrap_index_inputs) are single-device "
            "only; shard_map designs require the wide periodic margin"
        )
    n_dev = cfg.devices_needed
    if devices is None:
        devices = jax.devices()[:n_dev]
    k = len(devices)
    mesh = Mesh(np.array(devices), (AXIS,))
    R = spec.rows
    grid_shape = spec.shape
    boundary = spec.boundary
    wrap = boundary.kind == "periodic"
    # non-zero boundaries carry an explicit column belt the per-stage
    # fixup refreshes (zero keeps the seed's implicit zero-pad columns)
    p_col = 0 if boundary.is_zero else spec.radius
    col_pads = (p_col,) * (spec.ndim - 1)

    if cfg.variant == "temporal":
        R_pad = math.ceil(R / tile_rows) * tile_rows
        local = _temporal_pipeline_local(
            spec, it, grid_shape, tile_rows, k, col_pads
        )
        in_spec = P()   # replicated: one logical HBM bank
        out_spec = P()
    else:
        R_pad = math.ceil(R / k) * k
        R_k = R_pad // k
        if cfg.variant in ("spatial_r", "hybrid_r") and it * spec.radius > R_k:
            raise ValueError(
                f"{cfg.variant} needs iter*r <= rows/device "
                f"({it}*{spec.radius} > {R_k}); the auto-tuner excludes "
                "such configs (halo would span multiple neighbours)"
            )
        if wrap and R_pad != R:
            raise ValueError(
                f"periodic boundary needs rows divisible by the spatial "
                f"degree ({R} rows over k={k} devices leaves "
                f"{R_pad - R} padding rows that would break the "
                "wraparound halo adjacency); the auto-tuner falls back to "
                "the next candidate"
            )
        if boundary.kind == "replicate" and (k - 1) * R_k > R - 1:
            raise ValueError(
                f"replicate boundary needs every device to own at least "
                f"one real grid row ({R} rows over k={k} devices leaves "
                "an all-padding shard that cannot clamp to the edge); "
                "the auto-tuner falls back to the next candidate"
            )
        if cfg.variant == "spatial_s":
            local = _spatial_s_local(
                spec, it, grid_shape, R_k, col_pads, wrap
            )
        elif cfg.variant == "spatial_r":
            local = _spatial_r_local(
                spec, it, grid_shape, R_k, col_pads, wrap
            )
        elif cfg.variant == "hybrid_s":
            local = _hybrid_local(
                spec, it, grid_shape, R_k, max(cfg.s, 1), True, col_pads,
                wrap,
            )
        elif cfg.variant == "hybrid_r":
            local = _hybrid_local(
                spec, it, grid_shape, R_k, max(cfg.s, 1), False, col_pads,
                wrap,
            )
        else:
            raise ValueError(cfg.variant)
        in_spec = P(AXIS)
        out_spec = P(AXIS)

    if p_col:
        local = _with_col_belt(local, spec, boundary, p_col)

    names = list(spec.inputs)
    if batched:
        # batch axis is unsharded and invisible to the local program.
        # With cfg.batch_tile the batch is folded into a sequential grid
        # of batch_tile-wide vmapped chunks (the shard_map analogue of
        # the batch-in-grid tile pipeline): entries stream through the
        # same local-program residency instead of widening every
        # intermediate by the whole batch.  Falls back to one plain vmap
        # when the batch does not tile evenly.
        vm = jax.vmap(local)
        bt = cfg.batch_tile

        def local_batched(arrays: dict):
            B = next(iter(arrays.values())).shape[0]
            if bt and B > bt and B % bt == 0:
                chunked = {
                    n: a.reshape((B // bt, bt) + a.shape[1:])
                    for n, a in arrays.items()
                }
                out = jax.lax.map(vm, chunked)
                return out.reshape((B,) + out.shape[2:])
            return vm(arrays)

        local = local_batched
        if in_spec != P():
            in_spec = P(None, *in_spec)
            out_spec = P(None, *out_spec)
    row_axis = 1 if batched else 0

    @jax.jit
    def sharded_fn(arrays: dict):
        return shard_map(
            local, mesh=mesh,
            in_specs=({n: in_spec for n in names},),
            out_specs=out_spec,
        )(arrays)

    # The three dispatch phases are exposed separately so serving layers can
    # overlap host staging of micro-batch N+1 with device execution of
    # micro-batch N (async double-buffered submit): ``stage`` does host->
    # device placement, ``dispatch`` enqueues the computation without
    # blocking, ``finalize`` blocks (np.asarray) and strips row padding.
    def stage(arrays_host: Mapping[str, jnp.ndarray]) -> dict:
        padded = {}
        for n in names:
            a = jnp.asarray(arrays_host[n])
            if R_pad != R:
                pads = [(0, 0)] * a.ndim
                pads[row_axis] = (0, R_pad - R)
                a = jnp.pad(a, pads)
            padded[n] = jax.device_put(
                a, NamedSharding(mesh, in_spec)
            )
        return padded

    def dispatch(staged: Mapping[str, jnp.ndarray]) -> jnp.ndarray:
        return sharded_fn(dict(staged))

    def finalize(out: jnp.ndarray) -> np.ndarray:
        out = np.asarray(out)
        return out[:, :R] if batched else out[:R]

    def run(arrays_host: Mapping[str, jnp.ndarray]) -> jnp.ndarray:
        return finalize(dispatch(stage(arrays_host)))

    run.stage = stage
    run.dispatch = dispatch
    run.finalize = finalize
    run.mesh = mesh
    run.sharded_fn = sharded_fn
    run.R_pad = R_pad
    run.batched = batched
    return run
