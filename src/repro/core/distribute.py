"""Multi-device stencil execution: the five SASA parallelisms on a TPU mesh.

FPGA -> TPU mapping (Sec. 3 of the paper re-derived for ICI-connected
chips; DESIGN.md carries the full narrative):

  temporal    cascaded PEs, tiles streamed PE->PE     cross-device software
              through FIFOs, one HBM bank touched     pipeline: row tiles flow
                                                      through a ppermute chain,
                                                      device j applies iter j.
  spatial_r   row partitions + redundant halo         one up-front ppermute of
              compute, no inter-PE wires              iter*r rows, then local
                                                      trapezoid, no further comm.
  spatial_s   row partitions + border streaming       r-row ppermute halo
              wires each iteration                    exchange each iteration.
  hybrid_r    k spatial groups x s temporal stages,   up-front iter*r exchange,
              no sync (growing trapezoids)            rounds of s fused
                                                      (VMEM-blocked) iterations.
  hybrid_s    k groups x s stages, first stage        s*r-row ppermute per round,
              exchanges halo*s rows per round         rounds of s fused iters.

Every runner is a jit(shard_map(...)) program over a 1-D ("sp",) device
mesh, numerically equivalent to :func:`repro.kernels.ref.stencil_iterations_ref`
(tests enforce this on 8 forced host devices).

ppermute conveniently zero-fills non-participating edge devices, which is
exactly the exterior-zero boundary the reference semantics prescribe.
"""
from __future__ import annotations

import functools
import math
from typing import Mapping

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import axis_size, pvary, shard_map
from repro.core.model import ParallelismConfig
from repro.core.spec import StencilSpec
from repro.kernels.blockops import fused_iterations_on_block

AXIS = "sp"


# --------------------------------------------------------------------------
# Halo exchange primitives (the "border streaming" wires)
# --------------------------------------------------------------------------


def exchange_halo(local: jnp.ndarray, h: int, axis: str = AXIS):
    """Return (up_halo, down_halo): h rows from the previous / next device.

    Edge devices receive zeros (exterior-zero boundary for the global grid;
    padded-row shards are additionally handled by the grid mask).
    """
    k = axis_size(axis)
    if k == 1 or h == 0:
        zeros = jnp.zeros((h,) + local.shape[1:], local.dtype)
        return zeros, zeros
    down_perm = [(i, i + 1) for i in range(k - 1)]   # my bottom rows -> next
    up_perm = [(i, i - 1) for i in range(1, k)]      # my top rows -> previous
    up_halo = lax.ppermute(local[-h:], axis, down_perm)   # from device i-1
    down_halo = lax.ppermute(local[:h], axis, up_perm)    # from device i+1
    return up_halo, down_halo


def _extend(local, h, axis=AXIS):
    up, down = exchange_halo(local, h, axis)
    return jnp.concatenate([up, local, down], axis=0)


# --------------------------------------------------------------------------
# shard_map local programs
# --------------------------------------------------------------------------


def _local_rows(R_pad: int, k: int) -> int:
    return R_pad // k


def _spatial_s_local(spec, iterations, grid_shape, R_k):
    r = spec.radius
    col0 = (0,) * (spec.ndim - 1)

    def fn(arrays: dict):
        idx = lax.axis_index(AXIS)
        row0 = idx * R_k - r
        consts = {
            n: _extend(a, r) for n, a in arrays.items()
            if n != spec.iterate_input
        }
        cur = arrays[spec.iterate_input]
        for _ in range(iterations):
            ext = dict(consts)
            ext[spec.iterate_input] = _extend(cur, r)
            out = fused_iterations_on_block(
                spec, ext, 1, row0, grid_shape, col0
            )
            cur = out[r:r + R_k]
        return cur

    return fn


def _spatial_r_local(spec, iterations, grid_shape, R_k):
    r = spec.radius
    H = min(iterations * r, R_k)
    col0 = (0,) * (spec.ndim - 1)

    def fn(arrays: dict):
        idx = lax.axis_index(AXIS)
        row0 = idx * R_k - H
        ext = {n: _extend(a, H) for n, a in arrays.items()}
        cur = ext[spec.iterate_input]
        # one HBM round trip per iteration (faithful Spatial_R: the fused
        # trapezoid depth is 1; the halo just shrinks by r per iteration)
        for _ in range(iterations):
            ext[spec.iterate_input] = cur
            cur = fused_iterations_on_block(spec, ext, 1, row0, grid_shape, col0)
        return cur[H:H + R_k]

    return fn


def _hybrid_local(spec, iterations, grid_shape, R_k, s, streaming: bool):
    """hybrid_s (streaming=True): exchange s*r rows per round.
    hybrid_r (streaming=False): exchange iter*r rows once, then rounds."""
    r = spec.radius
    col0 = (0,) * (spec.ndim - 1)
    rounds = math.ceil(iterations / s)

    def fn(arrays: dict):
        idx = lax.axis_index(AXIS)
        if streaming:
            consts = {
                n: a for n, a in arrays.items() if n != spec.iterate_input
            }
            cur = arrays[spec.iterate_input]
            left = iterations
            while left > 0:
                step = min(s, left)
                h = step * r
                row0 = idx * R_k - h
                ext = {n: _extend(a, h) for n, a in consts.items()}
                ext[spec.iterate_input] = _extend(cur, h)
                out = fused_iterations_on_block(
                    spec, ext, step, row0, grid_shape, col0
                )
                cur = out[h:h + R_k]
                left -= step
            return cur
        # hybrid_r: single up-front exchange of the full run's halo
        H = min(iterations * r, R_k)
        row0 = idx * R_k - H
        ext = {n: _extend(a, H) for n, a in arrays.items()}
        cur = ext[spec.iterate_input]
        left = iterations
        while left > 0:
            step = min(s, left)
            ext[spec.iterate_input] = cur
            cur = fused_iterations_on_block(
                spec, ext, step, row0, grid_shape, col0
            )
            left -= step
        return cur[H:H + R_k]

    return fn


def _temporal_pipeline_local(spec, iterations, grid_shape, tile_rows, k):
    """SODA-analogue temporal pipeline: row tiles stream through the device
    chain, device j applies stencil iteration j of the current round.

    Per round of up-to-k iterations, the loop runs T + k - 1 steps (the
    paper's d*(s_t-1) pipeline-fill delay, Eq. 4).  Input is replicated
    (one logical HBM, as on the FPGA where temporal designs touch a single
    bank); device k-1 materialises the output, which is then broadcast.
    """
    r = spec.radius
    h = k * r
    R = grid_shape[0]
    T = math.ceil(R / tile_rows)
    R_pad = T * tile_rows
    col0 = (0,) * (spec.ndim - 1)
    rounds = math.ceil(iterations / k)

    def one_round(arrays, active):
        """active: number of live stages this round (idle PEs pass through)."""
        j = lax.axis_index(AXIS)
        cur_global = arrays[spec.iterate_input]  # replicated (R_pad, C...)
        consts = {n: a for n, a in arrays.items() if n != spec.iterate_input}
        padded = jnp.pad(
            cur_global, [(h, h)] + [(0, 0)] * (spec.ndim - 1)
        )
        consts_padded = {
            n: jnp.pad(a, [(h, h)] + [(0, 0)] * (spec.ndim - 1))
            for n, a in consts.items()
        }
        tile_shape = (tile_rows + 2 * h,) + tuple(cur_global.shape[1:])
        # carries become device-varying after the first ppermute; mark the
        # initial zeros as varying so the fori_loop carry types match
        out0 = pvary(jnp.zeros_like(cur_global), (AXIS,))
        buf0 = pvary(jnp.zeros(tile_shape, cur_global.dtype), (AXIS,))

        def step(n, state):
            buf, out = state
            tile_idx = n - j
            safe_idx = jnp.clip(tile_idx, 0, T - 1)
            start = (safe_idx * tile_rows,) + (0,) * (spec.ndim - 1)
            loaded = lax.dynamic_slice(padded, start, tile_shape)
            # stage 0 ingests from "HBM"; later stages use the pipelined buf
            buf = jnp.where(j == 0, loaded, buf)
            const_tiles = {
                n: lax.dynamic_slice(a, start, tile_shape)
                for n, a in consts_padded.items()
            }
            row0 = safe_idx * tile_rows - h
            env = dict(const_tiles)
            env[spec.iterate_input] = buf
            applied = fused_iterations_on_block(
                spec, env, 1, row0, grid_shape, col0
            )
            applied = jnp.where(j < active, applied, buf)  # idle stage
            # last live stage commits the tile's valid center to the output
            center = lax.dynamic_slice(
                applied, (h,) + (0,) * (spec.ndim - 1),
                (tile_rows,) + tuple(cur_global.shape[1:]),
            )
            valid = (tile_idx >= 0) & (tile_idx < T) & (j == active - 1)
            prev = lax.dynamic_slice(out, start[:1] + (0,) * (spec.ndim - 1),
                                     center.shape)
            out = lax.dynamic_update_slice(
                out, jnp.where(valid, center, prev),
                (safe_idx * tile_rows,) + (0,) * (spec.ndim - 1),
            )
            # stream the tile to the next stage
            k_ = axis_size(AXIS)
            if k_ > 1:
                buf = lax.ppermute(
                    applied, AXIS, [(i, i + 1) for i in range(k_ - 1)]
                )
            else:
                buf = applied
            return buf, out

        _, out = lax.fori_loop(0, T + k - 1, step, (buf0, out0))
        # only the last live stage holds real output rows; broadcast it
        contrib = jnp.where(j == active - 1, out, jnp.zeros_like(out))
        return lax.psum(contrib, AXIS)

    def fn(arrays: dict):
        cur = arrays[spec.iterate_input]
        left = iterations
        env = dict(arrays)
        while left > 0:
            active = min(k, left)
            env[spec.iterate_input] = cur
            cur = one_round(env, active)
            left -= active
        return cur

    return fn


# --------------------------------------------------------------------------
# Public runner builder
# --------------------------------------------------------------------------


def build_runner(
    spec: StencilSpec,
    cfg: ParallelismConfig,
    iterations: int | None = None,
    devices=None,
    tile_rows: int = 64,
    batched: bool = False,
):
    """Build a jitted multi-device runner for a parallelism configuration.

    Returns ``(run, mesh)`` where ``run(arrays_host) -> np.ndarray`` places
    inputs with the configuration's sharding, executes, and gathers.

    With ``batched=True`` the runner takes arrays with a leading batch
    axis — ``(B,) + spec.shape`` — and evaluates B independent grids in
    one dispatch: the local shard program is vmapped over the batch axis
    while rows stay sharded over the mesh, so one compiled design serves
    many grids (the serving hot path; see :mod:`repro.runtime.batching`).
    """
    it = spec.iterations if iterations is None else iterations
    n_dev = cfg.devices_needed
    if devices is None:
        devices = jax.devices()[:n_dev]
    k = len(devices)
    mesh = Mesh(np.array(devices), (AXIS,))
    R = spec.rows
    grid_shape = spec.shape

    if cfg.variant == "temporal":
        R_pad = math.ceil(R / tile_rows) * tile_rows
        local = _temporal_pipeline_local(
            spec, it, grid_shape, tile_rows, k
        )
        in_spec = P()   # replicated: one logical HBM bank
        out_spec = P()
    else:
        R_pad = math.ceil(R / k) * k
        R_k = R_pad // k
        if cfg.variant in ("spatial_r", "hybrid_r") and it * spec.radius > R_k:
            raise ValueError(
                f"{cfg.variant} needs iter*r <= rows/device "
                f"({it}*{spec.radius} > {R_k}); the auto-tuner excludes "
                "such configs (halo would span multiple neighbours)"
            )
        if cfg.variant == "spatial_s":
            local = _spatial_s_local(spec, it, grid_shape, R_k)
        elif cfg.variant == "spatial_r":
            local = _spatial_r_local(spec, it, grid_shape, R_k)
        elif cfg.variant == "hybrid_s":
            local = _hybrid_local(spec, it, grid_shape, R_k, max(cfg.s, 1), True)
        elif cfg.variant == "hybrid_r":
            local = _hybrid_local(spec, it, grid_shape, R_k, max(cfg.s, 1), False)
        else:
            raise ValueError(cfg.variant)
        in_spec = P(AXIS)
        out_spec = P(AXIS)

    names = list(spec.inputs)
    if batched:
        # batch axis is unsharded and invisible to the local program
        local = jax.vmap(local)
        if in_spec != P():
            in_spec = P(None, *in_spec)
            out_spec = P(None, *out_spec)
    row_axis = 1 if batched else 0

    @jax.jit
    def sharded_fn(arrays: dict):
        return shard_map(
            local, mesh=mesh,
            in_specs=({n: in_spec for n in names},),
            out_specs=out_spec,
        )(arrays)

    # The three dispatch phases are exposed separately so serving layers can
    # overlap host staging of micro-batch N+1 with device execution of
    # micro-batch N (async double-buffered submit): ``stage`` does host->
    # device placement, ``dispatch`` enqueues the computation without
    # blocking, ``finalize`` blocks (np.asarray) and strips row padding.
    def stage(arrays_host: Mapping[str, jnp.ndarray]) -> dict:
        padded = {}
        for n in names:
            a = jnp.asarray(arrays_host[n])
            if R_pad != R:
                pads = [(0, 0)] * a.ndim
                pads[row_axis] = (0, R_pad - R)
                a = jnp.pad(a, pads)
            padded[n] = jax.device_put(
                a, NamedSharding(mesh, in_spec)
            )
        return padded

    def dispatch(staged: Mapping[str, jnp.ndarray]) -> jnp.ndarray:
        return sharded_fn(dict(staged))

    def finalize(out: jnp.ndarray) -> np.ndarray:
        out = np.asarray(out)
        return out[:, :R] if batched else out[:R]

    def run(arrays_host: Mapping[str, jnp.ndarray]) -> jnp.ndarray:
        return finalize(dispatch(stage(arrays_host)))

    run.stage = stage
    run.dispatch = dispatch
    run.finalize = finalize
    run.mesh = mesh
    run.sharded_fn = sharded_fn
    run.R_pad = R_pad
    run.batched = batched
    return run
