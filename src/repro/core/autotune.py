"""SASA end-to-end automation flow (paper Sec. 4.3), TPU edition.

  DSL text ──parse──► StencilSpec ──IR lowering──► optimized spec
      ──analytical model──► ranked configs
      ──executor build──► jitted shard_map/Pallas runner (+ host driver)

Mirrors the paper's five steps, with the IR pass pipeline
(:mod:`repro.core.ir`, docs/DESIGN.md §IR pass pipeline) inserted between
the front end and everything else:
  1. parse DSL; lower through constant folding / algebraic simplification
     / CSE, so every later step sees post-optimization op counts;
  2. estimate the resource bound — on TPU this is the VMEM fusion limit
     (Eq. 1's analogue) and the chip count (Eq. 2's analogue);
  3. rank parallelism configs with the analytical model (Eqs. 4-9);
  4. emit the multi-PE program: a jit(shard_map(...)) with ppermute border
     streaming / redundant-halo trapezoids and fused Pallas iteration
     tiles — compiled from the *optimized* expression trees;
  5. if a config is infeasible on the actual device pool (e.g. halo or
     boundary constraint), fall back to the next-best candidate — the
     paper's "build next best design" retry loop.  Since the static
     analyzer (:mod:`repro.core.analysis`) mirrors every runtime guard,
     the loop consumes a precomputed feasibility verdict table: known-
     infeasible candidates are skipped without a build attempt and every
     skip is recorded as a diagnostic on the returned design.
"""
from __future__ import annotations

import dataclasses

import jax

from repro.core import analysis, dsl, model
from repro.core.analysis import Diagnostic
from repro.core.distribute import build_runner
from repro.core.ir import PassReport, lower
from repro.core.model import ParallelismConfig, Prediction
from repro.core.platform import DEFAULT_TPU, TPUPlatform
from repro.core.spec import StencilSpec


@dataclasses.dataclass
class TunedDesign:
    spec: StencilSpec   # the lowered (IR-optimized) spec executors run
    prediction: Prediction
    ranking: list[Prediction]
    runner: object  # callable(arrays) -> np.ndarray
    lowering: tuple[PassReport, ...] = ()  # per-pass op-delta report
    # static-analysis findings from tuning: infeasible-candidate skips
    # (SASA30x), unpredicted build refusals (SASA308), strict-mode output
    diagnostics: tuple[Diagnostic, ...] = ()

    @property
    def config(self) -> ParallelismConfig:
        return self.prediction.config


def autotune(
    source_or_spec,
    platform: TPUPlatform | None = None,
    iterations: int | None = None,
    devices=None,
    build: bool = True,
    tile_rows: int = 64,
    cache=None,
    bucket=False,
    strict: bool = False,
    store=None,
) -> TunedDesign:
    """The SASA entry point: DSL text (or parsed spec) -> optimized runner.

    With ``strict`` the spec is verified first and any error-severity
    diagnostic (division unsafety, no feasible candidate, ...) raises
    :class:`repro.core.analysis.VerificationError` before anything
    compiles; without it, analysis findings ride along on
    ``TunedDesign.diagnostics``.

    Pass a :class:`repro.runtime.DesignCache` as ``cache`` to memoize both
    the ranking and the jitted runner across calls (serving entry points
    do this by default; repeated tuning of the same spec then costs a
    dictionary lookup instead of a re-rank + re-jit).

    Pass a :class:`repro.runtime.DesignStore` (or a path) as ``store`` to
    make that memoization **persistent**: a warm store already holding
    this spec's ranking skips the design-space enumeration entirely, and
    fresh tuning results are written through for the next process.
    Without an explicit ``cache`` a store-backed cache is created; with
    one, the store is attached to it (a cache already bound to a
    *different* store is refused).

    With ``bucket`` (requires ``cache``; ``True`` for the default
    power-of-two ladder or a :class:`repro.runtime.ShapeBucketer`), the
    design is tuned and compiled for the spec's padded canonical *bucket*
    shape instead of its exact shape, and the returned runner pads, masks,
    and unpads transparently — so structurally identical specs whose grid
    sizes share a bucket share one compiled design (multi-geometry
    serving; see :mod:`repro.runtime.bucketing`).
    """
    spec_in = (
        source_or_spec
        if isinstance(source_or_spec, StencilSpec)
        else dsl.parse(source_or_spec)
    )
    if strict:
        analysis.verify_or_raise(
            spec_in, platform=platform, iterations=iterations,
        )
    if store is not None:
        from repro.runtime.cache import DesignCache
        from repro.runtime.store import as_store

        store = as_store(store)
        if cache is None:
            cache = DesignCache(store=store)
        elif cache.store is None:
            cache.store = store
        elif cache.store is not store:
            raise ValueError(
                "autotune(store=...) conflicts with the cache's own store; "
                "pass one or the other"
            )
    if bucket:
        if cache is None:
            raise ValueError("autotune(bucket=...) requires cache=")
        from repro.runtime.bucketing import ShapeBucketer

        spec = spec_in
        bucketer = bucket if isinstance(bucket, ShapeBucketer) else None
        bd = cache.bucketed(
            spec, bucketer=bucketer, platform=platform,
            iterations=iterations, devices=devices, tile_rows=tile_rows,
        )
        if not build:
            from repro.runtime.bucketing import bucket_spec as _bucket_spec

            # bd.bucket_for routes through the spec's halo margins
            # (periodic reserves iterations*radius per side)
            bucket_shape = bd.bucket_for(spec.shape)
            return cache.design(
                _bucket_spec(spec, bucket_shape), platform=platform,
                iterations=iterations, devices=devices,
            )
        entry = bd.runner_for(spec.shape)
        inner = entry.cached.design

        def runner(arrays):
            import numpy as np

            # pass every key through: the bucket runner validates names,
            # so unknown inputs fail loudly instead of being dropped here
            out = entry.runner(
                {n: np.asarray(a)[None] for n, a in arrays.items()}
            )
            return out[0]

        return TunedDesign(
            spec, inner.prediction, inner.ranking, runner,
            diagnostics=getattr(inner, "diagnostics", ()),
        )
    if cache is not None:
        if not build:
            return cache.design(
                spec_in, platform=platform, iterations=iterations,
                devices=devices,
            )
        return cache.get_or_build(
            spec_in, platform=platform, iterations=iterations,
            devices=devices, tile_rows=tile_rows, batched=False,
        ).design
    lowered = lower(spec_in)
    spec = lowered.spec  # ranking AND executors consume the optimized trees
    if platform is None:
        n_avail = len(devices) if devices is not None else len(jax.devices())
        platform = DEFAULT_TPU.with_chips(n_avail)
    elif build:
        n_avail = len(devices) if devices is not None else len(jax.devices())
        platform = platform.with_chips(min(platform.num_chips, n_avail))
    ranking = model.choose_best(
        spec, platform, iterations=iterations, optimize=False
    )
    # Static feasibility preflight mirrors build_runner's guards, so the
    # paper's "build next best design" retry loop consults a verdict
    # table instead of rediscovering each refusal as a ValueError.  Every
    # skip is kept as a diagnostic instead of being silently swallowed.
    n_pool = len(devices) if devices is not None else len(jax.devices())
    verdicts = analysis.preflight(
        spec, [p.config for p in ranking], n_pool, iterations=iterations,
        k_override=len(devices) if devices is not None else None,
    )
    from repro.core import numerics

    bound_diag = numerics.bound_diagnostic(spec, iterations=iterations)
    if not build:
        return TunedDesign(
            spec, ranking[0], ranking, None, lowered.reports,
            (bound_diag,) + tuple(
                v.diagnostic("info") for v in verdicts if not v.feasible
            ),
        )
    diags: list[Diagnostic] = [bound_diag]
    last_err = None
    for pred, verdict in zip(ranking, verdicts):
        runner = None
        if build:
            if not verdict.feasible:
                diags.append(verdict.diagnostic("info"))
                last_err = verdict.reason
                continue
            try:
                runner = build_runner(
                    spec, pred.config, iterations=iterations,
                    devices=devices, tile_rows=tile_rows,
                )
            except ValueError as e:  # a guard preflight did not predict
                diags.append(Diagnostic(
                    "SASA308", "info",
                    f"candidate {pred.config} refused at build time: {e}",
                ))
                last_err = e
                continue
        return TunedDesign(
            spec, pred, ranking, runner, lowered.reports, tuple(diags),
        )
    raise RuntimeError(
        f"no feasible configuration: {last_err}"
        + (
            "\n" + "\n".join(d.format() for d in diags)
            if diags else ""
        )
    )


def soda_baseline(
    source_or_spec,
    platform: TPUPlatform | None = None,
    iterations: int | None = None,
    devices=None,
    build: bool = True,
    tile_rows: int = 64,
) -> TunedDesign:
    """State-of-the-art baseline (SODA): temporal parallelism only.

    The paper's Sec. 5.4 comparison point — identical single-PE design and
    reuse optimisation, but the only multi-PE axis explored is temporal.
    """
    spec = (
        source_or_spec
        if isinstance(source_or_spec, StencilSpec)
        else dsl.parse(source_or_spec)
    )
    lowered = lower(spec)
    spec = lowered.spec
    if platform is None:
        n_avail = len(devices) if devices is not None else len(jax.devices())
        platform = DEFAULT_TPU.with_chips(n_avail)
    cands = [
        p for p in model.choose_best(
            spec, platform, iterations=iterations, optimize=False
        )
        if p.config.variant == "temporal"
    ]
    if not cands:
        raise RuntimeError(
            f"no temporal candidate configurations for {spec.name!r} on "
            f"{platform!r}: the SODA baseline explores only the temporal "
            "axis"
        )
    if not build:
        return TunedDesign(spec, cands[0], cands, None, lowered.reports)
    # same verdict-driven retry loop as autotune(): a statically
    # infeasible temporal config (e.g. a wrap-margin spec on a shard
    # pool) is skipped with a diagnostic, unpredicted build refusals
    # fall back to the next candidate
    n_pool = len(devices) if devices is not None else len(jax.devices())
    verdicts = analysis.preflight(
        spec, [p.config for p in cands], n_pool, iterations=iterations,
        k_override=len(devices) if devices is not None else None,
    )
    diags: list[Diagnostic] = []
    last_err = None
    for pred, verdict in zip(cands, verdicts):
        if not verdict.feasible:
            diags.append(verdict.diagnostic("info"))
            last_err = verdict.reason
            continue
        try:
            runner = build_runner(
                spec, pred.config, iterations=iterations, devices=devices,
                tile_rows=tile_rows,
            )
        except ValueError as e:
            diags.append(Diagnostic(
                "SASA308", "info",
                f"candidate {pred.config} refused at build time: {e}",
            ))
            last_err = e
            continue
        return TunedDesign(
            spec, pred, cands, runner, lowered.reports, tuple(diags),
        )
    raise RuntimeError(f"no feasible temporal configuration: {last_err}")
