"""Command-line DSL linter: ``python -m repro.lint kernel.dsl [...]``.

Runs the static verifier (:mod:`repro.core.analysis`) over DSL files —
or stdin with ``-`` — and prints structured diagnostics with source
spans and caret markers:

    kernel.dsl:5:26 error[SASA301]: stage 'out' divides by streamed ...
      output float: out(0,0) = in(0,0) / in(0,1)
                               ^^^^^^^^^^^^^^^^

Machine-readable output for CI annotation:

  ``--format json``   one stable JSON document (schema below)
  ``--format sarif``  SARIF 2.1.0 (GitHub code-scanning ingestible)

JSON schema (stable; codes/severities are API per
``analysis.DIAGNOSTIC_CODES``)::

    {"version": 1,
     "files": [{"file": "kernel.dsl",
                "diagnostics": [{"code": "SASA301",
                                 "severity": "error",
                                 "message": "...",
                                 "line": 5, "col": 26, "end_col": 42,
                                 "stage": "out"}]}],
     "summary": {"errors": 1, "warnings": 0, "infos": 0}}

``--numerics`` adds the certified-numerics explain mode: for every file
that parses, the per-stage error budget table from
:mod:`repro.core.numerics` (value envelope, accumulated absolute error
bound, and the bound in dtype ULPs) is printed after the diagnostics
(text format) or attached as a ``numerics`` object per file (json).
``--iterations`` / ``--assume-range`` parameterize that analysis.

``--from-py`` treats the given files as Python sources and lints every
embedded DSL string literal (an ast scan for literals with a
``kernel:`` header) — this is how scripts/ci.sh gates ``examples/``.

Exit status is 1 if any error-severity diagnostic was produced (or any
warning under ``--werror``), 0 otherwise — findings of lower severity
are printed but never gate (see scripts/lint_stencils.py, which lints
the stock kernel suite).
"""
from __future__ import annotations

import argparse
import ast
import dataclasses
import json
import math
import sys

from repro.core import analysis

#: severity -> SARIF level
_SARIF_LEVELS = {"error": "error", "warning": "warning", "info": "note"}


def dsl_literals(text: str, filename: str = "<string>") -> list[str]:
    """DSL kernel texts embedded as string literals in Python source.

    The scan is purely syntactic (``ast`` constants containing both a
    ``kernel:`` header and an ``output`` declaration), so it never
    imports or executes the scanned file.
    """
    tree = ast.parse(text, filename=filename)
    out = []
    for node in ast.walk(tree):
        if isinstance(node, ast.Constant) and isinstance(node.value, str):
            if "kernel:" in node.value and "output" in node.value:
                out.append(node.value)
    return out


def diagnostic_dict(d: analysis.Diagnostic) -> dict:
    """One diagnostic as the stable JSON object (span flattened)."""
    return {
        "code": d.code,
        "severity": d.severity,
        "message": d.message,
        "line": d.span.line if d.span else None,
        "col": d.span.col if d.span else None,
        "end_col": d.span.end_col if d.span else None,
        "stage": d.stage,
    }


@dataclasses.dataclass
class _FileResult:
    label: str
    text: str
    diagnostics: list
    numerics: "object | None" = None  # repro.core.numerics.ErrorReport


def _analyze_text(
    text: str,
    label: str,
    numerics_mode: bool,
    iterations: int | None,
    assume_range: float,
) -> _FileResult:
    spec, diags = analysis.lint_text(text)
    report = None
    if numerics_mode and spec is not None:
        from repro.core import numerics

        report = numerics.analyze(
            spec, iterations=iterations, input_range=assume_range,
        )
    return _FileResult(label, text, list(diags), report)


# --------------------------------------------------------------------------
# Renderers
# --------------------------------------------------------------------------


def _render_text(results: list[_FileResult], out) -> None:
    for res in results:
        for d in analysis.sort_diagnostics(res.diagnostics):
            rendered = d.format(res.text)
            first, sep, rest = rendered.partition("\n")
            print(f"{res.label}:{first}", file=out)
            if sep:
                print(rest, file=out)
        if res.numerics is not None:
            print(f"{res.label}: certified numerics", file=out)
            for line in res.numerics.table().splitlines():
                print(f"  {line}", file=out)


def _render_json(results: list[_FileResult], out) -> None:
    files = []
    for res in results:
        entry = {
            "file": res.label,
            "diagnostics": [
                diagnostic_dict(d)
                for d in analysis.sort_diagnostics(res.diagnostics)
            ],
        }
        if res.numerics is not None:
            rep = res.numerics
            entry["numerics"] = {
                "spec": rep.spec_name,
                "dtype": rep.dtype,
                "iterations": rep.iterations,
                "certified": rep.certified,
                "bound": rep.bound if math.isfinite(rep.bound) else None,
                "relative": (
                    rep.relative if math.isfinite(rep.relative) else None
                ),
                "assumed_range": rep.assumed_range,
                "stages": [
                    {
                        "stage": b.stage,
                        "lo": b.lo, "hi": b.hi,
                        "err": b.err if math.isfinite(b.err) else None,
                        "ulps": b.ulps if math.isfinite(b.ulps) else None,
                    }
                    for b in rep.budgets
                ],
            }
        files.append(entry)
    all_diags = [d for r in results for d in r.diagnostics]
    doc = {
        "version": 1,
        "files": files,
        "summary": {
            "errors": sum(d.severity == "error" for d in all_diags),
            "warnings": sum(d.severity == "warning" for d in all_diags),
            "infos": sum(d.severity == "info" for d in all_diags),
        },
    }
    json.dump(doc, out, indent=2)
    out.write("\n")


def _render_sarif(results: list[_FileResult], out) -> None:
    rules_seen: dict[str, dict] = {}
    sarif_results = []
    for res in results:
        for d in analysis.sort_diagnostics(res.diagnostics):
            rules_seen.setdefault(d.code, {
                "id": d.code,
                "shortDescription": {
                    "text": analysis.DIAGNOSTIC_CODES[d.code]
                },
            })
            region = {}
            if d.span is not None:
                region = {
                    "startLine": d.span.line,
                    "startColumn": d.span.col,
                    "endColumn": d.span.end_col,
                }
            sarif_results.append({
                "ruleId": d.code,
                "level": _SARIF_LEVELS[d.severity],
                "message": {"text": d.message},
                "locations": [{
                    "physicalLocation": {
                        "artifactLocation": {"uri": res.label},
                        **({"region": region} if region else {}),
                    },
                }],
            })
    doc = {
        "$schema": (
            "https://raw.githubusercontent.com/oasis-tcs/sarif-spec/"
            "master/Schemata/sarif-schema-2.1.0.json"
        ),
        "version": "2.1.0",
        "runs": [{
            "tool": {
                "driver": {
                    "name": "repro.lint",
                    "informationUri": "https://github.com/",
                    "rules": sorted(
                        rules_seen.values(), key=lambda r: r["id"]
                    ),
                },
            },
            "results": sarif_results,
        }],
    }
    json.dump(doc, out, indent=2)
    out.write("\n")


_RENDERERS = {
    "text": _render_text,
    "json": _render_json,
    "sarif": _render_sarif,
}


# --------------------------------------------------------------------------
# CLI
# --------------------------------------------------------------------------


def lint_source(
    text: str, label: str = "<stdin>", werror: bool = False, out=None
) -> bool:
    """Lint one DSL text; print findings; True iff it gates clean."""
    res = _analyze_text(text, label, False, None, 1.0)
    _render_text([res], out if out is not None else sys.stdout)
    failing = [
        d for d in res.diagnostics
        if d.is_error or (werror and d.severity == "warning")
    ]
    return not failing


def run(
    sources: list[tuple[str, str]],
    fmt: str = "text",
    werror: bool = False,
    numerics_mode: bool = False,
    iterations: int | None = None,
    assume_range: float = 1.0,
    out=None,
) -> int:
    """Lint ``(label, text)`` pairs; render in ``fmt``; return exit code."""
    results = [
        _analyze_text(text, label, numerics_mode, iterations, assume_range)
        for label, text in sources
    ]
    # resolve stdout at call time so redirect_stdout / capsys capture it
    _RENDERERS[fmt](results, out if out is not None else sys.stdout)
    failing = [
        d for r in results for d in r.diagnostics
        if d.is_error or (werror and d.severity == "warning")
    ]
    return 1 if failing else 0


def main(argv: list[str] | None = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.lint",
        description="statically verify SASA stencil DSL files",
    )
    parser.add_argument(
        "files", nargs="+",
        help="DSL files to lint ('-' reads one kernel from stdin)",
    )
    parser.add_argument(
        "--werror", action="store_true",
        help="treat warnings as gate failures",
    )
    parser.add_argument(
        "--format", choices=sorted(_RENDERERS), default="text",
        help="output format (default: human-readable text)",
    )
    parser.add_argument(
        "--numerics", action="store_true",
        help="print the certified per-stage error budget table",
    )
    parser.add_argument(
        "--iterations", type=int, default=None,
        help="iteration count for --numerics (default: the spec's own)",
    )
    parser.add_argument(
        "--assume-range", type=float, default=1.0, metavar="R",
        help="--numerics input-range assumption [-R, R] (default 1.0)",
    )
    parser.add_argument(
        "--from-py", action="store_true",
        help="treat files as Python sources; lint embedded DSL literals",
    )
    args = parser.parse_args(argv)
    sources: list[tuple[str, str]] = []
    for path in args.files:
        if path == "-":
            text = sys.stdin.read()
            label = "<stdin>"
        else:
            with open(path, "r", encoding="utf-8") as f:
                text = f.read()
            label = path
        if args.from_py:
            sources += [
                (f"{label}[{i}]", lit)
                for i, lit in enumerate(dsl_literals(text, filename=label))
            ]
        else:
            sources.append((label, text))
    return run(
        sources,
        fmt=args.format,
        werror=args.werror,
        numerics_mode=args.numerics,
        iterations=args.iterations,
        assume_range=args.assume_range,
    )


if __name__ == "__main__":
    sys.exit(main())
