"""Command-line DSL linter: ``python -m repro.lint kernel.dsl [...]``.

Runs the static verifier (:mod:`repro.core.analysis`) over DSL files —
or stdin with ``-`` — and prints structured diagnostics with source
spans and caret markers:

    kernel.dsl:5:26 error[SASA301]: stage 'out' divides by streamed ...
      output float: out(0,0) = in(0,0) / in(0,1)
                               ^^^^^^^^^^^^^^^^

Exit status is 1 if any error-severity diagnostic was produced (or any
warning under ``--werror``), 0 otherwise — suitable for CI gating (see
scripts/lint_stencils.py, which lints the stock kernel suite).
"""
from __future__ import annotations

import argparse
import sys

from repro.core import analysis


def lint_source(
    text: str, label: str = "<stdin>", werror: bool = False, out=sys.stdout
) -> bool:
    """Lint one DSL text; print findings; True iff it gates clean."""
    _, diags = analysis.lint_text(text)
    for d in analysis.sort_diagnostics(diags):
        rendered = d.format(text)
        first, sep, rest = rendered.partition("\n")
        print(f"{label}:{first}", file=out)
        if sep:
            print(rest, file=out)
    failing = [
        d for d in diags
        if d.is_error or (werror and d.severity == "warning")
    ]
    return not failing


def main(argv: list[str] | None = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.lint",
        description="statically verify SASA stencil DSL files",
    )
    parser.add_argument(
        "files", nargs="+",
        help="DSL files to lint ('-' reads one kernel from stdin)",
    )
    parser.add_argument(
        "--werror", action="store_true",
        help="treat warnings as gate failures",
    )
    args = parser.parse_args(argv)
    ok = True
    for path in args.files:
        if path == "-":
            text = sys.stdin.read()
            label = "<stdin>"
        else:
            with open(path, "r", encoding="utf-8") as f:
                text = f.read()
            label = path
        ok &= lint_source(text, label=label, werror=args.werror)
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
