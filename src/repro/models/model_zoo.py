"""Model wrapper: init / loss / prefill / decode over any ArchConfig.

A ``Model`` bundles the stack with embeddings, modality-frontend stubs
(per assignment: audio/VLM frontends provide *precomputed* embeddings via
input_specs; only a projection lives here), the LM head, and the
train/serve entry points the launcher jits.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import transformer as T


@dataclasses.dataclass
class Model:
    cfg: Any

    # ---------------- parameter init ----------------
    def init(self, key) -> dict:
        cfg = self.cfg
        ks = jax.random.split(key, 8)
        params: dict = {}
        params["embed"], _ = (L.embedding_init(ks[0], cfg.vocab, cfg.d_model),
                              None)
        params["embed"] = params["embed"][0]
        sc, tail, _ = T._stack_init(ks[1], cfg, cfg.pattern, cfg.n_layers)
        params["layers"] = {"scanned": sc, "tail": tail}
        params["ln_f"], _ = L.rmsnorm_init(cfg.d_model)
        if not cfg.tie_embeddings:
            params["unembed"] = L._init_dense(ks[2], (cfg.vocab, cfg.d_model),
                                              in_axis=1)
        if cfg.enc_layers:
            esc, etail, _ = T._stack_init(ks[3], cfg, cfg.enc_pattern,
                                          cfg.enc_layers)
            params["encoder"] = {"scanned": esc, "tail": etail}
            params["ln_enc"], _ = L.rmsnorm_init(cfg.d_model)
        if cfg.frontend:
            params["frontend_proj"] = L._init_dense(
                ks[4], (cfg.frontend_dim, cfg.d_model))
        return params

    # ---------------- logical sharding specs ----------------
    def param_specs(self) -> dict:
        cfg = self.cfg
        specs: dict = {"embed": ("vocab", "embed"), "ln_f": ("embed",)}
        sc, tails = T._stack_specs(cfg, cfg.pattern, cfg.n_layers)
        specs["layers"] = {"scanned": sc, "tail": tails}
        if not cfg.tie_embeddings:
            specs["unembed"] = ("vocab", "embed")
        if cfg.enc_layers:
            esc, etails = T._stack_specs(cfg, cfg.enc_pattern, cfg.enc_layers)
            specs["encoder"] = {"scanned": esc, "tail": etails}
            specs["ln_enc"] = ("embed",)
        if cfg.frontend:
            specs["frontend_proj"] = (None, "embed")
        return specs

    # ---------------- embedding assembly ----------------
    def _embed_inputs(self, params, batch):
        cfg = self.cfg
        dt = jnp.dtype(cfg.act_dtype)
        tok = batch["tokens"]
        x = L.embed(tok, params["embed"], dt)
        if cfg.frontend and "frontend_embeds" in batch:
            fe = batch["frontend_embeds"].astype(dt)
            fe = jnp.einsum("bnd,de->bne", fe, params["frontend_proj"].astype(dt))
            if cfg.enc_layers:
                return x, fe            # enc-dec: frontend feeds the encoder
            x = jnp.concatenate([fe, x], axis=1)  # VLM early fusion
        return x, None

    def _encode(self, params, enc_in):
        cfg = self.cfg
        pos = jnp.arange(enc_in.shape[1])[None].repeat(enc_in.shape[0], 0)
        h, _ = T.stack_apply(
            cfg, cfg.enc_pattern, params["encoder"]["scanned"],
            params["encoder"]["tail"], enc_in, positions=pos, mode="train")
        return L.rmsnorm(h, params["ln_enc"]), pos

    def _trunk(self, params, x, positions, mode, caches=None,
               enc_out=None, enc_positions=None):
        cfg = self.cfg
        x = T.constrain(x, ("batch", None, None))
        h, new_caches = T.stack_apply(
            cfg, cfg.pattern, params["layers"]["scanned"],
            params["layers"]["tail"], x, positions=positions, mode=mode,
            caches=caches, enc_out=enc_out, enc_positions=enc_positions)
        h = L.rmsnorm(h, params["ln_f"])
        table = params["embed"] if cfg.tie_embeddings else params["unembed"]
        logits = L.unembed(h, table)
        if mode == "train":
            # training loss reduces over vocab pointwise per token: keep
            # logits sequence-sharded so no chip materialises (S, V) fully
            logits = T.constrain(logits, ("batch", "seq", None))
        else:
            logits = T.constrain(logits, ("batch", None, "vocab"))
        return logits, new_caches

    # ---------------- train ----------------
    CHUNKED_XENT_MIN_VOCAB = 65536

    def loss(self, params, batch):
        cfg = self.cfg
        x, fe = self._embed_inputs(params, batch)
        enc_out = enc_pos = None
        if cfg.enc_layers:
            enc_in = fe if fe is not None else x  # audio enc-dec: frontend
            enc_out, enc_pos = self._encode(params, enc_in)
        B, S = x.shape[0], x.shape[1]
        positions = jnp.arange(S)[None].repeat(B, 0)
        labels = batch["labels"]
        if cfg.frontend and not cfg.enc_layers and "frontend_embeds" in batch:
            # VLM: frontend positions carry no next-token target
            n_front = batch["frontend_embeds"].shape[1]
            pad = jnp.full((B, n_front), -100, labels.dtype)
            labels = jnp.concatenate([pad, labels], axis=1)
        targets = labels[:, 1:]
        valid = targets >= 0

        table = (params["embed"] if cfg.tie_embeddings
                 else params["unembed"])
        if cfg.vocab >= self.CHUNKED_XENT_MIN_VOCAB:
            # big-vocab path: fuse unembed into a chunked online-softmax
            # CE so (B,S,V) logits are never materialised
            h, _ = self._hidden(params, x, positions, enc_out, enc_pos)
            nll_sum, n = L.chunked_cross_entropy(
                h[:, :-1], table, targets, valid)
            return nll_sum / jnp.maximum(n, 1)
        logits, _ = self._trunk(params, x, positions, "train",
                                enc_out=enc_out, enc_positions=enc_pos)
        logits = logits[:, :-1]
        tgt = jnp.where(valid, targets, 0)
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
        nll = jnp.where(valid, nll, 0.0)
        return nll.sum() / jnp.maximum(valid.sum(), 1)

    def _hidden(self, params, x, positions, enc_out=None, enc_pos=None):
        """Trunk up to the final norm (no unembedding)."""
        cfg = self.cfg
        x = T.constrain(x, ("batch", None, None))
        h, caches = T.stack_apply(
            cfg, cfg.pattern, params["layers"]["scanned"],
            params["layers"]["tail"], x, positions=positions, mode="train",
            enc_out=enc_out, enc_positions=enc_pos)
        h = L.rmsnorm(h, params["ln_f"])
        h = T.constrain(h, ("batch", "seq", None))
        return h, caches

    # ---------------- serve ----------------
    def prefill(self, params, batch):
        cfg = self.cfg
        x, fe = self._embed_inputs(params, batch)
        enc_out = enc_pos = None
        if cfg.enc_layers:
            enc_in = fe if fe is not None else x
            enc_out, enc_pos = self._encode(params, enc_in)
        B, S = x.shape[0], x.shape[1]
        positions = jnp.arange(S)[None].repeat(B, 0)
        logits, caches = self._trunk(params, x, positions, "prefill",
                                     enc_out=enc_out, enc_positions=enc_pos)
        return logits[:, -1], caches

    def init_cache(self, batch_size, cache_len, dtype=None):
        cfg = self.cfg
        dt = jnp.dtype(dtype or cfg.act_dtype)
        return T.init_stack_caches(cfg, cfg.pattern, cfg.n_layers,
                                   batch_size, cache_len, dt)

    def decode_step(self, params, tokens, caches, pos,
                    enc_out=None, enc_positions=None):
        """tokens (B,1) int32; pos (B,) current positions."""
        cfg = self.cfg
        dt = jnp.dtype(cfg.act_dtype)
        x = L.embed(tokens, params["embed"], dt)
        positions = pos[:, None]
        logits, new_caches = self._trunk(params, x, positions, "decode",
                                         caches=caches, enc_out=enc_out,
                                         enc_positions=enc_positions)
        return logits[:, 0], new_caches


def build_model(cfg) -> Model:
    return Model(cfg)
