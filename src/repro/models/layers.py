"""Core transformer layers in plain JAX (params = nested dict pytrees).

Conventions:
  * activations are (batch, seq, d_model) in ``cfg.act_dtype`` (bf16);
  * params are fp32 masters; matmuls cast to act dtype;
  * every init function returns (params, specs) where specs mirrors the
    params tree with *logical axis names*; the launch layer maps logical
    names to mesh axes (see repro/launch/sharding.py).

Logical axis vocabulary:
  "embed"   d_model            "vocab"  vocabulary
  "heads"   attention heads    "kv"     kv heads
  "mlp"     ffn hidden         "expert" MoE experts
  "layers"  scan-stacked layer axis (never sharded)
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np


def _init_dense(key, shape, in_axis=0, dtype=jnp.float32):
    fan_in = shape[in_axis] if isinstance(in_axis, int) else int(
        np.prod([shape[a] for a in in_axis])
    )
    scale = 1.0 / math.sqrt(max(fan_in, 1))
    return jax.random.normal(key, shape, dtype) * scale


def dense_init(key, shape, logical, in_axis=0):
    return _init_dense(key, shape, in_axis), logical


# --------------------------------------------------------------------------
# Norms
# --------------------------------------------------------------------------


def rmsnorm_init(d):
    return jnp.ones((d,), jnp.float32), ("embed",)


def rmsnorm(x, scale, eps=1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * scale).astype(dt)


def layernorm_init(d):
    return {"scale": jnp.ones((d,), jnp.float32),
            "bias": jnp.zeros((d,), jnp.float32)}, \
           {"scale": ("embed",), "bias": ("embed",)}


def layernorm(x, p, eps=1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    return ((x - mu) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]).astype(dt)


# --------------------------------------------------------------------------
# Rotary position embedding
# --------------------------------------------------------------------------


def rope(x, positions, theta=10000.0):
    """x: (..., seq, heads, d_head); positions: (..., seq)."""
    d = x.shape[-1]
    half = d // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    angles = angles[..., None, :]  # broadcast over heads
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# MLPs
# --------------------------------------------------------------------------


def swiglu_init(key, d, f):
    k1, k2, k3 = jax.random.split(key, 3)
    params = {
        "wi": _init_dense(k1, (d, f)),
        "wg": _init_dense(k2, (d, f)),
        "wo": _init_dense(k3, (f, d)),
    }
    specs = {
        "wi": ("embed", "mlp"), "wg": ("embed", "mlp"), "wo": ("mlp", "embed"),
    }
    return params, specs


def swiglu(x, p):
    dt = x.dtype
    h = jnp.einsum("...d,df->...f", x, p["wi"].astype(dt))
    g = jnp.einsum("...d,df->...f", x, p["wg"].astype(dt))
    h = jax.nn.silu(g) * h
    return jnp.einsum("...f,fd->...d", h, p["wo"].astype(dt))


def geglu(x, p):
    dt = x.dtype
    h = jnp.einsum("...d,df->...f", x, p["wi"].astype(dt))
    g = jnp.einsum("...d,df->...f", x, p["wg"].astype(dt))
    h = jax.nn.gelu(g) * h
    return jnp.einsum("...f,fd->...d", h, p["wo"].astype(dt))


# --------------------------------------------------------------------------
# Attention (GQA) — full chunked, local windowed, cross, and decode
# --------------------------------------------------------------------------


def attention_init(key, d_model, n_heads, n_kv, d_head, qkv_bias=False):
    ks = jax.random.split(key, 4)
    params = {
        "wq": _init_dense(ks[0], (d_model, n_heads, d_head)),
        "wk": _init_dense(ks[1], (d_model, n_kv, d_head)),
        "wv": _init_dense(ks[2], (d_model, n_kv, d_head)),
        "wo": _init_dense(ks[3], (n_heads, d_head, d_model), in_axis=(0, 1)),
    }
    specs = {
        "wq": ("embed", "heads", "head_dim"),
        "wk": ("embed", "kv", "head_dim"),
        "wv": ("embed", "kv", "head_dim"),
        "wo": ("heads", "head_dim", "embed"),
    }
    if qkv_bias:
        params["bq"] = jnp.zeros((n_heads, d_head), jnp.float32)
        params["bk"] = jnp.zeros((n_kv, d_head), jnp.float32)
        params["bv"] = jnp.zeros((n_kv, d_head), jnp.float32)
        specs["bq"] = ("heads", "head_dim")
        specs["bk"] = ("kv", "head_dim")
        specs["bv"] = ("kv", "head_dim")
    return params, specs


def _project_qkv(x, p, positions, theta, use_rope=True):
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(dt))
    if "bq" in p:
        q = q + p["bq"].astype(dt)
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    if use_rope:
        q = rope(q, positions, theta)
        k = rope(k, positions, theta)
    return q, k, v


def _repeat_kv(k, n_heads):
    """(B,S,Hkv,D) -> (B,S,Hq,D) by repeating groups."""
    n_kv = k.shape[2]
    if n_kv == n_heads:
        return k
    return jnp.repeat(k, n_heads // n_kv, axis=2)


def attention_chunked(q, k, v, *, causal=True, kv_block=1024,
                      q_positions=None, kv_positions=None, window=0):
    """Memory-bounded attention: lax.scan over KV chunks w/ online softmax.

    This is the flash-attention computation pattern expressed at the XLA
    level: live memory is O(B*H*Sq*kv_block) instead of O(B*H*Sq*Skv).
    ``window > 0`` additionally masks keys older than ``window`` positions.
    """
    B, Sq, H, D = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    qg = q.reshape(B, Sq, Hkv, G, D)  # grouped: K/V are never expanded
    if q_positions is None:
        q_positions = jnp.arange(Sq)[None, :].repeat(B, 0)
    if kv_positions is None:
        kv_positions = jnp.arange(Skv)[None, :].repeat(B, 0)
    scale = 1.0 / math.sqrt(D)
    n_blocks = -(-Skv // kv_block)
    Skv_pad = n_blocks * kv_block
    pad = Skv_pad - Skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_positions = jnp.pad(kv_positions, ((0, 0), (0, pad)),
                               constant_values=-(10 ** 9))
    kb = k.reshape(B, n_blocks, kv_block, Hkv, D).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(B, n_blocks, kv_block, Hkv, D).transpose(1, 0, 2, 3, 4)
    pb = kv_positions.reshape(B, n_blocks, kv_block).transpose(1, 0, 2)

    def step(carry, blk):
        m, l, acc = carry
        kc, vc, pc = blk
        # operands stay bf16; accumulate fp32 (no fp32 copy of K/V blocks)
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, kc,
                       preferred_element_type=jnp.float32) * scale
        mask = jnp.ones_like(s, dtype=bool)
        pcb = pc[:, None, None, None, :]
        qpb = q_positions[:, None, None, :, None]
        if causal:
            mask &= pcb <= qpb
        if window:
            mask &= pcb > qpb - window
        mask &= pcb >= 0
        s = jnp.where(mask, s, -1e30)
        m_new = jnp.maximum(m, s.max(-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhgqk,bkhd->bhgqd", p.astype(vc.dtype), vc,
            preferred_element_type=jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, Hkv, G, Sq), -1e30, jnp.float32)
    l0 = jnp.zeros((B, Hkv, G, Sq), jnp.float32)
    acc0 = jnp.zeros((B, Hkv, G, Sq, D), jnp.float32)

    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, acc0), (kb, vb, pb))
    out = acc / jnp.maximum(l, 1e-30)[..., None]     # (B,Hkv,G,Sq,D)
    out = out.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, D)
    return out.astype(q.dtype)


def local_attention_banded(q, k, v, window, q_positions=None):
    """Sliding-window attention as a 1-D *stencil*: queries in block i attend
    to keys in blocks {i-1, i} only (block size == window), i.e. a sequence
    partition plus one halo block — the paper's border-streaming pattern
    applied to the sequence dimension.  Memory O(S * 2W) instead of O(S^2).
    """
    B, S, H, D = q.shape
    k = _repeat_kv(k, H)
    v = _repeat_kv(v, H)
    W = window
    n = -(-S // W)
    Sp = n * W
    pad = Sp - S
    qp = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    pos = jnp.arange(Sp)
    qb = qp.reshape(B, n, W, H, D)
    # halo: previous key block prepended (zeros for block 0 = exterior-zero)
    kb = kp.reshape(B, n, W, H, D)
    vb = vp.reshape(B, n, W, H, D)
    k_halo = jnp.concatenate([jnp.zeros_like(kb[:, :1]), kb[:, :-1]], axis=1)
    v_halo = jnp.concatenate([jnp.zeros_like(vb[:, :1]), vb[:, :-1]], axis=1)
    k2 = jnp.concatenate([k_halo, kb], axis=2)  # (B,n,2W,H,D)
    v2 = jnp.concatenate([v_halo, vb], axis=2)
    qpos = pos.reshape(n, W)
    kpos = jnp.concatenate(
        [qpos - W, qpos], axis=1
    )  # (n, 2W); block0's halo -> negative = masked
    s = jnp.einsum("bnqhd,bnkhd->bnhqk", qb.astype(jnp.float32),
                   k2.astype(jnp.float32)) / math.sqrt(D)
    mask = (kpos[:, None, :] <= qpos[:, :, None]) & \
           (kpos[:, None, :] > qpos[:, :, None] - W) & \
           (kpos[:, None, :] >= 0) & (qpos[:, :, None] < S)
    s = jnp.where(mask[None, :, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bnhqk,bnkhd->bnqhd", p, v2.astype(jnp.float32))
    return out.reshape(B, Sp, H, D)[:, :S].astype(q.dtype)


def decode_attention(q, k_cache, v_cache, cache_positions, q_position,
                     window=0):
    """Single-step decode: q (B,1,H,D) against a (B,L,Hkv,D) cache.

    The cache stays in its storage dtype (never expanded across GQA
    groups — a 7x blow-up for yi-34b's 56q/8kv); accumulation is forced
    to fp32 via preferred_element_type."""
    B, _, H, D = q.shape
    Hkv = k_cache.shape[2]
    G = H // Hkv
    qg = q.reshape(B, 1, Hkv, G, D)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(k_cache.dtype), k_cache,
                   preferred_element_type=jnp.float32) / math.sqrt(D)
    mask = (cache_positions[:, None, None, None, :]
            <= q_position[:, None, None, None, None])
    mask &= cache_positions[:, None, None, None, :] >= 0
    if window:
        mask &= cache_positions[:, None, None, None, :] > (
            q_position[:, None, None, None, None] - window
        )
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, 1, H, D).astype(q.dtype)


def attn_out(ctx, p):
    return jnp.einsum("bshk,hkd->bsd", ctx, p["wo"].astype(ctx.dtype))


# --------------------------------------------------------------------------
# Embedding / unembedding
# --------------------------------------------------------------------------


def embedding_init(key, vocab, d):
    return _init_dense(key, (vocab, d)) , ("vocab", "embed")


def embed(tokens, table, dtype):
    return jnp.take(table, tokens, axis=0).astype(dtype)


def unembed(x, table):
    return jnp.einsum("bsd,vd->bsv", x, table.astype(x.dtype))


def chunked_cross_entropy(h, table, targets, valid, n_chunks=8):
    """Token cross-entropy WITHOUT materialising (B, S, V) logits.

    Scans the vocabulary in chunks with an online logsumexp and a
    target-logit gather; each chunk is rematerialised in the backward
    pass (jax.checkpoint), so live memory is O(B*S*V/n_chunks).  For a
    150k-200k vocab this removes the dominant training buffer (measured
    2.3 GiB x ~10 live on qwen2-moe train_4k).

    Returns (sum_nll, n_valid).
    """
    B, S, D = h.shape
    V = table.shape[0]
    Vc = -(-V // n_chunks)
    pad = n_chunks * Vc - V
    tbl = jnp.pad(table, ((0, pad), (0, 0))) if pad else table
    tbl = tbl.reshape(n_chunks, Vc, D)
    tgt = jnp.where(valid, targets, 0)

    @jax.checkpoint
    def chunk_stats(carry, args):
        m, l, tlogit = carry
        tc, c = args
        logits = jnp.einsum("bsd,vd->bsv", h, tc.astype(h.dtype),
                            preferred_element_type=jnp.float32)
        # mask vocab padding
        vidx = c * Vc + jnp.arange(Vc)
        logits = jnp.where(vidx < V, logits, -1e30)
        m_new = jnp.maximum(m, logits.max(-1))
        l = l * jnp.exp(m - m_new) + jnp.exp(
            logits - m_new[..., None]).sum(-1)
        # gather the target logit if it falls in this chunk
        local = tgt - c * Vc
        in_chunk = (local >= 0) & (local < Vc)
        got = jnp.take_along_axis(
            logits, jnp.clip(local, 0, Vc - 1)[..., None], axis=-1)[..., 0]
        tlogit = jnp.where(in_chunk, got, tlogit)
        return (m_new, l, tlogit), None

    m0 = jnp.full((B, S), -1e30, jnp.float32)
    l0 = jnp.zeros((B, S), jnp.float32)
    t0 = jnp.zeros((B, S), jnp.float32)
    (m, l, tlogit), _ = jax.lax.scan(
        chunk_stats, (m0, l0, t0), (tbl, jnp.arange(n_chunks)))
    nll = m + jnp.log(jnp.maximum(l, 1e-30)) - tlogit
    nll = jnp.where(valid, nll, 0.0)
    return nll.sum(), valid.sum()
