"""Sequence/channel mixers beyond vanilla attention: MoE, Mamba2 SSD, RG-LRU.

Each mixer exposes:
  * ``*_init(key, cfg)  -> (params, logical_specs)``
  * a full-sequence apply (training / prefill), and
  * a single-token decode step with an explicit recurrent state,
with tests asserting chunked/scan forms match the naive recurrence.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models import layers


# ==========================================================================
# Mixture of Experts (top-k routing, optional shared experts)
# ==========================================================================


def moe_init(key, d_model, n_experts, d_ff_expert, top_k,
             n_shared=0, d_ff_shared=0, n_experts_padded=0):
    """``n_experts_padded``: storage expert count, rounded up so the expert
    dim shards evenly over the model axis (e.g. qwen's 60 -> 64).  Padding
    experts exist in the weights but their router logits are masked to
    -inf, so they never receive tokens or gradients via routing."""
    E_store = max(n_experts_padded, n_experts)
    ks = jax.random.split(key, 6)
    params = {
        "router": layers._init_dense(ks[0], (d_model, E_store)),
        "wi": layers._init_dense(ks[1], (E_store, d_model, d_ff_expert), in_axis=1),
        "wg": layers._init_dense(ks[2], (E_store, d_model, d_ff_expert), in_axis=1),
        "wo": layers._init_dense(ks[3], (E_store, d_ff_expert, d_model), in_axis=1),
    }
    specs = {
        "router": ("embed", "expert"),
        "wi": ("expert", "embed", "mlp"),
        "wg": ("expert", "embed", "mlp"),
        "wo": ("expert", "mlp", "embed"),
    }
    if n_shared:
        sp, ss = layers.swiglu_init(ks[4], d_model, d_ff_shared)
        params["shared"] = sp
        specs["shared"] = ss
    return params, specs


def moe_apply(x, p, *, top_k: int, capacity_factor: float = 1.25,
              return_aux: bool = False, dropless: bool = False,
              n_experts_real: int = 0):
    """Capacity-based sorted dispatch (GShard-style, sort+scatter form).

    FLOPs scale with active params: tokens are argsorted by expert, packed
    into an (E, capacity, D) buffer, processed with one batched SwiGLU
    einsum per matrix, and combined back weighted by router probabilities.
    Overflowing tokens are dropped (standard capacity semantics); the
    auto-tuned capacity factor keeps drop rates negligible at balance.
    """
    from repro.models import transformer as _T

    B, S, D = x.shape
    T = B * S
    E = p["router"].shape[1]
    n_real = n_experts_real or E
    xt = x.reshape(T, D)
    xt = _T.constrain(xt, ("batch", None))
    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    if n_real < E:  # mask padding experts out of the routing distribution
        logits = jnp.where(jnp.arange(E) < n_real, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    topw, topi = jax.lax.top_k(probs, top_k)          # (T, k)
    topw = topw / jnp.maximum(topw.sum(-1, keepdims=True), 1e-9)

    e_flat = topi.reshape(-1)                          # (T*k,)
    w_flat = topw.reshape(-1)
    tok_flat = jnp.arange(T * top_k) // top_k
    order = jnp.argsort(e_flat)                        # stable
    e_sorted = e_flat[order]
    tok_sorted = tok_flat[order]
    w_sorted = w_flat[order]
    starts = jnp.searchsorted(e_sorted, jnp.arange(E))
    pos_sorted = jnp.arange(T * top_k) - starts[e_sorted]
    if dropless:
        cap = T * top_k  # worst case: every token routed to one expert
    else:
        cap = max(int(math.ceil(T * top_k / n_real * capacity_factor)), 1)
    keep = pos_sorted < cap
    pos_safe = jnp.where(keep, pos_sorted, cap)        # cap -> dropped

    src = xt[tok_sorted]
    buf = jnp.zeros((E, cap, D), x.dtype).at[e_sorted, pos_safe].set(
        src, mode="drop"
    )
    # pin expert-parallel layout so the partitioner never replicates the
    # (E, cap, D) dispatch buffer
    buf = _T.constrain(buf, ("expert", None, None))
    dt = x.dtype
    h = jnp.einsum("ecd,edf->ecf", buf, p["wi"].astype(dt))
    g = jnp.einsum("ecd,edf->ecf", buf, p["wg"].astype(dt))
    h = jax.nn.silu(g) * h
    out_buf = jnp.einsum("ecf,efd->ecd", h, p["wo"].astype(dt))
    out_buf = _T.constrain(out_buf, ("expert", None, None))

    gathered = out_buf[e_sorted, jnp.minimum(pos_safe, cap - 1)]
    gathered = gathered * (w_sorted * keep)[:, None].astype(dt)
    y = jnp.zeros((T, D), dt).at[tok_sorted].add(gathered)
    y = _T.constrain(y, ("batch", None))

    if "shared" in p:
        y = y + layers.swiglu(xt, p["shared"])
    y = y.reshape(B, S, D)
    if return_aux:
        # Switch-style load balance loss
        density = jnp.mean(
            jax.nn.one_hot(topi[:, 0], E, dtype=jnp.float32), axis=0
        )
        mean_probs = probs.mean(0)
        aux = E * jnp.sum(density * mean_probs)
        return y, {"load_balance": aux,
                   "dropped_frac": 1.0 - keep.mean()}
    return y


def moe_apply_ep(x, p, *, top_k: int, mesh, batch_axes, ep_axis="model",
                 capacity_factor: float = 1.25, dropless: bool = False,
                 n_experts_real: int = 0):
    """Expert-parallel MoE dispatch as an explicit shard_map program.

    The jit-level dispatch (moe_apply) sorts GLOBAL token indices, which
    GSPMD cannot partition — it replicates the (T*k, D) gather/scatter
    arrays on every chip (measured: 229 GB temps/chip for qwen2-moe
    train_4k).  Here the dispatch is rewritten the way production EP
    systems run it:

      chip (d, m): holds token shard d (replicated over m) and expert
      shard m (FSDP over d).  It routes its LOCAL tokens, packs only the
      experts of shard m (masked scatter, capacity per-shard), all-gathers
      expert weights over the fsdp axis (ZeRO-3), computes, scatters back
      a partial (T_local, D), and one psum over the EP axis combines
      routed + shared-expert partials.

    Requires the expert dim padded to a multiple of the EP axis
    (n_experts_padded in moe_init).
    """
    from jax.sharding import PartitionSpec as P

    from repro.compat import shard_map

    B, S, D = x.shape
    E_pad = p["router"].shape[1]
    n_real = n_experts_real or E_pad
    ep = mesh.shape[ep_axis]
    E_l = E_pad // ep
    fsdp_axis = "data" if "data" in mesh.axis_names else None
    has_shared = "shared" in p

    def local_fn(x_l, router, wi, wg, wo, *shared_ws):
        m = jax.lax.axis_index(ep_axis)
        Bl, Sl, _ = x_l.shape
        T = Bl * Sl
        xt = x_l.reshape(T, D)
        dt = x_l.dtype
        logits = jnp.einsum("td,de->te", xt.astype(jnp.float32),
                            router.astype(jnp.float32))
        logits = jnp.where(jnp.arange(E_pad) < n_real, logits, -1e30)
        probs = jax.nn.softmax(logits, axis=-1)
        topw, topi = jax.lax.top_k(probs, top_k)
        topw = topw / jnp.maximum(topw.sum(-1, keepdims=True), 1e-9)

        e_flat = topi.reshape(-1)
        w_flat = topw.reshape(-1)
        tok_flat = jnp.arange(T * top_k) // top_k
        order = jnp.argsort(e_flat)
        e_sorted = e_flat[order]
        tok_sorted = tok_flat[order]
        w_sorted = w_flat[order]
        starts = jnp.searchsorted(e_sorted, jnp.arange(E_pad))
        pos_sorted = jnp.arange(T * top_k) - starts[e_sorted]
        cap = (T * top_k if dropless else
               max(int(math.ceil(T * top_k / n_real * capacity_factor)), 1))
        keep = pos_sorted < cap
        pos_safe = jnp.where(keep, pos_sorted, cap)

        # pack ONLY this chip's expert shard (out-of-range rows drop)
        e_local = e_sorted - m * E_l
        src = xt[tok_sorted]
        buf = jnp.zeros((E_l, cap, D), dt).at[e_local, pos_safe].set(
            src, mode="drop")

        # ZeRO-3: gather expert weights over the fsdp axis
        if fsdp_axis:
            wi = jax.lax.all_gather(wi, fsdp_axis, axis=1, tiled=True)
            wg = jax.lax.all_gather(wg, fsdp_axis, axis=1, tiled=True)
            wo = jax.lax.all_gather(wo, fsdp_axis, axis=2, tiled=True)
        h = jnp.einsum("ecd,edf->ecf", buf, wi.astype(dt))
        g = jnp.einsum("ecd,edf->ecf", buf, wg.astype(dt))
        h = jax.nn.silu(g) * h
        out_buf = jnp.einsum("ecf,efd->ecd", h, wo.astype(dt))

        mine = keep & (e_local >= 0) & (e_local < E_l)
        gathered = out_buf[jnp.clip(e_local, 0, E_l - 1),
                           jnp.minimum(pos_safe, cap - 1)]
        gathered = gathered * (w_sorted * mine)[:, None].astype(dt)
        y = jnp.zeros((T, D), dt).at[tok_sorted].add(gathered)

        if has_shared:
            swi, swg, swo = shared_ws
            if fsdp_axis:
                swi = jax.lax.all_gather(swi, fsdp_axis, axis=0, tiled=True)
                swg = jax.lax.all_gather(swg, fsdp_axis, axis=0, tiled=True)
                swo = jax.lax.all_gather(swo, fsdp_axis, axis=1, tiled=True)
            hh = jnp.einsum("td,df->tf", xt, swi.astype(dt))
            gg = jnp.einsum("td,df->tf", xt, swg.astype(dt))
            y = y + jnp.einsum("tf,fd->td", jax.nn.silu(gg) * hh,
                               swo.astype(dt))
        y = jax.lax.psum(y, ep_axis)
        return y.reshape(Bl, Sl, D)

    x_spec = P(batch_axes, None, None)
    fs = fsdp_axis
    in_specs = [x_spec, P(None, None),                      # x, router
                P(ep_axis, fs, None), P(ep_axis, fs, None),  # wi, wg
                P(ep_axis, None, fs)]                        # wo
    args = [x, p["router"], p["wi"], p["wg"], p["wo"]]
    if has_shared:
        in_specs += [P(fs, ep_axis), P(fs, ep_axis), P(ep_axis, fs)]
        args += [p["shared"]["wi"], p["shared"]["wg"], p["shared"]["wo"]]
    fn = shard_map(local_fn, mesh=mesh, in_specs=tuple(in_specs),
                   out_specs=x_spec, check_vma=False)
    return fn(*args)


# ==========================================================================
# Mamba-2 (SSD — state space duality, chunked scan)  [arXiv:2405.21060]
# ==========================================================================


def mamba2_init(key, d_model, *, d_state=128, headdim=64, expand=2,
                d_conv=4, n_groups=1):
    d_inner = expand * d_model
    n_heads = d_inner // headdim
    conv_dim = d_inner + 2 * n_groups * d_state
    ks = jax.random.split(key, 5)
    params = {
        "in_proj": layers._init_dense(
            ks[0], (d_model, 2 * d_inner + 2 * n_groups * d_state + n_heads)
        ),
        "conv_w": layers._init_dense(ks[1], (d_conv, conv_dim)) * 0.5,
        "conv_b": jnp.zeros((conv_dim,), jnp.float32),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, n_heads).astype(jnp.float32)),
        "D": jnp.ones((n_heads,), jnp.float32),
        "dt_bias": jnp.zeros((n_heads,), jnp.float32) + jnp.log(
            jnp.expm1(jnp.linspace(1e-3, 0.1, n_heads))
        ).astype(jnp.float32),
        "norm": jnp.ones((d_inner,), jnp.float32),
        "out_proj": layers._init_dense(ks[2], (d_inner, d_model)),
    }
    specs = {
        "in_proj": ("embed", "mlp"), "conv_w": (None, "mlp"),
        "conv_b": ("mlp",), "A_log": ("heads",), "D": ("heads",),
        "dt_bias": ("heads",), "norm": ("mlp",), "out_proj": ("mlp", "embed"),
    }
    meta = dict(d_inner=d_inner, n_heads=n_heads, headdim=headdim,
                d_state=d_state, d_conv=d_conv, n_groups=n_groups)
    return params, specs, meta


def _causal_conv(x, w, b, state=None):
    """Depthwise causal conv1d; x (B,S,C), w (K,C). Returns (y, new_state)."""
    K = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    xx = jnp.concatenate([state, x], axis=1)
    y = sum(xx[:, i:i + x.shape[1]] * w[i].astype(x.dtype)
            for i in range(K))
    new_state = xx[:, -(K - 1):] if K > 1 else state
    return y + b.astype(x.dtype), new_state


def _split_zxbcdt(z_x_b_c_dt, meta):
    di, ng, ns, nh = (meta["d_inner"], meta["n_groups"], meta["d_state"],
                      meta["n_heads"])
    z = z_x_b_c_dt[..., :di]
    xBC = z_x_b_c_dt[..., di:di + di + 2 * ng * ns]
    dt = z_x_b_c_dt[..., -nh:]
    return z, xBC, dt


def mamba2_apply(x, p, meta, *, chunk=64, state=None, return_state=False):
    """Full-sequence SSD forward (chunked; lax.scan over chunks)."""
    B, S, D = x.shape
    di, nh, pd, ns, ng = (meta["d_inner"], meta["n_heads"], meta["headdim"],
                          meta["d_state"], meta["n_groups"])
    dt_act = x.dtype
    zxbcdt = jnp.einsum("bsd,dk->bsk", x, p["in_proj"].astype(dt_act))
    z, xBC, dt = _split_zxbcdt(zxbcdt, meta)
    conv_state = None if state is None else state["conv"]
    xBC, new_conv = _causal_conv(xBC, p["conv_w"], p["conv_b"], conv_state)
    xBC = jax.nn.silu(xBC)
    xs = xBC[..., :di].reshape(B, S, nh, pd)
    Bm = xBC[..., di:di + ng * ns].reshape(B, S, ng, ns)
    Cm = xBC[..., di + ng * ns:].reshape(B, S, ng, ns)
    # broadcast groups over heads
    Bm = jnp.repeat(Bm, nh // ng, axis=2)                   # (B,S,nh,ns)
    Cm = jnp.repeat(Cm, nh // ng, axis=2)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,S,nh)
    A = -jnp.exp(p["A_log"])                                 # (nh,)
    dA = dt * A                                              # (B,S,nh)

    # pad S to chunk multiple
    nc = -(-S // chunk)
    Sp = nc * chunk
    pad = Sp - S
    if pad:
        xs = jnp.pad(xs, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dA = jnp.pad(dA, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))

    def rs(a, *shape):
        return a.reshape(B, nc, chunk, *shape)

    xs_c, B_c, C_c = rs(xs, nh, pd), rs(Bm, nh, ns), rs(Cm, nh, ns)
    dA_c, dt_c = rs(dA, nh), rs(dt, nh)
    Acum = jnp.cumsum(dA_c, axis=2)                          # (B,nc,Q,nh)
    # intra-chunk (diagonal) term: L[i,j] = exp(Acum_i - Acum_j) for i>=j
    Lmat = jnp.exp(
        jnp.clip(Acum[:, :, :, None, :] - Acum[:, :, None, :, :], -60, 0)
    )  # (B,nc,Q,Q,nh) with i>=j valid
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))
    Lmat = jnp.where(tri[None, None, :, :, None], Lmat, 0.0)
    scores = jnp.einsum("bnqhs,bnkhs->bnqkh", C_c.astype(jnp.float32),
                        B_c.astype(jnp.float32))
    y_diag = jnp.einsum("bnqkh,bnqkh,bnkh,bnkhp->bnqhp",
                        scores, Lmat, dt_c, xs_c.astype(jnp.float32))
    # per-chunk input->final-state contribution
    decay_to_end = jnp.exp(jnp.clip(Acum[:, :, -1:, :] - Acum, -60, 0))
    chunk_states = jnp.einsum("bnkh,bnkh,bnkhs,bnkhp->bnhps",
                              dt_c, decay_to_end, B_c.astype(jnp.float32),
                              xs_c.astype(jnp.float32))     # (B,nc,nh,pd,ns)
    chunk_decay = jnp.exp(jnp.clip(Acum[:, :, -1, :], -60, 0))  # (B,nc,nh)

    h0 = (jnp.zeros((B, nh, pd, ns), jnp.float32) if state is None
          else state["ssm"].astype(jnp.float32))

    def scan_fn(h, inp):
        st, dec = inp                                       # (B,nh,pd,ns),(B,nh)
        h_new = h * dec[:, :, None, None] + st
        return h_new, h

    chunk_states_t = chunk_states.transpose(1, 0, 2, 3, 4)
    chunk_decay_t = chunk_decay.transpose(1, 0, 2)
    h_final, h_prevs = jax.lax.scan(
        scan_fn, h0, (chunk_states_t, chunk_decay_t)
    )
    h_prevs = h_prevs.transpose(1, 0, 2, 3, 4)              # (B,nc,nh,pd,ns)
    y_off = jnp.einsum("bnqhs,bnqh,bnhps->bnqhp",
                       C_c.astype(jnp.float32), jnp.exp(jnp.clip(Acum, -60, 0)),
                       h_prevs)
    y = (y_diag + y_off).reshape(B, Sp, nh, pd)[:, :S]
    y = y + xs.reshape(B, Sp, nh, pd)[:, :S].astype(jnp.float32) * p["D"][None, None, :, None]
    y = y.reshape(B, S, di)
    y = layers.rmsnorm(y.astype(dt_act), p["norm"]) * jax.nn.silu(z)
    out = jnp.einsum("bsk,kd->bsd", y, p["out_proj"].astype(dt_act))
    if return_state:
        return out, {"conv": new_conv, "ssm": h_final.astype(jnp.float32)}
    return out


def mamba2_step(x1, p, meta, state):
    """Single-token decode: x1 (B,1,D) with {'conv','ssm'} state."""
    B = x1.shape[0]
    di, nh, pd, ns, ng = (meta["d_inner"], meta["n_heads"], meta["headdim"],
                          meta["d_state"], meta["n_groups"])
    dt_act = x1.dtype
    zxbcdt = jnp.einsum("bsd,dk->bsk", x1, p["in_proj"].astype(dt_act))
    z, xBC, dt = _split_zxbcdt(zxbcdt, meta)
    xBC, new_conv = _causal_conv(xBC, p["conv_w"], p["conv_b"], state["conv"])
    xBC = jax.nn.silu(xBC)
    xs = xBC[..., :di].reshape(B, nh, pd)
    Bm = jnp.repeat(xBC[..., di:di + ng * ns].reshape(B, ng, ns), nh // ng, 1)
    Cm = jnp.repeat(xBC[..., di + ng * ns:].reshape(B, ng, ns), nh // ng, 1)
    dt = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])  # (B,nh)
    A = -jnp.exp(p["A_log"])
    dec = jnp.exp(dt * A)                                   # (B,nh)
    h = state["ssm"].astype(jnp.float32)
    h = h * dec[:, :, None, None] + jnp.einsum(
        "bh,bhs,bhp->bhps", dt, Bm.astype(jnp.float32), xs.astype(jnp.float32)
    )
    y = jnp.einsum("bhs,bhps->bhp", Cm.astype(jnp.float32), h)
    y = y + xs.astype(jnp.float32) * p["D"][None, :, None]
    y = y.reshape(B, 1, di)
    y = layers.rmsnorm(y.astype(dt_act), p["norm"]) * jax.nn.silu(z)
    out = jnp.einsum("bsk,kd->bsd", y, p["out_proj"].astype(dt_act))
    return out, {"conv": new_conv, "ssm": h}


# ==========================================================================
# RG-LRU (Griffin / RecurrentGemma)  [arXiv:2402.19427]
# ==========================================================================


def rglru_init(key, d_model, *, lru_width=None, d_conv=4):
    w = lru_width or d_model
    ks = jax.random.split(key, 6)
    # Lambda init so that a = exp(-8*softplus(L)*r) spans useful decays
    lam = jax.random.uniform(ks[0], (w,), minval=0.38, maxval=0.65)
    params = {
        "in_x": layers._init_dense(ks[1], (d_model, w)),
        "in_gate": layers._init_dense(ks[2], (d_model, w)),
        "conv_w": layers._init_dense(ks[3], (d_conv, w)) * 0.5,
        "conv_b": jnp.zeros((w,), jnp.float32),
        "wa": layers._init_dense(ks[4], (w, w)) * 0.1,
        "wx": layers._init_dense(ks[5], (w, w)) * 0.1,
        "ba": jnp.zeros((w,), jnp.float32),
        "bx": jnp.zeros((w,), jnp.float32),
        "Lambda": jnp.log(jnp.exp(-jnp.log(lam) * 0.125) - 1.0),
        "out": layers._init_dense(jax.random.fold_in(key, 9), (w, d_model)),
    }
    specs = {
        "in_x": ("embed", "mlp"), "in_gate": ("embed", "mlp"),
        "conv_w": (None, "mlp"), "conv_b": ("mlp",),
        "wa": ("mlp", "mlp2"), "wx": ("mlp", "mlp2"),
        "ba": ("mlp",), "bx": ("mlp",), "Lambda": ("mlp",),
        "out": ("mlp", "embed"),
    }
    return params, specs


_C_RGLRU = 8.0


def _rglru_gates(xc, p):
    r = jax.nn.sigmoid(
        jnp.einsum("bsw,wv->bsv", xc, p["wa"].astype(xc.dtype))
        + p["ba"].astype(xc.dtype))
    i = jax.nn.sigmoid(
        jnp.einsum("bsw,wv->bsv", xc, p["wx"].astype(xc.dtype))
        + p["bx"].astype(xc.dtype))
    log_a = (-_C_RGLRU * jax.nn.softplus(p["Lambda"])
             * r.astype(jnp.float32))                       # (B,S,w) <= 0
    a = jnp.exp(log_a)
    mult = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    b = mult * (i.astype(jnp.float32) * xc.astype(jnp.float32))
    return a, b


def rglru_apply(x, p, *, state=None, return_state=False, chunk=256):
    """Griffin recurrent block: linear -> conv1d -> RG-LRU, gated by GeLU
    branch, then output projection.

    The linear recurrence runs as a two-level scan: associative_scan
    within sequence chunks, lax.scan carrying the state across chunks,
    with the per-chunk body rematerialised in the backward pass — the
    fp32 gate tensors (a, sqrt(1-a^2)·i·x) then live for one chunk at a
    time instead of the full (B, S, w) sequence (the dominant training
    buffer for RecurrentGemma).  Exact: linear recurrences compose
    associatively across the chunk boundary via (A_prod, H) pairs.
    """
    dt = x.dtype
    B, S, _ = x.shape
    xw = jnp.einsum("bsd,dw->bsw", x, p["in_x"].astype(dt))
    gate = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", x, p["in_gate"].astype(dt)))
    conv_state = None if state is None else state["conv"]
    xc, new_conv = _causal_conv(xw, p["conv_w"], p["conv_b"], conv_state)
    w = xc.shape[-1]
    h0 = (jnp.zeros((B, w), jnp.float32) if state is None
          else state["h"].astype(jnp.float32))

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    Q = min(chunk, S)
    nc = -(-S // Q)
    pad = nc * Q - S
    xc_p = jnp.pad(xc, ((0, 0), (0, pad), (0, 0))) if pad else xc
    xs = xc_p.reshape(B, nc, Q, w).transpose(1, 0, 2, 3)  # (nc,B,Q,w)
    valid = (jnp.arange(nc * Q) < S).reshape(nc, 1, Q, 1)

    @jax.checkpoint
    def chunk_fn(h_in, inp):
        xc_c, v = inp
        a_c, b_c = _rglru_gates(xc_c, p)          # fp32, one chunk only
        a_c = jnp.where(v, a_c, 1.0)              # pad steps are identity
        b_c = jnp.where(v, b_c, 0.0)
        A, H = jax.lax.associative_scan(combine, (a_c, b_c), axis=1)
        h_t = A * h_in[:, None] + H               # (B,Q,w)
        return h_t[:, -1], h_t.astype(dt)

    h_last, hs = jax.lax.scan(chunk_fn, h0, (xs, valid))
    h = hs.transpose(1, 0, 2, 3).reshape(B, nc * Q, w)[:, :S]
    y = h * gate
    out = jnp.einsum("bsw,wd->bsd", y, p["out"].astype(dt))
    if return_state:
        return out, {"conv": new_conv, "h": h_last}
    return out


def rglru_step(x1, p, state):
    out, new_state = rglru_apply(x1, p, state=state, return_state=True)
    return out, new_state
