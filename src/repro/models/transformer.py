"""Architecture assembly: decoder-only / encoder-decoder / VLM stacks.

Layer stacks are scan-over-layers (params stacked on a leading "layers"
axis) with a configurable remat policy — required to keep 512-device HLO
compile times tractable for 40-60 layer models, and standard production
practice (MaxText does the same).  Non-divisible block patterns (e.g.
RecurrentGemma's 26 = 8x(rec,rec,local)+2) run the remainder unscanned.

Block kinds: "attn" (causal GQA + MLP), "attn_moe", "local" (sliding-window
GQA + MLP), "rec" (RG-LRU + MLP), "ssm" (Mamba2), "enc" (bidirectional),
"xattn" (decoder self+cross for enc-dec).

Activation sharding: ``set_mesh_rules`` installs a mesh + logical->axis
mapping; ``constrain`` applies with_sharding_constraint at the standard
cut points (embeddings, attention heads, MLP hidden, logits).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models import layers as L
from repro.models import mixers as M

# --------------------------------------------------------------------------
# Activation sharding context
# --------------------------------------------------------------------------

_MESH_CTX: dict[str, Any] = {"mesh": None, "rules": {}}


def set_mesh_rules(mesh, rules: dict[str, tuple]):
    """rules: logical activation axis -> mesh axis (or tuple), e.g.
    {"batch": ("pod", "data"), "heads": "model", "mlp": "model",
     "vocab": "model", "embed": None}."""
    _MESH_CTX["mesh"] = mesh
    _MESH_CTX["rules"] = dict(rules)


def clear_mesh_rules():
    _MESH_CTX["mesh"] = None
    _MESH_CTX["rules"] = {}


def constrain(x, logical: tuple):
    mesh = _MESH_CTX["mesh"]
    if mesh is None:
        return x
    rules = _MESH_CTX["rules"]
    spec = P(*[rules.get(a) for a in logical])
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


# --------------------------------------------------------------------------
# Block init / apply
# --------------------------------------------------------------------------


def _norm_init(cfg):
    return L.rmsnorm_init(cfg.d_model)


def block_init(key, cfg, kind: str):
    """Returns (params, specs) for one block of the given kind."""
    ks = jax.random.split(key, 8)
    params, specs = {}, {}

    def add(name, ps):
        params[name], specs[name] = ps

    if kind in ("attn", "attn_moe", "local", "enc", "xattn"):
        add("ln_attn", _norm_init(cfg))
        add("attn", L.attention_init(
            ks[0], cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head,
            qkv_bias=cfg.qkv_bias))
    if kind == "xattn":
        add("ln_cross", _norm_init(cfg))
        add("cross", L.attention_init(
            ks[1], cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head,
            qkv_bias=cfg.qkv_bias))
    if kind == "rec":
        add("ln_rec", _norm_init(cfg))
        rp, rs = M.rglru_init(ks[2], cfg.d_model,
                              lru_width=cfg.lru_width or cfg.d_model)
        add("rec", (rp, rs))
    if kind == "ssm":
        add("ln_ssm", _norm_init(cfg))
        sp, ss, _ = M.mamba2_init(
            ks[3], cfg.d_model, d_state=cfg.ssm_state,
            headdim=cfg.ssm_headdim, expand=cfg.ssm_expand)
        add("ssm", (sp, ss))
        return params, specs  # mamba blocks carry no separate MLP
    # feed-forward half
    add("ln_mlp", _norm_init(cfg))
    if kind.endswith("_moe"):
        mp, ms = M.moe_init(
            ks[4], cfg.d_model, cfg.n_experts, cfg.d_ff_expert, cfg.top_k,
            n_shared=cfg.n_shared_experts, d_ff_shared=cfg.d_ff_shared,
            n_experts_padded=cfg.n_experts_padded)
        add("moe", (mp, ms))
    else:
        add("mlp", L.swiglu_init(ks[5], cfg.d_model, cfg.d_ff))
    return params, specs


def _mlp_apply(cfg, p, x, mode="train"):
    h = L.rmsnorm(x, p["ln_mlp"])
    if "moe" in p:
        # decode batches are tiny: dropless dispatch (cap = T*k) is cheap
        # and keeps decode exactly consistent with the full forward.
        mesh = _MESH_CTX["mesh"]
        ep_ok = (
            mesh is not None and "model" in mesh.axis_names
            and p["moe"]["router"].shape[1] % mesh.shape["model"] == 0
        )
        if ep_ok:
            ba = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
            dp = int(np.prod([mesh.shape[a] for a in ba])) if ba else 1
            ep_ok = bool(ba) and x.shape[0] % dp == 0
        if ep_ok:
            out = M.moe_apply_ep(
                h, p["moe"], top_k=cfg.top_k, mesh=mesh, batch_axes=ba,
                capacity_factor=cfg.capacity_factor,
                dropless=(mode == "decode"),
                n_experts_real=cfg.n_experts)
        else:
            out = M.moe_apply(h, p["moe"], top_k=cfg.top_k,
                              capacity_factor=cfg.capacity_factor,
                              dropless=(mode == "decode"),
                              n_experts_real=cfg.n_experts)
    else:
        h = constrain(h, ("batch", None, None))
        fn = L.geglu if cfg.mlp == "geglu" else L.swiglu
        out = fn(h, p["mlp"])
    return x + out


def _ssm_meta(cfg):
    d_inner = cfg.ssm_expand * cfg.d_model
    return dict(d_inner=d_inner, n_heads=d_inner // cfg.ssm_headdim,
                headdim=cfg.ssm_headdim, d_state=cfg.ssm_state,
                d_conv=4, n_groups=1)


def block_apply(cfg, kind, p, x, *, positions, mode, cache=None,
                enc_out=None, enc_positions=None):
    """One block forward.  mode: 'train' | 'prefill' | 'decode'.
    Returns (x, new_cache)."""
    new_cache = cache
    if kind in ("attn", "attn_moe", "local", "enc", "xattn"):
        h = L.rmsnorm(x, p["ln_attn"])
        q, k, v = L._project_qkv(
            h, p["attn"], positions, cfg.rope_theta,
            use_rope=(kind != "enc" or cfg.rope_on_encoder))
        q = constrain(q, ("batch", None, "heads", None))
        window = cfg.window if kind == "local" else 0
        if mode == "decode":
            kc, vc, cpos = cache["k"], cache["v"], cache["pos"]
            slot = positions[:, 0] % kc.shape[1]
            kc = jax.vmap(lambda c, s, u: jax.lax.dynamic_update_slice(
                c, u, (s, 0, 0)))(kc, slot, k)
            vc = jax.vmap(lambda c, s, u: jax.lax.dynamic_update_slice(
                c, u, (s, 0, 0)))(vc, slot, v)
            cpos = jax.vmap(lambda c, s, u: jax.lax.dynamic_update_slice(
                c, u, (s,)))(cpos, slot, positions[:, :1])
            ctx = L.decode_attention(q, kc, vc, cpos, positions[:, 0],
                                     window=window)
            new_cache = dict(cache, k=kc, v=vc, pos=cpos)
        elif kind == "local" and mode == "train":
            ctx = L.local_attention_banded(q, k, v, cfg.window)
        else:
            causal = kind != "enc"
            ctx = L.attention_chunked(
                q, k, v, causal=causal, kv_block=cfg.kv_block,
                q_positions=positions, kv_positions=positions,
                window=window)
            if mode == "prefill":
                keep = min(cfg.window, k.shape[1]) if kind == "local" else k.shape[1]
                new_cache = {"k": k[:, -keep:], "v": v[:, -keep:],
                             "pos": positions[:, -keep:]}
        x = x + L.attn_out(ctx, p["attn"])
        if kind == "xattn":
            h = L.rmsnorm(x, p["ln_cross"])
            dt = h.dtype
            qx = jnp.einsum("bsd,dhk->bshk", h, p["cross"]["wq"].astype(dt))
            kx = jnp.einsum("bsd,dhk->bshk", enc_out,
                            p["cross"]["wk"].astype(enc_out.dtype))
            vx = jnp.einsum("bsd,dhk->bshk", enc_out,
                            p["cross"]["wv"].astype(enc_out.dtype))
            ctx = L.attention_chunked(
                qx, kx, vx, causal=False, kv_block=cfg.kv_block,
                q_positions=positions, kv_positions=enc_positions)
            x = x + L.attn_out(ctx, p["cross"])
    elif kind == "rec":
        h = L.rmsnorm(x, p["ln_rec"])
        if mode == "decode":
            out, new_cache = M.rglru_step(h, p["rec"], cache)
        elif mode == "prefill":
            out, new_cache = M.rglru_apply(h, p["rec"], return_state=True)
        else:
            out = M.rglru_apply(h, p["rec"])
        x = x + out
    elif kind == "ssm":
        h = L.rmsnorm(x, p["ln_ssm"])
        meta = _ssm_meta(cfg)
        if mode == "decode":
            out, new_cache = M.mamba2_step(h, p["ssm"], meta, cache)
        elif mode == "prefill":
            out, new_cache = M.mamba2_apply(h, p["ssm"], meta,
                                            chunk=cfg.ssm_chunk,
                                            return_state=True)
        else:
            out = M.mamba2_apply(h, p["ssm"], meta, chunk=cfg.ssm_chunk)
        x = x + out
        return x, new_cache
    else:
        raise ValueError(kind)
    x = _mlp_apply(cfg, p, x, mode)
    return x, new_cache


def init_block_cache(cfg, kind, batch, cache_len, dtype=jnp.bfloat16):
    if kind in ("attn", "attn_moe", "enc", "xattn"):
        L_ = cache_len
    elif kind == "local":
        L_ = min(cache_len, cfg.window)
    elif kind == "rec":
        w = cfg.lru_width or cfg.d_model
        return {"conv": jnp.zeros((batch, 3, w), dtype),
                "h": jnp.zeros((batch, w), jnp.float32)}
    elif kind == "ssm":
        meta = _ssm_meta(cfg)
        conv_dim = meta["d_inner"] + 2 * meta["n_groups"] * meta["d_state"]
        return {"conv": jnp.zeros((batch, meta["d_conv"] - 1, conv_dim), dtype),
                "ssm": jnp.zeros((batch, meta["n_heads"], meta["headdim"],
                                  meta["d_state"]), jnp.float32)}
    else:
        raise ValueError(kind)
    return {
        "k": jnp.zeros((batch, L_, cfg.n_kv_heads, cfg.d_head), dtype),
        "v": jnp.zeros((batch, L_, cfg.n_kv_heads, cfg.d_head), dtype),
        "pos": jnp.full((batch, L_), -1, jnp.int32),
    }


# --------------------------------------------------------------------------
# Layer stack (scan over pattern groups + unscanned tail)
# --------------------------------------------------------------------------


def _stack_init(key, cfg, pattern, n_layers):
    """Init params for n_layers following `pattern` cyclically.
    Returns (scanned, tail, specs) where scanned[kind-index] has a leading
    groups axis."""
    glen = len(pattern)
    n_groups = n_layers // glen
    tail = n_layers % glen
    group_params = []
    specs_one = None
    for g in range(n_groups):
        gp = []
        for j, kind in enumerate(pattern):
            p, s = block_init(jax.random.fold_in(key, g * glen + j), cfg, kind)
            gp.append(p)
            if g == 0 and specs_one is None and j == 0:
                pass
        group_params.append(gp)
    specs_group = []
    for j, kind in enumerate(pattern):
        _, s = block_init(jax.random.fold_in(key, j), cfg, kind)
        specs_group.append(s)
    if n_groups:
        scanned = [
            jax.tree.map(lambda *xs: jnp.stack(xs),
                         *[group_params[g][j] for g in range(n_groups)])
            for j in range(glen)
        ]
    else:
        scanned = []
    tail_params = []
    for j in range(tail):
        p, _ = block_init(
            jax.random.fold_in(key, n_groups * glen + j + 10_000),
            cfg, pattern[j])
        tail_params.append(p)
    return scanned, tail_params, specs_group


def block_specs(cfg, kind: str) -> dict:
    """Logical axis specs for one block WITHOUT materialising parameters
    (block_init creates real arrays; for a 400B config that is 16B params
    on the host).  Runs block_init abstractly via eval_shape and captures
    the spec tree from the closure."""
    stash = {}

    def f():
        p, s = block_init(jax.random.PRNGKey(0), cfg, kind)
        stash["s"] = s
        return p

    jax.eval_shape(f)
    return stash["s"]


def _stack_specs(cfg, pattern, n_layers):
    glen = len(pattern)
    n_groups = n_layers // glen
    tail = n_layers % glen
    specs_group = [block_specs(cfg, k) for k in pattern]
    # NOTE: only used for structure; values are logical tuples
    scanned = [jax.tree.map(lambda s: ("layers",) + tuple(s), sg,
                            is_leaf=lambda v: isinstance(v, tuple))
               for sg in specs_group] if n_groups else []
    tails = [specs_group[j] for j in range(tail)]
    return scanned, tails


def _remat_policy(cfg):
    if cfg.remat == "full":
        return jax.checkpoint_policies.nothing_saveable
    if cfg.remat == "dots":
        return jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
    return None


def stack_apply(cfg, pattern, scanned, tail, x, *, positions, mode,
                caches=None, enc_out=None, enc_positions=None):
    """Run the full layer stack.  caches: (scanned_caches, tail_caches)."""
    sc_caches, tail_caches = caches if caches is not None else (None, None)

    def group_fn(x, group_params, group_caches):
        new_caches = []
        for j, kind in enumerate(pattern):
            c = None if group_caches is None else group_caches[j]
            x, nc = block_apply(cfg, kind, group_params[j], x,
                                positions=positions, mode=mode, cache=c,
                                enc_out=enc_out, enc_positions=enc_positions)
            new_caches.append(nc)
        return x, new_caches

    if scanned:
        policy = _remat_policy(cfg)
        with_cache_xs = mode == "decode"

        def body(x, sl):
            if with_cache_xs:
                params_g, caches_g = sl
            else:
                params_g, caches_g = sl, None
            x, ncs = group_fn(x, params_g, caches_g)
            return x, ncs

        if cfg.remat in ("full", "dots"):
            body = jax.checkpoint(
                body, policy=policy, prevent_cse=not cfg.scan_layers)
        xs = (scanned, sc_caches) if with_cache_xs else scanned

        if cfg.scan_layers:
            x, new_sc = jax.lax.scan(body, x, xs)
        else:
            n_groups = jax.tree.leaves(scanned[0])[0].shape[0]
            outs = []
            for g in range(n_groups):
                xg = jax.tree.map(lambda a: a[g], xs)
                x, nc = body(x, xg)
                outs.append(nc)
            new_sc = (jax.tree.map(lambda *v: jnp.stack(v), *outs)
                      if mode != "train" else None)
        if mode == "train":
            new_sc = None
    else:
        new_sc = None

    new_tail = []
    for j, p in enumerate(tail):
        c = None if tail_caches is None else tail_caches[j]
        x, nc = block_apply(cfg, pattern[j], p, x, positions=positions,
                            mode=mode, cache=c,
                            enc_out=enc_out, enc_positions=enc_positions)
        new_tail.append(nc)
    return x, (new_sc, new_tail)


def init_stack_caches(cfg, pattern, n_layers, batch, cache_len,
                      dtype=jnp.bfloat16):
    glen = len(pattern)
    n_groups = n_layers // glen
    tail = n_layers % glen
    if n_groups:
        one_group = [init_block_cache(cfg, k, batch, cache_len, dtype)
                     for k in pattern]
        sc = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (n_groups,) + a.shape).copy(),
            one_group)
    else:
        sc = None
    tails = [init_block_cache(cfg, pattern[j], batch, cache_len, dtype)
             for j in range(tail)]
    return (sc, tails)
