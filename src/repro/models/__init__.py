"""Model substrate: layers, mixers (attention/MoE/SSM/RG-LRU), architectures."""
