"""Fault-tolerant training loop.

Production posture for a synchronous SPMD job on thousands of chips:

  * checkpoint/restart is the recovery primitive — atomic-commit
    checkpoints (repro.checkpoint) written asynchronously every
    ``ckpt_every`` steps, auto-resume from the latest on (re)start;
  * node failure => the job restarts on the surviving slice: restore
    accepts a *different* mesh (elastic rescale) because the data pipeline
    is a pure function of step and checkpoints are topology-free;
  * straggler mitigation: synchronous data parallelism cannot outrun a
    straggling chip, so mitigation = (a) detect via per-step wall-time
    z-score and (b) checkpoint + re-mesh without the offending host —
    the detector and the re-mesh path are both here; the scheduler hook
    (``on_straggler``) is pluggable;
  * failure injection for tests: ``fail_at_step`` raises mid-run, and the
    next Trainer.run() must resume losslessly.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import AsyncCheckpointer, latest_step, restore_checkpoint
from repro.data.pipeline import SyntheticLMData
from repro.optim import make_optimizer


@dataclasses.dataclass
class TrainConfig:
    steps: int = 100
    batch: int = 8
    seq: int = 64
    lr: float = 3e-4
    warmup: int = 10
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    ckpt_keep: int = 3
    log_every: int = 10
    seed: int = 0
    fail_at_step: Optional[int] = None     # failure injection (tests)
    straggler_zscore: float = 4.0
    compress_grads: bool = False


class Trainer:
    def __init__(self, model, cfg: TrainConfig, mesh=None, batch_spec=None,
                 on_straggler: Optional[Callable] = None):
        self.model = model
        self.cfg = cfg
        self.mesh = mesh
        arch = model.cfg
        self.optimizer = make_optimizer(
            arch.optimizer, lr=cfg.lr, total_steps=cfg.steps,
            warmup=cfg.warmup,
            **({"compress_grads": True} if cfg.compress_grads
               and arch.optimizer == "adamw" else {}),
        )
        self.data = SyntheticLMData(
            vocab=arch.vocab, batch=cfg.batch, seq=cfg.seq, seed=cfg.seed,
            frontend_tokens=arch.n_frontend_tokens if arch.frontend else 0,
            frontend_dim=arch.frontend_dim,
            mesh=mesh, batch_spec=batch_spec if batch_spec else (),
        )
        self.on_straggler = on_straggler
        self._step_times: list[float] = []

        def train_step(params, opt_state, batch, step):
            loss, grads = jax.value_and_grad(model.loss)(params, batch)
            new_params, new_opt = self.optimizer.update(
                grads, opt_state, params, step)
            return new_params, new_opt, loss

        self.train_step = jax.jit(train_step, donate_argnums=(0, 1))

    # ------------------------------------------------------------------
    def init_state(self, key=None):
        params = self.model.init(key or jax.random.PRNGKey(self.cfg.seed))
        opt_state = self.optimizer.init(params)
        return {"params": params, "opt": opt_state,
                "step": jnp.zeros((), jnp.int32)}

    def run(self, state=None, steps=None):
        cfg = self.cfg
        ckpt = AsyncCheckpointer(cfg.ckpt_dir, cfg.ckpt_keep) \
            if cfg.ckpt_dir else None
        if state is None:
            state = self.init_state()
            if cfg.ckpt_dir and (last := latest_step(cfg.ckpt_dir)) is not None:
                state = restore_checkpoint(cfg.ckpt_dir, last, state)
                print(f"[trainer] resumed from step {last}")
        start = int(state["step"])
        total = steps if steps is not None else cfg.steps
        losses = []
        for step in range(start, total):
            if cfg.fail_at_step is not None and step == cfg.fail_at_step:
                if ckpt:
                    ckpt.wait()
                raise RuntimeError(f"injected failure at step {step}")
            t0 = time.perf_counter()
            batch = self.data.batch_at(step)
            params, opt, loss = self.train_step(
                state["params"], state["opt"], batch,
                jnp.asarray(step, jnp.int32))
            state = {"params": params, "opt": opt,
                     "step": jnp.asarray(step + 1, jnp.int32)}
            dt = time.perf_counter() - t0
            self._check_straggler(step, dt)
            losses.append(float(loss))
            if step % cfg.log_every == 0:
                print(f"[trainer] step {step} loss {float(loss):.4f} "
                      f"({dt*1e3:.0f} ms)")
            if ckpt and (step + 1) % cfg.ckpt_every == 0:
                ckpt.save(step + 1, state)
        if ckpt:
            ckpt.save(int(state["step"]), state)
            ckpt.wait()
        return state, losses

    # ------------------------------------------------------------------
    def _check_straggler(self, step: int, dt: float):
        """Per-step wall-time z-score straggler detector."""
        if step < 3:
            return  # exclude compile-warmup steps from the baseline
        self._step_times.append(dt)
        hist = self._step_times[-50:]
        if len(hist) >= 20:
            mu = float(np.mean(hist[:-1]))
            sd = float(np.std(hist[:-1])) + 1e-9
            z = (dt - mu) / sd
            if z > self.cfg.straggler_zscore and self.on_straggler:
                self.on_straggler(step=step, zscore=z, dt=dt)
