from repro.train.trainer import Trainer, TrainConfig

__all__ = ["Trainer", "TrainConfig"]
