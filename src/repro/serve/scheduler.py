"""Continuous-batching scheduler: flush-free serving over a StencilServer.

The flush-driven loop in :mod:`repro.serve.engine` is a *barrier*
scheduler: requests wait until some caller flushes, every queued ticket
dispatches, the flush returns.  That shape is fine for offline batches
but wrong for open-loop traffic — arrivals between flushes wait for the
next barrier, and a slow design's batch blocks an interactive one's.

``StencilScheduler`` replaces the barrier with the continuous-batching
idiom from the LLM-serving ecosystem, adapted to SASA's bucketed
micro-batches (which are already the right admission unit: one compiled
design serves one ``design x bucket`` group at a fixed batch width):

  * **admission** — ``submit()`` validates against the registration
    (same checks as the engine), stamps a deadline from the request's
    SLO lane, and enqueues into its ``design x bucket`` group in
    deadline order.  Admission is bounded: a full queue or an exhausted
    per-tenant quota rejects with :class:`Backpressure` carrying a
    ``retry_after_s`` hint instead of growing without bound.
  * **dispatch loop** — a background thread coalesces each group up to
    the server's ``max_batch``, dispatching a group when it is full,
    when its oldest ticket has waited out the gather window, or when its
    head deadline's slack runs low.  Among due groups the earliest head
    deadline wins, tie-broken round-robin by least-recently-served
    design so one hot kernel cannot starve the others.  In-flight
    micro-batches are reaped **non-blockingly** (``runner.ready`` /
    :func:`repro.compat.is_ready`) so admission and staging overlap
    device execution, exactly like the engine's double-buffered flush.
  * **resolution** — every ticket is a small future: ``result()``
    blocks (with timeout) until its micro-batch materialises; dispatch
    faults surface per ticket, never as a dropped request.  ``drain()``
    resolves every outstanding ticket; ``close()`` drains and stops.

Results are **bitwise-identical** to the synchronous engine path: the
scheduler stages through the server's own ``_prepare`` (same padding to
the compiled ``max_batch`` width, same streamed service inputs, same
compiled runner), so on a fixed backend a grid's result does not depend
on which batch — or which scheduler — carried it.

Unit tests drive the loop deterministically: construct with
``start=False`` and call :meth:`StencilScheduler.step` by hand.
"""
from __future__ import annotations

import collections
import dataclasses
import heapq
import threading
import time

import jax
import numpy as np

# default SLO lanes (seconds of slack granted at admission). Tighter
# lane -> earlier deadline -> dispatched first under contention.
DEFAULT_LANES = {
    "interactive": 0.05,
    "standard": 0.5,
    "batch": 5.0,
}


class Backpressure(RuntimeError):
    """Admission rejected: queue or tenant quota is full.

    ``retry_after_s`` is the scheduler's estimate of when capacity
    frees up — clients back off instead of the queue growing without
    bound (reject-with-retry-after, not buffer-until-OOM).
    """

    def __init__(self, message: str, retry_after_s: float):
        super().__init__(message)
        self.retry_after_s = float(retry_after_s)

    def __reduce__(self):
        # default exception pickling calls cls(*args) with args=(message,),
        # losing retry_after_s — the router ships these across processes
        return (Backpressure, (str(self), self.retry_after_s))


@dataclasses.dataclass(eq=False)      # identity hash: tickets key results
class Ticket:
    """One admitted request: a future resolved by the dispatch loop."""

    id: int
    design: str
    lane: str
    tenant: str
    deadline: float                       # monotonic seconds
    _event: threading.Event = dataclasses.field(
        default_factory=threading.Event, repr=False
    )
    _result: "np.ndarray | None" = dataclasses.field(
        default=None, repr=False
    )
    _error: Exception | None = dataclasses.field(default=None, repr=False)
    completed_at: float | None = None     # monotonic resolution stamp

    def done(self) -> bool:
        return self._event.is_set()

    def exception(self) -> Exception | None:
        """The dispatch fault that resolved this ticket, if any (does
        not block; ``None`` while pending or on success)."""
        return self._error

    def result(self, timeout: float | None = None) -> np.ndarray:
        """Block until resolved; returns the grid or raises the fault."""
        if not self._event.wait(timeout):
            raise TimeoutError(
                f"ticket {self.id} ({self.design!r}, lane {self.lane!r}) "
                f"not resolved within {timeout}s"
            )
        if self._error is not None:
            raise self._error
        return self._result


@dataclasses.dataclass
class _Group:
    """Pending tickets of one ``design x bucket``, deadline-ordered."""

    key: tuple                            # (design name, bucket | None)
    heap: list = dataclasses.field(default_factory=list)
    oldest_t: float = 0.0                 # enqueue time of current oldest

    def __len__(self) -> int:
        return len(self.heap)


@dataclasses.dataclass
class _InFlight:
    reg: object
    chunk: list                           # [(ticket, request, shape), ...]
    out: object
    runner: object
    post: object
    pad: int
    t0: float


class StencilScheduler:
    """Flush-free continuous batching over a :class:`StencilServer`.

    The scheduler owns admission and dispatch; the server contributes
    its registrations, validation, staging (``_prepare``), counters, and
    batch geometry (``max_batch`` / ``max_inflight``).  Both serving
    paths can coexist on one server: the scheduler never touches the
    server's flush queue or ticket space.

    ``lanes`` maps lane name -> SLO seconds (:data:`DEFAULT_LANES` when
    omitted); ``max_queue`` bounds total pending tickets; ``quota``
    bounds *outstanding* (admitted, unresolved) tickets per tenant — an
    int applies to every tenant, a dict sets per-tenant limits with
    ``None`` meaning unlimited.  ``gather_window_s`` is how long a
    non-full group may wait for coalescing partners before it dispatches
    anyway.  ``start=False`` skips the background thread: tests call
    :meth:`step` / :meth:`drain` deterministically.
    """

    def __init__(
        self,
        server,
        lanes: dict | None = None,
        default_lane: str = "standard",
        max_queue: int = 1024,
        quota=None,
        gather_window_s: float = 0.002,
        poll_interval_s: float = 0.0005,
        start: bool = True,
    ):
        if max_queue < 1:
            raise ValueError(f"max_queue must be >= 1, got {max_queue}")
        self.server = server
        self.lanes = dict(lanes) if lanes is not None else dict(DEFAULT_LANES)
        if default_lane not in self.lanes:
            raise ValueError(
                f"default lane {default_lane!r} not in lanes "
                f"{sorted(self.lanes)}"
            )
        self.default_lane = default_lane
        self.max_queue = max_queue
        self.quota = quota
        self.gather_window_s = gather_window_s
        self.poll_interval_s = poll_interval_s
        self._mutex = threading.Lock()
        self._work = threading.Condition(self._mutex)
        self._groups: "collections.OrderedDict[tuple, _Group]" = (
            collections.OrderedDict()
        )
        self._pending = 0                 # tickets admitted, not dispatched
        self._outstanding: collections.Counter = collections.Counter()
        self._inflight: collections.deque[_InFlight] = collections.deque()
        self._dispatching = 0             # chunks owned by a dispatch/reap
        self._last_served: dict[str, int] = {}   # design -> serve sequence
        self._serve_seq = 0
        self._seq = 0                     # heap tie-break
        self._next_id = 0
        self._draining = False
        self._stop = False
        self._step_lock = threading.Lock()
        # counters (stats() keeps these finite-clean by construction)
        self.admitted = 0
        self.rejected = 0                 # Backpressure admissions
        self.dispatched_batches = 0
        self.completed = 0
        self.failed = 0
        self.deadline_misses = 0          # resolved after their deadline
        self._thread: threading.Thread | None = None
        if start:
            self._thread = threading.Thread(
                target=self._loop, name="stencil-scheduler", daemon=True
            )
            self._thread.start()

    # ------------------------------------------------------------------
    # admission
    # ------------------------------------------------------------------

    def _quota_for(self, tenant: str):
        if self.quota is None:
            return None
        if isinstance(self.quota, dict):
            return self.quota.get(tenant)
        return self.quota

    def _retry_after(self) -> float:
        """Capacity hint for rejected admissions: roughly one queue's
        worth of micro-batches at the fleet's observed mean batch
        latency (zero-guarded; floors at the gather window)."""
        mean_s, n = 0.0, 0
        for reg in self.server._designs.values():
            c = reg.counters
            if c.exec_count:
                mean_s += c.exec_mean_s
                n += 1
        mean_s = (mean_s / n) if n else 0.01
        batches = (self._pending // max(1, self.server.max_batch)) + 1
        return max(self.gather_window_s, batches * mean_s)

    def submit(
        self,
        request,
        lane: str | None = None,
        tenant: str = "default",
        deadline_s: float | None = None,
    ) -> Ticket:
        """Admit one request; returns a :class:`Ticket` future.

        Validation is the server's own (unknown design / bad inputs
        raise immediately).  ``lane`` picks the SLO deadline
        (``deadline_s`` overrides it outright); ``tenant`` is the quota
        accounting unit.  Raises :class:`Backpressure` — with a
        ``retry_after_s`` hint — when the queue or the tenant's quota
        is full.
        """
        shape = self.server._validate(request)
        reg = self.server._designs[request.design]
        bucket = reg.bucket_for(shape) if reg.bucketed else None
        lane = lane if lane is not None else self.default_lane
        if lane not in self.lanes:
            raise ValueError(f"unknown lane {lane!r} ({sorted(self.lanes)})")
        now = time.monotonic()
        slo = self.lanes[lane] if deadline_s is None else deadline_s
        with self._work:
            if self._pending >= self.max_queue:
                self.rejected += 1
                raise Backpressure(
                    f"queue full ({self._pending}/{self.max_queue} pending)",
                    retry_after_s=self._retry_after(),
                )
            limit = self._quota_for(tenant)
            if limit is not None and self._outstanding[tenant] >= limit:
                self.rejected += 1
                raise Backpressure(
                    f"tenant {tenant!r} quota exhausted "
                    f"({self._outstanding[tenant]}/{limit} outstanding)",
                    retry_after_s=self._retry_after(),
                )
            ticket = Ticket(
                id=self._next_id, design=request.design, lane=lane,
                tenant=tenant, deadline=now + slo,
            )
            self._next_id += 1
            key = (request.design, bucket)
            group = self._groups.get(key)
            if group is None:
                group = self._groups[key] = _Group(key=key)
            if not group.heap:
                group.oldest_t = now
            heapq.heappush(
                group.heap, (ticket.deadline, self._seq, ticket, request,
                             shape)
            )
            self._seq += 1
            self._pending += 1
            self._outstanding[tenant] += 1
            self.admitted += 1
            self._work.notify_all()
        return ticket

    # ------------------------------------------------------------------
    # the dispatch loop
    # ------------------------------------------------------------------

    def _has_work(self) -> bool:
        return bool(self._pending or self._dispatching or self._inflight)

    def _loop(self) -> None:
        while True:
            with self._work:
                if self._stop and not self._has_work():
                    return
                if not self._has_work():
                    self._work.wait(timeout=0.05)
                    continue
            if not self.step():
                time.sleep(self.poll_interval_s)

    def _select_due(self, now: float):
        """The due group to dispatch next, or None.

        Due = full batch, gather window elapsed, head-deadline slack at
        or below the gather window, or draining.  Earliest head deadline
        wins; ties go to the least-recently-served design (round-robin
        fairness across registered kernels).
        """
        best, best_rank = None, None
        for group in self._groups.values():
            if not group.heap:
                continue
            head_deadline = group.heap[0][0]
            due = (
                len(group.heap) >= self.server.max_batch
                or (now - group.oldest_t) >= self.gather_window_s
                or (head_deadline - now) <= self.gather_window_s
                or self._draining
                or self._stop
            )
            if not due:
                continue
            rank = (head_deadline, self._last_served.get(group.key[0], -1))
            if best_rank is None or rank < best_rank:
                best, best_rank = group, rank
        return best

    def step(self) -> bool:
        """One scheduling iteration: reap what finished, dispatch the
        most urgent due group.  Returns whether any progress was made
        (the loop sleeps a poll interval when idle).  Thread-safe;
        tests with ``start=False`` call this directly."""
        with self._step_lock:
            progressed = self._reap(block=False)
            now = time.monotonic()
            with self._work:
                group = self._select_due(now)
                chunk = None
                if group is not None:
                    n = min(len(group.heap), self.server.max_batch)
                    chunk = []
                    for _ in range(n):
                        _, _, ticket, request, shape = heapq.heappop(
                            group.heap
                        )
                        chunk.append((ticket, request, shape))
                    self._pending -= n
                    # counted until the chunk lands in _inflight or
                    # resolves, so drain()'s _has_work() barrier cannot
                    # slip through mid-dispatch
                    self._dispatching += 1
                    if group.heap:
                        group.oldest_t = now
                    self._serve_seq += 1
                    self._last_served[group.key[0]] = self._serve_seq
            if chunk is None:
                if self._draining and self._inflight:
                    return self._reap(block=True) or progressed
                return progressed
            try:
                while len(self._inflight) >= self.server.max_inflight:
                    self._reap(block=True)   # free an in-flight slot
                self._dispatch(group.key, chunk)
            finally:
                with self._work:
                    self._dispatching -= 1
                    self._work.notify_all()
            return True

    def _dispatch(self, key, chunk) -> None:
        """Stage + dispatch one micro-batch through the server's own
        staging path (identical padding and runner as the sync engine,
        hence bitwise-identical results)."""
        name, bucket = key
        reg = self.server._designs[name]
        t0 = time.perf_counter()
        try:
            runner, stacked, post, pad = self.server._prepare(
                reg, bucket, chunk
            )
            chain = (
                callable(getattr(runner, "stage", None))
                and callable(getattr(runner, "dispatch", None))
                and callable(getattr(runner, "finalize", None))
            )
            if not chain:
                # legacy / monkeypatched runner: synchronous plain call
                out = np.asarray(runner(stacked))
                self.server._account(reg, chunk, pad,
                                     time.perf_counter() - t0)
                self._resolve_chunk(chunk, post(out))
                self.dispatched_batches += 1
                return
            out = runner.dispatch(runner.stage(stacked))
        except Exception as e:
            self._fail_chunk(reg, chunk, e)
            return
        self.dispatched_batches += 1
        self._inflight.append(_InFlight(
            reg=reg, chunk=chunk, out=out, runner=runner, post=post,
            pad=pad, t0=t0,
        ))

    def _reap(self, block: bool) -> bool:
        """Resolve finished in-flight batches; with ``block`` resolve at
        least the oldest one even if it means waiting on the device."""
        did = False
        while self._inflight:
            head = self._inflight[0]
            ready = getattr(head.runner, "ready", None)
            is_done = bool(ready(head.out)) if callable(ready) else True
            if not (block or is_done):
                break
            # own the chunk across the reap: between popleft and
            # resolution it is in neither _inflight nor any queue, and
            # drain()'s _has_work() barrier must not slip through that
            # window while block_until_ready waits on the device
            with self._work:
                self._dispatching += 1
            try:
                infl = self._inflight.popleft()
                try:
                    jax.block_until_ready(infl.out)
                    out = infl.runner.finalize(infl.out)
                    self.server._account(
                        infl.reg, infl.chunk, infl.pad,
                        time.perf_counter() - infl.t0,
                    )
                    self._resolve_chunk(infl.chunk, infl.post(out))
                except Exception as e:
                    self._fail_chunk(infl.reg, infl.chunk, e)
            finally:
                with self._work:
                    self._dispatching -= 1
                    self._work.notify_all()
            did = True
            block = False                 # only force the oldest
        return did

    def _resolve_chunk(self, chunk, results: dict) -> None:
        now = time.monotonic()
        with self._work:
            for ticket, _, _ in chunk:
                ticket._result = results[ticket]
                ticket.completed_at = now
                if now > ticket.deadline:
                    self.deadline_misses += 1
                self._outstanding[ticket.tenant] -= 1
                self.completed += 1
                ticket._event.set()
            self._work.notify_all()

    def _fail_chunk(self, reg, chunk, exc: Exception) -> None:
        reg.counters.failed_requests += len(chunk)
        now = time.monotonic()
        with self._work:
            for ticket, _, _ in chunk:
                ticket._error = exc
                ticket.completed_at = now
                self._outstanding[ticket.tenant] -= 1
                self.failed += 1
                ticket._event.set()
            self._work.notify_all()

    # ------------------------------------------------------------------
    # lifecycle
    # ------------------------------------------------------------------

    def drain(self, timeout: float = 120.0) -> None:
        """Dispatch and resolve every outstanding ticket (all groups
        become due; in-flight batches block-reap).  Every admitted
        ticket is resolved — with a result or a fault — before this
        returns."""
        self._draining = True
        try:
            deadline = time.monotonic() + timeout
            if self._thread is None or not self._thread.is_alive():
                while self._has_work():
                    if not self.step():
                        self._reap(block=True)
                    if time.monotonic() > deadline:
                        raise TimeoutError("drain timed out")
            else:
                with self._work:
                    self._work.notify_all()
                    while self._has_work():
                        if not self._work.wait(timeout=0.05):
                            if time.monotonic() > deadline:
                                raise TimeoutError("drain timed out")
        finally:
            self._draining = False
        self.server.persist_telemetry()

    def close(self, timeout: float = 120.0) -> None:
        """Drain, then stop the background loop.  Idempotent."""
        self.drain(timeout=timeout)
        self._stop = True
        with self._work:
            self._work.notify_all()
        if self._thread is not None:
            self._thread.join(timeout=timeout)
            self._thread = None

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False

    # ------------------------------------------------------------------
    # introspection
    # ------------------------------------------------------------------

    def stats(self) -> dict:
        """Scheduler counters (always finite): admission, queue depth,
        dispatch, and per-lane pending breakdown."""
        with self._mutex:
            per_lane = collections.Counter()
            for group in self._groups.values():
                for _, _, ticket, _, _ in group.heap:
                    per_lane[ticket.lane] += 1
            return {
                "admitted": self.admitted,
                "rejected": self.rejected,
                "pending": self._pending,
                "inflight": len(self._inflight),
                "dispatched_batches": self.dispatched_batches,
                "completed": self.completed,
                "failed": self.failed,
                "deadline_misses": self.deadline_misses,
                "pending_by_lane": dict(per_lane),
                "outstanding_by_tenant": {
                    t: n for t, n in self._outstanding.items() if n
                },
            }
