"""Stencil serving engine: micro-batched, bucketed, async-dispatched
execution of cached compiled designs.

The production-facing front of the runtime subsystem.  A server owns a
:class:`repro.runtime.DesignCache`; clients register stencil designs (DSL
text or :class:`StencilSpec`) and then submit grids.  The serving flow is

  register(name, dsl)  ── autotune (ranking cached) ── compile batched
                          runner (jit cached) ── optional warmup dispatch
  submit(name, arrays) ── validated, queued (thread-safe)
  flush()              ── queued requests grouped by design (and, with
                          bucketing, by bucket shape), chunked into
                          micro-batches of ``max_batch`` grids, staged to
                          device, dispatched through a bounded in-flight
                          queue, unpadded

**Shape bucketing** (``bucketing=True`` or a
:class:`repro.runtime.ShapeBucketer`): a registered design is a *logical*
kernel that serves any grid shape its bucketer accepts, under **any**
boundary mode.  Each request is routed to a padded canonical bucket; one
streamed-boundary design per bucket is auto-tuned and compiled on first
use (all memoized in the shared cache), and grids of different sizes
sharing a bucket ride the same micro-batch, each carrying its own
streamed service inputs — the exterior mask, replicate halo-index maps,
or host-streamed periodic wrap margins (docs/DESIGN.md §Boundaries ×
bucketed serving).  Without bucketing, requests must match the
registered spec's exact shape (the pre-bucketing contract).

**Async double-buffered dispatch** (``async_dispatch=True``, the
default): each micro-batch is staged (host stack/pad + ``jax.device_put``)
and dispatched without blocking; the host then stages micro-batch N+1
while the device executes micro-batch N, and only blocks
(``jax.block_until_ready`` via the runner's ``finalize``) when the
bounded in-flight queue (``max_inflight``) is full or the flush drains.
``async_dispatch=False`` restores strictly synchronous dispatch for
debugging/benchmark baselines; results are identical either way.

**Batch-axis semantics** (shared with :mod:`repro.runtime.batching`): one
dispatch evaluates ``(B,) + bucket_shape`` arrays where the B grids are
fully independent — no halo exchange, reduction, or any other coupling
crosses the batch axis, and the spec's boundary rule applies per grid
(per *real* grid under bucketing, via the streamed inputs).  Requests for
different designs never share a batch.  Short final chunks are padded up
to the compiled batch size (so a design compiles exactly one batched
program) and the padding's outputs are discarded.

Per-design counters (``stats()``): requests served, batches dispatched,
design-cache hit/miss for the register call, compile/warmup seconds,
execution latency (count / total / mean / max seconds; under async
dispatch this is staging-to-completion latency and overlapping batches'
latencies overlap too), requests lost to dispatch faults (whose tickets
resolve via ``failures``), and — for bucketed designs — per-bucket
hit/miss/request counters.

The LM token-serving engine lives in :mod:`repro.serve.lm`; its classes
are re-exported here for backward compatibility.
"""
from __future__ import annotations

import collections
import dataclasses
import threading
import time
from typing import Mapping

import jax
import numpy as np

# backward-compatible re-exports (pre-runtime engine.py held the LM engine)
from repro.serve.lm import Request, ServeEngine  # noqa: F401
from repro.runtime.bucketing import ShapeBucketer
from repro.runtime.cache import (
    BucketedDesign,
    DesignCache,
    default_cache,
    structural_fingerprint,
)


@dataclasses.dataclass
class StencilRequest:
    """One grid to evaluate under a registered design."""

    design: str
    arrays: Mapping[str, np.ndarray]   # each shaped like one grid


@dataclasses.dataclass
class DesignCounters:
    cache_hit: bool = False            # register() served fully from cache
    build_time_s: float = 0.0          # ranking + jit trace time (0 on hit)
    warmup_time_s: float = 0.0
    requests: int = 0
    batches: int = 0
    padded_grids: int = 0              # throwaway grids added for batch pad
    failed_requests: int = 0           # requests lost to dispatch faults
    exec_count: int = 0
    exec_total_s: float = 0.0
    exec_max_s: float = 0.0

    @property
    def exec_mean_s(self) -> float:
        return self.exec_total_s / self.exec_count if self.exec_count else 0.0

    def as_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["exec_mean_s"] = self.exec_mean_s
        return d


@dataclasses.dataclass
class _Registered:
    name: str
    cached: object          # runtime CachedDesign, or BucketedDesign
    counters: DesignCounters
    iterations: int | None = None      # as passed at register time
    # static-analysis findings from registration-time verification
    # (repro.core.analysis.Diagnostic tuples; empty = clean)
    diagnostics: tuple = ()

    @property
    def bucketed(self) -> bool:
        return isinstance(self.cached, BucketedDesign)

    @property
    def spec(self):
        return self.cached.spec if self.bucketed else self.cached.design.spec

    @property
    def config(self):
        """The chosen config (exact mode) or per-bucket configs (bucketed)."""
        if not self.bucketed:
            return self.cached.design.config
        return {b: e.config for b, e in self.cached.buckets.items()}

    def bucket_for(self, shape):
        return self.cached.bucket_for(shape)


@dataclasses.dataclass
class _InFlight:
    """A dispatched, not-yet-materialised micro-batch."""

    reg: _Registered
    items: list                       # [(ticket, request, shape), ...]
    out: object                       # device array (possibly still computing)
    finalize: object                  # runner.finalize: device -> np, blocks
    post: object                      # np batch -> {ticket: np grid}
    pad: int
    t0: float


class StencilServer:
    """Micro-batching server over cached, batched stencil designs.

    ``max_batch`` bounds grids per dispatch.  ``warmup=True`` (default)
    pushes one zero batch through a freshly compiled design at register
    time so the first real request never pays the compile.  ``bucketing``
    (True / a :class:`ShapeBucketer`) turns registrations into
    multi-geometry logical kernels; ``max_buckets`` caps each bucketed
    registration's ladder with LRU eviction of the least-recently-hit
    bucket design; ``async_dispatch`` + ``max_inflight`` control the
    double-buffered dispatch loop; ``strict`` refuses (rather than warns
    about) designs degraded by a too-small device pool and refuses
    registrations carrying error-severity static-analysis findings
    (:mod:`repro.core.analysis`).  ``store_dir`` points the server at a
    persistent :class:`repro.runtime.DesignStore` (the FPGA-bitstream
    analogue on disk): rankings, compiled executables, and serving
    telemetry survive the process, so a restarted replica — or a fresh
    replica sharing the directory — cold-starts to its first
    bitwise-identical result without re-autotuning or re-jitting
    (docs/DESIGN.md §Persistent design store).
    """

    def __init__(
        self,
        max_batch: int = 8,
        platform=None,
        devices=None,
        cache: DesignCache | None = None,
        warmup: bool = True,
        backend: str = "auto",
        tile_rows: int = 64,
        bucketing: bool | ShapeBucketer | None = None,
        async_dispatch: bool = True,
        max_inflight: int = 2,
        strict: bool = False,
        max_buckets: int | None = None,
        store_dir=None,
    ):
        assert max_batch >= 1
        assert max_inflight >= 1
        self.max_batch = max_batch
        self.platform = platform
        self.devices = devices
        if store_dir is not None:
            # a persistent replica: own store-backed cache (rankings +
            # executables read/written through disk, telemetry restored).
            # A shared in-process cache and a store-backed one are
            # configured through cache= directly — passing both here
            # would be ambiguous about which memoization the server owns.
            if cache is not None:
                raise ValueError(
                    "pass either cache= (optionally DesignCache(store=...)) "
                    "or store_dir=, not both"
                )
            cache = DesignCache(store=store_dir)
        self.cache = cache if cache is not None else default_cache()
        self.warmup = warmup
        self.backend = backend
        self.tile_rows = tile_rows
        self.bucketing = bucketing
        self.async_dispatch = async_dispatch
        self.max_inflight = max_inflight
        self.strict = strict
        self.max_buckets = max_buckets
        self._designs: dict[str, _Registered] = {}
        self._queue: list[tuple[int, StencilRequest, tuple]] = []
        self._lock = threading.Lock()
        self.failures: dict[int, Exception] = {}   # ticket -> dispatch fault
        self.completed: dict[int, np.ndarray] = {}  # ticket -> result
        self._next_ticket = 0

    # ------------------------------------------------------------------
    # design registration
    # ------------------------------------------------------------------

    def _bucketer_for(self, bucketing) -> ShapeBucketer | None:
        b = self.bucketing if bucketing is None else bucketing
        if not b:
            return None
        return b if isinstance(b, ShapeBucketer) else ShapeBucketer()

    def register(
        self,
        name: str,
        source_or_spec,
        iterations: int | None = None,
        bucketing: bool | ShapeBucketer | None = None,
    ) -> _Registered:
        """Auto-tune + compile (both through the design cache) and warm up.

        With bucketing (per-call override of the server default), the
        registration is a logical kernel: only the bucket containing the
        spec's declared shape is compiled/warmed now, further buckets
        lazily on first request.  Re-registering a name with the same
        design and iterations is idempotent; re-registering it with a
        different one raises.

        Registration runs the static verifier
        (:func:`repro.core.analysis.verify`): findings are attached to
        the returned registration's ``diagnostics``, and under
        ``strict`` any error-severity finding refuses the registration
        with a :class:`repro.core.analysis.VerificationError` before
        anything compiles.
        """
        bucketer = self._bucketer_for(bucketing)
        if name in self._designs:
            existing = self._designs[name]
            from repro.runtime.cache import _as_spec, spec_fingerprint

            spec = _as_spec(source_or_spec)
            # bucketed designs are shape-agnostic: compare structure only
            fp = (structural_fingerprint(spec) if existing.bucketed
                  else spec_fingerprint(spec))
            have = (existing.cached.structural if existing.bucketed
                    else existing.cached.fingerprint)
            policy_changed = (
                existing.bucketed != bool(bucketer)
                or (existing.bucketed
                    and existing.cached.bucketer != bucketer)
            )
            if fp != have or iterations != existing.iterations \
                    or policy_changed:
                raise ValueError(
                    f"design {name!r} is already registered with a "
                    "different spec, iteration count, or bucketing "
                    "policy; pick a new name"
                )
            return existing

        from repro.core import analysis
        from repro.runtime.cache import _as_spec

        spec0 = _as_spec(source_or_spec)
        fn = analysis.verify_or_raise if self.strict else analysis.verify
        diags = tuple(fn(
            spec0, iterations=iterations, bucketed=bucketer is not None,
        ))
        # every registration carries its certified rounding-error bound
        from repro.core import numerics

        diags += (numerics.bound_diagnostic(spec0, iterations=iterations),)

        if bucketer is not None:
            bucketed = self.cache.bucketed(
                source_or_spec, bucketer=bucketer, platform=self.platform,
                iterations=iterations, devices=self.devices,
                tile_rows=self.tile_rows, backend=self.backend,
                strict=self.strict, max_buckets=self.max_buckets,
            )
            entry = bucketed.runner_for(bucketed.spec.shape, count=0)
            ctr = DesignCounters(
                cache_hit=entry.stats.cache_hit,
                build_time_s=entry.stats.build_time_s,
            )
            reg = _Registered(
                name=name, cached=bucketed, counters=ctr,
                iterations=iterations, diagnostics=diags,
            )
            if self.warmup:
                spec = bucketed.spec
                zeros = {
                    n: np.zeros((self.max_batch,) + tuple(shape), dtype=dt)
                    for n, (dt, shape) in spec.inputs.items()
                }
                t0 = time.perf_counter()
                entry.runner(zeros)
                ctr.warmup_time_s = time.perf_counter() - t0
            self._designs[name] = reg
            return reg

        cached = self.cache.get_or_build(
            source_or_spec, platform=self.platform, iterations=iterations,
            devices=self.devices, tile_rows=self.tile_rows,
            backend=self.backend, strict=self.strict,
        )
        ctr = DesignCounters(
            cache_hit=cached.hit,
            build_time_s=0.0 if cached.hit else cached.build_time_s,
        )
        reg = _Registered(
            name=name, cached=cached, counters=ctr, iterations=iterations,
            diagnostics=diags,
        )
        # Warm even on a design-cache hit: the compiled program is shaped
        # (max_batch, ...) and THIS server's bucket size may be new.  When
        # the shape is already jit-cached the warmup dispatch is ~free.
        if self.warmup:
            spec = reg.spec
            zeros = {
                n: np.zeros((self.max_batch,) + tuple(shape), dtype=dt)
                for n, (dt, shape) in spec.inputs.items()
            }
            t0 = time.perf_counter()
            cached.runner(zeros)
            ctr.warmup_time_s = time.perf_counter() - t0
        self._designs[name] = reg
        return reg

    def design(self, name: str) -> _Registered:
        return self._designs[name]

    # ------------------------------------------------------------------
    # request path
    # ------------------------------------------------------------------

    def submit(self, request: StencilRequest, claim=None) -> int:
        """Queue one grid; returns a ticket resolved by a later flush().

        Requests are validated here (input names + grid shapes against
        the registered spec, bucketability under bucketing), so a
        malformed request is rejected at submit time instead of poisoning
        a later batch.  Safe to call from multiple threads.

        ``claim`` makes ticket ownership explicit **at submit time**: a
        ticket submitted under a claim token is invisible to plain
        ``flush()`` calls and is only drained by ``flush(claim=token)``.
        This is what lets concurrent ``serve()`` callers share one
        server without one caller's flush stealing (and racing the
        resolution of) another caller's tickets.
        """
        shape = self._validate(request)
        with self._lock:
            ticket = self._next_ticket
            self._next_ticket += 1
            self._queue.append((ticket, request, shape, claim))
        return ticket

    def _validate(self, request: StencilRequest) -> tuple:
        """Validate one request against its registration; returns the
        request's grid shape.  Raises on unknown designs, unknown/missing
        inputs, shape mismatches, and unbucketable shapes — shared by
        :meth:`submit` and the continuous scheduler's admission path."""
        if request.design not in self._designs:
            raise KeyError(
                f"design {request.design!r} is not registered "
                f"(have {sorted(self._designs)})"
            )
        reg = self._designs[request.design]
        spec = reg.spec
        unknown = sorted(set(request.arrays) - set(spec.inputs))
        if unknown:
            raise ValueError(
                f"request for {request.design!r} has unknown input(s) "
                f"{unknown} (spec inputs: {sorted(spec.inputs)})"
            )
        shape = None
        for n, (_, declared) in spec.inputs.items():
            if n not in request.arrays:
                raise ValueError(
                    f"request for {request.design!r} is missing input {n!r}"
                )
            got = tuple(np.shape(request.arrays[n]))
            if reg.bucketed:
                if shape is None:
                    if len(got) != spec.ndim:
                        raise ValueError(
                            f"request for {request.design!r}: {n} must be a "
                            f"{spec.ndim}-D grid, got shape {got}"
                        )
                    shape = got
                elif got != shape:
                    raise ValueError(
                        f"request for {request.design!r}: inconsistent grid "
                        f"shapes ({n} is {got}, expected {shape})"
                    )
            elif got != tuple(declared):
                raise ValueError(
                    f"request for {request.design!r}: {n} must be shaped "
                    f"{tuple(declared)}, got {got}"
                )
            else:
                shape = got
        if reg.bucketed:
            try:
                reg.bucket_for(shape)     # raises if unservable
            except ValueError as e:
                raise ValueError(
                    f"request for {request.design!r} is not bucketable: {e}"
                ) from e
        return shape

    def flush(self, claim=None) -> dict[int, np.ndarray]:
        """Dispatch queued requests, micro-batched per design/bucket.

        ``flush()`` claims exactly the **unclaimed** tickets queued at
        call time; ``flush(claim=token)`` claims exactly the tickets
        submitted under ``token``.  Either way the claimed set is fixed
        under one lock acquisition and nothing outside it is touched —
        tickets another caller claimed at submit time can never be
        drained (or have their resolution raced) by this call.

        The dispatch loop is double-buffered: while the device executes
        one micro-batch, the host stages the next; completed batches are
        only materialised when the bounded in-flight queue is full or the
        queue drains.  A dispatch fault in one micro-batch never drops
        other requests: every chunk is attempted, successful results are
        returned (and retained in ``self.completed`` until claimed), and
        the failed chunk's tickets land in ``self.failures`` (ticket ->
        exception) instead of resolving.
        """
        with self._lock:
            queue = [e for e in self._queue if e[3] == claim]
            self._queue = [e for e in self._queue if e[3] != claim]
        groups: dict[tuple, list] = {}
        for ticket, req, shape, _ in queue:
            reg = self._designs[req.design]
            bucket = reg.bucket_for(shape) if reg.bucketed else None
            groups.setdefault((req.design, bucket), []).append(
                (ticket, req, shape)
            )
        results: dict[int, np.ndarray] = {}
        inflight: collections.deque[_InFlight] = collections.deque()
        for (name, bucket), items in groups.items():
            reg = self._designs[name]
            for lo in range(0, len(items), self.max_batch):
                chunk = items[lo:lo + self.max_batch]
                while len(inflight) >= self.max_inflight:
                    self._resolve(inflight.popleft(), results)
                t0 = time.perf_counter()
                try:
                    runner, stacked, post, pad = self._prepare(
                        reg, bucket, chunk
                    )
                    chain = (
                        callable(getattr(runner, "stage", None))
                        and callable(getattr(runner, "dispatch", None))
                        and callable(getattr(runner, "finalize", None))
                    )
                    if bucket is None and not chain:
                        # legacy / monkeypatched runner: plain callable
                        out = np.asarray(runner(stacked))
                        self._account(reg, chunk, pad,
                                      time.perf_counter() - t0)
                        results.update(post(out))
                    elif self.async_dispatch:
                        out = runner.dispatch(runner.stage(stacked))
                        inflight.append(_InFlight(
                            reg=reg, items=chunk, out=out,
                            finalize=runner.finalize, post=post, pad=pad,
                            t0=t0,
                        ))
                    else:
                        out = runner.finalize(
                            runner.dispatch(runner.stage(stacked))
                        )
                        self._account(reg, chunk, pad,
                                      time.perf_counter() - t0)
                        results.update(post(out))
                except Exception as e:
                    self._fail(reg, chunk, e)
        while inflight:
            self._resolve(inflight.popleft(), results)
        self.completed.update(results)
        self.persist_telemetry()
        return results

    def persist_telemetry(self) -> None:
        """Write serving counters through to the cache's persistent store
        (no-op without one), so a restarted replica resumes its per-key
        and per-bucket statistics instead of zeroing them."""
        if self.cache.store is None:
            return
        for reg in self._designs.values():
            if reg.bucketed:
                reg.cached.persist_stats()
        self.cache.flush_telemetry()

    def serve(self, requests: list[StencilRequest]) -> list[np.ndarray]:
        """submit() + flush(), preserving request order; claims only THIS
        call's tickets from ``self.completed``.

        Each call submits under its own claim token, so concurrent
        serve() calls (and concurrent plain flush() callers) on one
        server never drain each other's tickets.

        Raises if any of this call's requests failed to dispatch — other
        tickets' results (and this call's successful ones) stay claimable
        in ``self.completed``.
        """
        claim = object()
        tickets = [self.submit(r, claim=claim) for r in requests]
        self.flush(claim=claim)
        failed = [t for t in tickets if t in self.failures]
        if failed:
            raise RuntimeError(
                f"{len(failed)}/{len(tickets)} requests failed to dispatch"
            ) from self.failures[failed[0]]
        return [self.completed.pop(t) for t in tickets]

    # ------------------------------------------------------------------
    # dispatch internals
    # ------------------------------------------------------------------

    def _prepare(self, reg: _Registered, bucket, chunk):
        """Host-side staging: stack (and under bucketing pad + mask) one
        micro-batch; returns (runner, stacked arrays, post, pad count)."""
        spec = reg.spec
        n = len(chunk)
        pad = self.max_batch - n
        if bucket is None:
            # exact-shape mode: pad the batch by repeating the first grid
            # (one compiled program per design)
            runner = reg.cached.runner
            stacked = {
                name: np.stack(
                    [np.asarray(req.arrays[name]) for _, req, _ in chunk]
                    + [np.asarray(chunk[0][1].arrays[name])] * pad
                )
                for name in spec.inputs
            }

            def post(out):
                return {t: out[i] for i, (t, _, _) in enumerate(chunk)}

            return runner, stacked, post, pad

        entry = reg.cached.entry_for_bucket(bucket, count=n)
        runner = entry.runner
        plan = runner.plan
        stacked = {}
        for name in spec.inputs:
            grids = [
                plan.place_entry(np.asarray(req.arrays[name]))
                for _, req, _ in chunk
            ]
            grids += [plan.filler_entry(name)] * pad
            stacked[name] = np.stack(grids)
        # per-entry streamed service arrays (mask and/or halo-index maps):
        # grids of different shapes share the batch, each re-imposing its
        # own real boundary in-kernel; batch-padding entries carry the
        # plan's throwaway filler (their outputs are discarded by post())
        service = [plan.service_entry(shape) for _, _, shape in chunk]
        filler = plan.service_filler()
        for sname in plan.service_names:
            stacked[sname] = np.stack(
                [e[sname] for e in service] + [filler[sname]] * pad
            )

        def post(out):
            return {
                t: out[i][plan.out_index(shape)]
                for i, (t, _, shape) in enumerate(chunk)
            }

        return runner, stacked, post, pad

    def _resolve(self, infl: _InFlight, results: dict) -> None:
        """Block on one in-flight micro-batch and resolve its tickets."""
        try:
            jax.block_until_ready(infl.out)
            out = infl.finalize(infl.out)
            self._account(infl.reg, infl.items, infl.pad,
                          time.perf_counter() - infl.t0)
            results.update(infl.post(out))
        except Exception as e:
            self._fail(infl.reg, infl.items, e)

    def _account(self, reg: _Registered, chunk, pad: int, dt: float) -> None:
        ctr = reg.counters
        ctr.requests += len(chunk)
        ctr.batches += 1
        ctr.padded_grids += pad
        ctr.exec_count += 1
        ctr.exec_total_s += dt
        ctr.exec_max_s = max(ctr.exec_max_s, dt)

    def _fail(self, reg: _Registered, chunk, exc: Exception) -> None:
        reg.counters.failed_requests += len(chunk)
        for ticket, _, _ in chunk:
            self.failures[ticket] = exc

    # ------------------------------------------------------------------
    # introspection
    # ------------------------------------------------------------------

    def stats(self) -> dict[str, dict]:
        """Per-design counters plus the shared cache's global hit/miss."""
        out = {}
        for n, r in self._designs.items():
            d = r.counters.as_dict()
            if r.bucketed:
                d["buckets"] = {
                    "x".join(map(str, b)): s
                    for b, s in r.cached.stats().items()
                }
                d["compiled_buckets"] = r.cached.num_buckets
            out[n] = d
        out["_cache"] = {
            "hits": self.cache.hits,
            "misses": self.cache.misses,
            "entries": len(self.cache),
            "runner_evictions": self.cache.runner_evictions,
            "autotune_calls": self.cache.autotune_calls,
            "jit_builds": self.cache.jit_builds,
            "store_hits": self.cache.store_hits,
        }
        if self.cache.store is not None:
            out["_store"] = self.cache.store.stats.as_dict()
        return out
