"""Stencil serving engine: micro-batched dispatch of cached compiled designs.

The production-facing front of the runtime subsystem.  A server owns a
:class:`repro.runtime.DesignCache`; clients register stencil designs (DSL
text or :class:`StencilSpec`) and then submit grids.  The serving flow is

  register(name, dsl)  ── autotune (ranking cached) ── compile batched
                          runner (jit cached) ── optional warmup dispatch
  submit(name, arrays) ── queued
  flush()              ── queued requests grouped by design, chunked into
                          micro-batches of ``max_batch`` grids, padded to
                          a fixed bucket size, dispatched, unpadded

**Batch-axis semantics** (shared with :mod:`repro.runtime.batching`): one
dispatch evaluates ``(B,) + spec.shape`` arrays where the B grids are
fully independent — no halo exchange, reduction, or any other coupling
crosses the batch axis, and the exterior-zero boundary applies per grid.
All grids in one dispatch share the design's spec (shape, dtype,
iterations); requests for different designs never share a batch.  Short
final chunks are padded by repeating the first grid of the chunk up to
the compiled bucket size (so a design compiles exactly one batched
program) and the padding's outputs are discarded.

Per-design counters (``stats()``): requests served, batches dispatched,
design-cache hit/miss for the register call, compile/warmup seconds,
execution latency (count / total / mean / max seconds), and requests
lost to dispatch faults (whose tickets resolve via ``failures``).

The LM token-serving engine lives in :mod:`repro.serve.lm`; its classes
are re-exported here for backward compatibility.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Mapping

import numpy as np

# backward-compatible re-exports (pre-runtime engine.py held the LM engine)
from repro.serve.lm import Request, ServeEngine  # noqa: F401
from repro.runtime.cache import DesignCache, default_cache


@dataclasses.dataclass
class StencilRequest:
    """One grid to evaluate under a registered design."""

    design: str
    arrays: Mapping[str, np.ndarray]   # each shaped spec.shape


@dataclasses.dataclass
class DesignCounters:
    cache_hit: bool = False            # register() served fully from cache
    build_time_s: float = 0.0          # ranking + jit trace time (0 on hit)
    warmup_time_s: float = 0.0
    requests: int = 0
    batches: int = 0
    padded_grids: int = 0              # throwaway grids added for bucketing
    failed_requests: int = 0           # requests lost to dispatch faults
    exec_count: int = 0
    exec_total_s: float = 0.0
    exec_max_s: float = 0.0

    @property
    def exec_mean_s(self) -> float:
        return self.exec_total_s / self.exec_count if self.exec_count else 0.0

    def as_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["exec_mean_s"] = self.exec_mean_s
        return d


@dataclasses.dataclass
class _Registered:
    name: str
    cached: object                     # runtime.cache.CachedDesign
    counters: DesignCounters
    iterations: int | None = None      # as passed at register time

    @property
    def spec(self):
        return self.cached.design.spec

    @property
    def config(self):
        return self.cached.design.config


class StencilServer:
    """Micro-batching server over cached, batched stencil designs.

    ``max_batch`` bounds grids per dispatch.  ``warmup=True`` (default)
    pushes one zero batch through a freshly compiled design at register
    time so the first real request never pays the compile.
    """

    def __init__(
        self,
        max_batch: int = 8,
        platform=None,
        devices=None,
        cache: DesignCache | None = None,
        warmup: bool = True,
        backend: str = "auto",
        tile_rows: int = 64,
    ):
        assert max_batch >= 1
        self.max_batch = max_batch
        self.platform = platform
        self.devices = devices
        self.cache = cache if cache is not None else default_cache()
        self.warmup = warmup
        self.backend = backend
        self.tile_rows = tile_rows
        self._designs: dict[str, _Registered] = {}
        self._queue: list[tuple[int, StencilRequest]] = []
        self.failures: dict[int, Exception] = {}   # ticket -> dispatch fault
        self.completed: dict[int, np.ndarray] = {}  # ticket -> result
        self._next_ticket = 0

    # ------------------------------------------------------------------
    # design registration
    # ------------------------------------------------------------------

    def register(
        self, name: str, source_or_spec, iterations: int | None = None
    ) -> _Registered:
        """Auto-tune + compile (both through the design cache) and warm up.

        Re-registering a name with the same spec and iterations is
        idempotent; re-registering it with a different one raises.
        """
        if name in self._designs:
            existing = self._designs[name]
            from repro.runtime.cache import _as_spec, spec_fingerprint

            fp = spec_fingerprint(_as_spec(source_or_spec))
            if (fp != existing.cached.fingerprint
                    or iterations != existing.iterations):
                raise ValueError(
                    f"design {name!r} is already registered with a "
                    "different spec or iteration count; pick a new name"
                )
            return existing
        cached = self.cache.get_or_build(
            source_or_spec, platform=self.platform, iterations=iterations,
            devices=self.devices, tile_rows=self.tile_rows,
            backend=self.backend,
        )
        ctr = DesignCounters(
            cache_hit=cached.hit,
            build_time_s=0.0 if cached.hit else cached.build_time_s,
        )
        reg = _Registered(
            name=name, cached=cached, counters=ctr, iterations=iterations
        )
        # Warm even on a design-cache hit: the compiled program is shaped
        # (max_batch, ...) and THIS server's bucket size may be new.  When
        # the shape is already jit-cached the warmup dispatch is ~free.
        if self.warmup:
            spec = reg.spec
            zeros = {
                n: np.zeros((self.max_batch,) + tuple(shape), dtype=dt)
                for n, (dt, shape) in spec.inputs.items()
            }
            t0 = time.perf_counter()
            cached.runner(zeros)
            ctr.warmup_time_s = time.perf_counter() - t0
        self._designs[name] = reg
        return reg

    def design(self, name: str) -> _Registered:
        return self._designs[name]

    # ------------------------------------------------------------------
    # request path
    # ------------------------------------------------------------------

    def submit(self, request: StencilRequest) -> int:
        """Queue one grid; returns a ticket resolved by the next flush().

        Requests are validated here (input names + grid shapes against
        the registered spec), so a malformed request is rejected at
        submit time instead of poisoning a later batch.
        """
        if request.design not in self._designs:
            raise KeyError(
                f"design {request.design!r} is not registered "
                f"(have {sorted(self._designs)})"
            )
        spec = self._designs[request.design].spec
        for n, (_, shape) in spec.inputs.items():
            if n not in request.arrays:
                raise ValueError(
                    f"request for {request.design!r} is missing input {n!r}"
                )
            got = tuple(np.shape(request.arrays[n]))
            if got != tuple(shape):
                raise ValueError(
                    f"request for {request.design!r}: {n} must be shaped "
                    f"{tuple(shape)}, got {got}"
                )
        ticket = self._next_ticket
        self._next_ticket += 1
        self._queue.append((ticket, request))
        return ticket

    def flush(self) -> dict[int, np.ndarray]:
        """Dispatch every queued request in design-grouped micro-batches.

        A dispatch fault in one micro-batch never drops other requests:
        every chunk is attempted, successful results are returned (and
        retained in ``self.completed`` until claimed), and the failed
        chunk's tickets land in ``self.failures`` (ticket -> exception)
        instead of resolving.
        """
        by_design: dict[str, list[tuple[int, StencilRequest]]] = {}
        for ticket, req in self._queue:
            by_design.setdefault(req.design, []).append((ticket, req))
        self._queue.clear()
        results: dict[int, np.ndarray] = {}
        for name, items in by_design.items():
            reg = self._designs[name]
            for lo in range(0, len(items), self.max_batch):
                chunk = items[lo:lo + self.max_batch]
                try:
                    results.update(self._dispatch(reg, chunk))
                except Exception as e:
                    reg.counters.failed_requests += len(chunk)
                    for ticket, _ in chunk:
                        self.failures[ticket] = e
        self.completed.update(results)
        return results

    def serve(self, requests: list[StencilRequest]) -> list[np.ndarray]:
        """submit() + flush(), preserving request order; claims only THIS
        call's tickets from ``self.completed``.

        Raises if any of this call's requests failed to dispatch — other
        tickets' results (and this call's successful ones) stay claimable
        in ``self.completed``.
        """
        tickets = [self.submit(r) for r in requests]
        self.flush()
        failed = [t for t in tickets if t in self.failures]
        if failed:
            raise RuntimeError(
                f"{len(failed)}/{len(tickets)} requests failed to dispatch"
            ) from self.failures[failed[0]]
        return [self.completed.pop(t) for t in tickets]

    def _dispatch(self, reg: _Registered, chunk) -> dict[int, np.ndarray]:
        spec = reg.spec
        n = len(chunk)
        # pad to the full compiled bucket: one batched program per design
        pad = self.max_batch - n
        stacked = {
            name: np.stack(
                [np.asarray(req.arrays[name]) for _, req in chunk]
                + [np.asarray(chunk[0][1].arrays[name])] * pad
            )
            for name in spec.inputs
        }
        t0 = time.perf_counter()
        out = reg.cached.runner(stacked)
        dt = time.perf_counter() - t0
        ctr = reg.counters
        ctr.requests += n
        ctr.batches += 1
        ctr.padded_grids += pad
        ctr.exec_count += 1
        ctr.exec_total_s += dt
        ctr.exec_max_s = max(ctr.exec_max_s, dt)
        return {ticket: out[i] for i, (ticket, _) in enumerate(chunk)}

    # ------------------------------------------------------------------
    # introspection
    # ------------------------------------------------------------------

    def stats(self) -> dict[str, dict]:
        """Per-design counters plus the shared cache's global hit/miss."""
        out = {n: r.counters.as_dict() for n, r in self._designs.items()}
        out["_cache"] = {
            "hits": self.cache.hits,
            "misses": self.cache.misses,
            "entries": len(self.cache),
        }
        return out
