from repro.serve.engine import (
    Request,
    ServeEngine,
    StencilRequest,
    StencilServer,
)

__all__ = ["Request", "ServeEngine", "StencilRequest", "StencilServer"]
