from repro.serve.engine import (
    Request,
    ServeEngine,
    StencilRequest,
    StencilServer,
)
from repro.serve.scheduler import (
    Backpressure,
    StencilScheduler,
    Ticket,
)
from repro.serve.router import (
    StencilRouter,
)

__all__ = [
    "Backpressure",
    "Request",
    "ServeEngine",
    "StencilRequest",
    "StencilRouter",
    "StencilScheduler",
    "StencilServer",
    "Ticket",
]
