"""``python -m repro.serve`` — the replicated serving tier's entrypoint.

Two modes:

  * ``--worker`` (what :class:`repro.serve.StencilRouter` spawns): run
    one replica — a :class:`StencilServer` over the shared persistent
    store plus a continuous-batching :class:`StencilScheduler` — and
    speak the router's length-prefixed pickle protocol on stdin/stdout.
    File descriptor 1 is re-pointed at stderr before jax ever runs, so
    stray prints can never corrupt the protocol stream.

  * default: a self-contained demo — spawn a small router fleet over a
    store directory, register a Jacobi kernel, push a mixed trace
    through it, and print per-replica stats.  Mostly documentation you
    can run.
"""
from __future__ import annotations

import argparse
import os
import sys
import threading


def _worker(args) -> int:
    # Claim fd 1 for the protocol BEFORE importing jax: anything that
    # prints to stdout afterwards lands on stderr instead of the wire.
    proto_out = os.fdopen(os.dup(1), "wb")
    os.dup2(2, 1)
    sys.stdout = sys.stderr

    from repro.serve.engine import StencilRequest, StencilServer
    from repro.serve.router import read_frame, write_frame
    from repro.serve.scheduler import StencilScheduler

    server = StencilServer(
        max_batch=args.max_batch,
        max_inflight=args.max_inflight,
        bucketing=args.bucketing,
        warmup=args.warmup,
        store_dir=args.store,
    )
    scheduler = StencilScheduler(server)
    out_lock = threading.Lock()
    stdin = sys.stdin.buffer

    def reply(msg_id, ok, result=None, error=None):
        write_frame(
            proto_out,
            {"id": msg_id, "ok": ok, "result": result, "error": error},
            out_lock,
        )

    def handle_submit(msg):
        try:
            ticket = scheduler.submit(
                StencilRequest(msg["design"], msg["arrays"]),
                lane=msg.get("lane"),
                tenant=msg.get("tenant") or "default",
            )
        except Exception as e:
            reply(msg["id"], False, error=e)
            return

        def wait():
            try:
                reply(msg["id"], True, result=ticket.result(timeout=600.0))
            except Exception as e:
                reply(msg["id"], False, error=e)

        # replies are per-ticket and out-of-order by design: the router
        # matches them by id, so a slow batch never blocks a fast one
        threading.Thread(target=wait, daemon=True).start()

    while True:
        msg = read_frame(stdin)
        if msg is None:                   # router hung up
            break
        op = msg.get("op")
        try:
            if op == "submit":
                handle_submit(msg)
            elif op == "register":
                reg = server.register(
                    msg["name"], msg["spec"], iterations=msg["iterations"],
                )
                reply(msg["id"], True, result={
                    "cache_hit": reg.counters.cache_hit,
                    "bucketed": reg.bucketed,
                })
            elif op == "ping":
                reply(msg["id"], True, result={
                    "pid": os.getpid(),
                    "scheduler": scheduler.stats(),
                })
            elif op == "drain":
                scheduler.drain()
                reply(msg["id"], True)
            elif op == "exit":
                scheduler.close()
                reply(msg["id"], True)
                break
            else:
                reply(msg["id"], False, error=ValueError(f"bad op {op!r}"))
        except Exception as e:
            reply(msg["id"], False, error=e)
    scheduler.close()
    return 0


def _demo(args) -> int:
    import tempfile

    import numpy as np

    from repro.configs import stencils
    from repro.serve.engine import StencilRequest
    from repro.serve.router import StencilRouter

    rng = np.random.default_rng(0)
    spec = stencils.jacobi2d(shape=(32, 16), iterations=2)
    store = args.store or tempfile.mkdtemp(prefix="sasa-store-")
    print(f"router: {args.replicas} replicas over store {store}")
    with StencilRouter(
        store, replicas=args.replicas, max_batch=args.max_batch,
    ) as router:
        router.register("jacobi", spec)
        reqs = [
            StencilRequest("jacobi", {
                n: rng.standard_normal(shape).astype(dt)
                for n, (dt, shape) in spec.inputs.items()
            })
            for _ in range(8)
        ]
        outs = router.serve(reqs)
        print(f"served {len(outs)} grids, first checksum "
              f"{float(np.sum(outs[0])):.6f}")
        for name, info in router.ping().items():
            sched = info.get("scheduler", {})
            print(f"  {name}: healthy={info.get('healthy')} "
                  f"completed={sched.get('completed')}")
    return 0


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.serve",
        description="replicated stencil-serving tier "
                    "(worker protocol or demo fleet)",
    )
    parser.add_argument("--worker", action="store_true",
                        help="run one router-spawned replica on stdio")
    parser.add_argument("--store", default=None,
                        help="shared DesignStore directory")
    parser.add_argument("--max-batch", type=int, default=4)
    parser.add_argument("--max-inflight", type=int, default=2)
    parser.add_argument("--bucketing", action="store_true")
    parser.add_argument("--warmup", action="store_true")
    parser.add_argument("--replicas", type=int, default=2,
                        help="demo mode: fleet size")
    args = parser.parse_args(argv)
    if args.worker:
        if not args.store:
            parser.error("--worker requires --store")
        return _worker(args)
    return _demo(args)


if __name__ == "__main__":
    sys.exit(main())
