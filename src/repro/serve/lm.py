"""LM serving engine: batched prefill + decode with KV caches.

Continuous-batching-lite: a fixed decode batch; finished sequences are
replaced by queued requests at step granularity (slot recycling).  Decode
and prefill are separately jitted — the production pattern where prefill
and decode run as distinct programs with different shardings.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class Request:
    prompt: np.ndarray            # (S,) int32
    max_new_tokens: int = 16
    eos: int = -1                 # -1: never stop early


class ServeEngine:
    def __init__(self, model, params, batch_size: int, cache_len: int):
        self.model = model
        self.params = params
        self.B = batch_size
        self.cache_len = cache_len
        self._decode = jax.jit(model.decode_step)
        self._prefill = jax.jit(
            lambda p, b: model.prefill(p, b))

    def _grow_caches(self, caches, S):
        cap = self.model.init_cache(self.B, self.cache_len,
                                    dtype=self.model.cfg.act_dtype)

        def merge(c, g):
            if c.shape == g.shape:
                return g
            pad = [(0, cs - gs) for cs, gs in zip(c.shape, g.shape)]
            cv = -1 if g.dtype == jnp.int32 else 0
            return jnp.pad(g, pad, constant_values=cv)

        return jax.tree.map(merge, cap, caches)

    def generate(self, requests: list[Request]) -> list[np.ndarray]:
        """Greedy decode a batch of same-length-padded prompts."""
        assert len(requests) <= self.B
        reqs = list(requests) + [requests[-1]] * (self.B - len(requests))
        S = max(len(r.prompt) for r in reqs)
        prompts = np.stack([
            np.pad(r.prompt, (S - len(r.prompt), 0)) for r in reqs])
        batch = {"tokens": jnp.asarray(prompts, jnp.int32)}
        logits, caches = self._prefill(self.params, batch)
        caches = self._grow_caches(caches, S)
        max_new = max(r.max_new_tokens for r in reqs)
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        outs = [tok]
        for t in range(max_new - 1):
            pos = jnp.full((self.B,), S + t, jnp.int32)
            logits, caches = self._decode(self.params, tok, caches, pos)
            tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
            outs.append(tok)
        gen = np.asarray(jnp.concatenate(outs, axis=1))
        results = []
        for i, r in enumerate(requests):
            g = gen[i, :r.max_new_tokens]
            if r.eos >= 0 and (g == r.eos).any():
                g = g[:int(np.argmax(g == r.eos)) + 1]
            results.append(g)
        return results
