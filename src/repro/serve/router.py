"""Multi-replica serving tier: N scheduler processes, one design store.

One :class:`repro.serve.StencilServer` process scales until a single
host's dispatch loop saturates.  The SASA analogy scales further by
*replication*: the expensive artefact (the tuned, compiled design) lives
in one persistent :class:`repro.runtime.DesignStore` directory, so extra
replicas are cheap — each cold-starts warm from disk (PR 8's half of the
story) and this module adds the serving half:

  * **workers** — ``python -m repro.serve --worker`` runs one replica: a
    ``StencilServer`` + continuous-batching ``StencilScheduler`` pair
    speaking a length-prefixed pickle protocol over stdin/stdout (no
    ports, no extra dependencies; stdout is re-pointed at stderr inside
    the worker so only protocol frames travel the pipe).
  * **routing** — :class:`StencilRouter` spawns N workers sharing one
    store directory and routes each request by **rendezvous (HRW)
    hashing of its design's structural fingerprint**: every replica
    serving a design keeps serving it (compiled buckets stay hot and the
    batcher sees coherent traffic), and when the replica set changes
    only that replica's designs move.
  * **health & handoff** — a dead worker (crash, EOF, kill) is detected
    by its reader thread; its in-flight submissions are **re-routed to
    surviving replicas** (requests are retained router-side until their
    reply arrives, so handoff needs no worker cooperation), and
    subsequent routing simply skips the dead replica.  ``ping()``
    health-checks the fleet; ``close()`` drains every replica before
    exit so no admitted ticket is ever dropped.

Results are bitwise-identical to a single in-process server: a replica
runs the same scheduler over the same staging path, and the store only
shares *designs*, never numerics.
"""
from __future__ import annotations

import hashlib
import os
import pickle
import struct
import subprocess
import sys
import threading
from pathlib import Path

import numpy as np

from repro.runtime.cache import _as_spec, structural_fingerprint
from repro.serve.engine import StencilRequest

_LEN = struct.Struct(">I")


def write_frame(stream, obj, lock=None) -> None:
    """One protocol frame: 4-byte big-endian length + pickle body."""
    body = pickle.dumps(obj, protocol=pickle.HIGHEST_PROTOCOL)
    data = _LEN.pack(len(body)) + body
    if lock is None:
        stream.write(data)
        stream.flush()
    else:
        with lock:
            stream.write(data)
            stream.flush()


def read_frame(stream):
    """The next frame, or ``None`` on EOF / truncation (peer is gone)."""
    header = stream.read(_LEN.size)
    if len(header) < _LEN.size:
        return None
    (n,) = _LEN.unpack(header)
    body = stream.read(n)
    if len(body) < n:
        return None
    return pickle.loads(body)


class ReplicaDied(ConnectionError):
    """A worker exited with requests outstanding and no survivor could
    take them over."""


class _Future:
    """Router-side pending reply (submit result or control-op ack)."""

    def __init__(self, payload: dict):
        self.payload = payload            # kept for re-route on death
        self._event = threading.Event()
        self._result = None
        self._error: Exception | None = None

    def resolve(self, msg: dict) -> None:
        if msg.get("ok"):
            self._result = msg.get("result")
        else:
            err = msg.get("error")
            self._error = err if isinstance(err, Exception) else \
                RuntimeError(str(err))
        self._event.set()

    def fail(self, exc: Exception) -> None:
        self._error = exc
        self._event.set()

    def done(self) -> bool:
        return self._event.is_set()

    def result(self, timeout: float | None = None):
        if not self._event.wait(timeout):
            raise TimeoutError(
                f"no reply for {self.payload.get('op')} within {timeout}s"
            )
        if self._error is not None:
            raise self._error
        return self._result


class _Replica:
    """One spawned worker process + its reader thread."""

    def __init__(self, name: str, proc: subprocess.Popen):
        self.name = name
        self.proc = proc
        self.healthy = True
        self.write_lock = threading.Lock()
        self.reader: threading.Thread | None = None

    def send(self, payload: dict) -> None:
        write_frame(self.proc.stdin, payload, self.write_lock)


class StencilRouter:
    """Route requests across N worker replicas sharing one design store.

    ``store_dir`` is the shared persistent store (created on first use);
    ``replicas`` is the worker count; ``max_batch`` / ``bucketing`` /
    ``max_inflight`` configure each worker's server.  Workers inherit
    this process's environment plus a ``PYTHONPATH`` that makes
    ``repro`` importable, so the router works from a source checkout
    without installation.
    """

    def __init__(
        self,
        store_dir,
        replicas: int = 2,
        max_batch: int = 4,
        bucketing: bool = False,
        max_inflight: int = 2,
        warmup: bool = False,
        spawn_timeout_s: float = 120.0,
    ):
        if replicas < 1:
            raise ValueError(f"need >= 1 replica, got {replicas}")
        self.store_dir = str(store_dir)
        self.max_batch = max_batch
        self._lock = threading.Lock()
        self._pending: dict[int, tuple[_Future, _Replica]] = {}
        self._next_id = 0
        self._specs: dict[str, object] = {}      # name -> registered spec
        self._registrations: list[dict] = []     # replayed on re-route
        self._closed = False
        self._replicas: list[_Replica] = []

        import repro

        # repro may be a namespace package (no __init__.py): resolve its
        # source root from __path__, not __file__
        pkg_dir = Path(next(iter(repro.__path__))).resolve()
        src_dir = str(pkg_dir.parent)
        env = dict(os.environ)
        env["PYTHONPATH"] = src_dir + (
            os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else ""
        )
        argv = [
            sys.executable, "-m", "repro.serve", "--worker",
            "--store", self.store_dir,
            "--max-batch", str(max_batch),
            "--max-inflight", str(max_inflight),
        ]
        if bucketing:
            argv.append("--bucketing")
        if warmup:
            argv.append("--warmup")
        for i in range(replicas):
            proc = subprocess.Popen(
                argv, stdin=subprocess.PIPE, stdout=subprocess.PIPE,
                env=env,
            )
            replica = _Replica(f"replica-{i}", proc)
            replica.reader = threading.Thread(
                target=self._read_loop, args=(replica,),
                name=f"router-read-{i}", daemon=True,
            )
            replica.reader.start()
            self._replicas.append(replica)
        # health-check now: a worker that can't even import dies here,
        # at construction, not at the first request
        for replica in self._replicas:
            self._control(replica, {"op": "ping"}).result(spawn_timeout_s)

    # ------------------------------------------------------------------
    # wire plumbing
    # ------------------------------------------------------------------

    def _enqueue(self, replica: _Replica, payload: dict) -> _Future:
        future = _Future(payload)
        with self._lock:
            payload["id"] = self._next_id
            self._next_id += 1
            self._pending[payload["id"]] = (future, replica)
        try:
            replica.send(payload)
        except (OSError, ValueError) as e:       # broken pipe: dead worker
            self._on_death(replica, e)
        return future

    def _control(self, replica: _Replica, payload: dict) -> _Future:
        return self._enqueue(replica, dict(payload))

    def _read_loop(self, replica: _Replica) -> None:
        while True:
            try:
                msg = read_frame(replica.proc.stdout)
            except Exception:
                msg = None
            if msg is None:
                break
            with self._lock:
                entry = self._pending.pop(msg.get("id"), None)
            if entry is not None:
                entry[0].resolve(msg)
        self._on_death(replica, None)

    def _on_death(self, replica: _Replica, cause) -> None:
        """Mark a replica dead and hand its outstanding requests to the
        survivors (re-routed whole: the router retains every payload
        until its reply arrives, so handoff needs nothing back from the
        dead worker)."""
        if not replica.healthy:
            return
        replica.healthy = False
        with self._lock:
            orphans = [
                (rid, fut) for rid, (fut, rep) in self._pending.items()
                if rep is replica
            ]
            for rid, _ in orphans:
                del self._pending[rid]
        if self._closed:
            for _, fut in orphans:
                fut.fail(ReplicaDied(
                    f"{replica.name} exited during shutdown"
                ))
            return
        for _, fut in orphans:
            survivor = self._pick(self._healthy())
            if survivor is None:
                fut.fail(ReplicaDied(
                    f"{replica.name} died ({cause!r}) with no surviving "
                    "replica to take over"
                ))
                continue
            payload = dict(fut.payload)
            payload.pop("id", None)
            if payload.get("op") == "submit":
                # the survivor may never have seen this design: replay
                # registrations first (idempotent server-side)
                self._ensure_registered(survivor)
            with self._lock:
                payload["id"] = self._next_id
                self._next_id += 1
                self._pending[payload["id"]] = (fut, survivor)
            fut.payload = payload
            try:
                survivor.send(payload)
            except (OSError, ValueError) as e:
                self._on_death(survivor, e)

    def _healthy(self) -> list[_Replica]:
        return [r for r in self._replicas if r.healthy]

    @staticmethod
    def _pick(candidates: list[_Replica], token: str = ""):
        """Rendezvous (highest-random-weight) hash: each token owns a
        stable replica while the set is unchanged, and a membership
        change only moves the dead replica's tokens."""
        best, best_score = None, None
        for replica in candidates:
            score = hashlib.sha256(
                f"{token}|{replica.name}".encode()
            ).digest()
            if best_score is None or score > best_score:
                best, best_score = replica, score
        return best

    def _route(self, design: str) -> _Replica:
        spec = self._specs.get(design)
        token = structural_fingerprint(spec) if spec is not None else design
        replica = self._pick(self._healthy(), token)
        if replica is None:
            raise ReplicaDied("no healthy replicas")
        return replica

    def _ensure_registered(self, replica: _Replica) -> None:
        for msg in list(self._registrations):
            if replica.name not in msg["_sent_to"]:
                self._control(replica, {
                    k: v for k, v in msg.items() if k != "_sent_to"
                }).result(120.0)
                msg["_sent_to"].add(replica.name)

    # ------------------------------------------------------------------
    # serving surface
    # ------------------------------------------------------------------

    def register(self, name: str, source_or_spec, iterations=None) -> None:
        """Register a design on every replica.

        The first replica registers alone — it autotunes/compiles and
        writes the shared store — then the rest register concurrently,
        each warm-starting from the persisted design instead of
        re-autotuning (the PR 8 cold-start path, now load-bearing)."""
        spec = _as_spec(source_or_spec)
        payload = {
            "op": "register", "name": name, "spec": spec,
            "iterations": iterations,
            "_sent_to": set(),
        }
        healthy = self._healthy()
        if not healthy:
            raise ReplicaDied("no healthy replicas")
        wire = {k: v for k, v in payload.items() if k != "_sent_to"}
        self._control(healthy[0], wire).result(300.0)
        payload["_sent_to"].add(healthy[0].name)
        futures = [
            (replica, self._control(replica, wire))
            for replica in healthy[1:]
        ]
        for replica, future in futures:
            future.result(300.0)
            payload["_sent_to"].add(replica.name)
        self._specs[name] = spec
        self._registrations.append(payload)

    def submit(
        self, request: StencilRequest, lane: str | None = None,
        tenant: str = "default",
    ) -> _Future:
        """Route one request to its design's replica; returns a future
        whose ``result()`` is the grid (or raises the replica's fault,
        :class:`repro.serve.Backpressure` included)."""
        replica = self._route(request.design)
        return self._enqueue(replica, {
            "op": "submit", "design": request.design,
            "arrays": {n: np.asarray(a) for n, a in request.arrays.items()},
            "lane": lane, "tenant": tenant,
        })

    def serve(self, requests: list[StencilRequest], timeout: float = 300.0):
        """Submit a batch and gather results in request order."""
        futures = [self.submit(r) for r in requests]
        return [f.result(timeout) for f in futures]

    def ping(self) -> dict:
        """Health-check every live replica; returns per-replica scheduler
        stats (dead replicas are reported, not raised)."""
        out = {}
        for replica in self._replicas:
            if not replica.healthy:
                out[replica.name] = {"healthy": False}
                continue
            try:
                stats = self._control(replica, {"op": "ping"}).result(60.0)
                out[replica.name] = {"healthy": True, **(stats or {})}
            except Exception as e:
                out[replica.name] = {"healthy": False, "error": repr(e)}
        return out

    def drain(self) -> None:
        """Resolve every outstanding ticket on every replica."""
        futures = [
            self._control(r, {"op": "drain"}) for r in self._healthy()
        ]
        for f in futures:
            f.result(300.0)

    def close(self) -> None:
        """Drain, stop, and reap every worker.  Idempotent."""
        if self._closed:
            return
        try:
            self.drain()
        except Exception:
            pass
        self._closed = True
        for replica in self._healthy():
            try:
                self._control(replica, {"op": "exit"}).result(60.0)
            except Exception:
                pass
        for replica in self._replicas:
            try:
                replica.proc.stdin.close()
            except Exception:
                pass
            try:
                replica.proc.wait(timeout=30)
            except subprocess.TimeoutExpired:
                replica.proc.kill()
                replica.proc.wait(timeout=30)
            replica.healthy = False

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False
