"""Generic Pallas TPU stencil kernel with temporal fusion (SASA single-PE,
TPU-native re-design).

FPGA -> TPU hardware adaptation (docs/DESIGN.md §FPGA-to-TPU mapping has
the full narrative):

  * SODA's 512-bit coalesced reuse FIFO becomes a VMEM-resident row tile:
    one (tile_rows + 2*s*r, C_pad) block is DMA'd HBM->VMEM per grid step,
    all reuse happens in VMEM registers/slices instead of FIFO taps.
  * The cascade of ``s`` temporal PEs becomes ``s`` fused iterations over
    the VMEM tile (temporal blocking): HBM traffic drops by ~s at the cost
    of a 2*s*r-row compute trapezoid per tile — the same redundant-compute
    vs. reuse trade the paper's hybrid designs make, moved down one level
    of the memory hierarchy.
  * Fine-grained parallelism U (16 PUs on a 512b AXI word) becomes the
    8x128 VPU lanes; we keep the full (padded) column dimension in the
    block so the lane dimension is dense and 128-aligned.

The kernel is generated from the same :class:`StencilSpec` the reference
executor consumes, and computes with the shared trapezoid helper in
:mod:`repro.kernels.blockops`, so kernel and oracle cannot drift.

Boundary conditions (docs/DESIGN.md §Boundary semantics): host padding is
boundary-aware — the row halo and column belt are filled with zeros, the
constant, the clamped edge, or the wrapped opposite edge — and the kernel
body re-imposes the rule per stage through the shared
:func:`~repro.kernels.blockops.boundary_fixup`.  For ``periodic`` the
wrap-filled row halo *is* the opposite edge's data and goes stale across
fused iterations exactly like a neighbour tile's halo (same trapezoid
safety argument); each round re-pads from the full updated grid.
"""
from __future__ import annotations

import functools
import math
from typing import Mapping

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.compat import element_block_spec
from repro.core.spec import StencilSpec
from repro.kernels.blockops import boundary_pad, fused_iterations_on_block


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def plan_blocks(
    spec: StencilSpec, s: int, tile_rows: int, align_cols: int = 1
) -> dict:
    """Static geometry for the fused kernel.

    ``align_cols`` pads the innermost dim up to a multiple (128 on real
    TPU for lane alignment; 1 in tests to keep interpret-mode shapes small).
    """
    r = spec.radius
    h = s * r                      # inter-tile row halo
    p = r                          # zero column pad (mask re-zeros each iter)
    grid_shape = spec.shape
    R = grid_shape[0]
    col_dims = tuple(grid_shape[1:])
    padded_cols = tuple(c + 2 * p for c in col_dims)
    if padded_cols:
        padded_cols = padded_cols[:-1] + (
            _round_up(padded_cols[-1], align_cols),
        )
    n_tiles = max(math.ceil(R / tile_rows), 1)
    rows_padded = n_tiles * tile_rows
    return dict(
        r=r, h=h, p=p, grid_shape=grid_shape, col_dims=col_dims,
        padded_cols=padded_cols, n_tiles=n_tiles, rows_padded=rows_padded,
        in_rows=tile_rows + 2 * h, tile_rows=tile_rows,
    )


def vmem_bytes_estimate(spec: StencilSpec, s: int, tile_rows: int) -> int:
    """Per-grid-step VMEM working set (used by the analytical model's
    resource bound and reported in the Fig. 8 analogue benchmark)."""
    g = plan_blocks(spec, s, tile_rows, align_cols=128)
    cols = 1
    for c in g["padded_cols"]:
        cols *= c
    block = g["in_rows"] * cols * spec.itemsize
    out = g["tile_rows"] * cols * spec.itemsize
    # inputs + iterate working copy + one stage temp + output, double-buffered
    return 2 * ((spec.num_inputs + 2) * block + out)


@functools.partial(
    jax.jit,
    static_argnames=("spec", "s", "tile_rows", "interpret", "align_cols"),
)
def stencil_pallas(
    spec: StencilSpec,
    arrays: Mapping[str, jnp.ndarray],
    s: int,
    tile_rows: int = 256,
    interpret: bool = True,
    align_cols: int = 1,
) -> jnp.ndarray:
    """Run ``s`` fused stencil iterations over the full grid via pallas_call."""
    g = plan_blocks(spec, s, tile_rows, align_cols)
    names = list(spec.inputs)
    grid_shape = g["grid_shape"]
    R = grid_shape[0]
    h, p = g["h"], g["p"]
    ndim = spec.ndim

    # ---- host-side padding: rows by (h, h + tile alignment), cols by p.
    # The boundary halo is laid down first (wrap/edge/constant fills need
    # real-data adjacency), then the lane/tile alignment zeros go outside
    # it, where the trapezoid argument keeps them from reaching the grid.
    def pad_host(a):
        bpads = [(h, h)] + [(p, p) for _ in g["col_dims"]]
        a = boundary_pad(a, bpads, spec.boundary)
        apads = [(0, g["rows_padded"] - R)]
        for d, c in enumerate(g["col_dims"]):
            apads.append((0, g["padded_cols"][d] - c - 2 * p))
        return jnp.pad(a, apads)

    padded = [pad_host(jnp.asarray(arrays[n])) for n in names]
    col_pads = tuple(p for _ in g["col_dims"])

    def kernel(*refs):
        in_refs, out_ref = refs[:-1], refs[-1]
        i = pl.program_id(0)
        row0 = i * g["tile_rows"] - h  # global grid row of block row 0
        blocks = {n: r_[...] for n, r_ in zip(names, in_refs)}
        res = fused_iterations_on_block(
            spec, blocks, s, row0, grid_shape, col_pads
        )
        sl = (slice(h, h + g["tile_rows"]),) + tuple(
            slice(0, cp) for cp in g["padded_cols"]
        )
        out_ref[...] = res[sl]

    in_block = (g["in_rows"],) + g["padded_cols"]
    in_index = lambda i: (i * g["tile_rows"],) + (0,) * (ndim - 1)
    out_block = (g["tile_rows"],) + g["padded_cols"]
    out_index = lambda i: (i,) + (0,) * (ndim - 1)

    out_padded = pl.pallas_call(
        kernel,
        grid=(g["n_tiles"],),
        in_specs=[element_block_spec(in_block, in_index) for _ in names],
        out_specs=pl.BlockSpec(out_block, out_index),
        out_shape=jax.ShapeDtypeStruct(
            (g["rows_padded"],) + g["padded_cols"], jnp.dtype(spec.dtype)
        ),
        interpret=interpret,
    )(*padded)

    sl = (slice(0, R),) + tuple(slice(p, p + c) for c in g["col_dims"])
    return out_padded[sl]
