"""Jit'd public entry points over the stencil executors.

``stencil_run`` is what the SASA executor calls once the auto-tuner has
chosen a configuration; it handles the round structure (ceil(iter/s)
kernel launches, with a smaller fused depth for a ragged last round).
"""
from __future__ import annotations

import functools
from typing import Mapping

import jax
import jax.numpy as jnp

from repro.core.spec import StencilSpec
from repro.kernels import ref as _ref
from repro.kernels.blockops import fused_iterations_dense, wrap_round_fixup
from repro.kernels.stencil import stencil_pallas


@functools.partial(
    jax.jit, static_argnames=("spec", "iterations", "s")
)
def stencil_fused_jnp(
    spec: StencilSpec,
    arrays: Mapping[str, jnp.ndarray],
    iterations: int,
    s: int,
) -> jnp.ndarray:
    """Fused-round execution in pure jnp (fast path on CPU hosts)."""
    return fused_iterations_dense(spec, dict(arrays), iterations, s)


def stencil_run(
    spec: StencilSpec,
    arrays: Mapping[str, jnp.ndarray],
    iterations: int | None = None,
    s: int = 1,
    tile_rows: int = 256,
    backend: str = "jnp",
    interpret: bool = True,
    align_cols: int = 1,
) -> jnp.ndarray:
    """Run the stencil to completion with fusion depth ``s``.

    backend: 'ref' (oracle), 'jnp' (fused dense), 'pallas' (TPU kernel;
    interpret=True executes the kernel body on CPU for validation).
    """
    it = spec.iterations if iterations is None else iterations
    if backend == "ref":
        return _ref.stencil_iterations_ref(spec, arrays, it)
    if backend == "jnp":
        return stencil_fused_jnp(spec, dict(arrays), it, min(s, it))
    if backend != "pallas":
        raise ValueError(f"unknown backend {backend!r}")
    env = dict(arrays)
    out = env[spec.iterate_input]
    left = it
    first = True
    while left > 0:
        step = min(s, left)
        if spec.wrap_index_inputs:
            step = min(step, max(spec.wrap_round_depth, 1))
            if not first:
                out = wrap_round_fixup(out, env, spec)
                env[spec.iterate_input] = out
        first = False
        out = stencil_pallas(
            spec, env, step, tile_rows=tile_rows,
            interpret=interpret, align_cols=align_cols,
        )
        env[spec.iterate_input] = out
        left -= step
    return out
