"""Batch-in-grid tile pipelines: the explicitly pipelined kernel path.

SASA's core trick is explicit placement of stencil streams into HBM banks
with overlapped DMA, so every PE's compute hides its memory traffic.  The
TPU analogue is the Pallas grid plus double-buffered HBM->VMEM copies —
but the vmapped serving path (``jax.vmap`` over whole-grid programs in
:mod:`repro.runtime.batching`) sidesteps it: batch entries never share
VMEM tiles and copy/compute overlap is left to XLA.  This module is the
execution idiom that replaces it (docs/DESIGN.md §Kernel layer):

  * :func:`stencil_pallas_batched` — the Pallas kernel iterates a
    ``(batch, tile)`` grid.  Each grid step DMAs one entry's
    ``(tile_rows + 2sr, C_pad)`` block HBM->VMEM; Pallas's grid pipeline
    double-buffers the copy for step ``(b, i+1)`` behind the compute of
    step ``(b, i)``, which is exactly SODA's FIFO-overlap property with
    VMEM standing in for the reuse buffer.  Streamed service inputs
    (``_mask``, halo-index maps, wrap maps) ride the same grid as
    per-entry block operands.
  * :func:`stencil_jnp_pipeline` — the same tile schedule in pure jnp
    for CPU hosts: a ``fori_loop`` over row tiles whose carry holds the
    *next* tile's prefetched block (software double buffering), with the
    batch folded into the block's leading axis so all entries stream
    through one residency.
  * :func:`stencil_run_batched` — the round loop over either executor
    (ceil(iterations/s) launches), with streamed wrap margins re-imposed
    between rounds (:func:`repro.kernels.blockops.wrap_round_fixup`).

Bitwise contract: both pipelines execute the *same tile program* — same
block geometry, same :func:`fused_iterations_on_block` trapezoid — as
``jax.vmap`` of the corresponding per-entry executor.  For the Pallas
pair the conformance suite holds the results **bitwise identical** on
XLA-CPU: vmap batches a ``pallas_call`` by adding a grid dimension,
which is exactly what :func:`stencil_pallas_batched` declares, so both
sides compile the identical kernel body.  The jnp pair agrees to ULP
scale but not always to the bit — the double-buffer carry makes the
loop body different HLO from the vmapped slice-per-step loop, and
XLA-CPU's instruction selection may round division / mul-add chains
differently per program.  (Tile decomposition itself is *not*
bitwise-stable against a dense whole-grid program either; only
identical programs at identical geometry are.)
"""
from __future__ import annotations

import functools
from typing import Mapping

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.compat import element_block_spec
from repro.core.spec import StencilSpec
from repro.kernels.blockops import (
    boundary_pad,
    fused_iterations_on_block,
    wrap_round_fixup,
)
from repro.kernels.stencil import plan_blocks


def _pad_host_batched(a: jnp.ndarray, spec: StencilSpec, g: dict):
    """Boundary halo + alignment padding on a (B,)-leading array."""
    h, p = g["h"], g["p"]
    R = g["grid_shape"][0]
    bpads = [(0, 0), (h, h)] + [(p, p) for _ in g["col_dims"]]
    a = boundary_pad(a, bpads, spec.boundary)
    apads = [(0, 0), (0, g["rows_padded"] - R)]
    for d, c in enumerate(g["col_dims"]):
        apads.append((0, g["padded_cols"][d] - c - 2 * p))
    return jnp.pad(a, apads)


def _out_slice(spec: StencilSpec, g: dict):
    """Strip alignment + column belt from a (B,)-leading padded output."""
    p = g["p"]
    return (slice(None), slice(0, g["grid_shape"][0])) + tuple(
        slice(p, p + c) for c in g["col_dims"]
    )


@functools.partial(
    jax.jit,
    static_argnames=("spec", "s", "tile_rows", "interpret", "align_cols"),
)
def stencil_pallas_batched(
    spec: StencilSpec,
    arrays: Mapping[str, jnp.ndarray],
    s: int,
    tile_rows: int = 256,
    interpret: bool = True,
    align_cols: int = 1,
) -> jnp.ndarray:
    """One round of ``s`` fused iterations over a whole batch, with the
    batch axis folded into the Pallas grid.

    Inputs are ``(B,) + spec.shape``; the kernel runs a ``(B, n_tiles)``
    grid where step ``(b, i)`` owns entry ``b``'s row tile ``i`` as a
    ``(1, tile_rows + 2sr, C_pad)`` VMEM block.  Identical tile geometry
    and kernel body to :func:`repro.kernels.stencil.stencil_pallas`, so
    the result is bitwise-identical to vmapping that kernel over the
    batch — the grid layout changes *scheduling*, not the computation.
    """
    g = plan_blocks(spec, s, tile_rows, align_cols)
    names = list(spec.inputs)
    grid_shape = g["grid_shape"]
    h = g["h"]
    ndim = spec.ndim
    B = int(next(iter(arrays.values())).shape[0])

    padded = [
        _pad_host_batched(jnp.asarray(arrays[n]), spec, g) for n in names
    ]
    col_pads = tuple(g["p"] for _ in g["col_dims"])

    def kernel(*refs):
        in_refs, out_ref = refs[:-1], refs[-1]
        i = pl.program_id(1)
        row0 = i * g["tile_rows"] - h
        blocks = {n: r_[...][0] for n, r_ in zip(names, in_refs)}
        res = fused_iterations_on_block(
            spec, blocks, s, row0, grid_shape, col_pads
        )
        sl = (slice(h, h + g["tile_rows"]),) + tuple(
            slice(0, cp) for cp in g["padded_cols"]
        )
        out_ref[...] = res[sl][None]

    # element-indexed input blocks: one batch entry (block size 1 at
    # element offset b), rows at element offset i*tile_rows.
    in_block = (1, g["in_rows"]) + g["padded_cols"]
    in_index = lambda b, i: (b, i * g["tile_rows"]) + (0,) * (ndim - 1)
    # block-indexed output: batch block 1 -> index b, row block tile_rows
    # -> index i.
    out_block = (1, g["tile_rows"]) + g["padded_cols"]
    out_index = lambda b, i: (b, i) + (0,) * (ndim - 1)

    out_padded = pl.pallas_call(
        kernel,
        grid=(B, g["n_tiles"]),
        in_specs=[element_block_spec(in_block, in_index) for _ in names],
        out_specs=pl.BlockSpec(out_block, out_index),
        out_shape=jax.ShapeDtypeStruct(
            (B, g["rows_padded"]) + g["padded_cols"], jnp.dtype(spec.dtype)
        ),
        interpret=interpret,
    )(*padded)

    return out_padded[_out_slice(spec, g)]


@functools.partial(
    jax.jit, static_argnames=("spec", "s", "tile_rows", "align_cols")
)
def stencil_jnp_tiled(
    spec: StencilSpec,
    arrays: Mapping[str, jnp.ndarray],
    s: int,
    tile_rows: int = 256,
    align_cols: int = 1,
) -> jnp.ndarray:
    """Per-entry tile-loop executor (no batch axis): the vmap reference
    for :func:`stencil_jnp_pipeline`.

    Walks the same ``(tile_rows + 2sr)``-row blocks as the pipelined
    path, single-buffered, via ``fori_loop`` + dynamic slices.  vmapping
    this function and running :func:`stencil_jnp_pipeline` trace to the
    same batched tile program, which is what makes the differential
    bitwise on CPU.
    """
    g = plan_blocks(spec, s, tile_rows, align_cols)
    names = list(spec.inputs)
    h = g["h"]
    one = {n: jnp.asarray(arrays[n])[None] for n in names}
    padded = {n: _pad_host_batched(a, spec, g)[0] for n, a in one.items()}
    col_pads = tuple(g["p"] for _ in g["col_dims"])
    blk_shape = (g["in_rows"],) + g["padded_cols"]
    zeros_nd = (0,) * (spec.ndim - 1)

    def fetch(i):
        start = (i * g["tile_rows"],) + zeros_nd
        return {
            n: jax.lax.dynamic_slice(a, start, blk_shape)
            for n, a in padded.items()
        }

    out0 = jnp.zeros(
        (g["rows_padded"],) + g["padded_cols"], jnp.dtype(spec.dtype)
    )

    def step(i, out):
        blocks = fetch(i)
        row0 = i * g["tile_rows"] - h
        res = fused_iterations_on_block(
            spec, blocks, s, row0, g["grid_shape"], col_pads
        )
        sl = (slice(h, h + g["tile_rows"]),)
        return jax.lax.dynamic_update_slice(
            out, res[sl], (i * g["tile_rows"],) + zeros_nd
        )

    out = jax.lax.fori_loop(0, g["n_tiles"], step, out0)
    return out[tuple(sl for sl in _out_slice(spec, g)[1:])]


@functools.partial(
    jax.jit, static_argnames=("spec", "s", "tile_rows", "align_cols")
)
def stencil_jnp_pipeline(
    spec: StencilSpec,
    arrays: Mapping[str, jnp.ndarray],
    s: int,
    tile_rows: int = 256,
    align_cols: int = 1,
) -> jnp.ndarray:
    """One round of ``s`` fused iterations over a whole batch as a
    software double-buffered tile loop (the jnp analogue of the Pallas
    grid pipeline, for CPU hosts).

    Inputs are ``(B,) + spec.shape``.  The ``fori_loop`` carry holds the
    *prefetched* next tile block — the fetch for tile ``i+1`` is issued
    before the compute of tile ``i`` consumes its buffer, giving the
    scheduler a full tile of copy/compute overlap (SNIPPETS.md Snippet
    2's ``emit_pipeline`` decomposition in miniature).  The batch rides
    the block's leading axis, so all B entries stream through one
    buffer residency per tile; the per-tile compute is
    ``jax.vmap(fused_iterations_on_block)``, the same trapezoid the
    per-entry executors run.
    """
    g = plan_blocks(spec, s, tile_rows, align_cols)
    names = list(spec.inputs)
    h = g["h"]
    B = int(next(iter(arrays.values())).shape[0])
    padded = {
        n: _pad_host_batched(jnp.asarray(arrays[n]), spec, g) for n in names
    }
    col_pads = tuple(g["p"] for _ in g["col_dims"])
    blk_shape = (B, g["in_rows"]) + g["padded_cols"]
    zeros_nd = (0,) * (spec.ndim - 1)

    def fetch(i):
        # double-buffer prefetch: clamped at the last tile (the fetched
        # block is discarded)
        i = jnp.minimum(i, g["n_tiles"] - 1)
        start = (0, i * g["tile_rows"]) + zeros_nd
        return {
            n: jax.lax.dynamic_slice(a, start, blk_shape)
            for n, a in padded.items()
        }

    compute = jax.vmap(
        lambda blocks, row0: fused_iterations_on_block(
            spec, blocks, s, row0, g["grid_shape"], col_pads
        ),
        in_axes=(0, None),
    )

    out0 = jnp.zeros(
        (B, g["rows_padded"]) + g["padded_cols"], jnp.dtype(spec.dtype)
    )

    def step(i, carry):
        buf, out = carry
        nxt = fetch(i + 1)           # issue next copy before this compute
        row0 = i * g["tile_rows"] - h
        res = compute(buf, row0)
        out = jax.lax.dynamic_update_slice(
            out, res[:, h:h + g["tile_rows"]],
            (0, i * g["tile_rows"]) + zeros_nd,
        )
        return (nxt, out)

    _, out = jax.lax.fori_loop(0, g["n_tiles"], step, (fetch(0), out0))
    return out[_out_slice(spec, g)]


def stencil_run_batched(
    spec: StencilSpec,
    arrays: Mapping[str, jnp.ndarray],
    iterations: int | None = None,
    s: int = 1,
    tile_rows: int = 256,
    backend: str = "jnp",
    interpret: bool = True,
    align_cols: int = 1,
) -> jnp.ndarray:
    """Run the stencil to completion over a batch through the tile
    pipeline: ceil(iterations/s) rounds of the batch-in-grid executor.

    backend: 'jnp' (software double-buffered tile loop), 'pallas'
    (batch-in-grid Pallas kernel; interpret=True for CPU validation).
    Specs with streamed wrap margins cap the per-round fused depth at
    ``spec.wrap_round_depth`` and re-wrap the iterate between rounds.
    """
    it = spec.iterations if iterations is None else iterations
    if backend not in ("jnp", "pallas"):
        raise ValueError(f"unknown tile-pipeline backend {backend!r}")
    env = dict(arrays)
    out = env[spec.iterate_input]
    rewrap = jax.vmap(lambda o, e: wrap_round_fixup(o, e, spec))
    left = it
    first = True
    while left > 0:
        step = min(s, left)
        if spec.wrap_index_inputs:
            step = min(step, max(spec.wrap_round_depth, 1))
            if not first:
                out = rewrap(out, {
                    n: jnp.asarray(env[n]) for n in spec.wrap_index_inputs
                })
                env[spec.iterate_input] = out
        first = False
        if backend == "pallas":
            out = stencil_pallas_batched(
                spec, env, step, tile_rows=tile_rows,
                interpret=interpret, align_cols=align_cols,
            )
        else:
            out = stencil_jnp_pipeline(
                spec, env, step, tile_rows=tile_rows, align_cols=align_cols,
            )
        env[spec.iterate_input] = out
        left -= step
    return out
