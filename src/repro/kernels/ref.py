"""Pure-jnp oracle for stencil execution (exact boundary semantics).

Every other executor in the framework (Pallas kernels, shard_map spatial /
hybrid / temporal-pipeline distributions) must agree with this module
bit-for-bit up to float associativity, for every boundary mode the spec
layer can express (docs/DESIGN.md §Boundary semantics): each stage reads
every array through the spec's :class:`~repro.core.spec.Boundary`
extension — zeros, a constant, the clamped edge cell, or the wrapped
opposite edge.
"""
from __future__ import annotations

from typing import Mapping

import jax
import jax.numpy as jnp

from repro.core.spec import Boundary, Stage, StencilSpec, ZERO_BOUNDARY, eval_expr
from repro.kernels.blockops import boundary_pad


def _shifted(padded: jnp.ndarray, offsets, radius: int, shape) -> jnp.ndarray:
    """View of ``padded`` shifted by ``offsets`` with the original shape."""
    idx = tuple(
        slice(radius + o, radius + o + s) for o, s in zip(offsets, shape)
    )
    return padded[idx]


def apply_stage(
    stage: Stage,
    arrays: Mapping[str, jnp.ndarray],
    boundary: Boundary = ZERO_BOUNDARY,
) -> jnp.ndarray:
    """Apply one stencil stage over the full grid with the boundary rule."""
    shape = next(iter(arrays.values())).shape
    r = stage.radius
    padded = {
        name: boundary_pad(a, [(r, r)] * a.ndim, boundary)
        for name, a in arrays.items()
    }

    def get_ref(name, offsets):
        return _shifted(padded[name], offsets, r, shape)

    out = eval_expr(stage.expr, get_ref)
    return out.astype(stage.dtype)


def stencil_step_ref(
    spec: StencilSpec, arrays: Mapping[str, jnp.ndarray]
) -> jnp.ndarray:
    """One full iteration (all local stages + output stage)."""
    env = dict(arrays)
    for stage in spec.stages:
        env[stage.name] = apply_stage(stage, env, spec.boundary)
    return env[spec.output_name]


def stencil_iterations_ref(
    spec: StencilSpec,
    arrays: Mapping[str, jnp.ndarray],
    iterations: int | None = None,
) -> jnp.ndarray:
    """Run ``iterations`` ping-pong iterations (Section 2.1)."""
    it = spec.iterations if iterations is None else iterations
    env = dict(arrays)
    out = env[spec.iterate_input]
    for _ in range(it):
        out = stencil_step_ref(spec, env)
        env[spec.iterate_input] = out
    return out


def stencil_run_ref_jit(spec: StencilSpec, iterations: int):
    """Jitted closure over the spec: arrays dict -> output array."""

    def run(arrays):
        return stencil_iterations_ref(spec, arrays, iterations)

    return jax.jit(run)
