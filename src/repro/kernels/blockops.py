"""Fused multi-iteration stencil execution on a block (trapezoid scheme).

This is the single implementation of truth for "apply ``s`` stencil
iterations to a block under the spec's boundary rule".  It is shared by
three executors so they cannot drift apart (docs/DESIGN.md §Executors):

  * the Pallas TPU kernel body (on VMEM-loaded values),
  * the single-device jnp fallback (whole array as one block),
  * the shard_map spatial/hybrid locals (local shard + exchanged halo).

Trapezoid correctness argument: a block carries ``h`` halo rows on each
side.  Each fused iteration invalidates ``r`` rows at each block edge
(they were computed from in-block zero padding instead of true neighbour
data), so after ``s`` iterations rows at distance >= s*r from the edge are
exact.  Callers must provide ``h >= s*r`` and only consume the safe
interior.

Boundary handling (docs/DESIGN.md §Boundary semantics): cells *outside
the global grid* that live inside a block are re-imposed after every
stage by :func:`boundary_fixup` — zeroed (``zero``), set to the constant
(``constant``), or gathered from the clamped nearest edge cell
(``replicate``).  ``periodic`` is the one mode whose row dimension is not
fixed up in-block: the wrapped rows come in as *data* (host wrap padding
or wraparound ppermute halo exchange) and go stale per the same trapezoid
argument, while the column dimensions — always resident in full — are
re-wrapped in-block each stage.
"""
from __future__ import annotations

from typing import Mapping, Sequence

import jax
import jax.numpy as jnp

from repro.core.spec import (
    Boundary,
    Stage,
    StencilSpec,
    ZERO_BOUNDARY,
    eval_expr,
)


def _block_stage(stage: Stage, env: Mapping[str, jnp.ndarray]) -> jnp.ndarray:
    """One stage over a block, zero-padding at block edges (same shape out)."""
    shape = next(iter(env.values())).shape
    r = stage.radius
    padded = {n: jnp.pad(a, [(r, r)] * a.ndim) for n, a in env.items()}

    def get_ref(name, offsets):
        idx = tuple(slice(r + o, r + o + s) for o, s in zip(offsets, shape))
        return padded[name][idx]

    return eval_expr(stage.expr, get_ref).astype(stage.dtype)


def boundary_pad(
    a: jnp.ndarray, pads: Sequence[tuple[int, int]], boundary: Boundary
) -> jnp.ndarray:
    """``jnp.pad`` with the fill the boundary rule prescribes."""
    pads = list(pads)
    k = boundary.kind
    if k == "zero":
        return jnp.pad(a, pads)
    if k == "constant":
        return jnp.pad(a, pads, constant_values=boundary.value)
    if k == "replicate":
        return jnp.pad(a, pads, mode="edge")
    if k == "periodic":
        return jnp.pad(a, pads, mode="wrap")
    raise ValueError(f"unknown boundary kind {k!r}")


def grid_mask(
    block_shape: tuple[int, ...],
    row0,
    grid_shape: tuple[int, ...],
    col_pads: tuple[int, ...],
    dtype,
) -> jnp.ndarray:
    """1.0 where the block cell maps to a real grid cell, else 0.0.

    ``row0`` is the global grid row of block row 0 (may be negative /
    traced).  ``col_pads[d]`` is the padding prepended to non-row dim
    ``d+1``.
    """
    ndim = len(block_shape)
    rows = jax.lax.broadcasted_iota(jnp.int32, block_shape, 0) + row0
    mask = (rows >= 0) & (rows < grid_shape[0])
    for d in range(1, ndim):
        cols = jax.lax.broadcasted_iota(jnp.int32, block_shape, d) - col_pads[d - 1]
        mask &= (cols >= 0) & (cols < grid_shape[d])
    return mask.astype(dtype)


def boundary_fixup(
    block: jnp.ndarray,
    row0,
    grid_shape: tuple[int, ...],
    col_pads: tuple[int, ...],
    boundary: Boundary = ZERO_BOUNDARY,
) -> jnp.ndarray:
    """Re-impose the boundary rule on every out-of-grid cell of a block.

    In-grid cells are returned untouched (for replicate/periodic the
    gather is the identity there), so neighbour-exchanged halo rows — real
    data — survive.  Replicate assumes the block physically contains the
    edge cell its out-of-grid cells clamp to; every tiler in the repo
    guarantees that (Pallas tiles span contiguous rows below ``R``, the
    distribution layer checks each device owns a real row).  Periodic
    never fixes the row dimension (wrapped rows arrive as data, see module
    docstring); columns are re-wrapped in place since blocks always hold
    the full column extent.
    """
    kind = boundary.kind
    shape = block.shape
    if kind == "zero":
        return block * grid_mask(shape, row0, grid_shape, col_pads, block.dtype)
    if kind == "constant":
        mask = grid_mask(shape, row0, grid_shape, col_pads, jnp.bool_)
        return jnp.where(mask, block, jnp.asarray(boundary.value, block.dtype))
    out = block
    if kind == "replicate":
        rows = jnp.arange(shape[0]) + row0
        tgt = jnp.clip(jnp.clip(rows, 0, grid_shape[0] - 1) - row0,
                       0, shape[0] - 1)
        out = jnp.take(out, tgt, axis=0)
    for d in range(1, len(shape)):
        pad = col_pads[d - 1]
        size = grid_shape[d]
        cols = jnp.arange(shape[d]) - pad
        if kind == "replicate":
            tgt = jnp.clip(cols, 0, size - 1) + pad
        else:  # periodic
            tgt = jnp.mod(cols, size) + pad
        out = jnp.take(out, jnp.clip(tgt, 0, shape[d] - 1), axis=d)
    return out


def streamed_halo_fixup(
    block: jnp.ndarray,
    env: Mapping[str, jnp.ndarray],
    spec: StencilSpec,
    row0,
    col_pads: tuple[int, ...],
) -> jnp.ndarray:
    """Re-impose a *streamed* (per-request) boundary on a block.

    ``spec.halo_index_inputs`` names one int32 input per dimension whose
    cells hold the global grid coordinate each cell should copy from
    (identity on the real region, clamp target on the padding belt of a
    bucket design).  The per-axis gather composes exactly like
    ``np.pad``'s per-axis edge extension, so after every stage the belt
    holds the smaller real grid's clamped exterior — in every executor,
    since they all compute through this helper.

    Locality: the gather target is converted to block-local coordinates
    (``- row0`` on the tiled/sharded row dim, ``+ col_pads`` on the fully
    resident column dims) and clipped to the block.  Clamp targets are
    the nearest real edge cells, which every tiler/shard holds in any
    block that owns belt cells within the trapezoid-safe depth (the same
    guarantee the non-bucketed replicate fixup relies on); deeper belt
    cells may gather clipped garbage, but their values never reach the
    safe interior within a round and are re-imposed or sliced off
    outside it.

    Clamp-map contract: every halo-index producer in the repo
    (:func:`repro.runtime.bucketing.halo_index_host` and the all-zero
    filler maps) emits per-axis maps of the form ``clip(identity, lo,
    hi)`` — monotone clamps of the axis coordinate.  Composing with the
    block-local shift and clip above preserves that form, so the gather
    is equivalent to *static slicing*: rows below ``lo`` copy row ``lo``,
    rows above ``hi`` copy row ``hi``, the middle is identity.  That is
    what this helper emits — two ``dynamic_index_in_dim`` broadcasts and
    two ``where`` selects per axis instead of a ``take_along_axis``
    gather, which keeps the inner loop on the TPU's statically-addressed
    VMEM path (gathers lower to scalar loops on the VPU).  All-constant
    filler maps are the degenerate ``lo == hi`` clamp and come out of the
    same select path.
    """
    names = spec.halo_index_inputs
    out = block
    for d, name in enumerate(names):
        idx = env[name]
        tgt = idx - row0 if d == 0 else idx + col_pads[d - 1]
        tgt = jnp.clip(tgt, 0, out.shape[d] - 1).astype(jnp.int32)
        lo = jnp.min(tgt)
        hi = jnp.max(tgt)
        coords = jax.lax.broadcasted_iota(jnp.int32, out.shape, d)
        at_lo = jax.lax.dynamic_index_in_dim(out, lo, axis=d, keepdims=True)
        at_hi = jax.lax.dynamic_index_in_dim(out, hi, axis=d, keepdims=True)
        out = jnp.where(
            coords < lo, at_lo, jnp.where(coords > hi, at_hi, out)
        )
    return out


def fused_iterations_on_block(
    spec: StencilSpec,
    blocks: Mapping[str, jnp.ndarray],
    s: int,
    row0,
    grid_shape: tuple[int, ...],
    col_pads: tuple[int, ...],
    boundary: Boundary | None = None,
) -> jnp.ndarray:
    """Apply ``s`` fused iterations to a block; returns the iterated array.

    ``blocks`` maps every spec input name to a same-shape block (halo rows
    and column padding already included).  Only the ``iterate_input``
    evolves; other inputs are constant across iterations.  ``boundary``
    defaults to the spec's own rule.  Specs carrying streamed halo-index
    inputs (bucketed replicate serving) additionally re-impose the
    per-request boundary via :func:`streamed_halo_fixup` after every
    stage, *before* the block-level boundary rule so out-of-grid cells
    clamp to the re-imposed belt.
    """
    boundary = spec.boundary if boundary is None else boundary
    env = {n: jnp.asarray(b) for n, b in blocks.items()}

    def fixup(a):
        return boundary_fixup(a, row0, grid_shape, col_pads, boundary)

    # Inputs may carry garbage outside the grid (e.g. unmasked host
    # padding); impose the boundary rule before the first iteration too.
    # Streamed specs also re-impose the per-request belt on entry: a block
    # whose copy of the gather source went stale late in the *previous*
    # round can hand a neighbour stale belt rows (real/belt edge
    # straddling a tile or shard boundary) — the entry gather repairs
    # every consumed belt cell from the committed real values before the
    # first stage reads it.
    streamed = bool(spec.halo_index_inputs)
    if streamed:
        src = dict(env)
        env = {
            n: streamed_halo_fixup(a, src, spec, row0, col_pads)
            for n, a in env.items()
        }
    env = {n: fixup(a) for n, a in env.items()}
    cur = env[spec.iterate_input]
    for _ in range(s):
        env[spec.iterate_input] = cur
        stage_env = dict(env)
        for stage in spec.stages:
            out = _block_stage(stage, stage_env)
            if streamed:
                out = streamed_halo_fixup(out, stage_env, spec, row0, col_pads)
            out = fixup(out)  # the boundary is re-imposed at every stage
            stage_env[stage.name] = out
        cur = stage_env[spec.output_name]
    return cur


def wrap_round_fixup(
    out: jnp.ndarray,
    env: Mapping[str, jnp.ndarray],
    spec: StencilSpec,
) -> jnp.ndarray:
    """Re-impose a streamed periodic wrap margin on the iterate.

    ``spec.wrap_index_inputs`` names one int32 grid-shaped input per
    dimension holding, for every cell, the coordinate it should copy from
    — identity on the real region, ``margin + ((coord - margin) mod S)``
    on the wrap belt of a bucket design.  Executors call this **between
    fused rounds** (never before the first): a round of depth
    ``wrap_round_depth`` stales at most ``wrap_round_depth * radius``
    margin cells, and this global gather refreshes them from the real
    region the round just committed.  Only the iterate needs it —
    constant inputs' wrapped margins never go stale.

    Unlike the per-stage clamp maps (:func:`streamed_halo_fixup`), wrap
    maps are modular, not monotone, so this stays a ``take_along_axis``
    gather; it runs once per round at grid granularity, outside the tile
    loop.
    """
    for d, name in enumerate(spec.wrap_index_inputs):
        tgt = jnp.clip(
            jnp.asarray(env[name]), 0, out.shape[d] - 1
        ).astype(jnp.int32)
        out = jnp.take_along_axis(out, tgt, axis=d)
    return out


def fused_iterations_dense(
    spec: StencilSpec,
    arrays: Mapping[str, jnp.ndarray],
    iterations: int,
    s: int,
) -> jnp.ndarray:
    """Single-device fused execution: rounds of ceil(iter/s) over the full
    grid held as one block.  Matches ``stencil_iterations_ref`` exactly.

    Non-zero boundaries carry an explicit boundary belt: rows get an
    ``s*r``-deep boundary-padded halo per round (for periodic this is the
    wrapped data the in-block fixup never regenerates), columns an
    ``r``-deep belt the per-stage fixup refreshes.

    Specs carrying streamed wrap inputs cap the fused depth per round at
    ``spec.wrap_round_depth`` and re-wrap the iterate's margin between
    rounds (:func:`wrap_round_fixup`).
    """
    grid_shape = spec.shape
    left = iterations
    cur = dict(arrays)
    out = cur[spec.iterate_input]
    boundary = spec.boundary
    r = spec.radius
    first = True
    while left > 0:
        step = min(s, left)
        if spec.wrap_index_inputs:
            step = min(step, max(spec.wrap_round_depth, 1))
            if not first:
                out = wrap_round_fixup(out, cur, spec)
                cur[spec.iterate_input] = out
        first = False
        if boundary.is_zero:
            out = fused_iterations_on_block(
                spec, cur, step, row0=0, grid_shape=grid_shape,
                col_pads=(0,) * (spec.ndim - 1),
            )
        else:
            h = step * r
            pads = [(h, h)] + [(r, r)] * (spec.ndim - 1)
            padded = {
                n: boundary_pad(jnp.asarray(a), pads, boundary)
                for n, a in cur.items()
            }
            ext = fused_iterations_on_block(
                spec, padded, step, row0=-h, grid_shape=grid_shape,
                col_pads=(r,) * (spec.ndim - 1),
            )
            sl = (slice(h, h + grid_shape[0]),) + tuple(
                slice(r, r + c) for c in grid_shape[1:]
            )
            out = ext[sl]
        cur[spec.iterate_input] = out
        left -= step
    return out
