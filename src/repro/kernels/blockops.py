"""Fused multi-iteration stencil execution on a block (trapezoid scheme).

This is the single implementation of truth for "apply ``s`` stencil
iterations to a block with exterior-zero boundary masking".  It is shared
by three executors so they cannot drift apart:

  * the Pallas TPU kernel body (on VMEM-loaded values),
  * the single-device jnp fallback (whole array as one block),
  * the shard_map spatial/hybrid locals (local shard + exchanged halo).

Trapezoid correctness argument: a block carries ``h`` halo rows on each
side.  Each fused iteration invalidates ``r`` rows at each block edge
(they were computed from in-block zero padding instead of true neighbour
data), so after ``s`` iterations rows at distance >= s*r from the edge are
exact.  Callers must provide ``h >= s*r`` and only consume the safe
interior.  Rows/cols *outside the global grid* are re-zeroed after every
iteration via masks, which is exactly the reference exterior-zero
semantics (and is what keeps global-edge blocks correct rather than merely
their interiors).
"""
from __future__ import annotations

from typing import Mapping

import jax
import jax.numpy as jnp

from repro.core.spec import Stage, StencilSpec, eval_expr


def _block_stage(stage: Stage, env: Mapping[str, jnp.ndarray]) -> jnp.ndarray:
    """One stage over a block, zero-padding at block edges (same shape out)."""
    shape = next(iter(env.values())).shape
    r = stage.radius
    padded = {n: jnp.pad(a, [(r, r)] * a.ndim) for n, a in env.items()}

    def get_ref(name, offsets):
        idx = tuple(slice(r + o, r + o + s) for o, s in zip(offsets, shape))
        return padded[name][idx]

    return eval_expr(stage.expr, get_ref).astype(stage.dtype)


def grid_mask(
    block_shape: tuple[int, ...],
    row0,
    grid_shape: tuple[int, ...],
    col_pads: tuple[int, ...],
    dtype,
) -> jnp.ndarray:
    """1.0 where the block cell maps to a real grid cell, else 0.0.

    ``row0`` is the global grid row of block row 0 (may be negative /
    traced).  ``col_pads[d]`` is the zero-padding prepended to non-row dim
    ``d+1``.
    """
    ndim = len(block_shape)
    rows = jax.lax.broadcasted_iota(jnp.int32, block_shape, 0) + row0
    mask = (rows >= 0) & (rows < grid_shape[0])
    for d in range(1, ndim):
        cols = jax.lax.broadcasted_iota(jnp.int32, block_shape, d) - col_pads[d - 1]
        mask &= (cols >= 0) & (cols < grid_shape[d])
    return mask.astype(dtype)


def fused_iterations_on_block(
    spec: StencilSpec,
    blocks: Mapping[str, jnp.ndarray],
    s: int,
    row0,
    grid_shape: tuple[int, ...],
    col_pads: tuple[int, ...],
) -> jnp.ndarray:
    """Apply ``s`` fused iterations to a block; returns the iterated array.

    ``blocks`` maps every spec input name to a same-shape block (halo rows
    and zero column padding already included).  Only the ``iterate_input``
    evolves; other inputs are constant across iterations.
    """
    env = {n: jnp.asarray(b) for n, b in blocks.items()}
    shape = env[spec.iterate_input].shape
    mask = grid_mask(shape, row0, grid_shape, col_pads, env[spec.iterate_input].dtype)
    # Inputs may carry garbage outside the grid (e.g. unmasked host padding);
    # enforce exterior-zero before the first iteration too.
    env = {n: a * mask for n, a in env.items()}
    cur = env[spec.iterate_input]
    for _ in range(s):
        env[spec.iterate_input] = cur
        stage_env = dict(env)
        for stage in spec.stages:
            out = _block_stage(stage, stage_env)
            out = out * mask  # exterior-zero is re-imposed at every stage
            stage_env[stage.name] = out
        cur = stage_env[spec.output_name]
    return cur


def fused_iterations_dense(
    spec: StencilSpec,
    arrays: Mapping[str, jnp.ndarray],
    iterations: int,
    s: int,
) -> jnp.ndarray:
    """Single-device fused execution: rounds of ceil(iter/s) over the full
    grid held as one block.  Matches ``stencil_iterations_ref`` exactly.
    """
    grid_shape = spec.shape
    left = iterations
    cur = dict(arrays)
    out = cur[spec.iterate_input]
    while left > 0:
        step = min(s, left)
        out = fused_iterations_on_block(
            spec, cur, step, row0=0, grid_shape=grid_shape,
            col_pads=(0,) * (spec.ndim - 1),
        )
        cur[spec.iterate_input] = out
        left -= step
    return out
