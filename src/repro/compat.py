"""Single-point jax version compatibility shim.

Supported-version policy (see ROADMAP.md): the repo pins the oldest
supported toolchain, **jax 0.4.37**, and tracks newer jax releases by
feature-detecting the handful of APIs that moved or were renamed since.
Everything version-sensitive is funnelled through this module so a jax
upgrade is a one-file change; no other module may import `shard_map`,
query an axis size, or build an element-indexed Pallas ``BlockSpec``
directly.

Shimmed surface:

  =====================  ==========================  =======================
  name                   jax >= 0.6 spelling         jax 0.4.37 spelling
  =====================  ==========================  =======================
  ``shard_map``          ``jax.shard_map``           ``jax.experimental.
                                                     shard_map.shard_map``
  ``axis_size(name)``    ``lax.axis_size(name)``     ``lax.psum(1, name)``
                                                     (constant-folded to a
                                                     Python int)
  ``pvary(x, names)``    ``lax.pcast(x, names,       identity (0.4.x rep
                         to="varying")``             tracking degrades loop
                                                     carries automatically)
  ``element_block_spec`` ``pl.BlockSpec`` with       ``pl.BlockSpec(...,
                         ``pl.Element`` dims         indexing_mode=
                                                     pl.Unblocked())``
  =====================  ==========================  =======================
"""
from __future__ import annotations

import re
from typing import Callable, Sequence

import jax
from jax import lax
from jax.experimental import pallas as pl


def _parse_version(v: str) -> tuple[int, ...]:
    return tuple(int(x) for x in re.findall(r"\d+", v)[:3])


JAX_VERSION: tuple[int, ...] = _parse_version(jax.__version__)

# Oldest toolchain the repo promises to run on (the pinned CI version).
MIN_SUPPORTED_JAX: tuple[int, ...] = (0, 4, 37)


# --------------------------------------------------------------------------
# shard_map: jax.shard_map (>=0.6) vs jax.experimental.shard_map (0.4.x)
# --------------------------------------------------------------------------

try:
    from jax import shard_map as _shard_map  # type: ignore[attr-defined]
except ImportError:  # jax 0.4.x / 0.5.x
    from jax.experimental.shard_map import shard_map as _shard_map

_SHARD_MAP_KWARGS = None


def shard_map(f=None, **kwargs):
    """`shard_map` with the replication-check flag name normalised.

    Newer jax renamed ``check_rep`` to ``check_vma``; callers may pass
    either and the one the installed jax understands is forwarded.
    """
    global _SHARD_MAP_KWARGS
    if _SHARD_MAP_KWARGS is None:
        import inspect

        _SHARD_MAP_KWARGS = frozenset(
            inspect.signature(_shard_map).parameters
        )
    check = kwargs.pop("check_vma", kwargs.pop("check_rep", None))
    if check is not None:
        name = "check_vma" if "check_vma" in _SHARD_MAP_KWARGS else "check_rep"
        kwargs[name] = check
    if f is None:
        return lambda g: _shard_map(g, **kwargs)
    return _shard_map(f, **kwargs)


# --------------------------------------------------------------------------
# axis_size: lax.axis_size appeared after 0.4.37
# --------------------------------------------------------------------------

if hasattr(lax, "axis_size"):

    def axis_size(axis_name: str) -> int:
        """Size of a mapped mesh axis, as a concrete Python int."""
        return lax.axis_size(axis_name)

else:

    def axis_size(axis_name: str) -> int:
        """Size of a mapped mesh axis, as a concrete Python int.

        ``psum`` of a non-tracer constant is folded to ``constant *
        axis_size`` at trace time, so this returns a plain int usable in
        Python control flow (e.g. building ppermute tables).
        """
        return lax.psum(1, axis_name)


# --------------------------------------------------------------------------
# pvary: mark a value as device-varying for shard_map replication typing
# --------------------------------------------------------------------------

if hasattr(lax, "pcast"):

    def pvary(x, axis_names: Sequence[str]):
        """Cast ``x`` to device-varying along ``axis_names``."""
        return lax.pcast(x, tuple(axis_names), to="varying")

elif hasattr(lax, "pvary"):

    def pvary(x, axis_names: Sequence[str]):
        return lax.pvary(x, tuple(axis_names))

else:

    def pvary(x, axis_names: Sequence[str]):
        """No-op on jax 0.4.x: shard_map's replication checker computes a
        fixpoint over loop carries there, so pre-casting is unnecessary."""
        return x


# --------------------------------------------------------------------------
# Element-indexed Pallas BlockSpec (overlapping input blocks)
# --------------------------------------------------------------------------


def element_block_spec(
    block_shape: Sequence[int], index_map: Callable[..., tuple]
) -> pl.BlockSpec:
    """A ``BlockSpec`` whose ``index_map`` returns **element** offsets.

    Blocked (default) indexing places block ``i`` at ``index_map(i) *
    block_shape`` — it cannot express overlapping input windows (block
    stride != block size), which the fused stencil kernel needs for its
    halo rows.  Newer jax spells this ``pl.Element`` per dimension; jax
    0.4.37 spells it ``indexing_mode=pl.Unblocked()``.
    """
    shape = tuple(int(n) for n in block_shape)
    if hasattr(pl, "Element"):
        return pl.BlockSpec(
            tuple(pl.Element(n) for n in shape), index_map
        )
    return pl.BlockSpec(shape, index_map, indexing_mode=pl.Unblocked())
