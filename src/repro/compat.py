"""Single-point jax version compatibility shim.

Supported-version policy (see ROADMAP.md): the repo pins the oldest
supported toolchain, **jax 0.4.37**, and tracks newer jax releases by
feature-detecting the handful of APIs that moved or were renamed since.
Everything version-sensitive is funnelled through this module so a jax
upgrade is a one-file change; no other module may import `shard_map`,
query an axis size, or build an element-indexed Pallas ``BlockSpec``
directly.

Shimmed surface:

  =====================  ==========================  =======================
  name                   jax >= 0.6 spelling         jax 0.4.37 spelling
  =====================  ==========================  =======================
  ``shard_map``          ``jax.shard_map``           ``jax.experimental.
                                                     shard_map.shard_map``
  ``axis_size(name)``    ``lax.axis_size(name)``     ``lax.psum(1, name)``
                                                     (constant-folded to a
                                                     Python int)
  ``pvary(x, names)``    ``lax.pcast(x, names,       identity (0.4.x rep
                         to="varying")``             tracking degrades loop
                                                     carries automatically)
  ``element_block_spec`` ``pl.BlockSpec`` with       ``pl.BlockSpec(...,
                         ``pl.Element`` dims         indexing_mode=
                                                     pl.Unblocked())``
  AOT persistence        ``jax.experimental.         same, or ``jax.export``
                         serialize_executable``      StableHLO when executable
                                                     (de)serialization is
                                                     missing, or ``None``
  =====================  ==========================  =======================

The AOT tier feeds the persistent design store
(:mod:`repro.runtime.store`): compiled executables are serialized with
the best mechanism the installed jax offers, in order of preference

  1. ``jax.experimental.serialize_executable`` — the whole XLA
     executable; deserialization skips tracing *and* compilation
     (milliseconds to first result);
  2. ``jax.export`` — portable StableHLO; deserialization skips Python
     tracing but still pays XLA compilation on first call;
  3. neither — the store persists rankings only and warm starts
     recompile from the persisted ranking (still skipping autotune).

No module outside this file may import either API directly
(``scripts/check_compat_imports.py`` enforces it).
"""
from __future__ import annotations

import pickle
import re
from typing import Callable, Sequence

import jax
from jax import lax
from jax.experimental import pallas as pl


def _parse_version(v: str) -> tuple[int, ...]:
    return tuple(int(x) for x in re.findall(r"\d+", v)[:3])


JAX_VERSION: tuple[int, ...] = _parse_version(jax.__version__)

# Oldest toolchain the repo promises to run on (the pinned CI version).
MIN_SUPPORTED_JAX: tuple[int, ...] = (0, 4, 37)


# --------------------------------------------------------------------------
# shard_map: jax.shard_map (>=0.6) vs jax.experimental.shard_map (0.4.x)
# --------------------------------------------------------------------------

try:
    from jax import shard_map as _shard_map  # type: ignore[attr-defined]
except ImportError:  # jax 0.4.x / 0.5.x
    from jax.experimental.shard_map import shard_map as _shard_map

_SHARD_MAP_KWARGS = None


def shard_map(f=None, **kwargs):
    """`shard_map` with the replication-check flag name normalised.

    Newer jax renamed ``check_rep`` to ``check_vma``; callers may pass
    either and the one the installed jax understands is forwarded.
    """
    global _SHARD_MAP_KWARGS
    if _SHARD_MAP_KWARGS is None:
        import inspect

        _SHARD_MAP_KWARGS = frozenset(
            inspect.signature(_shard_map).parameters
        )
    check = kwargs.pop("check_vma", kwargs.pop("check_rep", None))
    if check is not None:
        name = "check_vma" if "check_vma" in _SHARD_MAP_KWARGS else "check_rep"
        kwargs[name] = check
    if f is None:
        return lambda g: _shard_map(g, **kwargs)
    return _shard_map(f, **kwargs)


# --------------------------------------------------------------------------
# axis_size: lax.axis_size appeared after 0.4.37
# --------------------------------------------------------------------------

if hasattr(lax, "axis_size"):

    def axis_size(axis_name: str) -> int:
        """Size of a mapped mesh axis, as a concrete Python int."""
        return lax.axis_size(axis_name)

else:

    def axis_size(axis_name: str) -> int:
        """Size of a mapped mesh axis, as a concrete Python int.

        ``psum`` of a non-tracer constant is folded to ``constant *
        axis_size`` at trace time, so this returns a plain int usable in
        Python control flow (e.g. building ppermute tables).
        """
        return lax.psum(1, axis_name)


# --------------------------------------------------------------------------
# pvary: mark a value as device-varying for shard_map replication typing
# --------------------------------------------------------------------------

if hasattr(lax, "pcast"):

    def pvary(x, axis_names: Sequence[str]):
        """Cast ``x`` to device-varying along ``axis_names``."""
        return lax.pcast(x, tuple(axis_names), to="varying")

elif hasattr(lax, "pvary"):

    def pvary(x, axis_names: Sequence[str]):
        return lax.pvary(x, tuple(axis_names))

else:

    def pvary(x, axis_names: Sequence[str]):
        """No-op on jax 0.4.x: shard_map's replication checker computes a
        fixpoint over loop carries there, so pre-casting is unnecessary."""
        return x


# --------------------------------------------------------------------------
# AOT compile / serialize / deserialize (persistent design store)
# --------------------------------------------------------------------------


def _detect_serialize_executable():
    try:
        from jax.experimental import serialize_executable as se
    except ImportError:
        return None
    if hasattr(se, "serialize") and hasattr(se, "deserialize_and_load"):
        return se
    return None


def _detect_export():
    try:
        from jax import export as ex  # jax >= 0.4.30 spelling
    except ImportError:
        try:
            from jax.experimental import export as ex  # older spelling
        except ImportError:
            return None
    if hasattr(ex, "deserialize"):
        return ex
    return None


_SERIALIZE_EXECUTABLE = _detect_serialize_executable()
_EXPORT = _detect_export()

#: The executable-serialization tier the installed jax supports:
#: "executable" (whole XLA executable, ms warm start), "stablehlo"
#: (portable export, warm start still compiles), or None (rankings-only
#: persistence; warm starts recompile but skip autotune).
AOT_KIND: str | None = (
    "executable" if _SERIALIZE_EXECUTABLE is not None
    else "stablehlo" if _EXPORT is not None
    else None
)


def aot_compile(jitted, sample_args):
    """Explicit AOT compile of a jitted callable for concrete/abstract args.

    ``jit(f).lower(args).compile()`` is version-stable API; funnelled here
    anyway so the design store's whole AOT surface lives behind compat.
    The returned executable is also what :func:`aot_serialize` persists.
    """
    return jitted.lower(sample_args).compile()


def aot_serialize(compiled=None, jitted=None, sample_args=None):
    """Serialize a compiled design to bytes; returns ``(kind, blob)``.

    Pass the ``compiled`` executable from :func:`aot_compile` (preferred;
    used verbatim by the "executable" tier) and/or the ``jitted``
    callable + ``sample_args`` (the "stablehlo" tier re-exports from
    them).  Returns ``(None, None)`` when the installed jax supports
    neither — callers must then persist rankings only.
    """
    if _SERIALIZE_EXECUTABLE is not None and compiled is not None:
        payload, in_tree, out_tree = _SERIALIZE_EXECUTABLE.serialize(compiled)
        return "executable", pickle.dumps((payload, in_tree, out_tree))
    if _EXPORT is not None and jitted is not None and sample_args is not None:
        exported = _EXPORT.export(jitted)(sample_args)
        return "stablehlo", exported.serialize()
    return None, None


def aot_deserialize(kind: str, blob: bytes):
    """Rehydrate a persisted design into a callable executable.

    ``kind`` must match what :func:`aot_serialize` returned when the blob
    was written.  Raises ``ValueError`` when the installed jax cannot
    load that kind (e.g. the store was written by a jax with executable
    serialization and this one lacks it) — callers treat that as a store
    miss and recompile from the persisted ranking.
    """
    if kind == "executable":
        if _SERIALIZE_EXECUTABLE is None:
            raise ValueError(
                "this jax cannot deserialize persisted XLA executables"
            )
        payload, in_tree, out_tree = pickle.loads(blob)
        return _SERIALIZE_EXECUTABLE.deserialize_and_load(
            payload, in_tree, out_tree
        )
    if kind == "stablehlo":
        if _EXPORT is None:
            raise ValueError(
                "this jax cannot deserialize persisted StableHLO exports"
            )
        exported = _EXPORT.deserialize(blob)
        return jax.jit(exported.call)
    raise ValueError(f"unknown persisted-executable kind {kind!r}")


# --------------------------------------------------------------------------
# Non-blocking completion polling (continuous-batching reap path)
# --------------------------------------------------------------------------


def is_ready(x) -> bool:
    """Non-blocking poll: has a dispatched device value finished computing?

    True when every leaf of ``x`` reports complete — a following
    ``jax.block_until_ready`` / runner ``finalize`` returns without
    waiting.  Newer jax exposes ``jax.Array.is_ready()``; leaves without
    it (host arrays, older jax) are reported ready, which degrades a
    non-blocking reap into a blocking one — still correct, just less
    overlapped.  This is version-sensitive surface, so it lives here
    (scripts/check_compat_imports.py policy) rather than in the
    scheduler that polls it.
    """
    for leaf in jax.tree_util.tree_leaves(x):
        ready = getattr(leaf, "is_ready", None)
        if callable(ready):
            try:
                if not ready():
                    return False
            except Exception:
                continue   # polling is advisory: fall back to "ready"
    return True


# --------------------------------------------------------------------------
# Element-indexed Pallas BlockSpec (overlapping input blocks)
# --------------------------------------------------------------------------


def element_block_spec(
    block_shape: Sequence[int], index_map: Callable[..., tuple]
) -> pl.BlockSpec:
    """A ``BlockSpec`` whose ``index_map`` returns **element** offsets.

    Blocked (default) indexing places block ``i`` at ``index_map(i) *
    block_shape`` — it cannot express overlapping input windows (block
    stride != block size), which the fused stencil kernel needs for its
    halo rows.  Newer jax spells this ``pl.Element`` per dimension; jax
    0.4.37 spells it ``indexing_mode=pl.Unblocked()``.
    """
    shape = tuple(int(n) for n in block_shape)
    if hasattr(pl, "Element"):
        return pl.BlockSpec(
            tuple(pl.Element(n) for n in shape), index_map
        )
    return pl.BlockSpec(shape, index_map, indexing_mode=pl.Unblocked())
