"""Deterministic, shardable synthetic LM data pipeline.

Batches are a pure function of (seed, step), so the pipeline is:
  * checkpoint-free: resuming at step N reproduces the exact stream,
  * elastic: a different mesh/batch-sharding regenerates identical data,
  * host-parallel: each data shard is computed independently (in a real
    deployment this is per-host; here it is per-device-shard placement).

Token stream: a tiny LCG-mixed integer hash over (seed, step, position)
with a Zipf-ish modulus fold so losses are learnable but non-trivial.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P


def _hash_tokens(seed: int, step: int, batch: int, seq: int, vocab: int):
    b = np.arange(batch, dtype=np.uint64)[:, None]
    s = np.arange(seq, dtype=np.uint64)[None, :]
    with np.errstate(over="ignore"):  # uint64 wraparound is the hash mix
        x = (np.uint64(seed) * np.uint64(0x9E3779B97F4A7C15)
             + np.uint64(step) * np.uint64(0xBF58476D1CE4E5B9)
             + b * np.uint64(0x94D049BB133111EB) + s * np.uint64(2654435761))
        x ^= x >> np.uint64(31)
        x *= np.uint64(0xD6E8FEB86659FD93)
        x ^= x >> np.uint64(27)
    # fold to a skewed distribution: square-root-ish compaction
    u = (x % np.uint64(1 << 30)).astype(np.float64) / float(1 << 30)
    toks = (u * u * (vocab - 1)).astype(np.int32)
    return toks


@dataclasses.dataclass
class SyntheticLMData:
    vocab: int
    batch: int
    seq: int
    seed: int = 0
    frontend_tokens: int = 0
    frontend_dim: int = 0
    mesh: object = None
    batch_spec: P = P()

    def batch_at(self, step: int) -> dict:
        toks = _hash_tokens(self.seed, step, self.batch, self.seq, self.vocab)
        out = {
            "tokens": jnp.asarray(toks),
            "labels": jnp.asarray(np.roll(toks, -1, axis=1)),
        }
        if self.frontend_tokens:
            rng = np.random.default_rng((self.seed << 20) ^ step)
            out["frontend_embeds"] = jnp.asarray(
                rng.standard_normal(
                    (self.batch, self.frontend_tokens, self.frontend_dim)
                ).astype(np.float32) * 0.05)
        if self.mesh is not None:
            out = {
                k: jax.device_put(v, NamedSharding(
                    self.mesh,
                    P(self.batch_spec) if v.ndim == 2 else
                    P(self.batch_spec, None, None)))
                for k, v in out.items()
            }
        return out

    def __iter__(self):
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


def make_batch_specs(cfg, batch: int, seq: int, batch_axes=("pod", "data")):
    """ShapeDtypeStructs + PartitionSpecs for every model input at a shape."""
    specs = {
        "tokens": (jax.ShapeDtypeStruct((batch, seq), jnp.int32),
                   P(batch_axes, None)),
        "labels": (jax.ShapeDtypeStruct((batch, seq), jnp.int32),
                   P(batch_axes, None)),
    }
    if cfg.frontend:
        n = cfg.n_frontend_tokens or max(seq // 4, 8)
        specs["frontend_embeds"] = (
            jax.ShapeDtypeStruct((batch, n, cfg.frontend_dim), jnp.float32),
            P(batch_axes, None, None),
        )
    return specs
