"""mamba2-130m [ssm] — SSD (state-space duality), attention-free.
[arXiv:2405.21060; unverified]

d_state=128, headdim=64, expand=2 -> d_inner=1536, 24 heads.  O(1) decode
state (no KV cache): runs long_500k trivially (sub-quadratic).
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="mamba2_130m", family="ssm",
    n_layers=24, d_model=768, n_heads=24, n_kv_heads=24, d_head=64,
    d_ff=0, vocab=50280, pattern=("ssm",),
    ssm_state=128, ssm_headdim=64, ssm_expand=2,
    tie_embeddings=True, sub_quadratic=True,
))
