"""The paper's stencil benchmark suite, written in the SASA DSL (Section 5.1).

Eight kernels: JACOBI2D, JACOBI3D, BLUR, SEIDEL2D, DILATE, HOTSPOT, HEAT3D,
SOBEL2D — plus the two-loop BLUR-JACOBI2D fusion example from Listing 4,
and three non-zero-boundary variants exercising the boundary-condition
machinery end to end (docs/DESIGN.md §Boundary semantics): a periodic
(torus) HEAT3D and replicate-edge BLUR/SOBEL image filters.

Input sizes follow the paper: 2D ∈ {256x256, 720x1024, 9720x1024, 4096x4096},
3D ∈ {256x16x16, 720x32x32, 9720x32x32, 4096x64x64}.  Iterations sweep
1..64 in powers of two.
"""
from __future__ import annotations

from repro.core import dsl
from repro.core.spec import StencilSpec

SIZES_2D = [(256, 256), (720, 1024), (9720, 1024), (4096, 4096)]
SIZES_3D = [(256, 16, 16), (720, 32, 32), (9720, 32, 32), (4096, 64, 64)]
ITERATIONS = [1, 2, 4, 8, 16, 32, 64]


def _fmt_shape(shape):
    return ", ".join(str(s) for s in shape)


def jacobi2d(shape=(9720, 1024), iterations=4) -> StencilSpec:
    """5-point 2D Jacobi (paper Listing 2)."""
    return dsl.parse(f"""
kernel: JACOBI2D
iteration: {iterations}
input float: in_1({_fmt_shape(shape)})
output float: out_1(0,0) = (in_1(0,1) + in_1(1,0) + in_1(0,0) + in_1(0,-1) + in_1(-1,0)) / 5
""")


def jacobi3d(shape=(9720, 32, 32), iterations=4) -> StencilSpec:
    """7-point 3D Jacobi."""
    return dsl.parse(f"""
kernel: JACOBI3D
iteration: {iterations}
input float: in_1({_fmt_shape(shape)})
output float: out_1(0,0,0) = (in_1(0,0,0) + in_1(0,0,1) + in_1(0,0,-1)
    + in_1(0,1,0) + in_1(0,-1,0) + in_1(1,0,0) + in_1(-1,0,0)) / 7
""")


def blur(shape=(9720, 1024), iterations=4) -> StencilSpec:
    """9-point 2D box blur."""
    return dsl.parse(f"""
kernel: BLUR
iteration: {iterations}
input float: in_1({_fmt_shape(shape)})
output float: out_1(0,0) = (in_1(-1,-1) + in_1(-1,0) + in_1(-1,1)
    + in_1(0,-1) + in_1(0,0) + in_1(0,1)
    + in_1(1,-1) + in_1(1,0) + in_1(1,1)) / 9
""")


def seidel2d(shape=(9720, 1024), iterations=4) -> StencilSpec:
    """9-point 2D Seidel-style smoother (Jacobi-ordered as in SODA)."""
    return dsl.parse(f"""
kernel: SEIDEL2D
iteration: {iterations}
input float: in_1({_fmt_shape(shape)})
output float: out_1(0,0) = ((in_1(-1,-1) + in_1(-1,0) + in_1(-1,1))
    + (in_1(0,-1) + in_1(0,0) + in_1(0,1))
    + (in_1(1,-1) + in_1(1,0) + in_1(1,1))) / 9
""")


def dilate(shape=(9720, 1024), iterations=4) -> StencilSpec:
    """13-point morphological dilation (Rodinia leukocyte tracking).

    Pure compare-select logic — no multiplies, so on the FPGA it uses no
    DSPs (paper Fig. 8); on the TPU it runs on the VPU only (no MXU).
    """
    return dsl.parse(f"""
kernel: DILATE
iteration: {iterations}
input float: in_1({_fmt_shape(shape)})
output float: out_1(0,0) = max(in_1(0,0),
    max(in_1(-1,-1), in_1(-1,0), in_1(-1,1)),
    max(in_1(0,-2), in_1(0,-1), in_1(0,1), in_1(0,2)),
    max(in_1(1,-1), in_1(1,0), in_1(1,1)),
    max(in_1(-2,0), in_1(2,0)))
""")


def hotspot(shape=(9720, 1024), iterations=4) -> StencilSpec:
    """Rodinia HOTSPOT: two inputs (power, temperature), one output.

    ``in_2`` (temperature) is the iterated array; ``in_1`` (power) is
    constant across iterations (paper Listing 3).
    """
    return dsl.parse(f"""
kernel: HOTSPOT
iteration: {iterations}
input float: in_1({_fmt_shape(shape)})
input float: in_2({_fmt_shape(shape)})
iterate: in_2
output float: out_1(0,0) = in_2(0,0) + 1.296 * (
    (in_2(-1,0) + in_2(1,0) - in_2(0,0) - in_2(0,0)) * 0.949219
    + in_1(0,0)
    + (in_2(0,-1) + in_2(0,1) - in_2(0,0) - in_2(0,0)) * 0.010535
    + (80 - in_2(0,0)) * 0.00000514403)
""")


def heat3d(shape=(9720, 32, 32), iterations=4) -> StencilSpec:
    """7-point 3D heat diffusion."""
    return dsl.parse(f"""
kernel: HEAT3D
iteration: {iterations}
input float: in_1({_fmt_shape(shape)})
output float: out_1(0,0,0) = 0.125 * (in_1(1,0,0) - 2 * in_1(0,0,0) + in_1(-1,0,0))
    + 0.125 * (in_1(0,1,0) - 2 * in_1(0,0,0) + in_1(0,-1,0))
    + 0.125 * (in_1(0,0,1) - 2 * in_1(0,0,0) + in_1(0,0,-1))
    + in_1(0,0,0)
""")


def sobel2d(shape=(9720, 1024), iterations=4) -> StencilSpec:
    """9-point Sobel edge filter (|Gx| + |Gy| approximation)."""
    return dsl.parse(f"""
kernel: SOBEL2D
iteration: {iterations}
input float: in_1({_fmt_shape(shape)})
output float: out_1(0,0) = abs(in_1(-1,-1) + 2 * in_1(0,-1) + in_1(1,-1)
        - in_1(-1,1) - 2 * in_1(0,1) - in_1(1,1))
    + abs(in_1(-1,-1) + 2 * in_1(-1,0) + in_1(-1,1)
        - in_1(1,-1) - 2 * in_1(1,0) - in_1(1,1))
""")


def blur_jacobi2d(shape=(9720, 1024), iterations=4) -> StencilSpec:
    """Two fused stencil loops via a ``local`` stage (paper Listing 4)."""
    return dsl.parse(f"""
kernel: BLUR-JACOBI2D
iteration: {iterations}
input float: in({_fmt_shape(shape)})
local float: temp(0,0) = (in(-1,0) + in(-1,1) + in(-1,2) + in(0,0) + in(0,1)
    + in(0,2) + in(1,0) + in(1,1) + in(1,2)) / 9
output float: out(0,0) = (temp(0,1) + temp(1,0) + temp(0,0) + temp(0,-1) + temp(-1,0)) / 5
""")


def heat3d_periodic(shape=(9720, 32, 32), iterations=4) -> StencilSpec:
    """7-point 3D heat diffusion on a torus (periodic boundary).

    The molecular-dynamics / spectral-solver setting: heat leaving one
    face re-enters the opposite one.  Exercises the wraparound ppermute
    halo exchange in the distribution layer and the wrap-filled host
    padding in the Pallas kernel.
    """
    return dsl.parse(f"""
kernel: HEAT3D-PERIODIC
iteration: {iterations}
boundary: periodic
input float: in_1({_fmt_shape(shape)})
output float: out_1(0,0,0) = 0.125 * (in_1(1,0,0) - 2 * in_1(0,0,0) + in_1(-1,0,0))
    + 0.125 * (in_1(0,1,0) - 2 * in_1(0,0,0) + in_1(0,-1,0))
    + 0.125 * (in_1(0,0,1) - 2 * in_1(0,0,0) + in_1(0,0,-1))
    + in_1(0,0,0)
""")


def blur_replicate(shape=(9720, 1024), iterations=4) -> StencilSpec:
    """9-point box blur with clamped (replicate) edges.

    The image-processing convention: edge pixels average a clamped
    neighbourhood instead of darkening toward the zero exterior.
    """
    return dsl.parse(f"""
kernel: BLUR-REPLICATE
iteration: {iterations}
boundary: replicate
input float: in_1({_fmt_shape(shape)})
output float: out_1(0,0) = (in_1(-1,-1) + in_1(-1,0) + in_1(-1,1)
    + in_1(0,-1) + in_1(0,0) + in_1(0,1)
    + in_1(1,-1) + in_1(1,0) + in_1(1,1)) / 9
""")


def sobel2d_replicate(shape=(9720, 1024), iterations=4) -> StencilSpec:
    """Sobel edge filter with clamped edges (no spurious border edges)."""
    return dsl.parse(f"""
kernel: SOBEL2D-REPLICATE
iteration: {iterations}
boundary: replicate
input float: in_1({_fmt_shape(shape)})
output float: out_1(0,0) = abs(in_1(-1,-1) + 2 * in_1(0,-1) + in_1(1,-1)
        - in_1(-1,1) - 2 * in_1(0,1) - in_1(1,1))
    + abs(in_1(-1,-1) + 2 * in_1(-1,0) + in_1(-1,1)
        - in_1(1,-1) - 2 * in_1(1,0) - in_1(1,1))
""")


BENCHMARKS = {
    "jacobi2d": jacobi2d,
    "jacobi3d": jacobi3d,
    "blur": blur,
    "seidel2d": seidel2d,
    "dilate": dilate,
    "hotspot": hotspot,
    "heat3d": heat3d,
    "sobel2d": sobel2d,
    "blur_jacobi2d": blur_jacobi2d,
    "heat3d_periodic": heat3d_periodic,
    "blur_replicate": blur_replicate,
    "sobel2d_replicate": sobel2d_replicate,
}

BENCHMARKS_2D = [
    "jacobi2d", "blur", "seidel2d", "dilate", "hotspot", "sobel2d",
    "blur_jacobi2d", "blur_replicate", "sobel2d_replicate",
]
BENCHMARKS_3D = ["jacobi3d", "heat3d", "heat3d_periodic"]


def get(name: str, shape=None, iterations: int = 4) -> StencilSpec:
    fn = BENCHMARKS[name.lower()]
    if shape is None:
        return fn(iterations=iterations)
    return fn(shape=shape, iterations=iterations)
