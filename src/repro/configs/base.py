"""Architecture configuration schema + registry.

One ``<arch>.py`` per assigned architecture registers an :class:`ArchConfig`
here via :func:`register`.  ``reduced()`` produces the CPU smoke-test
version of the same family (tiny widths/depths, same block structure).
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Optional

_REGISTRY: dict[str, "ArchConfig"] = {}

ARCH_IDS = [
    "granite_3_8b", "internlm2_1_8b", "yi_34b", "granite_3_2b",
    "seamless_m4t_medium", "recurrentgemma_2b", "internvl2_1b",
    "mamba2_130m", "llama4_maverick_400b_a17b", "qwen2_moe_a2_7b",
]


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    vocab: int
    # block structure: kinds cycled over layers ("attn","attn_moe","local",
    # "rec","ssm"); enc-dec uses enc_pattern for the encoder.
    pattern: tuple = ("attn",)
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    d_ff_expert: int = 0
    d_ff_shared: int = 0
    capacity_factor: float = 1.25
    expert_pad_to: int = 16     # pad expert dim to a multiple (EP over model)
    # --- SSM (mamba2) ---
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 128
    # --- hybrid / local attention ---
    window: int = 0
    lru_width: int = 0
    # --- encoder-decoder ---
    enc_layers: int = 0
    enc_pattern: tuple = ("enc",)
    # --- modality frontend (STUB: input_specs provides embeddings) ---
    frontend: Optional[str] = None      # "patch" | "frames"
    frontend_dim: int = 0
    n_frontend_tokens: int = 0
    # --- common knobs ---
    rope_theta: float = 10000.0
    rope_on_encoder: bool = False
    qkv_bias: bool = False
    tie_embeddings: bool = False
    act_dtype: str = "bfloat16"
    mlp: str = "swiglu"
    kv_block: int = 1024
    remat: str = "full"                 # none | dots | full
    scan_layers: bool = True
    sub_quadratic: bool = False         # eligible for long_500k
    optimizer: str = "adamw"            # adamw | adafactor
    microbatches: int = 1               # gradient-accumulation splits

    # ------------------------------------------------------------------
    @property
    def n_experts_padded(self) -> int:
        if not self.n_experts or self.expert_pad_to <= 1:
            return self.n_experts
        m = self.expert_pad_to
        return (self.n_experts + m - 1) // m * m

    @property
    def params_dense_estimate(self) -> float:
        """Rough total parameter count (for 6ND MODEL_FLOPS accounting)."""
        d, f, L_ = self.d_model, self.d_ff, self.n_layers
        attn = d * self.d_head * (self.n_heads * 2 + self.n_kv_heads * 2)
        mlp = 3 * d * f
        per_moe = (3 * self.d_ff_expert * d * self.n_experts
                   + 3 * d * self.d_ff_shared + d * self.n_experts)
        n_moe = sum(1 for i in range(L_)
                    if self.pattern[i % len(self.pattern)].endswith("_moe"))
        n_ssm = sum(1 for i in range(L_)
                    if self.pattern[i % len(self.pattern)] == "ssm")
        n_rec = sum(1 for i in range(L_)
                    if self.pattern[i % len(self.pattern)] == "rec")
        n_attn = L_ - n_ssm - n_rec
        di = self.ssm_expand * d
        ssm = d * (2 * di + 2 * self.ssm_state + di // max(self.ssm_headdim, 1)) + di * d
        w = self.lru_width or d
        rec = 2 * d * w + 2 * w * w + w * d + w * d  # in/gate/wa/wx/out
        total = (n_attn * attn + n_moe * per_moe
                 + (n_attn - n_moe) * mlp
                 + n_ssm * ssm + n_rec * (rec + 3 * d * self.d_ff)
                 + self.vocab * d * (1 if self.tie_embeddings else 2))
        return float(total)

    @property
    def params_active_estimate(self) -> float:
        """Active params per token (MoE: only top_k + shared experts)."""
        if not self.n_experts:
            return self.params_dense_estimate
        d = self.d_model
        per_moe_active = (3 * self.d_ff_expert * d * self.top_k
                          + 3 * d * self.d_ff_shared + d * self.n_experts)
        per_moe_total = (3 * self.d_ff_expert * d * self.n_experts
                         + 3 * d * self.d_ff_shared + d * self.n_experts)
        n_moe = sum(1 for i in range(self.n_layers)
                    if self.pattern[i % len(self.pattern)].endswith("_moe"))
        return (self.params_dense_estimate
                - n_moe * (per_moe_total - per_moe_active))

    def reduced(self) -> "ArchConfig":
        """Tiny same-family config for CPU smoke tests."""
        glen = len(self.pattern)
        n_layers = max(2 * glen, glen)  # at least two pattern groups... or one
        if n_layers > 6:
            n_layers = glen if glen >= 3 else 2 * glen
        return dataclasses.replace(
            self,
            name=self.name + "-reduced",
            n_layers=n_layers,
            d_model=64,
            n_heads=4,
            n_kv_heads=max(1, min(self.n_kv_heads, 2)),
            d_head=16,
            d_ff=128,
            vocab=256,
            n_experts=min(self.n_experts, 8) if self.n_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            capacity_factor=8.0,  # tiny smoke batches: avoid router drops
            expert_pad_to=1,
            d_ff_expert=64 if self.n_experts else 0,
            d_ff_shared=64 if self.n_shared_experts else 0,
            ssm_state=16 if self.ssm_state else 0,
            ssm_headdim=16 if self.ssm_state else 64,
            ssm_chunk=16,
            window=min(self.window, 16) if self.window else 0,
            lru_width=64 if self.lru_width else 0,
            enc_layers=min(self.enc_layers, 2),
            frontend_dim=32 if self.frontend else 0,
            n_frontend_tokens=8 if self.frontend else 0,
            kv_block=32,
            remat="none",
            act_dtype="float32",
        )


def register(cfg: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get(name: str) -> ArchConfig:
    key = name.replace("-", "_").replace(".", "_")
    if key not in _REGISTRY:
        importlib.import_module(f"repro.configs.{key}")
    return _REGISTRY[key]


def all_archs() -> list[str]:
    return list(ARCH_IDS)
