"""internvl2-1b [vlm] — InternViT + Qwen2-0.5B backbone. [arXiv:2404.16821; hf]

The vision tower is a STUB per the assignment: input_specs() provides 256
precomputed patch embeddings (frontend_dim=1024, InternViT hidden) that a
projection maps into the LM sequence (early fusion).
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="internvl2_1b", family="vlm",
    n_layers=24, d_model=896, n_heads=14, n_kv_heads=2, d_head=64,
    d_ff=4864, vocab=151655, pattern=("attn",), qkv_bias=True,
    frontend="patch", frontend_dim=1024, n_frontend_tokens=256,
    tie_embeddings=True,
))
