"""recurrentgemma-2b [hybrid] — RG-LRU + local attention, 1:2 ratio.
[arXiv:2402.19427; hf]

26 layers = 8 x (rec, rec, local) + 2 tail rec layers; local attention is
MQA (kv=1) with a 2048 sliding window — a 1-D sequence *stencil*, served
with a window-sized ring cache (sub-quadratic; runs long_500k).
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="recurrentgemma_2b", family="hybrid",
    n_layers=26, d_model=2560, n_heads=10, n_kv_heads=1, d_head=256,
    d_ff=7680, vocab=256000,
    pattern=("rec", "rec", "local"), window=2048, lru_width=2560,
    mlp="geglu", sub_quadratic=True, tie_embeddings=True,
))
