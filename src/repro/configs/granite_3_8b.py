"""granite-3-8b [dense] — GQA.  [hf:ibm-granite/granite-3.0-8b-base; hf]"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="granite_3_8b", family="dense",
    n_layers=40, d_model=4096, n_heads=32, n_kv_heads=8, d_head=128,
    d_ff=12800, vocab=49155, pattern=("attn",),
))
