"""llama4-maverick-400b-a17b [moe] — 128 routed experts top-1 + 1 shared,
MoE every other layer (interleaved, as the released model), early fusion.
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]

24 MoE layers x 128 x 3 x 5120 x 8192 ~= 386B routed params + dense
layers/attention/embeddings ~= 400B total, ~17B active.  Adafactor keeps
optimizer HBM within a v5e pod at 512-way sharding.
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="llama4_maverick_400b_a17b", family="moe",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, d_head=128,
    d_ff=8192, vocab=202048,
    pattern=("attn", "attn_moe"),
    n_experts=128, top_k=1, n_shared_experts=1,
    d_ff_expert=8192, d_ff_shared=8192,
    optimizer="adafactor",
    # 400B on a 256-chip v5e pod runs at the HBM edge: 4 gradient-
    # accumulation microbatches keep activation residency inside 16 GB
    microbatches=4,
))
