"""qwen2-moe-a2.7b [moe] — 60 routed experts top-4 + 4 shared (fused as one
5632-wide shared expert), every layer MoE.  [hf:Qwen/Qwen1.5-MoE-A2.7B; hf]
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="qwen2_moe_a2_7b", family="moe",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=16, d_head=128,
    d_ff=1408, vocab=151936,
    pattern=("attn_moe",), qkv_bias=True,
    n_experts=60, top_k=4, n_shared_experts=4,
    d_ff_expert=1408, d_ff_shared=5632,
))
