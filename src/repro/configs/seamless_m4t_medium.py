"""seamless-m4t-medium [audio] — enc-dec, multimodal.  [arXiv:2308.11596; hf]

The speech frontend is a STUB per the assignment: input_specs() provides
precomputed frame embeddings (frontend_dim=1024) that feed the 12-layer
encoder; the 12-layer decoder cross-attends to it.
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="seamless_m4t_medium", family="encdec",
    n_layers=12, d_model=1024, n_heads=16, n_kv_heads=16, d_head=64,
    d_ff=4096, vocab=256206,
    pattern=("xattn",), enc_layers=12, enc_pattern=("enc",),
    frontend="frames", frontend_dim=1024,
))
