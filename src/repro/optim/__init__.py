from repro.optim.optimizer import (
    Optimizer, adamw, adafactor, make_optimizer, cosine_schedule,
)

__all__ = [
    "Optimizer", "adamw", "adafactor", "make_optimizer", "cosine_schedule",
]
