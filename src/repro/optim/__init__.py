from repro.optim.optimizer import (
    Optimizer, adamw, adafactor, make_optimizer, cosine_schedule,
)
