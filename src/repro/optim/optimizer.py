"""Optimizers as pure pytree transforms (no external deps).

AdamW for the standard runs; Adafactor (factored second moments) for the
400B MoE where full Adam state would not fit a v5e pod even at 512-way
sharding.  Both support global-norm clipping and a warmup+cosine schedule,
and an optional bf16 gradient "compression" that halves DP all-reduce
bytes (applied before the moment update; moments stay fp32/factored).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp


def cosine_schedule(base_lr: float, warmup: int, total: int):
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / jnp.maximum(warmup, 1)
        frac = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0, 1)
        cos = base_lr * 0.5 * (1 + jnp.cos(jnp.pi * frac))
        return jnp.where(step < warmup, warm, cos)
    return lr


def _global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable
    update: Callable  # (grads, state, params, step) -> (new_params, new_state)
    name: str = "opt"


def adamw(lr: Callable | float, b1=0.9, b2=0.95, eps=1e-8,
          weight_decay=0.1, clip_norm: float | None = 1.0,
          compress_grads: bool = False) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        return {
            "m": jax.tree.map(jnp.zeros_like, params),
            "v": jax.tree.map(jnp.zeros_like, params),
        }

    def update(grads, state, params, step):
        if compress_grads:
            grads = jax.tree.map(
                lambda g: g.astype(jnp.bfloat16).astype(jnp.float32), grads)
        if clip_norm is not None:
            gn = _global_norm(grads)
            scale = jnp.minimum(1.0, clip_norm / jnp.maximum(gn, 1e-9))
            grads = jax.tree.map(lambda g: g * scale, grads)
        t = step.astype(jnp.float32) + 1.0
        lr_t = lr_fn(step)

        def upd(g, m, v, p):
            g = g.astype(jnp.float32)
            m_new = b1 * m + (1 - b1) * g
            v_new = b2 * v + (1 - b2) * g * g
            mh = m_new / (1 - b1 ** t)
            vh = v_new / (1 - b2 ** t)
            step_ = mh / (jnp.sqrt(vh) + eps) + weight_decay * p
            return p - lr_t * step_, m_new, v_new

        flat = jax.tree.map(upd, grads, state["m"], state["v"], params)
        new_params = jax.tree.map(lambda x: x[0], flat,
                                  is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree.map(lambda x: x[1], flat,
                             is_leaf=lambda x: isinstance(x, tuple))
        new_v = jax.tree.map(lambda x: x[2], flat,
                             is_leaf=lambda x: isinstance(x, tuple))
        return new_params, {"m": new_m, "v": new_v}

    return Optimizer(init, update, "adamw")


def adafactor(lr: Callable | float, eps=1e-30, clip_threshold=1.0,
              decay=0.8, weight_decay=0.0, min_dim_factored=128,
              clip_norm: float | None = 1.0) -> Optimizer:
    """Factored second moments for >=2-D params whose trailing dims are both
    >= min_dim_factored; tiny params keep full moments."""
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def factored(p):
        return p.ndim >= 2 and p.shape[-1] >= min_dim_factored and \
            p.shape[-2] >= min_dim_factored

    def init(params):
        def st(p):
            if factored(p):
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                        jnp.float32)}
            return {"v": jnp.zeros_like(p, dtype=jnp.float32)}
        return {"v": jax.tree.map(st, params,
                                  is_leaf=lambda x: not isinstance(x, (dict, list, tuple)))}

    def update(grads, state, params, step):
        if clip_norm is not None:
            gn = _global_norm(grads)
            scale = jnp.minimum(1.0, clip_norm / jnp.maximum(gn, 1e-9))
            grads = jax.tree.map(lambda g: g * scale, grads)
        t = step.astype(jnp.float32) + 1.0
        beta = 1.0 - t ** (-decay)
        lr_t = lr_fn(step)

        def upd(g, v, p):
            g = g.astype(jnp.float32)
            g2 = g * g + eps
            if "vr" in v:
                vr = beta * v["vr"] + (1 - beta) * g2.mean(-1)
                vc = beta * v["vc"] + (1 - beta) * g2.mean(-2)
                denom = (vr[..., None] / jnp.maximum(
                    vr.mean(-1, keepdims=True)[..., None], eps)) * vc[..., None, :]
                u = g / jnp.sqrt(jnp.maximum(denom, eps))
                new_v = {"vr": vr, "vc": vc}
            else:
                vf = beta * v["v"] + (1 - beta) * g2
                u = g / jnp.sqrt(jnp.maximum(vf, eps))
                new_v = {"v": vf}
            rms_u = jnp.sqrt(jnp.mean(u * u) + 1e-12)
            u = u / jnp.maximum(1.0, rms_u / clip_threshold)
            new_p = p - lr_t * (u + weight_decay * p)
            return new_p, new_v

        leaves_g, treedef = jax.tree.flatten(grads)
        leaves_v = treedef.flatten_up_to(state["v"])
        leaves_p = jax.tree.leaves(params)
        out = [upd(g, v, p) for g, v, p in zip(leaves_g, leaves_v, leaves_p)]
        new_params = jax.tree.unflatten(treedef, [o[0] for o in out])
        new_v = jax.tree.unflatten(treedef, [o[1] for o in out])
        return new_params, {"v": new_v}

    return Optimizer(init, update, "adafactor")


def make_optimizer(name: str, lr=3e-4, total_steps=10_000, warmup=200,
                   **kw) -> Optimizer:
    sched = cosine_schedule(lr, warmup, total_steps)
    if name == "adamw":
        return adamw(sched, **kw)
    if name == "adafactor":
        return adafactor(sched, **kw)
    raise ValueError(name)
