from repro.roofline.analysis import (
    collective_bytes_from_hlo, roofline_from_compiled, RooflineReport,
    V5E_PEAK_BF16, V5E_HBM_BW, V5E_ICI_BW,
)

__all__ = [
    "collective_bytes_from_hlo", "roofline_from_compiled", "RooflineReport",
    "V5E_PEAK_BF16", "V5E_HBM_BW", "V5E_ICI_BW",
]
