"""Roofline terms from a compiled (dry-run) executable.

  compute term    = HLO_FLOPs / (chips * peak_FLOP/s)
  memory term     = HLO_bytes / (chips * HBM_bw)
  collective term = collective_bytes / (chips * link_bw)

``compiled.cost_analysis()`` supplies FLOPs / bytes.  Collective bytes are
NOT in cost_analysis: we parse the post-SPMD optimized HLO text and sum
the operand sizes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute.  The partitioned module's shapes are
per-device, so parsed totals are per-device values; dividing cost_analysis
totals by `chips` puts all three terms in the same per-device units.

Hardware constants (TPU v5e, per assignment):
  197 TFLOP/s bf16 / chip; 819 GB/s HBM; ~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import re


V5E_PEAK_BF16 = 197e12
V5E_HBM_BW = 819e9
V5E_ICI_BW = 50e9

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?(%?[\w.\-]+)\s*=\s*(.+)$")
_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)


def _shape_bytes(type_str: str) -> int:
    """Bytes of an HLO type string; handles tuples by summing elements."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


_COMP_START_RE = re.compile(r"^\s*(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*->.*\{\s*$")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALL_RE = re.compile(r"(?:calls|to_apply|condition|body)=%?([\w.\-]+)")
_OP_RE = re.compile(r"=\s*[\w\[\],{}/*\s]+?\s([a-z][a-z0-9\-]*)\(")


def parse_hlo_module(hlo_text: str):
    """Split an HLO module into computations with instruction lines.

    Returns (computations: {name: [line, ...]}, entry_name).
    """
    comps: dict[str, list[str]] = {}
    entry = None
    cur = None
    for line in hlo_text.splitlines():
        m = _COMP_START_RE.match(line)
        if m and line.rstrip().endswith("{"):
            cur = m.group(1)
            comps[cur] = []
            if line.lstrip().startswith("ENTRY"):
                entry = cur
            continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is not None and "=" in line:
            comps[cur].append(line.strip())
    return comps, entry


def _instr_shapes(comps) -> dict[str, int]:
    shapes = {}
    for lines in comps.values():
        for line in lines:
            m = _DEF_RE.match(line)
            if not m:
                continue
            name = m.group(1).lstrip("%")
            shapes[name] = _shape_bytes(m.group(2).split("(", 1)[0])
    return shapes


def _dot_flops(line: str, shapes: dict[str, int],
               dtype_numel: dict[str, int]) -> float:
    """FLOPs of one dot: 2 * numel(out) * prod(contracting dims of lhs)."""
    m = _DEF_RE.match(line)
    if not m:
        return 0.0
    rhs = m.group(2)
    out_type = rhs.split("(", 1)[0]
    out_numel = _shape_numel(out_type)
    args = rhs.split("(", 1)[1].split(")")[0]
    operand_names = re.findall(r"%?([\w.\-]+)", args)
    lhs = operand_names[0] if operand_names else None
    cdims = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", rhs)
    if lhs is None or lhs not in dtype_numel or cdims is None:
        return 2.0 * out_numel  # fallback: at least the output writes
    lhs_dims = dtype_numel[lhs]
    k = 1
    for d in cdims.group(1).split(","):
        if d and int(d) < len(lhs_dims):
            k *= lhs_dims[int(d)]
    return 2.0 * out_numel * k


def _shape_numel(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        if m.group(1) not in _DTYPE_BYTES:
            continue
        n = 1
        if m.group(2):
            for d in m.group(2).split(","):
                if d:
                    n *= int(d)
        total += n
    return total


def _shape_dims(type_str: str):
    m = _SHAPE_RE.search(type_str)
    if not m or m.group(1) not in _DTYPE_BYTES:
        return ()
    return tuple(int(d) for d in m.group(2).split(",") if d)


def analyze_hlo(hlo_text: str) -> dict:
    """Trip-count-aware per-device totals from a partitioned HLO module.

    XLA's cost_analysis counts while (lax.scan) bodies ONCE; production
    models scan over layers, so everything inside the layer loop would be
    undercounted by n_layers.  Every while op carries
    backend_config known_trip_count — we build the computation call graph
    (while: x trip_count; call/fusion/reduce: x 1), propagate multiplicity
    from the entry, and scale dot FLOPs, instruction bytes, and collective
    operand bytes by their computation's multiplicity.
    """
    comps, entry = parse_hlo_module(hlo_text)
    shapes = _instr_shapes(comps)
    # per-instruction dims (for dot contraction sizes)
    dims: dict[str, tuple] = {}
    for lines in comps.values():
        for line in lines:
            m = _DEF_RE.match(line)
            if m:
                dims[m.group(1).lstrip("%")] = _shape_dims(
                    m.group(2).split("(", 1)[0])

    # ---- call graph with weights ----
    edges: dict[str, list[tuple[str, float]]] = {c: [] for c in comps}
    fusion_bodies: set[str] = set()
    for cname, lines in comps.items():
        for line in lines:
            if " while(" in line:
                tm = _TRIP_RE.search(line)
                trip = float(tm.group(1)) if tm else 1.0
                bm = re.search(r"body=%?([\w.\-]+)", line)
                cm = re.search(r"condition=%?([\w.\-]+)", line)
                if bm and bm.group(1) in comps:
                    edges[cname].append((bm.group(1), trip))
                if cm and cm.group(1) in comps:
                    edges[cname].append((cm.group(1), trip + 1))
            else:
                for mm in re.finditer(r"(?:calls|to_apply)=%?([\w.\-]+)",
                                      line):
                    callee = mm.group(1)
                    if callee in comps:
                        edges[cname].append((callee, 1.0))
                        fusion_bodies.add(callee)

    # propagate multiplicity from entry (DAG: converges in depth rounds)
    mult: dict[str, float] = {c: 0.0 for c in comps}
    if entry:
        mult[entry] = 1.0
    for _ in range(len(comps)):
        new = {c: 0.0 for c in comps}
        if entry:
            new[entry] = 1.0
        for c in comps:
            if mult[c] <= 0.0:
                continue
            for callee, w in edges[c]:
                new[callee] += mult[c] * w
        if new == mult:
            break
        mult = new

    flops = 0.0
    bytes_accessed = 0.0
    per_kind = {k: 0.0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    skip_ops = {"parameter", "constant", "tuple", "get-tuple-element",
                "bitcast", "after-all", "partition-id", "replica-id"}
    for cname, lines in comps.items():
        m_c = mult.get(cname, 0.0)
        if m_c == 0.0:
            continue
        in_fusion = cname in fusion_bodies
        for line in lines:
            dm = _DEF_RE.match(line)
            if not dm:
                continue
            rhs = dm.group(2)
            opm = _OP_RE.search(line)
            op = opm.group(1) if opm else ""
            if op in ("dot", "convolution"):
                flops += m_c * _dot_flops(line, shapes, dims)
            kind = next((k for k in _COLLECTIVES
                         if op == k or op.startswith(k + "-")), None)
            if kind is not None:
                counts[kind] += int(m_c)
                args = rhs.split("(", 1)[1].split(")")[0]
                got = sum(shapes.get(on, 0) for on in
                          re.findall(r"%?([\w.\-]+)", args))
                if got == 0:
                    got = _shape_bytes(rhs.split("(", 1)[0])
                per_kind[kind] += m_c * got
            if in_fusion or not op or op in skip_ops:
                continue
            # byte accounting: operand + output bytes per materialised op
            # (fusion interiors are skipped; the fusion op itself counts)
            out_b = _shape_bytes(rhs.split("(", 1)[0])
            args = rhs.split("(", 1)[1].split(")")[0] if "(" in rhs else ""
            op_bytes = [shapes.get(on, 0) for on in
                        re.findall(r"%([\w.\-]+)", args)]
            in_b = sum(op_bytes)
            name = dm.group(1)
            if "dynamic-update-slice" in name or op == "dynamic-update-slice":
                # in-place DUS: traffic = read update + write region, NOT
                # the whole aliased buffer (charging it inflates loop-
                # carried stacking by the buffer/slice ratio)
                update = in_b - max(op_bytes, default=0)
                bytes_accessed += m_c * 2 * update
            elif "dynamic-slice" in name or op == "dynamic-slice":
                bytes_accessed += m_c * 2 * out_b
            else:
                bytes_accessed += m_c * (out_b + in_b)

    per_kind["total"] = sum(per_kind[k] for k in _COLLECTIVES)
    return {
        "flops": flops,
        "bytes_accessed": bytes_accessed,
        "collectives": per_kind,
        "collective_counts": counts,
        "multiplicities": {c: m for c, m in mult.items() if m > 1.0},
    }


def collective_bytes_from_hlo(hlo_text: str) -> dict:
    """Sum operand bytes per collective kind over the partitioned module.

    Operand sizes are looked up from each instruction's definition site;
    for ops whose operands are constants/parameters inline we fall back to
    the op's own output bytes (equal for all-reduce/permute; a lower bound
    for all-gather).
    """
    shapes: dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if not m:
            continue
        name = m.group(1).lstrip("%")
        rhs = m.group(2)
        # the type annotation is the first shape-looking token on the rhs
        tm = _SHAPE_RE.search(rhs.split("(", 1)[0])
        if tm is not None or "(" in rhs:
            shapes[name] = _shape_bytes(rhs.split("(", 1)[0])

    per_kind = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = _DEF_RE.match(stripped)
        if not m:
            continue
        rhs = m.group(2)
        opm = re.search(r"\b([a-z0-9\-]+)\(", rhs)
        if not opm:
            continue
        op = opm.group(1)
        kind = next((k for k in _COLLECTIVES
                     if op == k or op.startswith(k + "-")), None)
        if kind is None:
            continue
        counts[kind] += 1
        args_str = rhs.split("(", 1)[1]
        operand_names = re.findall(r"%?([\w.\-]+)", args_str.split(")")[0])
        got = 0
        for on in operand_names:
            if on in shapes:
                got += shapes[on]
        if got == 0:
            got = _shape_bytes(rhs.split("(", 1)[0])
        per_kind[kind] += got
    per_kind["total"] = sum(per_kind[k] for k in _COLLECTIVES)
    per_kind["counts"] = counts
    return per_kind


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float                # whole-job FLOPs (cost_analysis * chips?)
    hlo_bytes: float
    collective_bytes_per_chip: float
    collective_detail: dict
    compute_term: float
    memory_term: float
    collective_term: float
    model_flops: float              # 6*N*D (active params) per step
    memory_per_chip: dict
    fits: bool

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.compute_term, "memory": self.memory_term,
                 "collective": self.collective_term}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        return self.model_flops / self.hlo_flops if self.hlo_flops else 0.0

    @property
    def roofline_fraction(self) -> float:
        """How close the dominant term is to being the *only* cost: the
        achievable fraction of the compute roofline if perfectly
        overlapped = compute_term / max(all terms)."""
        worst = max(self.compute_term, self.memory_term,
                    self.collective_term)
        return self.compute_term / worst if worst else 0.0

    def to_dict(self):
        d = dataclasses.asdict(self)
        d["bottleneck"] = self.bottleneck
        d["useful_flops_ratio"] = self.useful_flops_ratio
        d["roofline_fraction"] = self.roofline_fraction
        return d


def roofline_from_compiled(compiled, *, arch: str, shape: str,
                           mesh_desc: str, chips: int, model_flops: float,
                           hbm_limit: float = 16 * 2**30) -> RooflineReport:
    # Trip-count-aware analysis of the partitioned module (XLA's own
    # cost_analysis counts scan bodies once — useless for layer-scanned
    # production programs).  All analyzer numbers are per-device.
    an = analyze_hlo(compiled.as_text())
    hlo_flops_total = an["flops"] * chips
    hlo_bytes_total = an["bytes_accessed"] * chips
    coll = dict(an["collectives"])
    coll["counts"] = an["collective_counts"]
    mem = compiled.memory_analysis()
    mem_per_chip = {
        "arguments": int(getattr(mem, "argument_size_in_bytes", 0)),
        "outputs": int(getattr(mem, "output_size_in_bytes", 0)),
        "temps": int(getattr(mem, "temp_size_in_bytes", 0)),
        "peak": int(getattr(mem, "peak_memory_in_bytes", 0) or 0),
    }
    # arguments are donated into outputs for train steps; peak residency is
    # max(args, outputs) + temps as a conservative bound
    resident = max(mem_per_chip["arguments"], mem_per_chip["outputs"]) \
        + mem_per_chip["temps"]
    return RooflineReport(
        arch=arch, shape=shape, mesh=mesh_desc, chips=chips,
        hlo_flops=hlo_flops_total, hlo_bytes=hlo_bytes_total,
        collective_bytes_per_chip=float(coll["total"]),
        collective_detail=coll,
        compute_term=hlo_flops_total / (chips * V5E_PEAK_BF16),
        memory_term=hlo_bytes_total / (chips * V5E_HBM_BW),
        collective_term=coll["total"] / V5E_ICI_BW,
        model_flops=model_flops,
        memory_per_chip=mem_per_chip,
        fits=resident <= hbm_limit,
    )
