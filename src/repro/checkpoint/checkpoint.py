"""Checkpoint / restore with atomic commits, async writes, and elastic
re-sharding.

Layout: <dir>/step_<N>/  one ``.npy`` per flattened pytree leaf (keypath-
encoded filename) + ``manifest.json`` (treedef + dtypes + step).  Writes go
to ``step_<N>.tmp`` and are renamed only after fsync — a preempted writer
can never corrupt the latest checkpoint (restart-safety).

Elastic scaling: arrays are stored unsharded; ``restore_checkpoint``
accepts a (mesh, shardings) pair and re-places leaves under the *new*
topology, so a job can resume on a different pod slice (e.g. after losing
a pod) without conversion.  A production deployment would swap this
single-host layout for tensorstore/OCDBT; the commit/restore protocol and
the resharding semantics are what the rest of the framework depends on.

``AsyncCheckpointer`` overlaps serialization with the next train steps
(snapshot-to-host happens synchronously, disk write on a worker thread).
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )
        out.append((key, leaf))
    return out, treedef


def save_checkpoint(directory: str, step: int, tree) -> str:
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat, _ = _flatten_with_paths(tree)
    manifest = {"step": step, "leaves": []}
    for key, leaf in flat:
        arr = np.asarray(leaf)
        fname = re.sub(r"[^A-Za-z0-9_.-]", "_", key) + ".npy"
        np.save(os.path.join(tmp, fname), arr)
        manifest["leaves"].append(
            {"key": key, "file": fname, "dtype": str(arr.dtype),
             "shape": list(arr.shape)})
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)  # atomic commit
    return final


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = [int(m.group(1)) for d in os.listdir(directory)
             if (m := re.fullmatch(r"step_(\d+)", d))]
    return max(steps) if steps else None


def restore_checkpoint(directory: str, step: int, like_tree,
                       mesh=None, shardings=None):
    """Restore into the structure of ``like_tree``.  If (mesh, shardings)
    given, every leaf is device_put with the corresponding sharding —
    this is the elastic-rescale path (topology may differ from writer's)."""
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    by_key = {l["key"]: l for l in manifest["leaves"]}
    flat, treedef = _flatten_with_paths(like_tree)
    leaves = []
    shard_flat = (jax.tree.leaves(shardings) if shardings is not None
                  else [None] * len(flat))
    for (key, like), shard in zip(flat, shard_flat):
        entry = by_key[key]
        arr = np.load(os.path.join(path, entry["file"]))
        if shard is not None:
            leaves.append(jax.device_put(arr, shard))
        else:
            leaves.append(jax.numpy.asarray(arr))
    return jax.tree.unflatten(treedef, leaves)


class AsyncCheckpointer:
    """Snapshot synchronously, write on a background thread, keep last K."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: threading.Thread | None = None
        self._error: BaseException | None = None

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def save(self, step: int, tree):
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)

        def work():
            try:
                save_checkpoint(self.directory, step, host_tree)
                self._gc()
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def _gc(self):
        steps = sorted(
            int(m.group(1)) for d in os.listdir(self.directory)
            if (m := re.fullmatch(r"step_(\d+)", d)))
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)
