"""Serving runtime: compiled-design cache + batched execution.

``DesignCache`` memoizes auto-tuner rankings and jitted executors (the
TPU analogue of reusing one FPGA bitstream across invocations);
``build_batched_runner`` threads a leading batch axis through the
single-PE Pallas kernel and the shard_map runners so one compiled design
serves many independent grids per dispatch.  ``repro.serve.engine``
builds the request-facing server on these pieces.
"""
from repro.runtime.batching import build_batched_runner, devices_needed
from repro.runtime.cache import (
    CachedDesign,
    DesignCache,
    default_cache,
    spec_fingerprint,
)

__all__ = [
    "build_batched_runner",
    "devices_needed",
    "CachedDesign",
    "DesignCache",
    "default_cache",
    "spec_fingerprint",
]
