"""Serving runtime: compiled-design cache + batched, bucketed execution.

``DesignCache`` memoizes auto-tuner rankings and jitted executors (the
TPU analogue of reusing one FPGA bitstream across invocations);
``build_batched_runner`` threads a leading batch axis through the
single-PE Pallas kernel and the shard_map runners so one compiled design
serves many independent grids per dispatch; ``ShapeBucketer`` +
``build_bucket_runner`` + ``DesignCache.bucketed`` let one logical kernel
registration serve heterogeneous grid shapes from a small ladder of
padded bucket designs, under any boundary mode (streamed mask, halo-index
gathers, or host-streamed periodic wrap margins).  ``repro.serve.engine``
builds the request-facing server on these pieces.
"""
from repro.runtime.batching import (
    DegradedDesignWarning,
    build_batched_runner,
    build_bucket_runner,
    devices_needed,
    validate_batch,
)
from repro.runtime.bucketing import (
    BucketPlan,
    ShapeBucketer,
    boundary_fill,
    bucket_margins,
    bucket_plan,
    bucket_spec,
    check_bucketable,
    grid_mask_host,
    halo_index_host,
    halo_index_names,
    mask_input_name,
    masked_spec,
    pad_batch,
    pad_grid,
    padded_request_shape,
    with_shape,
    wrap_index_host,
    wrap_index_names,
)
from repro.runtime.cache import (
    BucketEntry,
    BucketedDesign,
    BucketStats,
    CachedDesign,
    DesignCache,
    default_cache,
    spec_fingerprint,
    structural_fingerprint,
)
from repro.runtime.store import (
    DesignStore,
    StoreStats,
    environment_tag,
)

__all__ = [
    "DegradedDesignWarning",
    "build_batched_runner",
    "build_bucket_runner",
    "devices_needed",
    "validate_batch",
    "BucketPlan",
    "ShapeBucketer",
    "boundary_fill",
    "bucket_margins",
    "bucket_plan",
    "bucket_spec",
    "check_bucketable",
    "grid_mask_host",
    "halo_index_host",
    "halo_index_names",
    "mask_input_name",
    "masked_spec",
    "pad_batch",
    "pad_grid",
    "padded_request_shape",
    "with_shape",
    "wrap_index_host",
    "wrap_index_names",
    "BucketEntry",
    "BucketedDesign",
    "BucketStats",
    "CachedDesign",
    "DesignCache",
    "DesignStore",
    "StoreStats",
    "default_cache",
    "environment_tag",
    "spec_fingerprint",
    "structural_fingerprint",
]
