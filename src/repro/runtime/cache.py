"""Compiled-design cache: skip re-ranking and re-jitting across calls.

SASA's costly artefact on the FPGA is the synthesized bitstream; the
paper (and SODA before it) amortizes it by reusing one design across many
invocations.  The TPU analogue of the bitstream is the (ranking, jitted
executor) pair: re-running ``autotune`` re-enumerates the design space and
re-traces/re-compiles the shard_map/Pallas program, which at serving rates
dwarfs the stencil itself.  ``DesignCache`` memoizes both levels:

  * the *design* level — ``(spec fingerprint, platform, iterations)`` ->
    ranked predictions + chosen :class:`ParallelismConfig`;
  * the *runner* level — ``(spec fingerprint, ParallelismConfig, platform,
    execution options)`` -> a compiled (optionally batched) runner.

Hits and misses are counted per key so serving surfaces can report cache
behaviour (see ``StencilServer.stats``).
"""
from __future__ import annotations

import dataclasses
import hashlib
import time
from typing import Mapping

import jax

from repro.core import dsl
from repro.core.autotune import TunedDesign, autotune
from repro.core.distribute import build_runner
from repro.core.model import ParallelismConfig
from repro.core.platform import DEFAULT_TPU, TPUPlatform
from repro.core.spec import StencilSpec
from repro.runtime.batching import build_batched_runner


def spec_fingerprint(spec: StencilSpec) -> str:
    """Stable (process-independent) content hash of a stencil spec."""
    payload = repr((
        spec.name,
        spec.iterations,
        tuple((k, v[0], tuple(v[1])) for k, v in spec.inputs.items()),
        spec.stages,
        spec.iterate_input,
    ))
    return hashlib.sha1(payload.encode()).hexdigest()[:16]


def _as_spec(source_or_spec) -> StencilSpec:
    if isinstance(source_or_spec, StencilSpec):
        return source_or_spec
    return dsl.parse(source_or_spec)


def _resolve_platform(platform, devices, clip: bool) -> TPUPlatform:
    """Mirror ``autotune``'s platform handling: an explicit platform is
    clipped to the actual device pool only when an executor will be built
    (``clip``); ranking-only studies keep the hypothetical chip count."""
    n_avail = len(devices) if devices is not None else len(jax.devices())
    if platform is None:
        return DEFAULT_TPU.with_chips(n_avail)
    if clip:
        return platform.with_chips(min(platform.num_chips, n_avail))
    return platform


@dataclasses.dataclass
class KeyStats:
    hits: int = 0
    misses: int = 0
    build_time_s: float = 0.0


@dataclasses.dataclass
class CachedDesign:
    """A cache entry: tuned design + compiled batched runner + provenance."""

    design: TunedDesign
    runner: object                 # build_batched_runner result
    fingerprint: str
    key: tuple
    build_time_s: float
    hit: bool                      # whether THIS lookup was served from cache

    @property
    def config(self) -> ParallelismConfig:
        return self.design.config


class DesignCache:
    """In-process memoization of rankings and compiled runners."""

    def __init__(self):
        self._designs: dict[tuple, TunedDesign] = {}
        self._runners: dict[tuple, tuple[object, float]] = {}
        self._failed: dict[tuple, str] = {}    # infeasible-config memo
        self._stats: dict[tuple, KeyStats] = {}

    # ------------------------------------------------------------------
    # design level (ranking only, no executor build)
    # ------------------------------------------------------------------

    def design(
        self,
        source_or_spec,
        platform: TPUPlatform | None = None,
        iterations: int | None = None,
        devices=None,
        clip_to_devices: bool = False,
    ) -> TunedDesign:
        """Cached ``autotune(..., build=False)``: ranked configs for a spec."""
        spec = _as_spec(source_or_spec)
        plat = _resolve_platform(platform, devices, clip_to_devices)
        key = ("design", spec_fingerprint(spec), plat, iterations)
        st = self._stats.setdefault(key, KeyStats())
        if key in self._designs:
            st.hits += 1
            return self._designs[key]
        st.misses += 1
        t0 = time.perf_counter()
        tuned = autotune(
            spec, platform=plat, iterations=iterations, devices=devices,
            build=False,
        )
        st.build_time_s += time.perf_counter() - t0
        self._designs[key] = tuned
        return tuned

    # ------------------------------------------------------------------
    # runner level (compiled executor for a specific config)
    # ------------------------------------------------------------------

    def runner(
        self,
        spec: StencilSpec,
        cfg: ParallelismConfig,
        iterations: int | None = None,
        devices=None,
        tile_rows: int = 64,
        backend: str = "auto",
        align_cols: int = 1,
        batched: bool = True,
    ):
        """Cached runner for ``(spec, cfg, platform, options)``.

        ``batched=True`` compiles the serving runner (leading batch axis);
        ``batched=False`` compiles the classic per-grid runner with the
        ``autotune`` contract.
        """
        dev_key = (
            tuple(str(d) for d in devices) if devices is not None
            else ("default", len(jax.devices()), jax.default_backend())
        )
        key = (
            "runner", spec_fingerprint(spec), cfg, dev_key,
            iterations, tile_rows, backend, align_cols, batched,
        )
        st = self._stats.setdefault(key, KeyStats())
        if key in self._runners:
            st.hits += 1
            return self._runners[key][0]
        if key in self._failed:
            # known-infeasible: re-raising from the memo is a cache hit,
            # so the feasibility retry loop stays free on repeat calls
            st.hits += 1
            raise ValueError(self._failed[key])
        st.misses += 1
        t0 = time.perf_counter()
        try:
            if batched:
                run = build_batched_runner(
                    spec, cfg, iterations=iterations, devices=devices,
                    tile_rows=tile_rows, backend=backend,
                    align_cols=align_cols,
                )
            else:
                run = build_runner(
                    spec, cfg, iterations=iterations, devices=devices,
                    tile_rows=tile_rows,
                )
        except ValueError as e:
            self._failed[key] = str(e)
            raise
        dt = time.perf_counter() - t0
        st.build_time_s += dt
        self._runners[key] = (run, dt)
        return run

    # ------------------------------------------------------------------
    # combined entry point (what serving calls)
    # ------------------------------------------------------------------

    def get_or_build(
        self,
        source_or_spec,
        platform: TPUPlatform | None = None,
        iterations: int | None = None,
        devices=None,
        tile_rows: int = 64,
        backend: str = "auto",
        align_cols: int = 1,
        batched: bool = True,
    ) -> CachedDesign:
        """Rank (cached) then compile (cached) the best feasible design.

        ``CachedDesign.hit`` is True iff both levels were served from the
        cache — i.e. the call did no ranking and no re-jitting.
        """
        spec = _as_spec(source_or_spec)
        fp = spec_fingerprint(spec)
        before_miss = self.misses
        before_build_s = self._total_build_s()
        tuned = self.design(
            spec, platform=platform, iterations=iterations, devices=devices,
            clip_to_devices=True,   # an executor is built: rank what fits
        )
        # feasibility retry loop (paper's "build next best design"): the
        # cached runner level memoizes per-config, so a config that built
        # once keeps winning without re-trying the infeasible ones.
        last_err = None
        run = None
        chosen = None
        for pred in tuned.ranking:
            try:
                run = self.runner(
                    spec, pred.config, iterations=iterations, devices=devices,
                    tile_rows=tile_rows, backend=backend,
                    align_cols=align_cols, batched=batched,
                )
                chosen = pred
                break
            except ValueError as e:
                last_err = e
        if run is None:
            raise RuntimeError(f"no feasible configuration: {last_err}")
        design = TunedDesign(spec, chosen, tuned.ranking, run)
        return CachedDesign(
            design=design, runner=run, fingerprint=fp,
            key=("combined", fp),
            build_time_s=self._total_build_s() - before_build_s,
            hit=(self.misses == before_miss),
        )

    # ------------------------------------------------------------------
    # introspection
    # ------------------------------------------------------------------

    def _total_build_s(self) -> float:
        return sum(s.build_time_s for s in self._stats.values())

    @property
    def hits(self) -> int:
        return sum(s.hits for s in self._stats.values())

    @property
    def misses(self) -> int:
        return sum(s.misses for s in self._stats.values())

    def stats(self) -> Mapping[tuple, KeyStats]:
        return dict(self._stats)

    def __len__(self) -> int:
        return len(self._designs) + len(self._runners)

    def clear(self) -> None:
        self._designs.clear()
        self._runners.clear()
        self._failed.clear()
        self._stats.clear()


_DEFAULT_CACHE = DesignCache()


def default_cache() -> DesignCache:
    """The process-wide cache used when callers don't bring their own."""
    return _DEFAULT_CACHE
