"""Compiled-design cache: skip re-ranking and re-jitting across calls.

SASA's costly artefact on the FPGA is the synthesized bitstream; the
paper (and SODA before it) amortizes it by reusing one design across many
invocations.  The TPU analogue of the bitstream is the (ranking, jitted
executor) pair: re-running ``autotune`` re-enumerates the design space and
re-traces/re-compiles the shard_map/Pallas program, which at serving rates
dwarfs the stencil itself.  ``DesignCache`` memoizes both levels:

  * the *design* level — ``(structural fingerprint, shape, platform,
    iterations)`` -> ranked predictions + chosen :class:`ParallelismConfig`;
  * the *runner* level — ``(structural fingerprint, shape, config,
    device pool, devices actually used, execution options)`` -> a compiled
    (optionally batched) runner.

Keys split the spec's **structural fingerprint** (everything but the grid
shape) from the shape itself, so shape-bucketed serving — where one
logical kernel owns a ladder of bucket designs (:class:`BucketedDesign`)
— shares cache entries across registrations that differ only in declared
grid size.  The device count a runner actually executes on is part of the
key: a design built degraded on a small pool is never served to a larger
pool (or vice versa) as if it owned its configured parallelism.

Hits and misses are counted per key so serving surfaces can report cache
behaviour (see ``StencilServer.stats``).

With a :class:`repro.runtime.store.DesignStore` attached
(``DesignCache(store=...)``), both levels read through disk on a miss
and write through on a build: rankings are persisted whole, and
single-device batched runners persist their compiled executables per
input signature via :mod:`repro.compat`'s AOT tier — so a fresh process
pointed at a warm store serves its first result without autotuning,
tracing, or compiling anything (docs/DESIGN.md §Persistent design
store).
"""
from __future__ import annotations

import collections
import dataclasses
import hashlib
import time
from typing import Mapping, Sequence

import jax

from repro import compat
from repro.core import analysis, dsl
from repro.core.analysis import Diagnostic, require_bucketable
from repro.core.autotune import TunedDesign, autotune
from repro.core.distribute import build_runner
from repro.core.model import ParallelismConfig
from repro.core.platform import DEFAULT_TPU, TPUPlatform
from repro.core.spec import StencilSpec
from repro.runtime.batching import (
    build_batched_runner,
    build_bucket_runner,
    degraded_message,
    is_degraded,
    resolve_backend,
    validate_batch,
)
from repro.runtime.bucketing import (
    ShapeBucketer,
    bucket_spec,
    padded_request_shape,
)
from repro.runtime.store import (
    DesignStore,
    as_store,
    batch_signature,
    design_key,
    runner_key,
    subtract_counters,
)


def structural_fingerprint(spec: StencilSpec) -> str:
    """Content hash of everything about a spec *except* its grid shape.

    Two specs with equal structural fingerprints describe the same stencil
    on (possibly) different grid sizes and can share bucket designs.  The
    boundary rule is structural: a periodic and a zero-boundary variant of
    the same expression tree are different kernels.
    """
    payload = repr((
        spec.name,
        spec.iterations,
        spec.ndim,
        tuple((k, v[0]) for k, v in spec.inputs.items()),
        spec.stages,
        spec.iterate_input,
        spec.boundary,
        spec.halo_index_inputs,
        spec.wrap_index_inputs,
        spec.wrap_round_depth,
    ))
    return hashlib.sha1(payload.encode()).hexdigest()[:16]


def spec_fingerprint(spec: StencilSpec) -> str:
    """Stable (process-independent) content hash of a full stencil spec."""
    payload = repr((structural_fingerprint(spec), tuple(spec.shape)))
    return hashlib.sha1(payload.encode()).hexdigest()[:16]


def _as_spec(source_or_spec) -> StencilSpec:
    if isinstance(source_or_spec, StencilSpec):
        return source_or_spec
    return dsl.parse(source_or_spec)


def _resolve_platform(platform, devices, clip: bool) -> TPUPlatform:
    """Mirror ``autotune``'s platform handling: an explicit platform is
    clipped to the actual device pool only when an executor will be built
    (``clip``); ranking-only studies keep the hypothetical chip count."""
    n_avail = len(devices) if devices is not None else len(jax.devices())
    if platform is None:
        return DEFAULT_TPU.with_chips(n_avail)
    if clip:
        return platform.with_chips(min(platform.num_chips, n_avail))
    return platform


@dataclasses.dataclass
class KeyStats:
    hits: int = 0
    misses: int = 0
    build_time_s: float = 0.0
    store_hits: int = 0     # misses served warm from the persistent store


@dataclasses.dataclass
class CachedDesign:
    """A cache entry: tuned design + compiled batched runner + provenance."""

    design: TunedDesign
    runner: object                 # build_batched_runner result
    fingerprint: str
    key: tuple
    build_time_s: float
    hit: bool                      # whether THIS lookup was served from cache

    @property
    def config(self) -> ParallelismConfig:
        return self.design.config


class DesignCache:
    """In-process memoization of rankings and compiled runners.

    ``max_designs`` caps the number of *compiled runners* the cache
    memoizes (the expensive artefacts — rankings are cheap and uncapped):
    every runner hit marks its entry most-recently-used, and an insert
    past the cap evicts the least-recently-hit runner
    (``runner_evictions`` counts them; per-key hit/miss stats survive, so
    an evict-then-rehit shows up as a rebuild miss on the same key).
    This is the cache-level capacity management that used to be a ROADMAP
    item: bucket-ladder eviction (``max_buckets``) only drops a
    registration's reference, while this bounds the shared memoization
    itself.

    ``store`` (a :class:`repro.runtime.store.DesignStore` or a path)
    makes the cache **persistent**: rankings are read through from /
    written through to disk (a warm process never re-autotunes), and
    single-device batched runners persist their compiled executables per
    input signature through :mod:`repro.compat`'s AOT tier, so a warm
    replica's first dispatch deserializes instead of tracing+compiling.
    ``autotune_calls`` counts actual design-space enumerations and
    ``jit_builds`` counts actual AOT trace+compile events — both stay 0
    on a fully warm path (the cold-start gate asserts this).  An
    LRU-evicted runner (``max_designs``) rebuilds from the store:
    re-jitting only happens when the executable entry is gone too.
    """

    def __init__(
        self,
        max_designs: int | None = None,
        store: "DesignStore | str | None" = None,
    ):
        if max_designs is not None and max_designs < 1:
            raise ValueError(
                f"max_designs must be >= 1, got {max_designs}"
            )
        self.max_designs = max_designs
        self.store = as_store(store)
        self.runner_evictions = 0
        self.autotune_calls = 0    # design-space enumerations actually run
        self.jit_builds = 0        # AOT trace+compile events actually run
        self._designs: dict[tuple, TunedDesign] = {}
        self._runners: "collections.OrderedDict[tuple, tuple[object, float]]" = (
            collections.OrderedDict()
        )
        self._failed: dict[tuple, str] = {}    # infeasible-config memo
        self._stats: dict[tuple, KeyStats] = {}
        # restored-telemetry baselines: flush_telemetry persists only the
        # progress made by THIS cache (current - baseline), so restored
        # history is never written back and double-counted by the store's
        # multi-writer merge
        self._tel_baseline: dict[tuple, dict] = {}
        self._tel_buckets: dict[tuple, dict] = {}
        if self.store is not None:
            self._restore_telemetry()

    def _restore_telemetry(self) -> None:
        """Seed per-key counters from the store so a restart resumes the
        telemetry the measurement-calibrated cost model consumes."""
        tel = self.store.get_telemetry()
        if tel is None:
            return
        fields = {f.name for f in dataclasses.fields(KeyStats)}
        for key, d in tel.get("keys", {}).items():
            try:
                self._stats[key] = KeyStats(
                    **{k: v for k, v in d.items() if k in fields}
                )
            except (TypeError, ValueError):
                continue   # stale telemetry shape: skip, don't crash
            self._tel_baseline[key] = dataclasses.asdict(self._stats[key])

    def flush_telemetry(self, buckets: dict | None = None) -> None:
        """Write-through the per-key counters (and optionally per-bucket
        counters) to the attached store; no-op without one.

        What is persisted is this writer's contribution only: per-key
        deltas against the restored baselines, plus every per-bucket dict
        any registration has handed in so far (bucket callers subtract
        their own baselines before calling).  The store merges writers on
        read, so totals across replicas/restarts stay exact.
        """
        if self.store is None:
            return
        if buckets:
            self._tel_buckets.update(buckets)
        keys = {}
        for k, s in self._stats.items():
            d = dataclasses.asdict(s)
            base = self._tel_baseline.get(k)
            keys[k] = subtract_counters(d, base) if base else d
        self.store.put_telemetry(keys, self._tel_buckets)

    # ------------------------------------------------------------------
    # design level (ranking only, no executor build)
    # ------------------------------------------------------------------

    def design(
        self,
        source_or_spec,
        platform: TPUPlatform | None = None,
        iterations: int | None = None,
        devices=None,
        clip_to_devices: bool = False,
    ) -> TunedDesign:
        """Cached ``autotune(..., build=False)``: ranked configs for a spec.

        With a store attached the miss path reads through disk before
        autotuning: a persisted ranking (written by any process sharing
        the store) is rehydrated without enumerating the design space,
        and a fresh autotune result is written through for the next
        replica.
        """
        spec = _as_spec(source_or_spec)
        plat = _resolve_platform(platform, devices, clip_to_devices)
        structural = structural_fingerprint(spec)
        key = (
            "design", structural, tuple(spec.shape),
            plat, iterations,
        )
        st = self._stats.setdefault(key, KeyStats())
        if key in self._designs:
            st.hits += 1
            return self._designs[key]
        skey = None
        if self.store is not None:
            skey = design_key(structural, spec.shape, plat, iterations)
            got = self.store.get_design(skey)
            if got is not None:
                from repro.core import numerics

                stored_spec, ranking = got
                # the store persists spec + ranking only; the certified
                # bound is cheap static analysis, so recompute on warm
                # start rather than widening the store schema
                tuned = TunedDesign(
                    stored_spec, ranking[0], list(ranking), None,
                    diagnostics=(numerics.bound_diagnostic(
                        stored_spec, iterations=iterations,
                    ),),
                )
                st.store_hits += 1
                self._designs[key] = tuned
                # a store hit is already a disk event: persist the counter
                # so fleet telemetry sees warm starts, not just builds
                self.flush_telemetry()
                return tuned
        st.misses += 1
        self.autotune_calls += 1
        t0 = time.perf_counter()
        tuned = autotune(
            spec, platform=plat, iterations=iterations, devices=devices,
            build=False,
        )
        st.build_time_s += time.perf_counter() - t0
        self._designs[key] = tuned
        if skey is not None:
            # persist the lowered spec + full ranking: warm starts skip
            # both the IR lowering and the design-space enumeration
            self.store.put_design(skey, tuned.spec, tuned.ranking)
            self.flush_telemetry()
        return tuned

    # ------------------------------------------------------------------
    # runner level (compiled executor for a specific config)
    # ------------------------------------------------------------------

    def runner(
        self,
        spec: StencilSpec,
        cfg: ParallelismConfig,
        iterations: int | None = None,
        devices=None,
        tile_rows: int = 64,
        backend: str = "auto",
        align_cols: int = 1,
        batched: bool = True,
        strict: bool = False,
    ):
        """Cached runner for ``(spec, cfg, platform, options)``.

        ``batched=True`` compiles the serving runner (leading batch axis);
        ``batched=False`` compiles the classic per-grid runner with the
        ``autotune`` contract.  The key includes the device count the
        runner will actually occupy, so a degraded build (pool smaller
        than the config) is re-examined — not silently reused — when the
        pool changes.  ``strict`` is enforced *before* the lookup (it only
        changes behaviour for degraded configs), so strict and non-strict
        callers share cache entries.
        """
        n_avail = len(devices) if devices is not None else len(jax.devices())
        n_used = min(cfg.devices_needed, n_avail)
        if strict and is_degraded(cfg, n_avail):
            raise ValueError(degraded_message(cfg, n_avail))
        dev_key = (
            tuple(str(d) for d in devices) if devices is not None
            else ("default", n_avail, jax.default_backend())
        )
        key = (
            "runner", structural_fingerprint(spec), tuple(spec.shape), cfg,
            dev_key, n_used, iterations, tile_rows, backend, align_cols,
            batched,
        )
        st = self._stats.setdefault(key, KeyStats())
        if key in self._runners:
            st.hits += 1
            self._runners.move_to_end(key)      # most recently hit
            return self._runners[key][0]
        if key in self._failed:
            # known-infeasible: re-raising from the memo is a cache hit,
            # so the feasibility retry loop stays free on repeat calls
            st.hits += 1
            raise ValueError(self._failed[key])
        st.misses += 1
        t0 = time.perf_counter()
        try:
            if batched:
                run = build_batched_runner(
                    spec, cfg, iterations=iterations, devices=devices,
                    tile_rows=tile_rows, backend=backend,
                    align_cols=align_cols,
                )
            else:
                run = build_runner(
                    spec, cfg, iterations=iterations, devices=devices,
                    tile_rows=tile_rows,
                )
        except ValueError as e:
            self._failed[key] = str(e)
            raise
        if self.store is not None and getattr(run, "jitted", None) is not None:
            skey = runner_key(
                structural_fingerprint(spec), spec.shape, cfg, n_used,
                iterations, tile_rows, resolve_backend(backend),
                align_cols, batched,
            )
            run = self._attach_store(run, skey)
        dt = time.perf_counter() - t0
        st.build_time_s += dt
        self._runners[key] = (run, dt)
        if self.max_designs is not None:
            while len(self._runners) > self.max_designs:
                self._runners.popitem(last=False)   # least recently hit
                self.runner_evictions += 1
        return run

    def _attach_store(self, run, store_key: str):
        """Persistence layer over a batched runner's dispatch phase.

        jit compiles lazily per batch signature, so executables are
        intercepted where they materialize: on each new input signature
        the dispatch path tries the store first (deserializing a
        persisted executable in milliseconds), and only on a store miss
        AOT-compiles explicitly — counting ``jit_builds`` — and writes
        the serialized executable through for the next replica.  All
        phases and reporting attributes of the wrapped runner are
        preserved; results are bitwise-identical either way (the
        executable IS the program that would have been compiled).
        """
        store, spec, jitted = self.store, run.spec, run.jitted
        inner_stage, inner_finalize = run.stage, run.finalize
        executables: dict[str, object] = {}

        def dispatch(staged):
            staged = dict(staged)
            sig = batch_signature(staged)
            comp = executables.get(sig)
            if comp is None:
                comp = store.get_executable(store_key, sig)
                if comp is None:
                    comp = compat.aot_compile(jitted, staged)
                    self.jit_builds += 1
                    kind, blob = compat.aot_serialize(
                        compiled=comp, jitted=jitted, sample_args=staged,
                    )
                    if kind is not None:
                        store.put_executable(store_key, sig, kind, blob)
                executables[sig] = comp
            return comp(staged)

        def persistent_run(arrays):
            validate_batch(spec, arrays)
            return inner_finalize(dispatch(inner_stage(arrays)))

        for attr in (
            "spec", "cfg", "iterations", "path", "backend", "mesh",
            "n_devices", "devices_requested", "degraded", "jitted",
        ):
            setattr(persistent_run, attr, getattr(run, attr))
        persistent_run.stage = inner_stage
        persistent_run.dispatch = dispatch
        persistent_run.finalize = inner_finalize
        persistent_run.ready = getattr(run, "ready", compat.is_ready)
        persistent_run.store_key = store_key
        return persistent_run

    # ------------------------------------------------------------------
    # combined entry point (what serving calls)
    # ------------------------------------------------------------------

    def get_or_build(
        self,
        source_or_spec,
        platform: TPUPlatform | None = None,
        iterations: int | None = None,
        devices=None,
        tile_rows: int = 64,
        backend: str = "auto",
        align_cols: int = 1,
        batched: bool = True,
        strict: bool = False,
    ) -> CachedDesign:
        """Rank (cached) then compile (cached) the best feasible design.

        ``CachedDesign.hit`` is True iff both levels were served from the
        cache — i.e. the call did no ranking and no re-jitting.
        """
        spec = _as_spec(source_or_spec)
        fp = spec_fingerprint(spec)
        before_miss = self.misses
        before_build_s = self._total_build_s()
        tuned = self.design(
            spec, platform=platform, iterations=iterations, devices=devices,
            clip_to_devices=True,   # an executor is built: rank what fits
        )
        # feasibility retry loop (paper's "build next best design"): the
        # static preflight mirrors the runtime guards, so known-infeasible
        # candidates are skipped without touching the runner level (and
        # recorded as diagnostics); the cached runner level memoizes
        # per-config, so a config that built once keeps winning.  The
        # runner compiles ``tuned.spec`` — the IR-lowered trees the model
        # ranked — not the raw input spec.
        n_pool = len(devices) if devices is not None else len(jax.devices())
        verdicts = analysis.preflight(
            tuned.spec, [p.config for p in tuned.ranking], n_pool,
            iterations=iterations, batched=batched,
            k_override=(
                len(devices)
                if devices is not None and not batched else None
            ),
        )
        diags: list[Diagnostic] = []
        last_err = None
        run = None
        chosen = None
        for pred, verdict in zip(tuned.ranking, verdicts):
            if not verdict.feasible:
                diags.append(verdict.diagnostic("info"))
                last_err = verdict.reason
                continue
            try:
                run = self.runner(
                    tuned.spec, pred.config, iterations=iterations,
                    devices=devices, tile_rows=tile_rows, backend=backend,
                    align_cols=align_cols, batched=batched, strict=strict,
                )
                chosen = pred
                break
            except ValueError as e:
                diags.append(Diagnostic(
                    "SASA308", "info",
                    f"candidate {pred.config} refused at build time: {e}",
                ))
                last_err = e
        if run is None:
            raise RuntimeError(f"no feasible configuration: {last_err}")
        # carry the certified bound (SASA500) through from the cached
        # design; preflight skip diags are freshly collected above, so
        # only the numerics finding would otherwise be lost
        carried = tuple(
            d for d in tuned.diagnostics if d.code == "SASA500"
        )
        design = TunedDesign(
            tuned.spec, chosen, tuned.ranking, run, tuned.lowering,
            carried + tuple(diags),
        )
        return CachedDesign(
            design=design, runner=run, fingerprint=fp,
            key=("combined", fp),
            build_time_s=self._total_build_s() - before_build_s,
            hit=(self.misses == before_miss),
        )

    # ------------------------------------------------------------------
    # bucketed registration (multi-geometry serving)
    # ------------------------------------------------------------------

    def bucketed(
        self,
        source_or_spec,
        bucketer: ShapeBucketer | None = None,
        platform: TPUPlatform | None = None,
        iterations: int | None = None,
        devices=None,
        tile_rows: int = 64,
        backend: str = "auto",
        align_cols: int = 1,
        strict: bool = False,
        max_buckets: int | None = None,
    ) -> "BucketedDesign":
        """Register one logical kernel served across many grid shapes.

        The returned :class:`BucketedDesign` lazily owns a ladder of
        bucket designs (one auto-tuned, compiled, masked design per bucket
        shape actually requested), all memoized through this cache — so a
        second registration of a structurally identical kernel, even with
        a different declared grid size, reuses every compiled bucket.

        ``max_buckets`` caps the ladder with an LRU policy: when a new
        bucket would exceed the cap, the least-recently-hit bucket design
        is evicted (its counters survive and resume if the bucket is ever
        re-registered).  Every boundary mode is accepted — zero/constant
        via the streamed mask, replicate via streamed halo-index gathers,
        periodic via host-streamed wrap margins (docs/DESIGN.md
        §Boundaries × bucketed serving); only kernels no streamed bucket
        transform can serve bit-exactly (a divisor interval containing
        zero) are refused here, at registration time (see
        :func:`repro.core.analysis.require_bucketable`).  With
        ``strict`` the full static verification suite runs too and any
        error-severity diagnostic refuses the registration.
        """
        spec = _as_spec(source_or_spec)
        require_bucketable(spec)  # refuse un-bucketable kernels loudly, now
        if strict:
            analysis.verify_or_raise(spec, iterations=iterations)
        return BucketedDesign(
            cache=self,
            spec=spec,
            bucketer=bucketer if bucketer is not None else ShapeBucketer(),
            platform=platform,
            iterations=iterations,
            devices=devices,
            tile_rows=tile_rows,
            backend=backend,
            align_cols=align_cols,
            strict=strict,
            max_buckets=max_buckets,
        )

    # ------------------------------------------------------------------
    # introspection
    # ------------------------------------------------------------------

    def _total_build_s(self) -> float:
        return sum(s.build_time_s for s in self._stats.values())

    @property
    def hits(self) -> int:
        return sum(s.hits for s in self._stats.values())

    @property
    def misses(self) -> int:
        return sum(s.misses for s in self._stats.values())

    def stats(self) -> Mapping[tuple, KeyStats]:
        return dict(self._stats)

    def __len__(self) -> int:
        return len(self._designs) + len(self._runners)

    def clear(self) -> None:
        """Drop the in-memory memoization (the persistent store, if any,
        is untouched: a cleared cache re-warms from disk)."""
        self._designs.clear()
        self._runners.clear()
        self._failed.clear()
        self._stats.clear()
        self._tel_baseline.clear()
        self._tel_buckets.clear()
        self.runner_evictions = 0
        self.autotune_calls = 0
        self.jit_builds = 0

    @property
    def store_hits(self) -> int:
        return sum(s.store_hits for s in self._stats.values())


# --------------------------------------------------------------------------
# Bucketed registration: one logical kernel, a ladder of bucket designs
# --------------------------------------------------------------------------


@dataclasses.dataclass
class BucketStats:
    """Per-bucket serving counters of one logical registration."""

    hits: int = 0              # runner_for calls served by an existing bucket
    misses: int = 0            # runner_for calls that had to build the bucket
    requests: int = 0          # grids routed to this bucket
    build_time_s: float = 0.0  # rank + jit time paid by this registration
    cache_hit: bool = False    # the bucket's design came fully from the cache

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class BucketEntry:
    """One rung of a registration's bucket ladder."""

    bucket: tuple[int, ...]
    runner: object             # build_bucket_runner result (pad+mask wrapper)
    cached: CachedDesign       # the underlying masked bucket design
    stats: BucketStats

    @property
    def config(self) -> ParallelismConfig:
        return self.cached.design.config


class BucketedDesign:
    """One logical kernel registration owning a ladder of bucket designs.

    ``runner_for(shape)`` maps a grid shape (plus its streamed-halo
    margins) to its bucket via the :class:`ShapeBucketer` policy,
    auto-tunes and compiles that bucket's streamed-boundary design on
    first use (both levels memoized in the shared :class:`DesignCache`),
    and returns the :class:`BucketEntry` whose staging runner serves the
    shape.  Per-bucket hit counters live in ``BucketEntry.stats`` /
    :meth:`stats`.

    ``max_buckets`` bounds the ladder of a long-lived registration (the
    ROADMAP's bucket-eviction item): every ``runner_for`` marks its bucket
    most-recently-used, and building a bucket past the cap evicts the
    least-recently-hit entry.  An evicted bucket's counters are archived
    and resume when the bucket is rebuilt, so serving statistics survive
    eviction/re-registration cycles.  Eviction drops this registration's
    reference to the compiled design; while the shared
    :class:`DesignCache` still memoizes it a rebuild is a dictionary
    lookup, but under ``DesignCache(max_designs=)`` the runner itself
    may have been LRU-evicted, in which case the rebuild re-jits from
    the still-cached ranking.
    """

    def __init__(
        self, cache: DesignCache, spec: StencilSpec,
        bucketer: ShapeBucketer, platform=None, iterations=None,
        devices=None, tile_rows: int = 64, backend: str = "auto",
        align_cols: int = 1, strict: bool = False,
        max_buckets: int | None = None,
    ):
        if max_buckets is not None and max_buckets < 1:
            raise ValueError(f"max_buckets must be >= 1, got {max_buckets}")
        self.cache = cache
        self.spec = spec
        self.bucketer = bucketer
        self.platform = platform
        self.iterations = iterations
        self.devices = devices
        self.tile_rows = tile_rows
        self.backend = backend
        self.align_cols = align_cols
        self.strict = strict
        self.max_buckets = max_buckets
        self.structural = structural_fingerprint(spec)
        # insertion/access order = LRU order (oldest first)
        self._entries: "collections.OrderedDict[tuple[int, ...], BucketEntry]" = (
            collections.OrderedDict()
        )
        self._evicted_stats: dict[tuple[int, ...], BucketStats] = {}
        # restored per-bucket baselines: persist_stats() writes deltas
        # against these, so restored history isn't double-counted by the
        # store's multi-writer telemetry merge
        self._tel_baseline: dict[tuple[int, ...], dict] = {}
        self.evictions: int = 0
        self._wrap_rounds = ...   # undecided until first routing
        if cache.store is not None:
            # restart continuity: persisted per-bucket counters land in
            # the archived-stats map, so the first (re)build of each
            # bucket resumes them through the existing eviction-resume
            # path instead of zeroing the ladder's history
            tel = cache.store.get_telemetry()
            fields = {f.name for f in dataclasses.fields(BucketStats)}
            for bkey, d in (tel or {}).get("buckets", {}).items():
                try:
                    structural, bucket = bkey
                except (TypeError, ValueError):
                    continue
                if structural != self.structural:
                    continue
                try:
                    self._evicted_stats[tuple(bucket)] = BucketStats(
                        **{k: v for k, v in d.items() if k in fields}
                    )
                except (TypeError, ValueError):
                    continue
                self._tel_baseline[tuple(bucket)] = dataclasses.asdict(
                    self._evicted_stats[tuple(bucket)]
                )

    @property
    def wrap_rounds(self) -> int | None:
        """The narrow-margin wrap depth this registration serves with.

        Decided once at first routing and pinned for the registration's
        lifetime (margins are baked into bucket routing, so it cannot
        change per request): ``None`` — the legacy wide
        ``iterations * radius`` margin — unless the boundary is periodic
        *and* the device pool is a single device (the between-round
        re-wrap needs the whole grid resident; shard_map keeps the wide
        margin until the collective re-wrap lands — see the TODO in
        :mod:`repro.core.distribute`).  Otherwise the design-level
        ranking for the declared shape picks the fusion depth ``s`` the
        bucket designs will run, and the margin shrinks to
        ``s * radius``.
        """
        if self._wrap_rounds is ...:
            self._wrap_rounds = self._decide_wrap_rounds()
        return self._wrap_rounds

    def _decide_wrap_rounds(self) -> int | None:
        if self.spec.boundary.kind != "periodic":
            return None
        n_avail = (
            len(self.devices) if self.devices is not None
            else len(jax.devices())
        )
        if n_avail > 1:
            return None
        it = (
            self.spec.iterations if self.iterations is None
            else self.iterations
        )
        tuned = self.cache.design(
            self.spec, platform=self.platform, iterations=self.iterations,
            devices=self.devices, clip_to_devices=True,
        )
        return max(min(tuned.ranking[0].config.s, it), 1)

    def bucket_for(self, shape: Sequence[int]) -> tuple[int, ...]:
        """The bucket serving a *request* grid of ``shape``.

        Routing fits the grid plus its per-dimension halo margins
        (non-zero only for periodic specs, whose wrapped exterior is
        streamed into the margin as data; sized by this registration's
        :attr:`wrap_rounds` — see
        :func:`repro.runtime.bucketing.bucket_margins`).
        """
        return self.bucketer.bucket_for(
            padded_request_shape(
                self.spec, shape, self.iterations, self.wrap_rounds
            )
        )

    def runner_for(self, shape: Sequence[int], count: int = 1) -> BucketEntry:
        """The bucket entry serving request grids of ``shape`` (built and
        memoized on first use); ``count`` grids are attributed to the
        bucket's counters."""
        return self.entry_for_bucket(self.bucket_for(shape), count=count)

    def entry_for_bucket(
        self, bucket: tuple[int, ...], count: int = 1
    ) -> BucketEntry:
        """The entry for an already-routed bucket shape (what the server's
        flush loop calls after grouping requests per bucket; routing a
        bucket shape through :meth:`bucket_for` again would re-add halo
        margins)."""
        bucket = tuple(int(b) for b in bucket)
        entry = self._entries.get(bucket)
        if entry is not None:
            entry.stats.hits += 1
            entry.stats.requests += count
            self._entries.move_to_end(bucket)      # most recently hit
            return entry
        bspec = bucket_spec(self.spec, bucket, self.wrap_rounds)
        t0 = time.perf_counter()
        cached = self.cache.get_or_build(
            bspec, platform=self.platform, iterations=self.iterations,
            devices=self.devices, tile_rows=self.tile_rows,
            backend=self.backend, align_cols=self.align_cols,
            strict=self.strict,
        )
        wrapped = build_bucket_runner(
            self.spec, bucket, cached.design.config,
            iterations=self.iterations, inner=cached.runner,
            wrap_rounds=self.wrap_rounds,
        )
        # a previously evicted bucket resumes its archived counters
        stats = self._evicted_stats.pop(bucket, None) or BucketStats()
        stats.misses += 1
        stats.requests += count
        stats.build_time_s += 0.0 if cached.hit else time.perf_counter() - t0
        stats.cache_hit = cached.hit
        entry = BucketEntry(
            bucket=bucket, runner=wrapped, cached=cached, stats=stats
        )
        self._entries[bucket] = entry
        if self.max_buckets is not None:
            while len(self._entries) > self.max_buckets:
                old_bucket, old = self._entries.popitem(last=False)
                self._evicted_stats[old_bucket] = old.stats
                self.evictions += 1
        self.persist_stats()
        return entry

    def persist_stats(self) -> None:
        """Write-through this registration's per-bucket counters to the
        cache's persistent store (no-op without one); restarts restore
        them through the archived-stats map.  Counters restored from the
        store are subtracted back out before writing, so only this
        registration's own progress lands in its writer's telemetry file
        (the store merges writers on read)."""
        if self.cache.store is None:
            return
        live = {b: e.stats.as_dict() for b, e in self._entries.items()}
        live.update({b: s.as_dict() for b, s in self._evicted_stats.items()})
        buckets = {}
        for b, d in live.items():
            base = self._tel_baseline.get(b)
            buckets[(self.structural, b)] = (
                subtract_counters(d, base) if base else d
            )
        self.cache.flush_telemetry(buckets)

    def run(self, shape, arrays) -> "np.ndarray":
        """Convenience: serve one uniform-shape batch through its bucket."""
        return self.runner_for(shape).runner(arrays)

    @property
    def buckets(self) -> dict[tuple[int, ...], BucketEntry]:
        return dict(self._entries)

    @property
    def num_buckets(self) -> int:
        return len(self._entries)

    def stats(self) -> dict[tuple[int, ...], dict]:
        """Per-bucket counters, evicted rungs included (marked evicted)."""
        out = {b: e.stats.as_dict() for b, e in self._entries.items()}
        for b, s in self._evicted_stats.items():
            d = s.as_dict()
            d["evicted"] = True
            out[b] = d
        return out


_DEFAULT_CACHE = DesignCache()


def default_cache() -> DesignCache:
    """The process-wide cache used when callers don't bring their own."""
    return _DEFAULT_CACHE
