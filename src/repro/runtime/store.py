"""Persistent AOT design store: the on-disk half of the FPGA-bitstream
analogy.

SASA's tuned design is synthesized **once** into a bitstream and reused
for the deployment's lifetime; :class:`repro.runtime.DesignCache` is the
in-process analogue, but it dies with the process — every server restart
re-autotunes and re-jits the whole bucket ladder.  ``DesignStore``
completes the analogy by persisting both cache levels to a directory
that N replica processes can share:

  * **design entries** — the autotune ranking (the lowered spec + the
    full :class:`repro.core.model.Prediction` list), so a warm start
    never re-enumerates the design space;
  * **executable entries** — compiled executables serialized through
    :mod:`repro.compat`'s AOT tier (whole XLA executables when the
    installed jax supports it, portable StableHLO otherwise, rankings
    only when neither is available), one file per compiled input
    signature, so a warm replica reaches its first bitwise-identical
    result without tracing or compiling anything;
  * **telemetry** — the cache's per-key :class:`KeyStats` and each
    registration's per-bucket :class:`BucketStats` counters, restored on
    warm start so restarts don't zero the inputs the
    measurement-calibrated cost model consumes.

Layout and invalidation::

    <root>/
      manifest.json                  # schema + the envs ever written
      <env>/                         # schema<N>-jax<version>-<backend>
        designs/<digest>.pkl         # ranking entries
        executables/<digest>.<sig>.pkl
        telemetry/<writer>.pkl       # one counter file per writer
        telemetry.pkl                # legacy single-snapshot (read-only)
        quarantine/                  # corrupt/undecodable entries land here

The **environment tag** bakes the store schema version, the jax version,
and the default backend into the directory name: a jax upgrade (or a
schema bump) makes every stale entry invisible — clean invalidation with
no in-place migration — and ``python -m repro.store prune`` deletes the
dead environments.  Entry keys additionally carry the structural
fingerprint, grid/bucket shape, :class:`ParallelismConfig`, platform,
and the device count the runner occupies, so a design built for one pool
is never served to a different one as if it owned its parallelism.

Every write is atomic (tmp file + ``os.replace`` in the same directory),
so concurrent replicas sharing one store directory never observe a torn
entry; concurrent writers of the *same* entry are idempotent
(last-writer-wins on identical content).  Every entry is framed with a
magic header + SHA-256 checksum: a corrupt, truncated, or undecodable
file is **quarantined** (moved aside, counted, server keeps running)
rather than crashing the replica.  Telemetry writes never
read-modify-write a shared record: each writer owns one file under
``telemetry/`` and :meth:`DesignStore.get_telemetry` merges all of them
with the monotone-counter policy of :func:`merge_counters` (sum counts,
max-of-maxes, recompute means from sums) — N replicas sharing a
directory accumulate, they don't clobber.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import pickle
import tempfile
import time
import uuid
from pathlib import Path

import jax

from repro import compat

SCHEMA_VERSION = 1

_MAGIC = b"SASA-STORE\x01"


def environment_tag(backend: str | None = None) -> str:
    """The invalidation unit: schema x jax version x backend."""
    return (
        f"schema{SCHEMA_VERSION}-jax{jax.__version__}-"
        f"{backend or jax.default_backend()}"
    )


def _digest(payload: str, n: int = 24) -> str:
    return hashlib.sha256(payload.encode()).hexdigest()[:n]


def design_key(structural: str, shape, platform, iterations) -> str:
    """Process-independent key for a ranking entry (mirrors the cache's
    design-level key)."""
    return repr(("design", structural, tuple(shape), platform, iterations))


def runner_key(
    structural: str, shape, cfg, n_used: int, iterations,
    tile_rows: int, backend: str, align_cols: int, batched: bool,
) -> str:
    """Process-independent key for a compiled-executable entry.

    The device count the runner actually occupies (``n_used``) and the
    resolved backend are part of the key, so a warm replica on a
    different pool misses here and recompiles from the persisted ranking
    instead of loading an executable laid out for other hardware.
    """
    return repr((
        "runner", structural, tuple(shape), cfg, n_used, iterations,
        tile_rows, backend, align_cols, batched,
    ))


def batch_signature(arrays) -> str:
    """Input-signature key of one staged batch: sorted (name, shape,
    dtype) triples — the unit one serialized executable covers."""
    return repr(tuple(sorted(
        (n, tuple(int(d) for d in a.shape), str(a.dtype))
        for n, a in arrays.items()
    )))


def merge_counters(a: dict, b: dict) -> dict:
    """Merge two counter dicts of the same shape, field-wise, under the
    monotone-counter policy:

      * booleans OR (``cache_hit`` stays sticky once any writer hit);
      * fields named ``*max*`` take the max of the two observations;
      * derived means (``*mean*``) are **recomputed from the merged
        sums** (``exec_mean_s`` from ``exec_total_s / exec_count``),
        zero-guarded, never summed or averaged naively;
      * every other numeric field sums;
      * non-numeric fields keep ``a``'s value.

    This is what makes N telemetry writers sharing one store directory
    accumulate instead of clobbering each other.
    """
    out = dict(a)
    for k, vb in b.items():
        if k not in out:
            out[k] = vb
            continue
        va = out[k]
        if isinstance(va, bool) or isinstance(vb, bool):
            out[k] = bool(va) or bool(vb)
        elif "mean" in k:
            continue                       # recomputed from sums below
        elif not (isinstance(va, (int, float))
                  and isinstance(vb, (int, float))):
            continue                       # non-numeric: first writer wins
        elif "max" in k:
            out[k] = max(va, vb)
        else:
            out[k] = va + vb
    for k in list(out):
        if "mean" not in k:
            continue
        total_key = k.replace("mean", "total")
        count_key = k.replace("_mean_s", "_count").replace("_mean", "_count")
        if total_key in out and count_key in out:
            cnt = out[count_key]
            out[k] = out[total_key] / cnt if cnt else 0.0
    return out


def subtract_counters(current: dict, baseline: dict) -> dict:
    """``current - baseline`` under the same policy: the delta a writer
    persists when its in-memory counters were *seeded* from restored
    telemetry, so the restored history is never written back (and hence
    never double-counted by :func:`merge_counters`).  Summed fields
    subtract (clamped at zero); max / mean / bool fields pass through
    (re-asserting an already-achieved max is merge-idempotent)."""
    out = dict(current)
    for k, vb in baseline.items():
        va = out.get(k)
        if (
            isinstance(va, bool) or not isinstance(va, (int, float))
            or not isinstance(vb, (int, float))
            or "max" in k or "mean" in k
        ):
            continue
        out[k] = max(0, va - vb) if isinstance(va, int) else max(0.0, va - vb)
    return out


@dataclasses.dataclass
class StoreStats:
    design_hits: int = 0
    design_misses: int = 0
    executable_hits: int = 0
    executable_misses: int = 0
    writes: int = 0
    quarantined: int = 0

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


class DesignStore:
    """A persistent, multi-process-safe design store rooted at ``root``.

    ``readonly=True`` never writes (no manifest update, no entry or
    telemetry writes) — for fleet replicas that must not mutate a store
    baked into an image.  All ``get_*`` methods return ``None`` on miss
    and *never raise on bad entries*: undecodable files are quarantined
    and reported as misses.
    """

    def __init__(self, root, readonly: bool = False,
                 env_tag: str | None = None):
        self.root = Path(root)
        self.readonly = readonly
        self.env_tag = env_tag or environment_tag()
        self.stats = StoreStats()
        self._env = self.root / self.env_tag
        # telemetry writer identity: one counter file per store instance,
        # so concurrent replicas never read-modify-write a shared record
        self._writer_id = f"{os.getpid()}-{uuid.uuid4().hex[:8]}"
        if not readonly:
            for sub in ("designs", "executables", "quarantine"):
                (self._env / sub).mkdir(parents=True, exist_ok=True)
            self._update_manifest()

    # ------------------------------------------------------------------
    # manifest
    # ------------------------------------------------------------------

    def _update_manifest(self) -> None:
        path = self.root / "manifest.json"
        manifest = {"schema": SCHEMA_VERSION, "environments": []}
        if path.exists():
            try:
                manifest = json.loads(path.read_text())
            except (json.JSONDecodeError, OSError):
                pass  # rewrite a fresh manifest below
        envs = set(manifest.get("environments", ()))
        if self.env_tag in envs and manifest.get("schema") == SCHEMA_VERSION:
            return
        envs.add(self.env_tag)
        manifest = {
            "schema": SCHEMA_VERSION,
            "environments": sorted(envs),
            "updated": time.time(),
        }
        self._atomic_write(path, json.dumps(manifest, indent=2).encode())

    # ------------------------------------------------------------------
    # framed atomic file IO
    # ------------------------------------------------------------------

    def _atomic_write(self, path: Path, data: bytes) -> None:
        path.parent.mkdir(parents=True, exist_ok=True)
        fd, tmp = tempfile.mkstemp(
            dir=str(path.parent), prefix=path.name, suffix=".tmp"
        )
        try:
            with os.fdopen(fd, "wb") as f:
                f.write(data)
            os.replace(tmp, path)   # atomic on POSIX: readers see old or new
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise

    def _write_entry(self, path: Path, obj) -> None:
        if self.readonly:
            return
        body = pickle.dumps(obj, protocol=pickle.HIGHEST_PROTOCOL)
        framed = _MAGIC + hashlib.sha256(body).digest() + body
        self._atomic_write(path, framed)
        self.stats.writes += 1

    def _read_entry(self, path: Path):
        """Decode one framed entry; quarantine anything undecodable."""
        try:
            raw = path.read_bytes()
        except FileNotFoundError:
            return None
        except OSError:
            return None
        try:
            if not raw.startswith(_MAGIC):
                raise ValueError("bad magic")
            digest, body = raw[len(_MAGIC):len(_MAGIC) + 32], \
                raw[len(_MAGIC) + 32:]
            if hashlib.sha256(body).digest() != digest:
                raise ValueError("checksum mismatch")
            return pickle.loads(body)
        except Exception:
            self._quarantine(path)
            return None

    def _quarantine(self, path: Path) -> None:
        """Move a bad entry aside (atomic) so the replica keeps serving."""
        self.stats.quarantined += 1
        if self.readonly:
            return
        target = self._env / "quarantine" / f"{path.name}.{os.getpid()}"
        try:
            target.parent.mkdir(parents=True, exist_ok=True)
            os.replace(path, target)
        except OSError:
            pass  # another replica quarantined it first

    # ------------------------------------------------------------------
    # design (ranking) entries
    # ------------------------------------------------------------------

    def _design_path(self, key: str) -> Path:
        return self._env / "designs" / f"{_digest(key)}.pkl"

    def put_design(self, key: str, spec, ranking) -> None:
        """Persist one autotune ranking (write-through on build)."""
        self._write_entry(self._design_path(key), {
            "key": key,
            "spec": spec,
            "ranking": list(ranking),
            "meta": self._meta(),
        })

    def get_design(self, key: str):
        """``(spec, ranking)`` or ``None``; key echo verified (a digest
        collision or hand-copied file serving the wrong design would be
        silently catastrophic)."""
        entry = self._read_entry(self._design_path(key))
        if entry is None or entry.get("key") != key:
            self.stats.design_misses += 1
            return None
        self.stats.design_hits += 1
        return entry["spec"], entry["ranking"]

    # ------------------------------------------------------------------
    # executable entries
    # ------------------------------------------------------------------

    def _executable_path(self, key: str, signature: str) -> Path:
        return (
            self._env / "executables"
            / f"{_digest(key)}.{_digest(signature, 16)}.pkl"
        )

    def put_executable(
        self, key: str, signature: str, kind: str, blob: bytes,
    ) -> None:
        """Persist one compiled executable for one input signature.

        One file per (runner key, signature): concurrent replicas
        compiling different batch shapes never read-modify-write a
        shared record.
        """
        self._write_entry(self._executable_path(key, signature), {
            "key": key,
            "signature": signature,
            "kind": kind,
            "blob": blob,
            "meta": self._meta(),
        })

    def get_executable(self, key: str, signature: str):
        """Rehydrated executable (callable) or ``None``.

        Entries whose recorded device count or backend disagree with the
        current process (defense in depth — the key already encodes
        both) and blobs the installed jax cannot deserialize are misses,
        never crashes: the caller recompiles from the persisted ranking.
        """
        entry = self._read_entry(self._executable_path(key, signature))
        if (
            entry is None
            or entry.get("key") != key
            or entry.get("signature") != signature
        ):
            self.stats.executable_misses += 1
            return None
        meta = entry.get("meta", {})
        if (
            meta.get("backend") != jax.default_backend()
            or meta.get("device_count") != jax.device_count()
        ):
            self.stats.executable_misses += 1
            return None
        try:
            loaded = compat.aot_deserialize(entry["kind"], entry["blob"])
        except Exception:
            # undecodable for THIS jax (e.g. executable tier written by a
            # different minor build): not corruption, just unusable here
            self.stats.executable_misses += 1
            return None
        self.stats.executable_hits += 1
        return loaded

    def _meta(self) -> dict:
        return {
            "jax": jax.__version__,
            "backend": jax.default_backend(),
            "device_count": jax.device_count(),
            "schema": SCHEMA_VERSION,
            "aot_kind": compat.AOT_KIND,
            "created": time.time(),
            "pid": os.getpid(),
        }

    # ------------------------------------------------------------------
    # telemetry (KeyStats / BucketStats persistence)
    # ------------------------------------------------------------------

    def _telemetry_path(self) -> Path:
        # legacy single-snapshot location: still read (and merged) so
        # stores written by older builds keep their history, never written
        return self._env / "telemetry.pkl"

    def _telemetry_dir(self) -> Path:
        return self._env / "telemetry"

    def put_telemetry(self, keys: dict, buckets: dict) -> None:
        """Persist THIS writer's serving counters.

        ``keys`` maps cache key tuples to :class:`KeyStats`-shaped
        dicts; ``buckets`` maps ``(structural, bucket)`` to
        :class:`BucketStats`-shaped dicts.  Each store instance owns one
        file under ``telemetry/`` and replaces it whole — no shared
        read-modify-write, so concurrent replicas can never drop each
        other's counters.  :meth:`get_telemetry` merges all writers with
        the monotone policy of :func:`merge_counters`; callers whose
        in-memory counters were seeded from restored telemetry persist
        **deltas** (:func:`subtract_counters`) so history is counted
        exactly once.
        """
        if self.readonly:
            return
        self._write_entry(
            self._telemetry_dir() / f"{self._writer_id}.pkl",
            {"keys": dict(keys), "buckets": dict(buckets)},
        )

    def get_telemetry(self) -> dict | None:
        """All writers' counters (legacy snapshot included), merged under
        the monotone-counter policy; ``None`` when nothing is persisted."""
        paths = [self._telemetry_path()]
        tdir = self._telemetry_dir()
        if tdir.is_dir():
            paths += sorted(tdir.glob("*.pkl"))
        merged = None
        for path in paths:
            entry = self._read_entry(path)
            if not isinstance(entry, dict) or "keys" not in entry:
                continue
            if merged is None:
                merged = {"keys": {}, "buckets": {}}
            for section in ("keys", "buckets"):
                for k, d in entry.get(section, {}).items():
                    have = merged[section].get(k)
                    merged[section][k] = (
                        merge_counters(have, d) if have else dict(d)
                    )
        return merged

    # ------------------------------------------------------------------
    # maintenance (the `python -m repro.store` CLI surface)
    # ------------------------------------------------------------------

    def entries(self) -> list[dict]:
        """Decoded summaries of every entry in THIS environment."""
        out = []
        for sub, etype in (("designs", "design"), ("executables",
                                                   "executable")):
            base = self._env / sub
            if not base.is_dir():
                continue
            for path in sorted(base.glob("*.pkl")):
                entry = self._read_entry(path)
                if entry is None:
                    out.append({
                        "type": etype, "file": path.name,
                        "status": "quarantined",
                    })
                    continue
                meta = entry.get("meta", {})
                out.append({
                    "type": etype,
                    "file": path.name,
                    "status": "ok",
                    "key": entry.get("key", "?"),
                    "kind": entry.get("kind"),
                    "bytes": path.stat().st_size if path.exists() else 0,
                    "jax": meta.get("jax"),
                    "backend": meta.get("backend"),
                })
        return out

    def verify(self) -> dict:
        """Decode every entry; corrupt ones are quarantined as a side
        effect.  Returns ``{"ok": n, "quarantined": n, "backlog": n}``
        where ``quarantined`` counts entries quarantined by THIS pass
        and ``backlog`` the files already sitting in this environment's
        quarantine directory from earlier runs (cleared by
        :meth:`prune`)."""
        before = self.stats.quarantined
        entries = self.entries()
        ok = sum(1 for e in entries if e["status"] == "ok")
        q = self._env / "quarantine"
        backlog = sum(1 for p in q.iterdir() if p.is_file()) \
            if q.is_dir() else 0
        return {
            "ok": ok,
            "quarantined": self.stats.quarantined - before,
            "backlog": backlog,
        }

    def environments(self) -> list[str]:
        """Every environment directory present under the root."""
        if not self.root.is_dir():
            return []
        return sorted(
            p.name for p in self.root.iterdir()
            if p.is_dir() and p.name.startswith("schema")
        )

    def prune(self, keep_current: bool = True) -> list[str]:
        """Delete stale environments (and always the quarantine of the
        current one).  Returns the removed directory names."""
        import shutil

        removed = []
        for env in self.environments():
            if keep_current and env == self.env_tag:
                q = self.root / env / "quarantine"
                if q.is_dir() and any(q.iterdir()):
                    shutil.rmtree(q, ignore_errors=True)
                    removed.append(f"{env}/quarantine")
                continue
            shutil.rmtree(self.root / env, ignore_errors=True)
            removed.append(env)
        if not self.readonly:
            self._atomic_write(
                self.root / "manifest.json",
                json.dumps({
                    "schema": SCHEMA_VERSION,
                    "environments": self.environments(),
                    "updated": time.time(),
                }, indent=2).encode(),
            )
        return removed


def as_store(store) -> DesignStore | None:
    """Normalize a ``store=`` argument: None, a path, or a DesignStore."""
    if store is None or isinstance(store, DesignStore):
        return store
    return DesignStore(store)
