"""Persistent AOT design store: the on-disk half of the FPGA-bitstream
analogy.

SASA's tuned design is synthesized **once** into a bitstream and reused
for the deployment's lifetime; :class:`repro.runtime.DesignCache` is the
in-process analogue, but it dies with the process — every server restart
re-autotunes and re-jits the whole bucket ladder.  ``DesignStore``
completes the analogy by persisting both cache levels to a directory
that N replica processes can share:

  * **design entries** — the autotune ranking (the lowered spec + the
    full :class:`repro.core.model.Prediction` list), so a warm start
    never re-enumerates the design space;
  * **executable entries** — compiled executables serialized through
    :mod:`repro.compat`'s AOT tier (whole XLA executables when the
    installed jax supports it, portable StableHLO otherwise, rankings
    only when neither is available), one file per compiled input
    signature, so a warm replica reaches its first bitwise-identical
    result without tracing or compiling anything;
  * **telemetry** — the cache's per-key :class:`KeyStats` and each
    registration's per-bucket :class:`BucketStats` counters, restored on
    warm start so restarts don't zero the inputs the
    measurement-calibrated cost model consumes.

Layout and invalidation::

    <root>/
      manifest.json                  # schema + the envs ever written
      <env>/                         # schema<N>-jax<version>-<backend>
        designs/<digest>.pkl         # ranking entries
        executables/<digest>.<sig>.pkl
        telemetry.pkl
        quarantine/                  # corrupt/undecodable entries land here

The **environment tag** bakes the store schema version, the jax version,
and the default backend into the directory name: a jax upgrade (or a
schema bump) makes every stale entry invisible — clean invalidation with
no in-place migration — and ``python -m repro.store prune`` deletes the
dead environments.  Entry keys additionally carry the structural
fingerprint, grid/bucket shape, :class:`ParallelismConfig`, platform,
and the device count the runner occupies, so a design built for one pool
is never served to a different one as if it owned its parallelism.

Every write is atomic (tmp file + ``os.replace`` in the same directory),
so concurrent replicas sharing one store directory never observe a torn
entry; concurrent writers of the *same* entry are idempotent
(last-writer-wins on identical content).  Every entry is framed with a
magic header + SHA-256 checksum: a corrupt, truncated, or undecodable
file is **quarantined** (moved aside, counted, server keeps running)
rather than crashing the replica.  Telemetry is a best-effort
observability snapshot (last-writer-wins per environment), not an exact
ledger.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import pickle
import tempfile
import time
from pathlib import Path

import jax

from repro import compat

SCHEMA_VERSION = 1

_MAGIC = b"SASA-STORE\x01"


def environment_tag(backend: str | None = None) -> str:
    """The invalidation unit: schema x jax version x backend."""
    return (
        f"schema{SCHEMA_VERSION}-jax{jax.__version__}-"
        f"{backend or jax.default_backend()}"
    )


def _digest(payload: str, n: int = 24) -> str:
    return hashlib.sha256(payload.encode()).hexdigest()[:n]


def design_key(structural: str, shape, platform, iterations) -> str:
    """Process-independent key for a ranking entry (mirrors the cache's
    design-level key)."""
    return repr(("design", structural, tuple(shape), platform, iterations))


def runner_key(
    structural: str, shape, cfg, n_used: int, iterations,
    tile_rows: int, backend: str, align_cols: int, batched: bool,
) -> str:
    """Process-independent key for a compiled-executable entry.

    The device count the runner actually occupies (``n_used``) and the
    resolved backend are part of the key, so a warm replica on a
    different pool misses here and recompiles from the persisted ranking
    instead of loading an executable laid out for other hardware.
    """
    return repr((
        "runner", structural, tuple(shape), cfg, n_used, iterations,
        tile_rows, backend, align_cols, batched,
    ))


def batch_signature(arrays) -> str:
    """Input-signature key of one staged batch: sorted (name, shape,
    dtype) triples — the unit one serialized executable covers."""
    return repr(tuple(sorted(
        (n, tuple(int(d) for d in a.shape), str(a.dtype))
        for n, a in arrays.items()
    )))


@dataclasses.dataclass
class StoreStats:
    design_hits: int = 0
    design_misses: int = 0
    executable_hits: int = 0
    executable_misses: int = 0
    writes: int = 0
    quarantined: int = 0

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


class DesignStore:
    """A persistent, multi-process-safe design store rooted at ``root``.

    ``readonly=True`` never writes (no manifest update, no entry or
    telemetry writes) — for fleet replicas that must not mutate a store
    baked into an image.  All ``get_*`` methods return ``None`` on miss
    and *never raise on bad entries*: undecodable files are quarantined
    and reported as misses.
    """

    def __init__(self, root, readonly: bool = False,
                 env_tag: str | None = None):
        self.root = Path(root)
        self.readonly = readonly
        self.env_tag = env_tag or environment_tag()
        self.stats = StoreStats()
        self._env = self.root / self.env_tag
        if not readonly:
            for sub in ("designs", "executables", "quarantine"):
                (self._env / sub).mkdir(parents=True, exist_ok=True)
            self._update_manifest()

    # ------------------------------------------------------------------
    # manifest
    # ------------------------------------------------------------------

    def _update_manifest(self) -> None:
        path = self.root / "manifest.json"
        manifest = {"schema": SCHEMA_VERSION, "environments": []}
        if path.exists():
            try:
                manifest = json.loads(path.read_text())
            except (json.JSONDecodeError, OSError):
                pass  # rewrite a fresh manifest below
        envs = set(manifest.get("environments", ()))
        if self.env_tag in envs and manifest.get("schema") == SCHEMA_VERSION:
            return
        envs.add(self.env_tag)
        manifest = {
            "schema": SCHEMA_VERSION,
            "environments": sorted(envs),
            "updated": time.time(),
        }
        self._atomic_write(path, json.dumps(manifest, indent=2).encode())

    # ------------------------------------------------------------------
    # framed atomic file IO
    # ------------------------------------------------------------------

    def _atomic_write(self, path: Path, data: bytes) -> None:
        path.parent.mkdir(parents=True, exist_ok=True)
        fd, tmp = tempfile.mkstemp(
            dir=str(path.parent), prefix=path.name, suffix=".tmp"
        )
        try:
            with os.fdopen(fd, "wb") as f:
                f.write(data)
            os.replace(tmp, path)   # atomic on POSIX: readers see old or new
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise

    def _write_entry(self, path: Path, obj) -> None:
        if self.readonly:
            return
        body = pickle.dumps(obj, protocol=pickle.HIGHEST_PROTOCOL)
        framed = _MAGIC + hashlib.sha256(body).digest() + body
        self._atomic_write(path, framed)
        self.stats.writes += 1

    def _read_entry(self, path: Path):
        """Decode one framed entry; quarantine anything undecodable."""
        try:
            raw = path.read_bytes()
        except FileNotFoundError:
            return None
        except OSError:
            return None
        try:
            if not raw.startswith(_MAGIC):
                raise ValueError("bad magic")
            digest, body = raw[len(_MAGIC):len(_MAGIC) + 32], \
                raw[len(_MAGIC) + 32:]
            if hashlib.sha256(body).digest() != digest:
                raise ValueError("checksum mismatch")
            return pickle.loads(body)
        except Exception:
            self._quarantine(path)
            return None

    def _quarantine(self, path: Path) -> None:
        """Move a bad entry aside (atomic) so the replica keeps serving."""
        self.stats.quarantined += 1
        if self.readonly:
            return
        target = self._env / "quarantine" / f"{path.name}.{os.getpid()}"
        try:
            target.parent.mkdir(parents=True, exist_ok=True)
            os.replace(path, target)
        except OSError:
            pass  # another replica quarantined it first

    # ------------------------------------------------------------------
    # design (ranking) entries
    # ------------------------------------------------------------------

    def _design_path(self, key: str) -> Path:
        return self._env / "designs" / f"{_digest(key)}.pkl"

    def put_design(self, key: str, spec, ranking) -> None:
        """Persist one autotune ranking (write-through on build)."""
        self._write_entry(self._design_path(key), {
            "key": key,
            "spec": spec,
            "ranking": list(ranking),
            "meta": self._meta(),
        })

    def get_design(self, key: str):
        """``(spec, ranking)`` or ``None``; key echo verified (a digest
        collision or hand-copied file serving the wrong design would be
        silently catastrophic)."""
        entry = self._read_entry(self._design_path(key))
        if entry is None or entry.get("key") != key:
            self.stats.design_misses += 1
            return None
        self.stats.design_hits += 1
        return entry["spec"], entry["ranking"]

    # ------------------------------------------------------------------
    # executable entries
    # ------------------------------------------------------------------

    def _executable_path(self, key: str, signature: str) -> Path:
        return (
            self._env / "executables"
            / f"{_digest(key)}.{_digest(signature, 16)}.pkl"
        )

    def put_executable(
        self, key: str, signature: str, kind: str, blob: bytes,
    ) -> None:
        """Persist one compiled executable for one input signature.

        One file per (runner key, signature): concurrent replicas
        compiling different batch shapes never read-modify-write a
        shared record.
        """
        self._write_entry(self._executable_path(key, signature), {
            "key": key,
            "signature": signature,
            "kind": kind,
            "blob": blob,
            "meta": self._meta(),
        })

    def get_executable(self, key: str, signature: str):
        """Rehydrated executable (callable) or ``None``.

        Entries whose recorded device count or backend disagree with the
        current process (defense in depth — the key already encodes
        both) and blobs the installed jax cannot deserialize are misses,
        never crashes: the caller recompiles from the persisted ranking.
        """
        entry = self._read_entry(self._executable_path(key, signature))
        if (
            entry is None
            or entry.get("key") != key
            or entry.get("signature") != signature
        ):
            self.stats.executable_misses += 1
            return None
        meta = entry.get("meta", {})
        if (
            meta.get("backend") != jax.default_backend()
            or meta.get("device_count") != jax.device_count()
        ):
            self.stats.executable_misses += 1
            return None
        try:
            loaded = compat.aot_deserialize(entry["kind"], entry["blob"])
        except Exception:
            # undecodable for THIS jax (e.g. executable tier written by a
            # different minor build): not corruption, just unusable here
            self.stats.executable_misses += 1
            return None
        self.stats.executable_hits += 1
        return loaded

    def _meta(self) -> dict:
        return {
            "jax": jax.__version__,
            "backend": jax.default_backend(),
            "device_count": jax.device_count(),
            "schema": SCHEMA_VERSION,
            "aot_kind": compat.AOT_KIND,
            "created": time.time(),
            "pid": os.getpid(),
        }

    # ------------------------------------------------------------------
    # telemetry (KeyStats / BucketStats persistence)
    # ------------------------------------------------------------------

    def _telemetry_path(self) -> Path:
        return self._env / "telemetry.pkl"

    def put_telemetry(self, keys: dict, buckets: dict) -> None:
        """Persist serving counters (merged over what is already there).

        ``keys`` maps cache key tuples to :class:`KeyStats`-shaped
        dicts; ``buckets`` maps ``(structural, bucket)`` to
        :class:`BucketStats`-shaped dicts.  Merge policy is
        last-writer-wins per key: telemetry is observability input for
        the measurement-calibrated cost model, not an exact ledger.
        """
        if self.readonly:
            return
        current = self.get_telemetry() or {"keys": {}, "buckets": {}}
        current["keys"].update(keys)
        current["buckets"].update(buckets)
        self._write_entry(self._telemetry_path(), current)

    def get_telemetry(self) -> dict | None:
        entry = self._read_entry(self._telemetry_path())
        if not isinstance(entry, dict) or "keys" not in entry:
            return None
        return entry

    # ------------------------------------------------------------------
    # maintenance (the `python -m repro.store` CLI surface)
    # ------------------------------------------------------------------

    def entries(self) -> list[dict]:
        """Decoded summaries of every entry in THIS environment."""
        out = []
        for sub, etype in (("designs", "design"), ("executables",
                                                   "executable")):
            base = self._env / sub
            if not base.is_dir():
                continue
            for path in sorted(base.glob("*.pkl")):
                entry = self._read_entry(path)
                if entry is None:
                    out.append({
                        "type": etype, "file": path.name,
                        "status": "quarantined",
                    })
                    continue
                meta = entry.get("meta", {})
                out.append({
                    "type": etype,
                    "file": path.name,
                    "status": "ok",
                    "key": entry.get("key", "?"),
                    "kind": entry.get("kind"),
                    "bytes": path.stat().st_size if path.exists() else 0,
                    "jax": meta.get("jax"),
                    "backend": meta.get("backend"),
                })
        return out

    def verify(self) -> dict:
        """Decode every entry; corrupt ones are quarantined as a side
        effect.  Returns ``{"ok": n, "quarantined": n, "backlog": n}``
        where ``quarantined`` counts entries quarantined by THIS pass
        and ``backlog`` the files already sitting in this environment's
        quarantine directory from earlier runs (cleared by
        :meth:`prune`)."""
        before = self.stats.quarantined
        entries = self.entries()
        ok = sum(1 for e in entries if e["status"] == "ok")
        q = self._env / "quarantine"
        backlog = sum(1 for p in q.iterdir() if p.is_file()) \
            if q.is_dir() else 0
        return {
            "ok": ok,
            "quarantined": self.stats.quarantined - before,
            "backlog": backlog,
        }

    def environments(self) -> list[str]:
        """Every environment directory present under the root."""
        if not self.root.is_dir():
            return []
        return sorted(
            p.name for p in self.root.iterdir()
            if p.is_dir() and p.name.startswith("schema")
        )

    def prune(self, keep_current: bool = True) -> list[str]:
        """Delete stale environments (and always the quarantine of the
        current one).  Returns the removed directory names."""
        import shutil

        removed = []
        for env in self.environments():
            if keep_current and env == self.env_tag:
                q = self.root / env / "quarantine"
                if q.is_dir() and any(q.iterdir()):
                    shutil.rmtree(q, ignore_errors=True)
                    removed.append(f"{env}/quarantine")
                continue
            shutil.rmtree(self.root / env, ignore_errors=True)
            removed.append(env)
        if not self.readonly:
            self._atomic_write(
                self.root / "manifest.json",
                json.dumps({
                    "schema": SCHEMA_VERSION,
                    "environments": self.environments(),
                    "updated": time.time(),
                }, indent=2).encode(),
            )
        return removed


def as_store(store) -> DesignStore | None:
    """Normalize a ``store=`` argument: None, a path, or a DesignStore."""
    if store is None or isinstance(store, DesignStore):
        return store
    return DesignStore(store)
