"""Shape bucketing: one compiled design serves a whole family of grid sizes.

SASA's economics rest on amortizing one expensive artefact (the FPGA
bitstream; here the auto-tuned jitted design) across many invocations.
Compiling one design per *exact* grid shape breaks that the moment traffic
carries heterogeneous geometries.  This module maps a requested grid shape
onto a small ladder of padded canonical **bucket** shapes, so a kernel
registration owns at most a handful of compiled designs (one per bucket
actually hit) instead of one per distinct request shape.

Two pieces:

  * :class:`ShapeBucketer` — the bucket-ladder policy.  By default every
    dimension rounds up to the next power of two (floored at ``min_size``);
    alternatively callers supply an explicit per-dimension ladder of sizes.
    **Trade-off:** a coarser ladder (pure powers of two) means fewer
    compiled designs (less compile time, fewer cached executors) but more
    padded cells per dispatch (wasted FLOPs and HBM traffic up to ~4x for a
    2D grid just past a rung); a finer user ladder caps the padding waste
    at the cost of more designs.  ``max_shape`` bounds the largest bucket
    so one oversized request cannot force a huge compile.

  * the **pad-and-mask spec transform** — :func:`bucket_spec` rewrites a
    stencil spec onto the bucket shape and threads a streamed ``_mask``
    input (1.0 on the real grid, 0.0 on the padding) *multiplied into
    every stage*.  Because every executor (Pallas kernel, jnp fused
    fallback, all shard_map variants) evaluates stages through the same
    expression tree, the mask re-imposes the real grid's exterior-zero
    boundary at every stage of every fused iteration, in-kernel — this is
    the halo-padded-block trick of combined spatial/temporal blocking
    schemes, applied at the whole-grid level.  Interior cells compute
    ``expr * 1.0``, so results are bit-identical to running the unpadded
    grid; padding cells compute ``expr * 0.0 == 0.0``, exactly the zeros
    an unpadded run reads from its exterior.  Kernels whose padding cells
    could compute non-finite values (a division by streamed data: 0/0 or
    x/0 would survive the mask multiply as NaN) are rejected at transform
    time — see :func:`check_maskable`; serve those exact-shape.

    Boundary rules (docs/DESIGN.md §Boundary semantics): a ``constant v``
    boundary is re-imposed in-kernel by the mask-plus-offset form
    ``expr * m + v * (1 - m)`` with the bucket margin host-padded to
    ``v``; ``replicate``/``periodic`` boundaries depend on per-request
    edge positions and evolve every iteration, so they are refused at
    registration — those kernels are served exact-shape instead.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.core.spec import BinOp, Num, Ref, StencilSpec, refs_in, walk


def next_pow2(n: int) -> int:
    """Smallest power of two >= n (n >= 1)."""
    return 1 << (max(int(n), 1) - 1).bit_length()


@dataclasses.dataclass(frozen=True)
class ShapeBucketer:
    """Maps a requested grid shape to a padded canonical bucket shape.

    ``ladder`` — optional per-dimension rung lists; each dimension resolves
    to its smallest rung >= the requested size (a request exceeding the top
    rung raises).  Without a ladder, each dimension rounds up to the next
    power of two, floored at ``min_size``.  ``max_shape`` (optional) caps
    every bucket dimension; oversized requests raise instead of silently
    compiling an unbounded design.
    """

    ladder: tuple[tuple[int, ...], ...] | None = None
    min_size: int = 8
    max_shape: tuple[int, ...] | None = None

    def __post_init__(self):
        if self.ladder is not None:
            norm = tuple(
                tuple(sorted(int(x) for x in dim)) for dim in self.ladder
            )
            for dim in norm:
                if not dim or any(x < 1 for x in dim):
                    raise ValueError(f"ladder rungs must be >= 1, got {dim}")
            object.__setattr__(self, "ladder", norm)
        if self.max_shape is not None:
            object.__setattr__(
                self, "max_shape", tuple(int(x) for x in self.max_shape)
            )

    def bucket_for(self, shape: Sequence[int]) -> tuple[int, ...]:
        """The canonical bucket shape serving ``shape`` (>= it per dim)."""
        shape = tuple(int(s) for s in shape)
        if any(s < 1 for s in shape):
            raise ValueError(f"grid shape must be positive, got {shape}")
        if self.ladder is not None:
            if len(self.ladder) != len(shape):
                raise ValueError(
                    f"{len(shape)}-D shape {shape} vs "
                    f"{len(self.ladder)}-D bucket ladder"
                )
            bucket = []
            for d, (size, rungs) in enumerate(zip(shape, self.ladder)):
                for rung in rungs:
                    if rung >= size:
                        bucket.append(rung)
                        break
                else:
                    raise ValueError(
                        f"dim {d} size {size} exceeds the bucket ladder's "
                        f"top rung {rungs[-1]}"
                    )
            bucket = tuple(bucket)
        else:
            bucket = tuple(max(next_pow2(s), self.min_size) for s in shape)
        if self.max_shape is not None:
            if len(self.max_shape) != len(bucket):
                raise ValueError(
                    f"{len(bucket)}-D shape {shape} vs "
                    f"{len(self.max_shape)}-D max_shape"
                )
            if any(b > m for b, m in zip(bucket, self.max_shape)):
                raise ValueError(
                    f"shape {shape} buckets to {bucket}, exceeding "
                    f"max_shape {self.max_shape}"
                )
        return bucket


# --------------------------------------------------------------------------
# Spec transforms: re-shape + in-kernel exterior-zero mask
# --------------------------------------------------------------------------


def with_shape(spec: StencilSpec, shape: Sequence[int]) -> StencilSpec:
    """The same stencil structure declared on a different grid shape."""
    shape = tuple(int(s) for s in shape)
    if len(shape) != spec.ndim:
        raise ValueError(
            f"spec {spec.name!r} is {spec.ndim}-D, got shape {shape}"
        )
    inputs = {n: (dt, shape) for n, (dt, _) in spec.inputs.items()}
    return dataclasses.replace(spec, inputs=inputs)


def mask_input_name(spec: StencilSpec) -> str:
    """Collision-free name for the streamed mask input of ``spec``."""
    taken = set(spec.inputs) | {s.name for s in spec.stages}
    name = "_mask"
    while name in taken:
        name += "_"
    return name


def check_maskable(spec: StencilSpec) -> None:
    """Reject specs the streamed-mask trick cannot serve bit-exactly.

    Masking relies on ``x * 0.0 == 0.0``, which fails for ``x`` = inf/NaN.
    Padding cells hold zeros, so a stage that *divides by streamed data*
    (any array reference in a denominator) can produce 0/0 or x/0 on the
    padding; the resulting NaN survives the mask multiply and bleeds into
    the real grid on the next iteration.  Such kernels must be served
    exact-shape (division by constants — every kernel in the benchmark
    suite — is fine).

    Boundary rules: ``zero`` and ``constant`` boundaries are re-imposed
    in-kernel (mask multiply, respectively mask + offset — see
    :func:`masked_spec`).  ``replicate``/``periodic`` exteriors depend on
    per-request edge *positions* inside the shared bucket design, which a
    streamed 0/1 mask cannot express: the boundary values themselves
    evolve every iteration, so a host-side pad into the bucket margin
    diverges after the first iteration.  Those specs are refused at
    registration time — wrong edges are never served silently.
    """
    if spec.boundary.kind in ("replicate", "periodic"):
        raise ValueError(
            f"spec {spec.name!r} declares a {spec.boundary.kind!r} "
            "boundary: the streamed bucket mask can only re-impose "
            "zero/constant exteriors in-kernel, so this kernel cannot be "
            "shape-bucketed — serve it exact-shape instead (register "
            "without bucketing)"
        )
    for stage in spec.stages:
        for node in walk(stage.expr):
            if isinstance(node, BinOp) and node.op == "/":
                denom_refs = refs_in(node.rhs)
                if denom_refs:
                    names = sorted({r.name for r in denom_refs})
                    raise ValueError(
                        f"spec {spec.name!r} stage {stage.name!r} divides "
                        f"by streamed data ({', '.join(names)}): zero "
                        "padding would produce non-finite values that "
                        "survive the exterior mask, so this kernel cannot "
                        "be shape-bucketed — serve it exact-shape instead"
                    )


def boundary_fill(spec: StencilSpec) -> float:
    """The value host padding must carry outside the real grid."""
    return spec.boundary.value if spec.boundary.kind == "constant" else 0.0


def masked_spec(spec: StencilSpec) -> StencilSpec:
    """Add a constant (non-iterated) mask input woven into every stage.

    With the mask 1.0 on a subregion and 0.0 elsewhere, every stage's
    writeback outside the subregion is re-imposed to the spec's boundary
    value at every iteration in every executor — ``expr * m`` for a zero
    boundary, ``expr * m + v * (1 - m)`` for a constant-``v`` boundary —
    which reproduces the subregion's boundary rule exactly (local stages
    included: their padded-region values are re-imposed before any
    consumer reads them at an offset).  Raises for kernels the mask trick
    cannot serve (replicate/periodic boundaries, division by streamed
    data — see :func:`check_maskable`).
    """
    check_maskable(spec)
    mname = mask_input_name(spec)
    mref = Ref(mname, (0,) * spec.ndim)
    fill = boundary_fill(spec)

    def weave(expr):
        masked = BinOp("*", expr, mref)
        if fill == 0.0:
            return masked
        # constant boundary: out-of-grid cells read v, in-grid cells are
        # expr*1 + v*0 (bit-identical to expr up to +0.0)
        return BinOp(
            "+", masked, BinOp("*", Num(fill), BinOp("-", Num(1.0), mref))
        )

    stages = tuple(
        dataclasses.replace(st, expr=weave(st.expr)) for st in spec.stages
    )
    inputs = dict(spec.inputs)
    inputs[mname] = (spec.dtype, spec.shape)
    out = dataclasses.replace(
        spec, name=spec.name + "@masked", inputs=inputs, stages=stages
    )
    out.validate()
    return out


def bucket_spec(spec: StencilSpec, bucket_shape: Sequence[int]) -> StencilSpec:
    """The masked bucket-shaped spec a bucket design is compiled from.

    Per-request fit (grid <= bucket) is validated by the bucket runner;
    the spec's own declared shape only contributes structure here.
    """
    return masked_spec(with_shape(spec, bucket_shape))


# --------------------------------------------------------------------------
# Host-side pad / mask helpers (numpy: used while staging micro-batches)
# --------------------------------------------------------------------------


def grid_mask_host(
    shape: Sequence[int], bucket_shape: Sequence[int], dtype="float32"
) -> np.ndarray:
    """Bucket-shaped mask: 1 on the leading ``shape`` region, 0 on padding."""
    shape, bucket_shape = tuple(shape), tuple(bucket_shape)
    if len(shape) != len(bucket_shape) or any(
        s > b for s, b in zip(shape, bucket_shape)
    ):
        raise ValueError(f"grid {shape} does not fit bucket {bucket_shape}")
    m = np.zeros(bucket_shape, dtype=np.dtype(dtype))
    m[tuple(slice(0, s) for s in shape)] = 1
    return m


def pad_grid(
    a: np.ndarray, bucket_shape: Sequence[int], fill: float = 0.0
) -> np.ndarray:
    """Pad one grid (no batch axis) up to the bucket shape with ``fill``.

    ``fill`` is the spec's boundary value (:func:`boundary_fill`): under a
    constant-``v`` boundary, real edge cells read ``v`` from the bucket
    margin, exactly what an unpadded run reads from its exterior.
    """
    a = np.asarray(a)
    bucket_shape = tuple(bucket_shape)
    if a.ndim != len(bucket_shape) or any(
        s > b for s, b in zip(a.shape, bucket_shape)
    ):
        raise ValueError(
            f"grid shaped {a.shape} does not fit bucket {bucket_shape}"
        )
    if tuple(a.shape) == bucket_shape:
        return a
    return np.pad(
        a, [(0, b - s) for s, b in zip(a.shape, bucket_shape)],
        constant_values=fill,
    )


def pad_batch(
    a: np.ndarray, bucket_shape: Sequence[int], fill: float = 0.0
) -> np.ndarray:
    """Pad a batched array ``(B,) + grid`` up to ``(B,) + bucket``."""
    a = np.asarray(a)
    bucket_shape = tuple(bucket_shape)
    if a.ndim != len(bucket_shape) + 1 or any(
        s > b for s, b in zip(a.shape[1:], bucket_shape)
    ):
        raise ValueError(
            f"batched array shaped {a.shape} does not fit (B,) + "
            f"{bucket_shape}"
        )
    if tuple(a.shape[1:]) == bucket_shape:
        return a
    return np.pad(
        a,
        [(0, 0)] + [(0, b - s) for s, b in zip(a.shape[1:], bucket_shape)],
        constant_values=fill,
    )
