"""Shape bucketing: one compiled design serves a whole family of grid sizes.

SASA's economics rest on amortizing one expensive artefact (the FPGA
bitstream; here the auto-tuned jitted design) across many invocations.
Compiling one design per *exact* grid shape breaks that the moment traffic
carries heterogeneous geometries.  This module maps a requested grid shape
onto a small ladder of padded canonical **bucket** shapes, so a kernel
registration owns at most a handful of compiled designs (one per bucket
actually hit) instead of one per distinct request shape.

Three pieces:

  * :class:`ShapeBucketer` — the bucket-ladder policy.  By default every
    dimension rounds up to the next power of two (floored at ``min_size``);
    alternatively callers supply an explicit per-dimension ladder of sizes.
    **Trade-off:** a coarser ladder (pure powers of two) means fewer
    compiled designs (less compile time, fewer cached executors) but more
    padded cells per dispatch (wasted FLOPs and HBM traffic up to ~4x for a
    2D grid just past a rung); a finer user ladder caps the padding waste
    at the cost of more designs.  ``max_shape`` bounds the largest bucket
    so one oversized request cannot force a huge compile.

  * the **spec transforms** — :func:`bucket_spec` rewrites a stencil spec
    onto the bucket shape and threads the streamed inputs its boundary
    mode needs (see below); the compiled design is shape-agnostic within
    its bucket, every per-request quantity arrives as data.

  * the **host staging plan** — :func:`bucket_plan` captures everything
    the serving layers need to stage one request into a bucket design:
    where the real grid sits inside the bucket, how the margin is filled,
    which streamed service arrays (mask / halo indices) ride along, and
    which output slice to return.

Boundary rules (docs/DESIGN.md §Boundaries × bucketed serving) — every
mode is bucketable, each by the streaming trick that fits its semantics:

  ``zero``        streamed ``_mask`` input (1 on the real grid, 0 on the
                  padding) multiplied into every stage: padding cells
                  compute ``expr * 0.0 == 0.0``, exactly the zeros an
                  unpadded run reads from its exterior.  Bit-identical.
  ``constant v``  mask-plus-offset form ``expr * m + v * (1 - m)`` with
                  the bucket margin host-padded to ``v``.  Bit-identical.
  ``replicate``   ``_mask`` plus per-dimension streamed **halo-index**
                  inputs: after every stage the shared trapezoid helper
                  gathers each padding cell from its clamped nearest real
                  edge cell (:func:`repro.kernels.blockops.streamed_halo_fixup`),
                  re-creating the clamped exterior in-kernel from
                  per-request data.  Bit-identical: real cells compute
                  ``expr * 1.0`` over identical operand values.
  ``periodic``    **halo-streamed data**: the host lays the wrapped
                  extension of the real grid into a reserved margin of
                  ``iterations * radius`` cells per side
                  (:func:`bucket_margins`), computed from the real shape
                  at pad time.  A stencil commutes with its own periodic
                  extension, so the margin evolves as correct halo data;
                  staleness creeps inward from the bucket edge at
                  ``radius`` per iteration (the whole-run trapezoid
                  argument) and never reaches the real region.  The
                  compiled design is a plain zero-boundary bucket
                  iteration — no wrap machinery, no mask — and the real
                  region is bit-identical to unpadded execution.  On
                  single-device paths the serving layer passes
                  ``wrap_rounds`` (the design's fused depth ``s``), which
                  shrinks the margin to ``s * radius``: streamed
                  per-dimension **wrap maps** re-impose the wrap on the
                  iterate between fused rounds
                  (:func:`repro.kernels.blockops.wrap_round_fixup`), so
                  the margin only has to survive one round.  shard_map
                  designs keep the wide ``iterations * radius`` margin
                  (the re-wrap would need a cross-shard collective; see
                  the TODO in :mod:`repro.core.distribute`).

Kernels whose padding cells could compute non-finite values (a division
whose divisor interval contains zero: 0/0 or x/0 would survive the mask
multiply as NaN) are rejected at transform time by the static analyzer —
see :func:`repro.core.analysis.require_bucketable`; serve those
exact-shape.  Divisors provably bounded away from zero (constants,
``abs(...) + c``) are admitted.
"""
from __future__ import annotations

import dataclasses
import functools
import warnings
from typing import Sequence

import numpy as np

from repro.core.analysis import require_bucketable
from repro.core.spec import (
    BinOp,
    Num,
    Ref,
    StencilSpec,
    ZERO_BOUNDARY,
)


def next_pow2(n: int) -> int:
    """Smallest power of two >= n (n >= 1)."""
    return 1 << (max(int(n), 1) - 1).bit_length()


@dataclasses.dataclass(frozen=True)
class ShapeBucketer:
    """Maps a requested grid shape to a padded canonical bucket shape.

    ``ladder`` — optional per-dimension rung lists; each dimension resolves
    to its smallest rung >= the requested size (a request exceeding the top
    rung raises).  Without a ladder, each dimension rounds up to the next
    power of two, floored at ``min_size``.  ``max_shape`` (optional) caps
    every bucket dimension; oversized requests raise instead of silently
    compiling an unbounded design.
    """

    ladder: tuple[tuple[int, ...], ...] | None = None
    min_size: int = 8
    max_shape: tuple[int, ...] | None = None

    def __post_init__(self):
        if self.ladder is not None:
            norm = tuple(
                tuple(sorted(int(x) for x in dim)) for dim in self.ladder
            )
            for dim in norm:
                if not dim or any(x < 1 for x in dim):
                    raise ValueError(f"ladder rungs must be >= 1, got {dim}")
            object.__setattr__(self, "ladder", norm)
        if self.max_shape is not None:
            object.__setattr__(
                self, "max_shape", tuple(int(x) for x in self.max_shape)
            )

    def bucket_for(self, shape: Sequence[int]) -> tuple[int, ...]:
        """The canonical bucket shape serving ``shape`` (>= it per dim)."""
        shape = tuple(int(s) for s in shape)
        if any(s < 1 for s in shape):
            raise ValueError(f"grid shape must be positive, got {shape}")
        if self.ladder is not None:
            if len(self.ladder) != len(shape):
                raise ValueError(
                    f"{len(shape)}-D shape {shape} vs "
                    f"{len(self.ladder)}-D bucket ladder"
                )
            bucket = []
            for d, (size, rungs) in enumerate(zip(shape, self.ladder)):
                for rung in rungs:
                    if rung >= size:
                        bucket.append(rung)
                        break
                else:
                    raise ValueError(
                        f"dim {d} size {size} exceeds the bucket ladder's "
                        f"top rung {rungs[-1]}"
                    )
            bucket = tuple(bucket)
        else:
            bucket = tuple(max(next_pow2(s), self.min_size) for s in shape)
        if self.max_shape is not None:
            if len(self.max_shape) != len(bucket):
                raise ValueError(
                    f"{len(bucket)}-D shape {shape} vs "
                    f"{len(self.max_shape)}-D max_shape"
                )
            if any(b > m for b, m in zip(bucket, self.max_shape)):
                raise ValueError(
                    f"shape {shape} buckets to {bucket}, exceeding "
                    f"max_shape {self.max_shape}"
                )
        return bucket


# --------------------------------------------------------------------------
# Spec transforms: re-shape + streamed boundary inputs
# --------------------------------------------------------------------------


def with_shape(spec: StencilSpec, shape: Sequence[int]) -> StencilSpec:
    """The same stencil structure declared on a different grid shape."""
    shape = tuple(int(s) for s in shape)
    if len(shape) != spec.ndim:
        raise ValueError(
            f"spec {spec.name!r} is {spec.ndim}-D, got shape {shape}"
        )
    inputs = {n: (dt, shape) for n, (dt, _) in spec.inputs.items()}
    return dataclasses.replace(spec, inputs=inputs)


def _fresh_name(spec: StencilSpec, base: str, taken=()) -> str:
    """Collision-free streamed-input name for ``spec``."""
    used = set(spec.inputs) | {s.name for s in spec.stages} | set(taken)
    name = base
    while name in used:
        name += "_"
    return name


def mask_input_name(spec: StencilSpec) -> str:
    """Collision-free name for the streamed mask input of ``spec``."""
    return _fresh_name(spec, "_mask")


def halo_index_names(spec: StencilSpec) -> tuple[str, ...]:
    """Collision-free per-dimension streamed halo-index input names."""
    names: list[str] = []
    for d in range(spec.ndim):
        names.append(_fresh_name(spec, f"_bidx{d}", taken=names))
    return tuple(names)


def wrap_index_names(spec: StencilSpec) -> tuple[str, ...]:
    """Collision-free per-dimension streamed wrap-index input names."""
    names: list[str] = []
    for d in range(spec.ndim):
        names.append(_fresh_name(spec, f"_widx{d}", taken=names))
    return tuple(names)


def check_bucketable(spec: StencilSpec) -> None:
    """Deprecated: use :func:`repro.core.analysis.require_bucketable`.

    Historically this refused *any* array reference in a denominator
    syntactically.  The static analyzer's interval domain now proves
    divisors nonzero instead — admitting provably-safe kernels like
    ``x / (abs(y) + 2)`` that the syntactic rule rejected — so this shim
    just delegates and warns.  Raises the same ``ValueError`` family
    (:class:`repro.core.analysis.VerificationError`) for kernels whose
    divisor interval contains zero.
    """
    warnings.warn(
        "check_bucketable is deprecated; use "
        "repro.core.analysis.require_bucketable (interval-based division "
        "safety) instead",
        DeprecationWarning,
        stacklevel=2,
    )
    require_bucketable(spec)


def boundary_fill(spec: StencilSpec) -> float:
    """The value host padding must carry outside the real grid."""
    return spec.boundary.value if spec.boundary.kind == "constant" else 0.0


def bucket_margins(
    spec: StencilSpec,
    iterations: int | None = None,
    wrap_rounds: int | None = None,
) -> tuple[int, ...]:
    """Per-dimension margin a bucket reserves on *each* side of the grid.

    Only ``periodic`` needs one: the wrapped extension is streamed in as
    data and goes stale from the bucket edge inward at ``spec.radius``
    per iteration.  With ``wrap_rounds=None`` (the legacy wide margin)
    the margin covers the whole run (``iterations * radius``); with
    ``wrap_rounds`` set, the executors re-impose the wrap between fused
    rounds from streamed wrap maps, so the margin only has to survive
    one round: ``wrap_rounds * radius``.  All other modes re-impose
    their exterior in-kernel every stage and place the grid at the
    bucket origin.
    """
    if spec.boundary.kind != "periodic":
        return (0,) * spec.ndim
    it = spec.iterations if iterations is None else iterations
    rounds = int(it) if wrap_rounds is None else min(int(wrap_rounds), int(it))
    return (max(rounds, 1) * spec.radius,) * spec.ndim


def padded_request_shape(
    spec: StencilSpec,
    shape: Sequence[int],
    iterations: int | None = None,
    wrap_rounds: int | None = None,
) -> tuple[int, ...]:
    """The shape bucket routing must fit: grid plus both halo margins."""
    shape = tuple(int(s) for s in shape)
    if len(shape) != spec.ndim:
        raise ValueError(
            f"spec {spec.name!r} is {spec.ndim}-D, got shape {shape}"
        )
    margins = bucket_margins(spec, iterations, wrap_rounds)
    return tuple(s + 2 * m for s, m in zip(shape, margins))


def masked_spec(
    spec: StencilSpec, wrap_rounds: int | None = None
) -> StencilSpec:
    """The streamed-boundary spec a bucket design is compiled from.

    ``zero``/``constant`` weave a constant (non-iterated) ``_mask`` input
    into every stage — ``expr * m`` for zero, ``expr * m + v * (1 - m)``
    for constant-``v`` — so every executor re-imposes the real grid's
    exterior at every stage of every fused iteration, in-kernel.

    ``replicate`` additionally threads per-dimension int32 halo-index
    inputs and records them in ``halo_index_inputs``: the shared
    trapezoid helper gathers every padding cell from its clamped nearest
    real edge cell after each stage, *then* the bucket-level replicate
    rule clamps out-of-bucket reads to the (freshly re-imposed) belt —
    so leading edges (always real) and trailing edges both see the
    clamped exterior of the real grid.

    ``periodic`` threads nothing by default: the design is the plain
    zero-boundary iteration of the bucket grid, and the wrapped exterior
    arrives as host-streamed margin data (see :func:`bucket_margins`).
    Masking would zero the evolving halo, so the real region is
    recovered by output slicing instead.  With ``wrap_rounds`` set
    (single-device narrow-margin serving) the spec additionally threads
    per-dimension int32 **wrap-index** inputs and records them (plus the
    round-depth cap) in ``wrap_index_inputs``/``wrap_round_depth``:
    executors re-impose the wrap between fused rounds from the streamed
    maps, so the margin shrinks from ``iterations * radius`` to
    ``wrap_rounds * radius``.

    Raises for kernels no bucket transform can serve (a divisor whose
    value interval contains zero — see
    :func:`repro.core.analysis.require_bucketable`).
    """
    require_bucketable(spec)
    kind = spec.boundary.kind
    if kind != "periodic" and wrap_rounds is not None:
        raise ValueError(
            f"wrap_rounds only applies to periodic boundaries, not "
            f"{kind!r}"
        )
    if kind == "periodic":
        if wrap_rounds is None:
            out = dataclasses.replace(
                spec, name=spec.name + "@halo", boundary=ZERO_BOUNDARY
            )
            out.validate()
            return out
        wrap_rounds = max(int(wrap_rounds), 1)
        widx = wrap_index_names(spec)
        inputs = dict(spec.inputs)
        for n in widx:
            inputs[n] = ("int32", spec.shape)
        out = dataclasses.replace(
            spec, name=spec.name + f"@wrap{wrap_rounds}",
            boundary=ZERO_BOUNDARY, inputs=inputs,
            wrap_index_inputs=widx, wrap_round_depth=wrap_rounds,
        )
        out.validate()
        return out
    mname = mask_input_name(spec)
    mref = Ref(mname, (0,) * spec.ndim)
    fill = boundary_fill(spec)

    def weave(expr):
        masked = BinOp("*", expr, mref)
        if fill == 0.0:
            return masked
        # constant boundary: out-of-grid cells read v, in-grid cells are
        # expr*1 + v*0 (bit-identical to expr up to +0.0)
        return BinOp(
            "+", masked, BinOp("*", Num(fill), BinOp("-", Num(1.0), mref))
        )

    stages = tuple(
        dataclasses.replace(st, expr=weave(st.expr)) for st in spec.stages
    )
    inputs = dict(spec.inputs)
    inputs[mname] = (spec.dtype, spec.shape)
    halo_idx: tuple[str, ...] = ()
    if kind == "replicate":
        halo_idx = halo_index_names(spec)
        for n in halo_idx:
            inputs[n] = ("int32", spec.shape)
    out = dataclasses.replace(
        spec, name=spec.name + "@masked", inputs=inputs, stages=stages,
        halo_index_inputs=halo_idx,
    )
    out.validate()
    return out


def bucket_spec(
    spec: StencilSpec,
    bucket_shape: Sequence[int],
    wrap_rounds: int | None = None,
) -> StencilSpec:
    """The streamed bucket-shaped spec a bucket design is compiled from.

    Per-request fit (grid + margins <= bucket) is validated by the bucket
    runner; the spec's own declared shape only contributes structure here.
    """
    return masked_spec(with_shape(spec, bucket_shape), wrap_rounds)


# --------------------------------------------------------------------------
# Host-side staging plan (numpy: used while staging micro-batches)
# --------------------------------------------------------------------------


def grid_mask_host(
    shape: Sequence[int], bucket_shape: Sequence[int], dtype="float32"
) -> np.ndarray:
    """Bucket-shaped mask: 1 on the leading ``shape`` region, 0 on padding."""
    shape, bucket_shape = tuple(shape), tuple(bucket_shape)
    if len(shape) != len(bucket_shape) or any(
        s > b for s, b in zip(shape, bucket_shape)
    ):
        raise ValueError(f"grid {shape} does not fit bucket {bucket_shape}")
    m = np.zeros(bucket_shape, dtype=np.dtype(dtype))
    m[tuple(slice(0, s) for s in shape)] = 1
    return m


def halo_index_host(
    shape: Sequence[int], bucket_shape: Sequence[int], dim: int
) -> np.ndarray:
    """Bucket-shaped int32 gather-source map for dimension ``dim``.

    Cell value = the global bucket coordinate (along ``dim``) the cell
    copies from under the clamped-edge rule: identity below ``shape[dim]``,
    the last real coordinate beyond it.  A *clamp-form* map — the static
    contract :func:`repro.kernels.blockops.streamed_halo_fixup` lowers to
    slice/select ops instead of a gather.
    """
    shape, bucket_shape = tuple(shape), tuple(bucket_shape)
    idx = np.clip(np.arange(bucket_shape[dim]), 0, shape[dim] - 1)
    view = idx.reshape(
        tuple(-1 if d == dim else 1 for d in range(len(bucket_shape)))
    )
    return np.broadcast_to(view, bucket_shape).astype(np.int32)


def wrap_index_host(
    shape: Sequence[int],
    bucket_shape: Sequence[int],
    margin: int,
    dim: int,
) -> np.ndarray:
    """Bucket-shaped int32 wrap-source map for dimension ``dim``.

    Cell value = the bucket coordinate the cell copies from under the
    periodic rule with the real grid placed at offset ``margin``:
    identity on the real region ``[margin, margin + shape[dim])``,
    wrapped into it (modulo the real size) everywhere else.  Consumed
    between fused rounds by
    :func:`repro.kernels.blockops.wrap_round_fixup` — a modular map, so
    it stays a gather, once per round at grid granularity.
    """
    shape, bucket_shape = tuple(shape), tuple(bucket_shape)
    S = shape[dim]
    idx = margin + ((np.arange(bucket_shape[dim]) - margin) % S)
    view = idx.reshape(
        tuple(-1 if d == dim else 1 for d in range(len(bucket_shape)))
    )
    return np.broadcast_to(view, bucket_shape).astype(np.int32)


def pad_grid(
    a: np.ndarray, bucket_shape: Sequence[int], fill: float = 0.0
) -> np.ndarray:
    """Pad one grid (no batch axis) up to the bucket shape with ``fill``.

    ``fill`` is the spec's boundary value (:func:`boundary_fill`): under a
    constant-``v`` boundary, real edge cells read ``v`` from the bucket
    margin, exactly what an unpadded run reads from its exterior.
    """
    a = np.asarray(a)
    bucket_shape = tuple(bucket_shape)
    if a.ndim != len(bucket_shape) or any(
        s > b for s, b in zip(a.shape, bucket_shape)
    ):
        raise ValueError(
            f"grid shaped {a.shape} does not fit bucket {bucket_shape}"
        )
    if tuple(a.shape) == bucket_shape:
        return a
    return np.pad(
        a, [(0, b - s) for s, b in zip(a.shape, bucket_shape)],
        constant_values=fill,
    )


def pad_batch(
    a: np.ndarray, bucket_shape: Sequence[int], fill: float = 0.0
) -> np.ndarray:
    """Pad a batched array ``(B,) + grid`` up to ``(B,) + bucket``."""
    a = np.asarray(a)
    bucket_shape = tuple(bucket_shape)
    if a.ndim != len(bucket_shape) + 1 or any(
        s > b for s, b in zip(a.shape[1:], bucket_shape)
    ):
        raise ValueError(
            f"batched array shaped {a.shape} does not fit (B,) + "
            f"{bucket_shape}"
        )
    if tuple(a.shape[1:]) == bucket_shape:
        return a
    return np.pad(
        a,
        [(0, 0)] + [(0, b - s) for s, b in zip(a.shape[1:], bucket_shape)],
        constant_values=fill,
    )


@dataclasses.dataclass(frozen=True)
class BucketPlan:
    """Everything the host needs to stage requests into one bucket design.

    Built once per (spec, bucket, iterations) by :func:`bucket_plan`;
    shared by :func:`repro.runtime.batching.build_bucket_runner` (uniform
    batches) and the server's micro-batch staging (mixed shapes sharing a
    bucket, each entry carrying its own streamed service arrays).
    """

    spec: StencilSpec                 # the request-facing spec
    bucket: tuple[int, ...]
    mspec: StencilSpec                # the compiled-design (streamed) spec
    margins: tuple[int, ...]          # leading placement offset per dim
    mask_name: str | None             # None for periodic (no mask woven)
    halo_idx_names: tuple[str, ...]   # per-dim index inputs (replicate)
    wrap_idx_names: tuple[str, ...] = ()  # per-dim wrap maps (narrow periodic)
    wrap_rounds: int | None = None    # round-depth cap (narrow periodic)
    # per-(grid shape) placement index memo + build/reuse counters: a
    # mixed-shape serving trace replays the same few shapes thousands of
    # times and must not rebuild bucket-length index vectors per entry
    # (and the batched/unbatched call sites must share one memo — only
    # the batch slot differs).  Excluded from eq/hash/repr.
    _place_index_cache: dict = dataclasses.field(
        default_factory=dict, compare=False, repr=False
    )
    _place_stats: dict = dataclasses.field(
        default_factory=lambda: {"builds": 0, "reuses": 0},
        compare=False, repr=False,
    )

    @property
    def fill(self) -> float:
        return boundary_fill(self.spec)

    @property
    def service_names(self) -> tuple[str, ...]:
        """The streamed non-data inputs of the bucket design, in order."""
        names = () if self.mask_name is None else (self.mask_name,)
        return names + self.halo_idx_names + self.wrap_idx_names

    @property
    def place_index_builds(self) -> int:
        return self._place_stats["builds"]

    @property
    def place_index_reuses(self) -> int:
        return self._place_stats["reuses"]

    def validate_shape(self, shape: Sequence[int]) -> tuple[int, ...]:
        """Check a request grid (plus its halo margins) fits the bucket."""
        shape = tuple(int(s) for s in shape)
        if len(shape) != len(self.bucket) or any(
            s + 2 * m > b
            for s, m, b in zip(shape, self.margins, self.bucket)
        ):
            need = tuple(
                s + 2 * m for s, m in zip(shape, self.margins)
            ) if len(shape) == len(self.bucket) else shape
            raise ValueError(
                f"grid shaped {shape} (with halo margins: {need}) does "
                f"not fit bucket {self.bucket}"
            )
        return shape

    def out_index(self, shape: Sequence[int]) -> tuple[slice, ...]:
        """Slice of the bucket output holding the real grid's results."""
        return tuple(
            slice(m, m + s) for m, s in zip(self.margins, shape)
        )

    def place_entry(self, a: np.ndarray, batched: bool = False) -> np.ndarray:
        """Lay one grid (or ``(B,) + grid``) into the bucket shape.

        zero/constant fill the trailing margin with the boundary value;
        replicate extends the clamped edge (the correct exterior at t=0);
        periodic streams the wrapped extension into both margins — the
        per-request halo data the compiled design consumes.
        """
        a = np.asarray(a)
        off = 1 if batched else 0
        if a.ndim != len(self.bucket) + off:
            raise ValueError(
                f"array shaped {a.shape} does not fit "
                f"{'(B,) + ' if batched else ''}{self.bucket}"
            )
        self.validate_shape(a.shape[off:])
        kind = self.spec.boundary.kind
        if kind in ("zero", "constant"):
            pads = [(0, 0)] * off + [
                (0, b - s) for s, b in zip(a.shape[off:], self.bucket)
            ]
            if tuple(a.shape[off:]) == self.bucket:
                return a
            return np.pad(a, pads, constant_values=self.fill)
        for d, idx in enumerate(self._place_indices(tuple(a.shape[off:]))):
            if idx is not None:
                a = np.take(a, idx, axis=d + off)
        return a

    def _place_indices(
        self, shape: tuple[int, ...]
    ) -> tuple[np.ndarray | None, ...]:
        """Per-dimension placement index vectors for one grid shape,
        memoized per plan (``None`` marks a full-size dim needing no
        take).  Pure function of (shape, boundary mode); batched and
        unbatched placements of the same grid hit the same entry."""
        hit = self._place_index_cache.get(shape)
        if hit is not None:
            self._place_stats["reuses"] += 1
            return hit
        kind = self.spec.boundary.kind
        out: list[np.ndarray | None] = []
        for d, b in enumerate(self.bucket):
            s = shape[d]
            if s == b:
                out.append(None)
            elif kind == "replicate":
                out.append(np.clip(np.arange(b), 0, s - 1))
            else:  # periodic: wrapped extension around the placed grid
                out.append((np.arange(b) - self.margins[d]) % s)
        entry = tuple(out)
        self._place_index_cache[shape] = entry
        self._place_stats["builds"] += 1
        return entry

    def service_entry(self, shape: Sequence[int]) -> dict[str, np.ndarray]:
        """The streamed service arrays (mask / halo indices) for one grid.

        Pure functions of ``(plan, shape)``, so they are memoized: a
        serving trace replaying the same few shapes thousands of times
        must not rebuild bucket-sized masks and index maps per request.
        Callers stack or broadcast the returned arrays — never mutate
        them in place.
        """
        return _service_entry_cached(self, self.validate_shape(shape))

    def service_filler(self) -> dict[str, np.ndarray]:
        """Service arrays for throwaway batch-padding entries.

        An all-zero mask makes a padding entry's output the boundary
        constant everywhere (discarded by the caller); zero halo indices
        gather every cell from the bucket origin — finite, discarded.
        """
        out: dict[str, np.ndarray] = {}
        if self.mask_name is not None:
            dt = self.mspec.inputs[self.mask_name][0]
            out[self.mask_name] = np.zeros(self.bucket, np.dtype(dt))
        for name in self.halo_idx_names + self.wrap_idx_names:
            out[name] = np.zeros(self.bucket, np.int32)
        return out

    def filler_entry(self, name: str) -> np.ndarray:
        """A throwaway data grid for batch padding (boundary fill value)."""
        dt = self.spec.inputs[name][0]
        return np.full(self.bucket, self.fill, np.dtype(dt))


@functools.lru_cache(maxsize=512)
def _service_entry_cached(
    plan: BucketPlan, shape: tuple[int, ...]
) -> dict[str, np.ndarray]:
    out: dict[str, np.ndarray] = {}
    if plan.mask_name is not None:
        out[plan.mask_name] = grid_mask_host(
            shape, plan.bucket, plan.mspec.inputs[plan.mask_name][0]
        )
    for d, name in enumerate(plan.halo_idx_names):
        out[name] = halo_index_host(shape, plan.bucket, d)
    for d, name in enumerate(plan.wrap_idx_names):
        out[name] = wrap_index_host(shape, plan.bucket, plan.margins[d], d)
    return out


def bucket_plan(
    spec: StencilSpec,
    bucket_shape: Sequence[int],
    iterations: int | None = None,
    wrap_rounds: int | None = None,
) -> BucketPlan:
    """Build the host staging plan for ``spec`` served from ``bucket_shape``.

    ``wrap_rounds`` (periodic only) switches the design to the
    narrow-margin streamed-wrap form: the margin shrinks to
    ``wrap_rounds * radius`` and per-dimension wrap maps join the
    streamed service inputs (single-device executors only — see
    :func:`masked_spec`).
    """
    bucket = tuple(int(b) for b in bucket_shape)
    if spec.boundary.kind != "periodic":
        wrap_rounds = None
    mspec = bucket_spec(spec, bucket, wrap_rounds)
    kind = spec.boundary.kind
    return BucketPlan(
        spec=spec,
        bucket=bucket,
        mspec=mspec,
        margins=bucket_margins(spec, iterations, wrap_rounds),
        mask_name=None if kind == "periodic" else mask_input_name(spec),
        halo_idx_names=mspec.halo_index_inputs,
        wrap_idx_names=mspec.wrap_index_inputs,
        wrap_rounds=wrap_rounds,
    )
