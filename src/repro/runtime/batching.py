"""Batched stencil execution: one compiled design, many independent grids.

This is the serving analogue of SASA/SODA amortizing a single FPGA
bitstream across many invocations: the expensive artefact (an auto-tuned,
jitted design) is built once and then fed batches of grids, with the batch
axis threaded through whichever executor the design uses:

  * single-device designs with pipeline knobs (``cfg.buffer_depth >= 2``)
    run the batch-in-grid tile pipeline (:mod:`repro.kernels.pipeline`):
    the batch axis is folded into the kernel grid with explicitly
    double-buffered HBM->VMEM copies, so all B grids stream through one
    VMEM-tile residency with scheduled copy/compute overlap;
  * plain single-device designs run the single-PE fused kernel under
    ``jax.vmap`` (the legacy one-shot path, still the differential
    reference: both paths run the same tile program, bitwise-identical
    on a fixed backend);
  * multi-device designs run the same shard_map local programs vmapped
    over the batch axis (see ``build_runner(batched=True)``; with
    ``cfg.batch_tile`` the batch is chunked into a sequential grid of
    vmapped tiles), so rows stay sharded across the mesh while B grids
    ride one collective schedule.

Batch-axis semantics: every array in a batch call is ``(B,) + spec.shape``
and batch entries are fully independent — there is no halo exchange or any
other coupling across the batch axis, and the spec's boundary rule applies
per grid.

Every runner exposes three dispatch phases for the async serving loop —
``run.stage(arrays)`` (host -> device placement), ``run.dispatch(staged)``
(enqueue without blocking), ``run.finalize(out)`` (block + gather to
numpy) — with ``run(arrays)`` the validated synchronous composition.

:func:`build_bucket_runner` wraps a runner compiled for a padded canonical
**bucket** shape so it serves any grid that fits inside the bucket, with
the real grid's boundary rule — zero, constant, replicate, or periodic —
re-imposed from per-request streamed inputs (mask, halo-index maps, or
host-streamed wrap margins; see :mod:`repro.runtime.bucketing`); results
are bit-identical to executing the same design unpadded.
"""
from __future__ import annotations

import warnings
from typing import Mapping, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat
from repro.core.distribute import build_runner
from repro.core.model import ParallelismConfig
from repro.core.spec import StencilSpec
from repro.kernels import ops, pipeline
from repro.runtime.bucketing import bucket_plan


class DegradedDesignWarning(RuntimeWarning):
    """A design is executing with less parallelism than its config claims."""


def is_degraded(cfg: ParallelismConfig, n_avail: int) -> bool:
    """True when a pool of ``n_avail`` devices cannot realise ``cfg``'s
    parallelism.  The one sanctioned exception is a temporal design on a
    one-device host: the PE cascade degenerates to fused rounds on one
    chip with the fusion depth (and the analytical model's single-chip
    prediction) preserved."""
    n_dev = min(cfg.devices_needed, n_avail)
    return n_dev < cfg.devices_needed and not (
        cfg.variant == "temporal" and n_dev <= 1
    )


def degraded_message(cfg: ParallelismConfig, n_avail: int) -> str:
    n_dev = min(cfg.devices_needed, n_avail)
    return (
        f"design {cfg.variant}(k={cfg.k}, s={cfg.s}) needs "
        f"{cfg.devices_needed} device(s) but only {n_avail} are available; "
        f"executing on {n_dev} loses the configured parallelism while "
        f"run.cfg still claims it"
    )


def devices_needed(cfg: ParallelismConfig) -> int:
    """Device count a config occupies (see ParallelismConfig.devices_needed)."""
    return cfg.devices_needed


def resolve_backend(backend: str) -> str:
    """'auto' picks the Pallas kernel on TPU, the jnp executor elsewhere
    (interpret-mode Pallas is a validation tool, not a serving path)."""
    if backend != "auto":
        return backend
    return "pallas" if jax.default_backend() == "tpu" else "jnp"


def validate_batch(
    spec: StencilSpec,
    arrays: Mapping[str, np.ndarray],
    exact: bool = True,
) -> tuple[int, tuple[int, ...]]:
    """Check a batched input dict against ``spec``; returns ``(B, grid)``.

    Unknown array names raise (a typo'd input would otherwise be silently
    dropped and the stencil served with the wrong data), as do missing
    inputs and inconsistent batch shapes.  ``exact=True`` pins the grid
    to ``spec.shape``; ``exact=False`` (the bucket runner) accepts any
    uniform grid shape of the right rank and returns it.
    """
    unknown = sorted(set(arrays) - set(spec.inputs))
    if unknown:
        raise ValueError(
            f"unknown input(s) {unknown} for spec {spec.name!r} "
            f"(spec inputs: {sorted(spec.inputs)})"
        )
    full = None
    for n in spec.inputs:
        if n not in arrays:
            raise ValueError(
                f"batched runner missing input {n!r} "
                f"(spec inputs: {sorted(spec.inputs)})"
            )
        shape = tuple(jnp.shape(arrays[n]))
        if exact and (
            len(shape) != spec.ndim + 1 or shape[1:] != tuple(spec.shape)
        ):
            raise ValueError(
                f"batched runner expects {n} shaped (B,) + {spec.shape}, "
                f"got {shape}"
            )
        if full is None:
            if len(shape) != spec.ndim + 1:
                raise ValueError(
                    f"batched runner expects {n} shaped (B,) + grid, "
                    f"got {shape}"
                )
            full = shape
        elif shape != full:
            raise ValueError(
                f"inconsistent batch shapes: {n} is {shape}, "
                f"expected {full}"
            )
    return full[0], full[1:]


def build_batched_runner(
    spec: StencilSpec,
    cfg: ParallelismConfig,
    iterations: int | None = None,
    devices=None,
    tile_rows: int = 64,
    backend: str = "auto",
    interpret: bool | None = None,
    align_cols: int = 1,
    strict: bool = False,
):
    """Compile a runner mapping ``{name: (B,) + spec.shape}`` -> ``(B,) +
    spec.shape`` for a chosen parallelism configuration.

    Single-device configs use the single-PE kernel; multi-device configs
    use the batched shard_map runner.  A config needing more devices than
    the pool provides is **degraded**: it executes, but with less
    parallelism than ``run.cfg`` claims.  Degradation warns
    (:class:`DegradedDesignWarning`) or raises under ``strict=True``; the
    one sanctioned silent case is a temporal design on a one-device host,
    where the PE cascade degenerates to fused rounds on one chip with the
    fusion depth (and the analytical model's single-chip prediction)
    preserved.  The returned callable carries ``.path`` ("single_pe",
    "tile_pipeline", or "shard_map"), ``.backend``, ``.n_devices``,
    ``.devices_requested``,
    and ``.degraded`` for reporting and cache keying.
    """
    it = spec.iterations if iterations is None else iterations
    avail = list(devices) if devices is not None else jax.devices()
    need = devices_needed(cfg)
    n_dev = min(need, len(avail))
    degraded = is_degraded(cfg, len(avail))
    if degraded:
        msg = degraded_message(cfg, len(avail))
        if strict:
            raise ValueError(msg)
        warnings.warn(msg, DegradedDesignWarning, stacklevel=2)

    if n_dev <= 1:
        bk = resolve_backend(backend)
        interp = (jax.default_backend() != "tpu") if interpret is None else interpret
        s = max(min(cfg.s, it), 1)
        tile = cfg.tile_rows or tile_rows

        if cfg.buffer_depth >= 2:
            # Batch-in-grid tile pipeline: the batch axis is folded into
            # the kernel grid with explicitly double-buffered HBM->VMEM
            # copies (Pallas grid pipeline on TPU, software-prefetched
            # fori_loop on CPU hosts) instead of vmapping whole-grid
            # programs.  Same tile program as the vmapped path, so
            # results are bitwise-identical on a fixed backend.
            def batched_fn(arrays: Mapping[str, jnp.ndarray]) -> jnp.ndarray:
                return pipeline.stencil_run_batched(
                    spec, arrays, it, s=s, tile_rows=tile, backend=bk,
                    interpret=interp, align_cols=align_cols,
                )

            fn = jax.jit(batched_fn)
            path = "tile_pipeline"
        else:

            def one_grid(arrays: Mapping[str, jnp.ndarray]) -> jnp.ndarray:
                return ops.stencil_run(
                    spec, arrays, it, s=s, tile_rows=tile, backend=bk,
                    interpret=interp, align_cols=align_cols,
                )

            fn = jax.jit(jax.vmap(one_grid))
            path = "single_pe"

        def stage(arrays: Mapping[str, jnp.ndarray]) -> dict:
            return {
                n: jax.device_put(jnp.asarray(arrays[n])) for n in spec.inputs
            }

        def dispatch(staged: Mapping[str, jnp.ndarray]) -> jnp.ndarray:
            return fn(dict(staged))

        def finalize(out: jnp.ndarray) -> np.ndarray:
            return np.asarray(out)

        mesh, n_used, jitted = None, 1, fn
    else:
        bk = "shard_map"
        inner = build_runner(
            spec, cfg, iterations=it, devices=avail[:n_dev],
            tile_rows=tile_rows, batched=True,
        )
        stage, dispatch, finalize = inner.stage, inner.dispatch, inner.finalize
        path, mesh, n_used = "shard_map", inner.mesh, n_dev
        jitted = None   # shard_map programs are not AOT-persistable (yet)

    def run(arrays: Mapping[str, jnp.ndarray]) -> np.ndarray:
        validate_batch(spec, arrays)
        return finalize(dispatch(stage(arrays)))

    run.spec = spec
    run.cfg = cfg
    run.iterations = it
    run.path = path
    run.backend = bk
    run.mesh = mesh
    run.n_devices = n_used
    run.devices_requested = need
    run.degraded = degraded
    run.stage = stage
    run.dispatch = dispatch
    run.finalize = finalize
    # non-blocking completion poll over a dispatch()'s output: the
    # continuous-batching scheduler reaps finished micro-batches without
    # stalling its admission loop (falls back to "ready" = blocking reap
    # on jax versions without Array.is_ready)
    run.ready = compat.is_ready
    # the underlying jit-wrapped batched program (single-device paths):
    # what the persistent design store AOT-lowers, compiles, and
    # serializes per input signature (None = not AOT-persistable)
    run.jitted = jitted
    return run


def build_bucket_runner(
    spec: StencilSpec,
    bucket_shape: Sequence[int],
    cfg: ParallelismConfig,
    iterations: int | None = None,
    devices=None,
    tile_rows: int = 64,
    backend: str = "auto",
    interpret: bool | None = None,
    align_cols: int = 1,
    strict: bool = False,
    inner=None,
    wrap_rounds: int | None = None,
):
    """Streamed-boundary wrapper: a design compiled for ``bucket_shape``
    serving any fitting grid with the spec's exact boundary semantics.

    The compiled artefact is a batched runner for the **streamed bucket
    spec** (:func:`repro.runtime.bucketing.bucket_spec`); the wrapper
    stages each request through the bucket's host plan
    (:class:`repro.runtime.bucketing.BucketPlan`): inputs are laid into
    the bucket with the boundary-appropriate margin fill (zeros/constant,
    clamped edge, or the wrapped periodic halo computed from the *real*
    shape at pad time) alongside the per-request streamed service inputs
    — the ``_mask`` woven into every stage and, for replicate, the
    per-dimension halo-index maps the in-kernel per-stage gather
    consumes.  Interior results are bit-identical to executing the same
    design unpadded, for every boundary mode.

    ``run(arrays)`` takes one uniform-shape batch ``{name: (B,) + grid}``
    with ``grid + 2 * margins <= bucket_shape`` per dimension and returns
    ``(B,) + grid``.  Serving layers that mix grid shapes inside one
    micro-batch stage each entry through ``run.plan`` and drive
    ``run.stage`` / ``run.dispatch`` / ``run.finalize`` directly, slicing
    each entry's region out of the bucket-shaped output.

    Pass ``inner`` to wrap an already-compiled batched runner for the
    streamed bucket spec (the design-cache path) instead of compiling
    here.  ``wrap_rounds`` (periodic only) serves from the narrow
    ``wrap_rounds * radius`` margin with streamed wrap maps re-imposing
    the wrap between fused rounds — single-device executors only.
    """
    bucket_shape = tuple(int(b) for b in bucket_shape)
    plan = bucket_plan(
        spec, bucket_shape, iterations=iterations, wrap_rounds=wrap_rounds
    )
    mspec = plan.mspec
    if inner is None:
        inner = build_batched_runner(
            mspec, cfg, iterations=iterations, devices=devices,
            tile_rows=tile_rows, backend=backend, interpret=interpret,
            align_cols=align_cols, strict=strict,
        )

    def run(arrays: Mapping[str, np.ndarray]) -> np.ndarray:
        B, grid = validate_batch(spec, arrays, exact=False)
        padded = {
            n: plan.place_entry(np.asarray(arrays[n]), batched=True)
            for n in spec.inputs
        }
        for sname, svc in plan.service_entry(grid).items():
            padded[sname] = np.broadcast_to(
                svc[None], (B,) + bucket_shape
            )
        out = inner(padded)
        return out[(slice(None),) + plan.out_index(grid)]

    run.spec = spec
    run.masked_spec = mspec
    run.mask_name = plan.mask_name
    run.bucket_shape = bucket_shape
    run.plan = plan
    run.wrap_rounds = plan.wrap_rounds
    run.inner = inner
    run.cfg = inner.cfg
    run.iterations = inner.iterations
    run.path = inner.path
    run.backend = inner.backend
    run.n_devices = inner.n_devices
    run.devices_requested = inner.devices_requested
    run.degraded = inner.degraded
    run.stage = inner.stage
    run.dispatch = inner.dispatch
    run.finalize = inner.finalize
    run.ready = getattr(inner, "ready", compat.is_ready)
    run.jitted = getattr(inner, "jitted", None)
    return run
