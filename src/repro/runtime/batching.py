"""Batched stencil execution: one compiled design, many independent grids.

This is the serving analogue of SASA/SODA amortizing a single FPGA
bitstream across many invocations: the expensive artefact (an auto-tuned,
jitted design) is built once and then fed batches of grids, with the batch
axis threaded through whichever executor the design uses:

  * single-device designs run the single-PE fused kernel under ``jax.vmap``
    (the Pallas kernel gains a leading grid dimension; the jnp fallback
    vectorises directly), so B grids share one kernel launch sequence;
  * multi-device designs run the same shard_map local programs vmapped
    over the batch axis (see ``build_runner(batched=True)``), so rows stay
    sharded across the mesh while B grids ride one collective schedule.

Batch-axis semantics: every array in a batch call is ``(B,) + spec.shape``
and batch entries are fully independent — there is no halo exchange or any
other coupling across the batch axis, and the exterior-zero boundary
applies per grid.
"""
from __future__ import annotations

from typing import Mapping

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.distribute import build_runner
from repro.core.model import ParallelismConfig
from repro.core.spec import StencilSpec
from repro.kernels import ops


def devices_needed(cfg: ParallelismConfig) -> int:
    """Device count a config occupies (see ParallelismConfig.devices_needed)."""
    return cfg.devices_needed


def resolve_backend(backend: str) -> str:
    """'auto' picks the Pallas kernel on TPU, the jnp executor elsewhere
    (interpret-mode Pallas is a validation tool, not a serving path)."""
    if backend != "auto":
        return backend
    return "pallas" if jax.default_backend() == "tpu" else "jnp"


def build_batched_runner(
    spec: StencilSpec,
    cfg: ParallelismConfig,
    iterations: int | None = None,
    devices=None,
    tile_rows: int = 64,
    backend: str = "auto",
    interpret: bool | None = None,
    align_cols: int = 1,
):
    """Compile a runner mapping ``{name: (B,) + spec.shape}`` -> ``(B,) +
    spec.shape`` for a chosen parallelism configuration.

    Single-device configs (including temporal designs on a one-device
    host, where the PE cascade degenerates to fused rounds on one chip)
    use the single-PE kernel; multi-device configs use the batched
    shard_map runner.  The returned callable carries ``.path`` ("single_pe"
    or "shard_map"), ``.backend``, and ``.n_devices`` for reporting.
    """
    it = spec.iterations if iterations is None else iterations
    avail = list(devices) if devices is not None else jax.devices()
    n_dev = min(devices_needed(cfg), len(avail))

    if n_dev <= 1:
        bk = resolve_backend(backend)
        interp = (jax.default_backend() != "tpu") if interpret is None else interpret
        s = max(min(cfg.s, it), 1)
        tile = cfg.tile_rows or tile_rows

        def one_grid(arrays: Mapping[str, jnp.ndarray]) -> jnp.ndarray:
            return ops.stencil_run(
                spec, arrays, it, s=s, tile_rows=tile, backend=bk,
                interpret=interp, align_cols=align_cols,
            )

        fn = jax.jit(jax.vmap(one_grid))
        path, mesh, n_used = "single_pe", None, 1
    else:
        bk = "shard_map"
        fn = build_runner(
            spec, cfg, iterations=it, devices=avail[:n_dev],
            tile_rows=tile_rows, batched=True,
        )
        path, mesh, n_used = "shard_map", fn.mesh, n_dev

    def run(arrays: Mapping[str, jnp.ndarray]) -> np.ndarray:
        B = None
        for n in spec.inputs:
            if n not in arrays:
                raise ValueError(
                    f"batched runner missing input {n!r} "
                    f"(spec inputs: {sorted(spec.inputs)})"
                )
            shape = tuple(jnp.shape(arrays[n]))
            if len(shape) != spec.ndim + 1 or shape[1:] != tuple(spec.shape):
                raise ValueError(
                    f"batched runner expects {n} shaped (B,) + {spec.shape}, "
                    f"got {shape}"
                )
            if B is None:
                B = shape[0]
            elif shape[0] != B:
                raise ValueError(
                    f"inconsistent batch sizes: {n} has B={shape[0]}, "
                    f"expected {B}"
                )
        out = fn({n: jnp.asarray(arrays[n]) for n in spec.inputs})
        return np.asarray(out)

    run.spec = spec
    run.cfg = cfg
    run.iterations = it
    run.path = path
    run.backend = bk
    run.mesh = mesh
    run.n_devices = n_used
    return run
