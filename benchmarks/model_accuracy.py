"""Paper Fig. 9: analytical-model accuracy — predicted vs measured.

The paper reports <5% error between Eqs. 4-9 and on-board U280 execution.
Two validations stand in here (no U280/TPU on this container):

1. *Against the paper's own published results*: the U280 cycle model
   reproduces Table 3's best-parallelism picks (8/8 at iteration=64) and
   the published SODA-speedup sweep within ~8% (avg 4.03x vs 3.74x) —
   see best_config.py / speedup_vs_soda.py.

2. *Against measured wall-clock on this host*: the same analytic
   flop/byte counts drive a host cost model ``t = F/flops + B/bw + c``
   whose three constants are least-squares-fitted on a CALIBRATION set of
   kernels and validated on HELD-OUT kernels — the honest analogue of
   calibrating the platform once and predicting unseen workloads.  A
   dataflow FPGA is cycle-exact; an out-of-order CPU under an optimizing
   compiler is not, so the bar here is usefulness for *ranking*, which is
   what the auto-tuner needs.

Run directly (``PYTHONPATH=src:. python benchmarks/model_accuracy.py``)
it asserts that gate — held-out pairwise rank accuracy >= 0.5;
``--smoke`` (what ``scripts/ci.sh`` runs) shrinks kernels/points/grids
to CI size.  Under the harness (``benchmarks/run.py``) it just emits
CSV rows.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks.common import time_call
from repro.configs import stencils
from repro.kernels import ops

SHAPE = (2048, 512)
CALIBRATE_ON = ["jacobi2d", "blur", "heat3d", "hotspot", "dilate"]
VALIDATE_ON = ["sobel2d", "seidel2d", "jacobi3d", "blur_jacobi2d"]
POINTS = [(1, 1), (4, 1), (4, 4), (16, 4)]


def _features(spec, iters, s):
    """Analytic per-op-mix work vector for the fused executor: XLA CPU
    costs adds/muls/divs/compares very differently, so the calibration
    fits one throughput per op class plus a memory-traffic term."""
    from repro.core.model import _op_mix
    cells = float(np.prod(spec.shape))
    mix = _op_mix(spec)
    bytes_ = (cells * spec.itemsize
              * (spec.num_inputs + 1 + 2 * len(spec.stages)) * iters)
    return np.array([
        cells * iters * mix["add"],
        cells * iters * mix["mul"],
        cells * iters * mix["div"],
        cells * iters * mix["cmp"],
        bytes_,
    ])


def _measure(name, iters, s, smoke=False):
    if name in stencils.BENCHMARKS_3D:
        shape = (64, 16, 16) if smoke else (256, 32, 32)
    else:
        shape = (512, 128) if smoke else SHAPE
    spec = stencils.get(name, shape=shape, iterations=iters)
    arrays = {n: jnp.ones(shp, dt) for n, (dt, shp) in spec.inputs.items()}
    t = time_call(ops.stencil_run, spec, arrays, iters, s=s, backend="jnp")
    return spec, t


def run(check: bool = False, smoke: bool = False):
    # smoke (CI): fewer kernels, fewer sweep points, ~16x smaller grids —
    # same calibrate-on-some / validate-on-held-out protocol, gated only
    # on ranking usefulness (what the auto-tuner actually consumes);
    # absolute error percentages are noise-dominated at CI sizes.
    calibrate = CALIBRATE_ON[:3] if smoke else CALIBRATE_ON
    validate = VALIDATE_ON[:2] if smoke else VALIDATE_ON
    points = [(1, 1), (4, 1), (16, 4)] if smoke else POINTS
    rows = []
    X, y = [], []
    for name in calibrate:
        for iters, s in points:
            spec, t = _measure(name, iters, s, smoke)
            X.append(_features(spec, iters, s))
            y.append(t)
    X, y = np.array(X), np.array(y)
    # non-negative least squares via multiplicative updates (no scipy);
    # an op class absent from the whole calibration set (e.g. no compare
    # ops among the smoke kernels) leaves an all-zero column — scale it
    # by 1 instead of 0/0-poisoning the fit
    colmax = X.max(0)
    colmax[colmax == 0] = 1.0
    Xs = X / colmax
    coef = np.full(X.shape[1], 1e-3)
    for _ in range(5000):
        num = Xs.T @ y
        den = Xs.T @ (Xs @ coef) + 1e-18
        coef *= num / den
    coef = coef / colmax
    insample = X @ coef
    in_err = np.abs(insample - y) / y * 100
    rows.append(
        f"fig9/calibration,0.00,"
        f"op_costs_ns={';'.join(f'{c*1e9:.3f}' for c in coef[:4])};"
        f"eff_bw={1/max(coef[4],1e-18):.2e};"
        f"in_sample_mean_err_pct={in_err.mean():.1f};"
        f"fit_kernels={'+'.join(calibrate)}")

    errs = []
    rank_hits = 0
    rank_total = 0
    for name in validate:
        meas_by_pt = {}
        for iters, s in points:
            spec, t = _measure(name, iters, s, smoke)
            pred = float(_features(spec, iters, s) @ coef)
            err = abs(pred - t) / t * 100
            errs.append(err)
            meas_by_pt[(iters, s)] = (t, pred)
            rows.append(
                f"fig9/accuracy/{name}/it{iters}_s{s},{t*1e6:.2f},"
                f"predicted_us={pred*1e6:.2f};error_pct={err:.1f}")
        # ranking usefulness: does the model order the points correctly?
        pts = list(meas_by_pt.values())
        for i in range(len(pts)):
            for j in range(i + 1, len(pts)):
                rank_total += 1
                if (pts[i][0] < pts[j][0]) == (pts[i][1] < pts[j][1]):
                    rank_hits += 1
    rows.append(
        f"fig9/summary,0.00,"
        f"mean_error_pct={np.mean(errs):.1f};max_error_pct={np.max(errs):.1f};"
        f"pairwise_rank_accuracy={rank_hits}/{rank_total};"
        f"paper_fpga_error=under5pct(cycle-exact dataflow);"
        f"fpga_model_vs_published=Table3 8of8 + speedups within ~8pct")

    # --- paper-methodology variant: calibrate per design, predict the
    # iteration/fusion scaling (the paper's tool flow synthesises each
    # design, so per-design constants are known; Eqs. 4-8 then predict
    # latency across iteration counts — that prediction is what carried
    # the <5% claim).  One measurement at (iters=1, s=1) anchors each
    # kernel; all other (iters, s) points are blind predictions. ---
    errs2 = []
    for name in calibrate + validate:
        spec1, t1 = _measure(name, 1, 1, smoke)
        f1 = _features(spec1, 1, 1) @ coef
        scale = t1 / max(f1, 1e-12)
        for iters, s in points[1:]:
            spec, t = _measure(name, iters, s, smoke)
            pred = float(_features(spec, iters, s) @ coef) * scale
            err = abs(pred - t) / t * 100
            errs2.append(err)
            rows.append(
                f"fig9/per_design/{name}/it{iters}_s{s},{t*1e6:.2f},"
                f"predicted_us={pred*1e6:.2f};error_pct={err:.1f}")
    rows.append(
        f"fig9/per_design_summary,0.00,"
        f"mean_error_pct={np.mean(errs2):.1f};"
        f"median_error_pct={np.median(errs2):.1f};"
        f"max_error_pct={np.max(errs2):.1f};"
        f"methodology=calibrate-once-per-design predict-across-iterations")

    if check:
        # the model exists to *rank* candidate designs, so the CI gate is
        # ordering, not absolute error (an OoO CPU under XLA is not the
        # paper's cycle-exact dataflow FPGA): on held-out kernels the
        # predicted ordering of (iterations, fusion) points must beat a
        # coin flip, and the fit itself must be finite and usable.
        assert rank_total > 0, "no held-out pairwise ranking comparisons"
        rank_acc = rank_hits / rank_total
        assert rank_acc >= 0.5, (
            f"held-out pairwise rank accuracy {rank_hits}/{rank_total} "
            f"= {rank_acc:.2f} < 0.5 — the model orders designs worse "
            "than chance"
        )
        assert np.isfinite(errs).all() and np.isfinite(errs2).all()
    return rows


if __name__ == "__main__":
    import sys

    for row in run(check=True, smoke="--smoke" in sys.argv[1:]):
        print(row)
    print("OK: analytical host model calibrated on some kernels ranks "
          "held-out kernels' (iterations, fusion) points better than "
          "chance")
