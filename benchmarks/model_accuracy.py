"""Paper Fig. 9: analytical-model accuracy — predicted vs measured.

The paper reports <5% error between Eqs. 4-9 and on-board U280 execution.
Two validations stand in here (no U280/TPU on this container):

1. *Against the paper's own published results*: the U280 cycle model
   reproduces Table 3's best-parallelism picks (8/8 at iteration=64) and
   the published SODA-speedup sweep within ~8% (avg 4.03x vs 3.74x) —
   see best_config.py / speedup_vs_soda.py.

2. *Against measured wall-clock on this host*: the same analytic
   flop/byte counts drive a host cost model ``t = F/flops + B/bw + c``
   whose three constants are least-squares-fitted on a CALIBRATION set of
   kernels and validated on HELD-OUT kernels — the honest analogue of
   calibrating the platform once and predicting unseen workloads.  A
   dataflow FPGA is cycle-exact; an out-of-order CPU under an optimizing
   compiler is not, so the bar here is usefulness for *ranking*, which is
   what the auto-tuner needs.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks.common import time_call
from repro.configs import stencils
from repro.kernels import ops

SHAPE = (2048, 512)
CALIBRATE_ON = ["jacobi2d", "blur", "heat3d", "hotspot", "dilate"]
VALIDATE_ON = ["sobel2d", "seidel2d", "jacobi3d", "blur_jacobi2d"]
POINTS = [(1, 1), (4, 1), (4, 4), (16, 4)]


def _features(spec, iters, s):
    """Analytic per-op-mix work vector for the fused executor: XLA CPU
    costs adds/muls/divs/compares very differently, so the calibration
    fits one throughput per op class plus a memory-traffic term."""
    from repro.core.model import _op_mix
    cells = float(np.prod(spec.shape))
    mix = _op_mix(spec)
    bytes_ = (cells * spec.itemsize
              * (spec.num_inputs + 1 + 2 * len(spec.stages)) * iters)
    return np.array([
        cells * iters * mix["add"],
        cells * iters * mix["mul"],
        cells * iters * mix["div"],
        cells * iters * mix["cmp"],
        bytes_,
    ])


def _measure(name, iters, s):
    shape = (256, 32, 32) if name in stencils.BENCHMARKS_3D else SHAPE
    spec = stencils.get(name, shape=shape, iterations=iters)
    arrays = {n: jnp.ones(shp, dt) for n, (dt, shp) in spec.inputs.items()}
    t = time_call(ops.stencil_run, spec, arrays, iters, s=s, backend="jnp")
    return spec, t


def run():
    rows = []
    X, y = [], []
    for name in CALIBRATE_ON:
        for iters, s in POINTS:
            spec, t = _measure(name, iters, s)
            X.append(_features(spec, iters, s))
            y.append(t)
    X, y = np.array(X), np.array(y)
    # non-negative least squares via multiplicative updates (no scipy)
    Xs = X / X.max(0)
    coef = np.full(X.shape[1], 1e-3)
    for _ in range(5000):
        num = Xs.T @ y
        den = Xs.T @ (Xs @ coef) + 1e-18
        coef *= num / den
    coef = coef / X.max(0)
    insample = X @ coef
    in_err = np.abs(insample - y) / y * 100
    rows.append(
        f"fig9/calibration,0.00,"
        f"op_costs_ns={';'.join(f'{c*1e9:.3f}' for c in coef[:4])};"
        f"eff_bw={1/max(coef[4],1e-18):.2e};"
        f"in_sample_mean_err_pct={in_err.mean():.1f};"
        f"fit_kernels={'+'.join(CALIBRATE_ON)}")

    errs = []
    rank_hits = 0
    rank_total = 0
    for name in VALIDATE_ON:
        meas_by_pt = {}
        for iters, s in POINTS:
            spec, t = _measure(name, iters, s)
            pred = float(_features(spec, iters, s) @ coef)
            err = abs(pred - t) / t * 100
            errs.append(err)
            meas_by_pt[(iters, s)] = (t, pred)
            rows.append(
                f"fig9/accuracy/{name}/it{iters}_s{s},{t*1e6:.2f},"
                f"predicted_us={pred*1e6:.2f};error_pct={err:.1f}")
        # ranking usefulness: does the model order the points correctly?
        pts = list(meas_by_pt.values())
        for i in range(len(pts)):
            for j in range(i + 1, len(pts)):
                rank_total += 1
                if (pts[i][0] < pts[j][0]) == (pts[i][1] < pts[j][1]):
                    rank_hits += 1
    rows.append(
        f"fig9/summary,0.00,"
        f"mean_error_pct={np.mean(errs):.1f};max_error_pct={np.max(errs):.1f};"
        f"pairwise_rank_accuracy={rank_hits}/{rank_total};"
        f"paper_fpga_error=under5pct(cycle-exact dataflow);"
        f"fpga_model_vs_published=Table3 8of8 + speedups within ~8pct")

    # --- paper-methodology variant: calibrate per design, predict the
    # iteration/fusion scaling (the paper's tool flow synthesises each
    # design, so per-design constants are known; Eqs. 4-8 then predict
    # latency across iteration counts — that prediction is what carried
    # the <5% claim).  One measurement at (iters=1, s=1) anchors each
    # kernel; all other (iters, s) points are blind predictions. ---
    errs2 = []
    for name in CALIBRATE_ON + VALIDATE_ON:
        spec1, t1 = _measure(name, 1, 1)
        f1 = _features(spec1, 1, 1) @ coef
        scale = t1 / max(f1, 1e-12)
        for iters, s in POINTS[1:]:
            spec, t = _measure(name, iters, s)
            pred = float(_features(spec, iters, s) @ coef) * scale
            err = abs(pred - t) / t * 100
            errs2.append(err)
            rows.append(
                f"fig9/per_design/{name}/it{iters}_s{s},{t*1e6:.2f},"
                f"predicted_us={pred*1e6:.2f};error_pct={err:.1f}")
    rows.append(
        f"fig9/per_design_summary,0.00,"
        f"mean_error_pct={np.mean(errs2):.1f};"
        f"median_error_pct={np.median(errs2):.1f};"
        f"max_error_pct={np.max(errs2):.1f};"
        f"methodology=calibrate-once-per-design predict-across-iterations")
    return rows
