"""Serving-path throughput: cached batched dispatch vs per-request autotune.

The acceptance experiment for the runtime subsystem, on a 2D Jacobi
workload:

  * **baseline** — the pre-runtime flow: every request runs ``autotune``
    (re-ranking the design space and re-jitting the executor) and then the
    grid.  This is what "serve a stencil" cost before the design cache.
  * **served** — one ``StencilServer.register`` (autotune + compile +
    warmup, all through the ``DesignCache``), then micro-batched dispatch
    at several batch sizes; reports grids/sec vs batch size.
  * **cache check** — a second identical register on the shared cache must
    be a pure hit (no re-rank, no re-jit).

Run directly (``PYTHONPATH=src python benchmarks/serving_throughput.py``)
it asserts the >=5x speedup and the second-call cache hit, exiting
non-zero on regression; under the harness (``benchmarks/run.py``) it just
emits CSV rows.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit
from repro.core import autotune
from repro.core.dsl import parse
from repro.runtime import DesignCache
from repro.serve import StencilRequest, StencilServer

DSL = """
kernel: JACOBI2D_SERVE
iteration: 8
input float: in_1(256, 128)
output float: out_1(0,0) = (in_1(0,1) + in_1(1,0) + in_1(0,0)
    + in_1(0,-1) + in_1(-1,0)) / 5
"""

N_REQUESTS = 8
BATCH_SIZES = (1, 2, 4, 8)


def _requests(spec, n, rng):
    return [
        StencilRequest("jacobi2d", {
            name: rng.standard_normal(shape).astype(dt)
            for name, (dt, shape) in spec.inputs.items()
        })
        for _ in range(n)
    ]


def run(check: bool = False):
    rows = []
    spec = parse(DSL)
    rng = np.random.default_rng(0)
    reqs = _requests(spec, N_REQUESTS, rng)

    # ---- baseline: autotune + run per request (no cache, no batching) ----
    t0 = time.perf_counter()
    for req in reqs:
        design = autotune(spec)
        design.runner(req.arrays)
    baseline_s = time.perf_counter() - t0
    baseline_gps = N_REQUESTS / baseline_s
    emit(rows, "serving/baseline_autotune_per_req",
         baseline_s / N_REQUESTS * 1e6, f"{baseline_gps:.1f} grids/s")

    # ---- served: one cached design, micro-batched dispatch ----
    cache = DesignCache()
    best_gps = 0.0
    for bs in BATCH_SIZES:
        srv = StencilServer(max_batch=bs, cache=cache)
        srv.register("jacobi2d", spec)      # first bs: build; rest: cache hit
        t0 = time.perf_counter()
        srv.serve(reqs)
        served_s = time.perf_counter() - t0
        gps = N_REQUESTS / served_s
        best_gps = max(best_gps, gps)
        st = srv.stats()["jacobi2d"]
        emit(rows, f"serving/batched_bs{bs}", served_s / N_REQUESTS * 1e6,
             f"{gps:.1f} grids/s; {st['batches']} batches; "
             f"cache_hit={st['cache_hit']}")

    speedup = best_gps / baseline_gps
    emit(rows, "serving/speedup_vs_per_req_autotune", 0.0, f"{speedup:.1f}x")

    # ---- second identical serve call: must be a pure design-cache hit ----
    srv2 = StencilServer(max_batch=BATCH_SIZES[-1], cache=cache)
    reg2 = srv2.register("jacobi2d", spec)
    srv2.serve(_requests(spec, 4, rng))
    emit(rows, "serving/second_call_cache_hit", 0.0,
         f"hit={reg2.counters.cache_hit}; "
         f"build_s={reg2.counters.build_time_s:.3f}")

    if check:
        assert speedup >= 5.0, (
            f"serving speedup {speedup:.1f}x < 5x over per-request autotune"
        )
        assert reg2.counters.cache_hit, "second serve call missed the cache"
        assert reg2.counters.build_time_s == 0.0, "cache hit recompiled"
    return rows


if __name__ == "__main__":
    for row in run(check=True):
        print(row)
    print("OK: >=5x over per-request autotune; second call hit the cache")
