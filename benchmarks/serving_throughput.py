"""Serving-path throughput: cached, bucketed, async dispatch vs per-shape
autotune+compile.

The acceptance experiment for the runtime subsystem, in two parts:

**Single-geometry section** (the PR-1 gate, kept as a regression guard):

  * **baseline** — the pre-runtime flow: every request runs ``autotune``
    (re-ranking the design space and re-jitting the executor) and then the
    grid.
  * **served** — one ``StencilServer.register`` (autotune + compile +
    warmup, all through the ``DesignCache``), then micro-batched dispatch
    at several batch sizes; reports grids/sec vs batch size.
  * **cache check** — a second identical register on the shared cache must
    be a pure hit (no re-rank, no re-jit).

**Mixed-geometry section** (the shape-bucketing gate): a trace of >= 20
distinct grid shapes is served by ONE bucketed registration.

  * **baseline** — per-shape autotune+compile+run (what heterogeneous
    traffic cost before bucketing); sampled on a subset of shapes and
    averaged, since every sample pays a full re-rank + re-jit.
  * **bucketed** — one logical kernel, requests routed to padded masked
    bucket designs (must compile <= 4 buckets for the whole trace), async
    double-buffered dispatch.  Gates: >= 5x speedup per request over the
    per-shape baseline, and async dispatch no slower than sync (within a
    25% timing-noise allowance).
  * **correctness** — every result allclose (2e-4, the repo-wide executor
    tolerance) to ``kernels/ref.py``; additionally, for a subset of
    shapes, the bucketed result is **bit-identical** to executing the
    same masked design unpadded (bucket == grid shape).  Bit-identity is
    asserted against the same program *structure* because XLA does not
    guarantee bitwise-stable codegen across differently-shaped programs —
    the repo's own ref and jnp executors already differ by 1 ULP.

**Mixed-boundary section** (the full-boundary-matrix bucketing gate): a
trace of >= 20 distinct shapes spread across ALL FOUR boundary modes
(zero / constant / replicate / periodic) is served from one bucketed
registration per kernel, sharing the async micro-batch loop; every
result must be allclose to the reference oracle and bitwise-equal to
unpadded single-shot execution of the same streamed design (CPU).

**Mixed-boundary extras**: replicate/periodic placement index maps must
be memoized across the trace (builds bounded by distinct shapes, reuses
observed on replay), and the periodic registrations' narrow-margin
``wrap_rounds`` decision is threaded into the bitwise unpadded rebuild.

**Tile-pipeline section** (the kernel-layer gate): the batch-in-grid
double-buffered tile loop (``kernels/pipeline.py``) vs ``jax.vmap`` of
the same per-entry tile program — pipelined must be no slower on
XLA-CPU, lower to strictly fewer HLO fusion boundaries (optimized-HLO
inspection), and agree bitwise on CPU.

**IR optimizer section**: the lowering pipeline (``repro.core.ir``) must
strictly reduce ``ops_per_cell`` on at least one stock kernel (HEAT3D's
repeated ``2*in(0,0,0)`` sub-trees CSE to one binding), and the tuned
design's ranking must carry the per-pass op-delta report.

**Cold-start section** (the persistent-store gate, delegated to
``benchmarks/cold_start.py``): a fresh subprocess pointed at a warm
``DesignStore`` must reach its first result >= 10x faster than a cold
subprocess that autotunes + jits from scratch, bitwise-identical, with
zero autotune invocations and zero jit builds on the warm side.

Run directly (``PYTHONPATH=src python benchmarks/serving_throughput.py``)
it asserts all gates and exits non-zero on regression; ``--smoke`` runs
the same gates on a scaled-down trace (CI-sized: small grids, sampled
baseline).  Under the harness (``benchmarks/run.py``) it just emits CSV
rows.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit
from repro.core import autotune
from repro.core.dsl import parse
from repro.kernels import ref
from repro.runtime import DesignCache, build_bucket_runner
from repro.serve import StencilRequest, StencilServer

DSL = """
kernel: JACOBI2D_SERVE
iteration: 8
input float: in_1(256, 128)
output float: out_1(0,0) = (in_1(0,1) + in_1(1,0) + in_1(0,0)
    + in_1(0,-1) + in_1(-1,0)) / 5
"""

N_REQUESTS = 8
BATCH_SIZES = (1, 2, 4, 8)

MIXED_DSL = """
kernel: JACOBI2D_MIXED
iteration: {it}
input float: in_1({r}, {c})
output float: out_1(0,0) = (in_1(0,1) + in_1(1,0) + in_1(0,0)
    + in_1(0,-1) + in_1(-1,0)) / 5
"""


def _requests(spec, n, rng):
    return [
        StencilRequest("jacobi2d", {
            name: rng.standard_normal(shape).astype(dt)
            for name, (dt, shape) in spec.inputs.items()
        })
        for _ in range(n)
    ]


def _mixed_shapes(rng, n, lo, hi):
    """>= n distinct (R, C) shapes whose pow2 buckets span <= 4 rungs."""
    shapes = []
    seen = set()
    while len(shapes) < n:
        s = (int(rng.integers(lo[0], hi[0])), int(rng.integers(lo[1], hi[1])))
        if s not in seen:
            seen.add(s)
            shapes.append(s)
    return shapes


def _oracle(spec, arrays, iters):
    import jax.numpy as jnp

    one = {n: jnp.asarray(a) for n, a in arrays.items()}
    return np.asarray(ref.stencil_iterations_ref(spec, one, iters))


def run_single_geometry(rows, check: bool):
    spec = parse(DSL)
    rng = np.random.default_rng(0)
    reqs = _requests(spec, N_REQUESTS, rng)

    # ---- baseline: autotune + run per request (no cache, no batching) ----
    t0 = time.perf_counter()
    for req in reqs:
        design = autotune(spec)
        design.runner(req.arrays)
    baseline_s = time.perf_counter() - t0
    baseline_gps = N_REQUESTS / baseline_s
    emit(rows, "serving/baseline_autotune_per_req",
         baseline_s / N_REQUESTS * 1e6, f"{baseline_gps:.1f} grids/s")

    # ---- served: one cached design, micro-batched dispatch ----
    cache = DesignCache()
    best_gps = 0.0
    for bs in BATCH_SIZES:
        srv = StencilServer(max_batch=bs, cache=cache)
        srv.register("jacobi2d", spec)      # first bs: build; rest: cache hit
        t0 = time.perf_counter()
        srv.serve(reqs)
        served_s = time.perf_counter() - t0
        gps = N_REQUESTS / served_s
        best_gps = max(best_gps, gps)
        st = srv.stats()["jacobi2d"]
        emit(rows, f"serving/batched_bs{bs}", served_s / N_REQUESTS * 1e6,
             f"{gps:.1f} grids/s; {st['batches']} batches; "
             f"cache_hit={st['cache_hit']}")

    speedup = best_gps / baseline_gps
    emit(rows, "serving/speedup_vs_per_req_autotune", 0.0, f"{speedup:.1f}x")

    # ---- second identical serve call: must be a pure design-cache hit ----
    srv2 = StencilServer(max_batch=BATCH_SIZES[-1], cache=cache)
    reg2 = srv2.register("jacobi2d", spec)
    srv2.serve(_requests(spec, 4, rng))
    emit(rows, "serving/second_call_cache_hit", 0.0,
         f"hit={reg2.counters.cache_hit}; "
         f"build_s={reg2.counters.build_time_s:.3f}")

    if check:
        assert speedup >= 5.0, (
            f"serving speedup {speedup:.1f}x < 5x over per-request autotune"
        )
        assert reg2.counters.cache_hit, "second serve call missed the cache"
        assert reg2.counters.build_time_s == 0.0, "cache hit recompiled"


def run_mixed_geometry(rows, check: bool, smoke: bool):
    iters = 4 if smoke else 8
    n_shapes = 20
    lo, hi = ((20, 12), (60, 30)) if smoke else ((100, 70), (250, 120))
    n_baseline = 5 if smoke else n_shapes
    rng = np.random.default_rng(1)
    shapes = _mixed_shapes(rng, n_shapes, lo, hi)

    def spec_for(shape):
        return parse(MIXED_DSL.format(it=iters, r=shape[0], c=shape[1]))

    base_spec = spec_for(shapes[0])
    traffic = {
        s: {"in_1": rng.standard_normal(s).astype(np.float32)}
        for s in shapes
    }

    # ---- baseline: per-shape autotune + compile + run ----
    t0 = time.perf_counter()
    for s in shapes[:n_baseline]:
        design = autotune(spec_for(s))      # no cache: re-rank + re-jit
        design.runner(traffic[s])
    baseline_per_req = (time.perf_counter() - t0) / n_baseline
    emit(rows, "serving/mixed_baseline_per_shape_autotune",
         baseline_per_req * 1e6,
         f"{n_baseline} shapes sampled; {1.0 / baseline_per_req:.2f} grids/s")

    # ---- bucketed: one registration serves the whole trace ----
    # cold pass: register + first serve (pays the <= 4 bucket compiles) —
    # this is what amortization must beat.  warm pass: steady-state
    # dispatch, used for the async-vs-sync comparison so compile noise
    # doesn't drown the dispatch-path difference.
    # one shared cache: the async pass pays the bucket compiles (its cold
    # time is the speedup gate); the sync pass reuses the same compiled
    # designs, so async-vs-sync compares the very same programs
    shared_cache = DesignCache()

    def serve_trace(async_dispatch):
        srv = StencilServer(
            max_batch=4, cache=shared_cache, bucketing=True,
            async_dispatch=async_dispatch,
        )
        reqs = [StencilRequest("jacobi2d", traffic[s]) for s in shapes]
        t0 = time.perf_counter()
        srv.register("jacobi2d", base_spec)
        srv.serve(reqs)
        cold_s = time.perf_counter() - t0
        t0 = time.perf_counter()
        outs = srv.serve(reqs)
        warm_s = time.perf_counter() - t0
        return srv, outs, cold_s, warm_s

    srv_a, outs_a, cold_async_s, async_s = serve_trace(async_dispatch=True)
    srv_s, outs_s, _, sync_s = serve_trace(async_dispatch=False)
    st = srv_a.stats()["jacobi2d"]
    buckets = st["compiled_buckets"]
    speedup = baseline_per_req / (cold_async_s / n_shapes)
    emit(rows, "serving/mixed_bucketed_cold", cold_async_s / n_shapes * 1e6,
         f"{n_shapes} shapes from {buckets} buckets incl. compiles; "
         f"{n_shapes / cold_async_s:.1f} grids/s")
    emit(rows, "serving/mixed_bucketed_async_warm", async_s / n_shapes * 1e6,
         f"{n_shapes / async_s:.1f} grids/s")
    emit(rows, "serving/mixed_bucketed_sync_warm", sync_s / n_shapes * 1e6,
         f"{n_shapes / sync_s:.1f} grids/s")
    emit(rows, "serving/mixed_speedup_vs_per_shape", 0.0,
         f"{speedup:.1f}x (cold, compiles included)")
    emit(rows, "serving/mixed_async_vs_sync", 0.0,
         f"{sync_s / async_s:.2f}x (warm; async/sync must be >= ~0.8)")

    # ---- correctness: allclose vs the reference oracle on every shape,
    # async == sync bitwise, and bit-identity vs unpadded execution of the
    # same masked design on a subset ----
    for s, out_a, out_s in zip(shapes, outs_a, outs_s):
        assert out_a.shape == s, (out_a.shape, s)
        np.testing.assert_array_equal(out_a, out_s)
        np.testing.assert_allclose(
            out_a, _oracle(spec_for(s), traffic[s], iters),
            rtol=2e-4, atol=2e-4,
        )
    # bit-identity vs unpadded execution of the same masked design: XLA
    # compiles the bucket and exact shapes as separate programs, so exact
    # equality is only guaranteed on backends with shape-stable elementwise
    # codegen — CPU (where CI runs) in practice.  Elsewhere fall back to
    # the repo-wide tolerance rather than gating on XLA internals.
    import jax

    bit_exact = jax.default_backend() == "cpu"
    bit_checked = 0
    for s, out_a in list(zip(shapes, outs_a))[:3]:
        sp = spec_for(s)
        entry = srv_a.design("jacobi2d").cached.runner_for(s, count=0)
        unpadded = build_bucket_runner(
            sp, s, entry.config, iterations=iters,
        )({n: a[None] for n, a in traffic[s].items()})[0]
        if bit_exact:
            np.testing.assert_array_equal(out_a, unpadded)
        else:
            np.testing.assert_allclose(
                out_a, unpadded, rtol=2e-4, atol=2e-4
            )
        bit_checked += 1
    emit(rows, "serving/mixed_correctness", 0.0,
         f"{n_shapes} shapes allclose vs ref; {bit_checked} "
         f"{'bit-identical' if bit_exact else 'allclose'} vs unpadded")

    if check:
        assert len(set(shapes)) >= 20, "trace must cover >= 20 shapes"
        assert buckets <= 4, (
            f"{buckets} compiled bucket designs > 4 for the mixed trace"
        )
        assert speedup >= 5.0, (
            f"bucketed serving {speedup:.1f}x < 5x over per-shape autotune"
        )
        assert async_s <= sync_s * 1.25, (
            f"async dispatch slower than sync: {async_s:.3f}s vs "
            f"{sync_s:.3f}s"
        )


BOUNDARY_DSL = """
kernel: JACOBI2D_{tag}
iteration: {it}
boundary: {boundary}
input float: in_1({r}, {c})
output float: out_1(0,0) = (in_1(0,1) + in_1(1,0) + in_1(0,0)
    + in_1(0,-1) + in_1(-1,0)) / 5
"""


def run_mixed_boundary(rows, check: bool, smoke: bool):
    """The full-boundary-matrix bucketing gate: a mixed-shape trace under
    ALL FOUR boundary modes (>= 20 distinct shapes total, >= 5 per mode)
    is served from ONE bucketed registration per kernel, sharing the
    async micro-batch loop, with every result bitwise-equal to unpadded
    single-shot execution of the same streamed design (CPU backends;
    allclose + oracle-exact elsewhere — the repo-wide XLA caveat)."""
    import jax

    from repro.runtime import build_bucket_runner, padded_request_shape

    iters = 3 if smoke else 6
    per_mode = 5 if smoke else 8
    lo, hi = ((18, 12), (48, 28)) if smoke else ((80, 60), (200, 100))
    rng = np.random.default_rng(2)
    modes = ["zero", "constant 25.0", "replicate", "periodic"]

    def spec_for(boundary, shape):
        tag = boundary.split()[0].upper()
        return parse(BOUNDARY_DSL.format(
            tag=tag, it=iters, boundary=boundary, r=shape[0], c=shape[1],
        ))

    srv = StencilServer(
        max_batch=4, cache=DesignCache(), bucketing=True,
        async_dispatch=True,
    )
    traffic = {}        # (mode, shape) -> arrays
    shapes_by_mode = {}
    for mode in modes:
        shapes = _mixed_shapes(rng, per_mode, lo, hi)
        shapes_by_mode[mode] = shapes
        srv.register(mode.split()[0], spec_for(mode, shapes[0]))
        for s in shapes:
            traffic[(mode, s)] = {
                "in_1": rng.standard_normal(s).astype(np.float32)
            }

    reqs = [
        StencilRequest(mode.split()[0], traffic[(mode, s)])
        for mode in modes for s in shapes_by_mode[mode]
    ]
    t0 = time.perf_counter()
    outs = srv.serve(reqs)
    trace_s = time.perf_counter() - t0
    # warm replay: serving traffic repeats its shapes, which is what the
    # per-(shape, mode) placement-index memo exists for — and replayed
    # dispatch must be deterministic
    outs_warm = srv.serve(reqs)
    for a, b in zip(outs, outs_warm):
        np.testing.assert_array_equal(a, b)
    n_total = len(reqs)
    n_distinct = len({s for m in modes for s in shapes_by_mode[m]})
    compiled = sum(
        srv.stats()[m.split()[0]]["compiled_buckets"] for m in modes
    )
    emit(rows, "serving/mixed_boundary_trace", trace_s / n_total * 1e6,
         f"{n_total} grids, {n_distinct} distinct shapes, 4 boundary "
         f"modes, {compiled} compiled bucket designs, "
         f"{n_total / trace_s:.1f} grids/s")

    # correctness: oracle allclose everywhere; bitwise vs unpadded
    # single-shot execution of the same streamed design on CPU
    bit_exact = jax.default_backend() == "cpu"
    bit_checked = 0
    it = iter(outs)
    for mode in modes:
        for s in shapes_by_mode[mode]:
            out = next(it)
            sp = spec_for(mode, s)
            assert out.shape == s, (mode, out.shape, s)
            np.testing.assert_allclose(
                out, _oracle(sp, traffic[(mode, s)], iters),
                rtol=2e-4, atol=2e-4, err_msg=f"{mode} {s}",
            )
            # unpadded single-shot: the same streamed design at its
            # minimal fit (grid + halo margins, no bucket padding).  Run
            # at the server's batch width: XLA-CPU codegen is bitwise
            # shape-stable across grid shapes but NOT across vmap batch
            # widths (B=1 vs B=4 re-vectorises with 1-ULP FMA drift).
            bd = srv.design(mode.split()[0]).cached
            entry = bd.runner_for(s, count=0)
            # the registration's narrow-margin decision (periodic
            # single-device serves from wrap_rounds * radius, not
            # iterations * radius) shapes the compiled design — the
            # unpadded rebuild must thread it to compare the same program
            minimal = padded_request_shape(sp, s, iters, bd.wrap_rounds)
            unpadded = build_bucket_runner(
                sp, minimal, entry.config, iterations=iters,
                wrap_rounds=bd.wrap_rounds,
            )({
                n: np.stack([a] * srv.max_batch)
                for n, a in traffic[(mode, s)].items()
            })[0]
            if bit_exact:
                np.testing.assert_array_equal(
                    out, unpadded, err_msg=f"{mode} {s} vs unpadded"
                )
            else:
                np.testing.assert_allclose(
                    out, unpadded, rtol=2e-4, atol=2e-4,
                    err_msg=f"{mode} {s} vs unpadded",
                )
            bit_checked += 1
    emit(rows, "serving/mixed_boundary_correctness", 0.0,
         f"{n_total} grids allclose vs ref; {bit_checked} "
         f"{'bit-identical' if bit_exact else 'allclose'} vs unpadded "
         "single-shot")

    # placement index maps must be memoized across the trace: replicate /
    # periodic staging gathers through per-(shape, mode) index vectors
    # that a serving loop replays thousands of times — count builds vs
    # reuses over every bucket plan the trace touched
    place_builds = place_reuses = 0
    for mode in ("replicate", "periodic"):
        bd = srv.design(mode).cached
        for bucket in bd.buckets:
            plan = bd.entry_for_bucket(bucket, count=0).runner.plan
            place_builds += plan.place_index_builds
            place_reuses += plan.place_index_reuses
    emit(rows, "serving/mixed_boundary_place_index_memo", 0.0,
         f"{place_builds} index-map builds, {place_reuses} reuses")

    if check:
        assert n_distinct >= 20, (
            f"mixed-boundary trace covers {n_distinct} shapes < 20"
        )
        assert all(
            len(set(shapes_by_mode[m])) >= 5 for m in modes
        ), "each boundary mode must contribute >= 5 shapes"
        for m in modes:
            st = srv.stats()[m.split()[0]]
            # cold trace + warm replay each serve per_mode requests
            assert st["requests"] == 2 * per_mode, (m, st["requests"])
            assert st["failed_requests"] == 0, (m, st["failed_requests"])
        # each distinct shape builds its index maps at most once per
        # bucket plan; the bitwise-comparison rebuilds above replayed the
        # trace shapes, so reuse must have kicked in
        assert place_builds <= 2 * per_mode, (
            f"{place_builds} place-index builds for {2 * per_mode} "
            "(mode, shape) pairs — memoization regressed"
        )
        assert place_reuses > 0, "place-index maps never reused"


PIPE_DSL = """
kernel: JACOBI2D_PIPE
iteration: {it}
input float: in_1({r}, {c})
output float: out_1(0,0) = (in_1(0,1) + in_1(1,0) + in_1(0,0)
    + in_1(0,-1) + in_1(-1,0)) / 5
"""


def run_tile_pipeline(rows, check: bool, smoke: bool):
    """The batch-in-grid tile-pipeline gate (kernel-layer acceptance).

    Compares the two ways of running the *same tile program* over a
    batch on XLA-CPU:

      * **vmap** — the legacy idiom: ``jax.vmap`` wraps a per-entry
        single-buffered tile loop, so every batch entry drags its own
        loop state through the batched program.
      * **pipelined** — the batch axis folded into one double-buffered
        tile loop (``pipeline.stencil_run_batched``).

    Gates: the pipelined program is no slower (25% timing-noise
    allowance), lowers to **strictly fewer HLO fusion boundaries**
    (counted on the optimized HLO — each fusion region boundary is a
    materialization point the scheduler cannot overlap across), and is
    bitwise-identical on CPU (same tile program, different schedule).
    The dense whole-grid vmap path is emitted as context, not gated: it
    runs a different (untiled) program, so its timing answers a
    different question.
    """
    import jax
    import jax.numpy as jnp

    from repro.kernels import ops, pipeline

    it, s, tile = (4, 2, 32) if smoke else (8, 4, 64)
    shape = (128, 64) if smoke else (256, 128)
    B = 4 if smoke else 8
    spec = parse(PIPE_DSL.format(it=it, r=shape[0], c=shape[1]))
    rng = np.random.default_rng(7)
    batched = {
        "in_1": jnp.asarray(
            rng.standard_normal((B,) + shape).astype(np.float32)
        )
    }

    def vmap_tiled(arrays):
        def one(entry):
            cur, left = dict(entry), it
            while left > 0:
                step = min(s, left)
                out = pipeline.stencil_jnp_tiled(spec, cur, step, tile)
                cur[spec.iterate_input] = out
                left -= step
            return out

        return jax.vmap(one)(arrays)

    def pipelined(arrays):
        return pipeline.stencil_run_batched(
            spec, arrays, it, s=s, tile_rows=tile, backend="jnp"
        )

    def vmap_dense(arrays):
        return jax.vmap(
            lambda one: ops.stencil_run(
                spec, one, it, s=s, backend="jnp", tile_rows=tile
            )
        )(arrays)

    def bench(fn):
        j = jax.jit(fn)
        fusions = j.lower(batched).compile().as_text().count("fusion(")
        out = np.asarray(j(batched))              # compile + warm
        reps = 3 if smoke else 5
        t0 = time.perf_counter()
        for _ in range(reps):
            out = np.asarray(j(batched))
        return (time.perf_counter() - t0) / reps, fusions, out

    vmap_s, vmap_fusions, out_vmap = bench(vmap_tiled)
    pipe_s, pipe_fusions, out_pipe = bench(pipelined)
    dense_s, dense_fusions, _ = bench(vmap_dense)

    emit(rows, "pipeline/vmap_tiled", vmap_s * 1e6,
         f"{vmap_fusions} HLO fusion boundaries")
    emit(rows, "pipeline/batch_in_grid", pipe_s * 1e6,
         f"{pipe_fusions} HLO fusion boundaries; "
         f"{vmap_s / pipe_s:.2f}x vs vmap")
    emit(rows, "pipeline/vmap_dense_context", dense_s * 1e6,
         f"{dense_fusions} HLO fusion boundaries (untiled program, "
         "not gated)")

    bit_exact = jax.default_backend() == "cpu"
    if bit_exact:
        np.testing.assert_array_equal(out_pipe, out_vmap)
    else:
        np.testing.assert_allclose(out_pipe, out_vmap, rtol=2e-4, atol=2e-4)
    emit(rows, "pipeline/differential", 0.0,
         "bitwise vs vmap" if bit_exact else "allclose vs vmap")

    if check:
        assert pipe_s <= vmap_s * 1.25, (
            f"tile pipeline slower than vmap: {pipe_s:.4f}s vs "
            f"{vmap_s:.4f}s"
        )
        assert pipe_fusions < vmap_fusions, (
            f"tile pipeline must lower to strictly fewer HLO fusion "
            f"boundaries: {pipe_fusions} vs vmap's {vmap_fusions}"
        )


def run_ir_optimizer(rows, check: bool):
    """The IR gate: lowering strictly reduces ops on >= 1 stock kernel."""
    from repro.configs import stencils
    from repro.core.ir import lower

    reduced = []
    for name in sorted(stencils.BENCHMARKS):
        shape = (16, 8, 8) if name in stencils.BENCHMARKS_3D else (16, 8)
        spec = stencils.get(name, shape=shape, iterations=2)
        low = lower(spec)
        if low.ops_per_cell < spec.ops_per_cell:
            reduced.append((name, spec.ops_per_cell, low.ops_per_cell))
    emit(rows, "ir/kernels_with_reduced_ops", 0.0,
         "; ".join(f"{n}: {b}->{a} ops/cell" for n, b, a in reduced)
         or "none")
    # the analytical model consumes post-optimization counts: the tuned
    # design's spec must carry the reduced op count + the op-delta report
    spec = stencils.get("heat3d", shape=(64, 8, 8), iterations=2)
    design = autotune(spec, build=False)
    emit(rows, "ir/autotuned_heat3d_ops_per_cell",
         float(design.spec.ops_per_cell),
         "; ".join(str(r) for r in design.lowering))
    if check:
        assert reduced, (
            "IR optimizer failed to strictly reduce ops_per_cell on any "
            "stock kernel"
        )
        assert design.spec.ops_per_cell < spec.ops_per_cell
        assert any(r.delta > 0 for r in design.lowering), design.lowering


def run_cold_start(rows, check: bool):
    """The persistent-store gate: a fresh subprocess against a warm
    ``DesignStore`` reaches its first bitwise-identical result >= 10x
    faster than cold autotune+jit, with zero autotune invocations and
    zero jit builds (see :mod:`benchmarks.cold_start`)."""
    from benchmarks import cold_start

    cold_start.run_cold_start(rows, check)


def run(check: bool = False, smoke: bool = False):
    rows = []
    run_ir_optimizer(rows, check)
    run_tile_pipeline(rows, check, smoke)
    run_single_geometry(rows, check)
    run_mixed_geometry(rows, check, smoke)
    run_mixed_boundary(rows, check, smoke)
    run_cold_start(rows, check)
    return rows


if __name__ == "__main__":
    import sys

    smoke = "--smoke" in sys.argv[1:]
    for row in run(check=True, smoke=smoke):
        print(row)
    print("OK: IR optimizer strictly reduces ops_per_cell; tile pipeline "
          "no slower than vmap with strictly fewer HLO fusion boundaries "
          "and bitwise-equal results; single-geometry >=5x + cache hit; "
          "mixed trace: >=20 shapes from <=4 buckets, >=5x over per-shape "
          "autotune, async not slower than sync, results reference-exact; "
          "mixed-boundary trace: >=20 shapes across all 4 boundary modes "
          "from one registration per kernel, bitwise-equal to unpadded "
          "single-shot execution, placement index maps memoized; "
          "cold-start: warm-store subprocess >=10x faster to first "
          "bitwise-identical result with zero autotune/jit")
