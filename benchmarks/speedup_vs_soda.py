"""Paper Sec. 5.4 headline: SASA (best hybrid/spatial/temporal) speedup
over SODA (temporal-only), averaged across kernels and iteration counts.

Paper: 3.74x average, 15.73x max (JACOBI3D at iteration=1) on U280.
We report the same sweep on both modelled platforms, plus a measured
single-host data point (fused temporal executor vs per-iteration
executor, the single-PE reuse benefit).
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks.common import time_call
from repro.configs import stencils
from repro.core import model
from repro.core.platform import DEFAULT_FPGA, DEFAULT_TPU
from repro.kernels import ops

PAPER_PE = {
    "jacobi2d": 21, "jacobi3d": 15, "blur": 12, "seidel2d": 12,
    "dilate": 18, "hotspot": 9, "heat3d": 12, "sobel2d": 12,
}
ITERS = [1, 2, 4, 8, 16, 32, 64]


def _sweep(platform, pe_override=None):
    speedups = {}
    for name, pe in PAPER_PE.items():
        shape = (9720, 32, 32) if name in stencils.BENCHMARKS_3D \
            else (9720, 1024)
        for it in ITERS:
            spec = stencils.get(name, shape=shape, iterations=it)
            kw = {"pe_res_override": pe} if pe_override else {}
            ranked = model.choose_best(spec, platform, **kw)
            best = ranked[0]
            temporal = min(
                (p for p in ranked if p.config.variant == "temporal"),
                key=lambda p: p.latency)
            speedups[(name, it)] = temporal.latency / best.latency
    return speedups


def run():
    rows = []
    for label, plat, pe in [("fpga_u280", DEFAULT_FPGA, True),
                            ("tpu_v5e_8chip", DEFAULT_TPU.with_chips(8),
                             False)]:
        sp = _sweep(plat, pe)
        vals = np.array(list(sp.values()))
        mx = max(sp, key=sp.get)
        rows.append(
            f"sec5.4/speedup_vs_soda/{label},0.00,"
            f"avg={vals.mean():.2f}x;max={vals.max():.2f}x;"
            f"max_at={mx[0]}.iter{mx[1]};paper_avg=3.74x;paper_max=15.73x")
        for name in PAPER_PE:
            per = [sp[(name, it)] for it in ITERS]
            rows.append(
                f"sec5.4/speedup/{label}/{name},0.00,"
                f"avg={np.mean(per):.2f}x;iter1={sp[(name, 1)]:.2f}x;"
                f"iter64={sp[(name, 64)]:.2f}x")
    # measured on this host: fused temporal (s=16) vs per-iteration (s=1)
    spec = stencils.jacobi2d(shape=(972, 128), iterations=16)
    arrays = {"in_1": jnp.ones((972, 128), jnp.float32)}
    t1 = time_call(ops.stencil_run, spec, arrays, 16, s=1, backend="jnp")
    t16 = time_call(ops.stencil_run, spec, arrays, 16, s=16, backend="jnp")
    rows.append(
        f"sec5.4/measured_fusion_speedup/jacobi2d,{t16*1e6:.2f},"
        f"s1_us={t1*1e6:.2f};speedup={t1/t16:.2f}x")
    return rows
