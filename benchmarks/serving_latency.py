"""Open-loop serving latency: continuous batching vs the flush barrier.

The acceptance experiment for the continuous-batching serving tier
(:mod:`repro.serve.scheduler`).  A **Poisson open-loop load generator**
replays one mixed-kernel / mixed-shape arrival trace — arrivals are
drawn once (exponential inter-arrival gaps) and then fired at their
scheduled times regardless of how fast the server responds, which is
what real traffic does and what closed-loop benchmarks get wrong —
against the two serving paths, built over one shared design cache so
both dispatch the *same compiled programs*:

  * **flush baseline** — the engine's barrier loop as a service:
    arrivals are ``submit()``-ed and a flusher calls ``flush()`` every
    ``flush_interval_s``.  A request's latency includes however much of
    the flush interval it spent waiting for the next barrier, plus the
    whole barrier's dispatch time.
  * **continuous** — arrivals go straight to
    ``StencilScheduler.submit``; the background loop coalesces per
    design x bucket up to ``max_batch`` and dispatches as soon as a
    group fills, its gather window lapses, or deadline slack runs low.

Reported per path: makespan throughput (grids/s over first-arrival ->
last-resolution) and latency percentiles (p50 / p99 of scheduled-arrival
-> resolution).  Gates (``check=True``):

  * **zero drops** — every admitted ticket resolves, both paths;
  * **throughput** — continuous >= 0.9x the flush baseline (same trace,
    same compiled designs; the scheduler must not tax steady-state
    throughput for its latency win);
  * **p99** — continuous <= the flush baseline's p99 (the entire point:
    no request waits for a barrier);
  * **bitwise** — every continuous result equals synchronous single-shot
    ``serve()`` of the same request bit-for-bit (CPU backends; the
    scheduler stages through the engine's own padded ``_prepare``, so
    batch composition cannot leak into numerics).

``--smoke`` runs the same gates on a CI-sized trace.  Under the harness
(``benchmarks/run.py``) it emits CSV rows only.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit
from repro.configs import stencils
from repro.runtime import DesignCache
from repro.serve import StencilRequest, StencilScheduler, StencilServer


def _percentile(lat_s: list[float], q: float) -> float:
    return float(np.percentile(np.asarray(lat_s), q)) if lat_s else 0.0


def build_trace(smoke: bool, rng):
    """One Poisson arrival schedule over a mixed-kernel, mixed-shape mix.

    Returns ``(designs, trace)`` where ``designs`` maps name -> spec and
    ``trace`` is ``[(arrival_s, StencilRequest), ...]`` sorted by
    arrival.  The mix interleaves two kernels at two grid geometries, so
    the batcher must keep four design x shape groups coherent at once.
    """
    iters = 2 if smoke else 4
    n = 48 if smoke else 240
    rate_hz = 150.0 if smoke else 300.0
    designs = {
        "jac_s": stencils.jacobi2d(
            shape=(20, 12) if smoke else (64, 32), iterations=iters),
        "jac_l": stencils.jacobi2d(
            shape=(28, 16) if smoke else (96, 48), iterations=iters),
        "hot_s": stencils.hotspot(
            shape=(20, 12) if smoke else (64, 32), iterations=iters),
        "hot_l": stencils.hotspot(
            shape=(28, 16) if smoke else (96, 48), iterations=iters),
    }
    names = sorted(designs)
    arrivals = np.cumsum(rng.exponential(1.0 / rate_hz, size=n))
    trace = []
    for t in arrivals:
        name = names[int(rng.integers(len(names)))]
        spec = designs[name]
        trace.append((float(t), StencilRequest(name, {
            k: rng.standard_normal(shape).astype(dt)
            for k, (dt, shape) in spec.inputs.items()
        })))
    return designs, trace


def replay_flush(server, trace, flush_interval_s: float):
    """Fire the trace open-loop at a flush-barrier server; returns
    (latencies, makespan, unresolved count)."""
    lat = []
    pending: dict[int, float] = {}       # ticket -> scheduled arrival
    t0 = time.monotonic()
    last_flush = t0

    def collect(done, now):
        for ticket in done:
            if ticket in pending:
                lat.append(now - pending.pop(ticket))

    for arrive_s, request in trace:
        due = t0 + arrive_s
        delay = due - time.monotonic()
        if delay > 0:
            time.sleep(delay)
        pending[server.submit(request)] = due
        now = time.monotonic()
        if now - last_flush >= flush_interval_s:
            collect(server.flush(), time.monotonic())
            last_flush = time.monotonic()
    collect(server.flush(), time.monotonic())
    makespan = time.monotonic() - t0
    return lat, makespan, len(pending)


def replay_continuous(scheduler, trace):
    """Fire the same trace open-loop at the continuous scheduler;
    returns (latencies, makespan, tickets-with-requests)."""
    fired = []
    t0 = time.monotonic()
    for arrive_s, request in trace:
        due = t0 + arrive_s
        delay = due - time.monotonic()
        if delay > 0:
            time.sleep(delay)
        fired.append((due, scheduler.submit(request), request))
    scheduler.drain()
    makespan = time.monotonic() - t0
    lat = [t.completed_at - due for due, t, _ in fired if t.completed_at]
    return lat, makespan, fired


def run(check: bool = False, smoke: bool = False):
    rows = []
    rng = np.random.default_rng(42)
    designs, trace = build_trace(smoke, rng)
    n = len(trace)
    max_batch = 4
    flush_interval_s = 0.05 if smoke else 0.1
    cache = DesignCache()                # shared: same compiled programs

    def new_server():
        srv = StencilServer(max_batch=max_batch, cache=cache, warmup=True)
        for name, spec in designs.items():
            srv.register(name, spec)
        return srv

    # ---- flush-barrier baseline ----
    srv_flush = new_server()
    flush_lat, flush_span, flush_lost = replay_flush(
        srv_flush, trace, flush_interval_s
    )
    flush_gps = n / flush_span
    emit(rows, "latency/flush_p50_ms", _percentile(flush_lat, 50) * 1e3,
         f"{n} reqs, flush every {flush_interval_s * 1e3:.0f}ms")
    emit(rows, "latency/flush_p99_ms", _percentile(flush_lat, 99) * 1e3,
         f"{flush_gps:.1f} grids/s; {flush_lost} unresolved")

    # ---- continuous batching ----
    srv_cont = new_server()
    scheduler = StencilScheduler(srv_cont)
    cont_lat, cont_span, fired = replay_continuous(scheduler, trace)
    scheduler.close()
    cont_gps = n / cont_span
    unresolved = sum(1 for _, t, _ in fired if not t.done())
    faults = sum(1 for _, t, _ in fired if t.exception() is not None)
    emit(rows, "latency/continuous_p50_ms", _percentile(cont_lat, 50) * 1e3,
         f"{n} reqs, gather window "
         f"{scheduler.gather_window_s * 1e3:.1f}ms")
    emit(rows, "latency/continuous_p99_ms", _percentile(cont_lat, 99) * 1e3,
         f"{cont_gps:.1f} grids/s; {unresolved} unresolved; "
         f"{faults} faults; "
         f"{scheduler.stats()['dispatched_batches']} batches")
    emit(rows, "latency/p99_improvement", 0.0,
         f"{_percentile(flush_lat, 99) / max(_percentile(cont_lat, 99), 1e-9):.1f}x "
         f"lower p99; throughput {cont_gps / flush_gps:.2f}x of flush")

    # ---- bitwise identity vs synchronous single-shot execution ----
    import jax

    srv_ref = new_server()
    bit_exact = jax.default_backend() == "cpu"
    checked = 0
    sample = fired if smoke else fired[:: max(1, len(fired) // 50)]
    for _, ticket, request in sample:
        ref_out = srv_ref.serve([request])[0]
        got = ticket.result(timeout=60.0)
        if bit_exact:
            np.testing.assert_array_equal(got, ref_out)
        else:
            np.testing.assert_allclose(got, ref_out, rtol=2e-4, atol=2e-4)
        checked += 1
    emit(rows, "latency/bitwise_vs_sync", 0.0,
         f"{checked} results "
         f"{'bit-identical' if bit_exact else 'allclose'} to single-shot "
         "serve()")

    if check:
        assert flush_lost == 0, f"flush baseline lost {flush_lost} tickets"
        assert unresolved == 0, (
            f"continuous scheduler left {unresolved} tickets unresolved"
        )
        assert faults == 0, f"{faults} dispatch faults on the trace"
        assert len(cont_lat) == n and len(flush_lat) == n, (
            f"latency samples short of trace: continuous {len(cont_lat)}, "
            f"flush {len(flush_lat)}, trace {n}"
        )
        assert cont_gps >= flush_gps * 0.9, (
            f"continuous throughput {cont_gps:.1f} grids/s < 0.9x flush "
            f"baseline {flush_gps:.1f}"
        )
        p99_f, p99_c = _percentile(flush_lat, 99), _percentile(cont_lat, 99)
        assert p99_c <= p99_f, (
            f"continuous p99 {p99_c * 1e3:.1f}ms worse than flush barrier "
            f"{p99_f * 1e3:.1f}ms"
        )
    return rows


if __name__ == "__main__":
    import sys

    smoke = "--smoke" in sys.argv[1:]
    for row in run(check=True, smoke=smoke):
        print(row)
    print("OK: Poisson open-loop trace served with zero drops; continuous "
          "batching sustains >= 0.9x flush-barrier throughput with p99 at "
          "or below the barrier's; every result bitwise-identical to "
          "synchronous single-shot serve()")
