"""Shared benchmark utilities."""
from __future__ import annotations

import time

import jax
import numpy as np


def time_call(fn, *args, warmup=1, iters=3, **kw):
    """Median wall-time of fn(*args) in seconds (blocks on jax arrays)."""
    for _ in range(warmup):
        r = fn(*args, **kw)
        jax.block_until_ready(r)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        r = fn(*args, **kw)
        jax.block_until_ready(r)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def emit(rows, name, us, derived=""):
    rows.append(f"{name},{us:.2f},{derived}")
    return rows
