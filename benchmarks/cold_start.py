"""Cold-start gate: a fresh replica warm-loads the design store >= 10x
faster than re-autotuning and re-jitting, with bitwise-identical output.

The persistent :class:`repro.runtime.DesignStore` is the TPU analogue of
shipping a compiled FPGA bitstream: the expensive artifact (the tuned
ranking + the AOT-compiled executable) outlives the process that built
it.  This benchmark proves the claim end to end, across real process
boundaries:

  1. **cold child** — a fresh subprocess pointed at an *empty* store
     serves one request: pays the full autotune (design-space rank) +
     jit trace/compile + AOT serialize-to-store cost.
  2. **warm child** — a second fresh subprocess pointed at the *same*
     store serves the identical request: must reach its first result
     with **zero autotune invocations and zero jit builds**
     (``autotune_calls == 0 and jit_builds == 0``), >= 10x faster than
     the cold child, and the saved outputs must be **bitwise equal**
     (the warm path replays the very same XLA executable, so this holds
     on every backend, not just CPU).

Time-to-first-result is measured *inside* each child from after process
bootstrap (interpreter + jax import) to the first completed result:
import cost is identical on both sides and is not what the store
optimizes away.  The cold child's store writes are inside its timed
region — warm-start wins even after charging cold for populating the
store.

Run directly (``PYTHONPATH=src:. python benchmarks/cold_start.py``) it
asserts the gates; ``--smoke`` uses the same trace (already CI-sized).
``scripts/ci.sh`` runs it via ``serving_throughput.py --smoke``.
"""
from __future__ import annotations

import json
import os
import pathlib
import subprocess
import sys
import tempfile
import time

import numpy as np

ROOT = pathlib.Path(__file__).resolve().parent.parent

DSL = """
kernel: JACOBI2D_COLDSTART
iteration: 32
input float: in_1(256, 128)
output float: out_1(0,0) = (in_1(0,1) + in_1(1,0) + in_1(0,0)
    + in_1(0,-1) + in_1(-1,0)) / 5
"""


def _child(store_dir: str, out_npy: str, report_json: str) -> None:
    """One serving replica: store-backed server, one request, one result.

    Runs in a fresh subprocess.  Everything a replica pays between
    "process is up" and "first result returned" is inside the timed
    region: cache construction (store manifest + telemetry load),
    registration (autotune or store ranking hit), and the first dispatch
    (jit+AOT compile or store executable load).
    """
    from repro.core.dsl import parse
    from repro.serve import StencilRequest, StencilServer

    spec = parse(DSL)
    rng = np.random.default_rng(42)
    arrays = {
        name: rng.standard_normal(shape).astype(dt)
        for name, (dt, shape) in spec.inputs.items()
    }

    t0 = time.perf_counter()
    srv = StencilServer(max_batch=1, store_dir=store_dir)
    srv.register("jacobi2d", spec)
    out = srv.serve([StencilRequest("jacobi2d", arrays)])[0]
    elapsed = time.perf_counter() - t0

    srv.persist_telemetry()
    np.save(out_npy, np.asarray(out))
    st = srv.stats()
    report = {
        "elapsed_s": elapsed,
        "autotune_calls": st["_cache"]["autotune_calls"],
        "jit_builds": st["_cache"]["jit_builds"],
        "store_hits": st["_cache"]["store_hits"],
        "store": st.get("_store", {}),
    }
    with open(report_json, "w") as f:
        json.dump(report, f)


def _spawn(store_dir: str, out_npy: str, report_json: str) -> dict:
    env = dict(os.environ)
    env["PYTHONPATH"] = f"{ROOT / 'src'}:{ROOT}"
    env.setdefault("JAX_PLATFORMS", "cpu")
    subprocess.run(
        [sys.executable, str(ROOT / "benchmarks" / "cold_start.py"),
         "--child", store_dir, out_npy, report_json],
        check=True, env=env, cwd=str(ROOT),
    )
    with open(report_json) as f:
        return json.load(f)


def run_cold_start(rows, check: bool):
    from benchmarks.common import emit

    with tempfile.TemporaryDirectory() as td:
        store = os.path.join(td, "store")
        cold = _spawn(store, os.path.join(td, "cold.npy"),
                      os.path.join(td, "cold.json"))
        warm = _spawn(store, os.path.join(td, "warm.npy"),
                      os.path.join(td, "warm.json"))
        out_cold = np.load(os.path.join(td, "cold.npy"))
        out_warm = np.load(os.path.join(td, "warm.npy"))

    ratio = cold["elapsed_s"] / warm["elapsed_s"]
    emit(rows, "coldstart/cold_first_result", cold["elapsed_s"] * 1e6,
         f"autotune_calls={cold['autotune_calls']}; "
         f"jit_builds={cold['jit_builds']} (fresh store)")
    emit(rows, "coldstart/warm_first_result", warm["elapsed_s"] * 1e6,
         f"autotune_calls={warm['autotune_calls']}; "
         f"jit_builds={warm['jit_builds']}; "
         f"store_hits={warm['store_hits']}")
    emit(rows, "coldstart/speedup", 0.0,
         f"{ratio:.1f}x warm vs cold (subprocess, gate >= 10x)")

    bitwise = bool(np.array_equal(out_cold, out_warm))
    emit(rows, "coldstart/bitwise", 0.0,
         "bitwise-identical" if bitwise else "MISMATCH")

    if check:
        assert bitwise, "warm-start result differs from cold-start result"
        assert warm["autotune_calls"] == 0, (
            f"warm replica re-ran autotune {warm['autotune_calls']}x"
        )
        assert warm["jit_builds"] == 0, (
            f"warm replica re-jitted {warm['jit_builds']}x "
            "(executable deserialization regressed to recompile)"
        )
        assert warm["store_hits"] >= 1, "warm replica never hit the store"
        assert ratio >= 10.0, (
            f"warm start only {ratio:.1f}x faster than cold (gate: 10x)"
        )
    return rows


def run(check: bool = False, smoke: bool = False):
    # the trace is already CI-sized; smoke changes nothing, the flag
    # exists so the harness/CI call-shape matches the other benchmarks
    del smoke
    return run_cold_start([], check)


if __name__ == "__main__":
    if len(sys.argv) >= 2 and sys.argv[1] == "--child":
        _child(*sys.argv[2:5])
        sys.exit(0)
    for row in run(check=True, smoke="--smoke" in sys.argv[1:]):
        print(row)
    print("OK: warm replica reached its first bitwise-identical result "
          ">=10x faster than cold autotune+jit, with zero autotune "
          "invocations and zero jit builds")
