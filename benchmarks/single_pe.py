"""Paper Fig. 8: single-PE resource usage — SODA's distributed reuse
buffers + line buffer vs. SASA's coalesced reuse buffer.

On the FPGA this is BRAM/FF/LUT; we report the modelled FPGA numbers
(stand-in for Vitis synthesis) AND the TPU translation: VMEM working-set
bytes per fused tile, where the coalesced-buffer idea becomes "one wide
VMEM block instead of per-tap FIFO slices"."""
from __future__ import annotations

from repro.configs import stencils
from repro.core.model import estimate_pe_resources
from repro.core.platform import DEFAULT_FPGA
from repro.kernels.stencil import vmem_bytes_estimate

BENCHES = ["jacobi2d", "jacobi3d", "blur", "seidel2d", "dilate", "hotspot",
           "heat3d", "sobel2d"]


def soda_style_resources(spec, fpga, U=16):
    """SODA baseline: adds the 512-bit line buffer and per-tap narrow FIFO
    overhead that the coalesced design removes (Sec. 3.1 / Fig. 3)."""
    base = estimate_pe_resources(spec, fpga, U)
    # line buffer: one row of 512b words double-buffered per input
    line_buffer_bytes = 2 * spec.cols_flat * spec.itemsize * spec.num_inputs
    # distributed FIFOs: one BRAM-min per tap channel (U channels per tap)
    taps = spec.points
    distributed_overhead = taps * 1.0 + line_buffer_bytes / 4608
    out = dict(base)
    out["bram"] = base["bram"] + distributed_overhead
    out["ff"] = base["ff"] * 1.25       # extra fan-out registers
    out["lut"] = base["lut"] * 1.15
    return out


def run():
    rows = []
    fpga = DEFAULT_FPGA
    for name in BENCHES:
        shape = (9720, 32, 32) if name in stencils.BENCHMARKS_3D \
            else (9720, 1024)
        spec = stencils.get(name, shape=shape, iterations=4)
        ours = estimate_pe_resources(spec, fpga)
        soda = soda_style_resources(spec, fpga)
        bram_red = 100 * (1 - ours["bram"] / soda["bram"])
        rows.append(
            f"fig8/single_pe/{name},0.00,"
            f"bram_ours={ours['bram']:.0f};bram_soda={soda['bram']:.0f};"
            f"bram_reduction_pct={bram_red:.1f};dsp={ours['dsp']:.0f};"
            f"lut={ours['lut']:.0f}")
        # TPU translation: VMEM bytes of the fused tile at s in {1, 4}
        for s in (1, 4):
            vm = vmem_bytes_estimate(spec, s, tile_rows=256)
            rows.append(
                f"fig8/vmem_tile/{name}/s{s},0.00,"
                f"vmem_bytes={vm};fits_16MB={vm < 16 * 2**20}")
    return rows
