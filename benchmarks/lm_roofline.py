"""Assigned-architecture roofline table (EXPERIMENTS.md §Roofline source).

Reads dryrun_results.json (written by repro.launch.dryrun --all) and emits
one row per (arch x shape x mesh) cell: the three roofline terms, the
dominant bottleneck, MODEL_FLOPS/HLO_FLOPs, and fit status.  If the
dry-run has not been executed yet, emits a pointer row instead of failing.
"""
from __future__ import annotations

import json
import os

RESULTS = os.environ.get("DRYRUN_RESULTS", "dryrun_results.json")


def run():
    rows = []
    if not os.path.exists(RESULTS):
        rows.append(
            "roofline/missing,0.00,"
            "run 'PYTHONPATH=src python -m repro.launch.dryrun --all' first")
        return rows
    with open(RESULTS) as f:
        results = json.load(f)
    for key in sorted(results):
        res = results[key]
        arch, shape, mesh = key.split("|")
        if res["status"] == "skipped":
            rows.append(f"roofline/{arch}/{shape}/{mesh},0.00,"
                        f"status=skipped;reason={res['reason'][:60]}")
            continue
        if res["status"] != "ok":
            rows.append(f"roofline/{arch}/{shape}/{mesh},0.00,"
                        f"status=FAILED;reason={res['reason'][:80]}")
            continue
        r = res["report"]
        dominant = r["bottleneck"]
        rows.append(
            f"roofline/{arch}/{shape}/{mesh},"
            f"{max(r['compute_term'], r['memory_term'], r['collective_term'])*1e6:.1f},"
            f"compute_s={r['compute_term']:.4f};"
            f"memory_s={r['memory_term']:.4f};"
            f"collective_s={r['collective_term']:.4f};"
            f"bottleneck={dominant};"
            f"useful_flops={r['useful_flops_ratio']:.2f};"
            f"fits={r['fits']}")
    return rows
