"""Paper Fig. 1: computation intensity (OPs/byte) per stencil kernel and
vs. iteration count (assuming optimal data reuse)."""
from __future__ import annotations

from repro.configs import stencils


def run():
    rows = []
    # Fig 1a: per-kernel intensity at iteration = 1
    for name in ["jacobi2d", "jacobi3d", "blur", "seidel2d", "dilate",
                 "hotspot", "heat3d", "sobel2d"]:
        spec = stencils.get(name, iterations=1)
        rows.append(
            f"fig1a/intensity/{name},0.00,"
            f"ops_per_cell={spec.ops_per_cell};points={spec.points};"
            f"intensity={spec.computation_intensity(1):.3f}")
    # Fig 1b: JACOBI2D intensity grows linearly with iterations
    for it in [1, 2, 4, 8, 16, 32, 64]:
        spec = stencils.jacobi2d(iterations=it)
        rows.append(
            f"fig1b/intensity/jacobi2d/iter{it},0.00,"
            f"intensity={spec.computation_intensity(it):.3f}")
    return rows
