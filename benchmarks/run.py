"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  Modules:
  intensity          — Fig. 1  (computation intensity)
  single_pe          — Fig. 8  (single-PE resources / VMEM tiles)
  model_accuracy     — Fig. 9  (analytical model vs measured)
  parallelism_sweep  — Figs. 10-17 (GCell/s per parallelism x iteration)
  best_config        — Table 3 (best parallelism per benchmark)
  speedup_vs_soda    — Sec. 5.4 (SASA vs SODA headline speedups)
  serving_throughput — runtime subsystem: cached+batched serving vs
                       per-request autotune (grids/s vs batch size)
  lm_roofline        — assigned-arch roofline table from the dry-run
"""
from __future__ import annotations

import sys
import time
import traceback


def main() -> None:
    from benchmarks import (best_config, intensity, lm_roofline,
                            model_accuracy, parallelism_sweep,
                            serving_throughput, single_pe, speedup_vs_soda)
    modules = [
        ("intensity", intensity),
        ("single_pe", single_pe),
        ("best_config", best_config),
        ("speedup_vs_soda", speedup_vs_soda),
        ("serving_throughput", serving_throughput),
        ("model_accuracy", model_accuracy),
        ("parallelism_sweep", parallelism_sweep),
        ("lm_roofline", lm_roofline),
    ]
    print("name,us_per_call,derived")
    for name, mod in modules:
        t0 = time.time()
        try:
            for row in mod.run():
                print(row, flush=True)
        except Exception as e:  # keep the harness alive per-module
            traceback.print_exc(file=sys.stderr)
            print(f"{name}/ERROR,0.00,{type(e).__name__}: {e}")
        print(f"{name}/elapsed,{(time.time() - t0) * 1e6:.0f},",
              flush=True)


if __name__ == "__main__":
    main()
