"""Paper Figs. 10-17: throughput (GCell/s) of each parallelism vs
iteration count, per stencil kernel.

Two layers of results per cell:
  * model-projected GCell/s on the TPU-v5e 8-chip slice (the deployment
    target this framework optimises for), and
  * measured GCell/s for the single-device fused executor on this host
    (temporal variants; spatial variants need the multi-device runner and
    are exercised in tests/_multidevice_main.py).
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks.common import time_call
from repro.configs import stencils
from repro.core import model
from repro.core.platform import DEFAULT_TPU
from repro.kernels import ops

BENCHES = ["jacobi2d", "jacobi3d", "blur", "seidel2d", "dilate", "hotspot",
           "heat3d", "sobel2d"]
ITERS = [1, 2, 4, 8, 16, 32, 64]


def run(fast: bool = True):
    rows = []
    tpu = DEFAULT_TPU.with_chips(8)
    for name in BENCHES:
        shape = (9720, 32, 32) if name in stencils.BENCHMARKS_3D \
            else (9720, 1024)
        cells = float(np.prod(shape))
        for it in ITERS:
            spec = stencils.get(name, shape=shape, iterations=it)
            for pred in model.choose_best(spec, tpu):
                pass
            cands = model.tpu_candidate_configs(spec, tpu)
            best_per_variant = {}
            for cfg in cands:
                p = model.predict_tpu(spec, cfg, tpu)
                cur = best_per_variant.get(cfg.variant)
                if cur is None or p.latency < cur.latency:
                    best_per_variant[cfg.variant] = p
            for variant, p in sorted(best_per_variant.items()):
                gcells = cells * it / p.latency / 1e9
                rows.append(
                    f"fig10-17/{name}/iter{it}/{variant},"
                    f"{p.latency*1e6:.2f},"
                    f"gcells_per_s={gcells:.2f};k={p.config.k};"
                    f"s={p.config.s};bottleneck={p.bottleneck}")
        # measured single-device fused execution (temporal path)
        meas_shape = (486, 64) if name not in stencils.BENCHMARKS_3D \
            else (243, 16, 16)
        for it, s in [(4, 4), (16, 16)]:
            spec = stencils.get(name, shape=meas_shape, iterations=it)
            arrays = {n: jnp.ones(shp, dt) for n, (dt, shp)
                      in spec.inputs.items()}
            t = time_call(ops.stencil_run, spec, arrays, it, s=s,
                          backend="jnp")
            g = np.prod(meas_shape) * it / t / 1e9
            rows.append(
                f"fig10-17/measured/{name}/iter{it}_s{s},{t*1e6:.2f},"
                f"gcells_per_s={g:.3f};shape={'x'.join(map(str, meas_shape))}")
    return rows
