"""Paper Table 3: best parallelism configuration per benchmark.

Reported twice:
  * FPGA/U280 with the paper's synthesizer PE counts (pure reproduction —
    matches Table 3 at iteration=64 exactly, see tests/test_model.py), and
  * TPU-v5e 8-chip slice with our re-derived model (the deployment config
    this framework would actually launch).
"""
from __future__ import annotations

from repro.configs import stencils
from repro.core import model
from repro.core.platform import DEFAULT_FPGA, DEFAULT_TPU

PAPER_PE = {
    "jacobi2d": 21, "jacobi3d": 15, "blur": 12, "seidel2d": 12,
    "dilate": 18, "hotspot": 9, "heat3d": 12, "sobel2d": 12,
}
PAPER_TABLE3 = {   # iter=64 / iter=2 published picks
    "jacobi2d": (("hybrid_s", 3, 7), ("spatial_r", 15, 1)),
    "jacobi3d": (("hybrid_s", 3, 5), ("spatial_r", 15, 1)),
    "blur": (("hybrid_s", 3, 4), ("spatial_r", 12, 1)),
    "seidel2d": (("hybrid_s", 3, 4), ("spatial_r", 12, 1)),
    "dilate": (("hybrid_s", 3, 6), ("hybrid_s", 6, 2)),
    "hotspot": (("hybrid_s", 3, 3), ("spatial_s", 9, 1)),
    "heat3d": (("hybrid_s", 3, 4), ("spatial_r", 12, 1)),
    "sobel2d": (("hybrid_s", 3, 4), ("hybrid_s", 3, 4)),
}


def run():
    rows = []
    exact = {64: 0, 2: 0}
    for name, pe in PAPER_PE.items():
        for idx, it in enumerate((64, 2)):
            shape = (9720, 32, 32) if name in stencils.BENCHMARKS_3D \
                else (9720, 1024)
            spec = stencils.get(name, shape=shape, iterations=it)
            best = model.choose_best(spec, DEFAULT_FPGA,
                                     pe_res_override=pe)[0]
            got = (best.config.variant, best.config.k, best.config.s)
            want = PAPER_TABLE3[name][idx]
            exact[it] += got == want
            rows.append(
                f"table3/fpga/{name}/iter{it},{best.latency*1e6:.2f},"
                f"got={got[0]}(k={got[1]}.s={got[2]});"
                f"paper={want[0]}(k={want[1]}.s={want[2]});"
                f"match={got == want}")
            tbest = model.choose_best(spec, DEFAULT_TPU.with_chips(8))[0]
            rows.append(
                f"table3/tpu8/{name}/iter{it},{tbest.latency*1e6:.2f},"
                f"variant={tbest.config.variant};k={tbest.config.k};"
                f"s={tbest.config.s};bottleneck={tbest.bottleneck}")
    rows.append(f"table3/summary,0.00,"
                f"exact_match_iter64={exact[64]}/8;"
                f"exact_match_iter2={exact[2]}/8;"
                f"note=iter2 cells are <1pct analytic near-ties decided "
                f"on-board by timing closure (Sec 5.3.6)")
    return rows
