#!/usr/bin/env python
"""Slow-marker audit: keep tier-1 wall-clock honest as the suite grows.

Static checks (no test execution) run by scripts/ci.sh:

  1. every test module that launches the multi-device / subprocess
     helpers carries ``@pytest.mark.slow`` somewhere, so
     ``scripts/ci.sh fast`` (-m "not slow") really skips them;
  2. pytest.ini registers the ``slow`` marker (a typo'd marker silently
     deselects nothing);
  3. the conformance suite caps its hypothesis profile for CI (the
     ``ci`` profile must exist and be the env-var default) and keeps a
     ``nightly`` profile for the scheduled deep-fuzz job;
  4. the conformance suite's pinned floor stays >= 200 random specs
     (the acceptance bar: N_BLOCKS * BLOCK).

Exits non-zero with an actionable message on any violation.
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
TESTS = ROOT / "tests"

SUBPROCESS_HELPERS = ("_multidevice_main", "_ep_moe_main", "repro.serve")


def fail(msg: str) -> None:
    print(f"slow-marker audit: FAIL: {msg}")
    sys.exit(1)


def main() -> None:
    # 1. subprocess-launching test modules must be slow-marked (a mere
    # docstring mention of a helper does not count as a launch)
    for test_file in sorted(TESTS.glob("test_*.py")):
        text = test_file.read_text()
        launches = "import subprocess" in text and any(
            h in text for h in SUBPROCESS_HELPERS + ("_main.py",)
        )
        if launches and "pytest.mark.slow" not in text:
            fail(
                f"{test_file.name} launches a subprocess helper but "
                "has no @pytest.mark.slow marker — 'ci.sh fast' "
                "would not skip it"
            )

    # 2. the marker must be registered
    ini = (ROOT / "pytest.ini").read_text()
    if not re.search(r"^\s*slow\s*:", ini, re.MULTILINE):
        fail("pytest.ini does not register the 'slow' marker")

    # 3. conformance hypothesis profiles: ci-capped, nightly available
    # (whitespace-insensitive so a reformat cannot trip the audit)
    conf = (TESTS / "test_conformance.py").read_text()
    for pattern, why in [
        (r'register_profile\(\s*"ci"', "the capped CI profile"),
        (r'register_profile\(\s*"nightly"', "the nightly profile"),
        (r'os\.environ\.get\(\s*"HYPOTHESIS_PROFILE",\s*"ci"\s*\)',
         "the env-selected default profile"),
    ]:
        if not re.search(pattern, conf):
            fail(f"test_conformance.py lost {why}")
    m = re.search(
        r'"ci", max_examples=(\d+)', conf
    )
    if not m or int(m.group(1)) > 50:
        fail(
            "the conformance 'ci' hypothesis profile must cap "
            "max_examples at <= 50 (tier-1 wall-clock)"
        )

    # 4. the pinned conformance floor stays >= 200 specs
    m = re.search(r"N_BLOCKS, BLOCK = (\d+), (\d+)", conf)
    if not m or int(m.group(1)) * int(m.group(2)) < 200:
        fail(
            "the seed-pinned conformance floor dropped below 200 "
            "random specs (N_BLOCKS * BLOCK)"
        )

    print("slow-marker audit: OK (subprocess suites slow-marked; "
          "hypothesis ci profile capped; conformance floor >= 200)")


if __name__ == "__main__":
    main()
