#!/usr/bin/env python
"""Repo lint: version-sensitive jax APIs live only in src/repro/compat.py.

The ROADMAP's version policy pins every jax surface that moved between
0.4.x and current releases behind one shim module, so a jax upgrade is a
one-file change.  This ast-based check enforces it: outside compat.py no
module may

  * import ``shard_map`` from jax (``from jax import shard_map``,
    ``from jax.experimental.shard_map import ...``), or touch
    ``jax.experimental.shard_map`` / ``jax.shard_map`` attributes;
  * use ``lax.pcast`` / ``lax.pvary`` (the replication-typing rename);
  * build element-indexed BlockSpecs directly (``pl.Element``,
    ``pl.Unblocked``, or an ``indexing_mode=`` keyword) instead of
    ``repro.compat.element_block_spec``;
  * pass ``check_rep=``/``check_vma=`` to anything that was not
    imported from ``repro.compat`` (the shim normalises the kwarg name);
  * touch the AOT export/serialize surface the persistent design store
    is built on — ``jax.experimental.serialize_executable`` and
    ``jax.export`` / ``jax.experimental.export`` — instead of
    ``repro.compat.aot_compile`` / ``aot_serialize`` /
    ``aot_deserialize`` (these APIs moved between jax releases and the
    store must keep loading with a recompile fallback when they are
    absent).

Exit 1 with file:line findings on violation, 0 when clean.
"""
from __future__ import annotations

import ast
import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
SCAN_DIRS = ("src", "tests", "benchmarks", "examples", "scripts")
ALLOWED = {ROOT / "src" / "repro" / "compat.py"}


def _dotted(node: ast.AST) -> str:
    """Best-effort dotted name of an attribute/name chain."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
    return ".".join(reversed(parts))


def check_file(path: pathlib.Path) -> list[str]:
    tree = ast.parse(path.read_text(), filename=str(path))
    rel = path.relative_to(ROOT)
    findings: list[str] = []
    compat_names: set[str] = set()

    def flag(node: ast.AST, msg: str) -> None:
        findings.append(f"{rel}:{node.lineno}: {msg}")

    for node in ast.walk(tree):
        if isinstance(node, ast.ImportFrom):
            mod = node.module or ""
            if mod == "repro.compat" or mod.endswith(".compat"):
                compat_names.update(a.asname or a.name for a in node.names)
                continue
            if mod.startswith("jax"):
                for a in node.names:
                    if a.name == "shard_map" or "shard_map" in mod:
                        flag(node, (
                            f"direct shard_map import from {mod!r}; use "
                            "repro.compat.shard_map"
                        ))
                    if a.name in ("pcast", "pvary"):
                        flag(node, (
                            f"direct {a.name} import from {mod!r}; use "
                            "repro.compat.pvary"
                        ))
                    if (
                        a.name == "serialize_executable"
                        or "serialize_executable" in mod
                    ):
                        flag(node, (
                            f"direct serialize_executable import from "
                            f"{mod!r}; use repro.compat.aot_serialize/"
                            "aot_deserialize"
                        ))
                    if a.name == "export" and mod in (
                        "jax", "jax.experimental",
                    ) or mod in ("jax.export", "jax.experimental.export"):
                        flag(node, (
                            f"direct jax export import from {mod!r}; use "
                            "repro.compat.aot_serialize/aot_deserialize"
                        ))
        elif isinstance(node, ast.Import):
            for a in node.names:
                if "shard_map" in a.name:
                    flag(node, (
                        f"direct import of {a.name!r}; use "
                        "repro.compat.shard_map"
                    ))
                if "serialize_executable" in a.name or a.name in (
                    "jax.export", "jax.experimental.export",
                ):
                    flag(node, (
                        f"direct import of {a.name!r}; use "
                        "repro.compat.aot_serialize/aot_deserialize"
                    ))
        elif isinstance(node, ast.Attribute):
            dotted = _dotted(node)
            if dotted.endswith("experimental.shard_map") or dotted in (
                "jax.shard_map",
            ):
                flag(node, (
                    f"direct use of {dotted}; use repro.compat.shard_map"
                ))
            elif dotted.endswith("experimental.serialize_executable") or (
                dotted in ("jax.export", "jax.experimental.export")
            ):
                flag(node, (
                    f"direct use of {dotted}; use repro.compat."
                    "aot_serialize/aot_deserialize"
                ))
            elif node.attr in ("pcast", "pvary") and dotted.startswith(
                ("lax.", "jax.lax.")
            ):
                flag(node, (
                    f"direct use of {dotted}; use repro.compat.pvary"
                ))
            elif node.attr in ("Element", "Unblocked") and dotted.split(
                "."
            )[0] in ("pl", "pallas") or dotted.endswith(
                ("pallas.Element", "pallas.Unblocked")
            ):
                flag(node, (
                    f"direct use of {dotted}; use "
                    "repro.compat.element_block_spec"
                ))
        elif isinstance(node, ast.Call):
            callee = _dotted(node.func)
            for kw in node.keywords:
                if kw.arg == "indexing_mode":
                    flag(node, (
                        "indexing_mode= BlockSpec keyword; use "
                        "repro.compat.element_block_spec"
                    ))
                elif kw.arg in ("check_rep", "check_vma") and (
                    callee.split(".")[0] not in compat_names
                ):
                    flag(node, (
                        f"{kw.arg}= passed to {callee or '<call>'}, which "
                        "is not the repro.compat.shard_map shim"
                    ))
    return findings


def main() -> int:
    findings: list[str] = []
    for d in SCAN_DIRS:
        base = ROOT / d
        if not base.is_dir():
            continue
        for path in sorted(base.rglob("*.py")):
            if path in ALLOWED:
                continue
            findings.extend(check_file(path))
    for f in findings:
        print(f)
    print(
        "check_compat_imports:",
        "OK" if not findings else f"{len(findings)} violation(s)",
    )
    return 0 if not findings else 1


if __name__ == "__main__":
    sys.exit(main())
