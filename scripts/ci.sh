#!/usr/bin/env bash
# Tier-1 CI gate: full test suite + CPU smoke of the end-to-end flows.
#
# Usage: scripts/ci.sh [fast]
#   fast: skip the `slow`-marked multi-device subprocess tests.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"

MARK=()
if [[ "${1:-}" == "fast" ]]; then
  MARK=(-m "not slow")
fi

echo "== lint: pyflakes =="
# CI installs pyflakes (see .github/workflows/ci.yml); hosts without it
# fall back to a byte-compile pass so the gate never silently vanishes.
if python -c "import pyflakes" >/dev/null 2>&1; then
  python -m pyflakes src tests benchmarks examples scripts
else
  echo "pyflakes not installed; falling back to compileall"
  python -m compileall -q src tests benchmarks examples scripts
fi

echo "== lint: compat imports =="
# ast-based version-policy guard: version-sensitive jax APIs (shard_map,
# check_rep/check_vma, element-indexed BlockSpecs) only via repro/compat.py
python scripts/check_compat_imports.py

echo "== lint: stock kernels + example DSL =="
# static analyzer gate: every stock kernel x 4 boundary modes and every
# example DSL source must verify with zero error-severity diagnostics
python scripts/lint_stencils.py

echo "== lint: machine-readable numerics pass over examples =="
# repro.lint's JSON mode over every DSL literal embedded in examples/:
# exits non-zero only on error-severity diagnostics, and the JSON output
# is itself validated (this doubles as a CI check of the --format json
# contract that editor/CI integrations consume)
python -m repro.lint --format json --from-py examples/*.py | python -c '
import json, sys
doc = json.load(sys.stdin)
assert doc["version"] == 1 and "summary" in doc, "bad lint JSON shape"
s = doc["summary"]
print("lint JSON ok: %d literal(s), %d error(s), %d warning(s)"
      % (len(doc["files"]), s["errors"], s["warnings"]))
'

echo "== slow-marker audit =="
# static guard: subprocess suites stay slow-marked, the conformance
# suite's hypothesis profile stays CI-capped, and the pinned random-spec
# floor stays >= 200 — so the growing suite can't silently blow up
# tier-1 wall-clock
python scripts/audit_slow_markers.py

echo "== tier-1: pytest =="
# --durations=15 prints the slowest tests on every run, making
# wall-clock regressions visible in the CI log before they hurt
python -m pytest -x -q --durations=15 "${MARK[@]}"

echo "== smoke: examples/quickstart.py =="
python examples/quickstart.py

echo "== smoke: serving runtime (pipeline + cache + batching + bucketing) =="
# --smoke scales the traces down to CI size while asserting the same
# gates: tile pipeline no slower than vmap with strictly fewer HLO fusion
# boundaries; >=20 shapes from <=4 bucket designs, >=5x over per-shape
# autotune, async dispatch not slower than sync, reference-exact results;
# cold-start: a fresh subprocess against a warm DesignStore reaches its
# first bitwise-identical result >=10x faster than cold autotune+jit,
# with zero autotune invocations and zero jit builds on the warm side.
PYTHONPATH="src:.${PYTHONPATH:+:$PYTHONPATH}" \
  python benchmarks/serving_throughput.py --smoke

echo "== smoke: analytical-model ranking accuracy =="
# calibrate-on-some / validate-on-held-out at CI size; gate: the model
# must order held-out kernels' (iterations, fusion) points better than
# chance — ranking is what the auto-tuner consumes
PYTHONPATH="src:.${PYTHONPATH:+:$PYTHONPATH}" \
  python benchmarks/model_accuracy.py --smoke

echo "== smoke: continuous-batching serving latency =="
# Poisson open-loop trace against the flush-barrier loop and the
# continuous scheduler over one shared cache; gates: zero drops,
# continuous throughput >= 0.9x flush, p99 at or below the barrier's,
# every result bitwise-identical to synchronous single-shot serve()
PYTHONPATH="src:.${PYTHONPATH:+:$PYTHONPATH}" \
  python benchmarks/serving_latency.py --smoke

echo "CI OK"
