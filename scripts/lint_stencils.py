#!/usr/bin/env python
"""CI gate: every stock kernel and example DSL source verifies clean.

Three sweeps, all through the static analyzer (repro.core.analysis):

  1. every stock kernel in repro.configs.stencils across ALL FOUR
     boundary modes (zero / constant / replicate / periodic), verified
     both as a spec and as DSL text re-emitted by format_spec (which
     also exercises the parser round-trip and source spans);
  2. every DSL string literal embedded in examples/*.py (found by an
     ast scan for literals containing a ``kernel:`` header);
  3. every standalone ``*.dsl`` file under examples/, if any.

Additionally, every stock kernel must carry a *finite* certified
rounding-error bound (repro.core.numerics) at its documented iteration
count across all four boundary modes — a kernel whose bound diverges
could not honestly advertise SASA's provable-equivalence story.

The gate fails on any error-severity diagnostic; warnings and infos are
printed but do not fail (hygiene findings are advisory).
"""
from __future__ import annotations

import dataclasses
import math
import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "src"))

from repro.configs import stencils                      # noqa: E402
from repro.core import analysis, dsl, numerics          # noqa: E402
from repro.core.spec import Boundary                    # noqa: E402
from repro.lint import dsl_literals                     # noqa: E402

BOUNDARIES = (
    Boundary("zero"),
    Boundary("constant", 1.5),
    Boundary("replicate"),
    Boundary("periodic"),
)


def gate(label: str, diags, source=None) -> bool:
    errors = [d for d in diags if d.is_error]
    for d in analysis.sort_diagnostics(diags):
        print(f"{label}: {d.format(source)}")
    if errors:
        print(f"FAIL {label}: {len(errors)} error diagnostic(s)")
        return False
    return True


def main() -> int:
    ok = True
    shapes = {2: (64, 32), 3: (32, 16, 16)}

    for name, fn in stencils.BENCHMARKS.items():
        base = fn(iterations=4)
        spec = fn(shape=shapes[base.ndim], iterations=4)
        for boundary in BOUNDARIES:
            sp = dataclasses.replace(spec, boundary=boundary)
            sp.validate()
            label = f"stock:{name}:{boundary.kind}"
            ok &= gate(label, analysis.verify(sp))
            rep = numerics.analyze(sp, iterations=4)
            if not math.isfinite(rep.bound):
                print(
                    f"FAIL {label}: no finite certified error bound at "
                    f"iterations=4 (rounds analyzed: {rep.rounds_analyzed})"
                )
                ok = False
            # re-emitted DSL text must lint clean too (round-trip + spans)
            text = dsl.format_spec(sp)
            parsed, diags = analysis.lint_text(text)
            ok &= gate(label + ":text", diags, source=text)
            if parsed is not None and parsed != sp:
                print(f"FAIL {label}: format_spec round-trip mismatch")
                ok = False

    examples = ROOT / "examples"
    for py in sorted(examples.glob("*.py")):
        literals = dsl_literals(py.read_text(), filename=str(py))
        for i, text in enumerate(literals):
            _, diags = analysis.lint_text(text)
            ok &= gate(f"{py.name}[{i}]", diags, source=text)
    for f in sorted(examples.glob("*.dsl")):
        _, diags = analysis.lint_text(f.read_text())
        ok &= gate(f.name, diags, source=f.read_text())

    print("lint_stencils:", "OK" if ok else "FAILED")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
