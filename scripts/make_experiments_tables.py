"""Render EXPERIMENTS.md §Dry-run and §Roofline tables from the results
JSONs (baseline: dryrun_results.json; hillclimb: hillclimb_results.json).
"""
import json


def fmt_bytes(b):
    return f"{b / 2**30:.1f}"


def dryrun_table(results):
    lines = [
        "| arch | shape | mesh | status | compile s | args GiB | temps GiB | fits |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for k in sorted(results):
        v = results[k]
        arch, shape, mesh = k.split("|")[:3]
        if v["status"] == "skipped":
            lines.append(f"| {arch} | {shape} | {mesh} | skipped "
                         f"(long-context needs sub-quadratic attention) "
                         f"| — | — | — | — |")
            continue
        r = v["report"]
        m = r["memory_per_chip"]
        lines.append(
            f"| {arch} | {shape} | {mesh} | {v['status']} "
            f"| {v['seconds']:.0f} | {fmt_bytes(m['arguments'])} "
            f"| {fmt_bytes(m['temps'])} | {r['fits']} |")
    return "\n".join(lines)


def roofline_table(results, hillclimb=None):
    hillclimb = hillclimb or {}
    lines = [
        "| arch | shape | mesh | compute s | memory s | collective s | "
        "bottleneck | MODEL/HLO flops | note |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for k in sorted(results):
        v = results[k]
        if v["status"] != "ok":
            continue
        arch, shape, mesh = k.split("|")[:3]
        r = v["report"]
        note = ""
        if k in hillclimb and hillclimb[k].get("status") == "ok":
            h = hillclimb[k]["report"]
            note = (f"**optimized**: {h['compute_term']:.2f}/"
                    f"{h['memory_term']:.2f}/{h['collective_term']:.2f} s, "
                    f"useful {h['useful_flops_ratio']:.2f}, "
                    f"fits {h['fits']}")
        lines.append(
            f"| {arch} | {shape} | {mesh} | {r['compute_term']:.3f} "
            f"| {r['memory_term']:.3f} | {r['collective_term']:.3f} "
            f"| {r['bottleneck']} | {r['useful_flops_ratio']:.2f} "
            f"| {note} |")
    return "\n".join(lines)


def main():
    results = json.load(open("dryrun_results.json"))
    try:
        hc = json.load(open("hillclimb_results.json"))
    except FileNotFoundError:
        hc = {}
    with open("EXPERIMENTS.md") as f:
        doc = f.read()
    doc = doc.replace("<!-- DRYRUN_TABLE -->", dryrun_table(results))
    doc = doc.replace("<!-- ROOFLINE_TABLE -->", roofline_table(results, hc))
    with open("EXPERIMENTS.md", "w") as f:
        f.write(doc)
    ok = sum(1 for v in results.values() if v["status"] == "ok")
    sk = sum(1 for v in results.values() if v["status"] == "skipped")
    print(f"tables written: {ok} ok, {sk} skipped, "
          f"{len(results) - ok - sk} failed")


if __name__ == "__main__":
    main()
