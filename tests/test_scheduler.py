"""StencilScheduler: continuous batching, SLO lanes, quotas, drain.

``start=False`` schedulers are stepped deterministically (``step()`` /
manual ``drain()``); a couple of tests run the real background thread to
cover the drain barrier under concurrency.
"""
import pickle
import time

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import stencils
from repro.kernels import ref
from repro.runtime import DesignCache
from repro.serve import (
    Backpressure,
    StencilRequest,
    StencilScheduler,
    StencilServer,
)

RNG = np.random.default_rng(23)


def grid_request(design, spec):
    return StencilRequest(design, {
        n: RNG.standard_normal(shape).astype(dt)
        for n, (dt, shape) in spec.inputs.items()
    })


def mixed_request(design, shape):
    return StencilRequest(design, {
        "in_1": RNG.standard_normal(shape).astype(np.float32)
    })


def oracle(spec, req, iters):
    one = {n: jnp.asarray(a) for n, a in req.arrays.items()}
    return np.asarray(ref.stencil_iterations_ref(spec, one, iters))


def small_server(max_batch=2, **kw):
    spec = stencils.jacobi2d(shape=(16, 8), iterations=2)
    srv = StencilServer(max_batch=max_batch, cache=DesignCache(),
                        warmup=True, **kw)
    srv.register("jac", spec)
    return srv, spec


def test_scheduler_results_match_oracle():
    srv, spec = small_server(max_batch=3)
    with StencilScheduler(srv, start=False) as sched:
        reqs = [grid_request("jac", spec) for _ in range(5)]
        tickets = [sched.submit(r) for r in reqs]
        sched.drain()
        for req, t in zip(reqs, tickets):
            np.testing.assert_allclose(
                t.result(), oracle(spec, req, 2), rtol=2e-4, atol=2e-4
            )
    st = sched.stats()
    assert st["admitted"] == st["completed"] == 5
    assert st["pending"] == st["inflight"] == st["failed"] == 0


def test_priority_lanes_order_dispatch_under_contention():
    """Six tickets contend for one design at max_batch=2: dispatch must
    go interactive pair, then standard, then batch — by SLO deadline,
    not submission order (batch was submitted first)."""
    srv, spec = small_server(max_batch=2)
    sched = StencilScheduler(srv, start=False)
    lanes = ["batch", "batch", "standard", "standard",
             "interactive", "interactive"]
    tickets = {
        lane: [] for lane in ("interactive", "standard", "batch")
    }
    for lane in lanes:
        tickets[lane].append(
            sched.submit(grid_request("jac", spec), lane=lane)
        )

    assert sched.step()                     # dispatches exactly one chunk
    by_lane = sched.stats()["pending_by_lane"]
    assert "interactive" not in by_lane     # urgent pair left the queue
    assert by_lane == {"standard": 2, "batch": 2}

    assert sched.step()
    assert sched.stats()["pending_by_lane"] == {"batch": 2}

    sched.drain()
    order = {
        lane: max(t.completed_at for t in ts)
        for lane, ts in tickets.items()
    }
    assert order["interactive"] <= order["standard"] <= order["batch"]
    assert all(t.done() for ts in tickets.values() for t in ts)


def test_explicit_deadline_overrides_lane():
    """A batch-lane ticket with a tight explicit deadline jumps the
    standard-lane queue."""
    srv, spec = small_server(max_batch=1)
    sched = StencilScheduler(srv, start=False)
    slow = sched.submit(grid_request("jac", spec), lane="standard")
    urgent = sched.submit(
        grid_request("jac", spec), lane="batch", deadline_s=0.001
    )
    assert sched.step()
    assert sched.stats()["pending"] == 1
    sched.drain()
    assert urgent.completed_at <= slow.completed_at


def test_tenant_quota_exhaustion_is_backpressure_not_loss():
    srv, spec = small_server(max_batch=4)
    sched = StencilScheduler(srv, start=False, quota=2)
    first = [
        sched.submit(grid_request("jac", spec), tenant="acme")
        for _ in range(2)
    ]
    with pytest.raises(Backpressure) as exc_info:
        sched.submit(grid_request("jac", spec), tenant="acme")
    assert exc_info.value.retry_after_s > 0
    assert "acme" in str(exc_info.value)

    # other tenants are unaffected by acme's exhaustion
    other = sched.submit(grid_request("jac", spec), tenant="zen")
    sched.drain()
    assert all(t.done() for t in first) and other.done()

    # resolution frees the quota: the retry is admitted
    retry = sched.submit(grid_request("jac", spec), tenant="acme")
    sched.drain()
    assert retry.done() and retry.exception() is None
    assert sched.stats()["rejected"] == 1


def test_full_queue_backpressure():
    srv, spec = small_server()
    sched = StencilScheduler(srv, start=False, max_queue=1)
    kept = sched.submit(grid_request("jac", spec))
    with pytest.raises(Backpressure):
        sched.submit(grid_request("jac", spec))
    sched.drain()
    assert kept.done()


def test_backpressure_pickles_with_retry_hint():
    """The router ships Backpressure across process boundaries; the
    default exception reduce would drop retry_after_s."""
    err = pickle.loads(pickle.dumps(Backpressure("queue full", 0.25)))
    assert isinstance(err, Backpressure)
    assert err.retry_after_s == 0.25
    assert "queue full" in str(err)


def test_drain_resolves_every_ticket_with_background_thread():
    """Regression: drain() must not return while a chunk is mid-dispatch
    or mid-reap (popped off the in-flight deque but not yet resolved) —
    every admitted ticket is done the moment drain() returns."""
    srv, spec = small_server(max_batch=2)
    with StencilScheduler(srv) as sched:       # real dispatch thread
        for _ in range(5):
            tickets = [
                sched.submit(grid_request("jac", spec)) for _ in range(5)
            ]
            sched.drain()
            assert all(t.done() for t in tickets), (
                "drain() returned with unresolved tickets"
            )
    assert sched.stats()["completed"] == 25


def test_unknown_design_and_lane_fail_fast():
    srv, spec = small_server()
    sched = StencilScheduler(srv, start=False)
    with pytest.raises(KeyError):
        sched.submit(grid_request("nope", spec))
    with pytest.raises(ValueError):
        sched.submit(grid_request("jac", spec), lane="warp-speed")
    assert sched.stats()["pending"] == 0


def test_dispatch_fault_resolves_tickets_with_the_error():
    """A runner blow-up must fail the chunk's tickets, not strand them."""
    srv, spec = small_server(max_batch=2)
    boom = RuntimeError("device on fire")

    def broken(prepared):
        raise boom

    srv._designs["jac"].cached.runner = broken
    sched = StencilScheduler(srv, start=False)
    tickets = [sched.submit(grid_request("jac", spec)) for _ in range(2)]
    sched.drain()
    for t in tickets:
        assert t.done()
        with pytest.raises(RuntimeError, match="device on fire"):
            t.result()
    st = sched.stats()
    assert st["failed"] == 2 and st["completed"] == 0
    assert st["outstanding_by_tenant"] == {}


def test_async_bitwise_equal_to_sync_on_mixed_boundary_trace():
    """The scheduler stages through the engine's own padded _prepare, so
    a mixed-shape bucketed trace must match the synchronous serve()
    path bit-for-bit (CPU) regardless of how batches coalesced."""
    iters = 3
    spec = stencils.jacobi2d(shape=(24, 16), iterations=iters)
    cache = DesignCache()
    shapes = [(24, 16), (20, 12), (17, 9), (30, 28), (10, 30), (31, 31),
              (24, 16), (18, 10), (8, 8)]
    rng_a = np.random.default_rng(7)
    rng_b = np.random.default_rng(7)

    def requests(rng):
        return [
            StencilRequest("jac", {
                "in_1": rng.standard_normal(s).astype(np.float32)
            })
            for s in shapes
        ]

    srv_sync = StencilServer(
        max_batch=3, cache=cache, bucketing=True, tile_rows=8,
    )
    srv_sync.register("jac", spec)
    outs_sync = srv_sync.serve(requests(rng_a))

    srv_async = StencilServer(
        max_batch=3, cache=cache, bucketing=True, tile_rows=8,
    )
    srv_async.register("jac", spec)
    with StencilScheduler(srv_async) as sched:
        tickets = [sched.submit(r) for r in requests(rng_b)]
        sched.drain()
    outs_async = [t.result() for t in tickets]

    bit_exact = jax.default_backend() == "cpu"
    for a, s, shape in zip(outs_async, outs_sync, shapes):
        assert a.shape == shape
        if bit_exact:
            np.testing.assert_array_equal(a, s)
        else:
            np.testing.assert_allclose(a, s, rtol=2e-4, atol=2e-4)


def test_gather_window_coalesces_trickled_arrivals():
    """Arrivals inside the gather window ride one batch; the window
    lapsing dispatches a partial batch rather than waiting forever."""
    srv, spec = small_server(max_batch=4)
    # batch lane (5s deadline) keeps deadline slack out of the picture;
    # only batch-full vs window-lapsed decide here
    sched = StencilScheduler(srv, start=False, gather_window_s=2.0)
    t1 = sched.submit(grid_request("jac", spec), lane="batch")
    assert not sched.step()                 # 1 < max_batch, window open
    for _ in range(3):
        sched.submit(grid_request("jac", spec), lane="batch")
    assert sched.step()                     # full batch dispatches now
    sched.drain()
    assert sched.stats()["dispatched_batches"] == 1
    assert t1.done()

    lone = StencilScheduler(srv, start=False, gather_window_s=0.005)
    lone_t = lone.submit(grid_request("jac", spec), lane="batch")
    time.sleep(0.01)
    assert lone.step()                      # window lapsed: partial batch
    lone.drain()
    assert lone_t.done()


def test_scheduler_stats_are_finite_clean():
    srv, spec = small_server()
    with StencilScheduler(srv, start=False) as sched:
        sched.submit(grid_request("jac", spec))
        sched.drain()
        st = sched.stats()

    def assert_finite(node):
        if isinstance(node, dict):
            for v in node.values():
                assert_finite(v)
        elif isinstance(node, (int, float)):
            assert np.isfinite(node)

    assert_finite(st)
