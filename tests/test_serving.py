"""StencilServer: micro-batching, warmup, counters, cache behaviour."""
import numpy as np
import jax.numpy as jnp

from repro.configs import stencils
from repro.kernels import ref
from repro.runtime import DesignCache, ShapeBucketer
from repro.serve import StencilRequest, StencilServer

RNG = np.random.default_rng(11)


def grid_request(design, spec):
    return StencilRequest(design, {
        n: RNG.standard_normal(shape).astype(dt)
        for n, (dt, shape) in spec.inputs.items()
    })


def oracle(spec, req, iters):
    one = {n: jnp.asarray(a) for n, a in req.arrays.items()}
    return np.asarray(ref.stencil_iterations_ref(spec, one, iters))


def test_serve_matches_oracle_and_microbatches():
    iters = 3
    spec = stencils.jacobi2d(shape=(20, 12), iterations=iters)
    srv = StencilServer(max_batch=4, cache=DesignCache())
    srv.register("jac", spec)
    reqs = [grid_request("jac", spec) for _ in range(7)]
    outs = srv.serve(reqs)
    for req, out in zip(reqs, outs):
        np.testing.assert_allclose(
            out, oracle(spec, req, iters), rtol=2e-4, atol=2e-4
        )
    st = srv.stats()["jac"]
    assert st["requests"] == 7
    assert st["batches"] == 2          # 7 grids / max_batch 4
    assert st["padded_grids"] == 1     # second bucket padded 3 -> 4
    assert st["exec_count"] == 2
    assert st["exec_total_s"] > 0
    assert st["exec_max_s"] >= st["exec_mean_s"] > 0


def test_warmup_compiles_at_register_time():
    spec = stencils.jacobi2d(shape=(16, 8), iterations=2)
    srv = StencilServer(max_batch=2, cache=DesignCache(), warmup=True)
    reg = srv.register("jac", spec)
    assert not reg.counters.cache_hit       # fresh cache: built, then warmed
    assert reg.counters.warmup_time_s > 0
    assert reg.counters.build_time_s > 0


def test_second_register_is_a_design_cache_hit():
    cache = DesignCache()
    spec = stencils.jacobi2d(shape=(16, 8), iterations=2)
    srv1 = StencilServer(max_batch=2, cache=cache)
    srv1.register("jac", spec)
    srv2 = StencilServer(max_batch=2, cache=cache)
    reg2 = srv2.register("jac", spec)
    assert reg2.counters.cache_hit          # no re-rank, no re-jit
    assert reg2.counters.build_time_s == 0.0
    assert reg2.cached.runner is srv1.design("jac").cached.runner


def test_mixed_designs_never_share_a_batch():
    cache = DesignCache()
    iters = 2
    jac = stencils.jacobi2d(shape=(16, 8), iterations=iters)
    hot = stencils.hotspot(shape=(16, 8), iterations=iters)
    srv = StencilServer(max_batch=8, cache=cache)
    srv.register("jac", jac)
    srv.register("hot", hot)
    reqs = [grid_request("jac", jac), grid_request("hot", hot),
            grid_request("jac", jac)]
    outs = srv.serve(reqs)
    np.testing.assert_allclose(
        outs[0], oracle(jac, reqs[0], iters), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(
        outs[1], oracle(hot, reqs[1], iters), rtol=2e-4, atol=2e-4)
    st = srv.stats()
    assert st["jac"]["batches"] == 1 and st["jac"]["requests"] == 2
    assert st["hot"]["batches"] == 1 and st["hot"]["requests"] == 1


def test_submit_unknown_design_raises():
    srv = StencilServer(cache=DesignCache())
    import pytest
    with pytest.raises(KeyError, match="not registered"):
        srv.submit(StencilRequest("nope", {}))


def test_submit_validates_inputs_eagerly():
    import pytest
    spec = stencils.jacobi2d(shape=(12, 6), iterations=2)
    srv = StencilServer(max_batch=2, cache=DesignCache())
    srv.register("jac", spec)
    with pytest.raises(ValueError, match="missing input"):
        srv.submit(StencilRequest("jac", {}))
    with pytest.raises(ValueError, match="must be shaped"):
        srv.submit(StencilRequest(
            "jac", {"in_1": np.zeros((6, 12), np.float32)}))
    assert srv.flush() == {}  # nothing malformed reached the queue


def test_register_name_collision():
    import pytest
    a = stencils.jacobi2d(shape=(12, 6), iterations=2)
    b = stencils.jacobi2d(shape=(16, 6), iterations=2)
    srv = StencilServer(max_batch=2, cache=DesignCache())
    r1 = srv.register("jac", a)
    assert srv.register("jac", a) is r1      # same spec: idempotent
    with pytest.raises(ValueError, match="already registered"):
        srv.register("jac", b)               # different spec: rejected


def test_dispatch_fault_isolates_to_its_chunk():
    """One faulty micro-batch must not drop other chunks' results."""
    spec = stencils.jacobi2d(shape=(12, 6), iterations=2)
    srv = StencilServer(max_batch=2, cache=DesignCache())
    srv.register("jac", spec)
    reqs = [grid_request("jac", spec) for _ in range(4)]  # 2 chunks
    tickets = [srv.submit(r) for r in reqs]
    runner = srv.design("jac").cached.runner
    calls = {"n": 0}

    def flaky(arrays):
        calls["n"] += 1
        if calls["n"] == 1:
            raise RuntimeError("injected dispatch fault")
        return runner(arrays)

    srv.design("jac").cached.runner = flaky
    done = srv.flush()
    # chunk 2 completed despite chunk 1 faulting; its tickets resolved
    assert sorted(done) == tickets[2:]
    np.testing.assert_allclose(
        done[tickets[2]], oracle(spec, reqs[2], 2), rtol=2e-4, atol=2e-4)
    # chunk 1's tickets carry the fault
    assert set(srv.failures) == set(tickets[:2])
    assert srv.stats()["jac"]["failed_requests"] == 2
    srv.design("jac").cached.runner = runner


def test_serve_raises_when_own_request_fails():
    import pytest
    spec = stencils.jacobi2d(shape=(12, 6), iterations=2)
    srv = StencilServer(max_batch=2, cache=DesignCache())
    srv.register("jac", spec)

    def broken(arrays):
        raise RuntimeError("injected dispatch fault")

    srv.design("jac").cached.runner = broken
    with pytest.raises(RuntimeError, match="failed to dispatch"):
        srv.serve([grid_request("jac", spec)])


def test_bystander_results_survive_another_clients_failed_serve():
    """serve() raising must not lose results for tickets it doesn't own."""
    import pytest
    jac = stencils.jacobi2d(shape=(12, 6), iterations=2)
    hot = stencils.hotspot(shape=(12, 6), iterations=2)
    srv = StencilServer(max_batch=2, cache=DesignCache())
    srv.register("jac", jac)
    srv.register("hot", hot)
    bystander_req = grid_request("jac", jac)
    bystander = srv.submit(bystander_req)          # client A, not yet flushed

    def broken(arrays):
        raise RuntimeError("injected dispatch fault")

    srv.design("hot").cached.runner = broken
    with pytest.raises(RuntimeError, match="failed to dispatch"):
        srv.serve([grid_request("hot", hot)])      # client B fails
    # B's serve() claimed only its own tickets: A's unclaimed submission
    # is still queued, untouched by B's flush, and resolves on A's flush.
    assert bystander not in srv.completed
    assert bystander not in srv.failures
    out = srv.flush()[bystander]
    np.testing.assert_allclose(
        out, oracle(jac, bystander_req, 2), rtol=2e-4, atol=2e-4)


def test_concurrent_flush_cannot_steal_claimed_tickets():
    """Regression: a flush racing a serve() must not drain its tickets.

    Client B submits a plain (unclaimed) request, then client A runs
    serve() on another thread while A's dispatch is held open by a gated
    runner.  Pre-fix, A's flush snapshotted the WHOLE queue — including
    B's ticket — so B's own flush() returned {} and this test failed.
    Post-fix A's serve() claims only its own tickets at submit time.
    """
    import threading
    iters = 2
    spec = stencils.jacobi2d(shape=(12, 6), iterations=iters)
    srv = StencilServer(max_batch=2, cache=DesignCache())
    srv.register("jac", spec)
    req_b = grid_request("jac", spec)
    t_b = srv.submit(req_b)                 # client B: plain submit/flush

    runner = srv.design("jac").cached.runner
    started = threading.Event()
    gate = threading.Event()

    def gated(arrays):
        started.set()
        assert gate.wait(timeout=30)
        return runner(arrays)

    srv.design("jac").cached.runner = gated
    out_a = []
    thread_a = threading.Thread(
        target=lambda: out_a.append(srv.serve([grid_request("jac", spec)]))
    )
    thread_a.start()
    assert started.wait(timeout=30)         # A's flush is mid-dispatch
    gate.set()
    done = srv.flush()                      # client B's own flush
    thread_a.join(timeout=60)
    assert t_b in done
    np.testing.assert_allclose(
        done[t_b], oracle(spec, req_b, iters), rtol=2e-4, atol=2e-4)
    assert len(out_a) == 1 and len(out_a[0]) == 1   # A's serve unaffected
    assert not srv.failures


def test_stats_finite_with_never_dispatched_design():
    """A registered-but-never-dispatched design must not poison stats()
    aggregation: every numeric counter (including exec_mean_s, which
    divides by the execution count) stays finite."""
    spec = stencils.jacobi2d(shape=(12, 6), iterations=2)
    srv = StencilServer(max_batch=2, cache=DesignCache(), warmup=False)
    srv.register("idle", spec)

    def assert_finite(node, path=""):
        if isinstance(node, dict):
            for k, v in node.items():
                assert_finite(v, f"{path}.{k}")
        elif isinstance(node, (int, float)):
            assert np.isfinite(node), f"non-finite counter at {path}"

    st = srv.stats()
    assert st["idle"]["exec_count"] == 0
    assert st["idle"]["exec_mean_s"] == 0.0
    assert_finite(st)


def test_sync_dispatch_mode_matches_oracle():
    """async_dispatch=False must produce the same (correct) results."""
    iters = 2
    spec = stencils.jacobi2d(shape=(16, 8), iterations=iters)
    srv = StencilServer(max_batch=2, cache=DesignCache(), async_dispatch=False)
    srv.register("jac", spec)
    reqs = [grid_request("jac", spec) for _ in range(3)]
    outs = srv.serve(reqs)
    for req, out in zip(reqs, outs):
        np.testing.assert_allclose(
            out, oracle(spec, req, iters), rtol=2e-4, atol=2e-4
        )
    assert srv.stats()["jac"]["batches"] == 2


# ---------------------------------------------------------------------------
# bucketed (multi-geometry) serving
# ---------------------------------------------------------------------------


def mixed_request(design, shape, rng=RNG):
    return StencilRequest(design, {
        "in_1": rng.standard_normal(shape).astype(np.float32)
    })


def test_bucketed_server_serves_mixed_shapes():
    iters = 3
    spec = stencils.jacobi2d(shape=(24, 16), iterations=iters)
    srv = StencilServer(
        max_batch=4, cache=DesignCache(), bucketing=True, tile_rows=8,
    )
    srv.register("jac", spec)
    shapes = [(24, 16), (20, 12), (17, 9), (30, 28), (10, 30), (31, 31),
              (24, 16), (18, 10)]
    reqs = [mixed_request("jac", s) for s in shapes]
    outs = srv.serve(reqs)
    for req, out, shape in zip(reqs, outs, shapes):
        assert out.shape == shape
        np.testing.assert_allclose(
            out, oracle(spec, req, iters), rtol=2e-4, atol=2e-4
        )
    st = srv.stats()["jac"]
    assert st["requests"] == len(shapes)
    # 8 distinct-shape requests served from a handful of bucket designs
    assert st["compiled_buckets"] <= 4
    assert sum(b["requests"] for b in st["buckets"].values()) == len(shapes)


def test_bucketed_grids_share_a_micro_batch():
    """Different shapes in the same bucket ride one dispatch, each with
    its own exterior-zero mask."""
    iters = 2
    spec = stencils.jacobi2d(shape=(16, 12), iterations=iters)
    srv = StencilServer(
        max_batch=4, cache=DesignCache(), bucketing=True, tile_rows=8,
    )
    srv.register("jac", spec)
    reqs = [mixed_request("jac", s) for s in [(16, 12), (13, 9), (9, 16)]]
    outs = srv.serve(reqs)                  # all bucket to (16, 16)
    st = srv.stats()["jac"]
    assert st["batches"] == 1 and st["compiled_buckets"] == 1
    assert st["padded_grids"] == 1          # 3 grids padded up to max_batch 4
    for req, out in zip(reqs, outs):
        np.testing.assert_allclose(
            out, oracle(spec, req, iters), rtol=2e-4, atol=2e-4
        )


def test_bucketed_async_matches_sync_bitwise():
    """Async double-buffered dispatch must be a pure scheduling change."""
    iters = 3
    spec = stencils.jacobi2d(shape=(24, 16), iterations=iters)
    cache = DesignCache()
    shapes = [(24, 16), (20, 12), (17, 9), (30, 28), (10, 30), (24, 16)]
    rng_a = np.random.default_rng(99)
    rng_b = np.random.default_rng(99)
    srv_async = StencilServer(
        max_batch=2, cache=cache, bucketing=True, tile_rows=8,
        async_dispatch=True, max_inflight=2,
    )
    srv_sync = StencilServer(
        max_batch=2, cache=cache, bucketing=True, tile_rows=8,
        async_dispatch=False,
    )
    srv_async.register("jac", spec)
    srv_sync.register("jac", spec)
    outs_a = srv_async.serve([mixed_request("jac", s, rng_a) for s in shapes])
    outs_s = srv_sync.serve([mixed_request("jac", s, rng_b) for s in shapes])
    for a, b in zip(outs_a, outs_s):
        np.testing.assert_array_equal(a, b)


def test_concurrent_submits_all_resolve():
    """submit() is thread-safe: tickets from racing threads stay unique
    and every request resolves to its own oracle result."""
    import threading

    iters = 2
    spec = stencils.jacobi2d(shape=(16, 12), iterations=iters)
    srv = StencilServer(
        max_batch=4, cache=DesignCache(), bucketing=True, tile_rows=8,
    )
    srv.register("jac", spec)
    shapes = [(16, 12), (13, 9), (9, 16), (16, 16), (8, 8), (12, 10)]
    per_thread = 4
    results: dict[int, tuple] = {}
    lock = threading.Lock()

    def client(tid):
        rng = np.random.default_rng(1000 + tid)
        for i in range(per_thread):
            req = mixed_request("jac", shapes[(tid + i) % len(shapes)], rng)
            ticket = srv.submit(req)
            with lock:
                results[ticket] = req

    threads = [threading.Thread(target=client, args=(t,)) for t in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert len(results) == 4 * per_thread    # no ticket collisions
    done = srv.flush()
    assert sorted(done) == sorted(results)
    for ticket, req in results.items():
        np.testing.assert_allclose(
            done[ticket], oracle(spec, req, iters), rtol=2e-4, atol=2e-4
        )


def test_bucketed_submit_validation():
    import pytest

    spec = stencils.jacobi2d(shape=(16, 12), iterations=2)
    srv = StencilServer(
        max_batch=2, cache=DesignCache(), tile_rows=8,
        bucketing=ShapeBucketer(max_shape=(32, 32)),
    )
    srv.register("jac", spec)
    with pytest.raises(ValueError, match="unknown input"):
        srv.submit(StencilRequest(
            "jac", {"in_1": np.zeros((8, 8), np.float32),
                    "in_2": np.zeros((8, 8), np.float32)}))
    with pytest.raises(ValueError, match="2-D grid"):
        srv.submit(StencilRequest(
            "jac", {"in_1": np.zeros((8, 8, 3), np.float32)}))
    with pytest.raises(ValueError, match="not bucketable"):
        srv.submit(StencilRequest(
            "jac", {"in_1": np.zeros((64, 8), np.float32)}))
    assert srv.flush() == {}                # nothing malformed was queued
    # a fitting request still works
    out = srv.serve([mixed_request("jac", (10, 10))])
    assert out[0].shape == (10, 10)


def test_bucketed_register_idempotent_across_shapes():
    """Bucketed registrations are shape-agnostic: re-registering the same
    structure with a different declared grid size is idempotent."""
    import pytest

    a = stencils.jacobi2d(shape=(16, 12), iterations=2)
    b = stencils.jacobi2d(shape=(24, 10), iterations=2)   # same structure
    hot = stencils.hotspot(shape=(16, 12), iterations=2)
    srv = StencilServer(
        max_batch=2, cache=DesignCache(), bucketing=True, tile_rows=8,
    )
    r1 = srv.register("jac", a)
    assert srv.register("jac", b) is r1
    with pytest.raises(ValueError, match="already registered"):
        srv.register("jac", hot)
    with pytest.raises(ValueError, match="already registered"):
        srv.register("jac", a, bucketing=False)   # mode mismatch
    with pytest.raises(ValueError, match="already registered"):
        # same mode, different ladder policy: must not be silently ignored
        srv.register("jac", a, bucketing=ShapeBucketer(max_shape=(64, 64)))


def test_tickets_resolve_in_submission_order():
    iters = 2
    spec = stencils.jacobi2d(shape=(12, 6), iterations=iters)
    srv = StencilServer(max_batch=2, cache=DesignCache())
    srv.register("jac", spec)
    reqs = [grid_request("jac", spec) for _ in range(3)]
    tickets = [srv.submit(r) for r in reqs]
    done = srv.flush()
    assert sorted(done) == sorted(tickets)
    for t, r in zip(tickets, reqs):
        np.testing.assert_allclose(
            done[t], oracle(spec, r, iters), rtol=2e-4, atol=2e-4
        )
    assert srv.flush() == {}  # queue drained
