"""Boundary-condition semantics: every stock kernel x every boundary mode.

The acceptance matrix of the boundary generalization (docs/DESIGN.md
§Boundary semantics): the reference executor defines the truth for each
mode; the fused jnp fallback and the single-PE Pallas kernel must agree
to reference-exactness for every benchmark kernel under every boundary.
The real multi-device shard_map paths (including the periodic wraparound
ppermute exchange) are covered by ``_multidevice_main.py``; the bucketed
serving interaction lives in ``test_bucketing.py``.
"""
import dataclasses

import numpy as np
import pytest
import jax.numpy as jnp

from repro.configs import stencils

from repro.core.spec import Boundary
from repro.kernels import ops, ref

RNG = np.random.default_rng(23)

BOUNDARIES = [
    Boundary("zero"),
    Boundary("constant", 1.5),
    Boundary("replicate"),
    Boundary("periodic"),
]


def _spec(name, boundary, iterations=3):
    shape = (12, 6, 6) if name in stencils.BENCHMARKS_3D else (16, 11)
    base = stencils.get(name, shape=shape, iterations=iterations)
    return dataclasses.replace(base, boundary=boundary)


def _arrays(spec):
    return {
        n: jnp.asarray(RNG.standard_normal(shp).astype(dt))
        for n, (dt, shp) in spec.inputs.items()
    }


# ---------------------------------------------------------------------------
# reference semantics (hand-computed oracles per mode)
# ---------------------------------------------------------------------------


def _one_step_numpy(x, boundary):
    """5-point Jacobi step with explicit numpy boundary handling."""
    if boundary.kind == "zero":
        p = np.pad(x, 1)
    elif boundary.kind == "constant":
        p = np.pad(x, 1, constant_values=boundary.value)
    elif boundary.kind == "replicate":
        p = np.pad(x, 1, mode="edge")
    else:
        p = np.pad(x, 1, mode="wrap")
    r, c = x.shape
    return (
        p[1:r + 1, 2:c + 2] + p[2:r + 2, 1:c + 1] + p[1:r + 1, 1:c + 1]
        + p[1:r + 1, 0:c] + p[0:r, 1:c + 1]
    ) / 5


@pytest.mark.parametrize("boundary", BOUNDARIES, ids=lambda b: b.kind)
def test_ref_matches_numpy_oracle(boundary):
    spec = _spec("jacobi2d", boundary, iterations=2)
    x = RNG.standard_normal(spec.shape).astype(np.float32)
    want = _one_step_numpy(_one_step_numpy(x, boundary), boundary)
    got = ref.stencil_iterations_ref(spec, {"in_1": jnp.asarray(x)}, 2)
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-5, atol=1e-5)


def test_periodic_conserves_mean():
    """On a torus, averaging stencils conserve the grid mean exactly."""
    spec = _spec("jacobi2d", Boundary("periodic"), iterations=5)
    x = RNG.standard_normal(spec.shape).astype(np.float32)
    out = ref.stencil_iterations_ref(spec, {"in_1": jnp.asarray(x)}, 5)
    assert float(jnp.mean(out)) == pytest.approx(float(np.mean(x)), abs=1e-5)


def test_replicate_preserves_constant_field():
    """A constant field is a fixed point under clamped-edge averaging."""
    spec = _spec("blur", Boundary("replicate"), iterations=4)
    x = np.full(spec.shape, 3.25, np.float32)
    out = ref.stencil_iterations_ref(spec, {"in_1": jnp.asarray(x)}, 4)
    np.testing.assert_allclose(np.asarray(out), x, rtol=1e-6)


# ---------------------------------------------------------------------------
# the full matrix: kernels x boundaries x executors
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("boundary", BOUNDARIES, ids=lambda b: b.kind)
@pytest.mark.parametrize("name", sorted(stencils.BENCHMARKS))
def test_fused_jnp_matches_ref_all_boundaries(name, boundary):
    spec = _spec(name, boundary)
    arrays = _arrays(spec)
    want = ref.stencil_iterations_ref(spec, arrays, 3)
    got = ops.stencil_run(spec, arrays, 3, s=2, backend="jnp")
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4,
    )


@pytest.mark.parametrize("boundary", BOUNDARIES, ids=lambda b: b.kind)
@pytest.mark.parametrize("name", sorted(stencils.BENCHMARKS))
def test_pallas_matches_ref_all_boundaries(name, boundary):
    spec = _spec(name, boundary)
    arrays = _arrays(spec)
    want = ref.stencil_iterations_ref(spec, arrays, 3)
    got = ops.stencil_run(
        spec, arrays, 3, s=2, tile_rows=5, backend="pallas"
    )
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4,
    )


@pytest.mark.parametrize("boundary", BOUNDARIES[1:], ids=lambda b: b.kind)
def test_pallas_ragged_tiles_and_lane_alignment(boundary):
    """Boundary halos must survive row-tile raggedness + 128-lane padding."""
    base = stencils.jacobi2d(shape=(13, 10), iterations=4)
    spec = dataclasses.replace(base, boundary=boundary)
    arrays = _arrays(spec)
    want = ref.stencil_iterations_ref(spec, arrays, 4)
    got = ops.stencil_run(
        spec, arrays, 4, s=2, tile_rows=4, backend="pallas", align_cols=128,
    )
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4,
    )


# ---------------------------------------------------------------------------
# the new stock kernels carry their boundary declarations
# ---------------------------------------------------------------------------


def test_new_stock_kernels_declare_boundaries():
    assert stencils.get("heat3d_periodic").boundary == Boundary("periodic")
    assert stencils.get("blur_replicate").boundary == Boundary("replicate")
    assert stencils.get("sobel2d_replicate").boundary == \
        Boundary("replicate")
    # identical expression trees, different boundary: different kernels
    from repro.runtime import structural_fingerprint

    a = stencils.get("heat3d", shape=(16, 8, 8))
    b = dataclasses.replace(
        stencils.get("heat3d_periodic", shape=(16, 8, 8)), name=a.name
    )
    assert structural_fingerprint(a) != structural_fingerprint(b)


def test_autotune_end_to_end_nonzero_boundary():
    """autotune -> runner on the new boundary kernels matches the oracle."""
    from repro.core import autotune

    for name in ["heat3d_periodic", "blur_replicate"]:
        shape = (16, 6, 6) if name in stencils.BENCHMARKS_3D else (16, 11)
        spec = stencils.get(name, shape=shape, iterations=2)
        design = autotune(spec, tile_rows=8)
        arrays = {
            n: RNG.standard_normal(shp).astype(dt)
            for n, (dt, shp) in spec.inputs.items()
        }
        want = ref.stencil_iterations_ref(
            spec, {n: jnp.asarray(a) for n, a in arrays.items()}, 2
        )
        np.testing.assert_allclose(
            design.runner(arrays), np.asarray(want), rtol=2e-4, atol=2e-4,
            err_msg=name,
        )


def test_boundary_value_requires_constant():
    with pytest.raises(ValueError, match="only applies to 'constant'"):
        Boundary("replicate", 2.0)
    with pytest.raises(ValueError, match="unknown boundary kind"):
        Boundary("mirror")


def test_boundary_dsl_spec_validates_iterations():
    with pytest.raises(ValueError, match="iteration count"):
        dataclasses.replace(
            stencils.jacobi2d(shape=(8, 8)), iterations=0
        ).validate()
