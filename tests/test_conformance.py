"""Cross-executor conformance suite: random specs, differential checking.

The gate that lets the executor zoo grow without drifting: a seeded
random-spec generator (arity, taps, stages, iterations, all four boundary
modes) drives every execution path against an independent **pure-numpy
oracle** implemented in this file — no jax, no shared helpers, so a bug
in `kernels/blockops.py` cannot hide in its own reference:

  * `kernels/ref.py` (the jnp oracle the repo tests against elsewhere),
  * the fused trapezoid path (`stencil_run(backend="jnp", s=2)`),
  * the Pallas kernel in interpret mode (row-tiled, on a seed subset —
    it is the slowest executor),
  * the bucketed-padded path (`build_bucket_runner`: streamed mask /
    halo-index / wrap-margin transforms, routed exactly like serving).

Three layers of coverage:

  * ``test_conformance_random_block``: 200 seed-pinned random specs
    (20 blocks x 10 seeds), deterministic across runs — the CI floor.
  * ``test_conformance_corpus``: a checked-in regression corpus of seeds
    whose generated specs exercise known-tricky structure (multi-input
    iterate choice, local-stage chains, radius-2 taps, bucket-edge
    straddles).  Add the seed here whenever a fuzz run finds a
    disagreement, so it is replayed forever.
  * ``test_conformance_hypothesis_fuzz``: hypothesis-driven seed search
    beyond the pinned range.  The ``ci`` profile caps examples so tier-1
    wall-clock stays bounded; the ``nightly`` profile (select with
    ``HYPOTHESIS_PROFILE=nightly``, run by the nightly workflow job)
    searches much deeper.
"""
from __future__ import annotations

import math
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import numerics
from repro.kernels import pipeline, stencil
from repro.core.spec import (
    BinOp,
    Boundary,
    Call,
    Neg,
    Num,
    Ref,
    Stage,
    StencilSpec,
)
from repro.kernels import ops, ref
from repro.runtime import (
    ShapeBucketer,
    build_bucket_runner,
    padded_request_shape,
)
from repro.core.model import ParallelismConfig

pytestmark = pytest.mark.filterwarnings(
    "ignore::repro.runtime.batching.DegradedDesignWarning"
)

# Legacy repo-wide executor tolerance (vs the numpy oracle).  Since the
# certified-numerics analyzer (repro.core.numerics) this is a regression
# BACKSTOP only: every differential gate uses the analyzer-derived
# per-case bound, widened to the legacy constant where that is larger
# (and test_certified_bounds_tight_and_not_vacuous proves it never is on
# the seed-pinned corpus — the analyzer tightened, not loosened, the
# suite).
RTOL = ATOL = 2e-4

BOUNDARIES = (
    Boundary("zero"),
    Boundary("constant", 1.5),
    Boundary("replicate"),
    Boundary("periodic"),
)


# ---------------------------------------------------------------------------
# Pure-numpy oracle (independent of every jax executor)
# ---------------------------------------------------------------------------


def _np_pad(a: np.ndarray, r: int, boundary: Boundary) -> np.ndarray:
    pads = [(r, r)] * a.ndim
    k = boundary.kind
    if k == "zero":
        return np.pad(a, pads)
    if k == "constant":
        return np.pad(a, pads, constant_values=boundary.value)
    if k == "replicate":
        return np.pad(a, pads, mode="edge")
    return np.pad(a, pads, mode="wrap")


def _np_eval(expr, get_ref):
    if isinstance(expr, Num):
        return expr.value
    if isinstance(expr, Ref):
        return get_ref(expr.name, expr.offsets)
    if isinstance(expr, Neg):
        return -_np_eval(expr.arg, get_ref)
    if isinstance(expr, BinOp):
        lhs = _np_eval(expr.lhs, get_ref)
        rhs = _np_eval(expr.rhs, get_ref)
        return {"+": np.add, "-": np.subtract,
                "*": np.multiply, "/": np.divide}[expr.op](lhs, rhs)
    if isinstance(expr, Call):
        args = [_np_eval(a, get_ref) for a in expr.args]
        if expr.fn == "abs":
            return np.abs(args[0])
        acc = args[0]
        for a in args[1:]:
            acc = np.maximum(acc, a) if expr.fn == "max" else np.minimum(acc, a)
        return acc
    raise TypeError(f"oracle cannot evaluate {expr!r}")


def numpy_oracle(
    spec: StencilSpec, arrays: dict, iterations: int
) -> np.ndarray:
    """Iterate ``spec`` entirely in numpy with exact boundary semantics."""
    env = {n: np.asarray(a) for n, a in arrays.items()}
    out = env[spec.iterate_input]
    shape = out.shape
    for _ in range(iterations):
        stage_env = dict(env)
        for stage in spec.stages:
            r = stage.radius
            padded = {
                n: _np_pad(a, r, spec.boundary)
                for n, a in stage_env.items()
            }

            def get_ref(name, offsets, padded=padded, r=r):
                idx = tuple(
                    slice(r + o, r + o + s) for o, s in zip(offsets, shape)
                )
                return padded[name][idx]

            res = _np_eval(stage.expr, get_ref)
            stage_env[stage.name] = np.asarray(
                np.broadcast_to(res, shape), dtype=stage.dtype
            )
        out = stage_env[spec.output_name]
        env[spec.iterate_input] = out
    return out


# ---------------------------------------------------------------------------
# Seeded random-spec generator
# ---------------------------------------------------------------------------


def _random_expr(rng, readable, ndim, radius, depth):
    """Random expression over the readable arrays, taps within ``radius``."""

    def tap():
        name = readable[rng.integers(len(readable))]
        offs = tuple(int(rng.integers(-radius, radius + 1))
                     for _ in range(ndim))
        return Ref(name, offs)

    def leaf():
        if rng.random() < 0.3:
            return Num(round(float(rng.uniform(-2.0, 2.0)), 3))
        return tap()

    def build(d):
        if d <= 0:
            return leaf()
        roll = rng.random()
        if roll < 0.15:
            return Neg(build(d - 1))
        if roll < 0.30:
            fn = ("max", "min", "abs")[rng.integers(3)]
            n_args = 1 if fn == "abs" else int(rng.integers(2, 4))
            return Call(fn, tuple(build(d - 1) for _ in range(n_args)))
        if roll < 0.40:
            # division only by non-zero constants: division by streamed
            # data is not bucketable (check_bucketable) by design
            return BinOp("/", build(d - 1),
                         Num(round(float(rng.uniform(1.5, 4.0)), 3)))
        op = "+-*"[rng.integers(3)]
        return BinOp(op, build(d - 1), build(d - 1))

    expr = build(depth)
    if not any(isinstance(n, Ref) for n in _walk(expr)):
        expr = BinOp("+", expr, tap())   # every stage taps streamed data
    return expr


def _walk(expr):
    yield expr
    if isinstance(expr, BinOp):
        yield from _walk(expr.lhs)
        yield from _walk(expr.rhs)
    elif isinstance(expr, Call):
        for a in expr.args:
            yield from _walk(a)
    elif isinstance(expr, Neg):
        yield from _walk(expr.arg)


def random_spec(seed: int):
    """Deterministic (spec, arrays, iterations) for one seed.

    Small grids and shallow trees keep per-seed jit cost low; the
    dimensions the executors branch on — arity, local stages, tap radius,
    iterate-input choice, boundary mode, grid raggedness — are all
    exercised.  The boundary mode cycles with the seed so every block of
    four seeds covers the full matrix.
    """
    rng = np.random.default_rng(seed)
    ndim = 2 if rng.random() < 0.75 else 3
    if ndim == 2:
        shape = tuple(int(rng.integers(4, 10)) for _ in range(2))
        radius = int(rng.integers(1, 3))
        depth = int(rng.integers(1, 4))
    else:
        shape = tuple(int(rng.integers(4, 7)) for _ in range(3))
        radius = 1
        depth = int(rng.integers(1, 3))
    iterations = int(rng.integers(1, 4)) if ndim == 2 else int(
        rng.integers(1, 3)
    )
    boundary = BOUNDARIES[seed % len(BOUNDARIES)]

    n_inputs = int(rng.integers(1, 3))
    inputs = {
        f"in_{i}": ("float32", shape) for i in range(n_inputs)
    }
    iterate = f"in_{int(rng.integers(n_inputs))}"
    readable = list(inputs)
    stages = []
    if rng.random() < 0.4:
        stages.append(Stage(
            "tmp", "float32",
            _random_expr(rng, readable, ndim, 1, depth), False,
        ))
        readable.append("tmp")
    stages.append(Stage(
        "out", "float32",
        _random_expr(rng, readable, ndim, radius, depth), True,
    ))
    spec = StencilSpec(
        name=f"CONF-{seed}",
        iterations=iterations,
        inputs=inputs,
        stages=tuple(stages),
        iterate_input=iterate,
        boundary=boundary,
    )
    spec.validate()
    arrays = {
        n: rng.standard_normal(shape).astype(np.float32) for n in inputs
    }
    return spec, arrays, iterations


# ---------------------------------------------------------------------------
# Differential check
# ---------------------------------------------------------------------------


def check_seed(seed: int, pallas: bool) -> None:
    spec, arrays, iters = random_spec(seed)
    want = numpy_oracle(spec, arrays, iters)
    assert np.isfinite(want).all(), f"seed {seed}: oracle not finite"
    check_case(spec, arrays, iters, want, pallas, f"seed {seed}")


# Per-case stats accumulated by check_case: (certified bound, legacy
# backstop, worst measured divergence, output scale).  The post-hoc test
# test_certified_bounds_tight_and_not_vacuous (defined after the block
# tests, so pytest's in-module definition order runs it last) proves the
# analyzer bounds sound AND tighter than the legacy constants over the
# whole seed-pinned corpus.
_CORPUS_STATS: list[dict] = []


def check_case(
    spec: StencilSpec,
    arrays: dict,
    iters: int,
    want: np.ndarray,
    pallas: bool,
    label: str,
) -> None:
    jarrays = {n: jnp.asarray(a) for n, a in arrays.items()}
    msg = (
        f"{label}: {spec.boundary.kind} {spec.ndim}-D "
        f"{spec.shape} it={iters} r={spec.radius}"
    )
    # Analyzer-derived differential tolerance: a certified bound on
    # |executor - oracle| from the measured-envelope error analysis
    # (repro.core.numerics.tolerance_for).  It replaces the old
    # scale-aware heuristic — which survives only as a widening backstop
    # below, proven redundant by the post-hoc corpus test.
    certified = numerics.tolerance_for(spec, iters, arrays)
    assert math.isfinite(certified), f"{msg}: certified bound not finite"
    legacy = ATOL * max(1.0, float(np.abs(want).max()))
    atol = max(certified, legacy)
    worst = 0.0

    def gate(got, name):
        nonlocal worst
        got = np.asarray(got)
        diff = float(np.abs(got - np.asarray(want)).max())
        worst = max(worst, diff)
        # soundness: the certified bound must cover every executor's
        # actual divergence from the oracle — this is the acceptance
        # gate for the analyzer itself, not just for the executor
        assert diff <= certified, (
            f"{msg} [{name}]: measured divergence {diff:.3g} exceeds "
            f"the certified bound {certified:.3g}"
        )
        np.testing.assert_allclose(
            got, want, rtol=RTOL, atol=atol, err_msg=f"{msg} [{name}]"
        )

    gate(ref.stencil_iterations_ref(spec, jarrays, iters), "jnp ref")
    gate(
        ops.stencil_run(spec, jarrays, iters, s=2, backend="jnp"),
        "trapezoid",
    )

    if pallas:
        gate(
            ops.stencil_run(
                spec, jarrays, iters, s=2, backend="pallas",
                interpret=True, tile_rows=4,
            ),
            "pallas",
        )

    bucket = ShapeBucketer().bucket_for(
        padded_request_shape(spec, spec.shape, iters)
    )
    run = build_bucket_runner(
        spec, bucket, ParallelismConfig("temporal", k=1, s=2), tile_rows=8,
    )
    gate(
        run({n: a[None] for n, a in arrays.items()})[0],
        f"bucketed {bucket}",
    )

    _CORPUS_STATS.append({
        "label": label,
        "certified": certified,
        "legacy": legacy,
        "measured": worst,
        "scale": float(np.abs(want).max()),
    })


# ---------------------------------------------------------------------------
# CI floor: 200 seed-pinned random specs (deterministic)
# ---------------------------------------------------------------------------

N_BLOCKS, BLOCK = 20, 10          # 200 specs; Pallas on every 4th seed


@pytest.mark.parametrize("block", range(N_BLOCKS))
def test_conformance_random_block(block):
    for seed in range(block * BLOCK, (block + 1) * BLOCK):
        check_seed(seed, pallas=(seed % 4 == 0))


# ---------------------------------------------------------------------------
# Batch-in-grid vs vmap: the tile-pipeline bitwise differential
# ---------------------------------------------------------------------------

# Folding the batch axis into the kernel grid changes *scheduling*, never
# the computation — so the differential can demand far more than the
# repo-wide executor tolerance:
#
#   * Pallas batch-in-grid vs ``jax.vmap(stencil_pallas)``: the kernel
#     body is the identical traced function at identical block shapes
#     (vmap adds the batch as a grid dimension, which is exactly what
#     the batched kernel declares explicitly), so on CPU the results are
#     **bitwise equal** — a plain allclose would let a subtly different
#     trapezoid hide inside the tolerance.
#   * jnp software pipeline vs ``jax.vmap`` of the per-entry tile loop:
#     the tile *values* are the same, but the loop bodies are different
#     HLO (double-buffer carry vs slice-per-step), and XLA-CPU's
#     instruction selection may round division / mul-add chains
#     differently per program by 1 ULP.  The bound is ULP-scale —
#     orders of magnitude tighter than the executor tolerance — not
#     exact.
#
# Off-CPU backends may legally re-fuse, so both gates degrade to the
# repo tolerance there.
BITWISE = jax.default_backend() == "cpu"
ULP = float(np.finfo(np.float32).eps)


def _assert_ulp_close(got, want, msg, certified=0.0, n_ulp=4):
    """Pipeline differential: analyzer-certified bound, legacy 4-ULP floor.

    ``certified`` is the analyzer-derived bound on the two programs'
    divergence (each is a faithful evaluation within the forward error
    bound of the same exact iteration); the legacy ``n_ulp``-ULP
    scale-aware constant remains as a regression backstop during this
    PR — the gate is ``max`` of the two, so it can only have tightened
    where the analyzer says the computation is ULP-clean.
    """
    got, want = np.asarray(got), np.asarray(want)
    if BITWISE:
        legacy = n_ulp * ULP * max(1.0, float(np.abs(want).max()))
        bound = max(certified, legacy)
        diff = float(np.abs(got - want).max())
        assert diff <= bound, (
            f"{msg}: max diff {diff} > bound {bound} "
            f"(certified {certified}, legacy {n_ulp}-ULP {legacy})"
        )
    else:
        np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL,
                                   err_msg=msg)


def check_seed_batched(seed: int, pallas: bool, B: int = 3) -> None:
    """Batch-in-grid executors vs ``jax.vmap`` of their per-entry twins."""
    spec, arrays, _ = random_spec(seed)
    rng = np.random.default_rng(seed + 10_000)
    batched = {
        n: np.stack([a] + [
            rng.standard_normal(a.shape).astype(a.dtype)
            for _ in range(B - 1)
        ])
        for n, a in arrays.items()
    }
    jbatched = {n: jnp.asarray(a) for n, a in batched.items()}
    msg = f"seed {seed}: {spec.boundary.kind} {spec.ndim}-D {spec.shape}"
    # both programs run the lowered trees over the same (batched) data,
    # so their divergence is certifiably at most tolerance_for's bound
    certified = numerics.tolerance_for(spec, 2, batched)
    assert math.isfinite(certified), f"{msg}: certified bound not finite"

    got = pipeline.stencil_jnp_pipeline(spec, jbatched, 2, tile_rows=4)
    want = jax.vmap(
        lambda one: pipeline.stencil_jnp_tiled(spec, one, 2, tile_rows=4)
    )(jbatched)
    _assert_ulp_close(
        got, want, f"{msg} [jnp pipeline vs vmap]", certified=certified
    )

    if pallas:
        got_pl = np.asarray(pipeline.stencil_pallas_batched(
            spec, jbatched, 2, tile_rows=4, interpret=True
        ))
        want_pl = np.asarray(jax.vmap(
            lambda one: stencil.stencil_pallas(
                spec, one, 2, tile_rows=4, interpret=True
            )
        )(jbatched))
        if BITWISE:
            np.testing.assert_array_equal(
                got_pl, want_pl,
                err_msg=f"{msg} [pallas batch-in-grid vs vmap]",
            )
        else:
            np.testing.assert_allclose(
                got_pl, want_pl, rtol=RTOL, atol=ATOL,
                err_msg=f"{msg} [pallas batch-in-grid vs vmap]",
            )


@pytest.mark.parametrize("block", range(N_BLOCKS))
def test_batch_in_grid_matches_vmap_block(block):
    for seed in range(block * BLOCK, (block + 1) * BLOCK):
        check_seed_batched(seed, pallas=(seed % 8 == 0))


def test_tile_pipeline_full_run_matches_oracle():
    """stencil_run_batched (round loop + re-wrap handling) end to end
    against the numpy oracle, both backends, all boundary modes."""
    for seed in (0, 1, 2, 3):     # one seed per boundary mode
        spec, arrays, iters = random_spec(seed)
        want = np.stack([numpy_oracle(spec, arrays, iters)])
        jbatched = {n: jnp.asarray(a)[None] for n, a in arrays.items()}
        atol = ATOL * max(1.0, float(np.abs(want).max()))
        for backend in ("jnp", "pallas"):
            got = np.asarray(pipeline.stencil_run_batched(
                spec, jbatched, iters, s=2, tile_rows=4, backend=backend,
            ))
            np.testing.assert_allclose(
                got, want, rtol=RTOL, atol=atol,
                err_msg=f"seed {seed} [{backend} tile pipeline]",
            )


# ---------------------------------------------------------------------------
# Seed-pinned regression corpus
# ---------------------------------------------------------------------------

# Seeds replayed forever (beyond the 0..199 CI floor).  Each entry names
# the structural trait it pins (verified against the generator); add the
# offending seed here whenever any fuzz run (nightly hypothesis job
# included) finds an executor disagreement.
REGRESSION_CORPUS = [
    (201, "constant 3-D two-input spec iterating the second input"),
    (203, "periodic 3-D with a local stage chain (wrap on 3 dims)"),
    (207, "periodic 2-D iterations=3 (widest wrap margin in suite)"),
    (209, "constant 2-D radius-2 with a local stage"),
    (210, "replicate 2-D radius-2 taps (halo-index gather depth 2)"),
    (212, "zero-boundary two-input local-stage chain, ragged 8x5"),
    (226, "replicate 2-D it=3 with value blow-up (scale-aware tolerance)"),
    (250, "replicate pow2 rows: real/belt edge on a bucket-rung boundary"),
]


@pytest.mark.parametrize(
    "seed", [s for s, _ in REGRESSION_CORPUS],
    ids=[f"seed{s}" for s, _ in REGRESSION_CORPUS],
)
def test_conformance_corpus(seed):
    check_seed(seed, pallas=True)


# ---------------------------------------------------------------------------
# Hypothesis fuzzing beyond the pinned range (ci-capped; nightly deep)
# ---------------------------------------------------------------------------

try:
    import hypothesis
    from hypothesis import given, settings, strategies as st

    settings.register_profile(
        "ci", max_examples=15, deadline=None,
        suppress_health_check=list(hypothesis.HealthCheck),
    )
    settings.register_profile(
        "nightly", max_examples=1000, deadline=None,
        suppress_health_check=list(hypothesis.HealthCheck),
    )
    settings.load_profile(os.environ.get("HYPOTHESIS_PROFILE", "ci"))
    HAVE_HYPOTHESIS = True
except ImportError:     # the seed-pinned layers above still run
    HAVE_HYPOTHESIS = False


if HAVE_HYPOTHESIS:

    # Structure-aware strategy: hypothesis draws the spec's *structure*
    # (grid, arity, stages, expression tree, boundary) directly instead
    # of an opaque generator seed.  A failing case therefore shrinks to
    # a minimal spec — fewer inputs, a shallower expression, a smaller
    # grid — rather than to an arbitrary seed that reproduces a huge one.

    def _expr_strategy(readable, ndim, radius):
        offsets = st.tuples(
            *[st.integers(-radius, radius) for _ in range(ndim)]
        )
        tap = st.builds(Ref, st.sampled_from(readable), offsets)
        const = st.builds(
            lambda m: Num(m / 1000.0), st.integers(-2000, 2000)
        )
        leaf = st.one_of(tap, const)

        def extend(inner):
            return st.one_of(
                st.builds(Neg, inner),
                st.builds(
                    BinOp, st.sampled_from("+-*"), inner, inner
                ),
                # division only by non-zero constants: division by
                # streamed data is not bucketable by design
                st.builds(
                    lambda l, m: BinOp("/", l, Num(1.5 + m / 1000.0)),
                    inner, st.integers(0, 2500),
                ),
                st.builds(
                    lambda fn, args: Call(fn, tuple(args)),
                    st.sampled_from(["max", "min"]),
                    st.lists(inner, min_size=2, max_size=3),
                ),
                st.builds(lambda a: Call("abs", (a,)), inner),
            )

        # every stage must tap streamed data somewhere
        expr = st.recursive(leaf, extend, max_leaves=8)
        return expr.map(
            lambda e: e if any(isinstance(n, Ref) for n in _walk(e))
            else BinOp("+", e, Ref(readable[0], (0,) * ndim))
        )

    @st.composite
    def conformance_cases(draw):
        ndim = draw(st.sampled_from([2, 2, 2, 3]))
        hi = 9 if ndim == 2 else 6
        shape = tuple(
            draw(st.integers(4, hi)) for _ in range(ndim)
        )
        radius = draw(st.integers(1, 2)) if ndim == 2 else 1
        iterations = draw(st.integers(1, 3))
        boundary = draw(st.sampled_from(BOUNDARIES))
        n_inputs = draw(st.integers(1, 2))
        inputs = {f"in_{i}": ("float32", shape) for i in range(n_inputs)}
        iterate = f"in_{draw(st.integers(0, n_inputs - 1))}"
        readable = list(inputs)
        stages = []
        if draw(st.booleans()):
            stages.append(Stage(
                "tmp", "float32",
                draw(_expr_strategy(readable, ndim, 1)), False,
            ))
            readable.append("tmp")
        stages.append(Stage(
            "out", "float32",
            draw(_expr_strategy(readable, ndim, radius)), True,
        ))
        spec = StencilSpec(
            name="CONF-HYP",
            iterations=iterations,
            inputs=inputs,
            stages=tuple(stages),
            iterate_input=iterate,
            boundary=boundary,
        )
        spec.validate()
        rng = np.random.default_rng(draw(st.integers(0, 2**31 - 1)))
        arrays = {
            n: rng.standard_normal(shape).astype(np.float32)
            for n in inputs
        }
        return spec, arrays, iterations

    @given(case=conformance_cases())
    def test_conformance_hypothesis_fuzz(case):
        # restrict to the cheap executors so the nightly profile's
        # example count buys breadth; pallas depth comes from the pinned
        # layers
        spec, arrays, iters = case
        want = numpy_oracle(spec, arrays, iters)
        # iterated random products can overflow float32 — not a
        # conformance question
        hypothesis.assume(np.isfinite(want).all())
        check_case(spec, arrays, iters, want, pallas=False, label="hyp")

else:

    @pytest.mark.skip(reason="hypothesis not installed")
    def test_conformance_hypothesis_fuzz():
        pass


def test_boundary_modes_all_covered():
    """The seed-cycling generator must cover all 4 modes in every block."""
    kinds = {random_spec(s)[0].boundary.kind for s in range(8)}
    assert kinds == {"zero", "constant", "replicate", "periodic"}


# ---------------------------------------------------------------------------
# Certified-bound quality over the corpus (runs after the block tests:
# pytest executes tests in in-module definition order)
# ---------------------------------------------------------------------------


def test_certified_bounds_tight_and_not_vacuous():
    """The analyzer bounds are tighter than the legacy constants and
    within the documented slack of measured error on the corpus.

    Two claims over every seed-pinned case check_case ran this session:

      * **no loosening** — the certified bound never exceeds the legacy
        scale-aware tolerance it replaced, so deriving tolerances from
        the analyzer strictly tightened the differential suite;
      * **non-vacuous** — the corpus-median ratio of certified bound to
        measured divergence (floored at one output-scale ULP, so exact
        agreement doesn't divide by ~0) stays within
        ``numerics.NONVACUITY_SLACK``; a bound orders of magnitude
        beyond that would certify nothing worth having.
    """
    stats = [s for s in _CORPUS_STATS if s["label"].startswith("seed ")]
    if len(stats) < 150:
        pytest.skip(
            f"corpus stats incomplete ({len(stats)} cases): run the "
            "full conformance block tests in the same session"
        )
    loose = [
        s for s in stats if s["certified"] > s["legacy"]
    ]
    assert not loose, (
        "certified bound exceeds the legacy tolerance on "
        f"{len(loose)} case(s), e.g. {loose[:3]}"
    )
    ratios = sorted(
        s["certified"] / max(s["measured"], ULP * max(1.0, s["scale"]))
        for s in stats
    )
    median = ratios[len(ratios) // 2]
    assert median <= numerics.NONVACUITY_SLACK, (
        f"corpus-median certified/measured ratio {median:.1f} exceeds "
        f"the documented slack {numerics.NONVACUITY_SLACK}"
    )


if HAVE_HYPOTHESIS:

    @pytest.mark.skipif(
        os.environ.get("HYPOTHESIS_PROFILE", "ci") != "nightly",
        reason="soundness property sweep runs in the nightly profile",
    )
    @given(case=conformance_cases())
    def test_certified_bound_soundness_nightly(case):
        """Property: measured executor-vs-oracle divergence never
        exceeds the certified bound (deep sweep beyond the pinned
        seeds; the ci profile exercises the same property through
        check_case's inline assertion)."""
        spec, arrays, iters = case
        want = numpy_oracle(spec, arrays, iters)
        hypothesis.assume(np.isfinite(want).all())
        certified = numerics.tolerance_for(spec, iters, arrays)
        assert math.isfinite(certified)
        jarrays = {n: jnp.asarray(a) for n, a in arrays.items()}
        got = np.asarray(ref.stencil_iterations_ref(spec, jarrays, iters))
        diff = float(np.abs(got - np.asarray(want)).max())
        assert diff <= certified, (
            f"divergence {diff:.3g} > certified {certified:.3g} for "
            f"{spec.boundary.kind} {spec.shape} it={iters}"
        )


def test_numpy_oracle_matches_known_jacobi():
    """Anchor the oracle itself against a hand-checkable case."""
    spec, _, _ = random_spec(0)
    del spec
    jac = StencilSpec(
        name="J", iterations=1,
        inputs={"a": ("float32", (3, 3))},
        stages=(Stage("o", "float32", BinOp(
            "+", Ref("a", (0, 0)), Ref("a", (0, 1))
        ), True),),
        iterate_input="a",
        boundary=Boundary("periodic"),
    )
    x = np.arange(9, dtype=np.float32).reshape(3, 3)
    got = numpy_oracle(jac, {"a": x}, 1)
    want = x + np.roll(x, -1, axis=1)
    np.testing.assert_array_equal(got, want)
