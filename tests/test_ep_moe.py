"""Expert-parallel shard_map MoE dispatch equivalence (subprocess, 8 dev)."""
import os
import subprocess
import sys

import pytest


@pytest.mark.slow
def test_ep_moe_matches_dense_dispatch():
    script = os.path.join(os.path.dirname(__file__), "_ep_moe_main.py")
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, script], capture_output=True,
                          text=True, timeout=600, env=env)
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "EP_MOE_OK" in proc.stdout
