"""Shape bucketing: ladder policy, pad-and-mask equivalence, cache sharing.

The correctness core of multi-geometry serving: a design compiled for a
padded canonical bucket shape must serve any smaller grid with the exact
exterior-zero semantics of :func:`repro.kernels.ref.stencil_iterations_ref`,
across every parallelism variant.  In-process tests exercise the (possibly
degraded-to-single-PE) executor paths on the host's single device; the
real 8-device shard_map paths are covered by ``_multidevice_main.py``.
"""
import numpy as np
import pytest
import jax.numpy as jnp

from repro.configs import stencils
from repro.core import autotune
from repro.core.model import VARIANTS, ParallelismConfig
from repro.kernels import ref
from repro.runtime import (
    DesignCache,
    ShapeBucketer,
    build_bucket_runner,
    bucket_spec,
    mask_input_name,
    masked_spec,
    structural_fingerprint,
    with_shape,
)

RNG = np.random.default_rng(17)

# several in-process cases run spatial/hybrid configs on the 1-device host,
# which (deliberately) warns about the degraded parallelism
pytestmark = pytest.mark.filterwarnings(
    "ignore::repro.runtime.batching.DegradedDesignWarning"
)


def batch_for(spec, B, shape=None):
    shape = tuple(spec.shape) if shape is None else tuple(shape)
    return {
        n: RNG.standard_normal((B,) + shape).astype(dt)
        for n, (dt, _) in spec.inputs.items()
    }


def oracle(spec, arrays_b, iters, b):
    one = {n: jnp.asarray(a[b]) for n, a in arrays_b.items()}
    return np.asarray(ref.stencil_iterations_ref(spec, one, iters))


# ---------------------------------------------------------------------------
# ShapeBucketer policy
# ---------------------------------------------------------------------------


def test_pow2_bucketing():
    b = ShapeBucketer()
    assert b.bucket_for((20, 13)) == (32, 16)
    assert b.bucket_for((32, 16)) == (32, 16)     # idempotent
    assert b.bucket_for((3, 2)) == (8, 8)         # min_size floor
    assert b.bucket_for((33, 129, 5)) == (64, 256, 8)


def test_user_ladder():
    b = ShapeBucketer(ladder=((16, 64, 720), (128, 1024)))
    assert b.bucket_for((10, 100)) == (16, 128)
    assert b.bucket_for((65, 1024)) == (720, 1024)
    with pytest.raises(ValueError, match="top rung"):
        b.bucket_for((721, 100))
    with pytest.raises(ValueError, match="bucket ladder"):
        b.bucket_for((10, 10, 10))                # wrong arity


def test_max_shape_cap():
    b = ShapeBucketer(max_shape=(64, 64))
    assert b.bucket_for((60, 60)) == (64, 64)
    with pytest.raises(ValueError, match="max_shape"):
        b.bucket_for((65, 8))


def test_bucketer_rejects_nonpositive():
    with pytest.raises(ValueError, match="positive"):
        ShapeBucketer().bucket_for((0, 8))


# ---------------------------------------------------------------------------
# spec transforms
# ---------------------------------------------------------------------------


def test_with_shape_keeps_structure():
    a = stencils.jacobi2d(shape=(16, 8), iterations=2)
    b = with_shape(a, (32, 16))
    assert b.shape == (32, 16)
    assert structural_fingerprint(a) == structural_fingerprint(b)
    with pytest.raises(ValueError, match="2-D"):
        with_shape(a, (32, 16, 4))


def test_masked_spec_adds_mask_input():
    spec = stencils.hotspot(shape=(16, 8), iterations=2)
    m = masked_spec(spec)
    mname = mask_input_name(spec)
    assert mname in m.inputs and mname not in spec.inputs
    assert m.iterate_input == spec.iterate_input
    assert m.radius == spec.radius          # mask taps at offset 0 only
    m.validate()


def test_masked_spec_rejects_division_by_streamed_data():
    """Zero padding would turn x/0 into NaN, which survives the exterior
    mask — such kernels must be refused, not silently corrupted."""
    from repro.core.dsl import parse

    spec = parse("""
kernel: RATIO
iteration: 2
input float: in_1(16, 8)
input float: in_2(16, 8)
output float: out_1(0,0) = in_1(0,0) / (in_2(0,0) + 1)
""")
    with pytest.raises(ValueError, match="divides by streamed data"):
        masked_spec(spec)
    with pytest.raises(ValueError, match="cannot be shape-bucketed"):
        bucket_spec(spec, (32, 16))
    # division by constants stays fine (the whole benchmark suite)
    masked_spec(stencils.jacobi2d(shape=(16, 8), iterations=2))


def test_autotune_bucket_runner_rejects_unknown_inputs():
    """The bucket-aware autotune wrapper must not pre-filter a typo'd
    array name into silence."""
    cache = DesignCache()
    spec = stencils.jacobi2d(shape=(16, 8), iterations=2)
    d = autotune(spec, cache=cache, bucket=True, tile_rows=8)
    x = np.zeros((16, 8), np.float32)
    with pytest.raises(ValueError, match="unknown input"):
        d.runner({"in_1": x, "in_1_typo": x})


def test_bucket_spec_shape_and_fingerprint_sharing():
    a = stencils.jacobi2d(shape=(20, 13), iterations=2)
    b = stencils.jacobi2d(shape=(25, 10), iterations=2)
    ba = bucket_spec(a, (32, 16))
    bb = bucket_spec(b, (32, 16))
    assert ba.shape == (32, 16)
    # different declared sizes, same bucket -> identical compiled spec
    assert structural_fingerprint(ba) == structural_fingerprint(bb)
    assert ba == bb


# ---------------------------------------------------------------------------
# pad-and-mask equivalence: every variant vs the reference oracle
# ---------------------------------------------------------------------------

VARIANT_CFGS = {
    "temporal": ParallelismConfig("temporal", k=1, s=2),
    "spatial_r": ParallelismConfig("spatial_r", k=2, s=1),
    "spatial_s": ParallelismConfig("spatial_s", k=2, s=1),
    "hybrid_r": ParallelismConfig("hybrid_r", k=2, s=2),
    "hybrid_s": ParallelismConfig("hybrid_s", k=2, s=2),
}


@pytest.mark.parametrize("variant", VARIANTS)
def test_bucket_matches_ref_all_variants(variant):
    iters = 4
    spec = stencils.get("jacobi2d", shape=(20, 13), iterations=iters)
    cfg = VARIANT_CFGS[variant]
    run = build_bucket_runner(spec, (32, 16), cfg, tile_rows=8)
    arrays = batch_for(spec, B=2)
    out = run(arrays)
    assert out.shape == (2, 20, 13)
    for b in range(2):
        np.testing.assert_allclose(
            out[b], oracle(spec, arrays, iters, b), rtol=2e-4, atol=2e-4,
        )


@pytest.mark.parametrize("name,shape,bucket", [
    ("hotspot", (20, 13), (32, 16)),          # two inputs, one iterated
    ("blur_jacobi2d", (20, 13), (32, 16)),    # local stage (fused loops)
    ("heat3d", (12, 6, 5), (16, 8, 8)),       # 3-D
])
def test_bucket_matches_ref_hard_specs(name, shape, bucket):
    iters = 3
    spec = stencils.get(name, shape=shape, iterations=iters)
    cfg = ParallelismConfig("temporal", k=1, s=3)
    run = build_bucket_runner(spec, bucket, cfg, tile_rows=8)
    arrays = batch_for(spec, B=2)
    out = run(arrays)
    assert out.shape == (2,) + shape
    for b in range(2):
        np.testing.assert_allclose(
            out[b], oracle(spec, arrays, iters, b), rtol=2e-4, atol=2e-4,
        )


def test_bucket_bit_identical_to_unpadded_same_design():
    """Padding + masking must not perturb a single bit: the bucket run of
    a grid equals running the identical (masked) design unpadded."""
    iters = 5
    spec = stencils.get("jacobi2d", shape=(20, 13), iterations=iters)
    cfg = ParallelismConfig("temporal", k=1, s=3)
    arrays = batch_for(spec, B=2)
    # bucket == grid shape: the mask is all ones, nothing is padded
    unpadded = build_bucket_runner(spec, (20, 13), cfg, tile_rows=8)(arrays)
    for bucket in [(32, 16), (64, 64)]:
        padded = build_bucket_runner(spec, bucket, cfg, tile_rows=8)(arrays)
        np.testing.assert_array_equal(padded, unpadded)


def test_bucket_runner_pallas_backend():
    iters = 3
    spec = stencils.jacobi2d(shape=(20, 13), iterations=iters)
    cfg = ParallelismConfig("temporal", k=1, s=3)
    run = build_bucket_runner(
        spec, (32, 16), cfg, tile_rows=8, backend="pallas", interpret=True,
    )
    arrays = batch_for(spec, B=2)
    out = run(arrays)
    for b in range(2):
        np.testing.assert_allclose(
            out[b], oracle(spec, arrays, iters, b), rtol=2e-4, atol=2e-4,
        )


def test_bucket_runner_validates_fit_and_names():
    spec = stencils.jacobi2d(shape=(16, 8), iterations=2)
    run = build_bucket_runner(
        spec, (16, 8), ParallelismConfig("temporal", k=1, s=2), tile_rows=8,
    )
    with pytest.raises(ValueError, match="does not fit"):
        run({"in_1": np.zeros((1, 20, 8), np.float32)})   # exceeds bucket
    with pytest.raises(ValueError, match="unknown input"):
        run({"in_1": np.zeros((1, 16, 8), np.float32),
             "oops": np.zeros((1, 16, 8), np.float32)})
    with pytest.raises(ValueError, match="missing input"):
        run({})


# ---------------------------------------------------------------------------
# bucketed design cache + bucket-aware autotune
# ---------------------------------------------------------------------------


def test_bucketed_designs_shared_across_registrations():
    cache = DesignCache()
    a = stencils.jacobi2d(shape=(20, 13), iterations=2)
    b = stencils.jacobi2d(shape=(25, 10), iterations=2)   # same bucket
    e1 = cache.bucketed(a, tile_rows=8).runner_for((20, 13))
    misses = cache.misses
    e2 = cache.bucketed(b, tile_rows=8).runner_for((25, 10))
    assert e1.bucket == e2.bucket == (32, 16)
    assert e2.stats.cache_hit                 # no re-rank, no re-jit
    assert cache.misses == misses
    assert e2.cached.runner is e1.cached.runner


def test_bucketed_design_per_bucket_counters():
    cache = DesignCache()
    spec = stencils.jacobi2d(shape=(20, 13), iterations=2)
    bd = cache.bucketed(spec, tile_rows=8)
    bd.runner_for((20, 13), count=3)
    bd.runner_for((18, 9), count=2)           # same bucket: a hit
    bd.runner_for((40, 40), count=1)          # new bucket: a miss
    st = bd.stats()
    assert bd.num_buckets == 2
    assert st[(32, 16)]["misses"] == 1 and st[(32, 16)]["hits"] == 1
    assert st[(32, 16)]["requests"] == 5
    assert st[(64, 64)]["misses"] == 1 and st[(64, 64)]["requests"] == 1


def test_autotune_bucket_path_matches_ref_and_shares_designs():
    cache = DesignCache()
    iters = 3
    a = stencils.jacobi2d(shape=(20, 13), iterations=iters)
    d1 = autotune(a, cache=cache, bucket=True, tile_rows=8)
    x = RNG.standard_normal((20, 13)).astype(np.float32)
    got = d1.runner({"in_1": x})
    want = np.asarray(
        ref.stencil_iterations_ref(a, {"in_1": jnp.asarray(x)}, iters)
    )
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)
    # a second spec in the same bucket is a pure cache hit
    misses = cache.misses
    b = stencils.jacobi2d(shape=(28, 12), iterations=iters)
    d2 = autotune(b, cache=cache, bucket=True, tile_rows=8)
    assert cache.misses == misses
    y = RNG.standard_normal((28, 12)).astype(np.float32)
    got2 = d2.runner({"in_1": y})
    want2 = np.asarray(
        ref.stencil_iterations_ref(b, {"in_1": jnp.asarray(y)}, iters)
    )
    np.testing.assert_allclose(got2, want2, rtol=2e-4, atol=2e-4)


def test_autotune_bucket_requires_cache():
    spec = stencils.jacobi2d(shape=(16, 8), iterations=2)
    with pytest.raises(ValueError, match="requires cache"):
        autotune(spec, bucket=True)


# ---------------------------------------------------------------------------
# non-zero boundaries x bucketing: every mode exact, never silently wrong
# ---------------------------------------------------------------------------


def _with_boundary(spec, boundary):
    import dataclasses

    return dataclasses.replace(spec, boundary=boundary)


def test_constant_boundary_bucket_matches_ref_and_is_bit_exact():
    """constant-v bucketing: mask+offset in-kernel, margin padded to v —
    allclose vs the oracle AND bit-identical to the unpadded masked run."""
    from repro.core.spec import Boundary

    iters = 4
    spec = _with_boundary(
        stencils.get("jacobi2d", shape=(20, 13), iterations=iters),
        Boundary("constant", 1.5),
    )
    cfg = ParallelismConfig("temporal", k=1, s=2)
    arrays = batch_for(spec, B=2)
    out = build_bucket_runner(spec, (32, 16), cfg, tile_rows=8)(arrays)
    for b in range(2):
        np.testing.assert_allclose(
            out[b], oracle(spec, arrays, iters, b), rtol=2e-4, atol=2e-4,
        )
    unpadded = build_bucket_runner(spec, (20, 13), cfg, tile_rows=8)(arrays)
    np.testing.assert_array_equal(out, unpadded)


def test_constant_boundary_bucket_hotspot_multi_input():
    """Both inputs (iterated and constant) read v from the bucket margin."""
    from repro.core.spec import Boundary

    iters = 3
    spec = _with_boundary(
        stencils.get("hotspot", shape=(20, 13), iterations=iters),
        Boundary("constant", -0.75),
    )
    cfg = ParallelismConfig("temporal", k=1, s=3)
    arrays = batch_for(spec, B=2)
    out = build_bucket_runner(spec, (32, 16), cfg, tile_rows=8)(arrays)
    for b in range(2):
        np.testing.assert_allclose(
            out[b], oracle(spec, arrays, iters, b), rtol=2e-4, atol=2e-4,
        )


def test_constant_boundary_bucketed_through_server():
    """The full serving path (_prepare: fill-padded grids, np.full batch
    padding, per-entry masks) must keep constant edges exact for a
    mixed-shape micro-batch, short-chunk padding included."""
    from repro.core.dsl import parse
    from repro.serve import StencilRequest, StencilServer

    DSL = """
kernel: HOT-EDGES
iteration: 3
boundary: constant 25.0
input float: t({r}, {c})
output float: o(0,0) = (t(0,1) + t(1,0) + t(0,0) + t(0,-1) + t(-1,0)) / 5
"""
    srv = StencilServer(
        cache=DesignCache(), max_batch=4, bucketing=True, tile_rows=8,
    )
    srv.register("hot", DSL.format(r=20, c=13))
    shapes = [(20, 13), (18, 10), (40, 40), (25, 9), (19, 12)]
    reqs = [
        StencilRequest("hot", {
            "t": RNG.standard_normal(s).astype(np.float32)
        })
        for s in shapes
    ]
    outs = srv.serve(reqs)
    for s, req, out in zip(shapes, reqs, outs):
        want = np.asarray(ref.stencil_iterations_ref(
            parse(DSL.format(r=s[0], c=s[1])),
            {"t": jnp.asarray(req.arrays["t"])}, 3,
        ))
        np.testing.assert_allclose(out, want, rtol=2e-4, atol=2e-4,
                                   err_msg=str(s))


@pytest.mark.parametrize("bad", ["inf", "-inf", "nan"])
def test_nonfinite_boundary_constants_rejected(bad):
    """inf/NaN constants would survive the mask multiply as NaN on
    IN-grid cells (inf * 0) — refused at spec construction and parse."""
    from repro.core.dsl import parse
    from repro.core.spec import Boundary

    with pytest.raises(ValueError, match="finite"):
        Boundary("constant", float(bad))
    with pytest.raises(SyntaxError, match="finite"):
        parse(f"""
kernel: K
boundary: constant {bad}
input float: a(8, 8)
output float: o(0,0) = a(0,0)
""")


def _route(spec, shape, iters):
    from repro.runtime import padded_request_shape

    return ShapeBucketer().bucket_for(padded_request_shape(spec, shape, iters))


@pytest.mark.parametrize("kind", ["replicate", "periodic"])
def test_replicate_periodic_bucket_matches_ref(kind):
    """The halo-streamed bucket transforms: replicate re-imposes the
    clamped exterior per stage from streamed index maps; periodic streams
    the wrapped extension into the reserved halo margin.  Both must match
    the oracle for grids strictly inside their bucket."""
    from repro.core.spec import Boundary

    iters = 4
    spec = _with_boundary(
        stencils.get("jacobi2d", shape=(20, 13), iterations=iters),
        Boundary(kind),
    )
    cfg = ParallelismConfig("temporal", k=1, s=2)
    bucket = _route(spec, (20, 13), iters)
    run = build_bucket_runner(spec, bucket, cfg, tile_rows=8)
    arrays = batch_for(spec, B=2)
    out = run(arrays)
    assert out.shape == (2, 20, 13)
    for b in range(2):
        np.testing.assert_allclose(
            out[b], oracle(spec, arrays, iters, b), rtol=2e-4, atol=2e-4,
        )


@pytest.mark.parametrize("kind", ["replicate", "periodic"])
@pytest.mark.parametrize("name,shape", [
    ("hotspot", (20, 13)),          # two inputs, one iterated
    ("blur_jacobi2d", (20, 13)),    # local stage (fused loops)
    ("heat3d", (12, 6, 5)),         # 3-D
])
def test_replicate_periodic_bucket_hard_specs(kind, name, shape):
    from repro.core.spec import Boundary

    iters = 3
    spec = _with_boundary(
        stencils.get(name, shape=shape, iterations=iters), Boundary(kind)
    )
    cfg = ParallelismConfig("temporal", k=1, s=3)
    run = build_bucket_runner(spec, _route(spec, shape, iters), cfg,
                              tile_rows=8)
    arrays = batch_for(spec, B=2)
    out = run(arrays)
    assert out.shape == (2,) + shape
    for b in range(2):
        np.testing.assert_allclose(
            out[b], oracle(spec, arrays, iters, b), rtol=2e-4, atol=2e-4,
        )


@pytest.mark.parametrize("kind", ["replicate", "periodic"])
def test_replicate_periodic_bucket_bit_identical_across_rungs(kind):
    """Widening the bucket must not perturb a single bit: the minimal-fit
    run of the streamed design equals every larger rung's run."""
    from repro.core.spec import Boundary
    from repro.runtime import padded_request_shape

    iters = 4
    spec = _with_boundary(
        stencils.get("jacobi2d", shape=(20, 13), iterations=iters),
        Boundary(kind),
    )
    cfg = ParallelismConfig("temporal", k=1, s=2)
    arrays = batch_for(spec, B=2)
    minimal = padded_request_shape(spec, (20, 13), iters)
    base = build_bucket_runner(spec, minimal, cfg, tile_rows=8)(arrays)
    for bucket in [ShapeBucketer().bucket_for(minimal), (64, 64)]:
        got = build_bucket_runner(spec, bucket, cfg, tile_rows=8)(arrays)
        np.testing.assert_array_equal(got, base, err_msg=str(bucket))


def test_replicate_bucket_exact_fit_and_pallas_backend():
    """Replicate needs no margin: bucket == grid works (belt width 0,
    bucket-level clamp == real clamp), and the streamed gather fixup runs
    inside the Pallas kernel body (interpret mode)."""
    from repro.core.spec import Boundary

    iters = 4
    spec = _with_boundary(
        stencils.get("jacobi2d", shape=(16, 8), iterations=iters),
        Boundary("replicate"),
    )
    cfg = ParallelismConfig("temporal", k=1, s=2)
    arrays = batch_for(spec, B=2)
    exact = build_bucket_runner(spec, (16, 8), cfg, tile_rows=8)(arrays)
    for b in range(2):
        np.testing.assert_allclose(
            exact[b], oracle(spec, arrays, iters, b), rtol=2e-4, atol=2e-4,
        )
    pall = build_bucket_runner(
        spec, (32, 16), cfg, tile_rows=8, backend="pallas", interpret=True,
    )(arrays)
    for b in range(2):
        np.testing.assert_allclose(
            pall[b], oracle(spec, arrays, iters, b), rtol=2e-4, atol=2e-4,
        )


@pytest.mark.parametrize("kind", ["replicate", "periodic"])
def test_replicate_periodic_bucketed_through_server(kind):
    """The full serving path — registration accepted, ragged shapes
    sharing bucket rungs, short-chunk batch padding, per-entry streamed
    service inputs — must keep replicate/periodic edges exact."""
    from repro.core.spec import Boundary
    from repro.serve import StencilRequest, StencilServer

    iters = 3
    base = _with_boundary(
        stencils.get("jacobi2d", shape=(20, 13), iterations=iters),
        Boundary(kind),
    )
    srv = StencilServer(
        cache=DesignCache(), max_batch=4, bucketing=True, tile_rows=8,
    )
    srv.register("jac", base, iterations=iters)
    shapes = [(20, 13), (18, 10), (40, 40), (25, 9), (19, 12)]
    reqs = [
        StencilRequest("jac", {
            "in_1": RNG.standard_normal(s).astype(np.float32)
        })
        for s in shapes
    ]
    outs = srv.serve(reqs)
    for s, req, out in zip(shapes, reqs, outs):
        spec_s = _with_boundary(
            stencils.get("jacobi2d", shape=s, iterations=iters),
            Boundary(kind),
        )
        want = np.asarray(ref.stencil_iterations_ref(
            spec_s, {"in_1": jnp.asarray(req.arrays["in_1"])}, iters,
        ))
        np.testing.assert_allclose(out, want, rtol=2e-4, atol=2e-4,
                                   err_msg=f"{kind} {s}")


def test_new_boundary_stock_kernels_bucketable():
    """heat3d_periodic / blur_replicate / sobel2d_replicate are servable
    stock kernels: registration accepted, multi-shape traffic exact."""
    for name, shapes in [
        ("heat3d_periodic", [(12, 6, 5), (10, 8, 6)]),
        ("blur_replicate", [(20, 13), (18, 10)]),
        ("sobel2d_replicate", [(20, 13), (25, 9)]),
    ]:
        from repro.serve import StencilRequest, StencilServer

        iters = 2
        spec0 = stencils.get(name, shape=shapes[0], iterations=iters)
        bd = DesignCache().bucketed(spec0, tile_rows=8)  # no refusal
        assert bd.spec.boundary.kind in ("replicate", "periodic")
        srv = StencilServer(
            cache=DesignCache(), max_batch=2, bucketing=True, tile_rows=8,
        )
        srv.register(name, spec0, iterations=iters)
        reqs = [
            StencilRequest(name, {
                n: RNG.standard_normal(s).astype(dt)
                for n, (dt, _) in spec0.inputs.items()
            })
            for s in shapes
        ]
        outs = srv.serve(reqs)
        for s, req, out in zip(shapes, reqs, outs):
            spec_s = stencils.get(name, shape=s, iterations=iters)
            want = np.asarray(ref.stencil_iterations_ref(
                spec_s,
                {n: jnp.asarray(a) for n, a in req.arrays.items()}, iters,
            ))
            np.testing.assert_allclose(out, want, rtol=2e-4, atol=2e-4,
                                       err_msg=f"{name} {s}")


def test_periodic_margin_routing_and_masked_spec_structure():
    """Periodic buckets reserve iterations*radius per side and compile a
    plain zero-boundary design with no mask; replicate designs thread a
    mask plus one int32 halo-index input per dimension."""
    from repro.core.spec import Boundary
    from repro.runtime import bucket_margins, padded_request_shape

    spec_p = _with_boundary(
        stencils.jacobi2d(shape=(20, 13), iterations=4), Boundary("periodic")
    )
    assert bucket_margins(spec_p, 4) == (4, 4)          # r=1, it=4
    assert padded_request_shape(spec_p, (20, 13), 4) == (28, 21)
    mp = masked_spec(spec_p)
    assert mp.boundary.is_zero and set(mp.inputs) == set(spec_p.inputs)
    assert not mp.halo_index_inputs

    spec_r = _with_boundary(
        stencils.jacobi2d(shape=(20, 13), iterations=4), Boundary("replicate")
    )
    assert bucket_margins(spec_r, 4) == (0, 0)
    mr = masked_spec(spec_r)
    assert mask_input_name(spec_r) in mr.inputs
    assert len(mr.halo_index_inputs) == 2
    for n in mr.halo_index_inputs:
        assert mr.inputs[n][0] == "int32"
    mr.validate()


# ---------------------------------------------------------------------------
# narrow periodic margins: wrap_rounds * radius instead of iterations * radius
# ---------------------------------------------------------------------------


def test_narrow_periodic_margin_structure():
    """wrap_rounds switches the bucket design to the narrow streamed-wrap
    form: margins shrink to wrap_rounds * radius, the compiled spec gains
    one int32 wrap-index input per dimension and caps its fused depth."""
    from repro.core.spec import Boundary
    from repro.runtime import bucket_margins, padded_request_shape

    it = 8
    spec = _with_boundary(
        stencils.jacobi2d(shape=(20, 13), iterations=it), Boundary("periodic")
    )
    assert bucket_margins(spec, it) == (8, 8)             # legacy wide
    assert bucket_margins(spec, it, wrap_rounds=2) == (2, 2)
    assert padded_request_shape(spec, (20, 13), it, 2) == (24, 17)
    m = masked_spec(spec, wrap_rounds=2)
    assert m.wrap_round_depth == 2
    assert len(m.wrap_index_inputs) == 2
    for n in m.wrap_index_inputs:
        assert m.inputs[n][0] == "int32"
    m.validate()
    b = bucket_spec(spec, (32, 32), 2)
    assert b.shape == (32, 32) and b.wrap_round_depth == 2
    # narrow margins are a periodic-only notion
    rep = _with_boundary(
        stencils.jacobi2d(shape=(20, 13), iterations=it), Boundary("replicate")
    )
    with pytest.raises(ValueError, match="periodic"):
        masked_spec(rep, wrap_rounds=2)


@pytest.mark.parametrize("wrap_rounds", [1, 3])
def test_narrow_periodic_bucket_matches_ref(wrap_rounds):
    """Serving from the narrow margin (between-round re-wrap capping the
    fused depth) must match the oracle even when wrap_rounds is far below
    the iteration count."""
    from repro.core.spec import Boundary
    from repro.runtime import padded_request_shape

    iters = 9
    spec = _with_boundary(
        stencils.get("jacobi2d", shape=(20, 13), iterations=iters),
        Boundary("periodic"),
    )
    cfg = ParallelismConfig("temporal", k=1, s=3)
    bucket = ShapeBucketer().bucket_for(
        padded_request_shape(spec, (20, 13), iters, wrap_rounds)
    )
    run = build_bucket_runner(
        spec, bucket, cfg, iterations=iters, tile_rows=8,
        wrap_rounds=wrap_rounds,
    )
    arrays = batch_for(spec, B=2)
    out = run(arrays)
    assert out.shape == (2, 20, 13)
    for b in range(2):
        np.testing.assert_allclose(
            out[b], oracle(spec, arrays, iters, b), rtol=2e-4, atol=2e-4,
            err_msg=f"wrap_rounds={wrap_rounds}",
        )


def test_narrow_periodic_margin_actually_shrinks_routing():
    """The point of the narrow margin: high-iteration periodic specs stop
    routing to buckets inflated by iterations * radius."""
    from repro.core.spec import Boundary
    from repro.runtime import padded_request_shape

    iters = 24
    spec = _with_boundary(
        stencils.jacobi2d(shape=(20, 13), iterations=iters),
        Boundary("periodic"),
    )
    wide = ShapeBucketer().bucket_for(padded_request_shape(spec, (20, 13), iters))
    narrow = ShapeBucketer().bucket_for(
        padded_request_shape(spec, (20, 13), iters, 2)
    )
    assert np.prod(narrow) < np.prod(wide)


def test_bucketed_design_wrap_rounds_decision():
    """Registration decides wrap_rounds once: periodic single-device pins
    it to the ranked fusion depth (capped at the iteration count, >= 1);
    every other boundary keeps the legacy wide margin (None)."""
    from repro.core.spec import Boundary

    it = 6
    periodic = _with_boundary(
        stencils.jacobi2d(shape=(20, 13), iterations=it), Boundary("periodic")
    )
    cache = DesignCache()
    bd = cache.bucketed(periodic, tile_rows=8, iterations=it)
    wr = bd.wrap_rounds
    ranked_s = cache.design(
        periodic, iterations=it, clip_to_devices=True
    ).ranking[0].config.s
    assert wr == max(min(ranked_s, it), 1)
    assert bd.wrap_rounds is wr                # pinned, not re-decided
    for kind in ("zero", "replicate"):
        other = _with_boundary(
            stencils.jacobi2d(shape=(20, 13), iterations=it), Boundary(kind)
        )
        assert DesignCache().bucketed(other, tile_rows=8).wrap_rounds is None


def test_narrow_periodic_end_to_end_through_cache():
    """The registration-level path: bucket routing, the streamed-wrap
    bucket design, and the wrap-index service inputs all agree."""
    from repro.core.spec import Boundary

    iters = 5
    spec = _with_boundary(
        stencils.jacobi2d(shape=(20, 13), iterations=iters),
        Boundary("periodic"),
    )
    bd = DesignCache().bucketed(spec, tile_rows=8, iterations=iters)
    entry = bd.runner_for((20, 13))
    arrays = batch_for(spec, B=2)
    out = entry.runner(arrays)
    for b in range(2):
        np.testing.assert_allclose(
            out[b], oracle(spec, arrays, iters, b), rtol=2e-4, atol=2e-4,
        )


# ---------------------------------------------------------------------------
# place_entry index-map memoization
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kind", ["replicate", "periodic"])
def test_place_entry_indices_memoized_per_shape(kind):
    """A serving trace replaying a few grid shapes must not rebuild the
    bucket-sized placement index maps per request: one build per distinct
    (shape, mode), every later placement a reuse — batched and unbatched
    placements of the same grid sharing one entry."""
    from repro.core.spec import Boundary
    from repro.runtime.bucketing import bucket_plan

    spec = _with_boundary(
        stencils.jacobi2d(shape=(20, 13), iterations=2), Boundary(kind)
    )
    plan = bucket_plan(spec, (32, 32), iterations=2)
    a = RNG.standard_normal((20, 13)).astype(np.float32)
    b = RNG.standard_normal((18, 10)).astype(np.float32)
    for _ in range(3):
        plan.place_entry(a)
        plan.place_entry(b)
    plan.place_entry(a[None], batched=True)     # same shape via batched path
    assert plan.place_index_builds == 2         # one per distinct shape
    assert plan.place_index_reuses == 5
    # identical results from build and reuse
    np.testing.assert_array_equal(plan.place_entry(a), plan.place_entry(a))


def test_lru_eviction_caps_ladder_and_preserves_counters():
    cache = DesignCache()
    spec = stencils.jacobi2d(shape=(20, 13), iterations=2)
    bd = cache.bucketed(spec, tile_rows=8, max_buckets=2)
    bd.runner_for((20, 13), count=4)         # bucket (32, 16)
    bd.runner_for((40, 40))                  # bucket (64, 64)
    assert bd.num_buckets == 2 and bd.evictions == 0
    bd.runner_for((70, 70))                  # bucket (128, 128): evicts LRU
    assert bd.num_buckets == 2
    assert bd.evictions == 1
    assert (32, 16) not in bd.buckets        # least-recently-hit went first
    st = bd.stats()
    assert st[(32, 16)]["evicted"] and st[(32, 16)]["requests"] == 4
    # rebuilding the evicted bucket resumes its counters (and is a pure
    # design-cache hit: the shared cache still memoizes the compiled design)
    misses = cache.misses
    entry = bd.runner_for((20, 13), count=1)
    assert cache.misses == misses
    assert entry.stats.requests == 5 and entry.stats.misses == 2
    assert (32, 16) in bd.buckets and bd.evictions == 2  # (64,64) evicted


def test_lru_order_follows_hits_not_insertion():
    cache = DesignCache()
    spec = stencils.jacobi2d(shape=(20, 13), iterations=2)
    bd = cache.bucketed(spec, tile_rows=8, max_buckets=2)
    bd.runner_for((20, 13))                  # (32, 16)
    bd.runner_for((40, 40))                  # (64, 64)
    bd.runner_for((20, 13))                  # refresh (32, 16): now MRU
    bd.runner_for((70, 70))                  # evicts (64, 64), not (32, 16)
    assert set(bd.buckets) == {(32, 16), (128, 128)}


def test_max_buckets_validation_and_server_passthrough():
    cache = DesignCache()
    spec = stencils.jacobi2d(shape=(20, 13), iterations=2)
    with pytest.raises(ValueError, match="max_buckets"):
        cache.bucketed(spec, max_buckets=0)
    from repro.serve import StencilRequest, StencilServer

    srv = StencilServer(
        cache=cache, bucketing=True, max_batch=2, tile_rows=8,
        max_buckets=1,
    )
    srv.register("j", spec)
    for shape in [(20, 13), (40, 40), (18, 10)]:
        x = RNG.standard_normal(shape).astype(np.float32)
        got = srv.serve([StencilRequest("j", {"in_1": x})])[0]
        want = np.asarray(ref.stencil_iterations_ref(
            stencils.jacobi2d(shape=shape, iterations=2),
            {"in_1": jnp.asarray(x)}, 2,
        ))
        np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)
    reg = srv.design("j")
    assert reg.cached.max_buckets == 1
    assert reg.cached.num_buckets == 1
    assert reg.cached.evictions >= 1
