"""Roofline HLO analyzer: trip-count scaling, dot FLOPs, collective bytes."""

from repro.roofline.analysis import (analyze_hlo, collective_bytes_from_hlo,
                                     _shape_bytes)

SYNTH = """\
HloModule test, num_partitions=4

%body (p: (s32[], f32[8,16])) -> (s32[], f32[8,16]) {
  %p = (s32[], f32[8,16]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,16] get-tuple-element(%p), index=1
  %w = f32[16,16] constant({...})
  %d = f32[8,16] dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,16] all-reduce(%d), replica_groups={}
  %one = s32[] constant(1)
  %ni = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[8,16]) tuple(%ni, %ar)
}

%cond (p2: (s32[], f32[8,16])) -> pred[] {
  %p2 = (s32[], f32[8,16]) parameter(0)
  %i2 = s32[] get-tuple-element(%p2), index=0
  %c = s32[] constant(10)
  ROOT %lt = pred[] compare(%i2, %c), direction=LT
}

ENTRY %main (a: f32[8,16]) -> f32[8,16] {
  %a = f32[8,16] parameter(0)
  %zero = s32[] constant(0)
  %init = (s32[], f32[8,16]) tuple(%zero, %a)
  %w2 = (s32[], f32[8,16]) while(%init), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"10"}}
  %res = f32[8,16] get-tuple-element(%w2), index=1
  %ag = f32[32,16] all-gather(%res), dimensions={0}
  %red = f32[8,16] slice(%ag), slice={[0:8], [0:16]}
  ROOT %out = f32[8,16] add(%red, %res)
}
"""


def test_shape_bytes():
    assert _shape_bytes("f32[8,16]") == 8 * 16 * 4
    assert _shape_bytes("bf16[2,3]") == 12
    assert _shape_bytes("(s32[], f32[8,16])") == 4 + 512
    assert _shape_bytes("pred[10]") == 10


def test_trip_count_scaling_of_dots_and_collectives():
    an = analyze_hlo(SYNTH)
    # dot: 2 * (8*16) * 16 = 4096 flops, x10 trips
    assert an["flops"] == 10 * 2 * 8 * 16 * 16
    # all-reduce inside loop: operand 512B x10; all-gather outside: 512B
    assert an["collectives"]["all-reduce"] == 10 * 512
    assert an["collectives"]["all-gather"] == 512
    assert an["collective_counts"]["all-reduce"] == 10
    assert an["collective_counts"]["all-gather"] == 1
    # while body got multiplicity 10
    assert an["multiplicities"].get("body") == 10.0


def test_collective_bytes_flat_parser_consistent():
    flat = collective_bytes_from_hlo(SYNTH)
    # flat parser (no trip awareness) counts each op once
    assert flat["all-reduce"] == 512
    assert flat["all-gather"] == 512


def test_bytes_accessed_positive_and_loop_scaled():
    an = analyze_hlo(SYNTH)
    assert an["bytes_accessed"] > 10 * 512  # loop body ops dominate
