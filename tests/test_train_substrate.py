"""Training substrate: optimizer convergence, checkpoint atomicity/resume,
failure injection, data determinism, serving engine."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (latest_step, restore_checkpoint,
                              save_checkpoint)
from repro.configs import base
from repro.data.pipeline import SyntheticLMData
from repro.models.model_zoo import build_model
from repro.optim import adafactor, adamw, cosine_schedule
from repro.serve.engine import Request, ServeEngine
from repro.train import TrainConfig, Trainer


def tiny_model():
    return build_model(base.get("internlm2_1_8b").reduced())


def test_adamw_converges_quadratic():
    opt = adamw(1e-1, weight_decay=0.0)
    params = {"w": jnp.array([3.0, -2.0])}
    state = opt.init(params)
    for step in range(200):
        grads = {"w": 2 * params["w"]}
        params, state = opt.update(grads, state, params,
                                   jnp.asarray(step, jnp.int32))
    assert float(jnp.abs(params["w"]).max()) < 1e-2


def test_adafactor_converges_matrix():
    opt = adafactor(5e-2, weight_decay=0.0, min_dim_factored=4)
    params = {"w": jnp.ones((8, 8)) * 2.0}
    state = opt.init(params)
    for step in range(300):
        grads = {"w": 2 * params["w"]}
        params, state = opt.update(grads, state, params,
                                   jnp.asarray(step, jnp.int32))
    assert float(jnp.abs(params["w"]).max()) < 5e-2
    # factored state really is factored (vectors, not a matrix)
    v = state["v"]["w"]
    assert set(v) == {"vr", "vc"} and v["vr"].shape == (8,)


def test_schedule_warmup_and_decay():
    lr = cosine_schedule(1.0, warmup=10, total=100)
    assert float(lr(0)) == 0.0
    assert float(lr(10)) == pytest.approx(1.0)
    assert float(lr(100)) == pytest.approx(0.0, abs=1e-6)
    assert float(lr(5)) == pytest.approx(0.5)


def test_data_pipeline_deterministic_and_stateless():
    d1 = SyntheticLMData(vocab=100, batch=4, seq=16, seed=3)
    d2 = SyntheticLMData(vocab=100, batch=4, seq=16, seed=3)
    b1, b2 = d1.batch_at(7), d2.batch_at(7)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    b3 = d1.batch_at(8)
    assert not np.array_equal(np.asarray(b1["tokens"]), np.asarray(b3["tokens"]))
    toks = np.asarray(b1["tokens"])
    assert toks.min() >= 0 and toks.max() < 100


def test_checkpoint_roundtrip_and_atomicity(tmp_path):
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
            "b": {"c": jnp.asarray(7, jnp.int32),
                  "d": [jnp.ones(4), jnp.zeros(2)]}}
    path = save_checkpoint(str(tmp_path), 5, tree)
    assert os.path.basename(path) == "step_00000005"
    assert latest_step(str(tmp_path)) == 5
    restored = restore_checkpoint(str(tmp_path), 5, tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # no .tmp directories may survive a successful commit
    assert not [d for d in os.listdir(tmp_path) if d.endswith(".tmp")]


def test_crash_resume_is_lossless(tmp_path):
    """5 steps, injected crash, resume, 5 more == 10 straight steps."""
    model = tiny_model()

    straight = Trainer(model, TrainConfig(
        steps=10, batch=2, seq=16, ckpt_dir=None, log_every=100))
    state_a, losses_a = straight.run()

    crashy = Trainer(model, TrainConfig(
        steps=10, batch=2, seq=16, ckpt_dir=str(tmp_path), ckpt_every=5,
        log_every=100, fail_at_step=5))
    with pytest.raises(RuntimeError, match="injected failure"):
        crashy.run()
    assert latest_step(str(tmp_path)) == 5

    resumed = Trainer(model, TrainConfig(
        steps=10, batch=2, seq=16, ckpt_dir=str(tmp_path), ckpt_every=5,
        log_every=100))
    state_b, losses_b = resumed.run()

    for a, b in zip(jax.tree.leaves(state_a["params"]),
                    jax.tree.leaves(state_b["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(losses_a[5:], losses_b, rtol=1e-6)


def test_training_reduces_loss():
    model = tiny_model()
    tr = Trainer(model, TrainConfig(steps=30, batch=4, seq=32, lr=3e-3,
                                    warmup=5, log_every=100))
    _, losses = tr.run()
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.2


def test_straggler_detector_fires():
    model = tiny_model()
    events = []
    tr = Trainer(model, TrainConfig(steps=25, batch=2, seq=16, log_every=100,
                                    straggler_zscore=3.0),
                 on_straggler=lambda **kw: events.append(kw))
    import time as _t
    orig = tr.train_step

    calls = {"n": 0}

    def slow_step(*a, **kw):
        calls["n"] += 1
        if calls["n"] == 24:
            _t.sleep(1.0)
        return orig(*a, **kw)

    tr.train_step = slow_step
    tr.run()
    assert events and events[0]["zscore"] > 3.0


def test_serve_engine_generates():
    model = tiny_model()
    params = model.init(jax.random.PRNGKey(0))
    eng = ServeEngine(model, params, batch_size=4, cache_len=64)
    reqs = [Request(prompt=np.arange(5) + 1, max_new_tokens=8),
            Request(prompt=np.arange(9) + 3, max_new_tokens=4)]
    outs = eng.generate(reqs)
    assert outs[0].shape == (8,) and outs[1].shape == (4,)
    assert all(o.min() >= 0 and o.max() < model.cfg.vocab for o in outs)


def test_serve_greedy_matches_repeated_prefill():
    """Decode path must agree with re-running prefill on the grown prompt."""
    model = tiny_model()
    params = model.init(jax.random.PRNGKey(1))
    eng = ServeEngine(model, params, batch_size=1, cache_len=32)
    prompt = np.arange(6, dtype=np.int32) + 2
    out = eng.generate([Request(prompt=prompt, max_new_tokens=3)])[0]
    seq = list(prompt)
    for _ in range(3):
        logits, _ = model.prefill(
            params, {"tokens": jnp.asarray([seq], jnp.int32)})
        seq.append(int(jnp.argmax(logits[0])))
    np.testing.assert_array_equal(out, np.asarray(seq[len(prompt):]))
