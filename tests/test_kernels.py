"""Per-kernel validation: Pallas fused stencil vs. the pure-jnp oracle.

Sweeps shapes, dtypes, fusion depths, and tile sizes for every benchmark
kernel; pallas_call runs in interpret mode (kernel body executed on CPU).
"""
import numpy as np
import pytest
import jax.numpy as jnp

from repro.configs import stencils
from repro.kernels import ops, ref

RNG = np.random.default_rng(42)


def make_arrays(spec, scale=1.0):
    out = {}
    for name, (dtype, shape) in spec.inputs.items():
        a = (RNG.standard_normal(shape) * scale).astype(dtype)
        out[name] = jnp.asarray(a)
    return out


def tol(dtype):
    # fp32 reassociation across fused iterations (HOTSPOT amplifies ~1.3x/iter)
    return dict(rtol=2e-4, atol=2e-4) if dtype == "float32" else dict(rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("name", list(stencils.BENCHMARKS))
@pytest.mark.parametrize("iters,s", [(1, 1), (3, 1), (4, 2), (5, 4)])
def test_pallas_matches_ref(name, iters, s):
    shape = (24, 6, 6) if name in stencils.BENCHMARKS_3D else (24, 17)
    spec = stencils.get(name, shape=shape, iterations=iters)
    arrays = make_arrays(spec)
    want = ref.stencil_iterations_ref(spec, arrays, iters)
    got = ops.stencil_run(
        spec, arrays, iters, s=s, tile_rows=8, backend="pallas"
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **tol(spec.dtype))


@pytest.mark.parametrize("name", ["jacobi2d", "hotspot", "dilate", "blur_jacobi2d"])
@pytest.mark.parametrize("shape", [(7, 5), (16, 16), (33, 9), (64, 128)])
def test_pallas_shape_sweep(name, shape):
    spec = stencils.get(name, shape=shape, iterations=2)
    arrays = make_arrays(spec)
    want = ref.stencil_iterations_ref(spec, arrays, 2)
    got = ops.stencil_run(spec, arrays, 2, s=2, tile_rows=8, backend="pallas")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **tol(spec.dtype))


@pytest.mark.parametrize("align", [1, 128])
def test_pallas_col_alignment(align):
    spec = stencils.jacobi2d(shape=(32, 50), iterations=3)
    arrays = make_arrays(spec)
    want = ref.stencil_iterations_ref(spec, arrays, 3)
    got = ops.stencil_run(
        spec, arrays, 3, s=3, tile_rows=16, backend="pallas", align_cols=align
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **tol(spec.dtype))


@pytest.mark.parametrize("name", list(stencils.BENCHMARKS))
@pytest.mark.parametrize("s", [1, 2, 4, 7])
def test_fused_jnp_matches_ref(name, s):
    shape = (20, 5, 7) if name in stencils.BENCHMARKS_3D else (20, 13)
    spec = stencils.get(name, shape=shape, iterations=7)
    arrays = make_arrays(spec)
    want = ref.stencil_iterations_ref(spec, arrays, 7)
    got = ops.stencil_run(spec, arrays, 7, s=s, backend="jnp")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **tol(spec.dtype))


def test_bfloat16_kernel():
    import repro.core.dsl as dsl
    spec = dsl.parse("""
kernel: J2D_BF16
iteration: 2
input bfloat16: x(16, 24)
output bfloat16: y(0,0) = (x(0,1) + x(1,0) + x(0,0) + x(0,-1) + x(-1,0)) / 5
""")
    arrays = {"x": jnp.asarray(RNG.standard_normal((16, 24)), dtype=jnp.bfloat16)}
    want = ref.stencil_iterations_ref(spec, arrays, 2).astype(jnp.float32)
    got = ops.stencil_run(spec, arrays, 2, s=2, tile_rows=8, backend="pallas")
    np.testing.assert_allclose(
        np.asarray(got, dtype=np.float32), np.asarray(want), rtol=3e-2, atol=3e-2
    )
