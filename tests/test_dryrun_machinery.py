"""Dry-run machinery gate: lower+compile a small arch on a small forced
mesh in a subprocess (the full 512-device sweep runs via
scripts/run_dryrun_cells.sh; this test keeps the machinery from rotting).
"""
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["REPRO_DRYRUN_DEVICES"] = "512"
import json
from repro.launch import dryrun

res = dryrun.lower_cell("mamba2_130m", "decode_32k", verbose=False)
assert res.status == "ok", res
rep = res.report
assert rep["fits"], rep["memory_per_chip"]
assert rep["compute_term"] > 0 and rep["memory_term"] > 0
res2 = dryrun.lower_cell("internlm2_1_8b", "decode_32k", multi_pod=True,
                         verbose=False)
assert res2.status == "ok", res2
print("DRYRUN_MACHINERY_OK")
"""


@pytest.mark.slow
def test_dryrun_lowers_and_compiles_small_cells():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = os.path.join(
        os.path.dirname(__file__), "..", "src")
    proc = subprocess.run(
        [sys.executable, "-c", SCRIPT], capture_output=True, text=True,
        timeout=1200, env=env)
    assert proc.returncode == 0, proc.stderr[-4000:]
    assert "DRYRUN_MACHINERY_OK" in proc.stdout


def test_guard_spec_and_plan_rules():
    """Pure-python guard logic (no devices needed)."""
    import numpy as np
    import jax
    from jax.sharding import PartitionSpec as P
    from repro.launch import sharding as shlib

    mesh = jax.sharding.Mesh(
        np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    # duplicate axes are deduped, first occurrence wins
    spec = shlib.guard_spec((8, 16, 32),
                            P("model", "data", "model"), mesh)
    assert spec == P("model", "data", None)
    # non-divisible dims fall back to replication
    mesh16 = jax.sharding.Mesh(
        np.array(jax.devices() * 1).reshape(1, 1), ("data", "model"))
    spec = shlib.guard_spec((7,), P("model"), mesh16)
    assert spec == P("model")  # axis size 1 divides everything

    plan = shlib.DEFAULT_PLAN
    assert plan.rule("expert") == "model"
    # embed carries FSDP over data AND pod (guard drops "pod" when absent)
    assert shlib.logical_to_spec(("expert", "embed", "mlp"), plan) == \
        ["model", ("data", "pod"), "model"]
