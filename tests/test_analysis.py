"""Static analyzer suite: footprint exactness, intervals, mutations.

Four layers, mirroring the analyses in :mod:`repro.core.analysis`:

  * **Footprint property test** — the inferred per-input tap bounding
    box must equal the *empirically measured* blast radius against the
    pure-numpy oracle from test_conformance.py: perturb one input cell
    with NaN (NaN survives every oracle op, so the blast is exactly the
    structural dependency set) and compare per-dim extremes.  Runs over
    the same 200 seed-pinned random specs as the conformance floor,
    plus a hypothesis layer over fresh seeds.
  * **Interval-domain division safety** — the regression matrix for the
    check_bucketable replacement: provably-safe kernels newly admitted
    (and served bucketed, bit-compared to the oracle), straddling-zero
    kernels still refused with the pinned message, fill-value widening
    across chained stages.
  * **Mutation corpus** — each seeded defect produces exactly the
    expected SASA code at the expected source span.
  * **Preflight parity** — candidate verdicts agree with
    ``distribute.build_runner``'s actual accept/refuse behavior, and
    ``autotune`` ranking is unchanged while infeasible candidates ride
    along as diagnostics.
"""
from __future__ import annotations

import dataclasses

import numpy as np
import pytest

import test_conformance
from repro.configs import stencils
from repro.core import analysis, dsl
from repro.core.analysis import (
    DIAGNOSTIC_CODES,
    Diagnostic,
    VerificationError,
    candidate_verdict,
    preflight,
)
from repro.core.autotune import autotune
from repro.core.distribute import build_runner
from repro.core.ir import lower
from repro.core.model import ParallelismConfig, choose_best
from repro.core.platform import DEFAULT_TPU
from repro.core.spec import Boundary, SourceSpan, ZERO_BOUNDARY
from repro.runtime import ShapeBucketer, build_bucket_runner, padded_request_shape
from repro.runtime.bucketing import check_bucketable, masked_spec, with_shape

# ---------------------------------------------------------------------------
# Footprint inference == empirical blast radius (NaN perturbation oracle)
# ---------------------------------------------------------------------------


def _measured_blast(spec, iterations, inp):
    """Per-dim (min, max) offsets of cells affected by poking ``inp``.

    The spec is re-declared on a grid large enough that the blast never
    reaches the boundary, with zero boundary so nothing wraps; one NaN
    is planted at the center of ``inp`` and the oracle's NaN output set
    is the exact dependency footprint (NaN survives +,-,*,/ by nonzero
    constants, abs, max, min and negation).
    """
    ext = 0
    for box in analysis.spec_footprint(spec, iterations).values():
        if box is not None:
            for lo, hi in box:
                ext = max(ext, -lo, hi)
    shape = tuple(2 * ext + 5 for _ in range(spec.ndim))
    big = dataclasses.replace(with_shape(spec, shape), boundary=ZERO_BOUNDARY)
    rng = np.random.default_rng(0)
    arrays = {
        n: rng.standard_normal(shape).astype(np.float32) for n in big.inputs
    }
    center = tuple(s // 2 for s in shape)
    arrays[inp] = arrays[inp].copy()
    arrays[inp][center] = np.nan
    out = test_conformance.numpy_oracle(big, arrays, iterations)
    idx = np.argwhere(np.isnan(np.asarray(out)))
    if idx.size == 0:
        return None
    return tuple(
        (int(idx[:, d].min() - center[d]), int(idx[:, d].max() - center[d]))
        for d in range(big.ndim)
    )


def _check_footprint_seed(seed: int) -> None:
    spec, _arrays, iterations = test_conformance.random_spec(seed)
    footprint = analysis.spec_footprint(spec, iterations)
    assert set(footprint) == set(spec.inputs)
    for inp, box in footprint.items():
        blast = _measured_blast(spec, iterations, inp)
        if box is None:
            assert blast is None, (seed, inp, blast)
        else:
            # output[c] reads input[c + o] for o in box, so the blast of
            # a poke at p spans [p - hi, p - lo] per dim — and the box
            # extremes are per-dim achievable (Minkowski extremes add),
            # so the match is exact, not just a bound.
            want = tuple((-hi, -lo) for lo, hi in box)
            assert blast == want, (seed, inp, blast, want)


@pytest.mark.parametrize("block", range(test_conformance.N_BLOCKS))
def test_footprint_matches_oracle_blast(block):
    """200 seed-pinned specs: inferred box == measured blast radius."""
    for seed in range(
        block * test_conformance.BLOCK, (block + 1) * test_conformance.BLOCK
    ):
        _check_footprint_seed(seed)


try:
    import hypothesis
    from hypothesis import given, settings, strategies as st

    @given(st.integers(min_value=1000, max_value=100_000))
    @settings(
        max_examples=25, deadline=None,
        suppress_health_check=list(hypothesis.HealthCheck),
    )
    def test_footprint_hypothesis(seed):
        _check_footprint_seed(seed)
except ImportError:  # pragma: no cover - hypothesis is a tier-1 dep
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_footprint_hypothesis():
        pass


def test_footprint_blur_jacobi2d_pinned():
    """Asymmetric two-stage kernel: exact composed box, per-dim slack."""
    spec = stencils.get("blur_jacobi2d", shape=(32, 16), iterations=3)
    assert analysis.spec_footprint(spec) == {"in": ((-6, 6), (-3, 9))}
    assert analysis.per_dim_radii(spec) == (2, 3)
    assert spec.radius == 3  # Chebyshev sum bounds the per-dim radii


def test_footprint_survives_lowering():
    """CSE/Let introduction must not change the inferred footprint."""
    for name in ("blur_jacobi2d", "seidel2d", "heat3d", "dilate"):
        spec = stencils.get(name, iterations=3)
        assert analysis.spec_footprint(lower(spec).spec) == \
            analysis.spec_footprint(spec), name


def test_required_margins_and_proof():
    spec = stencils.get("jacobi2d", shape=(16, 16), iterations=3)
    spec = dataclasses.replace(spec, boundary=Boundary("periodic"))
    assert analysis.required_margins(spec) == (3, 3)
    assert analysis.margin_diagnostics(spec, (3, 3)) == []
    diags = analysis.margin_diagnostics(spec, (2, 3))
    assert [d.code for d in diags] == ["SASA307"]
    assert diags[0].is_error and "dim 0" in diags[0].message
    # non-periodic modes re-impose the exterior in-kernel: no margin
    assert analysis.required_margins(
        stencils.get("jacobi2d", iterations=3)
    ) == (0, 0)


# ---------------------------------------------------------------------------
# Interval-domain division safety (the check_bucketable replacement)
# ---------------------------------------------------------------------------

DIV_BAD = """kernel: DIV-BAD
iteration: 1
input float: a(8, 8)
input float: b(8, 8)
output float: out(0, 0) = a(0, 0) / b(0, 1)
"""

DIV_SHIFTED = """kernel: DIV-SHIFTED
iteration: 1
input float: a(8, 8)
input float: b(8, 8)
output float: out(0, 0) = a(0, 0) / (b(0, 0) + 1.0)
"""

DIV_SAFE = """kernel: DIV-SAFE
iteration: 1
input float: a(8, 8)
input float: b(8, 8)
output float: out(0, 0) = a(0, 0) / (abs(b(0, 1)) + 2.0)
"""

DIV_CHAINED = """kernel: DIV-CHAINED
iteration: 1
input float: a(8, 8)
local float: t(0, 0) = abs(a(0, 0)) + 1.0
output float: out(0, 0) = a(0, 0) / t(0, 0)
"""


def test_division_still_refused():
    """The historically-refused kernels stay refused, message pinned."""
    for text in (DIV_BAD, DIV_SHIFTED):
        spec = dsl.parse(text)
        with pytest.raises(ValueError, match="divides by streamed data"):
            analysis.require_bucketable(spec)
        with pytest.raises(ValueError, match="cannot be shape-bucketed"):
            masked_spec(spec)


def test_division_provably_safe_admitted():
    """``x / (abs(y) + 2)``: syntactically refused before, now proven safe
    over intervals — and the bucketed runner matches the oracle."""
    spec = dsl.parse(DIV_SAFE)
    analysis.require_bucketable(spec)           # does not raise
    assert analysis.division_diagnostics(spec) == []
    masked_spec(spec)                           # bucket transforms accept it

    rng = np.random.default_rng(7)
    arrays = {
        n: rng.standard_normal(spec.shape).astype(np.float32)
        for n in spec.inputs
    }
    want = test_conformance.numpy_oracle(spec, arrays, 1)
    bucket = ShapeBucketer().bucket_for(
        padded_request_shape(spec, spec.shape, 1)
    )
    run = build_bucket_runner(
        spec, bucket, ParallelismConfig("temporal", k=1, s=1), tile_rows=8
    )
    got = run({n: a[None] for n, a in arrays.items()})[0]
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_division_fill_widening_across_stages():
    """A stage divisor proven nonzero on real data must also tolerate the
    mask fill the bucket weave writes onto its padding."""
    spec = dsl.parse(DIV_CHAINED)
    # zero fill: t's padding holds 0.0 -> the division is unsafe bucketed
    diags = analysis.division_diagnostics(spec, bucketed=True)
    assert [d.code for d in diags] == ["SASA301"]
    assert diags[0].is_error
    # exact-shape there is no fill: ``abs(a) + 1`` is proven nonzero
    assert analysis.division_diagnostics(spec, bucketed=False) == []
    # while a genuinely unbounded divisor is the author's runtime hazard
    # exact-shape: same code, demoted to a warning
    warn = analysis.division_diagnostics(dsl.parse(DIV_BAD), bucketed=False)
    assert [(d.code, d.severity) for d in warn] == [("SASA301", "warning")]
    # constant fill 1.5 keeps t's interval away from zero: proven safe
    const = dataclasses.replace(spec, boundary=Boundary("constant", 1.5))
    assert analysis.division_diagnostics(const, bucketed=True) == []


def test_check_bucketable_deprecated_shim():
    with pytest.warns(DeprecationWarning, match="require_bucketable"):
        check_bucketable(dsl.parse(DIV_SAFE))   # admitted, still warns
    with pytest.warns(DeprecationWarning):
        with pytest.raises(ValueError, match="divides by streamed data"):
            check_bucketable(dsl.parse(DIV_BAD))


def test_interval_arithmetic():
    I = analysis.Interval
    assert analysis._idiv(I(1, 2), I(2, 4)) == I(0.25, 1.0)
    assert analysis._idiv(I(1, 2), I(-1, 1)) == analysis.TOP
    assert analysis._iabs(I(-3, 2)) == I(0, 3)
    assert analysis._imul(I(0, 0), analysis.TOP) == I(0, 0)
    assert not analysis._iadd(analysis._iabs(analysis.TOP), I(2, 2)) \
        .contains_zero


# ---------------------------------------------------------------------------
# Mutation corpus: seeded defect -> expected code at the expected span
# ---------------------------------------------------------------------------

DEAD_STAGE = """kernel: DEAD-MUT
iteration: 1
input float: a(8, 8)
local float: unused(0, 0) = a(1, 0) + a(-1, 0)
output float: out(0, 0) = a(0, 0) * 2.0
"""

UNUSED_INPUT = """kernel: UNUSED-MUT
iteration: 1
iterate: a
input float: a(8, 8)
input float: b(8, 8)
output float: out(0, 0) = a(0, 0) + 1.0
"""

DEAD_ITERATE = """kernel: ITER-MUT
iteration: 3
iterate: a
input float: a(8, 8)
input float: b(8, 8)
output float: out(0, 0) = b(0, 0) * 2.0
"""

INVARIANT_SUBEXPR = """kernel: INV-MUT
iteration: 3
iterate: a
input float: a(8, 8)
input float: b(8, 8)
output float: out(0, 0) = a(0, 0) + (b(0, 0) * 2.0 + b(1, 0))
"""

# -- certified-numerics seeded defects (repro.core.numerics) --------------

OVERFLOW_MUT = """kernel: OVF-MUT
iteration: 1
input float: a(8, 8)
output float: out(0, 0) = a(0, 0) * 1e38 * 8.0
"""

CANCEL_MUT = """kernel: CANCEL-MUT
iteration: 1
input float: a(8, 8)
output float: out(0, 0) = (a(0, 0) + 100000000.0) - 100000000.0
"""

DIVAMP_MUT = """kernel: DIVAMP-MUT
iteration: 1
input float: a(8, 8)
input float: b(8, 8)
output float: out(0, 0) = a(0, 0) / (abs(b(0, 0)) + 0.0009)
"""

BLOWUP_MUT = """kernel: BLOWUP-MUT
iteration: 4096
iterate: a
input float: a(8, 8)
output float: out(0, 0) = (a(0, -1) + a(0, 1) + a(-1, 0) + a(1, 0) \
+ a(0, 0)) / 5.0
"""

MUTATIONS = [
    # (source, expected code, severity, (line, col))
    (DIV_BAD, "SASA301", "error", (5, 27)),
    (OVERFLOW_MUT, "SASA501", "warning", (4, 27)),
    (CANCEL_MUT, "SASA502", "warning", (4, 27)),
    (DIVAMP_MUT, "SASA503", "warning", (5, 27)),
    (BLOWUP_MUT, "SASA510", "warning", (5, 15)),
    (DEAD_STAGE, "SASA210", "warning", (4, 14)),
    (UNUSED_INPUT, "SASA211", "warning", None),
    (DEAD_ITERATE, "SASA402", "warning", (6, 15)),
    (INVARIANT_SUBEXPR, "SASA403", "warning", (6, 38)),
    ("kernel: X\nflibber\n", "SASA104", "error", (2, 1)),
    ("kernel: X\niteration: nope\n", "SASA105", "error", (2, 12)),
    (
        "kernel: X\niteration: 1\ninput float: a(8, 8)\n"
        "output float: out(0, 0) = a(0, 0) $ 2.0\n",
        "SASA101", "error", (4, 35),
    ),
    (
        "kernel: X\niteration: 1\ninput float: a(8, 8)\n"
        "output float: out(0, 0) = a(0, 0)\n"
        "output float: out(0, 0) = a(0, 0)\n",
        "SASA107", "error", (5, 15),
    ),
    ("kernel: X\niteration: 1\ninput float: a(8, 8)\n",
     "SASA106", "error", (1, 1)),
]


@pytest.mark.parametrize(
    "text,code,severity,loc", MUTATIONS, ids=[m[1] for m in MUTATIONS]
)
def test_mutation_corpus(text, code, severity, loc):
    _, diags = analysis.lint_text(text)
    hits = [d for d in diags if d.code == code]
    assert hits, (code, [d.code for d in diags])
    d = hits[0]
    assert d.severity == severity
    if loc is None:
        assert d.span is None
    else:
        assert (d.span.line, d.span.col) == loc, d.format(text)
        # the caret rendering points into the real source line
        assert text.splitlines()[d.span.line - 1] in d.format(text)


def test_margin_mutation_is_error():
    """Undersizing the bucket margin is the SASA307 error (the margin
    the real bucket layer reserves always passes the proof)."""
    spec = stencils.get("heat3d_periodic", iterations=2)
    need = analysis.required_margins(spec, iterations=2)
    assert analysis.margin_diagnostics(spec, need, iterations=2) == []
    short = tuple(m - 1 for m in need)
    diags = analysis.margin_diagnostics(spec, short, iterations=2)
    assert diags and all(
        d.code == "SASA307" and d.is_error for d in diags
    )


def test_diagnostic_registry_and_sorting():
    for code, doc in DIAGNOSTIC_CODES.items():
        assert code.startswith("SASA") and len(code) == 7 and doc
    with pytest.raises(AssertionError):
        Diagnostic("SASA999", "error", "unregistered code")
    with pytest.raises(AssertionError):
        Diagnostic("SASA301", "fatal", "unknown severity")
    d1 = Diagnostic("SASA210", "warning", "w", span=SourceSpan(1, 1, 1))
    d2 = Diagnostic("SASA301", "error", "e", span=SourceSpan(9, 1, 1))
    assert analysis.sort_diagnostics([d1, d2]) == [d2, d1]


def test_stock_kernels_verify_clean():
    """Every stock kernel x all four boundary modes: zero diagnostics."""
    shapes = {2: (64, 32), 3: (32, 16, 16)}
    for name, fn in stencils.BENCHMARKS.items():
        base = fn(iterations=4)
        spec = fn(shape=shapes[base.ndim], iterations=4)
        for boundary in test_conformance.BOUNDARIES:
            sp = dataclasses.replace(spec, boundary=boundary)
            assert analysis.verify(sp) == [], (name, boundary.kind)


# ---------------------------------------------------------------------------
# Feasibility preflight: parity with build_runner, autotune integration
# ---------------------------------------------------------------------------


def test_preflight_static_codes():
    """Every build_runner refusal class is predicted, with its code."""
    spec = stencils.get("jacobi2d", shape=(30, 8), iterations=3)

    periodic = dataclasses.replace(spec, boundary=Boundary("periodic"))
    v = candidate_verdict(periodic, ParallelismConfig("spatial_s", k=4), 8)
    assert (v.feasible, v.code, v.k) == (False, "SASA302", 4)

    replicate = dataclasses.replace(
        with_shape(spec, (4, 8)), boundary=Boundary("replicate")
    )
    v = candidate_verdict(replicate, ParallelismConfig("spatial_s", k=8), 8)
    assert (v.feasible, v.code) == (False, "SASA303")

    tall = stencils.get("jacobi2d", shape=(16, 8), iterations=3)
    v = candidate_verdict(tall, ParallelismConfig("spatial_r", k=8), 8)
    assert (v.feasible, v.code) == (False, "SASA305")
    # spatial_s streams fresh halos every round: same k is fine
    assert candidate_verdict(
        tall, ParallelismConfig("spatial_s", k=8), 8
    ).feasible

    wrapped = masked_spec(periodic, wrap_rounds=1)
    assert wrapped.wrap_index_inputs
    v = candidate_verdict(wrapped, ParallelismConfig("spatial_s", k=2), 8)
    assert (v.feasible, v.code) == (False, "SASA304")
    # temporal is single-device: immune to every shard guard but wrap
    assert candidate_verdict(
        periodic, ParallelismConfig("temporal", s=4), 8
    ).feasible

    # k is clamped to the pool exactly like build_runner's device slice
    assert candidate_verdict(
        periodic, ParallelismConfig("spatial_s", k=4), 1
    ).feasible
    # batched single-device candidates bypass build_runner entirely
    assert candidate_verdict(
        wrapped, ParallelismConfig("spatial_s", k=2), 1, batched=True
    ).feasible
    verdicts = preflight(
        periodic,
        [ParallelismConfig("spatial_s", k=4), ParallelismConfig("temporal")],
        8,
    )
    assert [v.feasible for v in verdicts] == [False, True]
    assert verdicts[0].diagnostic().code == "SASA302"
    assert verdicts[1].diagnostic() is None


def test_preflight_matches_build_runner():
    """On the real device pool: predicted-infeasible candidates raise in
    build_runner, predicted-feasible ones build."""
    import jax

    n = len(jax.devices())
    cases = [
        (stencils.get("jacobi2d", shape=(4, 8), iterations=8),
         ParallelismConfig("spatial_r", k=1)),
        (stencils.get("jacobi2d", shape=(16, 8), iterations=2),
         ParallelismConfig("spatial_s", k=4)),
        (stencils.get("jacobi2d", shape=(16, 8), iterations=2),
         ParallelismConfig("temporal", s=2)),
        (masked_spec(
            dataclasses.replace(
                stencils.get("jacobi2d", shape=(16, 8), iterations=2),
                boundary=Boundary("periodic"),
            ), wrap_rounds=1,
        ), ParallelismConfig("spatial_s", k=2)),
    ]
    for spec, cfg in cases:
        v = candidate_verdict(spec, cfg, n)
        if v.feasible:
            assert callable(build_runner(spec, cfg))
        else:
            with pytest.raises(ValueError):
                build_runner(spec, cfg)


def test_autotune_ranking_unchanged_with_diagnostics():
    """The verdict table must not perturb the ranking; infeasible
    candidates surface as info diagnostics instead of silent retries."""
    spec = stencils.get("jacobi2d", shape=(32, 16), iterations=2)
    td = autotune(spec, platform=DEFAULT_TPU, iterations=2, build=False)
    want = choose_best(spec, DEFAULT_TPU, iterations=2)
    assert [p.config for p in td.ranking] == [p.config for p in want]
    assert isinstance(td.diagnostics, tuple)
    assert all(d.severity == "info" for d in td.diagnostics)

    # periodic rows not divisible by the forced spatial degree: every
    # spatial candidate becomes infeasible on this pool and is reported
    # as a verdict diagnostic, not rediscovered via ValueError
    import jax

    periodic = dataclasses.replace(
        stencils.get("jacobi2d", shape=(30, 8), iterations=2),
        boundary=Boundary("periodic"),
    )
    td = autotune(periodic, platform=DEFAULT_TPU, iterations=2, build=False,
                  devices=list(jax.devices()) * 4)
    assert any(d.code == "SASA302" for d in td.diagnostics)
    assert all(d.severity == "info" for d in td.diagnostics)
    ranked = [p.config for p in choose_best(periodic, DEFAULT_TPU,
                                            iterations=2)]
    assert [p.config for p in td.ranking] == ranked


def test_autotune_and_parse_strict():
    with pytest.raises(VerificationError):
        autotune(DIV_BAD, platform=DEFAULT_TPU, build=False, strict=True)
    td = autotune(DIV_BAD, platform=DEFAULT_TPU, build=False)  # non-strict
    assert td.ranking
    with pytest.raises(VerificationError) as ei:
        dsl.parse(DIV_BAD, strict=True)
    assert any(d.code == "SASA301" for d in ei.value.diagnostics)
    assert dsl.parse(DIV_BAD).name == "DIV-BAD"  # default stays lenient


def test_verify_platform_sasa306():
    """A spec every candidate refuses is the SASA306 error."""
    spec = masked_spec(
        dataclasses.replace(
            stencils.get("jacobi2d", shape=(16, 8), iterations=2),
            boundary=Boundary("periodic"),
        ), wrap_rounds=1,
    )
    diags = analysis.verify(spec, platform=DEFAULT_TPU, iterations=2,
                            n_devices=8)
    codes = {d.code for d in diags}
    # every ranked candidate is multi-shard-hostile here (wrap margin)
    if any(d.code == "SASA306" for d in diags):
        assert any(d.code == "SASA304" for d in diags)
    else:
        # a single-device candidate in the ranking keeps it feasible
        assert "SASA304" in codes or not codes


def test_verification_error_formatting():
    spec = dsl.parse(DIV_BAD)
    with pytest.raises(VerificationError) as ei:
        analysis.verify_or_raise(spec, source=DIV_BAD)
    msg = str(ei.value)
    assert "SASA301" in msg and "5:27" in msg
    assert "out(0, 0) = a(0, 0) / b(0, 1)" in msg  # source line rendered
    assert ei.value.diagnostics


# ---------------------------------------------------------------------------
# DSL spans: located syntax errors, equality modulo location
# ---------------------------------------------------------------------------


def test_dsl_syntax_error_attributes():
    with pytest.raises(dsl.DSLSyntaxError) as ei:
        dsl.parse("kernel: X\niteration: nope\n")
    e = ei.value
    assert (e.code, e.lineno, e.col) == ("SASA105", 2, 12)
    assert e.span == SourceSpan(2, 12, 12)
    assert "line 2" in str(e)
    assert isinstance(e, SyntaxError)  # pre-analyzer callers keep working


def test_spans_excluded_from_equality():
    from repro.core.spec import Ref, Stage, StencilSpec

    hand = StencilSpec(
        name="SPAN-EQ", iterations=1,
        inputs={"a": ("float32", (8, 8))},
        stages=(Stage("out", "float32", Ref("a", (0, 1)), True),),
        iterate_input="a",
    )
    text = dsl.format_spec(hand)
    parsed = dsl.parse(text)
    assert parsed == hand                       # round-trip identity
    assert parsed.output_stage.expr.span is not None
    assert hand.output_stage.expr.span is None  # hand-built: no spans
    # shifting the source (different spans) still compares equal
    assert dsl.parse("# shifted\n" + text) == parsed
