"""EP shard_map MoE vs jit-level MoE equivalence, on 8 forced host devices.

Run as a subprocess (pytest wrapper in test_distribute.py-style):
    python tests/_ep_moe_main.py
"""
import os
import sys

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 "
    + os.environ.get("XLA_FLAGS", ""))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.models import mixers  # noqa: E402


def main():
    assert jax.device_count() == 8
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    key = jax.random.PRNGKey(0)
    B, S, D = 4, 16, 32
    E_real, E_pad, k = 6, 8, 2
    p, _ = mixers.moe_init(key, D, n_experts=E_real, d_ff_expert=64,
                           top_k=k, n_shared=1, d_ff_shared=64,
                           n_experts_padded=E_pad)
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, D), jnp.float32)

    # dense single-logical-device reference (dropless so no capacity noise)
    want = mixers.moe_apply(x, p, top_k=k, dropless=True,
                            n_experts_real=E_real)
    got = mixers.moe_apply_ep(x, p, top_k=k, mesh=mesh,
                              batch_axes=("data",), dropless=True,
                              n_experts_real=E_real)
    err = float(jnp.abs(want - got).max())
    print("max err dropless:", err)
    assert err < 2e-4, err

    # capacity mode: both paths drop the same tokens (same order/cap rule)
    # -> compare only that outputs are finite and close in aggregate
    w2 = mixers.moe_apply(x, p, top_k=k, capacity_factor=8.0,
                          n_experts_real=E_real)
    g2 = mixers.moe_apply_ep(x, p, top_k=k, mesh=mesh, batch_axes=("data",),
                             capacity_factor=8.0, n_experts_real=E_real)
    assert bool(jnp.isfinite(g2).all())
    # generous capacity -> no drops in either path -> exact match
    err2 = float(jnp.abs(w2 - g2).max())
    print("max err capacity8:", err2)
    assert err2 < 2e-4, err2

    # gradients flow through the EP path
    def loss(px):
        return jnp.sum(mixers.moe_apply_ep(
            x, px, top_k=k, mesh=mesh, batch_axes=("data",), dropless=True,
            n_experts_real=E_real) ** 2)
    g = jax.grad(loss)(p)
    gn = sum(float(jnp.abs(v).sum()) for v in jax.tree.leaves(g))
    print("grad norm:", gn)
    assert np.isfinite(gn) and gn > 0
    # padding experts receive zero routing gradient
    wi_pad_grad = float(jnp.abs(g["wi"][E_real:]).max())
    print("pad expert grad:", wi_pad_grad)
    assert wi_pad_grad == 0.0

    print("EP_MOE_OK")


if __name__ == "__main__":
    main()
