"""DSL parser tests, including the paper's own listings 2-4, the
``boundary:`` header, spec validation, and the format_spec round trip."""
import dataclasses

import pytest

from repro.configs import stencils
from repro.core import dsl
from repro.core.spec import BinOp, Boundary, Call, Num

LISTING2 = """
kernel: JACOBI2D
iteration: 4
input float: in_1(9720, 1024)
output float: out_1(0,0) = ( in_1(0,1) + in_1(1,0) + in_1(0,0) + in_1(0,-1) + in_1(-1,0) ) / 5
"""

LISTING3 = """
kernel: HOTSPOT
iteration: 64
input float: in_1(9720, 1024)
input float: in_2(9720, 1024)
output float: out_1(0,0) = 1.296 * ((in_2(-1,0) + in_2(1,0) - in_2(0,0) + in_2(0,0)) * 0.949219
    + in_1(-1,0) + (in_2(0,-1) + in_2(0,1) - in_2(0,0) + in_2(0,0)) * 0.010535
    + (80 - in_2(0,0)) * 0.00000514403)
"""

LISTING4 = """
kernel: BLUR-JACOBI2D
iteration: 4
input float: in(9720, 1024)
local float: temp(0,0) = (in(-1,0) + in(-1,1) + in(-1,2) + in(0,0) + in(0,1) + in(0,2) + in(1,0) + in(1,1) + in(1,2)) / 9
output float: out(0,0) = (temp(0,1) + temp(1,0) + temp(0,0) + temp(0,-1) + temp(-1,0)) / 5
"""


def test_listing2_jacobi2d():
    spec = dsl.parse(LISTING2)
    assert spec.name == "JACOBI2D"
    assert spec.iterations == 4
    assert spec.shape == (9720, 1024)
    assert spec.radius == 1 and spec.halo == 2
    assert spec.iterate_input == "in_1"
    assert spec.points == 5
    assert isinstance(spec.output_stage.expr, BinOp)
    assert spec.output_stage.expr.op == "/"


def test_listing3_hotspot_two_inputs():
    spec = dsl.parse(LISTING3)
    assert spec.num_inputs == 2
    assert spec.iterate_input == "in_2"  # default: last declared input
    assert spec.iterations == 64
    refs = {r.name for s in spec.stages for r in
            __import__("repro.core.spec", fromlist=["refs_in"]).refs_in(s.expr)}
    assert refs == {"in_1", "in_2"}


def test_listing4_two_loops_local():
    spec = dsl.parse(LISTING4)
    assert spec.name == "BLUR-JACOBI2D"
    assert len(spec.stages) == 2
    assert not spec.stages[0].is_output and spec.stages[1].is_output
    # composite radius: blur reaches offset 2, jacobi adds 1
    assert spec.stages[0].radius == 2 and spec.stages[1].radius == 1
    assert spec.radius == 3


def test_3d_and_intrinsics():
    spec = dsl.parse("""
kernel: T3D
iteration: 2
input float: x(16, 8, 8)
output float: y(0,0,0) = max(x(0,0,0), x(1,0,0), abs(x(-1,0,0)))
""")
    assert spec.ndim == 3
    assert spec.cols_flat == 64
    assert isinstance(spec.output_stage.expr, Call)


def test_iterate_directive():
    spec = dsl.parse("""
kernel: K
iteration: 2
iterate: a
input float: a(8, 8)
input float: b(8, 8)
output float: o(0,0) = a(0,0) + b(0,0)
""")
    assert spec.iterate_input == "a"


@pytest.mark.parametrize("bad", [
    "iteration: 4",                                     # no kernel
    "kernel: K\ninput float: a(8,8)",                   # no output
    "kernel: K\ninput float: a(8,8)\noutput float: o(0,0) = q(0,0)",  # unknown ref
    "kernel: K\ninput float: a(8,8)\noutput float: o(0) = a(0,0)",    # arity
])
def test_rejects_malformed(bad):
    with pytest.raises((SyntaxError, ValueError)):
        dsl.parse(bad)


BOUNDARY_TEMPLATE = """
kernel: K
iteration: 2
{header}
input float: a(8, 8)
output float: o(0,0) = a(0,1) + a(1,0)
"""


@pytest.mark.parametrize("header,want", [
    ("", Boundary("zero")),
    ("boundary: zero", Boundary("zero")),
    ("boundary: constant 1.5", Boundary("constant", 1.5)),
    ("boundary: constant -2", Boundary("constant", -2.0)),
    ("boundary: replicate", Boundary("replicate")),
    ("boundary: periodic", Boundary("periodic")),
])
def test_boundary_header(header, want):
    spec = dsl.parse(BOUNDARY_TEMPLATE.format(header=header))
    assert spec.boundary == want


@pytest.mark.parametrize("header,msg", [
    ("boundary: wavy", "unknown boundary"),
    ("boundary: constant", "exactly one value"),
    ("boundary: constant x", "must be a number"),
    ("boundary: constant 1 2", "exactly one value"),
    ("boundary: periodic 3", "takes no value"),
    ("boundary: replicate zero", "takes no value"),
])
def test_boundary_header_errors(header, msg):
    with pytest.raises(SyntaxError, match=msg):
        dsl.parse(BOUNDARY_TEMPLATE.format(header=header))


@pytest.mark.parametrize("bad,msg", [
    ("iteration: 0", "must be >= 1"),
    ("iteration: -3", "must be >= 1"),
    ("iteration: many", "must be an integer"),
])
def test_rejects_bad_iteration_counts(bad, msg):
    with pytest.raises(SyntaxError, match=msg):
        dsl.parse(f"kernel: K\n{bad}\ninput float: a(8,8)\n"
                  "output float: o(0,0) = a(0,0)")


def test_rejects_duplicate_input_declaration():
    """The inputs dict used to silently overwrite the first declaration."""
    with pytest.raises(SyntaxError, match="duplicate input"):
        dsl.parse("""
kernel: K
input float: a(8, 8)
input float: a(16, 16)
output float: o(0,0) = a(0,0)
""")


def test_rejects_stage_shadowing_input():
    with pytest.raises(SyntaxError, match="shadows the input"):
        dsl.parse("""
kernel: K
input float: a(8, 8)
local float: a(0,0) = a(0,0) * 2
output float: o(0,0) = a(0,0)
""")


def test_rejects_duplicate_stage():
    with pytest.raises(SyntaxError, match="duplicate stage"):
        dsl.parse("""
kernel: K
input float: a(8, 8)
local float: t(0,0) = a(0,0)
local float: t(0,0) = a(0,1)
output float: o(0,0) = t(0,0)
""")


# ---------------------------------------------------------------------------
# format_spec round trip
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", sorted(stencils.BENCHMARKS))
def test_format_spec_roundtrip_identity(name):
    """parse(format_spec(spec)) is structurally identical for every stock
    kernel (boundary declarations included)."""
    shape = (32, 8, 8) if name in stencils.BENCHMARKS_3D else (32, 16)
    spec = stencils.get(name, shape=shape, iterations=3)
    assert dsl.parse(dsl.format_spec(spec)) == spec


@pytest.mark.parametrize("boundary", [
    Boundary("zero"), Boundary("constant", -0.25), Boundary("replicate"),
    Boundary("periodic"),
], ids=lambda b: b.kind)
def test_format_spec_roundtrip_all_boundaries(boundary):
    spec = dataclasses.replace(
        stencils.hotspot(shape=(16, 8), iterations=2), boundary=boundary
    )
    again = dsl.parse(dsl.format_spec(spec))
    assert again == spec
    assert again.boundary == boundary


def test_format_spec_inlines_lowered_lets():
    """A CSE'd spec prints as plain DSL (Let has no surface syntax) and
    re-parses to the same semantics, pre-CSE."""
    from repro.core.ir import lower

    spec = stencils.heat3d(shape=(16, 6, 6), iterations=2)
    low = lower(spec).spec
    again = dsl.parse(dsl.format_spec(low))
    # the reparsed spec is the un-CSE'd tree: same taps, more ops
    assert again.radius == low.radius
    assert again.ops_per_cell >= low.ops_per_cell
    assert lower(again).spec.ops_per_cell == low.ops_per_cell


def test_scientific_notation_constants():
    spec = dsl.parse("""
kernel: SCI
iteration: 1
input float: a(8, 8)
output float: o(0,0) = a(0,0) * 5.14403e-6 + 1e2
""")
    nums = [n.value for s in spec.stages
            for n in __import__("repro.core.spec", fromlist=["walk"]).walk(s.expr)
            if isinstance(n, Num)]
    assert 5.14403e-6 in nums and 100.0 in nums
