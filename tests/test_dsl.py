"""DSL parser tests, including the paper's own listings 2-4."""
import pytest

from repro.core import dsl
from repro.core.spec import BinOp, Call, Num, Ref

LISTING2 = """
kernel: JACOBI2D
iteration: 4
input float: in_1(9720, 1024)
output float: out_1(0,0) = ( in_1(0,1) + in_1(1,0) + in_1(0,0) + in_1(0,-1) + in_1(-1,0) ) / 5
"""

LISTING3 = """
kernel: HOTSPOT
iteration: 64
input float: in_1(9720, 1024)
input float: in_2(9720, 1024)
output float: out_1(0,0) = 1.296 * ((in_2(-1,0) + in_2(1,0) - in_2(0,0) + in_2(0,0)) * 0.949219
    + in_1(-1,0) + (in_2(0,-1) + in_2(0,1) - in_2(0,0) + in_2(0,0)) * 0.010535
    + (80 - in_2(0,0)) * 0.00000514403)
"""

LISTING4 = """
kernel: BLUR-JACOBI2D
iteration: 4
input float: in(9720, 1024)
local float: temp(0,0) = (in(-1,0) + in(-1,1) + in(-1,2) + in(0,0) + in(0,1) + in(0,2) + in(1,0) + in(1,1) + in(1,2)) / 9
output float: out(0,0) = (temp(0,1) + temp(1,0) + temp(0,0) + temp(0,-1) + temp(-1,0)) / 5
"""


def test_listing2_jacobi2d():
    spec = dsl.parse(LISTING2)
    assert spec.name == "JACOBI2D"
    assert spec.iterations == 4
    assert spec.shape == (9720, 1024)
    assert spec.radius == 1 and spec.halo == 2
    assert spec.iterate_input == "in_1"
    assert spec.points == 5
    assert isinstance(spec.output_stage.expr, BinOp)
    assert spec.output_stage.expr.op == "/"


def test_listing3_hotspot_two_inputs():
    spec = dsl.parse(LISTING3)
    assert spec.num_inputs == 2
    assert spec.iterate_input == "in_2"  # default: last declared input
    assert spec.iterations == 64
    refs = {r.name for s in spec.stages for r in
            __import__("repro.core.spec", fromlist=["refs_in"]).refs_in(s.expr)}
    assert refs == {"in_1", "in_2"}


def test_listing4_two_loops_local():
    spec = dsl.parse(LISTING4)
    assert spec.name == "BLUR-JACOBI2D"
    assert len(spec.stages) == 2
    assert not spec.stages[0].is_output and spec.stages[1].is_output
    # composite radius: blur reaches offset 2, jacobi adds 1
    assert spec.stages[0].radius == 2 and spec.stages[1].radius == 1
    assert spec.radius == 3


def test_3d_and_intrinsics():
    spec = dsl.parse("""
kernel: T3D
iteration: 2
input float: x(16, 8, 8)
output float: y(0,0,0) = max(x(0,0,0), x(1,0,0), abs(x(-1,0,0)))
""")
    assert spec.ndim == 3
    assert spec.cols_flat == 64
    assert isinstance(spec.output_stage.expr, Call)


def test_iterate_directive():
    spec = dsl.parse("""
kernel: K
iteration: 2
iterate: a
input float: a(8, 8)
input float: b(8, 8)
output float: o(0,0) = a(0,0) + b(0,0)
""")
    assert spec.iterate_input == "a"


@pytest.mark.parametrize("bad", [
    "iteration: 4",                                     # no kernel
    "kernel: K\ninput float: a(8,8)",                   # no output
    "kernel: K\ninput float: a(8,8)\noutput float: o(0,0) = q(0,0)",  # unknown ref
    "kernel: K\ninput float: a(8,8)\noutput float: o(0) = a(0,0)",    # arity
])
def test_rejects_malformed(bad):
    with pytest.raises((SyntaxError, ValueError)):
        dsl.parse(bad)


def test_scientific_notation_constants():
    spec = dsl.parse("""
kernel: SCI
iteration: 1
input float: a(8, 8)
output float: o(0,0) = a(0,0) * 5.14403e-6 + 1e2
""")
    nums = [n.value for s in spec.stages
            for n in __import__("repro.core.spec", fromlist=["walk"]).walk(s.expr)
            if isinstance(n, Num)]
    assert 5.14403e-6 in nums and 100.0 in nums
