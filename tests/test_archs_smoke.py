"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, asserting output shapes + finite values; plus prefill+decode
consistency against the full forward for every block family."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import base
from repro.models.model_zoo import build_model


def make_batch(cfg, B=2, S=24, key=0):
    k = jax.random.PRNGKey(key)
    batch = {
        "tokens": jax.random.randint(k, (B, S), 0, cfg.vocab),
        "labels": jax.random.randint(jax.random.fold_in(k, 1), (B, S), 0,
                                     cfg.vocab),
    }
    if cfg.frontend:
        n = cfg.n_frontend_tokens or 8
        batch["frontend_embeds"] = 0.1 * jax.random.normal(
            jax.random.fold_in(k, 2), (B, n, cfg.frontend_dim))
    return batch


@pytest.mark.parametrize("arch", base.all_archs())
def test_reduced_train_step(arch):
    cfg = base.get(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg)
    loss, grads = jax.value_and_grad(model.loss)(params, batch)
    assert np.isfinite(float(loss))
    gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                         for g in jax.tree.leaves(grads)))
    assert np.isfinite(float(gnorm)) and float(gnorm) > 0


@pytest.mark.parametrize("arch", base.all_archs())
def test_reduced_forward_shapes(arch):
    cfg = base.get(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, B=2, S=16)
    logits, caches = model.prefill(params, batch)
    assert logits.shape == (2, cfg.vocab)
    assert np.isfinite(np.asarray(logits, dtype=np.float32)).all()


@pytest.mark.parametrize("arch", [
    "granite_3_2b",            # dense GQA
    "recurrentgemma_2b",       # RG-LRU + local attention
    "mamba2_130m",             # SSD
    "qwen2_moe_a2_7b",         # MoE (qkv bias)
])
def test_prefill_decode_matches_full_forward(arch):
    """logits from [prefill(S) ; decode x2] must equal full forward logits."""
    cfg = base.get(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    B, S, extra = 2, 16, 2
    batch = make_batch(cfg, B=B, S=S + extra, key=3)
    tokens = batch["tokens"]

    # full forward logits at each position via prefill of increasing length
    full_logits, _ = model.prefill(params, {"tokens": tokens})

    # prefill first S, then decode the remaining tokens step by step
    logits, caches = model.prefill(params, {"tokens": tokens[:, :S]})
    # grow caches to capacity S+extra for the attention layers
    cap_caches = model.init_cache(B, S + extra, dtype=cfg.act_dtype)

    def graft(cap, got):
        if cap is None or got is None:
            return got
        def leafmerge(c, g):
            if c.shape == g.shape:
                return g
            pad = [(0, cs - gs) for cs, gs in zip(c.shape, g.shape)]
            return jnp.pad(g, pad, constant_values=(-1 if g.dtype == jnp.int32
                                                    else 0))
        return jax.tree.map(leafmerge, cap, got)

    caches = graft(cap_caches, caches)
    last = None
    for t in range(extra):
        pos = jnp.full((B,), S + t, jnp.int32)
        last, caches = model.decode_step(params, tokens[:, S + t:S + t + 1],
                                         caches, pos)
    np.testing.assert_allclose(
        np.asarray(last, np.float32), np.asarray(full_logits, np.float32),
        rtol=2e-2, atol=2e-2,
    )


def test_encdec_decode_with_cross_attention():
    cfg = base.get("seamless_m4t_medium").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(2))
    B, S = 2, 12
    batch = make_batch(cfg, B=B, S=S)
    # encoder output computed once; decoder prefill + one decode step
    x, fe = model._embed_inputs(params, batch)
    enc_out, enc_pos = model._encode(params, fe)
    assert enc_out.shape == (B, fe.shape[1], cfg.d_model)
    logits, caches = model.prefill(params, batch)
    cap = model.init_cache(B, S + 1, dtype=cfg.act_dtype)
    caches = jax.tree.map(
        lambda c, g: g if c.shape == g.shape else jnp.pad(
            g, [(0, cs - gs) for cs, gs in zip(c.shape, g.shape)],
            constant_values=(-1 if g.dtype == jnp.int32 else 0)),
        cap, caches)
    pos = jnp.full((B,), S, jnp.int32)
    last, _ = model.decode_step(params, batch["tokens"][:, -1:], caches, pos,
                                enc_out=enc_out, enc_positions=enc_pos)
    assert last.shape == (B, cfg.vocab)
    assert np.isfinite(np.asarray(last, np.float32)).all()


def test_local_attention_equals_full_when_window_covers_seq():
    """Sliding-window attention (the 1-D stencil) == full attention when
    the window is at least the sequence length."""
    from repro.models import layers as L
    k = jax.random.PRNGKey(0)
    B, S, H, D = 2, 24, 4, 16
    q = jax.random.normal(k, (B, S, H, D))
    kk = jax.random.normal(jax.random.fold_in(k, 1), (B, S, 2, D))
    v = jax.random.normal(jax.random.fold_in(k, 2), (B, S, 2, D))
    full = L.attention_chunked(q, kk, v, causal=True, kv_block=8)
    local = L.local_attention_banded(q, kk, v, window=S)
    np.testing.assert_allclose(np.asarray(local), np.asarray(full),
                               rtol=2e-4, atol=2e-4)


def test_local_attention_matches_masked_full():
    from repro.models import layers as L
    k = jax.random.PRNGKey(3)
    B, S, H, D, W = 1, 40, 2, 8, 8
    q = jax.random.normal(k, (B, S, H, D))
    kk = jax.random.normal(jax.random.fold_in(k, 1), (B, S, H, D))
    v = jax.random.normal(jax.random.fold_in(k, 2), (B, S, H, D))
    want = L.attention_chunked(q, kk, v, causal=True, kv_block=16, window=W)
    got = L.local_attention_banded(q, kk, v, window=W)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)
