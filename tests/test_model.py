"""Analytical model tests: paper-exact FPGA model (Eqs. 1-9) + TPU model."""
import math

import pytest

from repro.configs import stencils
from repro.core import model
from repro.core.model import ParallelismConfig
from repro.core.platform import DEFAULT_FPGA, DEFAULT_TPU

# Paper-reported resource-bound PE counts (Figs. 18-20, column size 1024)
PAPER_PE = {
    "jacobi2d": 21, "jacobi3d": 15, "blur": 12, "seidel2d": 12,
    "dilate": 18, "hotspot": 9, "heat3d": 12, "sobel2d": 12,
}
# Paper Table 3: best parallelism at iteration=64, input 9720x1024
PAPER_TABLE3_IT64 = {
    "jacobi2d": ("hybrid_s", 3, 7), "jacobi3d": ("hybrid_s", 3, 5),
    "blur": ("hybrid_s", 3, 4), "seidel2d": ("hybrid_s", 3, 4),
    "dilate": ("hybrid_s", 3, 6), "hotspot": ("hybrid_s", 3, 3),
    "heat3d": ("hybrid_s", 3, 4), "sobel2d": ("hybrid_s", 3, 4),
}


def _spec(name, it):
    shape = (9720, 32, 32) if name in stencils.BENCHMARKS_3D else (9720, 1024)
    return stencils.get(name, shape=shape, iterations=it)


@pytest.mark.parametrize("name", sorted(PAPER_TABLE3_IT64))
def test_reproduces_paper_table3_iter64(name):
    """With the paper's synthesizer PE counts, Eq. 9 reproduces Table 3."""
    spec = _spec(name, 64)
    best = model.choose_best(
        spec, DEFAULT_FPGA, pe_res_override=PAPER_PE[name]
    )[0]
    got = (best.config.variant, best.config.k, best.config.s)
    assert got == PAPER_TABLE3_IT64[name]


def test_eq4_temporal_latency_exact():
    spec = _spec("jacobi2d", 64)
    cfg = ParallelismConfig("temporal", k=1, s=8)
    pred = model.predict_fpga(spec, cfg, DEFAULT_FPGA)
    R, C, U, d = 9720, 1024, 16, 2
    cycles = math.ceil((R + d * 7) * C / U) * math.ceil(64 / 8)
    assert pred.latency == pytest.approx(cycles / DEFAULT_FPGA.freq_hz)


def test_eq2_bandwidth_bound():
    # JACOBI2D: 2 banks per PE over 30 usable banks -> 15
    assert model.fpga_pe_bw(_spec("jacobi2d", 4), DEFAULT_FPGA) == 15
    # HOTSPOT: 3 banks per PE -> 10
    assert model.fpga_pe_bw(_spec("hotspot", 4), DEFAULT_FPGA) == 10


def test_spatial_s_linear_in_iter_spatial_r_superlinear():
    """Paper observation 1 (Sec. 4.2): L_ss grows exactly linearly with
    iter, L_sr slightly more than linearly."""
    spec1, spec8 = _spec("blur", 8), _spec("blur", 64)
    k = 12
    lss_1 = model.predict_fpga(spec1, ParallelismConfig("spatial_s", k=k), DEFAULT_FPGA).latency
    lss_8 = model.predict_fpga(spec8, ParallelismConfig("spatial_s", k=k), DEFAULT_FPGA).latency
    assert lss_8 == pytest.approx(8 * lss_1, rel=1e-6)
    lsr_1 = model.predict_fpga(spec1, ParallelismConfig("spatial_r", k=k), DEFAULT_FPGA).latency
    lsr_8 = model.predict_fpga(spec8, ParallelismConfig("spatial_r", k=k), DEFAULT_FPGA).latency
    assert lsr_8 > 8 * lsr_1


def test_tpu_fusion_reduces_memory_term():
    spec = _spec("jacobi2d", 16)
    tpu = DEFAULT_TPU.with_chips(8)
    p1 = model.predict_tpu(spec, ParallelismConfig("hybrid_s", k=8, s=1), tpu)
    p4 = model.predict_tpu(spec, ParallelismConfig("hybrid_s", k=8, s=4), tpu)
    assert p4.memory_term < p1.memory_term / 2
    assert p4.flops > p1.flops  # trapezoid redundancy is the price


def test_tpu_spatial_s_collective_scales_with_iter():
    spec16, spec64 = _spec("jacobi2d", 16), _spec("jacobi2d", 64)
    tpu = DEFAULT_TPU.with_chips(8)
    c16 = model.predict_tpu(spec16, ParallelismConfig("spatial_s", k=8), tpu)
    c64 = model.predict_tpu(spec64, ParallelismConfig("spatial_s", k=8), tpu)
    assert c64.collective_bytes == pytest.approx(4 * c16.collective_bytes)


def test_tpu_candidates_respect_halo_constraint():
    spec = _spec("jacobi2d", 64)
    tpu = DEFAULT_TPU.with_chips(256)
    for pred in model.choose_best(spec, tpu):
        cfg = pred.config
        if cfg.variant in ("spatial_r", "hybrid_r") and cfg.k > 1:
            assert 64 * spec.radius <= math.ceil(9720 / cfg.k)


def test_vmem_limit_monotone_in_tile():
    spec = _spec("blur", 64)
    s_small = model.vmem_fusion_limit(spec, DEFAULT_TPU, 128)
    s_large = model.vmem_fusion_limit(spec, DEFAULT_TPU, 2048)
    assert s_small >= s_large >= 1


def test_best_config_beats_soda_at_low_iter():
    """The paper's headline: hybrid/spatial beats temporal-only at low iter."""
    spec = _spec("jacobi2d", 1)
    tpu = DEFAULT_TPU.with_chips(8)
    ranked = model.choose_best(spec, tpu)
    best = ranked[0]
    temporal = [p for p in ranked if p.config.variant == "temporal"][0]
    assert temporal.latency / best.latency > 3.0
    assert best.config.k > 1
