"""The jax-version shim: every shimmed API must work on the installed jax."""
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro import compat


def test_version_policy():
    assert compat.JAX_VERSION >= compat.MIN_SUPPORTED_JAX


def test_axis_size_is_concrete_under_shard_map():
    mesh = Mesh(np.array(jax.devices()[:1]), ("a",))

    def local(x):
        k = compat.axis_size("a")
        assert isinstance(k, int), type(k)  # concrete: usable in range()
        return x * k

    out = compat.shard_map(
        local, mesh=mesh, in_specs=(P("a"),), out_specs=P("a")
    )(jnp.ones((4,)))
    np.testing.assert_array_equal(np.asarray(out), np.ones(4))


def test_shard_map_accepts_both_rep_flag_spellings():
    mesh = Mesh(np.array(jax.devices()[:1]), ("a",))
    x = jnp.arange(4.0)
    for kw in ({"check_vma": False}, {"check_rep": False}, {}):
        out = compat.shard_map(
            lambda v: v + 1, mesh=mesh, in_specs=(P("a"),),
            out_specs=P("a"), **kw
        )(x)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(x) + 1)


def test_pvary_is_identity_shaped():
    x = jnp.ones((3, 2))
    y = compat.pvary(x, ("a",)) if compat.JAX_VERSION < (0, 5) else x
    assert y.shape == x.shape


def test_element_block_spec_overlapping_windows():
    """Overlapping (stride < size) input blocks — the fused-kernel layout."""
    from jax.experimental import pallas as pl

    R, C, h, tile = 16, 8, 2, 4
    x = jnp.arange((R + 2 * h) * C, dtype=jnp.float32).reshape(R + 2 * h, C)

    def kernel(x_ref, o_ref):
        o_ref[...] = x_ref[...][h:h + tile]

    out = pl.pallas_call(
        kernel,
        grid=(R // tile,),
        in_specs=[compat.element_block_spec(
            (tile + 2 * h, C), lambda i: (i * tile, 0)
        )],
        out_specs=pl.BlockSpec((tile, C), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, C), jnp.float32),
        interpret=True,
    )(x)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(x[h:h + R]))
