"""Property-based tests (hypothesis) over the system's invariants."""
import numpy as np
import jax.numpy as jnp
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.configs import stencils
from repro.core import dsl, model
from repro.core import spec as spec_mod
from repro.core.platform import DEFAULT_TPU
from repro.kernels import ops, ref


@st.composite
def grids(draw, min_side=4, max_side=24):
    r = draw(st.integers(min_side, max_side))
    c = draw(st.integers(min_side, max_side))
    return (r, c)


@settings(max_examples=25, deadline=None)
@given(shape=grids(), iters=st.integers(1, 5), seed=st.integers(0, 2**31 - 1))
def test_linearity_of_linear_stencils(shape, iters, seed):
    """JACOBI2D is linear: F(a*x + b*y) == a*F(x) + b*F(y)."""
    spec = stencils.jacobi2d(shape=shape, iterations=iters)
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal(shape).astype(np.float32))
    y = jnp.asarray(rng.standard_normal(shape).astype(np.float32))
    a, b = 2.0, -0.5
    lhs = ref.stencil_iterations_ref(spec, {"in_1": a * x + b * y}, iters)
    rhs = a * ref.stencil_iterations_ref(spec, {"in_1": x}, iters) + \
        b * ref.stencil_iterations_ref(spec, {"in_1": y}, iters)
    np.testing.assert_allclose(np.asarray(lhs), np.asarray(rhs), rtol=1e-4, atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(shape=grids(8, 20), iters=st.integers(1, 6),
       s=st.integers(1, 6), seed=st.integers(0, 2**31 - 1))
def test_fusion_depth_invariance(shape, iters, s, seed):
    """Fused execution must be independent of the fusion depth s."""
    spec = stencils.blur(shape=shape, iterations=iters)
    rng = np.random.default_rng(seed)
    arrays = {"in_1": jnp.asarray(rng.standard_normal(shape).astype(np.float32))}
    want = ref.stencil_iterations_ref(spec, arrays, iters)
    got = ops.stencil_run(spec, arrays, iters, s=s, backend="jnp")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(shape=grids(6, 16), seed=st.integers(0, 2**31 - 1))
def test_dilate_monotone_and_idempotent_on_flat(shape, seed):
    """max-stencil invariants: output >= centre input (for >=0 inputs)."""
    spec = stencils.dilate(shape=shape, iterations=1)
    rng = np.random.default_rng(seed)
    x = jnp.asarray(np.abs(rng.standard_normal(shape)).astype(np.float32))
    out = ref.stencil_iterations_ref(spec, {"in_1": x}, 1)
    assert bool(jnp.all(out >= x))


@settings(max_examples=30, deadline=None)
@given(it=st.integers(1, 64), chips=st.sampled_from([1, 4, 8, 16, 64, 256]))
def test_model_latency_positive_and_bounded(it, chips):
    spec = stencils.jacobi2d(shape=(4096, 1024), iterations=it)
    tpu = DEFAULT_TPU.with_chips(chips)
    preds = model.choose_best(spec, tpu)
    assert preds, "candidate set must never be empty"
    for p in preds:
        assert p.latency > 0 and np.isfinite(p.latency)
        assert p.compute_term >= 0 and p.memory_term > 0
        assert p.rounds >= 1
    # more chips can never make the best latency worse
    if chips > 1:
        solo = model.choose_best(spec, DEFAULT_TPU.with_chips(1))[0]
        assert preds[0].latency <= solo.latency * 1.01


@settings(max_examples=25, deadline=None)
@given(it=st.integers(1, 64))
def test_intensity_linear_in_iterations(it):
    """Fig. 1b: computation intensity grows linearly with iterations."""
    spec = stencils.jacobi2d(iterations=it)
    assert spec.computation_intensity(it) == it * spec.computation_intensity(1)


@settings(max_examples=40, deadline=None)
@given(
    name=st.sampled_from(sorted(stencils.BENCHMARKS)),
    shape=grids(8, 40),
    iters=st.integers(1, 64),
    boundary=st.one_of(
        st.sampled_from(["zero", "replicate", "periodic"]).map(
            lambda k: spec_mod.Boundary(k)
        ),
        st.floats(-10, 10, allow_nan=False).map(
            lambda v: spec_mod.Boundary("constant", float(v))
        ),
    ),
)
def test_format_spec_parse_roundtrip_property(name, shape, iters, boundary):
    """parse(format_spec(spec)) is the identity over every stock kernel,
    randomized across shapes, iteration counts, and boundary rules."""
    import dataclasses

    full = (shape[0], shape[1], 8) if name in stencils.BENCHMARKS_3D \
        else shape
    spec = dataclasses.replace(
        stencils.get(name, shape=full, iterations=iters), boundary=boundary
    )
    assert dsl.parse(dsl.format_spec(spec)) == spec


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), shape=grids(6, 14))
def test_dsl_roundtrip_semantics(seed, shape):
    """Parsing an equivalent DSL permutation yields identical semantics."""
    rng = np.random.default_rng(seed)
    a = dsl.parse(f"""
kernel: A
iteration: 2
input float: x({shape[0]}, {shape[1]})
output float: o(0,0) = x(0,1) + x(1,0) * 2
""")
    b = dsl.parse(f"""
kernel: B
iteration: 2
input float: x({shape[0]}, {shape[1]})
output float: o(0,0) = (2 * x(1,0)) + x(0,1)
""")
    arrays = {"x": jnp.asarray(rng.standard_normal(shape).astype(np.float32))}
    np.testing.assert_allclose(
        np.asarray(ref.stencil_iterations_ref(a, arrays, 2)),
        np.asarray(ref.stencil_iterations_ref(b, arrays, 2)),
        rtol=1e-5, atol=1e-5,
    )
