"""Serving runtime: design cache semantics + batched execution correctness.

Single-device paths run in-process; the batched shard_map path is covered
by the 8-device subprocess checks in ``_multidevice_main.py``.
"""
import numpy as np
import pytest
import jax.numpy as jnp

from repro.configs import stencils
from repro.core import autotune, soda_baseline
from repro.core.model import ParallelismConfig
from repro.kernels import ref
from repro.runtime import (
    DegradedDesignWarning,
    DesignCache,
    build_batched_runner,
    devices_needed,
    spec_fingerprint,
)

RNG = np.random.default_rng(3)


def batch_for(spec, B):
    return {
        n: RNG.standard_normal((B,) + tuple(shape)).astype(dt)
        for n, (dt, shape) in spec.inputs.items()
    }


def per_grid_oracle(spec, arrays_b, iters, b):
    one = {n: jnp.asarray(a[b]) for n, a in arrays_b.items()}
    return np.asarray(ref.stencil_iterations_ref(spec, one, iters))


# ---------------------------------------------------------------------------
# batched execution
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name,shape", [
    ("jacobi2d", (24, 17)), ("hotspot", (24, 17)), ("heat3d", (16, 6, 6)),
])
@pytest.mark.parametrize("s", [1, 2])
def test_batched_single_pe_matches_oracle(name, shape, s):
    iters = 4
    spec = stencils.get(name, shape=shape, iterations=iters)
    cfg = ParallelismConfig("temporal", k=1, s=s)
    run = build_batched_runner(spec, cfg, tile_rows=8)
    arrays = batch_for(spec, B=3)
    out = run(arrays)
    assert out.shape == (3,) + tuple(shape)
    for b in range(3):
        np.testing.assert_allclose(
            out[b], per_grid_oracle(spec, arrays, iters, b),
            rtol=2e-4, atol=2e-4,
        )


def test_batched_pallas_backend_matches_oracle():
    iters = 3
    spec = stencils.jacobi2d(shape=(24, 17), iterations=iters)
    cfg = ParallelismConfig("temporal", k=1, s=3)
    run = build_batched_runner(
        spec, cfg, tile_rows=8, backend="pallas", interpret=True
    )
    arrays = batch_for(spec, B=2)
    out = run(arrays)
    for b in range(2):
        np.testing.assert_allclose(
            out[b], per_grid_oracle(spec, arrays, iters, b),
            rtol=2e-4, atol=2e-4,
        )


def test_batched_runner_rejects_bad_shapes():
    spec = stencils.jacobi2d(shape=(16, 8), iterations=2)
    run = build_batched_runner(spec, ParallelismConfig("temporal", k=1, s=2))
    with pytest.raises(ValueError, match="batched runner expects"):
        run({"in_1": np.zeros((16, 8), np.float32)})      # missing batch axis
    with pytest.raises(ValueError, match="batched runner expects"):
        run({"in_1": np.zeros((2, 8, 16), np.float32)})   # transposed grid


def test_batch_entries_are_independent():
    """Zero grids stay zero next to non-zero neighbours in the batch."""
    spec = stencils.jacobi2d(shape=(16, 8), iterations=3)
    run = build_batched_runner(spec, ParallelismConfig("temporal", k=1, s=3))
    arrays = batch_for(spec, B=3)
    arrays["in_1"][1] = 0.0
    out = run(arrays)
    np.testing.assert_array_equal(out[1], np.zeros((16, 8), np.float32))
    assert np.abs(out[0]).max() > 0


def test_devices_needed():
    assert devices_needed(ParallelismConfig("temporal", k=1, s=4)) == 4
    assert devices_needed(ParallelismConfig("spatial_s", k=8, s=1)) == 8
    assert devices_needed(ParallelismConfig("hybrid_s", k=2, s=3)) == 2


# ---------------------------------------------------------------------------
# degraded designs (device pool smaller than the config claims)
# ---------------------------------------------------------------------------


def test_degraded_design_warns_and_is_flagged():
    """hybrid_r(k=8) on a 1-device host must not *silently* degrade."""
    spec = stencils.jacobi2d(shape=(64, 8), iterations=2)
    cfg = ParallelismConfig("hybrid_r", k=8, s=2)
    with pytest.warns(DegradedDesignWarning, match="needs 8 device"):
        run = build_batched_runner(spec, cfg, tile_rows=8)
    assert run.degraded
    assert run.cfg.k == 8                 # the config still claims k=8 ...
    assert run.n_devices == 1             # ... but execution is single-PE
    assert run.devices_requested == 8
    arrays = batch_for(spec, B=2)
    out = run(arrays)                     # degraded, but still correct
    np.testing.assert_allclose(
        out[0], per_grid_oracle(spec, arrays, 2, 0), rtol=2e-4, atol=2e-4,
    )


def test_degraded_design_raises_under_strict():
    spec = stencils.jacobi2d(shape=(64, 8), iterations=2)
    cfg = ParallelismConfig("spatial_s", k=4, s=1)
    with pytest.raises(ValueError, match="needs 4 device"):
        build_batched_runner(spec, cfg, strict=True)


def test_strict_and_lax_callers_share_cache_entries():
    """strict only matters for degraded configs: on a feasible config a
    strict lookup must hit the entry a non-strict caller built."""
    cache = DesignCache()
    spec = stencils.jacobi2d(shape=(16, 8), iterations=2)
    cfg = ParallelismConfig("temporal", k=1, s=2)
    first = cache.runner(spec, cfg, tile_rows=8)
    misses = cache.misses
    again = cache.runner(spec, cfg, tile_rows=8, strict=True)
    assert again is first and cache.misses == misses
    # ... while a degraded config still refuses under strict, pre-cache
    bad = ParallelismConfig("hybrid_s", k=2, s=2)
    with pytest.raises(ValueError, match="needs 2 device"):
        cache.runner(spec, bad, tile_rows=8, strict=True)


def test_temporal_on_one_device_is_not_degraded():
    """The sanctioned degenerate case: a temporal cascade on one chip runs
    as fused rounds with the fusion depth (and the model's single-chip
    prediction) preserved — no warning, no degraded flag."""
    import warnings as _warnings

    spec = stencils.jacobi2d(shape=(16, 8), iterations=4)
    with _warnings.catch_warnings():
        _warnings.simplefilter("error", DegradedDesignWarning)
        run = build_batched_runner(
            spec, ParallelismConfig("temporal", k=1, s=4), tile_rows=8
        )
    assert not run.degraded


def test_batched_runner_rejects_unknown_inputs():
    """A typo'd array name must fail loudly, not serve garbage-by-omission."""
    spec = stencils.jacobi2d(shape=(16, 8), iterations=2)
    run = build_batched_runner(spec, ParallelismConfig("temporal", k=1, s=2))
    good = np.zeros((2, 16, 8), np.float32)
    with pytest.raises(ValueError, match="unknown input"):
        run({"in_1": good, "in_2": good})


def test_pool_change_rebuilds_degraded_runner(monkeypatch):
    """A runner cached while degraded (pool < config) must not be reused
    when the device pool grows: the actual device count is in the key."""
    import repro.runtime.cache as cache_mod

    cache = DesignCache()
    spec = stencils.jacobi2d(shape=(64, 8), iterations=2)
    cfg = ParallelismConfig("hybrid_s", k=2, s=2)
    with pytest.warns(DegradedDesignWarning):
        first = cache.runner(spec, cfg, tile_rows=8)   # degraded: 1 device
    assert first.degraded

    built = []

    def fake_build(spec_, cfg_, **kw):
        built.append(kw)
        return object()      # stand-in runner; never executed

    monkeypatch.setattr(cache_mod, "build_batched_runner", fake_build)
    # same pool: pure hit, no rebuild even through the fake builder
    again = cache.runner(spec, cfg, tile_rows=8)
    assert again is first and not built
    # pool grows to 2 devices: the degraded entry must NOT be served
    monkeypatch.setattr(
        cache_mod.jax, "devices", lambda: [object(), object()]
    )
    rebuilt = cache.runner(spec, cfg, tile_rows=8)
    assert len(built) == 1
    assert rebuilt is not first


# ---------------------------------------------------------------------------
# soda_baseline fallback behaviour
# ---------------------------------------------------------------------------


def test_soda_baseline_empty_candidates_raises(monkeypatch):
    import sys

    at = sys.modules["repro.core.autotune"]
    monkeypatch.setattr(at.model, "choose_best", lambda *a, **k: [])
    spec = stencils.jacobi2d(shape=(16, 8), iterations=2)
    with pytest.raises(RuntimeError, match="no temporal candidate"):
        soda_baseline(spec)


def test_soda_baseline_retries_infeasible_configs(monkeypatch):
    """An infeasible top temporal config must fall back to the next
    candidate, mirroring autotune()'s retry loop."""
    import sys

    at = sys.modules["repro.core.autotune"]
    spec = stencils.jacobi2d(shape=(20, 10), iterations=4)
    real = at.build_runner
    calls = {"n": 0}

    def flaky(spec_, cfg, **kw):
        calls["n"] += 1
        if calls["n"] == 1:
            raise ValueError("synthetic infeasible temporal config")
        return real(spec_, cfg, **kw)

    monkeypatch.setattr(at, "build_runner", flaky)
    design = soda_baseline(spec, tile_rows=8)
    assert calls["n"] == 2                 # first failed, second built
    assert design.config.variant == "temporal"
    assert design.config == design.ranking[1].config
    x = RNG.standard_normal((20, 10)).astype(np.float32)
    want = np.asarray(
        ref.stencil_iterations_ref(spec, {"in_1": jnp.asarray(x)}, 4)
    )
    np.testing.assert_allclose(
        design.runner({"in_1": x}), want, rtol=2e-4, atol=2e-4
    )


def test_soda_baseline_all_infeasible_raises(monkeypatch):
    import sys

    at = sys.modules["repro.core.autotune"]

    def broken(*a, **k):
        raise ValueError("synthetic: nothing fits")

    monkeypatch.setattr(at, "build_runner", broken)
    spec = stencils.jacobi2d(shape=(16, 8), iterations=2)
    with pytest.raises(RuntimeError, match="no feasible temporal"):
        soda_baseline(spec)


def test_soda_baseline_build_false_skips_executor():
    spec = stencils.jacobi2d(shape=(16, 8), iterations=2)
    design = soda_baseline(spec, build=False)
    assert design.runner is None
    assert design.config.variant == "temporal"


# ---------------------------------------------------------------------------
# design cache
# ---------------------------------------------------------------------------


def test_spec_fingerprint_stable_and_discriminating():
    a = stencils.jacobi2d(shape=(16, 8), iterations=2)
    b = stencils.jacobi2d(shape=(16, 8), iterations=2)
    c = stencils.jacobi2d(shape=(16, 9), iterations=2)
    assert spec_fingerprint(a) == spec_fingerprint(b)
    assert spec_fingerprint(a) != spec_fingerprint(c)


def test_cache_hit_skips_rebuild():
    cache = DesignCache()
    spec = stencils.jacobi2d(shape=(16, 8), iterations=2)
    c1 = cache.get_or_build(spec)
    misses_after_first = cache.misses
    c2 = cache.get_or_build(spec)
    assert not c1.hit and c2.hit
    assert c2.runner is c1.runner
    assert cache.misses == misses_after_first  # nothing rebuilt
    assert cache.hits > 0


def test_cache_distinguishes_specs_and_options():
    cache = DesignCache()
    a = stencils.jacobi2d(shape=(16, 8), iterations=2)
    b = stencils.jacobi2d(shape=(24, 8), iterations=2)
    ra = cache.get_or_build(a).runner
    rb = cache.get_or_build(b).runner
    assert ra is not rb
    ra2 = cache.get_or_build(a, tile_rows=16).runner
    assert ra2 is not ra  # different execution options -> different runner


def test_infeasible_configs_are_memoized(monkeypatch):
    """A ValueError-raising config must not cost a rebuild attempt (or a
    cache miss) on repeat calls — hit stays True for identical lookups."""
    import repro.runtime.cache as cache_mod

    cache = DesignCache()
    spec = stencils.jacobi2d(shape=(16, 8), iterations=2)
    real = cache_mod.build_batched_runner
    calls = {"n": 0}

    def flaky_build(spec_, cfg, **kw):
        calls["n"] += 1
        if calls["n"] == 1:
            raise ValueError("synthetic infeasible top config")
        return real(spec_, cfg, **kw)

    monkeypatch.setattr(cache_mod, "build_batched_runner", flaky_build)
    c1 = cache.get_or_build(spec)            # top config "fails", next builds
    assert not c1.hit
    builds_after_first = calls["n"]
    c2 = cache.get_or_build(spec)            # both levels + the failure memo
    assert c2.hit
    assert calls["n"] == builds_after_first  # no re-attempt of the failure


def test_cached_design_runs_correctly():
    cache = DesignCache()
    iters = 3
    spec = stencils.jacobi2d(shape=(20, 10), iterations=iters)
    cached = cache.get_or_build(spec)
    arrays = batch_for(spec, B=2)
    out = cached.runner(arrays)
    for b in range(2):
        np.testing.assert_allclose(
            out[b], per_grid_oracle(spec, arrays, iters, b),
            rtol=2e-4, atol=2e-4,
        )


def test_autotune_cache_kwarg_reuses_runner():
    cache = DesignCache()
    spec = stencils.jacobi2d(shape=(20, 10), iterations=2)
    d1 = autotune(spec, cache=cache)
    d2 = autotune(spec, cache=cache)
    assert d2.runner is d1.runner
    assert d2.config == d1.config
    # the cached runner still honours the unbatched autotune contract
    x = RNG.standard_normal((20, 10)).astype(np.float32)
    want = np.asarray(ref.stencil_iterations_ref(spec, {"in_1": jnp.asarray(x)}, 2))
    np.testing.assert_allclose(d1.runner({"in_1": x}), want, rtol=2e-4, atol=2e-4)


def test_autotune_cache_build_false_caches_ranking():
    cache = DesignCache()
    spec = stencils.jacobi2d(shape=(20, 10), iterations=2)
    d1 = autotune(spec, cache=cache, build=False)
    assert d1.runner is None
    before = cache.misses
    d2 = autotune(spec, cache=cache, build=False)
    assert cache.misses == before
    assert d2.config == d1.config


# ---------------------------------------------------------------------------
# cache-level capacity management (max_designs LRU over compiled runners)
# ---------------------------------------------------------------------------


def test_max_designs_validation():
    with pytest.raises(ValueError, match="max_designs"):
        DesignCache(max_designs=0)


def test_max_designs_lru_evicts_and_rebuilds_on_rehit():
    """The shared cache itself is now capacity-managed: past the cap the
    least-recently-hit compiled runner is dropped, an evict-then-rehit is
    a rebuild miss on the same key, and counters record the churn."""
    cache = DesignCache(max_designs=1)
    a = stencils.jacobi2d(shape=(16, 8), iterations=2)
    b = stencils.jacobi2d(shape=(24, 8), iterations=2)
    ca = cache.get_or_build(a)
    assert cache.runner_evictions == 0
    cb = cache.get_or_build(b)            # evicts a's runner
    assert cache.runner_evictions == 1
    assert not ca.hit and not cb.hit
    # rankings stay cached, so the rehit re-jits but does not re-rank
    misses_before = cache.misses
    ca2 = cache.get_or_build(a)
    assert cache.runner_evictions == 2    # b evicted in turn
    assert not ca2.hit                    # the combined call was not free
    assert cache.misses == misses_before + 1   # exactly the runner rebuild
    # the rebuilt runner still serves traffic correctly
    arrays = batch_for(a, B=2)
    out = ca2.runner(arrays)
    for i in range(2):
        np.testing.assert_allclose(
            out[i], per_grid_oracle(a, arrays, 2, i), rtol=2e-4, atol=2e-4,
        )


def test_max_designs_lru_order_follows_hits():
    cache = DesignCache(max_designs=2)
    a = stencils.jacobi2d(shape=(16, 8), iterations=2)
    b = stencils.jacobi2d(shape=(24, 8), iterations=2)
    c = stencils.jacobi2d(shape=(32, 8), iterations=2)
    cache.get_or_build(a)
    cache.get_or_build(b)
    cache.get_or_build(a)                 # refresh a: now MRU
    cache.get_or_build(c)                 # evicts b, not a
    assert cache.runner_evictions == 1
    misses = cache.misses
    assert cache.get_or_build(a).hit      # still resident
    assert cache.misses == misses
    assert not cache.get_or_build(b).hit  # was evicted: rebuild


def test_max_designs_composes_with_bucketed_registrations():
    """Bucket-ladder eviction drops the registration's reference; the
    cache cap bounds the shared memoization underneath.  A bucketed rehit
    after cache eviction rebuilds instead of silently growing."""
    cache = DesignCache(max_designs=1)
    spec = stencils.jacobi2d(shape=(20, 13), iterations=2)
    bd = cache.bucketed(spec, tile_rows=8)
    bd.runner_for((20, 13))               # bucket (32, 16)
    bd2 = cache.bucketed(spec, tile_rows=8)
    bd2.runner_for((40, 40))              # bucket (64, 64): evicts the first
    assert cache.runner_evictions >= 1
    # the first registration still holds its compiled reference and serves
    arrays = {"in_1": RNG.standard_normal((1, 20, 13)).astype(np.float32)}
    out = bd.runner_for((20, 13)).runner(arrays)
    np.testing.assert_allclose(
        out[0],
        np.asarray(ref.stencil_iterations_ref(
            stencils.jacobi2d(shape=(20, 13), iterations=2),
            {"in_1": jnp.asarray(arrays["in_1"][0])}, 2,
        )),
        rtol=2e-4, atol=2e-4,
    )


def test_clear_resets_eviction_counter():
    cache = DesignCache(max_designs=1)
    cache.get_or_build(stencils.jacobi2d(shape=(16, 8), iterations=2))
    cache.get_or_build(stencils.jacobi2d(shape=(24, 8), iterations=2))
    assert cache.runner_evictions == 1
    cache.clear()
    assert cache.runner_evictions == 0 and len(cache) == 0
