"""Serving runtime: design cache semantics + batched execution correctness.

Single-device paths run in-process; the batched shard_map path is covered
by the 8-device subprocess checks in ``_multidevice_main.py``.
"""
import numpy as np
import pytest
import jax.numpy as jnp

from repro.configs import stencils
from repro.core import autotune
from repro.core.model import ParallelismConfig
from repro.kernels import ref
from repro.runtime import (
    DesignCache,
    build_batched_runner,
    devices_needed,
    spec_fingerprint,
)

RNG = np.random.default_rng(3)


def batch_for(spec, B):
    return {
        n: RNG.standard_normal((B,) + tuple(shape)).astype(dt)
        for n, (dt, shape) in spec.inputs.items()
    }


def per_grid_oracle(spec, arrays_b, iters, b):
    one = {n: jnp.asarray(a[b]) for n, a in arrays_b.items()}
    return np.asarray(ref.stencil_iterations_ref(spec, one, iters))


# ---------------------------------------------------------------------------
# batched execution
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name,shape", [
    ("jacobi2d", (24, 17)), ("hotspot", (24, 17)), ("heat3d", (16, 6, 6)),
])
@pytest.mark.parametrize("s", [1, 2])
def test_batched_single_pe_matches_oracle(name, shape, s):
    iters = 4
    spec = stencils.get(name, shape=shape, iterations=iters)
    cfg = ParallelismConfig("temporal", k=1, s=s)
    run = build_batched_runner(spec, cfg, tile_rows=8)
    arrays = batch_for(spec, B=3)
    out = run(arrays)
    assert out.shape == (3,) + tuple(shape)
    for b in range(3):
        np.testing.assert_allclose(
            out[b], per_grid_oracle(spec, arrays, iters, b),
            rtol=2e-4, atol=2e-4,
        )


def test_batched_pallas_backend_matches_oracle():
    iters = 3
    spec = stencils.jacobi2d(shape=(24, 17), iterations=iters)
    cfg = ParallelismConfig("temporal", k=1, s=3)
    run = build_batched_runner(
        spec, cfg, tile_rows=8, backend="pallas", interpret=True
    )
    arrays = batch_for(spec, B=2)
    out = run(arrays)
    for b in range(2):
        np.testing.assert_allclose(
            out[b], per_grid_oracle(spec, arrays, iters, b),
            rtol=2e-4, atol=2e-4,
        )


def test_batched_runner_rejects_bad_shapes():
    spec = stencils.jacobi2d(shape=(16, 8), iterations=2)
    run = build_batched_runner(spec, ParallelismConfig("temporal", k=1, s=2))
    with pytest.raises(ValueError, match="batched runner expects"):
        run({"in_1": np.zeros((16, 8), np.float32)})      # missing batch axis
    with pytest.raises(ValueError, match="batched runner expects"):
        run({"in_1": np.zeros((2, 8, 16), np.float32)})   # transposed grid


def test_batch_entries_are_independent():
    """Zero grids stay zero next to non-zero neighbours in the batch."""
    spec = stencils.jacobi2d(shape=(16, 8), iterations=3)
    run = build_batched_runner(spec, ParallelismConfig("temporal", k=1, s=3))
    arrays = batch_for(spec, B=3)
    arrays["in_1"][1] = 0.0
    out = run(arrays)
    np.testing.assert_array_equal(out[1], np.zeros((16, 8), np.float32))
    assert np.abs(out[0]).max() > 0


def test_devices_needed():
    assert devices_needed(ParallelismConfig("temporal", k=1, s=4)) == 4
    assert devices_needed(ParallelismConfig("spatial_s", k=8, s=1)) == 8
    assert devices_needed(ParallelismConfig("hybrid_s", k=2, s=3)) == 2


# ---------------------------------------------------------------------------
# design cache
# ---------------------------------------------------------------------------


def test_spec_fingerprint_stable_and_discriminating():
    a = stencils.jacobi2d(shape=(16, 8), iterations=2)
    b = stencils.jacobi2d(shape=(16, 8), iterations=2)
    c = stencils.jacobi2d(shape=(16, 9), iterations=2)
    assert spec_fingerprint(a) == spec_fingerprint(b)
    assert spec_fingerprint(a) != spec_fingerprint(c)


def test_cache_hit_skips_rebuild():
    cache = DesignCache()
    spec = stencils.jacobi2d(shape=(16, 8), iterations=2)
    c1 = cache.get_or_build(spec)
    misses_after_first = cache.misses
    c2 = cache.get_or_build(spec)
    assert not c1.hit and c2.hit
    assert c2.runner is c1.runner
    assert cache.misses == misses_after_first  # nothing rebuilt
    assert cache.hits > 0


def test_cache_distinguishes_specs_and_options():
    cache = DesignCache()
    a = stencils.jacobi2d(shape=(16, 8), iterations=2)
    b = stencils.jacobi2d(shape=(24, 8), iterations=2)
    ra = cache.get_or_build(a).runner
    rb = cache.get_or_build(b).runner
    assert ra is not rb
    ra2 = cache.get_or_build(a, tile_rows=16).runner
    assert ra2 is not ra  # different execution options -> different runner


def test_infeasible_configs_are_memoized(monkeypatch):
    """A ValueError-raising config must not cost a rebuild attempt (or a
    cache miss) on repeat calls — hit stays True for identical lookups."""
    import repro.runtime.cache as cache_mod

    cache = DesignCache()
    spec = stencils.jacobi2d(shape=(16, 8), iterations=2)
    real = cache_mod.build_batched_runner
    calls = {"n": 0}

    def flaky_build(spec_, cfg, **kw):
        calls["n"] += 1
        if calls["n"] == 1:
            raise ValueError("synthetic infeasible top config")
        return real(spec_, cfg, **kw)

    monkeypatch.setattr(cache_mod, "build_batched_runner", flaky_build)
    c1 = cache.get_or_build(spec)            # top config "fails", next builds
    assert not c1.hit
    builds_after_first = calls["n"]
    c2 = cache.get_or_build(spec)            # both levels + the failure memo
    assert c2.hit
    assert calls["n"] == builds_after_first  # no re-attempt of the failure


def test_cached_design_runs_correctly():
    cache = DesignCache()
    iters = 3
    spec = stencils.jacobi2d(shape=(20, 10), iterations=iters)
    cached = cache.get_or_build(spec)
    arrays = batch_for(spec, B=2)
    out = cached.runner(arrays)
    for b in range(2):
        np.testing.assert_allclose(
            out[b], per_grid_oracle(spec, arrays, iters, b),
            rtol=2e-4, atol=2e-4,
        )


def test_autotune_cache_kwarg_reuses_runner():
    cache = DesignCache()
    spec = stencils.jacobi2d(shape=(20, 10), iterations=2)
    d1 = autotune(spec, cache=cache)
    d2 = autotune(spec, cache=cache)
    assert d2.runner is d1.runner
    assert d2.config == d1.config
    # the cached runner still honours the unbatched autotune contract
    x = RNG.standard_normal((20, 10)).astype(np.float32)
    want = np.asarray(ref.stencil_iterations_ref(spec, {"in_1": jnp.asarray(x)}, 2))
    np.testing.assert_allclose(d1.runner({"in_1": x}), want, rtol=2e-4, atol=2e-4)


def test_autotune_cache_build_false_caches_ranking():
    cache = DesignCache()
    spec = stencils.jacobi2d(shape=(20, 10), iterations=2)
    d1 = autotune(spec, cache=cache, build=False)
    assert d1.runner is None
    before = cache.misses
    d2 = autotune(spec, cache=cache, build=False)
    assert cache.misses == before
    assert d2.config == d1.config
